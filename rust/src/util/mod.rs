//! Shared substrates built in-tree because the offline environment vendors
//! only the `xla` dependency closure (no serde / clap / rand / criterion /
//! tokio / proptest). See DESIGN.md §4 row 10.

pub mod bench;
pub mod cli;
pub mod csv;
pub mod json;
pub mod rng;
pub mod stats;
pub mod table;
