//! Deterministic pseudo-random number generation.
//!
//! The offline build has no `rand` crate, so this module provides the two
//! generators the repo needs:
//!
//! * [`SplitMix64`] — tiny, stateless-feelings hash/stream generator; used to
//!   derive deterministic "measurement noise" in the GPU simulator from a
//!   `(gpu, m, n, k)` key, and to seed [`Xoshiro256pp`].
//! * [`Xoshiro256pp`] — xoshiro256++ 1.0, the general-purpose generator for
//!   dataset shuffles, CV folds, synthetic data and property tests.
//!
//! Everything is explicitly seeded; nothing reads the OS entropy pool, so
//! every table in the paper reproduction is bit-identical run-to-run.

/// SplitMix64 (Steele, Lea, Flood 2014). Also usable as a mixing hash.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64 uniformly distributed bits.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform f64 in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        // 53 explicit mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }
}

/// One-shot stateless mix of an arbitrary key to 64 bits. Used to derive
/// deterministic per-case noise in `gpusim` without carrying RNG state.
pub fn mix64(key: u64) -> u64 {
    SplitMix64::new(key).next_u64()
}

/// Combine multiple key parts into one 64-bit hash (order-sensitive).
pub fn mix_parts(parts: &[u64]) -> u64 {
    let mut h = 0x51_7C_C1_B7_27_22_0A_95u64;
    for &p in parts {
        h = mix64(h ^ p.wrapping_mul(0x2545_F491_4F6C_DD1D));
    }
    h
}

/// xoshiro256++ 1.0 (Blackman & Vigna 2019).
#[derive(Debug, Clone)]
pub struct Xoshiro256pp {
    s: [u64; 4],
}

impl Xoshiro256pp {
    /// Seed via SplitMix64 as the authors recommend.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, bound) without modulo bias (Lemire's method).
    pub fn next_bounded(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "bound must be positive");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in [lo, hi) — convenience for index ranges.
    pub fn next_range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "empty range");
        lo + self.next_bounded((hi - lo) as u64) as usize
    }

    /// Standard normal via Box–Muller (two uniforms, one output kept).
    pub fn next_gaussian(&mut self) -> f64 {
        // Avoid ln(0).
        let u1 = (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64);
        let u1 = if u1 <= 0.0 { f64::MIN_POSITIVE } else { u1 };
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle in place.
    pub fn shuffle<T>(&mut self, slice: &mut [T]) {
        for i in (1..slice.len()).rev() {
            let j = self.next_bounded((i + 1) as u64) as usize;
            slice.swap(i, j);
        }
    }

    /// A shuffled index permutation [0, n).
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx
    }

    /// Bernoulli draw.
    pub fn next_bool(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector() {
        // Reference values for seed 1234567 (from the public-domain C impl).
        let mut sm = SplitMix64::new(1234567);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // Determinism: same seed, same stream.
        let mut sm2 = SplitMix64::new(1234567);
        assert_eq!(a, sm2.next_u64());
        assert_eq!(b, sm2.next_u64());
    }

    #[test]
    fn mix_parts_is_order_sensitive() {
        assert_ne!(mix_parts(&[1, 2, 3]), mix_parts(&[3, 2, 1]));
        assert_eq!(mix_parts(&[1, 2, 3]), mix_parts(&[1, 2, 3]));
    }

    #[test]
    fn xoshiro_deterministic_and_well_spread() {
        let mut a = Xoshiro256pp::new(42);
        let mut b = Xoshiro256pp::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Xoshiro256pp::new(43);
        let same = (0..100).filter(|_| a.next_u64() == c.next_u64()).count();
        assert!(same < 3, "different seeds should diverge");
    }

    #[test]
    fn uniform_f64_in_unit_interval_and_roughly_uniform() {
        let mut r = Xoshiro256pp::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean} too far from 0.5");
    }

    #[test]
    fn bounded_draws_stay_in_bounds_and_hit_all_values() {
        let mut r = Xoshiro256pp::new(11);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.next_bounded(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Xoshiro256pp::new(13);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.next_gaussian();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Xoshiro256pp::new(3);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>(), "shuffle should move things");
    }

    #[test]
    fn permutation_covers_range() {
        let mut r = Xoshiro256pp::new(5);
        let p = r.permutation(257);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..257).collect::<Vec<_>>());
    }
}
