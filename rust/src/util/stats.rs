//! Descriptive statistics and histograms for the experiment harness.
//!
//! The paper reports ratio histograms (Figs 1, 3, 6), averages and extrema
//! (Table VIII) and latency percentiles (serving example); this module is
//! the shared vocabulary for all of them.

/// Basic summary of a sample: n, mean, std, min, max.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        if xs.is_empty() {
            return Summary {
                n: 0,
                mean: f64::NAN,
                std: f64::NAN,
                min: f64::NAN,
                max: f64::NAN,
            };
        }
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let (mut min, mut max) = (f64::INFINITY, f64::NEG_INFINITY);
        for &x in xs {
            min = min.min(x);
            max = max.max(x);
        }
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min,
            max,
        }
    }
}

/// Mean of a slice; NaN on empty.
pub fn mean(xs: &[f64]) -> f64 {
    Summary::of(xs).mean
}

/// Linear-interpolation percentile, `p` in [0, 100]. NaN on empty input.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = rank - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

/// Fraction of samples satisfying a predicate.
pub fn fraction_where(xs: &[f64], pred: impl Fn(f64) -> bool) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().filter(|&&x| pred(x)).count() as f64 / xs.len() as f64
}

/// A fixed-bin histogram in the paper's style: uniform bins over
/// `[lo, hi)` plus a final overflow bin `>= hi` (the "2.0+" bar).
#[derive(Debug, Clone)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub width: f64,
    /// counts[0..nbins] are the uniform bins; counts[nbins] is overflow.
    pub counts: Vec<usize>,
    pub underflow: usize,
    pub total: usize,
}

impl Histogram {
    /// `nbins` uniform bins over [lo, hi) + one overflow bin.
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Histogram {
        assert!(hi > lo && nbins > 0);
        Histogram {
            lo,
            hi,
            width: (hi - lo) / nbins as f64,
            counts: vec![0; nbins + 1],
            underflow: 0,
            total: 0,
        }
    }

    pub fn nbins(&self) -> usize {
        self.counts.len() - 1
    }

    pub fn add(&mut self, x: f64) {
        self.total += 1;
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            let last = self.counts.len() - 1;
            self.counts[last] += 1;
        } else {
            let i = ((x - self.lo) / self.width) as usize;
            let i = i.min(self.nbins() - 1); // guard fp edge
            self.counts[i] += 1;
        }
    }

    pub fn add_all(&mut self, xs: &[f64]) {
        for &x in xs {
            self.add(x);
        }
    }

    /// Frequency (fraction of total) of each bin, overflow last.
    pub fn frequencies(&self) -> Vec<f64> {
        if self.total == 0 {
            return vec![0.0; self.counts.len()];
        }
        self.counts
            .iter()
            .map(|&c| c as f64 / self.total as f64)
            .collect()
    }

    /// Bin labels like "0.6", "0.8", ..., "2.0+" matching the paper's axes.
    pub fn labels(&self) -> Vec<String> {
        let mut out: Vec<String> = (0..self.nbins())
            .map(|i| format!("{:.1}", self.lo + (i as f64 + 1.0) * self.width))
            .collect();
        out.push(format!("{:.1}+", self.hi));
        out
    }

    /// Render as an ASCII bar chart (one row per bin), the repo's stand-in
    /// for the paper's matplotlib figures.
    pub fn render(&self, title: &str) -> String {
        let freqs = self.frequencies();
        let labels = self.labels();
        let maxf = freqs.iter().cloned().fold(0.0, f64::max).max(1e-12);
        let mut out = format!("{title}  (n={})\n", self.total);
        for (label, f) in labels.iter().zip(&freqs) {
            let bar_len = ((f / maxf) * 50.0).round() as usize;
            out.push_str(&format!(
                "  {label:>6} | {:<50} {:5.1}%\n",
                "#".repeat(bar_len),
                f * 100.0
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.n, 4);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!((s.std - (1.25f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn summary_empty_is_nan() {
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
        assert!(s.mean.is_nan());
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 100.0), 40.0);
        assert!((percentile(&xs, 50.0) - 25.0).abs() < 1e-12);
        // unsorted input works too
        let ys = [40.0, 10.0, 30.0, 20.0];
        assert!((percentile(&ys, 50.0) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn fraction_where_counts() {
        let xs = [0.5, 1.5, 2.5, 3.5];
        assert!((fraction_where(&xs, |x| x > 1.0) - 0.75).abs() < 1e-12);
    }

    #[test]
    fn histogram_binning_matches_paper_axes() {
        // Paper Fig 1 style: bins of width 0.1 from 0.6 to 2.0 plus "2.0+".
        let mut h = Histogram::new(0.6, 2.0, 14);
        h.add(0.65); // bin 0
        h.add(1.05); // bin 4
        h.add(2.0); // overflow
        h.add(5.0); // overflow
        h.add(0.1); // underflow
        assert_eq!(h.counts[0], 1);
        assert_eq!(h.counts[4], 1);
        assert_eq!(*h.counts.last().unwrap(), 2);
        assert_eq!(h.underflow, 1);
        assert_eq!(h.total, 5);
        assert_eq!(h.labels().last().unwrap(), "2.0+");
    }

    #[test]
    fn histogram_edge_values() {
        let mut h = Histogram::new(0.0, 1.0, 10);
        h.add(0.0);
        h.add(0.999999999);
        h.add(1.0);
        assert_eq!(h.counts[0], 1);
        assert_eq!(h.counts[9], 1);
        assert_eq!(*h.counts.last().unwrap(), 1);
    }

    #[test]
    fn histogram_render_contains_bars() {
        let mut h = Histogram::new(0.0, 2.0, 4);
        h.add_all(&[0.1, 0.2, 1.9, 3.0]);
        let text = h.render("test");
        assert!(text.contains("test"));
        assert!(text.contains('#'));
        assert!(text.contains("2.0+"));
    }

    #[test]
    fn frequencies_sum_to_one_ignoring_underflow() {
        let mut h = Histogram::new(0.0, 1.0, 5);
        for i in 0..100 {
            h.add(i as f64 / 100.0);
        }
        let sum: f64 = h.frequencies().iter().sum();
        assert!((sum - 1.0).abs() < 1e-9);
    }
}
