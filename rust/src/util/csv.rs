//! Tiny CSV reader/writer for dataset and result persistence.
//!
//! Scope: comma-separated, first row is a header, fields may be quoted with
//! `"` (doubling escapes the quote), no embedded newlines in unquoted
//! fields. This covers everything the repo writes; it is not a general
//! dialect-sniffing CSV engine.

use std::fmt::Write as _;
use std::path::Path;

/// An in-memory CSV table: header + rows of strings.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct CsvTable {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl CsvTable {
    pub fn new(header: &[&str]) -> Self {
        Self {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Index of a column by name.
    pub fn col(&self, name: &str) -> Option<usize> {
        self.header.iter().position(|h| h == name)
    }

    /// Push a row of displayable values; panics if arity mismatches.
    pub fn push_row(&mut self, fields: Vec<String>) {
        assert_eq!(
            fields.len(),
            self.header.len(),
            "row arity {} != header arity {}",
            fields.len(),
            self.header.len()
        );
        self.rows.push(fields);
    }

    /// Fetch field by (row, column name); None if the column is unknown.
    pub fn get(&self, row: usize, name: &str) -> Option<&str> {
        let c = self.col(name)?;
        self.rows.get(row).map(|r| r[c].as_str())
    }

    /// Typed fetch helper.
    pub fn get_f64(&self, row: usize, name: &str) -> Option<f64> {
        self.get(row, name)?.parse().ok()
    }

    pub fn get_usize(&self, row: usize, name: &str) -> Option<usize> {
        self.get(row, name)?.parse().ok()
    }

    // ---- serialization ----------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        write_record(&mut out, &self.header);
        for row in &self.rows {
            write_record(&mut out, row);
        }
        out
    }

    pub fn save(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_string())
    }

    pub fn load(path: impl AsRef<Path>) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(&path).map_err(|e| {
            anyhow::anyhow!("reading {}: {e}", path.as_ref().display())
        })?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> anyhow::Result<Self> {
        let mut records = parse_records(text)?;
        if records.is_empty() {
            anyhow::bail!("csv: empty input (no header)");
        }
        let header = records.remove(0);
        for (i, r) in records.iter().enumerate() {
            if r.len() != header.len() {
                anyhow::bail!(
                    "csv: row {} has {} fields, header has {}",
                    i + 1,
                    r.len(),
                    header.len()
                );
            }
        }
        Ok(Self {
            header,
            rows: records,
        })
    }
}

fn needs_quoting(field: &str) -> bool {
    field.contains(',') || field.contains('"') || field.contains('\n') || field.contains('\r')
}

fn write_record(out: &mut String, fields: &[String]) {
    for (i, f) in fields.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        if needs_quoting(f) {
            out.push('"');
            for c in f.chars() {
                if c == '"' {
                    out.push('"');
                }
                out.push(c);
            }
            out.push('"');
        } else {
            let _ = write!(out, "{f}");
        }
    }
    out.push('\n');
}

fn parse_records(text: &str) -> anyhow::Result<Vec<Vec<String>>> {
    let mut records = Vec::new();
    let mut field = String::new();
    let mut record: Vec<String> = Vec::new();
    let mut chars = text.chars().peekable();
    let mut in_quotes = false;
    let mut any = false;

    while let Some(c) = chars.next() {
        any = true;
        if in_quotes {
            match c {
                '"' => {
                    if chars.peek() == Some(&'"') {
                        chars.next();
                        field.push('"');
                    } else {
                        in_quotes = false;
                    }
                }
                c => field.push(c),
            }
        } else {
            match c {
                '"' => in_quotes = true,
                ',' => {
                    record.push(std::mem::take(&mut field));
                }
                '\r' => {
                    // swallow; \n handles the record break
                }
                '\n' => {
                    record.push(std::mem::take(&mut field));
                    records.push(std::mem::take(&mut record));
                }
                c => field.push(c),
            }
        }
    }
    if in_quotes {
        anyhow::bail!("csv: unterminated quoted field");
    }
    // Final record without trailing newline.
    if any && (!field.is_empty() || !record.is_empty()) {
        record.push(field);
        records.push(record);
    }
    Ok(records)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_simple() {
        let mut t = CsvTable::new(&["m", "n", "k", "label"]);
        t.push_row(vec!["128".into(), "256".into(), "512".into(), "-1".into()]);
        t.push_row(vec!["1024".into(), "1".into(), "2".into(), "1".into()]);
        let back = CsvTable::parse(&t.to_string()).unwrap();
        assert_eq!(back, t);
        assert_eq!(back.get_usize(0, "k"), Some(512));
        assert_eq!(back.get_f64(1, "label"), Some(1.0));
    }

    #[test]
    fn quoting_roundtrip() {
        let mut t = CsvTable::new(&["name", "note"]);
        t.push_row(vec!["a,b".into(), "says \"hi\"\nsecond line".into()]);
        let text = t.to_string();
        let back = CsvTable::parse(&text).unwrap();
        assert_eq!(back.get(0, "note"), Some("says \"hi\"\nsecond line"));
        assert_eq!(back.get(0, "name"), Some("a,b"));
    }

    #[test]
    fn crlf_and_missing_trailing_newline() {
        let t = CsvTable::parse("a,b\r\n1,2\r\n3,4").unwrap();
        assert_eq!(t.rows.len(), 2);
        assert_eq!(t.get(1, "b"), Some("4"));
    }

    #[test]
    fn arity_mismatch_rejected() {
        assert!(CsvTable::parse("a,b\n1,2,3\n").is_err());
        assert!(CsvTable::parse("").is_err());
    }

    #[test]
    fn unterminated_quote_rejected() {
        assert!(CsvTable::parse("a\n\"oops\n").is_err());
    }

    #[test]
    #[should_panic]
    fn push_row_arity_panics() {
        let mut t = CsvTable::new(&["a", "b"]);
        t.push_row(vec!["1".into()]);
    }

    #[test]
    fn save_and_load_tempfile() {
        let mut t = CsvTable::new(&["x"]);
        t.push_row(vec!["42".into()]);
        let path = std::env::temp_dir().join("mtnn_csv_test.csv");
        t.save(&path).unwrap();
        let back = CsvTable::load(&path).unwrap();
        assert_eq!(back, t);
        std::fs::remove_file(&path).ok();
    }
}
