//! Aligned ASCII table rendering — every paper table is printed through
//! this so bench output is uniform and diffable.

/// A simple column-aligned text table with a title row.
#[derive(Debug, Clone, Default)]
pub struct TextTable {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl TextTable {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, fields: Vec<String>) -> &mut Self {
        assert_eq!(fields.len(), self.header.len(), "table row arity");
        self.rows.push(fields);
        self
    }

    /// Render with per-column width = max cell width, ` | ` separators and
    /// a rule under the header.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.chars().count());
            }
        }
        let render_row = |cells: &[String]| -> String {
            let mut line = String::from("| ");
            for i in 0..ncol {
                let cell = &cells[i];
                let pad = widths[i] - cell.chars().count();
                line.push_str(cell);
                line.push_str(&" ".repeat(pad));
                line.push_str(" | ");
            }
            line.trim_end().to_string()
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        out.push_str(&render_row(&self.header));
        out.push('\n');
        let rule: String = widths
            .iter()
            .map(|w| format!("|{}", "-".repeat(w + 2)))
            .collect::<String>()
            + "|";
        out.push_str(&rule);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&render_row(row));
            out.push('\n');
        }
        out
    }

    /// Write to a results file (creating parent dirs) and also return text.
    pub fn save(&self, path: impl AsRef<std::path::Path>) -> std::io::Result<String> {
        let text = self.render();
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, &text)?;
        Ok(text)
    }
}

/// Format an f64 with fixed decimals; the shared number style of all tables.
pub fn fnum(x: f64, decimals: usize) -> String {
    if x.is_nan() {
        "-".to_string()
    } else {
        format!("{x:.decimals$}")
    }
}

/// Format a percentage ("54.03%").
pub fn fpct(x: f64, decimals: usize) -> String {
    if x.is_nan() {
        "-".to_string()
    } else {
        format!("{:.decimals$}%", x * 100.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_columns() {
        let mut t = TextTable::new("Table X", &["Metric", "GTX1080", "TitanX"]);
        t.row(vec!["MTNN vs NT".into(), "57.78".into(), "50.48".into()]);
        t.row(vec!["GOW_max".into(), "1439.39".into(), "957.44".into()]);
        let s = t.render();
        assert!(s.contains("== Table X =="));
        let lines: Vec<&str> = s.lines().collect();
        // All body lines have equal length (alignment check).
        let lens: Vec<usize> = lines[1..].iter().map(|l| l.chars().count()).collect();
        assert!(lens.windows(2).all(|w| w[0] == w[1]), "{s}");
    }

    #[test]
    #[should_panic]
    fn row_arity_checked() {
        let mut t = TextTable::new("t", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn number_formatting() {
        assert_eq!(fnum(1.23456, 2), "1.23");
        assert_eq!(fnum(f64::NAN, 2), "-");
        assert_eq!(fpct(0.5403, 2), "54.03%");
    }

    #[test]
    fn unicode_width_alignment() {
        let mut t = TextTable::new("", &["col"]);
        t.row(vec!["αβγ".into()]);
        t.row(vec!["abcdef".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(
            lines[0].chars().count(),
            lines[2].chars().count(),
            "greek letters should count as width 1:\n{s}"
        );
    }
}
