//! Minimal JSON parser / serializer (the offline build has no `serde`).
//!
//! Supports the full JSON grammar (RFC 8259) minus exotic corner cases we
//! never produce: surrogate-pair escapes are decoded, numbers are f64
//! (adequate for model weights, timings and shape integers ≤ 2^53).
//!
//! Used for: trained-model persistence (`ml`), the AOT artifact manifest
//! (`runtime`), and experiment result dumps.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use `BTreeMap` so serialization is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset for diagnostics.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---- constructors ----------------------------------------------------

    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    /// Builder-style insert; panics if self is not an object.
    pub fn set(mut self, key: &str, value: impl Into<Json>) -> Json {
        match &mut self {
            Json::Obj(m) => {
                m.insert(key.to_string(), value.into());
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    // ---- accessors -------------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 && n <= 9.0e15 {
                Some(n as usize)
            } else {
                None
            }
        })
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().and_then(|n| {
            if n.fract() == 0.0 && n.abs() <= 9.0e15 {
                Some(n as i64)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Object field lookup; `Json::Null` if absent or not an object.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(m) => m.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Array element; `Json::Null` out of range.
    pub fn at(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(a) => a.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    // ---- serialization ---------------------------------------------------

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty serialization with 2-space indent.
    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, level: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, level + 1);
                    v.write(out, indent, level + 1);
                }
                newline_indent(out, indent, level);
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, level + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, level + 1);
                }
                newline_indent(out, indent, level);
                out.push('}');
            }
        }
    }

    // ---- parsing ---------------------------------------------------------

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters after value"));
        }
        Ok(v)
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, level: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * level {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.is_nan() || n.is_infinite() {
        // JSON has no NaN/Inf; we encode as null (documented lossy case).
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() < 9.0e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        // {:?} on f64 prints the shortest representation that round-trips.
        out.push_str(&format!("{n:?}"));
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            msg: msg.to_string(),
            offset: self.pos,
        }
    }

    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, val: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let start = self.pos;
            // Fast path: copy a run of plain bytes.
            while let Some(&b) = self.bytes.get(self.pos) {
                if b == b'"' || b == b'\\' || b < 0x20 {
                    break;
                }
                self.pos += 1;
            }
            if self.pos > start {
                s.push_str(
                    std::str::from_utf8(&self.bytes[start..self.pos])
                        .map_err(|_| self.err("invalid utf-8"))?,
                );
            }
            match self.peek() {
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hi = self.hex4()?;
                            let cp = if (0xD800..0xDC00).contains(&hi) {
                                // Surrogate pair.
                                if self.peek() == Some(b'\\') {
                                    self.pos += 1;
                                    self.expect(b'u')?;
                                    let lo = self.hex4()?;
                                    if !(0xDC00..0xE000).contains(&lo) {
                                        return Err(self.err("invalid low surrogate"));
                                    }
                                    0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                                } else {
                                    return Err(self.err("lone high surrogate"));
                                }
                            } else {
                                hi
                            };
                            s.push(
                                char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(_) => return Err(self.err("control character in string")),
                None => return Err(self.err("unterminated string")),
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.peek().ok_or_else(|| self.err("bad \\u escape"))?;
            let d = (b as char)
                .to_digit(16)
                .ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
            self.pos += 1;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b) if b.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b) if b.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b) if b.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("invalid utf-8 in number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

// ---- From conversions ----------------------------------------------------

impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<f32> for Json {
    fn from(v: f32) -> Self {
        Json::Num(v as f64)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Self {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Self {
        Json::Num(v as f64)
    }
}
impl From<i32> for Json {
    fn from(v: i32) -> Self {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}
impl From<&[f64]> for Json {
    fn from(v: &[f64]) -> Self {
        Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for text in ["null", "true", "false", "0", "-1", "3.5", "1e3"] {
            let v = Json::parse(text).unwrap();
            let v2 = Json::parse(&v.to_string()).unwrap();
            assert_eq!(v, v2, "roundtrip {text}");
        }
    }

    #[test]
    fn parse_nested_structure() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").at(0).as_f64(), Some(1.0));
        assert_eq!(v.get("a").at(2).get("b").as_str(), Some("x"));
        assert_eq!(v.get("c"), &Json::Null);
        assert_eq!(v.get("missing"), &Json::Null);
    }

    #[test]
    fn string_escapes_roundtrip() {
        let s = "line\n\ttab \"quoted\" back\\slash \u{1F600} unicode\u{7}";
        let j = Json::Str(s.to_string());
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(back.as_str(), Some(s));
    }

    #[test]
    fn surrogate_pair_decoding() {
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("\u{1F600}"));
    }

    #[test]
    fn builder_and_lookup() {
        let j = Json::obj()
            .set("name", "gbdt")
            .set("depth", 8usize)
            .set("weights", vec![1.0f64, 2.0, 3.0]);
        assert_eq!(j.get("depth").as_usize(), Some(8));
        assert_eq!(j.get("weights").at(1).as_f64(), Some(2.0));
        let t = j.to_pretty();
        let back = Json::parse(&t).unwrap();
        assert_eq!(back, j);
    }

    #[test]
    fn numbers_roundtrip_precisely() {
        for &x in &[
            0.1,
            -2.5e-8,
            1.0 / 3.0,
            f64::MAX / 2.0,
            9007199254740991.0,
        ] {
            let t = Json::Num(x).to_string();
            let v = Json::parse(&t).unwrap();
            assert_eq!(v.as_f64(), Some(x), "text {t}");
        }
    }

    #[test]
    fn errors_carry_offsets() {
        let e = Json::parse("[1, 2,,]").unwrap_err();
        assert!(e.offset >= 6, "{e}");
        assert!(Json::parse("{\"a\": 1").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn deep_nesting_parses() {
        let depth = 200;
        let text = "[".repeat(depth) + &"]".repeat(depth);
        assert!(Json::parse(&text).is_ok());
    }

    #[test]
    fn nan_and_inf_serialize_as_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
    }
}
