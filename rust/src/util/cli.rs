//! Minimal command-line parsing (no `clap` offline).
//!
//! Grammar: `prog [subcommand] [--flag] [--key value]... [positional]...`
//! Flags may also be written `--key=value`. Unknown keys are collected and
//! reported by [`Args::finish`] so typos fail loudly.
//!
//! Ambiguity rule (no schema): a bare `--key` followed by a token that does
//! not start with `--` binds as a key/value pair. Boolean flags therefore
//! go last, before another `--option`, or use the explicit `--flag=true`.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub program: String,
    pub subcommand: Option<String>,
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
    consumed: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse from the process arguments. `expect_subcommand` controls
    /// whether the first bare word is treated as a subcommand.
    pub fn from_env(expect_subcommand: bool) -> Args {
        let argv: Vec<String> = std::env::args().collect();
        Self::parse(&argv, expect_subcommand)
    }

    pub fn parse(argv: &[String], expect_subcommand: bool) -> Args {
        let mut out = Args {
            program: argv.first().cloned().unwrap_or_default(),
            ..Default::default()
        };
        let mut i = 1;
        if expect_subcommand {
            if let Some(first) = argv.get(1) {
                if !first.starts_with("--") {
                    out.subcommand = Some(first.clone());
                    i = 2;
                }
            }
        }
        while i < argv.len() {
            let a = &argv[i];
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.values.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    out.values
                        .insert(stripped.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        out
    }

    fn mark(&self, key: &str) {
        self.consumed.borrow_mut().push(key.to_string());
    }

    /// String option with default.
    pub fn get(&self, key: &str, default: &str) -> String {
        self.mark(key);
        self.values
            .get(key)
            .cloned()
            .unwrap_or_else(|| default.to_string())
    }

    /// Optional string.
    pub fn opt(&self, key: &str) -> Option<String> {
        self.mark(key);
        self.values.get(key).cloned()
    }

    /// Parsed numeric option with default; panics with a clear message on
    /// malformed input (CLI misuse should fail fast, not silently default).
    pub fn get_num<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        self.mark(key);
        match self.values.get(key) {
            None => default,
            Some(v) => v
                .parse()
                .unwrap_or_else(|_| panic!("--{key}: cannot parse {v:?}")),
        }
    }

    /// Boolean flag (`--verbose`) or `--verbose=true/false`.
    pub fn flag(&self, key: &str) -> bool {
        self.mark(key);
        if self.flags.iter().any(|f| f == key) {
            return true;
        }
        matches!(
            self.values.get(key).map(String::as_str),
            Some("true") | Some("1") | Some("yes")
        )
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// Error if any provided `--key` was never consumed by the program —
    /// catches typos like `--estimtors 8`.
    pub fn finish(&self) -> anyhow::Result<()> {
        let consumed = self.consumed.borrow();
        let mut unknown: Vec<String> = Vec::new();
        for k in self.values.keys().chain(self.flags.iter()) {
            if !consumed.iter().any(|c| c == k) {
                unknown.push(format!("--{k}"));
            }
        }
        if unknown.is_empty() {
            Ok(())
        } else {
            anyhow::bail!("unknown options: {}", unknown.join(", "))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn parses_subcommand_kv_flags_positional() {
        let a = Args::parse(
            &argv("prog collect --gpu gtx1080 --cases=500 data.csv --verbose"),
            true,
        );
        assert_eq!(a.subcommand.as_deref(), Some("collect"));
        assert_eq!(a.get("gpu", "x"), "gtx1080");
        assert_eq!(a.get_num::<usize>("cases", 0), 500);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional(), &["data.csv".to_string()]);
        assert!(a.finish().is_ok());
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(&argv("prog"), false);
        assert_eq!(a.get("seed", "42"), "42");
        assert_eq!(a.get_num::<u64>("seed", 42), 42);
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn unknown_keys_detected() {
        let a = Args::parse(&argv("prog --good 1 --typo 2"), false);
        let _ = a.get_num::<usize>("good", 0);
        let err = a.finish().unwrap_err().to_string();
        assert!(err.contains("--typo"), "{err}");
    }

    #[test]
    #[should_panic(expected = "cannot parse")]
    fn malformed_number_panics() {
        let a = Args::parse(&argv("prog --n abc"), false);
        let _: usize = a.get_num("n", 0);
    }

    #[test]
    fn no_subcommand_when_flag_first() {
        let a = Args::parse(&argv("prog --x 1"), true);
        assert_eq!(a.subcommand, None);
        assert_eq!(a.get_num::<i64>("x", 0), 1);
    }

    #[test]
    fn boolean_via_equals() {
        let a = Args::parse(&argv("prog --fast=true --slow=false"), false);
        assert!(a.flag("fast"));
        assert!(!a.flag("slow"));
    }
}
