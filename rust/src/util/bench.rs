//! In-tree micro-benchmark harness (criterion is unavailable offline).
//!
//! Provides warmup + repeated timed runs with mean/std/percentiles, plus a
//! one-line report format shared by `rust/benches/*` and the §Perf pass.

use super::stats::{percentile, Summary};
use std::time::Instant;

/// Result of one benchmark: per-iteration wall times in nanoseconds.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub samples_ns: Vec<f64>,
}

impl BenchResult {
    pub fn mean_ns(&self) -> f64 {
        Summary::of(&self.samples_ns).mean
    }

    pub fn std_ns(&self) -> f64 {
        Summary::of(&self.samples_ns).std
    }

    pub fn p50_ns(&self) -> f64 {
        percentile(&self.samples_ns, 50.0)
    }

    pub fn p99_ns(&self) -> f64 {
        percentile(&self.samples_ns, 99.0)
    }

    /// "name  mean ± std  [p50 p99]  (n)" with human units.
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>12} ± {:>10}  p50 {:>12}  p99 {:>12}  n={}",
            self.name,
            human_ns(self.mean_ns()),
            human_ns(self.std_ns()),
            human_ns(self.p50_ns()),
            human_ns(self.p99_ns()),
            self.samples_ns.len()
        )
    }
}

/// Render nanoseconds with an adaptive unit.
pub fn human_ns(ns: f64) -> String {
    if ns.is_nan() {
        "-".into()
    } else if ns < 1e3 {
        format!("{ns:.1}ns")
    } else if ns < 1e6 {
        format!("{:.2}us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.3}ms", ns / 1e6)
    } else {
        format!("{:.3}s", ns / 1e9)
    }
}

/// Benchmark `f`, discarding `warmup` runs then timing `iters` runs.
/// `f` should return something observable to stop the optimizer from
/// deleting the body; the return value is black-boxed here.
pub fn bench<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> BenchResult {
    for _ in 0..warmup {
        black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        black_box(f());
        samples.push(t0.elapsed().as_nanos() as f64);
    }
    BenchResult {
        name: name.to_string(),
        samples_ns: samples,
    }
}

/// Benchmark where each timed sample runs `batch` calls (for sub-microsecond
/// bodies whose individual timing would be clock-noise dominated).
/// Reported samples are per-call (divided by `batch`).
pub fn bench_batched<T>(
    name: &str,
    warmup: usize,
    iters: usize,
    batch: usize,
    mut f: impl FnMut() -> T,
) -> BenchResult {
    for _ in 0..warmup {
        black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        for _ in 0..batch {
            black_box(f());
        }
        samples.push(t0.elapsed().as_nanos() as f64 / batch as f64);
    }
    BenchResult {
        name: name.to_string(),
        samples_ns: samples,
    }
}

/// Optimizer barrier (stable-rust version of `std::hint::black_box`).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_requested_samples() {
        let r = bench("noop", 2, 10, || 1 + 1);
        assert_eq!(r.samples_ns.len(), 10);
        assert!(r.mean_ns() >= 0.0);
        assert!(r.report().contains("noop"));
    }

    #[test]
    fn batched_bench_reports_per_call() {
        let r = bench_batched("add", 1, 5, 1000, || std::hint::black_box(3u64) * 7);
        assert_eq!(r.samples_ns.len(), 5);
        // Per-call cost of a multiply must be well under a microsecond.
        assert!(r.mean_ns() < 1e3, "mean {}ns", r.mean_ns());
    }

    #[test]
    fn timing_reflects_work() {
        let quick = bench("q", 1, 5, || 0u64);
        let slow = bench("s", 1, 5, || {
            let mut acc = 0u64;
            for i in 0..200_000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert!(
            slow.mean_ns() > quick.mean_ns(),
            "slow {} vs quick {}",
            slow.mean_ns(),
            quick.mean_ns()
        );
    }

    #[test]
    fn human_units() {
        assert!(human_ns(5.0).ends_with("ns"));
        assert!(human_ns(5.0e3).ends_with("us"));
        assert!(human_ns(5.0e6).ends_with("ms"));
        assert!(human_ns(5.0e9).ends_with('s'));
    }
}
