//! ASCII winner-grid rendering shared by Fig 2 and Fig 5: for each K, a
//! 10×10 (M × N) grid of symbols — the text analogue of the paper's
//! scatter plots. `#` = first algorithm wins ≥5%, `o` = second wins ≥5%,
//! `-` = within 5%, `.` = case excluded by the memory rule.

use crate::gpusim::SIZE_GRID;
use std::collections::HashMap;

/// Outcome of one grid cell.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Cell {
    FirstWins(f64),
    SecondWins(f64),
    Tie,
    Excluded,
}

/// Render the per-K grids. `cells` maps (m, n, k) → Cell.
pub fn render(
    title: &str,
    first: &str,
    second: &str,
    cells: &HashMap<(u64, u64, u64), Cell>,
) -> String {
    let mut out = format!(
        "== {title} ==\n  legend: '#' {first} wins, 'o' {second} wins, '-' tie(±5%), '.' OOM\n"
    );
    for &k in &SIZE_GRID {
        out.push_str(&format!("  K={k}\n       N: "));
        for (j, _) in SIZE_GRID.iter().enumerate() {
            out.push_str(&format!("2^{:<3}", 7 + j));
        }
        out.push('\n');
        for (i, &m) in SIZE_GRID.iter().enumerate() {
            out.push_str(&format!("  M=2^{:<2} | ", 7 + i));
            for &n in &SIZE_GRID {
                let c = cells.get(&(m, n, k)).copied().unwrap_or(Cell::Excluded);
                let ch = match c {
                    Cell::FirstWins(_) => '#',
                    Cell::SecondWins(_) => 'o',
                    Cell::Tie => '-',
                    Cell::Excluded => '.',
                };
                out.push_str(&format!("{ch}    "));
            }
            out.push('\n');
        }
    }
    out
}

/// Classify a performance pair into a cell with a ±5% tie band.
pub fn classify(p_first: f64, p_second: f64) -> Cell {
    let ratio = p_first / p_second;
    if ratio > 1.05 {
        Cell::FirstWins(ratio)
    } else if ratio < 1.0 / 1.05 {
        Cell::SecondWins(1.0 / ratio)
    } else {
        Cell::Tie
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classify_bands() {
        assert!(matches!(classify(2.0, 1.0), Cell::FirstWins(_)));
        assert!(matches!(classify(1.0, 2.0), Cell::SecondWins(_)));
        assert!(matches!(classify(1.0, 1.01), Cell::Tie));
    }

    #[test]
    fn render_contains_all_k_sections() {
        let mut cells = HashMap::new();
        cells.insert((128, 128, 128), Cell::FirstWins(2.0));
        let s = render("t", "NT", "TNN", &cells);
        for k in SIZE_GRID {
            assert!(s.contains(&format!("K={k}")), "missing K={k}");
        }
        assert!(s.contains('#'));
        assert!(s.contains('.'));
    }
}
