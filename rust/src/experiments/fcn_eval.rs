//! Fig 7 (MNIST FCN), Fig 8 (synthetic FCN) and Table X (forward/backward
//! breakdown) — the Caffe-integration evaluation of §VI.C, on the
//! simulated GPUs.

use crate::fcn::config::{mnist_configs, synthetic_configs, FcnConfig, MINI_BATCHES};
use crate::fcn::sim_trainer::{iteration_times, PhaseTimes, Policy};
use crate::gpusim::{GpuSpec, PAPER_GPUS};
use crate::selector::Selector;
use crate::util::csv::CsvTable;
use crate::util::table::{fnum, TextTable};

/// Fig 7 / Fig 8: per-iteration time of CaffeNT vs CaffeMTNN for every
/// (config, mini-batch) pair on one GPU.
pub fn fig(
    title: &str,
    configs: &[FcnConfig],
    gpu: &'static GpuSpec,
    selector: &Selector,
) -> (String, CsvTable) {
    let mut t = TextTable::new(
        title,
        &["network", "mb", "CaffeNT (ms)", "CaffeMTNN (ms)", "speedup"],
    );
    let mut csv = CsvTable::new(&["gpu", "network", "mb", "nt_ms", "mtnn_ms"]);
    for cfg in configs {
        for &mb in &MINI_BATCHES {
            let nt = iteration_times(gpu, None, &cfg.dims, mb, Policy::AlwaysNt);
            let mt = iteration_times(gpu, Some(selector), &cfg.dims, mb, Policy::Mtnn);
            t.row(vec![
                cfg.name.clone(),
                mb.to_string(),
                fnum(nt.total_ms(), 2),
                fnum(mt.total_ms(), 2),
                format!("{:.3}x", nt.total_ms() / mt.total_ms()),
            ]);
            csv.push_row(vec![
                gpu.name.into(),
                cfg.name.clone(),
                mb.to_string(),
                format!("{:.4}", nt.total_ms()),
                format!("{:.4}", mt.total_ms()),
            ]);
        }
    }
    (t.render(), csv)
}

/// Table X: average forward/backward/total times over all mini-batches and
/// layer counts, per dataset and GPU.
pub fn table10(selector: &Selector) -> String {
    let mut t = TextTable::new(
        "Table X — breakdown of average running time (ms) and speedups \
         (paper synthetic fwd speedups: 2.44x G.1080, 2.15x TitanX; bwd ~1.0)",
        &["Data set", "GPU", "Phase", "CaffeNT", "CaffeMTNN", "Speedup"],
    );
    for (ds_name, configs) in [
        ("MNIST", mnist_configs()),
        ("Synthetic", synthetic_configs()),
    ] {
        for gpu in PAPER_GPUS {
            let mut nt_sum = PhaseTimes::default();
            let mut mt_sum = PhaseTimes::default();
            let mut n = 0.0;
            for cfg in &configs {
                for &mb in &MINI_BATCHES {
                    let nt = iteration_times(gpu, None, &cfg.dims, mb, Policy::AlwaysNt);
                    let mt =
                        iteration_times(gpu, Some(selector), &cfg.dims, mb, Policy::Mtnn);
                    nt_sum.forward_ms += nt.forward_ms;
                    nt_sum.backward_ms += nt.backward_ms;
                    mt_sum.forward_ms += mt.forward_ms;
                    mt_sum.backward_ms += mt.backward_ms;
                    n += 1.0;
                }
            }
            let rows: [(&str, f64, f64); 3] = [
                ("Forward", nt_sum.forward_ms / n, mt_sum.forward_ms / n),
                ("Backward", nt_sum.backward_ms / n, mt_sum.backward_ms / n),
                (
                    "Total",
                    nt_sum.total_ms() / n,
                    mt_sum.total_ms() / n,
                ),
            ];
            for (phase, nt_ms, mt_ms) in rows {
                t.row(vec![
                    ds_name.into(),
                    gpu.name.into(),
                    phase.into(),
                    fnum(nt_ms, 2),
                    fnum(mt_ms, 2),
                    format!("{:.2}", nt_ms / mt_ms),
                ]);
            }
        }
    }
    t.render()
}

/// Table IX rendering (configuration constants, for completeness).
pub fn table9() -> String {
    let mut t = TextTable::new(
        "Table IX — FCN configurations",
        &["Data set", "network", "dims"],
    );
    for cfg in mnist_configs() {
        t.row(vec![
            "MNIST".into(),
            cfg.name.clone(),
            format!("{:?}", cfg.dims),
        ]);
    }
    for cfg in synthetic_configs() {
        t.row(vec![
            "Synthetic".into(),
            cfg.name.clone(),
            format!("{:?}", cfg.dims),
        ]);
    }
    t.render()
}

/// Summary statistic the paper quotes in the abstract: average MTNN
/// speedup over all (config, mb) pairs per dataset on a GPU.
pub fn avg_speedup(
    configs: &[FcnConfig],
    gpu: &'static GpuSpec,
    selector: &Selector,
) -> f64 {
    let mut sum = 0.0;
    let mut n = 0.0;
    for cfg in configs {
        for &mb in &MINI_BATCHES {
            let nt = iteration_times(gpu, None, &cfg.dims, mb, Policy::AlwaysNt);
            let mt = iteration_times(gpu, Some(selector), &cfg.dims, mb, Policy::Mtnn);
            sum += nt.total_ms() / mt.total_ms();
            n += 1.0;
        }
    }
    sum / n
}

/// Full §VI.C output.
pub fn run(selector: &Selector) -> String {
    let mut out = table9();
    out.push('\n');
    for gpu in PAPER_GPUS {
        let (f7, csv7) = fig(
            &format!("Fig 7 — MNIST FCN per-iteration time on {} (paper: ~parity, +1.74%)", gpu.name),
            &mnist_configs(),
            gpu,
            selector,
        );
        out.push_str(&f7);
        csv7.save(super::results_dir().join(format!("fig7_{}.csv", gpu.name)))
            .expect("save fig7");
        let (f8, csv8) = fig(
            &format!("Fig 8 — synthetic FCN per-iteration time on {} (paper: +28.2%)", gpu.name),
            &synthetic_configs(),
            gpu,
            selector,
        );
        out.push_str(&f8);
        csv8.save(super::results_dir().join(format!("fig8_{}.csv", gpu.name)))
            .expect("save fig8");
    }
    out.push_str(&table10(selector));
    for gpu in PAPER_GPUS {
        out.push_str(&format!(
            "\navg MTNN speedup on {}: MNIST {:.3}x (paper ~1.02x), synthetic {:.3}x (paper ~1.28x)",
            gpu.name,
            avg_speedup(&mnist_configs(), gpu, selector),
            avg_speedup(&synthetic_configs(), gpu, selector),
        ));
    }
    out.push('\n');
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::collect_paper_dataset;
    use crate::gpusim::GTX1080;
    use std::sync::OnceLock;

    fn selector() -> &'static Selector {
        static SEL: OnceLock<Selector> = OnceLock::new();
        SEL.get_or_init(|| Selector::train_default(&collect_paper_dataset()))
    }

    #[test]
    fn synthetic_speedup_exceeds_mnist_speedup() {
        // The paper's key contrast: big nets gain (28%), MNIST ~parity.
        let syn = avg_speedup(&synthetic_configs(), &GTX1080, selector());
        let mni = avg_speedup(&mnist_configs(), &GTX1080, selector());
        assert!(
            syn > mni + 0.05,
            "synthetic {syn:.3}x should clearly exceed MNIST {mni:.3}x"
        );
        assert!(syn > 1.08, "synthetic avg speedup {syn:.3}");
        assert!(mni > 0.97, "MNIST should not regress: {mni:.3}");
    }

    #[test]
    fn table10_backward_speedup_is_one() {
        let text = table10(selector());
        // All Backward rows must show speedup 1.00.
        for line in text.lines().filter(|l| l.contains("Backward")) {
            assert!(line.contains("1.00"), "{line}");
        }
    }

    #[test]
    fn fig_tables_cover_all_cells() {
        let (text, csv) = fig("t", &mnist_configs(), &GTX1080, selector());
        assert_eq!(csv.rows.len(), 3 * MINI_BATCHES.len());
        assert!(text.contains("mnist-4h"));
    }
}
