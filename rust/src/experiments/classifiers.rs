//! Table IV (5-fold CV per-class accuracy), Table VI (classifier
//! comparison incl. train/predict times) and Fig 4 (accuracy vs training
//! fraction) — the learning-side evaluation of §VI.A.

use crate::dataset::{collect_paper_dataset, to_ml_dataset};
use crate::ml::cv::{cross_validate, fold_stats};
use crate::ml::data::Dataset;
use crate::ml::gbdt::{Gbdt, GbdtParams};
use crate::ml::metrics::accuracy;
use crate::ml::scaler::MinMaxScaler;
use crate::ml::svm::{Svm, SvmParams};
use crate::ml::tree::DecisionTreeClassifier;
use crate::ml::Classifier;
use crate::util::csv::CsvTable;
use crate::util::table::{fnum, TextTable};
use std::time::Instant;

/// Table IV: 5-fold CV of the GBDT with per-class breakdown.
pub fn table4(data: &Dataset, seed: u64) -> (String, [f64; 3]) {
    let folds = cross_validate(data, 5, seed, || Gbdt::new(GbdtParams::default()));
    let mut t = TextTable::new(
        "Table IV — 5-fold CV accuracies (paper avg: neg 92.05, pos 88.39, total 90.51)",
        &["Class", "Minimum", "Maximum", "Average"],
    );
    let rows: [(&str, fn(&crate::ml::metrics::Accuracy) -> f64); 3] = [
        ("Negative", |a| a.negative),
        ("Positive", |a| a.positive),
        ("Total", |a| a.total),
    ];
    let mut avgs = [0.0; 3];
    for (i, (name, field)) in rows.iter().enumerate() {
        let (min, max, avg) = fold_stats(&folds, field);
        avgs[i] = avg;
        t.row(vec![
            name.to_string(),
            format!("{:.2}%", min * 100.0),
            format!("{:.2}%", max * 100.0),
            format!("{:.2}%", avg * 100.0),
        ]);
    }
    (t.render(), avgs)
}

/// One Table VI row: classifier name, accuracy, train ms, predict ms.
#[derive(Debug, Clone)]
pub struct ClassifierRow {
    pub name: String,
    pub accuracy: f64,
    pub train_ms: f64,
    pub predict_ms: f64,
}

fn time_classifier<C: Classifier>(
    mut model: C,
    train: &Dataset,
    test: &Dataset,
    scale: bool,
) -> ClassifierRow {
    let (train_x, test_x) = if scale {
        let scaler = MinMaxScaler::fit(&train.x);
        (scaler.transform(&train.x), scaler.transform(&test.x))
    } else {
        (train.x.clone(), test.x.clone())
    };
    let t0 = Instant::now();
    model.fit(&train_x, &train.y);
    let train_ms = t0.elapsed().as_secs_f64() * 1e3;
    // Predict-time is per single sample (the paper reports per-call
    // latency — 0.005 ms for GBDT), averaged over the test set.
    let t1 = Instant::now();
    let pred = model.predict(&test_x);
    let predict_ms = t1.elapsed().as_secs_f64() * 1e3 / test_x.len() as f64;
    ClassifierRow {
        name: model.name(),
        accuracy: accuracy(&pred, &test.y).total,
        train_ms,
        predict_ms,
    }
}

/// Table VI: GBDT vs SVM-RBF vs SVM-Poly vs DT on an 80/20 split.
pub fn table6(data: &Dataset, seed: u64) -> (String, Vec<ClassifierRow>) {
    let (train, test) = data.split_by_group(0.8, seed);
    let rows = vec![
        time_classifier(Gbdt::new(GbdtParams::default()), &train, &test, false),
        time_classifier(Svm::new(SvmParams::rbf()), &train, &test, true),
        time_classifier(Svm::new(SvmParams::poly()), &train, &test, true),
        time_classifier(DecisionTreeClassifier::default(), &train, &test, false),
    ];
    let mut t = TextTable::new(
        "Table VI — classifier comparison (paper: GBDT 90.51 / SVM-RBF 81.66 / SVM-Poly 77.68 / DT 87.84)",
        &["Classifier", "Accuracy (%)", "Train Time (ms)", "Predict Time (ms)"],
    );
    for r in &rows {
        t.row(vec![
            r.name.clone(),
            fnum(r.accuracy * 100.0, 2),
            fnum(r.train_ms, 1),
            fnum(r.predict_ms, 4),
        ]);
    }
    (t.render(), rows)
}

/// Fig 4: training accuracy (on ALL samples as test set, per the paper's
/// protocol) vs training fraction 10%..100% step 5.
pub fn fig4(data: &Dataset, seed: u64) -> (String, CsvTable) {
    let mut csv = CsvTable::new(&["train_pct", "accuracy"]);
    let mut out = String::from(
        "Fig 4 — training accuracy vs training-set size (paper: 96.39% at 100%)\n",
    );
    let mut final_acc = 0.0;
    for pct in (10..=100).step_by(5) {
        let (train, _) = data.split(pct as f64 / 100.0, seed);
        let mut g = Gbdt::new(GbdtParams::default());
        g.fit(&train.x, &train.y);
        let acc = accuracy(&g.predict(&data.x), &data.y).total;
        final_acc = acc;
        let bar = "#".repeat(((acc - 0.80).max(0.0) * 250.0) as usize);
        out.push_str(&format!("  {pct:>3}% | {bar:<50} {:.2}%\n", acc * 100.0));
        csv.push_row(vec![pct.to_string(), format!("{acc:.6}")]);
    }
    out.push_str(&format!(
        "  measured at 100%: {:.2}% (paper 96.39%)\n",
        final_acc * 100.0
    ));
    (out, csv)
}

/// Everything in §VI.A, on the standard dataset.
pub fn run(seed: u64) -> String {
    let data = to_ml_dataset(&collect_paper_dataset());
    let (t4, _) = table4(&data, seed);
    let (t6, _) = table6(&data, seed);
    let (f4, csv) = fig4(&data, seed);
    csv.save(super::results_dir().join("fig4_training_size.csv"))
        .expect("save fig4 csv");
    format!("{t4}\n{t6}\n{f4}")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_data() -> Dataset {
        // Down-sampled paper dataset for fast tests.
        let d = to_ml_dataset(&collect_paper_dataset());
        let idx: Vec<usize> = (0..d.len()).step_by(4).collect();
        d.subset(&idx)
    }

    #[test]
    fn table4_reports_three_classes() {
        let (text, avgs) = table4(&small_data(), 3);
        assert!(text.contains("Negative") && text.contains("Positive"));
        assert!(avgs[2] > 0.8, "total CV accuracy {avgs:?}");
    }

    #[test]
    fn table6_contains_all_classifiers() {
        let (text, rows) = table6(&small_data(), 3);
        for name in ["GBDT", "SVM-RBF", "SVM-Poly", "DT"] {
            assert!(text.contains(name), "{text}");
        }
        assert_eq!(rows.len(), 4);
        let gbdt = &rows[0];
        assert!(gbdt.predict_ms < 1.0, "GBDT predict {}ms", gbdt.predict_ms);
    }

    #[test]
    fn fig4_is_19_points() {
        let (_, csv) = fig4(&small_data(), 3);
        assert_eq!(csv.rows.len(), 19);
    }
}
