//! Fig 5 (NT-vs-MTNN winner grids), Fig 6 (P_MTNN/P_NT histogram) and
//! Table VIII (selection-quality metrics incl. GOW / LUB) — §VI.B.

use super::fig_grid::{classify, render, Cell};
use crate::dataset::{collect_gpu, Record};
use crate::gemm::Algorithm;
use crate::gpusim::{GpuSpec, Simulator, PAPER_GPUS, SIZE_GRID};
use crate::selector::Selector;
use crate::util::stats::{fraction_where, Histogram};
use crate::util::table::TextTable;
use std::collections::HashMap;

/// Per-GPU Table VIII metrics (all as fractions, not %).
#[derive(Debug, Clone, Copy, Default)]
pub struct SelectionMetrics {
    pub mtnn_vs_nt: f64,
    pub mtnn_vs_tnn: f64,
    pub gow_avg: f64,
    pub gow_max: f64,
    pub lub_avg: f64,
    pub lub_min: f64,
    pub n: usize,
}

/// MTNN's achieved performance on a benchmarked record.
fn p_mtnn(selector: &Selector, gpu: &GpuSpec, r: &Record) -> f64 {
    match selector.select(gpu, r.m, r.n, r.k).0 {
        Algorithm::Nt => r.p_nt,
        Algorithm::Tnn => r.p_tnn,
        Algorithm::Nn => unreachable!(),
    }
}

/// Compute Table VIII metrics over one GPU's records (Eq. 6 and Eq. 7).
pub fn metrics(selector: &Selector, gpu: &'static GpuSpec, records: &[Record]) -> SelectionMetrics {
    let mut m = SelectionMetrics {
        gow_max: f64::NEG_INFINITY,
        lub_min: f64::INFINITY,
        ..Default::default()
    };
    for r in records {
        let p = p_mtnn(selector, gpu, r);
        let worst = r.p_nt.min(r.p_tnn);
        let best = r.p_nt.max(r.p_tnn);
        let gow = (p - worst) / worst;
        let lub = (p - best) / best;
        m.mtnn_vs_nt += (p - r.p_nt) / r.p_nt;
        m.mtnn_vs_tnn += (p - r.p_tnn) / r.p_tnn;
        m.gow_avg += gow;
        m.gow_max = m.gow_max.max(gow);
        m.lub_avg += lub;
        m.lub_min = m.lub_min.min(lub);
        m.n += 1;
    }
    let n = m.n as f64;
    m.mtnn_vs_nt /= n;
    m.mtnn_vs_tnn /= n;
    m.gow_avg /= n;
    m.lub_avg /= n;
    m
}

/// Fig 5 + Fig 6 for one GPU.
pub fn figs56(selector: &Selector, gpu: &'static GpuSpec) -> (String, Histogram, f64, f64) {
    let sim = Simulator::new(gpu);
    let records = collect_gpu(&sim);
    let mut cells = HashMap::new();
    for &m in &SIZE_GRID {
        for &n in &SIZE_GRID {
            for &k in &SIZE_GRID {
                if !sim.fits(m, n, k) {
                    cells.insert((m, n, k), Cell::Excluded);
                }
            }
        }
    }
    let mut ratios = Vec::with_capacity(records.len());
    let mut max_nt_over_mtnn = 0.0f64;
    for r in &records {
        let p = p_mtnn(selector, gpu, r);
        cells.insert((r.m, r.n, r.k), classify(r.p_nt, p));
        ratios.push(p / r.p_nt);
        max_nt_over_mtnn = max_nt_over_mtnn.max(r.p_nt / p);
    }
    let grid = render(
        &format!("Fig 5 — NT vs MTNN winners on {}", gpu.name),
        "NT",
        "MTNN",
        &cells,
    );
    let mut hist = Histogram::new(0.6, 2.0, 14);
    hist.add_all(&ratios);
    let frac_gt_1 = fraction_where(&ratios, |x| x > 1.05);
    (grid, hist, frac_gt_1, max_nt_over_mtnn)
}

/// Full §VI.B output: Fig 5, Fig 6, Table VIII (per GPU + Total).
pub fn run(selector: &Selector) -> String {
    let mut out = String::new();
    let mut table8 = TextTable::new(
        "Table VIII — MTNN performance metrics in % (paper Total: 54.03 / 21.92 / 76.23 / 1439.39 / -0.28 / -71.62)",
        &["Metric", "GTX1080", "TitanX", "Total"],
    );
    let mut per_gpu: Vec<SelectionMetrics> = Vec::new();
    let mut all_records: Vec<(usize, Vec<Record>)> = Vec::new();
    for (gi, gpu) in PAPER_GPUS.iter().enumerate() {
        let (grid, hist, frac, max_ratio) = figs56(selector, gpu);
        out.push_str(&grid);
        out.push('\n');
        out.push_str(&hist.render(&format!(
            "Fig 6 — frequency of P_MTNN/P_NT on {} (paper: {:.2}% of cases MTNN > NT)",
            gpu.name,
            if gpu.name == "GTX1080" { 47.81 } else { 43.35 }
        )));
        out.push_str(&format!(
            "  measured: {:.1}% of cases MTNN wins by >5% | max P_NT/P_MTNN {:.2} (paper ~1.6)\n\n",
            frac * 100.0,
            max_ratio
        ));
        let records = collect_gpu(&Simulator::new(gpu));
        per_gpu.push(metrics(selector, gpu, &records));
        all_records.push((gi, records));
    }
    // Total = pooled over both GPUs.
    let mut pooled = SelectionMetrics {
        gow_max: f64::NEG_INFINITY,
        lub_min: f64::INFINITY,
        ..Default::default()
    };
    {
        let mut sum = |m: &SelectionMetrics| {
            let n = m.n as f64;
            pooled.mtnn_vs_nt += m.mtnn_vs_nt * n;
            pooled.mtnn_vs_tnn += m.mtnn_vs_tnn * n;
            pooled.gow_avg += m.gow_avg * n;
            pooled.lub_avg += m.lub_avg * n;
            pooled.gow_max = pooled.gow_max.max(m.gow_max);
            pooled.lub_min = pooled.lub_min.min(m.lub_min);
            pooled.n += m.n;
        };
        for m in &per_gpu {
            sum(m);
        }
    }
    let n = pooled.n as f64;
    pooled.mtnn_vs_nt /= n;
    pooled.mtnn_vs_tnn /= n;
    pooled.gow_avg /= n;
    pooled.lub_avg /= n;

    let pct = |x: f64| format!("{:.2}", x * 100.0);
    let rows: [(&str, fn(&SelectionMetrics) -> f64); 6] = [
        ("MTNN vs NT", |m| m.mtnn_vs_nt),
        ("MTNN vs TNN", |m| m.mtnn_vs_tnn),
        ("GOW_avg", |m| m.gow_avg),
        ("GOW_max", |m| m.gow_max),
        ("LUB_avg", |m| m.lub_avg),
        ("LUB_min", |m| m.lub_min),
    ];
    for (name, f) in rows {
        table8.row(vec![
            name.to_string(),
            pct(f(&per_gpu[0])),
            pct(f(&per_gpu[1])),
            pct(f(&pooled)),
        ]);
    }
    out.push_str(&table8.render());
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::collect_paper_dataset;
    use crate::gpusim::GTX1080;

    #[test]
    fn table8_shape_holds() {
        let selector = Selector::train_default(&collect_paper_dataset());
        let records = collect_gpu(&Simulator::new(&GTX1080));
        let m = metrics(&selector, &GTX1080, &records);
        assert!(m.mtnn_vs_nt > 0.10, "MTNN vs NT {:.3}", m.mtnn_vs_nt);
        assert!(m.mtnn_vs_tnn > 0.0, "MTNN vs TNN {:.3}", m.mtnn_vs_tnn);
        assert!(m.gow_avg > m.mtnn_vs_nt, "GOW should dominate vs-NT gain");
        assert!(m.gow_max > 1.0, "GOW_max {:.2} should be large", m.gow_max);
        assert!(
            m.lub_avg > -0.03 && m.lub_avg <= 0.0,
            "LUB_avg {:.4} should be tiny",
            m.lub_avg
        );
        assert!(m.lub_min >= -1.0 && m.lub_min < 0.0);
    }

    #[test]
    fn fig5_reduces_nt_wins_vs_fig2() {
        // The point of MTNN: far fewer '#' (NT-wins) cells than Fig 2.
        let selector = Selector::train_default(&collect_paper_dataset());
        let (grid5, _, _, max_ratio) = figs56(&selector, &GTX1080);
        let fig2 = super::super::fig23::compute(&GTX1080);
        let count = |s: &str| s.matches('#').count();
        assert!(
            count(&grid5) < count(&fig2.grid) / 2,
            "MTNN should eliminate most NT-better cells: fig5 {} vs fig2 {}",
            count(&grid5),
            count(&fig2.grid)
        );
        // Paper: max P_NT/P_MTNN drops from 15.39 to ~1.6.
        assert!(max_ratio < 3.0, "max NT/MTNN {max_ratio:.2}");
    }
}
