//! Extension experiments beyond the paper's evaluation:
//!
//! 1. **Extended classifier panel** — the paper motivates GBDT by citing
//!    Caruana & Niculescu-Mizil's 10-algorithm study; we extend Table VI
//!    with random forest, kNN and logistic regression.
//! 2. **Cross-GPU generalization** — the paper trains one model over both
//!    GPUs "so the model is equipped with robustness to different GPU
//!    hardware" but never tests on an *unseen* GPU. We hold out a GTX 1070
//!    (same Pascal generation, different SM count / clock / bandwidth)
//!    and measure zero-shot selection quality on it.

use super::classifiers::ClassifierRow;
use crate::dataset::{collect_gpu, collect_paper_dataset, to_ml_dataset, Record};
use crate::gemm::Algorithm;
use crate::gpusim::{GpuSpec, Simulator, GTX1070, GTX1080, TITANX};
use crate::ml::data::Dataset;
use crate::ml::forest::{ForestParams, RandomForest};
use crate::ml::gbdt::{Gbdt, GbdtParams};
use crate::ml::knn::Knn;
use crate::ml::linear::{LogReg, LogRegParams};
use crate::ml::metrics::accuracy;
use crate::ml::scaler::MinMaxScaler;
use crate::ml::svm::{Svm, SvmParams};
use crate::ml::tree::DecisionTreeClassifier;
use crate::ml::Classifier;
use crate::selector::{Selector, TrainedModel};
use crate::util::table::{fnum, TextTable};
use std::time::Instant;

fn bench_one<C: Classifier>(
    mut model: C,
    train: &Dataset,
    test: &Dataset,
    scale: bool,
) -> ClassifierRow {
    let (tx, sx) = if scale {
        let s = MinMaxScaler::fit(&train.x);
        (s.transform(&train.x), s.transform(&test.x))
    } else {
        (train.x.clone(), test.x.clone())
    };
    let t0 = Instant::now();
    model.fit(&tx, &train.y);
    let train_ms = t0.elapsed().as_secs_f64() * 1e3;
    let t1 = Instant::now();
    let pred = model.predict(&sx);
    let predict_ms = t1.elapsed().as_secs_f64() * 1e3 / sx.len() as f64;
    ClassifierRow {
        name: model.name(),
        accuracy: accuracy(&pred, &test.y).total,
        train_ms,
        predict_ms,
    }
}

/// Extended Table VI: seven learners on the paper's 80/20 protocol.
pub fn extended_table6(seed: u64) -> String {
    let data = to_ml_dataset(&collect_paper_dataset());
    let (train, test) = data.split_by_group(0.8, seed);
    let rows = vec![
        bench_one(Gbdt::new(GbdtParams::default()), &train, &test, false),
        bench_one(DecisionTreeClassifier::default(), &train, &test, false),
        bench_one(RandomForest::new(ForestParams::default()), &train, &test, false),
        bench_one(Svm::new(SvmParams::rbf()), &train, &test, true),
        bench_one(Svm::new(SvmParams::poly()), &train, &test, true),
        bench_one(Knn::new(5), &train, &test, true),
        bench_one(LogReg::new(LogRegParams::default()), &train, &test, true),
    ];
    let mut t = TextTable::new(
        "Extended Table VI — seven-learner panel (paper compares 4; Caruana-style extension)",
        &["Classifier", "Accuracy (%)", "Train Time (ms)", "Predict Time (ms)"],
    );
    for r in &rows {
        t.row(vec![
            r.name.clone(),
            fnum(r.accuracy * 100.0, 2),
            fnum(r.train_ms, 1),
            fnum(r.predict_ms, 4),
        ]);
    }
    t.render()
}

/// Accuracy + selection quality of a selector on one GPU's records.
fn eval_on(selector: &Selector, gpu: &'static GpuSpec, records: &[Record]) -> (f64, f64, f64) {
    let mut correct = 0usize;
    let (mut gain_nt, mut lub) = (0.0, 0.0);
    for r in records {
        let chosen = selector.select(gpu, r.m, r.n, r.k).0;
        if chosen.label() == r.label {
            correct += 1;
        }
        let p = match chosen {
            Algorithm::Nt => r.p_nt,
            Algorithm::Tnn => r.p_tnn,
            Algorithm::Nn => unreachable!(),
        };
        gain_nt += (p - r.p_nt) / r.p_nt;
        lub += (p - r.p_nt.max(r.p_tnn)) / r.p_nt.max(r.p_tnn);
    }
    let n = records.len() as f64;
    (correct as f64 / n, gain_nt / n, lub / n)
}

/// Cross-GPU generalization: several training regimes, all tested
/// zero-shot on the held-out GTX 1070.
pub fn cross_gpu() -> String {
    let r1080 = collect_gpu(&Simulator::new(&GTX1080));
    let rtitan = collect_gpu(&Simulator::new(&TITANX));
    let r1070 = collect_gpu(&Simulator::new(&GTX1070));

    let train_selector = |records: &[Record]| -> Selector {
        let d = to_ml_dataset(records);
        let mut g = Gbdt::new(GbdtParams::default());
        g.fit(&d.x, &d.y);
        Selector::new(TrainedModel::Gbdt(g))
    };

    let both: Vec<Record> = r1080.iter().chain(rtitan.iter()).cloned().collect();
    let regimes: Vec<(&str, Selector)> = vec![
        ("trained on GTX1080 only", train_selector(&r1080)),
        ("trained on TitanX only", train_selector(&rtitan)),
        ("trained on both (paper protocol)", train_selector(&both)),
        ("oracle upper bound", {
            // Selector trained ON the test GPU: the attainable ceiling.
            train_selector(&r1070)
        }),
    ];

    let mut t = TextTable::new(
        "Generalization — zero-shot selection on the unseen GTX 1070",
        &["training regime", "accuracy (%)", "gain vs NT (%)", "LUB (%)"],
    );
    for (name, sel) in &regimes {
        let (acc, gain, lub) = eval_on(sel, &GTX1070, &r1070);
        t.row(vec![
            name.to_string(),
            fnum(acc * 100.0, 2),
            fnum(gain * 100.0, 2),
            fnum(lub * 100.0, 2),
        ]);
    }
    let mut out = t.render();
    out.push_str(&format!(
        "  ({} valid samples on the GTX 1070 grid)\n",
        r1070.len()
    ));
    out
}

/// §VII future work, implemented: three-way selection with the in-place
/// transpose variant. Compares policy-average times over the NT-feasible
/// grid (which is larger than the paper's TNN-feasible grid — the whole
/// point of in-place).
pub fn future_work() -> String {
    use crate::gpusim::SIZE_GRID;
    use crate::selector::three_way::{time_case3, ThreeWay, ThreeWaySelector};
    let sel3 = ThreeWaySelector::train_default();
    let sel2 = Selector::train_default(&collect_paper_dataset());
    let mut t = TextTable::new(
        "Future work (§VII) — in-place transpose & three-way selection \
         (policy-average ms over the NT-feasible grid)",
        &["GPU", "always NT", "2-way MTNN", "3-way MTNN", "oracle", "cases unlocked"],
    );
    for gpu in crate::gpusim::PAPER_GPUS {
        let sim = Simulator::new(gpu);
        let (mut t_nt, mut t_2, mut t_3, mut t_best) = (0.0f64, 0.0, 0.0, 0.0);
        let (mut n, mut unlocked) = (0usize, 0usize);
        for &m in &SIZE_GRID {
            for &nn in &SIZE_GRID {
                for &k in &SIZE_GRID {
                    let Some(c) = time_case3(&sim, m, nn, k) else {
                        continue;
                    };
                    n += 1;
                    t_nt += c.t_nt;
                    // 2-way policy with the paper's memory fallback.
                    let a2 = sel2.select(gpu, m, nn, k).0;
                    t_2 += match a2 {
                        Algorithm::Tnn => c.t_tnn_oop.unwrap_or(c.t_nt),
                        _ => c.t_nt,
                    };
                    let a3 = sel3.select(gpu, m, nn, k);
                    t_3 += c.time_of(a3).unwrap_or(c.t_nt);
                    t_best += [Some(c.t_nt), c.t_tnn_oop, Some(c.t_tnn_ip)]
                        .iter()
                        .flatten()
                        .cloned()
                        .fold(f64::INFINITY, f64::min);
                    // "Unlocked": oop cannot run, but in-place beats NT.
                    if c.t_tnn_oop.is_none()
                        && a3 == ThreeWay::TnnInPlace
                        && c.t_tnn_ip < c.t_nt
                    {
                        unlocked += 1;
                    }
                }
            }
        }
        let ms = |x: f64| fnum(x / n as f64 * 1e3, 2);
        t.row(vec![
            gpu.name.into(),
            ms(t_nt),
            ms(t_2),
            ms(t_3),
            ms(t_best),
            unlocked.to_string(),
        ]);
    }
    t.render()
}

pub fn run(seed: u64) -> String {
    format!(
        "{}\n{}\n{}",
        extended_table6(seed),
        cross_gpu(),
        future_work()
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn two_gpu_training_generalizes_to_unseen_gpu() {
        // The headline claim of the extension: the paper-protocol model
        // (trained on 1080 + TitanX) transfers to the unseen 1070 with a
        // clearly positive gain over always-NT and small LUB.
        let both: Vec<Record> = collect_gpu(&Simulator::new(&GTX1080))
            .into_iter()
            .chain(collect_gpu(&Simulator::new(&TITANX)))
            .collect();
        let d = to_ml_dataset(&both);
        let mut g = Gbdt::new(GbdtParams::default());
        g.fit(&d.x, &d.y);
        let sel = Selector::new(TrainedModel::Gbdt(g));
        let r1070 = collect_gpu(&Simulator::new(&GTX1070));
        let (acc, gain, lub) = eval_on(&sel, &GTX1070, &r1070);
        assert!(acc > 0.80, "zero-shot accuracy {acc:.3}");
        assert!(gain > 0.10, "zero-shot gain vs NT {gain:.3}");
        assert!(lub > -0.10, "zero-shot LUB {lub:.3}");
    }

    #[test]
    fn extended_panel_renders_all_learners() {
        // Use the cheap learners only via the full function on a seed —
        // rendering includes all names.
        let text = extended_table6(3);
        for name in ["GBDT", "DT", "RF", "SVM-RBF", "kNN(k=5)", "LogReg"] {
            assert!(text.contains(name), "missing {name} in:\n{text}");
        }
    }
}
