//! Fig 2 (NT-vs-TNN winner grids per K), Fig 3 (P_TNN/P_NT histogram) and
//! Table II (sample distribution) — the TNN-motivation experiments.

use super::fig_grid::{classify, render, Cell};
use crate::gpusim::{GpuSpec, Simulator, PAPER_GPUS, SIZE_GRID};
use crate::util::csv::CsvTable;
use crate::util::stats::{fraction_where, Histogram};
use crate::util::table::TextTable;
use std::collections::HashMap;

pub struct Fig23Gpu {
    pub gpu: &'static str,
    pub grid: String,
    pub hist: Histogram,
    pub frac_tnn_lt_nt: f64,
    pub max_tnn_over_nt: f64,
    pub max_nt_over_tnn: f64,
    pub n_neg: usize,
    pub n_pos: usize,
    pub n: usize,
}

pub fn compute(gpu: &'static GpuSpec) -> Fig23Gpu {
    let sim = Simulator::new(gpu);
    let cases = sim.sweep();
    let mut cells = HashMap::new();
    for &m in &SIZE_GRID {
        for &n in &SIZE_GRID {
            for &k in &SIZE_GRID {
                if !sim.fits(m, n, k) {
                    cells.insert((m, n, k), Cell::Excluded);
                }
            }
        }
    }
    let mut ratios = Vec::with_capacity(cases.len());
    let (mut max_tnn, mut max_nt) = (0.0f64, 0.0f64);
    let mut n_neg = 0;
    for c in &cases {
        // Fig 2's symbols compare NT (first) against TNN (second).
        cells.insert((c.m, c.n, c.k), classify(c.p_nt, c.p_tnn));
        let r = c.p_tnn / c.p_nt;
        ratios.push(r);
        max_tnn = max_tnn.max(r);
        max_nt = max_nt.max(1.0 / r);
        if c.label() == -1 {
            n_neg += 1;
        }
    }
    let mut hist = Histogram::new(0.6, 2.0, 14);
    hist.add_all(&ratios);
    Fig23Gpu {
        gpu: gpu.name,
        grid: render(
            &format!("Fig 2 — NT vs TNN winners on {}", gpu.name),
            "NT",
            "TNN",
            &cells,
        ),
        hist,
        frac_tnn_lt_nt: fraction_where(&ratios, |x| x < 1.0),
        max_tnn_over_nt: max_tnn,
        max_nt_over_tnn: max_nt,
        n_neg,
        n_pos: cases.len() - n_neg,
        n: cases.len(),
    }
}

/// Full Fig 2 + Fig 3 + Table II output.
pub fn run() -> (String, CsvTable) {
    let mut out = String::new();
    let mut csv = CsvTable::new(&["gpu", "m", "n", "k", "p_nt", "p_tnn"]);
    let mut table2 = TextTable::new(
        "Table II — sample distribution (paper: GTX1080 649/242/891, TitanX 535/406/941)",
        &["GPU", "# of -1", "# of 1", "# of samples"],
    );
    let mut total = 0usize;
    for gpu in PAPER_GPUS {
        let r = compute(gpu);
        out.push_str(&r.grid);
        out.push('\n');
        out.push_str(&r.hist.render(&format!(
            "Fig 3 — frequency of P_TNN/P_NT on {} (paper: {:.1}% below 1.0)",
            r.gpu,
            if r.gpu == "GTX1080" { 41.5 } else { 43.0 }
        )));
        out.push_str(&format!(
            "  measured: {:.1}% < 1.0 | max TNN speedup {:.2}x (paper 4.7x) | \
             max NT speedup {:.2}x (paper 15.39x)\n\n",
            r.frac_tnn_lt_nt * 100.0,
            r.max_tnn_over_nt,
            r.max_nt_over_tnn
        ));
        table2.row(vec![
            r.gpu.into(),
            r.n_neg.to_string(),
            r.n_pos.to_string(),
            r.n.to_string(),
        ]);
        total += r.n;
        for c in Simulator::new(gpu).sweep() {
            csv.push_row(vec![
                gpu.name.into(),
                c.m.to_string(),
                c.n.to_string(),
                c.k.to_string(),
                format!("{:.4}", c.p_nt),
                format!("{:.4}", c.p_tnn),
            ]);
        }
    }
    table2.row(vec![
        "Total".into(),
        "-".into(),
        "-".into(),
        total.to_string(),
    ]);
    out.push_str(&table2.render());
    (out, csv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::GTX1080;

    #[test]
    fn grids_mark_oom_cases() {
        let r = compute(&GTX1080);
        assert!(r.grid.contains('.'), "largest cases must be excluded");
        assert!(r.grid.contains('#') && r.grid.contains('o'));
        assert_eq!(r.n_neg + r.n_pos, 891);
    }

    #[test]
    fn extremes_in_paper_ballpark() {
        let r = compute(&GTX1080);
        assert!(r.max_tnn_over_nt > 2.5 && r.max_tnn_over_nt < 7.0);
        assert!(r.max_nt_over_tnn > 7.0 && r.max_nt_over_tnn < 23.0);
    }
}
