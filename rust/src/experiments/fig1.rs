//! Fig 1: frequency of `P_NN / P_NT` over the benchmark sweep, per GPU —
//! the paper's motivation figure (NT is usually slower; ~20% of cases at
//! ratio ≥ 2).

use crate::gpusim::{calib, GpuSpec, Simulator, PAPER_GPUS};
use crate::util::csv::CsvTable;
use crate::util::stats::Histogram;

/// Results for one GPU.
pub struct Fig1Gpu {
    pub gpu: &'static str,
    pub hist: Histogram,
    pub frac_gt_1: f64,
    pub frac_ge_2: f64,
    pub n: usize,
}

/// Compute Fig 1 for one GPU (paper bins: 0.6 … 2.0 step 0.1, plus 2.0+).
pub fn compute(gpu: &'static GpuSpec) -> Fig1Gpu {
    let sim = Simulator::new(gpu);
    let ratios: Vec<f64> = sim.sweep().iter().map(|c| c.p_nn / c.p_nt).collect();
    let mut hist = Histogram::new(0.6, 2.0, 14);
    hist.add_all(&ratios);
    Fig1Gpu {
        gpu: gpu.name,
        frac_gt_1: crate::util::stats::fraction_where(&ratios, |x| x > 1.0),
        frac_ge_2: crate::util::stats::fraction_where(&ratios, |x| x >= 2.0),
        n: ratios.len(),
        hist,
    }
}

/// Full Fig 1 text output (both GPUs + calibration targets).
pub fn run() -> (String, CsvTable) {
    let mut out = String::new();
    let mut csv = CsvTable::new(&["gpu", "bin", "frequency"]);
    for gpu in PAPER_GPUS {
        let r = compute(gpu);
        out.push_str(&r.hist.render(&format!(
            "Fig 1 — frequency of P_NN/P_NT on {} (paper: {}% of cases > 1.0, ~20% >= 2.0)",
            r.gpu,
            if r.gpu == "GTX1080" { 71 } else { 62 }
        )));
        out.push_str(&format!(
            "  measured: {:.1}% > 1.0, {:.1}% >= 2.0 (n={})\n\n",
            r.frac_gt_1 * 100.0,
            r.frac_ge_2 * 100.0,
            r.n
        ));
        for (label, freq) in r.hist.labels().iter().zip(r.hist.frequencies()) {
            csv.push_row(vec![r.gpu.into(), label.clone(), format!("{freq:.6}")]);
        }
        // Calibration table against every published Fig-1/Table-II target.
        let sim = Simulator::new(gpu);
        let (_, targets) = calib::report(&sim);
        out.push_str(&calib::render_report(gpu.name, &targets));
        out.push('\n');
    }
    (out, csv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::GTX1080;

    #[test]
    fn histogram_covers_all_cases() {
        let r = compute(&GTX1080);
        assert_eq!(r.n, 891);
        let total: usize = r.hist.counts.iter().sum::<usize>() + r.hist.underflow;
        assert_eq!(total, r.n);
    }

    #[test]
    fn run_emits_both_gpus() {
        let (text, csv) = run();
        assert!(text.contains("GTX1080"));
        assert!(text.contains("TitanX"));
        // 15 bins × 2 GPUs.
        assert_eq!(csv.rows.len(), 30);
    }
}
