//! Experiment drivers — one module per paper table/figure family. Each
//! driver returns structured results, renders the paper-style tables /
//! ASCII histograms, and writes CSV + text into `results/`.
//!
//! | module        | regenerates                                   |
//! |---------------|-----------------------------------------------|
//! | [`fig1`]      | Fig 1 (P_NN/P_NT histograms)                  |
//! | [`fig23`]     | Fig 2 (winner grids), Fig 3, Table II         |
//! | [`classifiers`]| Table IV, Table VI, Fig 4                    |
//! | [`mtnn_eval`] | Fig 5, Fig 6, Table VIII                      |
//! | [`fcn_eval`]  | Fig 7, Fig 8, Table IX, Table X               |

pub mod classifiers;
pub mod fcn_eval;
pub mod fig1;
pub mod fig23;
pub mod fig_grid;
pub mod generalization;
pub mod mtnn_eval;

use std::path::{Path, PathBuf};

/// Results directory (created on demand).
pub fn results_dir() -> PathBuf {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("results");
    std::fs::create_dir_all(&dir).expect("create results dir");
    dir
}

/// Write text output both to stdout and `results/<name>`.
pub fn emit(name: &str, text: &str) {
    println!("{text}");
    let path = results_dir().join(name);
    std::fs::write(&path, text).expect("write results file");
}
