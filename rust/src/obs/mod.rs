//! Observability layer: request-path tracing, windowed rates, and a
//! chaos-triggered flight recorder.
//!
//! The serving core (`coordinator/`) answers *whether* requests
//! complete and how long they took end to end; this module answers
//! *where the time went*, *what the current rates are*, and *what
//! happened just before* an incident. It is deliberately decoupled:
//! the router and engine carry an `Option<SpanHandle>` and call cheap
//! atomic stamps; everything else (histograms, windows, triggers,
//! exposition) lives here.
//!
//! # Span lifecycle
//!
//! One `TraceSpan` per sampled request, stamped at every stage
//! boundary with µs-since-epoch monotonic timestamps:
//!
//! ```text
//!  Router::serve                Engine                    worker thread
//!  ─────────────                ──────                    ─────────────
//!  t_entry ──► decide()
//!  t_select ─► submit_traced ─► reuse classify (t_reuse)
//!                               ├─ hit/coalesced ··· (skips queue)
//!                               └─ lead/bypass ──► enqueue (t_enqueue)
//!                                                        │  queue wait
//!                                                        ▼
//!                                                  dequeue (t_dequeue)
//!                                                  batch join (t_batch)
//!                                                  execute (t_exec_start
//!                                                           … t_exec_end)
//!  t_complete ◄── response channel ◄─────────────── respond
//! ```
//!
//! At completion the router flattens the shared `SpanCell` into an
//! immutable `TraceSpan` (algo + selection reason + reuse class +
//! outcome + batch size + worker id) and hands it to
//! [`ObsLayer::complete`], which:
//!
//! 1. records per-stage (`queue_wait`, `execute`, `total`) per-algorithm
//!    (NT / TNN) latency histograms — the attribution the paper's
//!    measurement methodology demands,
//! 2. pushes the span into a lock-free Vyukov ring
//!    ([`span::SpanRing`], drop-not-block, same discipline as
//!    `online::SampleRing`) for external drain,
//! 3. feeds the flight recorder's recent ring and evaluates dump
//!    triggers (failure, shed, p99-over-threshold, mispredict burst).
//!
//! Sampling: `ObsConfig::sample_every = n` traces every n-th request
//! (1 = all, 0 = tracing off). Un-sampled requests pay one relaxed
//! `fetch_add`; windowed *rate* marks are recorded for every request
//! regardless of sampling so rates stay exact.
//!
//! # Windowed rates
//!
//! [`window::RateWindows`] keeps rotating time buckets over the serve
//! counters and reports last-N-seconds req/s, shed rate, reuse-hit
//! rate, probe rate, and mispredict rate — the live view that lifetime
//! ratios hide across regime changes.
//!
//! # Regret gauge
//!
//! Shadow probes already measure both algorithms; the layer folds the
//! counterfactual in as *regret* = served latency − measured winner
//! latency, exposed as a lifetime mean + last-value gauge.
//!
//! # Exposition
//!
//! `coordinator::MetricsSnapshot` embeds an [`ObsSnapshot`] and renders
//! it two ways (see `metrics.rs`):
//!
//! - `render_prometheus()` — text format 0.0.4. Counters end in
//!   `_total`; stage histograms emit cumulative
//!   `mtnn_stage_latency_us_bucket{stage="…",algo="…",le="…"}` series
//!   plus `_sum`/`_count`; windowed rates and regret are gauges.
//! - `render_json()` — the same snapshot as a JSON object for
//!   programmatic consumers.
//!
//! Both are plain string renders over an immutable snapshot, so the
//! ROADMAP item 1 `/metrics` endpoint reduces to one call.

pub mod recorder;
pub mod span;
pub mod window;

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use crate::coordinator::metrics::LatencyHistogram;

pub use recorder::{FlightDump, FlightRecorder};
pub use span::{SpanCell, SpanHandle, SpanRing, TraceSpan};
pub use window::{RateWindows, WindowKind, WindowRates};

/// Stage axis of the per-stage histograms.
pub const STAGE_NAMES: [&str; 3] = ["queue_wait", "execute", "total"];
const STAGE_QUEUE: usize = 0;
const STAGE_EXECUTE: usize = 1;
const STAGE_TOTAL: usize = 2;

/// Algorithm axis of the per-stage histograms.
pub const ALGO_NAMES: [&str; 2] = ["nt", "tnn"];

fn algo_slot(algo: u8) -> Option<usize> {
    match algo {
        span::ALGO_NT => Some(0),
        span::ALGO_TNN => Some(1),
        _ => None,
    }
}

/// Tracing/recording configuration. The default is "trace everything,
/// dump on failure or shed, never on latency" — a clean steady trace
/// produces zero dumps.
#[derive(Debug, Clone)]
pub struct ObsConfig {
    /// Trace every n-th request. 1 = every request, 0 = tracing off
    /// (windowed rates and regret still work).
    pub sample_every: u64,
    /// Capacity of the lock-free completed-span ring.
    pub span_ring_capacity: usize,
    /// How many recent spans a flight dump captures.
    pub recorder_capacity: usize,
    /// Maximum dumps retained; later triggers are suppressed.
    pub max_dumps: usize,
    /// Minimum µs between dump captures.
    pub dump_cooldown_us: u64,
    /// Capture a dump when a sampled request fails.
    pub trigger_on_failure: bool,
    /// Capture a dump when a sampled request is shed.
    pub trigger_on_shed: bool,
    /// Capture a dump when a sampled request's deadline expires.
    pub trigger_on_timeout: bool,
    /// Capture a dump when either algorithm's total-latency p99 exceeds
    /// this. `u64::MAX` disables.
    pub p99_threshold_us: u64,
    /// Samples required before the p99 trigger can fire.
    pub p99_min_samples: u64,
    /// Capture a dump when the current window holds at least this many
    /// mispredicts. 0 disables.
    pub mispredict_burst: u64,
    /// Width of one rate-window bucket.
    pub window_bucket_ms: u64,
    /// Number of rate-window buckets (window = buckets × bucket_ms).
    pub window_buckets: usize,
}

impl Default for ObsConfig {
    fn default() -> ObsConfig {
        ObsConfig {
            sample_every: 1,
            span_ring_capacity: 4096,
            recorder_capacity: 256,
            max_dumps: 8,
            dump_cooldown_us: 100_000,
            trigger_on_failure: true,
            trigger_on_shed: true,
            trigger_on_timeout: true,
            p99_threshold_us: u64::MAX,
            p99_min_samples: 32,
            mispredict_burst: 0,
            window_bucket_ms: 1000,
            window_buckets: 8,
        }
    }
}

/// Frozen per-stage/per-algo histogram view used by the exposition
/// renderers.
#[derive(Debug, Clone)]
pub struct StageStats {
    pub stage: &'static str,
    pub algo: &'static str,
    pub count: u64,
    pub sum_us: f64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    pub mean_us: f64,
    /// Cumulative (upper_bound_us, count ≤ upper_bound) points for
    /// non-empty buckets, ascending.
    pub buckets: Vec<(u64, u64)>,
}

/// Point-in-time view of the observability layer, embedded in
/// `coordinator::MetricsSnapshot`.
#[derive(Debug, Clone)]
pub struct ObsSnapshot {
    /// Spans started by `begin_span` (sampled requests).
    pub spans_begun: u64,
    /// Completed spans accepted by the span ring.
    pub spans_recorded: u64,
    /// Completed spans dropped because the ring was full.
    pub spans_dropped: u64,
    /// Per-stage per-algorithm latency attribution (6 entries).
    pub stages: Vec<StageStats>,
    /// Last-N-seconds rates.
    pub window: WindowRates,
    pub regret_count: u64,
    pub regret_mean_us: f64,
    pub regret_last_us: u64,
    pub recorder_triggered: u64,
    pub recorder_dumps: u64,
}

/// The observability layer. One per router; shared with
/// `CoordinatorMetrics` via `Arc` for snapshot embedding.
pub struct ObsLayer {
    config: ObsConfig,
    epoch: Instant,
    tick: AtomicU64,
    begun: AtomicU64,
    spans: SpanRing,
    recorder: FlightRecorder,
    /// `[stage][algo]`: stages queue_wait/execute/total × NT/TNN.
    stage_hist: [[LatencyHistogram; 2]; 3],
    windows: RateWindows,
    regret_sum_us: AtomicU64,
    regret_count: AtomicU64,
    regret_last_us: AtomicU64,
}

impl fmt::Debug for ObsLayer {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("ObsLayer")
            .field("config", &self.config)
            .field("spans_begun", &self.begun.load(Ordering::Relaxed))
            .finish_non_exhaustive()
    }
}

impl ObsLayer {
    pub fn new(config: ObsConfig) -> ObsLayer {
        ObsLayer {
            epoch: Instant::now(),
            tick: AtomicU64::new(0),
            begun: AtomicU64::new(0),
            spans: SpanRing::new(config.span_ring_capacity),
            recorder: FlightRecorder::new(
                config.recorder_capacity,
                config.max_dumps,
                config.dump_cooldown_us,
            ),
            stage_hist: Default::default(),
            windows: RateWindows::new(config.window_bucket_ms, config.window_buckets),
            regret_sum_us: AtomicU64::new(0),
            regret_count: AtomicU64::new(0),
            regret_last_us: AtomicU64::new(0),
            config,
        }
    }

    pub fn config(&self) -> &ObsConfig {
        &self.config
    }

    /// µs since the layer epoch, floored at 1 so 0 keeps meaning
    /// "never stamped".
    pub fn now_us(&self) -> u64 {
        (self.epoch.elapsed().as_micros() as u64).max(1)
    }

    fn now_ms(&self) -> u64 {
        self.epoch.elapsed().as_millis() as u64
    }

    /// Start a span for this request if it falls on the sampling
    /// lattice. The returned handle is stamped by the engine/worker and
    /// flattened by the router at completion.
    pub fn begin_span(&self) -> Option<SpanHandle> {
        let n = self.config.sample_every;
        if n == 0 {
            return None;
        }
        let t = self.tick.fetch_add(1, Ordering::Relaxed);
        if t % n != 0 {
            return None;
        }
        self.begun.fetch_add(1, Ordering::Relaxed);
        Some(std::sync::Arc::new(SpanCell::new(self.epoch)))
    }

    /// Accept a flattened span: attribute stage latencies, retain it
    /// for drains and flight dumps, and evaluate dump triggers.
    pub fn complete(&self, s: TraceSpan) {
        if s.outcome == span::OUTCOME_COMPLETED {
            if let Some(a) = algo_slot(s.algo) {
                if let Some(q) = s.queue_wait_us() {
                    self.stage_hist[STAGE_QUEUE][a].record_us(q as f64);
                }
                if let Some(e) = s.execute_us() {
                    self.stage_hist[STAGE_EXECUTE][a].record_us(e as f64);
                }
                if let Some(t) = s.total_us() {
                    self.stage_hist[STAGE_TOTAL][a].record_us(t as f64);
                }
            }
            if s.reuse == span::REUSE_HIT {
                self.windows.record_at(WindowKind::ReuseHit, self.now_ms());
            }
        }
        self.spans.push(&s);
        self.recorder.observe(s);
        let now = self.now_us();
        match s.outcome {
            span::OUTCOME_FAILED if self.config.trigger_on_failure => {
                self.recorder.trigger("failure", now);
            }
            span::OUTCOME_SHED if self.config.trigger_on_shed => {
                self.recorder.trigger("shed", now);
            }
            span::OUTCOME_TIMED_OUT if self.config.trigger_on_timeout => {
                self.recorder.trigger("timeout", now);
            }
            _ => {}
        }
        if self.config.p99_threshold_us != u64::MAX {
            self.check_p99(now);
        }
    }

    fn check_p99(&self, now_us: u64) {
        for a in 0..2 {
            let h = &self.stage_hist[STAGE_TOTAL][a];
            if h.count() < self.config.p99_min_samples {
                continue;
            }
            let (_, _, p99, _) = h.summary();
            if p99.is_finite() && p99 as u64 > self.config.p99_threshold_us {
                self.recorder.trigger("p99_over_threshold", now_us);
                return;
            }
        }
    }

    /// Windowed-rate marks — called for *every* request, sampled or
    /// not, so rates stay exact regardless of `sample_every`.
    pub fn mark_request(&self) {
        self.windows.record_at(WindowKind::Requests, self.now_ms());
    }

    pub fn mark_completed(&self) {
        self.windows.record_at(WindowKind::Completed, self.now_ms());
    }

    pub fn mark_shed(&self) {
        self.windows.record_at(WindowKind::Shed, self.now_ms());
    }

    pub fn mark_probe(&self) {
        self.windows.record_at(WindowKind::Probe, self.now_ms());
    }

    pub fn mark_timeout(&self) {
        self.windows.record_at(WindowKind::TimedOut, self.now_ms());
    }

    /// Mark one retry *attempt* (a request retried twice marks twice).
    pub fn mark_retry(&self) {
        self.windows.record_at(WindowKind::Retry, self.now_ms());
    }

    /// Mark one breaker-open fail-fast rejection.
    pub fn mark_breaker_open(&self) {
        self.windows
            .record_at(WindowKind::BreakerOpen, self.now_ms());
    }

    /// Fire the retry-budget-exhausted flight-recorder trigger.
    pub fn trigger_retry_exhausted(&self) {
        self.recorder.trigger("retry_exhausted", self.now_us());
    }

    /// Fire the breaker-tripped-open flight-recorder trigger.
    pub fn trigger_breaker_open(&self) {
        self.recorder.trigger("breaker_open", self.now_us());
    }

    /// Worst per-algorithm total-latency p99 (µs), 0 until any total
    /// samples exist — the cheap latency-pressure signal the brownout
    /// controller polls (O(histogram buckets), called on the brownout
    /// evaluation cadence, not per request).
    pub fn total_p99_us(&self) -> u64 {
        let mut worst = 0u64;
        for a in 0..2 {
            let h = &self.stage_hist[STAGE_TOTAL][a];
            if h.count() == 0 {
                continue;
            }
            let (_, _, p99, _) = h.summary();
            if p99.is_finite() {
                worst = worst.max(p99 as u64);
            }
        }
        worst
    }

    /// The current windowed rates (the brownout controller's pressure
    /// input; same view `snapshot()` embeds).
    pub fn window_rates(&self) -> WindowRates {
        self.windows.rates_at(self.now_ms())
    }

    /// Milliseconds since the layer epoch (public for rate-limited
    /// callers like the brownout evaluation tick).
    pub fn epoch_ms(&self) -> u64 {
        self.now_ms()
    }

    /// Mark a shadow-probe mispredict; fires the burst trigger when the
    /// current window accumulates `mispredict_burst` of them.
    pub fn mark_mispredict(&self) {
        let now_ms = self.now_ms();
        self.windows.record_at(WindowKind::Mispredict, now_ms);
        let burst = self.config.mispredict_burst;
        if burst > 0 && self.windows.rates_at(now_ms).mispredicts >= burst {
            self.recorder.trigger("mispredict_burst", self.now_us());
        }
    }

    /// Fold in one shadow-probe counterfactual: `served_us` is what the
    /// request actually took, `winner_us` the measured faster
    /// algorithm. Regret is their non-negative difference.
    pub fn record_regret(&self, served_us: u64, winner_us: u64) {
        let regret = served_us.saturating_sub(winner_us);
        self.regret_sum_us.fetch_add(regret, Ordering::Relaxed);
        self.regret_count.fetch_add(1, Ordering::Relaxed);
        self.regret_last_us.store(regret, Ordering::Relaxed);
    }

    /// Drain all completed spans currently in the ring (consuming).
    pub fn drain_spans(&self) -> Vec<TraceSpan> {
        self.spans.drain()
    }

    pub fn recorder(&self) -> &FlightRecorder {
        &self.recorder
    }

    pub fn dumps(&self) -> Vec<FlightDump> {
        self.recorder.dumps()
    }

    pub fn snapshot(&self) -> ObsSnapshot {
        let mut stages = Vec::with_capacity(6);
        for (si, stage) in STAGE_NAMES.iter().enumerate() {
            for (ai, algo) in ALGO_NAMES.iter().enumerate() {
                let h = &self.stage_hist[si][ai];
                let (p50, p95, p99, mean) = h.summary();
                stages.push(StageStats {
                    stage,
                    algo,
                    count: h.count(),
                    sum_us: h.sum_us(),
                    p50_us: p50,
                    p95_us: p95,
                    p99_us: p99,
                    mean_us: mean,
                    buckets: h.bucket_points(),
                });
            }
        }
        let rc = self.regret_count.load(Ordering::Relaxed);
        let rs = self.regret_sum_us.load(Ordering::Relaxed);
        ObsSnapshot {
            spans_begun: self.begun.load(Ordering::Relaxed),
            spans_recorded: self.spans.pushed(),
            spans_dropped: self.spans.dropped(),
            stages,
            window: self.windows.rates_at(self.now_ms()),
            regret_count: rc,
            regret_mean_us: if rc == 0 { 0.0 } else { rs as f64 / rc as f64 },
            regret_last_us: self.regret_last_us.load(Ordering::Relaxed),
            recorder_triggered: self.recorder.triggered(),
            recorder_dumps: self.recorder.dump_count() as u64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::span::{
        ALGO_NT, ALGO_TNN, OUTCOME_COMPLETED, OUTCOME_FAILED, REASON_PREDICTED_NT, REUSE_NONE,
    };

    fn completed_span(algo: u8, t_entry: u64, exec_us: u64) -> TraceSpan {
        TraceSpan {
            t_entry,
            t_select: t_entry + 1,
            t_reuse: t_entry + 2,
            t_enqueue: t_entry + 3,
            t_dequeue: t_entry + 8,
            t_batch: t_entry + 9,
            t_exec_start: t_entry + 10,
            t_exec_end: t_entry + 10 + exec_us,
            t_complete: t_entry + 12 + exec_us,
            algo,
            reason: REASON_PREDICTED_NT,
            reuse: REUSE_NONE,
            outcome: OUTCOME_COMPLETED,
            batch_size: 1,
            worker: 0,
            retries: 0,
        }
    }

    #[test]
    fn sampling_lattice_respects_sample_every() {
        let layer = ObsLayer::new(ObsConfig {
            sample_every: 3,
            ..ObsConfig::default()
        });
        let got: Vec<bool> = (0..9).map(|_| layer.begin_span().is_some()).collect();
        assert_eq!(
            got,
            vec![true, false, false, true, false, false, true, false, false]
        );
        assert_eq!(layer.snapshot().spans_begun, 3);
    }

    #[test]
    fn sample_every_zero_disables_tracing() {
        let layer = ObsLayer::new(ObsConfig {
            sample_every: 0,
            ..ObsConfig::default()
        });
        assert!(layer.begin_span().is_none());
        layer.mark_request(); // rates still work
        assert_eq!(layer.snapshot().window.requests, 1);
    }

    #[test]
    fn complete_attributes_stages_per_algorithm() {
        let layer = ObsLayer::new(ObsConfig::default());
        layer.complete(completed_span(ALGO_NT, 100, 50));
        layer.complete(completed_span(ALGO_TNN, 300, 80));
        let snap = layer.snapshot();
        let find = |stage: &str, algo: &str| {
            snap.stages
                .iter()
                .find(|s| s.stage == stage && s.algo == algo)
                .unwrap()
                .clone()
        };
        assert_eq!(find("queue_wait", "nt").count, 1);
        assert_eq!(find("execute", "nt").count, 1);
        assert_eq!(find("total", "nt").count, 1);
        assert_eq!(find("execute", "tnn").count, 1);
        // queue wait is 5 µs for both; execute 50 vs 80.
        assert!(find("execute", "nt").mean_us >= 50.0);
        assert!(find("execute", "tnn").mean_us >= 80.0);
        assert_eq!(snap.spans_recorded, 2);
        assert!(!find("execute", "nt").buckets.is_empty());
    }

    #[test]
    fn failure_span_fires_a_dump_with_context() {
        let layer = ObsLayer::new(ObsConfig::default());
        layer.complete(completed_span(ALGO_NT, 100, 10));
        let mut bad = completed_span(ALGO_NT, 200, 10);
        bad.outcome = OUTCOME_FAILED;
        layer.complete(bad);
        let dumps = layer.dumps();
        assert_eq!(dumps.len(), 1);
        assert_eq!(dumps[0].trigger, "failure");
        assert_eq!(dumps[0].spans.len(), 2, "preceding span is in the dump");
        assert_eq!(dumps[0].spans[1].outcome, OUTCOME_FAILED);
    }

    #[test]
    fn timed_out_span_fires_a_timeout_dump() {
        let layer = ObsLayer::new(ObsConfig::default());
        let mut late = completed_span(ALGO_NT, 100, 10);
        late.outcome = crate::obs::span::OUTCOME_TIMED_OUT;
        late.retries = 2;
        layer.complete(late);
        let dumps = layer.dumps();
        assert_eq!(dumps.len(), 1);
        assert_eq!(dumps[0].trigger, "timeout");
        assert_eq!(dumps[0].spans.last().unwrap().retries, 2);
    }

    #[test]
    fn lifecycle_marks_flow_into_window_rates() {
        let layer = ObsLayer::new(ObsConfig::default());
        layer.mark_request();
        layer.mark_timeout();
        layer.mark_retry();
        layer.mark_retry();
        layer.mark_breaker_open();
        let w = layer.snapshot().window;
        assert_eq!(w.timed_out, 1);
        assert_eq!(w.retries, 2);
        assert_eq!(w.breaker_opens, 1);
        assert!((w.timeout_rate - 1.0).abs() < 1e-12);
    }

    #[test]
    fn clean_completed_spans_fire_no_dumps() {
        let layer = ObsLayer::new(ObsConfig::default());
        for i in 0..200 {
            layer.complete(completed_span(ALGO_NT, i * 100, 10));
        }
        assert_eq!(layer.dumps().len(), 0);
        assert_eq!(layer.snapshot().recorder_triggered, 0);
    }

    #[test]
    fn p99_trigger_needs_min_samples_then_fires() {
        let layer = ObsLayer::new(ObsConfig {
            p99_threshold_us: 1_000,
            p99_min_samples: 4,
            ..ObsConfig::default()
        });
        // Three slow spans: below min samples, no dump.
        for i in 0..3 {
            layer.complete(completed_span(ALGO_NT, i * 100_000, 50_000));
        }
        assert_eq!(layer.dumps().len(), 0);
        layer.complete(completed_span(ALGO_NT, 400_000, 50_000));
        assert_eq!(layer.dumps().len(), 1);
        assert_eq!(layer.dumps()[0].trigger, "p99_over_threshold");
    }

    #[test]
    fn mispredict_burst_trigger() {
        let layer = ObsLayer::new(ObsConfig {
            mispredict_burst: 3,
            ..ObsConfig::default()
        });
        layer.mark_mispredict();
        layer.mark_mispredict();
        assert_eq!(layer.dumps().len(), 0);
        layer.mark_mispredict();
        assert_eq!(layer.dumps().len(), 1);
        assert_eq!(layer.dumps()[0].trigger, "mispredict_burst");
    }

    #[test]
    fn regret_gauge_accumulates() {
        let layer = ObsLayer::new(ObsConfig::default());
        layer.record_regret(150, 100); // served 150, winner 100 → 50
        layer.record_regret(90, 100); // served the winner → 0
        let snap = layer.snapshot();
        assert_eq!(snap.regret_count, 2);
        assert!((snap.regret_mean_us - 25.0).abs() < 1e-9);
        assert_eq!(snap.regret_last_us, 0);
    }

    #[test]
    fn drain_returns_completed_spans_in_order() {
        let layer = ObsLayer::new(ObsConfig::default());
        layer.complete(completed_span(ALGO_NT, 1, 10));
        layer.complete(completed_span(ALGO_TNN, 2, 10));
        let spans = layer.drain_spans();
        assert_eq!(spans.len(), 2);
        assert_eq!(spans[0].algo, ALGO_NT);
        assert_eq!(spans[1].algo, ALGO_TNN);
        assert!(layer.drain_spans().is_empty());
    }
}
