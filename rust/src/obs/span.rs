//! Per-request trace spans: stage timestamps threaded router → engine →
//! worker, plus the bounded lock-free ring completed spans are recorded
//! into.
//!
//! A sampled request owns one [`SpanCell`] (shared by `Arc` as
//! [`SpanHandle`]): the router stamps entry/selection/completion locally,
//! the engine submit path stamps reuse classification and enqueue, and
//! the executing worker stamps dequeue, batch assembly, and the execute
//! window. Every stamp is a relaxed atomic store of "µs since the
//! observability layer's epoch" (clamped to ≥ 1, so 0 always means
//! "never stamped") — no locks, no allocation after the one `Arc` the
//! sampler pays per traced request.
//!
//! On completion the cell plus the router's locals are flattened into a
//! [`TraceSpan`] (a `Copy` value) and pushed into the [`SpanRing`] — the
//! same Vyukov drop-not-block MPMC discipline as
//! `crate::online::SampleRing`: a full ring drops the span and counts it,
//! it never blocks the serving path.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

// ---- span field codes ------------------------------------------------------

/// `TraceSpan::algo`: which algorithm served the request.
pub const ALGO_UNKNOWN: u8 = 0;
pub const ALGO_NT: u8 = 1;
pub const ALGO_TNN: u8 = 2;
pub const ALGO_NN: u8 = 3;

/// `TraceSpan::reason`: why the algorithm was selected.
pub const REASON_UNKNOWN: u8 = 0;
pub const REASON_PREDICTED_NT: u8 = 1;
pub const REASON_PREDICTED_TNN: u8 = 2;
pub const REASON_MEMORY_FALLBACK: u8 = 3;
pub const REASON_FORCED: u8 = 4;

/// `TraceSpan::reuse`: how the reuse layer classified the submission
/// (0 also covers "no reuse layer installed" and deny-prefix bypasses).
pub const REUSE_NONE: u8 = 0;
pub const REUSE_LEAD: u8 = 1;
pub const REUSE_HIT: u8 = 2;
pub const REUSE_COALESCED: u8 = 3;

/// `TraceSpan::outcome`: how the request resolved.
pub const OUTCOME_COMPLETED: u8 = 0;
pub const OUTCOME_FAILED: u8 = 1;
pub const OUTCOME_SHED: u8 = 2;
/// The request's deadline expired (at admission, in queue, or while the
/// client waited) — the fourth term of the conservation ledger.
pub const OUTCOME_TIMED_OUT: u8 = 3;

pub fn algo_name(code: u8) -> &'static str {
    match code {
        ALGO_NT => "nt",
        ALGO_TNN => "tnn",
        ALGO_NN => "nn",
        _ => "unknown",
    }
}

pub fn outcome_name(code: u8) -> &'static str {
    match code {
        OUTCOME_COMPLETED => "completed",
        OUTCOME_FAILED => "failed",
        OUTCOME_SHED => "shed",
        OUTCOME_TIMED_OUT => "timed_out",
        _ => "unknown",
    }
}

// ---- the flattened span ----------------------------------------------------

/// One request's completed trace: monotonic stage timestamps (µs since
/// the observability epoch; 0 = that stage never happened, e.g. a reuse
/// hit never enqueues) plus classification codes. `Copy` so the flight
/// recorder and the span ring move it without allocating.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct TraceSpan {
    /// Router entry (request counted).
    pub t_entry: u64,
    /// Selection decided (algo + reason known).
    pub t_select: u64,
    /// Reuse layer classified the submission.
    pub t_reuse: u64,
    /// Job accepted onto a worker queue.
    pub t_enqueue: u64,
    /// Worker pulled the job off the queue fabric.
    pub t_dequeue: u64,
    /// Micro-batch assembled (size known).
    pub t_batch: u64,
    /// Backend execute began.
    pub t_exec_start: u64,
    /// Backend execute returned.
    pub t_exec_end: u64,
    /// Router observed the outcome.
    pub t_complete: u64,
    pub algo: u8,
    pub reason: u8,
    pub reuse: u8,
    pub outcome: u8,
    /// Micro-batch size this job executed in (0 = never batched).
    pub batch_size: u32,
    /// Executing worker index (only meaningful when `t_exec_start != 0`).
    pub worker: u32,
    /// Retry attempts the router spent on this request (0 = first try
    /// resolved it) — flight dumps carry it so a post-incident read
    /// shows how hard the retry policy was working.
    pub retries: u32,
}

/// Both stamps present (a stage that never ran yields `None`, not 0).
fn delta(start: u64, end: u64) -> Option<u64> {
    if start == 0 || end == 0 {
        None
    } else {
        Some(end.saturating_sub(start))
    }
}

impl TraceSpan {
    /// Enqueue → dequeue: time spent waiting in a worker queue.
    pub fn queue_wait_us(&self) -> Option<u64> {
        delta(self.t_enqueue, self.t_dequeue)
    }

    /// Execute start → end: backend time (batch-amortized wall clock).
    pub fn execute_us(&self) -> Option<u64> {
        delta(self.t_exec_start, self.t_exec_end)
    }

    /// Entry → completion: what the caller experienced.
    pub fn total_us(&self) -> Option<u64> {
        delta(self.t_entry, self.t_complete)
    }

    pub fn to_json(&self) -> crate::util::json::Json {
        crate::util::json::Json::obj()
            .set("t_entry", self.t_entry)
            .set("t_select", self.t_select)
            .set("t_reuse", self.t_reuse)
            .set("t_enqueue", self.t_enqueue)
            .set("t_dequeue", self.t_dequeue)
            .set("t_batch", self.t_batch)
            .set("t_exec_start", self.t_exec_start)
            .set("t_exec_end", self.t_exec_end)
            .set("t_complete", self.t_complete)
            .set("algo", algo_name(self.algo))
            .set("outcome", outcome_name(self.outcome))
            .set("reason", self.reason as u64)
            .set("reuse", self.reuse as u64)
            .set("batch_size", self.batch_size as u64)
            .set("worker", self.worker as u64)
            .set("retries", self.retries as u64)
    }
}

// ---- the live stamping cell ------------------------------------------------

/// The engine-visible half of a span while the request is in flight. The
/// router keeps its own stamps (entry, selection, completion) in locals;
/// everything the submit path and the worker touch lives here as relaxed
/// atomics so no stage ever takes a lock. The cell carries a copy of the
/// observability epoch so its stamps are directly comparable with the
/// router's without the engine ever holding an `ObsLayer` reference.
#[derive(Debug)]
pub struct SpanCell {
    epoch: Instant,
    t_reuse: AtomicU64,
    t_enqueue: AtomicU64,
    t_dequeue: AtomicU64,
    t_batch: AtomicU64,
    t_exec_start: AtomicU64,
    t_exec_end: AtomicU64,
    reuse_class: AtomicU64,
    batch_size: AtomicU64,
    worker: AtomicU64,
}

/// How spans travel through `EngineJob`: one `Arc` per sampled request.
pub type SpanHandle = std::sync::Arc<SpanCell>;

impl SpanCell {
    pub fn new(epoch: Instant) -> SpanCell {
        SpanCell {
            epoch,
            t_reuse: AtomicU64::new(0),
            t_enqueue: AtomicU64::new(0),
            t_dequeue: AtomicU64::new(0),
            t_batch: AtomicU64::new(0),
            t_exec_start: AtomicU64::new(0),
            t_exec_end: AtomicU64::new(0),
            reuse_class: AtomicU64::new(REUSE_NONE as u64),
            batch_size: AtomicU64::new(0),
            worker: AtomicU64::new(0),
        }
    }

    /// µs since the observability epoch, clamped to ≥ 1 so a stored stamp
    /// can never collide with 0 = "never stamped".
    pub fn now_us(&self) -> u64 {
        (self.epoch.elapsed().as_micros() as u64).max(1)
    }

    pub fn stamp_reuse(&self, class: u8) {
        self.reuse_class.store(class as u64, Ordering::Relaxed);
        self.t_reuse.store(self.now_us(), Ordering::Relaxed);
    }

    pub fn stamp_enqueue(&self) {
        self.t_enqueue.store(self.now_us(), Ordering::Relaxed);
    }

    /// Stamped each time a worker pulls the job off the fabric; a job
    /// deferred to a stash and re-serviced overwrites with the later pull,
    /// so queue-wait includes deferral time (the caller-visible truth).
    pub fn stamp_dequeue(&self) {
        self.t_dequeue.store(self.now_us(), Ordering::Relaxed);
    }

    pub fn stamp_batch(&self, batch_size: usize, worker: usize) {
        self.batch_size.store(batch_size as u64, Ordering::Relaxed);
        self.worker.store(worker as u64, Ordering::Relaxed);
        self.t_batch.store(self.now_us(), Ordering::Relaxed);
    }

    pub fn stamp_exec_start(&self) {
        self.t_exec_start.store(self.now_us(), Ordering::Relaxed);
    }

    pub fn stamp_exec_end(&self) {
        self.t_exec_end.store(self.now_us(), Ordering::Relaxed);
    }

    /// Reuse classification stamped so far (`REUSE_*`).
    pub fn reuse_class(&self) -> u8 {
        self.reuse_class.load(Ordering::Relaxed) as u8
    }

    /// Flatten the cell plus the router's locally-held stamps into the
    /// immutable completed span.
    #[allow(clippy::too_many_arguments)]
    pub fn to_span(
        &self,
        t_entry: u64,
        t_select: u64,
        t_complete: u64,
        algo: u8,
        reason: u8,
        outcome: u8,
        retries: u32,
    ) -> TraceSpan {
        TraceSpan {
            t_entry,
            t_select,
            t_reuse: self.t_reuse.load(Ordering::Relaxed),
            t_enqueue: self.t_enqueue.load(Ordering::Relaxed),
            t_dequeue: self.t_dequeue.load(Ordering::Relaxed),
            t_batch: self.t_batch.load(Ordering::Relaxed),
            t_exec_start: self.t_exec_start.load(Ordering::Relaxed),
            t_exec_end: self.t_exec_end.load(Ordering::Relaxed),
            t_complete,
            algo,
            reason,
            reuse: self.reuse_class(),
            outcome,
            batch_size: self.batch_size.load(Ordering::Relaxed) as u32,
            worker: self.worker.load(Ordering::Relaxed) as u32,
            retries,
        }
    }
}

// ---- the completed-span ring -----------------------------------------------

/// Value words per slot: 9 timestamps, one packed flags word
/// (`algo | reason<<8 | reuse<<16 | outcome<<24`), one packed
/// `batch_size | worker<<32` word, one retries word.
const FIELDS: usize = 12;

fn pack_flags(s: &TraceSpan) -> u64 {
    s.algo as u64 | (s.reason as u64) << 8 | (s.reuse as u64) << 16 | (s.outcome as u64) << 24
}

fn pack_wb(s: &TraceSpan) -> u64 {
    s.batch_size as u64 | (s.worker as u64) << 32
}

struct Slot {
    /// Vyukov sequence: `index` when free for the producer of that lap,
    /// `index + 1` once published, `index + capacity` after consumption.
    seq: AtomicU64,
    vals: [AtomicU64; FIELDS],
}

impl Slot {
    fn new(i: u64) -> Slot {
        Slot {
            seq: AtomicU64::new(i),
            vals: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }
}

/// Bounded lock-free MPMC ring of completed spans. Full ⇒ the span is
/// dropped and counted — recording never blocks serving (the same
/// discipline as `online::SampleRing`).
pub struct SpanRing {
    slots: Box<[Slot]>,
    mask: u64,
    capacity: u64,
    head: AtomicU64,
    tail: AtomicU64,
    pushed: AtomicU64,
    dropped: AtomicU64,
}

impl SpanRing {
    /// Ring with at least `capacity` slots (rounded up to a power of two,
    /// minimum 64).
    pub fn new(capacity: usize) -> SpanRing {
        let cap = capacity.max(64).next_power_of_two() as u64;
        SpanRing {
            slots: (0..cap).map(Slot::new).collect(),
            mask: cap - 1,
            capacity: cap,
            head: AtomicU64::new(0),
            tail: AtomicU64::new(0),
            pushed: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity as usize
    }

    pub fn pushed(&self) -> u64 {
        self.pushed.load(Ordering::Relaxed)
    }

    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Approximate occupancy (racy; for metrics only).
    pub fn len(&self) -> usize {
        let head = self.head.load(Ordering::Relaxed);
        let tail = self.tail.load(Ordering::Relaxed);
        head.saturating_sub(tail) as usize
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Record a span. Returns `false` (and counts a drop) when full —
    /// never blocks.
    pub fn push(&self, s: &TraceSpan) -> bool {
        let mut head = self.head.load(Ordering::Relaxed);
        loop {
            let slot = &self.slots[(head & self.mask) as usize];
            let seq = slot.seq.load(Ordering::Acquire);
            if seq == head {
                match self.head.compare_exchange_weak(
                    head,
                    head + 1,
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        let v = &slot.vals;
                        for (i, t) in [
                            s.t_entry,
                            s.t_select,
                            s.t_reuse,
                            s.t_enqueue,
                            s.t_dequeue,
                            s.t_batch,
                            s.t_exec_start,
                            s.t_exec_end,
                            s.t_complete,
                        ]
                        .into_iter()
                        .enumerate()
                        {
                            v[i].store(t, Ordering::Relaxed);
                        }
                        v[9].store(pack_flags(s), Ordering::Relaxed);
                        v[10].store(pack_wb(s), Ordering::Relaxed);
                        v[11].store(s.retries as u64, Ordering::Relaxed);
                        slot.seq.store(head + 1, Ordering::Release);
                        self.pushed.fetch_add(1, Ordering::Relaxed);
                        return true;
                    }
                    Err(h) => head = h,
                }
            } else if seq < head {
                // A full lap behind: the ring is full.
                self.dropped.fetch_add(1, Ordering::Relaxed);
                return false;
            } else {
                head = self.head.load(Ordering::Relaxed);
            }
        }
    }

    /// Drain one span (tests / exporters). Lock-free; safe with multiple
    /// consumers.
    pub fn pop(&self) -> Option<TraceSpan> {
        let mut tail = self.tail.load(Ordering::Relaxed);
        loop {
            let slot = &self.slots[(tail & self.mask) as usize];
            let seq = slot.seq.load(Ordering::Acquire);
            if seq == tail + 1 {
                match self.tail.compare_exchange_weak(
                    tail,
                    tail + 1,
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => {
                        let v = &slot.vals;
                        let flags = v[9].load(Ordering::Relaxed);
                        let wb = v[10].load(Ordering::Relaxed);
                        let s = TraceSpan {
                            t_entry: v[0].load(Ordering::Relaxed),
                            t_select: v[1].load(Ordering::Relaxed),
                            t_reuse: v[2].load(Ordering::Relaxed),
                            t_enqueue: v[3].load(Ordering::Relaxed),
                            t_dequeue: v[4].load(Ordering::Relaxed),
                            t_batch: v[5].load(Ordering::Relaxed),
                            t_exec_start: v[6].load(Ordering::Relaxed),
                            t_exec_end: v[7].load(Ordering::Relaxed),
                            t_complete: v[8].load(Ordering::Relaxed),
                            algo: flags as u8,
                            reason: (flags >> 8) as u8,
                            reuse: (flags >> 16) as u8,
                            outcome: (flags >> 24) as u8,
                            batch_size: wb as u32,
                            worker: (wb >> 32) as u32,
                            retries: v[11].load(Ordering::Relaxed) as u32,
                        };
                        slot.seq.store(tail + self.capacity, Ordering::Release);
                        return Some(s);
                    }
                    Err(t) => tail = t,
                }
            } else if seq < tail + 1 {
                return None; // empty
            } else {
                tail = self.tail.load(Ordering::Relaxed);
            }
        }
    }

    /// Drain everything currently poppable.
    pub fn drain(&self) -> Vec<TraceSpan> {
        let mut out = Vec::new();
        while let Some(s) = self.pop() {
            out.push(s);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(i: u64) -> TraceSpan {
        TraceSpan {
            t_entry: 10 + i,
            t_select: 12 + i,
            t_reuse: 13 + i,
            t_enqueue: 14 + i,
            t_dequeue: 20 + i,
            t_batch: 21 + i,
            t_exec_start: 22 + i,
            t_exec_end: 30 + i,
            t_complete: 32 + i,
            algo: ALGO_NT,
            reason: REASON_PREDICTED_NT,
            reuse: REUSE_LEAD,
            outcome: OUTCOME_COMPLETED,
            batch_size: 3,
            worker: 2,
            retries: 1,
        }
    }

    #[test]
    fn ring_roundtrip_preserves_every_field() {
        let r = SpanRing::new(64);
        let s = span(5);
        assert!(r.push(&s));
        assert_eq!(r.pop().unwrap(), s);
        assert!(r.pop().is_none());
        assert_eq!(r.pushed(), 1);
        assert_eq!(r.dropped(), 0);
    }

    #[test]
    fn ring_packs_extreme_flag_and_size_values() {
        let r = SpanRing::new(64);
        let s = TraceSpan {
            algo: 255,
            reason: 254,
            reuse: 253,
            outcome: 252,
            batch_size: u32::MAX,
            worker: u32::MAX,
            retries: u32::MAX,
            ..span(0)
        };
        assert!(r.push(&s));
        assert_eq!(r.pop().unwrap(), s);
    }

    #[test]
    fn full_ring_drops_instead_of_blocking() {
        let r = SpanRing::new(64);
        for i in 0..64 {
            assert!(r.push(&span(i)), "push {i}");
        }
        assert!(!r.push(&span(99)));
        assert_eq!(r.dropped(), 1);
        let mut n = 0;
        while r.pop().is_some() {
            n += 1;
        }
        assert_eq!(n, 64);
        assert!(r.push(&span(100)), "drained slots are reusable");
    }

    #[test]
    fn derived_durations_need_both_stamps() {
        let s = span(0);
        assert_eq!(s.queue_wait_us(), Some(6));
        assert_eq!(s.execute_us(), Some(8));
        assert_eq!(s.total_us(), Some(22));
        let hit = TraceSpan {
            t_enqueue: 0,
            t_dequeue: 0,
            t_exec_start: 0,
            t_exec_end: 0,
            ..span(0)
        };
        assert_eq!(hit.queue_wait_us(), None, "a reuse hit never queued");
        assert_eq!(hit.execute_us(), None);
        assert_eq!(hit.total_us(), Some(22));
    }

    #[test]
    fn cell_flattens_into_span() {
        let cell = SpanCell::new(Instant::now());
        cell.stamp_reuse(REUSE_LEAD);
        cell.stamp_enqueue();
        cell.stamp_dequeue();
        cell.stamp_batch(4, 2);
        cell.stamp_exec_start();
        cell.stamp_exec_end();
        let t_end = cell.now_us();
        let s = cell.to_span(1, 1, t_end, ALGO_TNN, REASON_PREDICTED_TNN, OUTCOME_COMPLETED, 2);
        assert_eq!(s.algo, ALGO_TNN);
        assert_eq!(s.reuse, REUSE_LEAD);
        assert_eq!(s.batch_size, 4);
        assert_eq!(s.worker, 2);
        assert_eq!(s.retries, 2);
        for t in [s.t_reuse, s.t_enqueue, s.t_dequeue, s.t_batch, s.t_exec_start, s.t_exec_end] {
            assert!(t >= 1, "stamps are clamped to >= 1");
        }
        // Monotone through the engine stages.
        assert!(s.t_reuse <= s.t_enqueue);
        assert!(s.t_enqueue <= s.t_dequeue);
        assert!(s.t_dequeue <= s.t_batch);
        assert!(s.t_batch <= s.t_exec_start);
        assert!(s.t_exec_start <= s.t_exec_end);
        assert!(s.queue_wait_us().unwrap() + s.execute_us().unwrap() <= s.total_us().unwrap());
    }

    #[test]
    fn unstamped_cell_yields_zeroed_stages() {
        let cell = SpanCell::new(Instant::now());
        let s = cell.to_span(5, 6, 9, ALGO_NT, REASON_FORCED, OUTCOME_FAILED, 0);
        assert_eq!(s.t_enqueue, 0);
        assert_eq!(s.queue_wait_us(), None);
        assert_eq!(s.execute_us(), None);
        assert_eq!(s.total_us(), Some(4));
        assert_eq!(s.reuse, REUSE_NONE);
    }

    #[test]
    fn span_json_names_algo_and_outcome() {
        let j = span(0).to_json();
        assert_eq!(j.get("algo").as_str(), Some("nt"));
        assert_eq!(j.get("outcome").as_str(), Some("completed"));
        assert_eq!(j.get("batch_size").as_f64(), Some(3.0));
        assert_eq!(j.get("retries").as_f64(), Some(1.0));
        assert_eq!(outcome_name(OUTCOME_TIMED_OUT), "timed_out");
    }
}
