//! Flight recorder: a bounded ring of the most recent *completed*
//! spans plus trigger-based dump capture. When a trigger condition
//! fires (request failure, load shed, p99 over threshold, mispredict
//! burst), the recorder freezes a copy of the ring — the spans that led
//! up to the event — into a `FlightDump` that can be rendered to JSON
//! and inspected after the fact. This is the "what happened just
//! before" instrument the lifetime counters cannot provide.
//!
//! Dumps are bounded (`max_dumps`) and rate-limited *per trigger
//! cause* (`cooldown_us` between captures of the same cause) so a
//! failure storm produces a handful of useful snapshots instead of
//! thousands of identical ones — while a `timeout` or `breaker_open`
//! incident arriving mid-storm still captures its own first dump
//! instead of being shadowed by the failure cooldown.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use super::span::TraceSpan;
use crate::util::json::Json;

/// One captured dump: the trigger that fired, when it fired (µs since
/// the obs epoch), and the ring contents at that moment (oldest first;
/// the last span is the one that tripped the trigger).
#[derive(Debug, Clone)]
pub struct FlightDump {
    pub trigger: String,
    pub at_us: u64,
    pub spans: Vec<TraceSpan>,
}

impl FlightDump {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("trigger", self.trigger.as_str())
            .set("at_us", self.at_us)
            .set("span_count", self.spans.len())
            .set(
                "spans",
                Json::Arr(self.spans.iter().map(|s| s.to_json()).collect()),
            )
    }
}

/// Bounded recent-span ring + bounded triggered-dump store.
///
/// Spans are ~100 bytes and `Copy`; the ring lives behind a plain
/// mutex because `observe` is called only for *sampled* spans at
/// completion time (never inside the engine hot path), and a dump is a
/// memcpy of at most `capacity` spans.
pub struct FlightRecorder {
    capacity: usize,
    max_dumps: usize,
    cooldown_us: u64,
    recent: Mutex<VecDeque<TraceSpan>>,
    dumps: Mutex<Vec<FlightDump>>,
    /// µs timestamp of the last capture *per trigger cause* (cooldown
    /// clocks); an absent cause has never captured.
    last_dump_us: Mutex<HashMap<String, u64>>,
    /// Triggers that fired, including ones suppressed by cooldown or
    /// the dump cap — observability for the observability layer.
    triggered: AtomicU64,
    captured: AtomicU64,
}

impl FlightRecorder {
    pub fn new(capacity: usize, max_dumps: usize, cooldown_us: u64) -> FlightRecorder {
        let capacity = capacity.max(8);
        FlightRecorder {
            capacity,
            max_dumps: max_dumps.max(1),
            cooldown_us,
            recent: Mutex::new(VecDeque::with_capacity(capacity)),
            dumps: Mutex::new(Vec::new()),
            last_dump_us: Mutex::new(HashMap::new()),
            triggered: AtomicU64::new(0),
            captured: AtomicU64::new(0),
        }
    }

    /// Append a completed span to the recent ring, evicting the oldest
    /// when full.
    pub fn observe(&self, span: TraceSpan) {
        let mut ring = self.recent.lock().unwrap();
        if ring.len() == self.capacity {
            ring.pop_front();
        }
        ring.push_back(span);
    }

    /// Fire a trigger at `now_us`. Captures a dump of the current ring
    /// unless within this *cause's* cooldown of its previous capture or
    /// the dump store is full. Returns true when a dump was actually
    /// captured.
    pub fn trigger(&self, name: &str, now_us: u64) -> bool {
        self.triggered.fetch_add(1, Ordering::Relaxed);
        // One capturer at a time; the dumps lock serializes the
        // per-cause cooldown check-and-set as well.
        let mut dumps = self.dumps.lock().unwrap();
        if dumps.len() >= self.max_dumps {
            return false;
        }
        {
            let mut clocks = self.last_dump_us.lock().unwrap();
            if let Some(&last) = clocks.get(name) {
                if now_us.saturating_sub(last) < self.cooldown_us {
                    return false;
                }
            }
            clocks.insert(name.to_string(), now_us.max(1));
        }
        let spans: Vec<TraceSpan> = self.recent.lock().unwrap().iter().copied().collect();
        dumps.push(FlightDump {
            trigger: name.to_string(),
            at_us: now_us,
            spans,
        });
        self.captured.fetch_add(1, Ordering::Relaxed);
        true
    }

    /// Copies of all captured dumps, oldest first.
    pub fn dumps(&self) -> Vec<FlightDump> {
        self.dumps.lock().unwrap().clone()
    }

    pub fn dump_count(&self) -> usize {
        self.dumps.lock().unwrap().len()
    }

    /// Total trigger firings, including suppressed ones.
    pub fn triggered(&self) -> u64 {
        self.triggered.load(Ordering::Relaxed)
    }

    pub fn captured(&self) -> u64 {
        self.captured.load(Ordering::Relaxed)
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::span::{OUTCOME_COMPLETED, OUTCOME_FAILED};

    fn span(t_entry: u64, outcome: u8) -> TraceSpan {
        TraceSpan {
            t_entry,
            t_complete: t_entry + 10,
            outcome,
            ..TraceSpan::default()
        }
    }

    #[test]
    fn ring_keeps_most_recent_spans() {
        let rec = FlightRecorder::new(8, 4, 0);
        for i in 0..20 {
            rec.observe(span(i + 1, OUTCOME_COMPLETED));
        }
        rec.trigger("failure", 1000);
        let dumps = rec.dumps();
        assert_eq!(dumps.len(), 1);
        assert_eq!(dumps[0].spans.len(), 8);
        assert_eq!(dumps[0].spans[0].t_entry, 13, "oldest surviving span");
        assert_eq!(dumps[0].spans[7].t_entry, 20, "newest span last");
    }

    #[test]
    fn dump_brackets_the_fault() {
        let rec = FlightRecorder::new(16, 4, 0);
        for i in 0..5 {
            rec.observe(span(100 + i, OUTCOME_COMPLETED));
        }
        rec.observe(span(200, OUTCOME_FAILED));
        assert!(rec.trigger("failure", 210));
        let d = &rec.dumps()[0];
        assert_eq!(d.trigger, "failure");
        let last = d.spans.last().unwrap();
        assert_eq!(last.outcome, OUTCOME_FAILED, "fault span is in the dump");
        assert!(
            d.spans.iter().any(|s| s.outcome == OUTCOME_COMPLETED),
            "spans preceding the fault are in the dump"
        );
    }

    #[test]
    fn cooldown_suppresses_rapid_retriggers() {
        let rec = FlightRecorder::new(8, 8, 1_000_000);
        rec.observe(span(1, OUTCOME_FAILED));
        assert!(rec.trigger("failure", 10));
        assert!(!rec.trigger("failure", 20), "inside cooldown");
        assert!(!rec.trigger("failure", 999_000), "still inside cooldown");
        assert!(rec.trigger("failure", 1_000_020), "cooldown elapsed");
        assert_eq!(rec.dump_count(), 2);
        assert_eq!(rec.triggered(), 4, "suppressed firings still counted");
        assert_eq!(rec.captured(), 2);
    }

    #[test]
    fn cooldowns_are_per_cause() {
        let rec = FlightRecorder::new(8, 8, 1_000_000);
        rec.observe(span(1, OUTCOME_FAILED));
        assert!(rec.trigger("failure", 10));
        assert!(!rec.trigger("failure", 20), "same cause inside cooldown");
        assert!(
            rec.trigger("timeout", 30),
            "a different cause has its own cooldown clock"
        );
        assert!(rec.trigger("breaker_open", 40));
        assert!(!rec.trigger("timeout", 50), "now timeout is cooling down");
        assert_eq!(rec.dump_count(), 3);
        let dumps = rec.dumps();
        let causes: Vec<&str> = dumps.iter().map(|d| d.trigger.as_str()).collect();
        assert_eq!(causes, vec!["failure", "timeout", "breaker_open"]);
    }

    #[test]
    fn dump_store_is_bounded() {
        let rec = FlightRecorder::new(8, 2, 0);
        rec.observe(span(1, OUTCOME_FAILED));
        assert!(rec.trigger("a", 10));
        assert!(rec.trigger("b", 20));
        assert!(!rec.trigger("c", 30), "store full");
        assert_eq!(rec.dump_count(), 2);
    }

    #[test]
    fn dump_json_shape() {
        let rec = FlightRecorder::new(8, 2, 0);
        rec.observe(span(5, OUTCOME_FAILED));
        rec.trigger("shed", 42);
        let j = rec.dumps()[0].to_json();
        assert_eq!(j.get("trigger").and_then(|t| t.as_str()), Some("shed"));
        assert_eq!(j.get("at_us").and_then(|t| t.as_usize()), Some(42));
        assert_eq!(j.get("span_count").and_then(|t| t.as_usize()), Some(1));
    }
}
