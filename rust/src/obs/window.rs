//! Windowed rates: rotating time-bucket counters over the serving
//! counters, so operators see *current* req/s, shed rate, reuse-hit
//! rate, probe rate, and mispredict rate instead of lifetime ratios
//! (which flatten out exactly when the workload lab's regime changes
//! make the live rates interesting).
//!
//! The registry is a fixed array of buckets, each owning one time slice
//! of `bucket_ms` and a stamp recording *which* slice it currently
//! holds. Recording hashes the current slice index onto a bucket; a
//! bucket whose stamp is stale is zeroed and re-stamped before the
//! increment (lazy rotation — no background thread). Reading sums every
//! bucket whose stamp still falls inside the window.
//!
//! Concurrency note: rotation (`swap` + zeroing) races with concurrent
//! increments — an increment can land between the swap and the zeroing
//! and be lost, or land on the old slice and survive into the new one.
//! Both windows are a few events wide at a bucket boundary; this is
//! telemetry, and the lifetime counters in `CoordinatorMetrics` stay
//! exact. The trade buys a hot path of one load + one `fetch_add`.

use std::sync::atomic::{AtomicU64, Ordering};

/// What a window bucket counts. Index into each bucket's count array.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WindowKind {
    Requests = 0,
    Completed = 1,
    Shed = 2,
    ReuseHit = 3,
    Probe = 4,
    Mispredict = 5,
    TimedOut = 6,
    Retry = 7,
    BreakerOpen = 8,
}

const KINDS: usize = 9;

/// Stamp value meaning "this bucket has never held any slice".
const NEVER: u64 = u64::MAX;

struct Bucket {
    /// Slice index (`now_ms / bucket_ms`) this bucket currently holds.
    stamp: AtomicU64,
    counts: [AtomicU64; KINDS],
}

/// Rotating time-bucket rate windows.
pub struct RateWindows {
    bucket_ms: u64,
    buckets: Box<[Bucket]>,
}

/// Point-in-time rates over the last window. Rates whose denominator is
/// zero are reported as 0.0 (a quiet window is a zero rate, not NaN).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct WindowRates {
    /// Seconds of window actually covered (≤ buckets × bucket_ms / 1000;
    /// the current bucket counts only its elapsed fraction).
    pub window_secs: f64,
    pub requests: u64,
    pub completed: u64,
    pub shed: u64,
    pub reuse_hits: u64,
    pub probes: u64,
    pub mispredicts: u64,
    pub timed_out: u64,
    pub retries: u64,
    pub breaker_opens: u64,
    pub req_per_s: f64,
    /// `shed / requests` within the window.
    pub shed_rate: f64,
    /// `reuse_hits / completed` within the window.
    pub reuse_hit_rate: f64,
    /// `probes / requests` within the window.
    pub probe_rate: f64,
    /// `mispredicts / probes` within the window.
    pub mispredict_rate: f64,
    /// `timed_out / requests` within the window.
    pub timeout_rate: f64,
    /// `retries / requests` within the window (can exceed 1: a request
    /// may retry more than once).
    pub retry_rate: f64,
    /// `breaker_opens / requests` within the window (breaker-open
    /// fail-fast rejections, not trip events).
    pub breaker_open_rate: f64,
}

fn ratio(num: u64, den: u64) -> f64 {
    if den == 0 {
        0.0
    } else {
        num as f64 / den as f64
    }
}

impl RateWindows {
    /// `buckets` slices of `bucket_ms` each (window = buckets × bucket_ms).
    /// Minimums of 2 buckets / 1 ms keep the arithmetic non-degenerate.
    pub fn new(bucket_ms: u64, buckets: usize) -> RateWindows {
        RateWindows {
            bucket_ms: bucket_ms.max(1),
            buckets: (0..buckets.max(2))
                .map(|_| Bucket {
                    stamp: AtomicU64::new(NEVER),
                    counts: std::array::from_fn(|_| AtomicU64::new(0)),
                })
                .collect(),
        }
    }

    /// Count one event at `now_ms` (milliseconds since the layer epoch).
    pub fn record_at(&self, kind: WindowKind, now_ms: u64) {
        let idx = now_ms / self.bucket_ms;
        let b = &self.buckets[(idx % self.buckets.len() as u64) as usize];
        if b.stamp.load(Ordering::Acquire) != idx {
            // First writer of the new slice zeroes the stale counts; the
            // swap makes sure exactly one writer does.
            if b.stamp.swap(idx, Ordering::AcqRel) != idx {
                for c in &b.counts {
                    c.store(0, Ordering::Relaxed);
                }
            }
        }
        b.counts[kind as usize].fetch_add(1, Ordering::Relaxed);
    }

    /// Rates over every bucket still inside the window ending at `now_ms`.
    pub fn rates_at(&self, now_ms: u64) -> WindowRates {
        let n = self.buckets.len() as u64;
        let idx_now = now_ms / self.bucket_ms;
        let mut sums = [0u64; KINDS];
        let mut covered_ms = 0u64;
        for b in self.buckets.iter() {
            let stamp = b.stamp.load(Ordering::Acquire);
            if stamp == NEVER || stamp > idx_now || idx_now - stamp >= n {
                continue; // never used, or rotated out of the window
            }
            for (s, c) in sums.iter_mut().zip(&b.counts) {
                *s += c.load(Ordering::Relaxed);
            }
            covered_ms += if stamp == idx_now {
                (now_ms % self.bucket_ms) + 1 // current bucket: partial
            } else {
                self.bucket_ms
            };
        }
        let window_secs = covered_ms as f64 / 1e3;
        WindowRates {
            window_secs,
            requests: sums[WindowKind::Requests as usize],
            completed: sums[WindowKind::Completed as usize],
            shed: sums[WindowKind::Shed as usize],
            reuse_hits: sums[WindowKind::ReuseHit as usize],
            probes: sums[WindowKind::Probe as usize],
            mispredicts: sums[WindowKind::Mispredict as usize],
            timed_out: sums[WindowKind::TimedOut as usize],
            retries: sums[WindowKind::Retry as usize],
            breaker_opens: sums[WindowKind::BreakerOpen as usize],
            req_per_s: if covered_ms == 0 {
                0.0
            } else {
                sums[WindowKind::Requests as usize] as f64 / window_secs
            },
            shed_rate: ratio(
                sums[WindowKind::Shed as usize],
                sums[WindowKind::Requests as usize],
            ),
            reuse_hit_rate: ratio(
                sums[WindowKind::ReuseHit as usize],
                sums[WindowKind::Completed as usize],
            ),
            probe_rate: ratio(
                sums[WindowKind::Probe as usize],
                sums[WindowKind::Requests as usize],
            ),
            mispredict_rate: ratio(
                sums[WindowKind::Mispredict as usize],
                sums[WindowKind::Probe as usize],
            ),
            timeout_rate: ratio(
                sums[WindowKind::TimedOut as usize],
                sums[WindowKind::Requests as usize],
            ),
            retry_rate: ratio(
                sums[WindowKind::Retry as usize],
                sums[WindowKind::Requests as usize],
            ),
            breaker_open_rate: ratio(
                sums[WindowKind::BreakerOpen as usize],
                sums[WindowKind::Requests as usize],
            ),
        }
    }

    /// Window span in milliseconds (buckets × bucket_ms).
    pub fn span_ms(&self) -> u64 {
        self.bucket_ms * self.buckets.len() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_windows_report_zero_not_nan() {
        let w = RateWindows::new(1000, 8);
        let r = w.rates_at(0);
        assert_eq!(r.requests, 0);
        assert_eq!(r.req_per_s, 0.0);
        assert_eq!(r.shed_rate, 0.0);
        assert_eq!(r.mispredict_rate, 0.0);
        assert_eq!(r.window_secs, 0.0);
    }

    #[test]
    fn rates_reflect_only_the_window() {
        let w = RateWindows::new(1000, 4);
        // 10 requests in slice 0, then nothing for 10 slices.
        for _ in 0..10 {
            w.record_at(WindowKind::Requests, 500);
        }
        let r = w.rates_at(999);
        assert_eq!(r.requests, 10);
        assert!((r.window_secs - 1.0).abs() < 1e-9, "{}", r.window_secs);
        assert!((r.req_per_s - 10.0).abs() < 1e-9, "{}", r.req_per_s);
        // 10 slices later the slice-0 bucket is outside the 4-slice window.
        let r = w.rates_at(10_500);
        assert_eq!(r.requests, 0, "old traffic rotated out");
    }

    #[test]
    fn known_phase_rate_converges() {
        // 100 req/s for 5 s into 1 s × 8 buckets: the window rate must
        // report ~100 req/s over the last full buckets.
        let w = RateWindows::new(1000, 8);
        let mut now = 0u64;
        for _ in 0..500 {
            w.record_at(WindowKind::Requests, now);
            now += 10; // one request every 10 ms
        }
        let r = w.rates_at(now - 1);
        assert!(
            (r.req_per_s - 100.0).abs() < 5.0,
            "req_per_s={} window={}s",
            r.req_per_s,
            r.window_secs
        );
    }

    #[test]
    fn stale_bucket_is_zeroed_on_reuse() {
        let w = RateWindows::new(100, 2); // slice i lands on bucket i % 2
        w.record_at(WindowKind::Requests, 50); // slice 0 → bucket 0
        w.record_at(WindowKind::Requests, 150); // slice 1 → bucket 1
        // Slice 2 reuses bucket 0: the old count must not leak in.
        w.record_at(WindowKind::Requests, 250);
        let r = w.rates_at(299);
        assert_eq!(r.requests, 2, "slices 1 and 2 only");
    }

    #[test]
    fn derived_rates_divide_the_right_counters() {
        let w = RateWindows::new(1000, 8);
        for _ in 0..10 {
            w.record_at(WindowKind::Requests, 100);
        }
        for _ in 0..6 {
            w.record_at(WindowKind::Completed, 100);
        }
        for _ in 0..4 {
            w.record_at(WindowKind::Shed, 100);
        }
        for _ in 0..3 {
            w.record_at(WindowKind::ReuseHit, 100);
        }
        for _ in 0..2 {
            w.record_at(WindowKind::Probe, 100);
        }
        w.record_at(WindowKind::Mispredict, 100);
        for _ in 0..2 {
            w.record_at(WindowKind::TimedOut, 100);
        }
        for _ in 0..5 {
            w.record_at(WindowKind::Retry, 100);
        }
        w.record_at(WindowKind::BreakerOpen, 100);
        let r = w.rates_at(100);
        assert!((r.shed_rate - 0.4).abs() < 1e-12);
        assert!((r.reuse_hit_rate - 0.5).abs() < 1e-12);
        assert!((r.probe_rate - 0.2).abs() < 1e-12);
        assert!((r.mispredict_rate - 0.5).abs() < 1e-12);
        assert!((r.timeout_rate - 0.2).abs() < 1e-12);
        assert!((r.retry_rate - 0.5).abs() < 1e-12);
        assert!((r.breaker_open_rate - 0.1).abs() < 1e-12);
        assert_eq!(r.timed_out, 2);
        assert_eq!(r.retries, 5);
        assert_eq!(r.breaker_opens, 1);
    }

    #[test]
    fn partial_current_bucket_scales_the_denominator() {
        let w = RateWindows::new(1000, 8);
        // 50 requests within the first 500 ms of the current bucket.
        for i in 0..50 {
            w.record_at(WindowKind::Requests, i * 10);
        }
        let r = w.rates_at(499);
        assert!((r.window_secs - 0.5).abs() < 1e-9, "{}", r.window_secs);
        assert!((r.req_per_s - 100.0).abs() < 1.0, "{}", r.req_per_s);
    }
}
