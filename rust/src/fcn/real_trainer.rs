//! Real FCN training — through the AOT train-step artifacts on PJRT, or
//! natively on the blocked CPU GEMM backend ([`train_native`], the default
//! when no artifact catalog is present). Holds parameters as host
//! matrices, generates a synthetic MNIST-like dataset, and steps the train
//! step; the per-layer {NT, TNN} plan is chosen by the Rust-side selector
//! against a simulated GPU, proving the full L3 → L2 → L1 stack composes
//! with MTNN in the loop. The native path issues exactly Caffe's
//! InnerProduct GEMM sequence (see [`super::gemm_seq`]): NT forwards
//! (routed per plan to the direct-NT or transpose-then-NN blocked kernel),
//! NN data gradients, TN weight gradients.

use super::config::{e2e_config, FcnConfig, E2E_BATCH};
use crate::gemm::cpu::Matrix;
use crate::gemm::{blocked, Algorithm};
use crate::gpusim::GpuSpec;
use crate::runtime::Runtime;
use crate::selector::Selector;
use crate::util::rng::Xoshiro256pp;

/// A synthetic classification dataset shaped like MNIST (f32 features in
/// [0,1), one-hot labels) with a learnable linear-ish structure: each
/// class has a random prototype and samples are noisy prototypes, so a
/// small MLP can fit it quickly — the loss curve must visibly fall.
pub struct SyntheticMnist {
    pub x: Matrix,
    pub y_onehot: Matrix,
    pub labels: Vec<usize>,
}

impl SyntheticMnist {
    pub fn generate(n: usize, in_dim: usize, n_classes: usize, seed: u64) -> SyntheticMnist {
        let mut rng = Xoshiro256pp::new(seed);
        // Class prototypes.
        let protos: Vec<Vec<f32>> = (0..n_classes)
            .map(|_| (0..in_dim).map(|_| rng.next_f32()).collect())
            .collect();
        let mut x = Matrix::zeros(n, in_dim);
        let mut y = Matrix::zeros(n, n_classes);
        let mut labels = Vec::with_capacity(n);
        for i in 0..n {
            let c = rng.next_range(0, n_classes);
            labels.push(c);
            y.set(i, c, 1.0);
            for j in 0..in_dim {
                let noise = (rng.next_f32() - 0.5) * 0.6;
                x.set(i, j, (protos[c][j] + noise).clamp(0.0, 1.0));
            }
        }
        SyntheticMnist {
            x,
            y_onehot: y,
            labels,
        }
    }

    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Copy minibatch `b` (wrapping) into (x, y) matrices.
    pub fn batch(&self, b: usize, mb: usize) -> (Matrix, Matrix) {
        let in_dim = self.x.cols;
        let n_classes = self.y_onehot.cols;
        let mut x = Matrix::zeros(mb, in_dim);
        let mut y = Matrix::zeros(mb, n_classes);
        for r in 0..mb {
            let src = (b * mb + r) % self.len();
            x.data[r * in_dim..(r + 1) * in_dim]
                .copy_from_slice(&self.x.data[src * in_dim..(src + 1) * in_dim]);
            y.data[r * n_classes..(r + 1) * n_classes].copy_from_slice(
                &self.y_onehot.data[src * n_classes..(src + 1) * n_classes],
            );
        }
        (x, y)
    }
}

/// He-style deterministic parameter init matching `model.init_params`
/// semantics (not bit-identical — training converges from any sane init).
pub fn init_params(cfg: &FcnConfig, seed: u64) -> Vec<Matrix> {
    let mut rng = Xoshiro256pp::new(seed);
    let mut out = Vec::new();
    for (fan_in, fan_out) in cfg.layers() {
        let std = (2.0 / fan_in as f64).sqrt() as f32;
        let mut w = Matrix::zeros(fan_out as usize, fan_in as usize);
        for v in &mut w.data {
            *v = rng.next_gaussian() as f32 * std;
        }
        out.push(w);
        out.push(Matrix::zeros(1, fan_out as usize)); // bias as 1×out
    }
    out
}

/// Choose the per-layer plan with the selector against a simulated GPU:
/// layer i's forward NT op has shape (mb, out, in).
pub fn select_plan(sel: &Selector, gpu: &GpuSpec, cfg: &FcnConfig, mb: u64) -> Vec<Algorithm> {
    cfg.layers()
        .iter()
        .map(|&(fan_in, fan_out)| sel.select(gpu, mb, fan_out, fan_in).0)
        .collect()
}

/// Artifact name for a plan, e.g. "fcn_train_nt-tnn-nt".
pub fn plan_artifact(prefix: &str, plan: &[Algorithm]) -> String {
    let tags: Vec<&str> = plan
        .iter()
        .map(|a| match a {
            Algorithm::Nt => "nt",
            Algorithm::Tnn => "tnn",
            Algorithm::Nn => panic!("NN is not a plan entry"),
        })
        .collect();
    format!("{prefix}_{}", tags.join("-"))
}

/// Outcome of a training run.
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub losses: Vec<f32>,
    pub steps: usize,
    pub artifact: String,
    pub total_wall: std::time::Duration,
    pub step_wall_ms: Vec<f64>,
}

/// Train the e2e FCN for `steps` minibatches with a fixed plan.
pub fn train(
    rt: &Runtime,
    plan: &[Algorithm],
    steps: usize,
    seed: u64,
) -> anyhow::Result<TrainReport> {
    let cfg = e2e_config();
    anyhow::ensure!(
        plan.len() == cfg.n_layers(),
        "plan arity {} != {} layers",
        plan.len(),
        cfg.n_layers()
    );
    let artifact = plan_artifact("fcn_train", plan);
    let data = SyntheticMnist::generate(
        1024,
        cfg.dims[0] as usize,
        *cfg.dims.last().unwrap() as usize,
        seed,
    );
    let mut params = init_params(&cfg, seed ^ 0x5EED);
    let mut losses = Vec::with_capacity(steps);
    let mut step_wall_ms = Vec::with_capacity(steps);
    let t0 = std::time::Instant::now();
    for step in 0..steps {
        let (x, y) = data.batch(step, E2E_BATCH as usize);
        let mut inputs: Vec<&Matrix> = params.iter().collect();
        inputs.push(&x);
        inputs.push(&y);
        let ts = std::time::Instant::now();
        let mut outs = rt.execute(&artifact, &inputs)?;
        step_wall_ms.push(ts.elapsed().as_secs_f64() * 1e3);
        let loss = outs.pop().expect("train step returns loss last").data[0];
        anyhow::ensure!(loss.is_finite(), "loss diverged at step {step}: {loss}");
        params = outs;
        losses.push(loss);
    }
    Ok(TrainReport {
        losses,
        steps,
        artifact,
        total_wall: t0.elapsed(),
        step_wall_ms,
    })
}

// ---- native backend ---------------------------------------------------------

/// SGD step size of the native trainer.
const NATIVE_LR: f32 = 0.2;

/// Run one forward NT op under the plan's algorithm on the blocked backend.
fn plan_matmul(h: &Matrix, w: &Matrix, algo: Algorithm) -> Matrix {
    match algo {
        Algorithm::Nt => blocked::matmul_nt(h, w),
        Algorithm::Tnn => blocked::matmul_tnn(h, w),
        Algorithm::Nn => panic!("NN is not a plan entry"),
    }
}

/// One native train step: relu-MLP forward, softmax cross-entropy,
/// backward, in-place SGD. Issues exactly Caffe's GEMM sequence — forward
/// NT per `plan`, backward-data NN, backward-weights TN (transpose + NN,
/// the same out-of-place-transpose trick as Algorithm 1).
fn native_step(
    params: &mut [Matrix],
    x: &Matrix,
    y: &Matrix,
    plan: &[Algorithm],
    lr: f32,
) -> anyhow::Result<f32> {
    let n_layers = plan.len();
    let mb = x.rows;
    // Forward: acts[0] = x, acts[i+1] = layer i output (relu except last).
    let mut acts: Vec<Matrix> = Vec::with_capacity(n_layers + 1);
    acts.push(x.clone());
    for (i, &algo) in plan.iter().enumerate() {
        let w = &params[2 * i];
        let b = &params[2 * i + 1];
        let mut z = plan_matmul(acts.last().expect("nonempty"), w, algo);
        for r in 0..z.rows {
            let row = &mut z.data[r * b.cols..(r + 1) * b.cols];
            for (v, &bv) in row.iter_mut().zip(&b.data) {
                *v += bv;
            }
        }
        if i + 1 < n_layers {
            for v in &mut z.data {
                if *v < 0.0 {
                    *v = 0.0;
                }
            }
        }
        acts.push(z);
    }
    // Softmax cross-entropy (mean over the batch) and logits gradient.
    let logits = acts.last().expect("nonempty");
    let classes = logits.cols;
    let mut dz = Matrix::zeros(mb, classes);
    let mut loss_sum = 0.0f64;
    for r in 0..mb {
        let row = &logits.data[r * classes..(r + 1) * classes];
        let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for &v in row {
            sum += (v - max).exp();
        }
        let log_sum = sum.ln() + max;
        for c in 0..classes {
            let t = y.at(r, c);
            let log_p = row[c] - log_sum;
            dz.data[r * classes + c] = (log_p.exp() - t) / mb as f32;
            if t > 0.0 {
                loss_sum -= log_p as f64;
            }
        }
    }
    let loss = (loss_sum / mb as f64) as f32;
    anyhow::ensure!(loss.is_finite(), "native loss diverged: {loss}");
    // Backward + SGD, layer by layer from the top.
    let mut dz = dz;
    for i in (0..n_layers).rev() {
        let h_prev = &acts[i];
        // dW[out,in] = dzᵀ[out,mb] × h_prev[mb,in] — the TN call, with the
        // transpose landing in thread-local scratch instead of a fresh
        // allocation every step.
        let dw = blocked::matmul_tn(&dz, h_prev);
        let out_dim = dz.cols;
        let mut db = vec![0.0f32; out_dim];
        for r in 0..dz.rows {
            for (c, dbv) in db.iter_mut().enumerate() {
                *dbv += dz.data[r * out_dim + c];
            }
        }
        // dH[mb,in] = dz[mb,out] × W[out,in] — the NN call — masked by the
        // previous layer's relu. Skipped for the input layer like Caffe.
        let prop = if i > 0 {
            let mut dh = blocked::matmul_nn(&dz, &params[2 * i]);
            for (dv, &hv) in dh.data.iter_mut().zip(&acts[i].data) {
                if hv <= 0.0 {
                    *dv = 0.0;
                }
            }
            Some(dh)
        } else {
            None
        };
        let w = &mut params[2 * i];
        for (wv, &gv) in w.data.iter_mut().zip(&dw.data) {
            *wv -= lr * gv;
        }
        let b = &mut params[2 * i + 1];
        for (bv, &gv) in b.data.iter_mut().zip(&db) {
            *bv -= lr * gv;
        }
        if let Some(dh) = prop {
            dz = dh;
        }
    }
    Ok(loss)
}

/// Train the e2e FCN natively on the blocked CPU GEMM backend — the
/// default execution path when no PJRT artifact catalog is present. Same
/// dataset, init, and plan semantics as [`train`].
pub fn train_native(plan: &[Algorithm], steps: usize, seed: u64) -> anyhow::Result<TrainReport> {
    let cfg = e2e_config();
    anyhow::ensure!(
        plan.len() == cfg.n_layers(),
        "plan arity {} != {} layers",
        plan.len(),
        cfg.n_layers()
    );
    let artifact = plan_artifact("fcn_train_native", plan);
    // Spawn the persistent GEMM pool and pre-size its packing scratch once,
    // so step timings measure kernels rather than first-call warmup.
    blocked::prewarm();
    let data = SyntheticMnist::generate(
        1024,
        cfg.dims[0] as usize,
        *cfg.dims.last().unwrap() as usize,
        seed,
    );
    let mut params = init_params(&cfg, seed ^ 0x5EED);
    let mut losses = Vec::with_capacity(steps);
    let mut step_wall_ms = Vec::with_capacity(steps);
    let t0 = std::time::Instant::now();
    for step in 0..steps {
        let (x, y) = data.batch(step, E2E_BATCH as usize);
        let ts = std::time::Instant::now();
        let loss = native_step(&mut params, &x, &y, plan, NATIVE_LR)?;
        step_wall_ms.push(ts.elapsed().as_secs_f64() * 1e3);
        anyhow::ensure!(loss.is_finite(), "loss diverged at step {step}: {loss}");
        losses.push(loss);
    }
    Ok(TrainReport {
        losses,
        steps,
        artifact,
        total_wall: t0.elapsed(),
        step_wall_ms,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::GTX1080;

    #[test]
    fn synthetic_data_is_wellformed() {
        let d = SyntheticMnist::generate(64, 20, 4, 9);
        assert_eq!(d.len(), 64);
        assert_eq!(d.x.rows, 64);
        // One-hot rows sum to 1.
        for r in 0..d.len() {
            let s: f32 = (0..4).map(|c| d.y_onehot.at(r, c)).sum();
            assert_eq!(s, 1.0);
        }
        // Features in [0, 1].
        assert!(d.x.data.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn batches_wrap_and_copy() {
        let d = SyntheticMnist::generate(10, 5, 2, 1);
        let (x, y) = d.batch(0, 4);
        assert_eq!((x.rows, x.cols), (4, 5));
        assert_eq!((y.rows, y.cols), (4, 2));
        // Wrapping batch reads the same rows as the start.
        let (x2, _) = d.batch(5, 4); // offset 20 ≡ 0 mod 10
        assert_eq!(x.data, x2.data);
    }

    #[test]
    fn init_param_shapes() {
        let cfg = e2e_config();
        let p = init_params(&cfg, 3);
        assert_eq!(p.len(), 2 * cfg.n_layers());
        assert_eq!((p[0].rows, p[0].cols), (512, 784));
        assert_eq!((p[1].rows, p[1].cols), (1, 512));
    }

    #[test]
    fn plan_artifact_names() {
        use Algorithm::*;
        assert_eq!(
            plan_artifact("fcn_train", &[Nt, Tnn, Nt]),
            "fcn_train_nt-tnn-nt"
        );
    }

    #[test]
    fn selected_plan_has_layer_arity() {
        let sel = Selector::train_default(&crate::dataset::collect_paper_dataset());
        let cfg = e2e_config();
        let plan = select_plan(&sel, &GTX1080, &cfg, 128);
        assert_eq!(plan.len(), cfg.n_layers());
        assert!(plan
            .iter()
            .all(|a| matches!(a, Algorithm::Nt | Algorithm::Tnn)));
    }

    #[test]
    fn native_training_reduces_loss() {
        // No artifacts required: the blocked-GEMM backend trains for real.
        let report = train_native(&[Algorithm::Nt; 3], 50, 7).unwrap();
        assert_eq!(report.losses.len(), 50);
        let first = report.losses[0];
        let last = *report.losses.last().unwrap();
        assert!(first.is_finite() && last.is_finite());
        // 10-way init loss ≈ ln(10); it must clearly fall on this easy data.
        assert!(first < 10.0, "init loss {first} looks broken");
        assert!(
            last < first * 0.85,
            "native loss should fall clearly: {first} → {last}"
        );
        assert!(report.artifact.starts_with("fcn_train_native_"));
    }

    #[test]
    fn native_nt_and_tnn_plans_are_bit_identical() {
        // Blocked NT and TNN feed identical packed panels to the same
        // kernel, so whole training trajectories agree exactly. Pin the
        // kernel choice so a concurrent forced-kernel test section can't
        // flip SIMD↔scalar between the two runs.
        crate::gemm::kernels::with_forced_kernel(None, || {
            let nt = train_native(&[Algorithm::Nt; 3], 5, 3).unwrap();
            let tnn = train_native(&[Algorithm::Tnn; 3], 5, 3).unwrap();
            assert_eq!(nt.losses, tnn.losses);
        });
    }

    #[test]
    fn native_selector_driven_plan_trains() {
        let sel = Selector::train_default(&crate::dataset::collect_paper_dataset());
        let plan = select_plan(&sel, &GTX1080, &e2e_config(), 128);
        let report = train_native(&plan, 3, 11).unwrap();
        assert!(report.losses.iter().all(|l| l.is_finite()));
    }

    #[test]
    fn native_plan_arity_is_validated() {
        let err = train_native(&[Algorithm::Nt], 1, 1).unwrap_err().to_string();
        assert!(err.contains("plan arity"), "{err}");
    }
}
