//! Real FCN training through the AOT train-step artifacts on PJRT — the
//! engine behind examples/train_fcn.rs. Holds parameters as host matrices,
//! generates a synthetic MNIST-like dataset, and steps the compiled
//! train-step executable; the per-layer {NT, TNN} plan is chosen by the
//! Rust-side selector against a simulated GPU, proving the full
//! L3 → L2 → L1 stack composes with MTNN in the loop.

use super::config::{e2e_config, FcnConfig, E2E_BATCH};
use crate::gemm::cpu::Matrix;
use crate::gemm::Algorithm;
use crate::gpusim::GpuSpec;
use crate::runtime::Runtime;
use crate::selector::Selector;
use crate::util::rng::Xoshiro256pp;

/// A synthetic classification dataset shaped like MNIST (f32 features in
/// [0,1), one-hot labels) with a learnable linear-ish structure: each
/// class has a random prototype and samples are noisy prototypes, so a
/// small MLP can fit it quickly — the loss curve must visibly fall.
pub struct SyntheticMnist {
    pub x: Matrix,
    pub y_onehot: Matrix,
    pub labels: Vec<usize>,
}

impl SyntheticMnist {
    pub fn generate(n: usize, in_dim: usize, n_classes: usize, seed: u64) -> SyntheticMnist {
        let mut rng = Xoshiro256pp::new(seed);
        // Class prototypes.
        let protos: Vec<Vec<f32>> = (0..n_classes)
            .map(|_| (0..in_dim).map(|_| rng.next_f32()).collect())
            .collect();
        let mut x = Matrix::zeros(n, in_dim);
        let mut y = Matrix::zeros(n, n_classes);
        let mut labels = Vec::with_capacity(n);
        for i in 0..n {
            let c = rng.next_range(0, n_classes);
            labels.push(c);
            y.set(i, c, 1.0);
            for j in 0..in_dim {
                let noise = (rng.next_f32() - 0.5) * 0.6;
                x.set(i, j, (protos[c][j] + noise).clamp(0.0, 1.0));
            }
        }
        SyntheticMnist {
            x,
            y_onehot: y,
            labels,
        }
    }

    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Copy minibatch `b` (wrapping) into (x, y) matrices.
    pub fn batch(&self, b: usize, mb: usize) -> (Matrix, Matrix) {
        let in_dim = self.x.cols;
        let n_classes = self.y_onehot.cols;
        let mut x = Matrix::zeros(mb, in_dim);
        let mut y = Matrix::zeros(mb, n_classes);
        for r in 0..mb {
            let src = (b * mb + r) % self.len();
            x.data[r * in_dim..(r + 1) * in_dim]
                .copy_from_slice(&self.x.data[src * in_dim..(src + 1) * in_dim]);
            y.data[r * n_classes..(r + 1) * n_classes].copy_from_slice(
                &self.y_onehot.data[src * n_classes..(src + 1) * n_classes],
            );
        }
        (x, y)
    }
}

/// He-style deterministic parameter init matching `model.init_params`
/// semantics (not bit-identical — training converges from any sane init).
pub fn init_params(cfg: &FcnConfig, seed: u64) -> Vec<Matrix> {
    let mut rng = Xoshiro256pp::new(seed);
    let mut out = Vec::new();
    for (fan_in, fan_out) in cfg.layers() {
        let std = (2.0 / fan_in as f64).sqrt() as f32;
        let mut w = Matrix::zeros(fan_out as usize, fan_in as usize);
        for v in &mut w.data {
            *v = rng.next_gaussian() as f32 * std;
        }
        out.push(w);
        out.push(Matrix::zeros(1, fan_out as usize)); // bias as 1×out
    }
    out
}

/// Choose the per-layer plan with the selector against a simulated GPU:
/// layer i's forward NT op has shape (mb, out, in).
pub fn select_plan(sel: &Selector, gpu: &GpuSpec, cfg: &FcnConfig, mb: u64) -> Vec<Algorithm> {
    cfg.layers()
        .iter()
        .map(|&(fan_in, fan_out)| sel.select(gpu, mb, fan_out, fan_in).0)
        .collect()
}

/// Artifact name for a plan, e.g. "fcn_train_nt-tnn-nt".
pub fn plan_artifact(prefix: &str, plan: &[Algorithm]) -> String {
    let tags: Vec<&str> = plan
        .iter()
        .map(|a| match a {
            Algorithm::Nt => "nt",
            Algorithm::Tnn => "tnn",
            Algorithm::Nn => panic!("NN is not a plan entry"),
        })
        .collect();
    format!("{prefix}_{}", tags.join("-"))
}

/// Outcome of a training run.
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub losses: Vec<f32>,
    pub steps: usize,
    pub artifact: String,
    pub total_wall: std::time::Duration,
    pub step_wall_ms: Vec<f64>,
}

/// Train the e2e FCN for `steps` minibatches with a fixed plan.
pub fn train(
    rt: &Runtime,
    plan: &[Algorithm],
    steps: usize,
    seed: u64,
) -> anyhow::Result<TrainReport> {
    let cfg = e2e_config();
    anyhow::ensure!(
        plan.len() == cfg.n_layers(),
        "plan arity {} != {} layers",
        plan.len(),
        cfg.n_layers()
    );
    let artifact = plan_artifact("fcn_train", plan);
    let data = SyntheticMnist::generate(
        1024,
        cfg.dims[0] as usize,
        *cfg.dims.last().unwrap() as usize,
        seed,
    );
    let mut params = init_params(&cfg, seed ^ 0x5EED);
    let mut losses = Vec::with_capacity(steps);
    let mut step_wall_ms = Vec::with_capacity(steps);
    let t0 = std::time::Instant::now();
    for step in 0..steps {
        let (x, y) = data.batch(step, E2E_BATCH as usize);
        let mut inputs: Vec<&Matrix> = params.iter().collect();
        inputs.push(&x);
        inputs.push(&y);
        let ts = std::time::Instant::now();
        let mut outs = rt.execute(&artifact, &inputs)?;
        step_wall_ms.push(ts.elapsed().as_secs_f64() * 1e3);
        let loss = outs.pop().expect("train step returns loss last").data[0];
        anyhow::ensure!(loss.is_finite(), "loss diverged at step {step}: {loss}");
        params = outs;
        losses.push(loss);
    }
    Ok(TrainReport {
        losses,
        steps,
        artifact,
        total_wall: t0.elapsed(),
        step_wall_ms,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::GTX1080;

    #[test]
    fn synthetic_data_is_wellformed() {
        let d = SyntheticMnist::generate(64, 20, 4, 9);
        assert_eq!(d.len(), 64);
        assert_eq!(d.x.rows, 64);
        // One-hot rows sum to 1.
        for r in 0..d.len() {
            let s: f32 = (0..4).map(|c| d.y_onehot.at(r, c)).sum();
            assert_eq!(s, 1.0);
        }
        // Features in [0, 1].
        assert!(d.x.data.iter().all(|&v| (0.0..=1.0).contains(&v)));
    }

    #[test]
    fn batches_wrap_and_copy() {
        let d = SyntheticMnist::generate(10, 5, 2, 1);
        let (x, y) = d.batch(0, 4);
        assert_eq!((x.rows, x.cols), (4, 5));
        assert_eq!((y.rows, y.cols), (4, 2));
        // Wrapping batch reads the same rows as the start.
        let (x2, _) = d.batch(5, 4); // offset 20 ≡ 0 mod 10
        assert_eq!(x.data, x2.data);
    }

    #[test]
    fn init_param_shapes() {
        let cfg = e2e_config();
        let p = init_params(&cfg, 3);
        assert_eq!(p.len(), 2 * cfg.n_layers());
        assert_eq!((p[0].rows, p[0].cols), (512, 784));
        assert_eq!((p[1].rows, p[1].cols), (1, 512));
    }

    #[test]
    fn plan_artifact_names() {
        use Algorithm::*;
        assert_eq!(
            plan_artifact("fcn_train", &[Nt, Tnn, Nt]),
            "fcn_train_nt-tnn-nt"
        );
    }

    #[test]
    fn selected_plan_has_layer_arity() {
        let sel = Selector::train_default(&crate::dataset::collect_paper_dataset());
        let cfg = e2e_config();
        let plan = select_plan(&sel, &GTX1080, &cfg, 128);
        assert_eq!(plan.len(), cfg.n_layers());
        assert!(plan
            .iter()
            .all(|a| matches!(a, Algorithm::Nt | Algorithm::Tnn)));
    }
}
