//! Simulated FCN training timing: CaffeNT (always the direct cuBLAS NT
//! call) vs CaffeMTNN (per-call MTNN selection) on the calibrated GPU
//! models — regenerates Figs 7–8 and Table X.

use super::gemm_seq::{training_calls, GemmCall, GemmKind};
use crate::gemm::{Algorithm, GemmShape};
use crate::gpusim::{GpuSpec, Simulator};
use crate::selector::Selector;

/// Forward/backward/total per-iteration times in milliseconds.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PhaseTimes {
    pub forward_ms: f64,
    pub backward_ms: f64,
}

impl PhaseTimes {
    pub fn total_ms(&self) -> f64 {
        self.forward_ms + self.backward_ms
    }
}

/// Which NT policy the simulated Caffe uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// Original Caffe: every NT op calls the direct NT kernel.
    AlwaysNt,
    /// Original Caffe with TNN unconditionally (ablation).
    AlwaysTnn,
    /// The revised Caffe: MTNN selects per call (with memory fallback).
    Mtnn,
}

/// Time one GEMM call on the simulator under a policy.
fn call_time(
    sim: &Simulator,
    sel: Option<&Selector>,
    gpu: &'static GpuSpec,
    call: &GemmCall,
    policy: Policy,
) -> f64 {
    let GemmShape { m, n, k } = call.shape;
    match call.kind {
        // TN and NN calls are not NT ops: both run as plain NN-cost GEMMs
        // (cuBLAS's TN kernel streams A rows exactly like NN).
        GemmKind::Nn | GemmKind::Tn => sim.model.t_nn(m, n, k),
        GemmKind::Nt => {
            let algo = match policy {
                Policy::AlwaysNt => Algorithm::Nt,
                Policy::AlwaysTnn => {
                    if sim.fits(m, n, k) {
                        Algorithm::Tnn
                    } else {
                        Algorithm::Nt
                    }
                }
                Policy::Mtnn => {
                    sel.expect("MTNN policy needs a selector")
                        .select(gpu, m, n, k)
                        .0
                }
            };
            match algo {
                Algorithm::Nt => sim.model.t_nt(m, n, k),
                Algorithm::Tnn => sim.model.t_tnn(m, n, k),
                Algorithm::Nn => unreachable!(),
            }
        }
    }
}

/// Simulate one training iteration of `dims` with mini-batch `mb`.
pub fn iteration_times(
    gpu: &'static GpuSpec,
    sel: Option<&Selector>,
    dims: &[u64],
    mb: u64,
    policy: Policy,
) -> PhaseTimes {
    let sim = Simulator::new(gpu);
    let mut t = PhaseTimes::default();
    for call in training_calls(dims, mb) {
        let secs = call_time(&sim, sel, gpu, &call, policy);
        if call.forward {
            t.forward_ms += secs * 1e3;
        } else {
            t.backward_ms += secs * 1e3;
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::collect_paper_dataset;
    use crate::fcn::config::{mnist_configs, synthetic_configs};
    use crate::gpusim::GTX1080;
    use std::sync::OnceLock;

    fn selector() -> &'static Selector {
        static SEL: OnceLock<Selector> = OnceLock::new();
        SEL.get_or_init(|| Selector::train_default(&collect_paper_dataset()))
    }

    #[test]
    fn mtnn_never_much_worse_than_nt() {
        // LUB-style bound: across configs, MTNN total should be within a
        // few percent of NT even when predictions err.
        for cfg in mnist_configs().iter().chain(synthetic_configs().iter()) {
            for &mb in &[256u64, 1024] {
                let nt = iteration_times(&GTX1080, None, &cfg.dims, mb, Policy::AlwaysNt);
                let mt =
                    iteration_times(&GTX1080, Some(selector()), &cfg.dims, mb, Policy::Mtnn);
                assert!(
                    mt.total_ms() < nt.total_ms() * 1.10,
                    "{} mb={mb}: MTNN {:.1}ms vs NT {:.1}ms",
                    cfg.name,
                    mt.total_ms(),
                    nt.total_ms()
                );
            }
        }
    }

    #[test]
    fn synthetic_large_batch_shows_speedup() {
        // The paper's headline: ~28% on the synthetic nets at large mb.
        let cfg = &synthetic_configs()[1];
        let nt = iteration_times(&GTX1080, None, &cfg.dims, 4096, Policy::AlwaysNt);
        let mt = iteration_times(&GTX1080, Some(selector()), &cfg.dims, 4096, Policy::Mtnn);
        let speedup = nt.total_ms() / mt.total_ms();
        assert!(
            speedup > 1.10,
            "expected a clear speedup on synth-3h@4096, got {speedup:.3}"
        );
    }

    #[test]
    fn backward_unaffected_by_policy() {
        // Table X: backward has no NT calls, so policies agree there.
        let cfg = &mnist_configs()[0];
        let nt = iteration_times(&GTX1080, None, &cfg.dims, 1024, Policy::AlwaysNt);
        let mt = iteration_times(&GTX1080, Some(selector()), &cfg.dims, 1024, Policy::Mtnn);
        assert!((nt.backward_ms - mt.backward_ms).abs() < 1e-9);
    }

    #[test]
    fn forward_speedup_is_where_the_gain_lives() {
        let cfg = &synthetic_configs()[0];
        let nt = iteration_times(&GTX1080, None, &cfg.dims, 2048, Policy::AlwaysNt);
        let mt = iteration_times(&GTX1080, Some(selector()), &cfg.dims, 2048, Policy::Mtnn);
        let fwd_speedup = nt.forward_ms / mt.forward_ms;
        let bwd_speedup = nt.backward_ms / mt.backward_ms;
        assert!(fwd_speedup > 1.2, "fwd speedup {fwd_speedup:.2}");
        assert!((bwd_speedup - 1.0).abs() < 1e-9);
    }

    #[test]
    fn always_tnn_policy_runs_and_obeys_memory() {
        let cfg = &synthetic_configs()[2];
        let t = iteration_times(&GTX1080, None, &cfg.dims, 4096, Policy::AlwaysTnn);
        assert!(t.total_ms() > 0.0);
    }
}
