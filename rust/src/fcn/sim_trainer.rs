//! Simulated FCN training timing: CaffeNT (always the direct cuBLAS NT
//! call) vs CaffeMTNN (per-call MTNN selection) on the calibrated GPU
//! models — regenerates Figs 7–8 and Table X.
//!
//! For steady-state runs, MTNN selection goes through the shape-keyed
//! [`crate::selector::cache::DecisionCache`]: an FCN iteration re-issues
//! the same `(gpu, m, n, k)` NT shapes every mini-batch, so after the
//! first step each selection is a lock-free table lookup rather than a
//! GBDT descent. Hold a [`CachedSelector`] across iterations
//! ([`epoch_times`] / [`iteration_times_cached`]) to amortize across a
//! whole training run; the one-shot [`iteration_times`] selects directly.

use super::gemm_seq::{training_calls, GemmCall, GemmKind};
use crate::gemm::{Algorithm, GemmShape};
use crate::gpusim::{GpuSpec, Simulator};
use crate::selector::cache::CachedSelector;
use crate::selector::Selector;

/// Forward/backward/total per-iteration times in milliseconds.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PhaseTimes {
    pub forward_ms: f64,
    pub backward_ms: f64,
}

impl PhaseTimes {
    pub fn total_ms(&self) -> f64 {
        self.forward_ms + self.backward_ms
    }
}

/// Which NT policy the simulated Caffe uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// Original Caffe: every NT op calls the direct NT kernel.
    AlwaysNt,
    /// Original Caffe with TNN unconditionally (ablation).
    AlwaysTnn,
    /// The revised Caffe: MTNN selects per call (with memory fallback).
    Mtnn,
}

/// Anything that can answer Algorithm 2 — the plain selector (one-shot
/// sweeps) or the cached wrapper (steady-state epochs). Keeps
/// [`iteration_times`] allocation-free while [`epoch_times`] reuses one
/// warm cache.
trait SelectAlgo {
    fn algo_for(&self, gpu: &GpuSpec, m: u64, n: u64, k: u64) -> Algorithm;
}

impl SelectAlgo for Selector {
    fn algo_for(&self, gpu: &GpuSpec, m: u64, n: u64, k: u64) -> Algorithm {
        self.select(gpu, m, n, k).0
    }
}

impl SelectAlgo for CachedSelector<'_> {
    fn algo_for(&self, gpu: &GpuSpec, m: u64, n: u64, k: u64) -> Algorithm {
        self.select(gpu, m, n, k).0
    }
}

/// Time one GEMM call on the simulator under a policy.
fn call_time(
    sim: &Simulator,
    sel: Option<&dyn SelectAlgo>,
    gpu: &'static GpuSpec,
    call: &GemmCall,
    policy: Policy,
) -> f64 {
    let GemmShape { m, n, k } = call.shape;
    match call.kind {
        // TN and NN calls are not NT ops: both run as plain NN-cost GEMMs
        // (cuBLAS's TN kernel streams A rows exactly like NN).
        GemmKind::Nn | GemmKind::Tn => sim.model.t_nn(m, n, k),
        GemmKind::Nt => {
            let algo = match policy {
                Policy::AlwaysNt => Algorithm::Nt,
                Policy::AlwaysTnn => {
                    if sim.fits(m, n, k) {
                        Algorithm::Tnn
                    } else {
                        Algorithm::Nt
                    }
                }
                Policy::Mtnn => sel
                    .expect("MTNN policy needs a selector")
                    .algo_for(gpu, m, n, k),
            };
            match algo {
                Algorithm::Nt => sim.model.t_nt(m, n, k),
                Algorithm::Tnn => sim.model.t_tnn(m, n, k),
                Algorithm::Nn => unreachable!(),
            }
        }
    }
}

/// Simulate one training iteration of `dims` with mini-batch `mb` using a
/// caller-held cached selector — the serving-path configuration, where the
/// shape-keyed cache persists across iterations.
pub fn iteration_times_cached(
    gpu: &'static GpuSpec,
    sel: Option<&CachedSelector>,
    dims: &[u64],
    mb: u64,
    policy: Policy,
) -> PhaseTimes {
    iteration_times_impl(gpu, sel.map(|s| s as &dyn SelectAlgo), dims, mb, policy)
}

/// Simulate one training iteration of `dims` with mini-batch `mb`,
/// selecting directly through the plain selector (no cache allocation —
/// one-shot sweeps dominate this entry point).
pub fn iteration_times(
    gpu: &'static GpuSpec,
    sel: Option<&Selector>,
    dims: &[u64],
    mb: u64,
    policy: Policy,
) -> PhaseTimes {
    iteration_times_impl(gpu, sel.map(|s| s as &dyn SelectAlgo), dims, mb, policy)
}

fn iteration_times_impl(
    gpu: &'static GpuSpec,
    sel: Option<&dyn SelectAlgo>,
    dims: &[u64],
    mb: u64,
    policy: Policy,
) -> PhaseTimes {
    let sim = Simulator::new(gpu);
    let mut t = PhaseTimes::default();
    for call in training_calls(dims, mb) {
        let secs = call_time(&sim, sel, gpu, &call, policy);
        if call.forward {
            t.forward_ms += secs * 1e3;
        } else {
            t.backward_ms += secs * 1e3;
        }
    }
    t
}

/// Simulate `iters` consecutive training iterations with one shared
/// selection cache: every iteration after the first resolves all its NT
/// selections by table lookup. Returns per-iteration times (identical
/// across iterations — the simulator is deterministic — which the tests
/// assert as the cache-transparency invariant).
pub fn epoch_times(
    gpu: &'static GpuSpec,
    sel: Option<&Selector>,
    dims: &[u64],
    mb: u64,
    policy: Policy,
    iters: usize,
) -> Vec<PhaseTimes> {
    let cached = sel.map(CachedSelector::new);
    (0..iters)
        .map(|_| iteration_times_cached(gpu, cached.as_ref(), dims, mb, policy))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::collect_paper_dataset;
    use crate::fcn::config::{mnist_configs, synthetic_configs};
    use crate::gpusim::GTX1080;
    use std::sync::OnceLock;

    fn selector() -> &'static Selector {
        static SEL: OnceLock<Selector> = OnceLock::new();
        SEL.get_or_init(|| Selector::train_default(&collect_paper_dataset()))
    }

    #[test]
    fn mtnn_never_much_worse_than_nt() {
        // LUB-style bound: across configs, MTNN total should be within a
        // few percent of NT even when predictions err.
        for cfg in mnist_configs().iter().chain(synthetic_configs().iter()) {
            for &mb in &[256u64, 1024] {
                let nt = iteration_times(&GTX1080, None, &cfg.dims, mb, Policy::AlwaysNt);
                let mt =
                    iteration_times(&GTX1080, Some(selector()), &cfg.dims, mb, Policy::Mtnn);
                assert!(
                    mt.total_ms() < nt.total_ms() * 1.10,
                    "{} mb={mb}: MTNN {:.1}ms vs NT {:.1}ms",
                    cfg.name,
                    mt.total_ms(),
                    nt.total_ms()
                );
            }
        }
    }

    #[test]
    fn synthetic_large_batch_shows_speedup() {
        // The paper's headline: ~28% on the synthetic nets at large mb.
        let cfg = &synthetic_configs()[1];
        let nt = iteration_times(&GTX1080, None, &cfg.dims, 4096, Policy::AlwaysNt);
        let mt = iteration_times(&GTX1080, Some(selector()), &cfg.dims, 4096, Policy::Mtnn);
        let speedup = nt.total_ms() / mt.total_ms();
        assert!(
            speedup > 1.10,
            "expected a clear speedup on synth-3h@4096, got {speedup:.3}"
        );
    }

    #[test]
    fn backward_unaffected_by_policy() {
        // Table X: backward has no NT calls, so policies agree there.
        let cfg = &mnist_configs()[0];
        let nt = iteration_times(&GTX1080, None, &cfg.dims, 1024, Policy::AlwaysNt);
        let mt = iteration_times(&GTX1080, Some(selector()), &cfg.dims, 1024, Policy::Mtnn);
        assert!((nt.backward_ms - mt.backward_ms).abs() < 1e-9);
    }

    #[test]
    fn forward_speedup_is_where_the_gain_lives() {
        let cfg = &synthetic_configs()[0];
        let nt = iteration_times(&GTX1080, None, &cfg.dims, 2048, Policy::AlwaysNt);
        let mt = iteration_times(&GTX1080, Some(selector()), &cfg.dims, 2048, Policy::Mtnn);
        let fwd_speedup = nt.forward_ms / mt.forward_ms;
        let bwd_speedup = nt.backward_ms / mt.backward_ms;
        assert!(fwd_speedup > 1.2, "fwd speedup {fwd_speedup:.2}");
        assert!((bwd_speedup - 1.0).abs() < 1e-9);
    }

    #[test]
    fn always_tnn_policy_runs_and_obeys_memory() {
        let cfg = &synthetic_configs()[2];
        let t = iteration_times(&GTX1080, None, &cfg.dims, 4096, Policy::AlwaysTnn);
        assert!(t.total_ms() > 0.0);
    }

    #[test]
    fn epoch_cache_is_transparent_and_hit_heavy() {
        // A shared cache across iterations must not change simulated times,
        // and every post-warmup selection must be a cache hit.
        let cfg = &mnist_configs()[0];
        let single = iteration_times(&GTX1080, Some(selector()), &cfg.dims, 512, Policy::Mtnn);
        let epoch = epoch_times(&GTX1080, Some(selector()), &cfg.dims, 512, Policy::Mtnn, 5);
        assert_eq!(epoch.len(), 5);
        for (i, t) in epoch.iter().enumerate() {
            assert_eq!(t, &single, "iteration {i} diverged under caching");
        }
        // Direct hit accounting on the cached wrapper.
        let cached = crate::selector::cache::CachedSelector::new(selector());
        iteration_times_cached(&GTX1080, Some(&cached), &cfg.dims, 512, Policy::Mtnn);
        let misses_after_first = cached.misses();
        iteration_times_cached(&GTX1080, Some(&cached), &cfg.dims, 512, Policy::Mtnn);
        assert_eq!(cached.misses(), misses_after_first, "iteration 2 must be all hits");
        assert!(cached.hits() > 0);
    }
}
