//! InnerProduct-layer → GEMM-call decomposition, exactly as Caffe performs
//! it (and as the paper's Table X breakdown assumes):
//!
//! * forward:  `Y[mb,out] = X[mb,in] · W[out,in]ᵀ`   — an **NT** call
//!   (the only place MTNN applies);
//! * backward-data:    `dX[mb,in] = dY[mb,out] · W[out,in]`  — **NN**;
//! * backward-weights: `dW[out,in] = dY[mb,out]ᵀ · X[mb,in]` — **TN**
//!   (transpose on A; cuBLAS handles this layout efficiently, which is
//!   why the paper's backward phase shows no speedup).

use crate::gemm::GemmShape;

/// Which SGEMM variant a call uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GemmKind {
    /// NT — selectable between direct NT and TNN by the selector.
    Nt,
    /// Plain NN.
    Nn,
    /// TN (Aᵀ·B) — not an NT op; never rerouted.
    Tn,
}

/// One GEMM call in a training iteration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GemmCall {
    pub kind: GemmKind,
    pub shape: GemmShape,
    /// Layer index this call belongs to.
    pub layer: usize,
    /// True if the call is part of the forward phase.
    pub forward: bool,
}

/// All GEMM calls of one forward pass over `dims` with mini-batch `mb`.
pub fn forward_calls(dims: &[u64], mb: u64) -> Vec<GemmCall> {
    dims.windows(2)
        .enumerate()
        .map(|(layer, w)| GemmCall {
            kind: GemmKind::Nt,
            // C[mb, out] = X[mb, in] × W[out, in]ᵀ  →  m=mb, n=out, k=in.
            shape: GemmShape::new(mb, w[1], w[0]),
            layer,
            forward: true,
        })
        .collect()
}

/// All GEMM calls of one backward pass (data + weight gradients).
pub fn backward_calls(dims: &[u64], mb: u64) -> Vec<GemmCall> {
    let mut out = Vec::new();
    for (layer, w) in dims.windows(2).enumerate() {
        let (fan_in, fan_out) = (w[0], w[1]);
        // dW[out,in] = dYᵀ[out,mb] × X[mb,in]  →  m=out, n=in, k=mb (TN).
        out.push(GemmCall {
            kind: GemmKind::Tn,
            shape: GemmShape::new(fan_out, fan_in, mb),
            layer,
            forward: false,
        });
        // dX[mb,in] = dY[mb,out] × W[out,in]  →  m=mb, n=in, k=out (NN).
        // Caffe skips dX for the first layer (no upstream consumer).
        if layer > 0 {
            out.push(GemmCall {
                kind: GemmKind::Nn,
                shape: GemmShape::new(mb, fan_in, fan_out),
                layer,
                forward: false,
            });
        }
    }
    out
}

/// Forward + backward calls of one training iteration.
pub fn training_calls(dims: &[u64], mb: u64) -> Vec<GemmCall> {
    let mut calls = forward_calls(dims, mb);
    calls.extend(backward_calls(dims, mb));
    calls
}

#[cfg(test)]
mod tests {
    use super::*;

    const DIMS: [u64; 4] = [784, 2048, 1024, 10];

    #[test]
    fn forward_shapes_are_nt() {
        let calls = forward_calls(&DIMS, 256);
        assert_eq!(calls.len(), 3);
        assert!(calls.iter().all(|c| c.kind == GemmKind::Nt && c.forward));
        // Layer 0: [256,784] × [2048,784]ᵀ.
        assert_eq!(calls[0].shape, GemmShape::new(256, 2048, 784));
        assert_eq!(calls[2].shape, GemmShape::new(256, 10, 1024));
    }

    #[test]
    fn backward_has_no_nt_calls() {
        // The paper's Table X: backward is NT-free, hence no MTNN effect.
        let calls = backward_calls(&DIMS, 256);
        assert!(calls.iter().all(|c| c.kind != GemmKind::Nt));
        // 3 dW (TN) + 2 dX (NN, first layer skipped).
        assert_eq!(
            calls.iter().filter(|c| c.kind == GemmKind::Tn).count(),
            3
        );
        assert_eq!(
            calls.iter().filter(|c| c.kind == GemmKind::Nn).count(),
            2
        );
    }

    #[test]
    fn weight_grad_shape() {
        let calls = backward_calls(&DIMS, 64);
        // dW for layer 0: [2048, 784] with k = mb.
        let dw0 = calls
            .iter()
            .find(|c| c.kind == GemmKind::Tn && c.layer == 0)
            .unwrap();
        assert_eq!(dw0.shape, GemmShape::new(2048, 784, 64));
    }

    #[test]
    fn training_is_concatenation() {
        let t = training_calls(&DIMS, 32);
        assert_eq!(
            t.len(),
            forward_calls(&DIMS, 32).len() + backward_calls(&DIMS, 32).len()
        );
    }
}
