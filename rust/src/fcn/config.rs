//! Table IX: the fully connected network configurations of the paper's
//! Caffe evaluation.

/// An FCN configuration: layer dimensionalities including input and output.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FcnConfig {
    /// e.g. "mnist-2h" (MNIST data, 2 hidden layers).
    pub name: String,
    pub dims: Vec<u64>,
}

impl FcnConfig {
    pub fn new(name: &str, dims: Vec<u64>) -> FcnConfig {
        assert!(dims.len() >= 2, "need at least input and output dims");
        FcnConfig {
            name: name.to_string(),
            dims,
        }
    }

    pub fn n_layers(&self) -> usize {
        self.dims.len() - 1
    }

    /// (in_dim, out_dim) per layer.
    pub fn layers(&self) -> Vec<(u64, u64)> {
        self.dims.windows(2).map(|w| (w[0], w[1])).collect()
    }

    pub fn n_params(&self) -> u64 {
        self.layers().iter().map(|(i, o)| i * o + o).sum()
    }
}

/// Table IX, MNIST column: input 784, output 10.
pub fn mnist_configs() -> Vec<FcnConfig> {
    vec![
        FcnConfig::new("mnist-2h", vec![784, 2048, 1024, 10]),
        FcnConfig::new("mnist-3h", vec![784, 2048, 2048, 1024, 10]),
        FcnConfig::new("mnist-4h", vec![784, 2048, 2048, 2048, 1024, 10]),
    ]
}

/// Table IX, synthetic column: input = output = 26752, hidden 4096.
pub fn synthetic_configs() -> Vec<FcnConfig> {
    vec![
        FcnConfig::new("synth-2h", vec![26752, 4096, 4096, 26752]),
        FcnConfig::new("synth-3h", vec![26752, 4096, 4096, 4096, 26752]),
        FcnConfig::new("synth-4h", vec![26752, 4096, 4096, 4096, 4096, 26752]),
    ]
}

/// Mini-batch sizes swept in Figs 7–8.
pub const MINI_BATCHES: [u64; 6] = [128, 256, 512, 1024, 2048, 4096];

/// The small end-to-end config of examples/train_fcn.rs — must match
/// `python/compile/aot.py::FCN_DIMS`.
pub fn e2e_config() -> FcnConfig {
    FcnConfig::new("e2e-mnist-small", vec![784, 512, 256, 10])
}

pub const E2E_BATCH: u64 = 128;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table9_shapes() {
        let m = mnist_configs();
        assert_eq!(m[0].dims, vec![784, 2048, 1024, 10]);
        assert_eq!(m[2].n_layers(), 5);
        let s = synthetic_configs();
        assert_eq!(s[1].dims, vec![26752, 4096, 4096, 4096, 26752]);
    }

    #[test]
    fn layer_decomposition() {
        let c = FcnConfig::new("t", vec![8, 4, 2]);
        assert_eq!(c.layers(), vec![(8, 4), (4, 2)]);
        assert_eq!(c.n_params(), 8 * 4 + 4 + 4 * 2 + 2);
    }

    #[test]
    fn synthetic_is_large() {
        // The synthetic nets are the ones where the paper sees 28% gains —
        // parameter counts in the hundreds of millions.
        let s = synthetic_configs();
        assert!(s[0].n_params() > 200_000_000);
    }

    #[test]
    #[should_panic]
    fn degenerate_config_rejected() {
        FcnConfig::new("bad", vec![10]);
    }
}
