//! The FCN engine — the reproduction's stand-in for Caffe (§VI.C):
//!
//! * [`config`] — the Table IX network configurations;
//! * [`gemm_seq`] — the exact InnerProduct-layer → GEMM-call decomposition
//!   Caffe performs in forward/backward;
//! * [`sim_trainer`] — per-minibatch timing of CaffeNT vs CaffeMTNN on the
//!   simulated GPUs (Figs 7–8, Table X);
//! * [`real_trainer`] — actual training of the small e2e FCN through the
//!   AOT train-step artifacts on PJRT (examples/train_fcn.rs).

pub mod config;
pub mod gemm_seq;
pub mod real_trainer;
pub mod sim_trainer;
