//! The fleet scheduler: joint (device, algorithm) placement across N
//! heterogeneous simulated GPUs.
//!
//! The single-engine [`Router`] assumes one backend, one model, one
//! breaker registry. A [`Fleet`] lifts that whole stack per device: each
//! [`FleetDevice`] owns an [`Engine`] whose workers run a
//! [`SimExecutor`] built from that device's *current* [`GpuSpec`]
//! (rebuilt through [`Engine::restartable`]'s factory on a mid-run spec
//! swap), plus its own `Router` — so per-device metrics/conservation,
//! per-device online specialization (a challenger promoted on device A
//! never touches device B's model), per-device decision-cache epochs,
//! and per-(device, artifact) breakers all fall out of ownership rather
//! than new locking.
//!
//! ```text
//!   clients ──► Fleet::serve(shape, a, b)
//!                 │ place(): score every (device, algo) candidate
//!                 │   est = pending_us + wait_ewma_us + modeled_exec_us
//!                 │   skip: workspace unfit, breaker Open (healable)
//!                 ▼ argmin
//!          ┌─ device 0 ─┐  ┌─ device 1 ─┐  ┌─ device N ─┐
//!          │ Router     │  │ Router     │  │ Router     │  each with its
//!          │ Engine     │  │ Engine     │  │ Engine     │  own selector,
//!          │ SimExec    │  │ SimExec    │  │ SimExec    │  hub, breakers,
//!          │ (spec i)   │  │ (spec j)   │  │ (spec k)   │  metrics
//!          └────────────┘  └────────────┘  └────────────┘
//! ```
//!
//! **Placement** ([`PlacementPolicy::Joint`]) estimates completion time
//! per candidate from three terms the scheduler can know without asking
//! the device: the modeled execution cost of *this* request under the
//! candidate algorithm (the same calibrated [`TimingModel`] the
//! `SimExecutor` reports, so the estimate is exact for sim fleets), the
//! device's in-flight modeled backlog (`pending_us`, added at dispatch
//! and removed at resolve), and an EWMA of observed queue-wait (wall
//! latency minus the modeled estimate). Round-robin and random policies
//! are kept as baselines; both leave the algorithm choice to the
//! device's own live selector.
//!
//! **Breaker drain + heal**: a candidate whose per-device breaker is
//! Open for the candidate artifact is skipped, so a sick device's
//! traffic drains to siblings. Skipping forever would also starve the
//! breaker of the `admit()` calls that drive its Open→HalfOpen cooldown
//! transition, so every `breaker_drain_recheck`-th placement ignores
//! Open-skips: the argmin then routes one request at the sick candidate
//! and the router's breaker admission either coerces it (pre-cooldown)
//! or serves the half-open probe that heals the breaker. When *every*
//! candidate is Open-skipped the skip set is ignored entirely —
//! placement never deadlocks.
//!
//! **Conservation**: each device's router keeps the invariant
//! `completed + failed + shed + timed_out == requests` per device;
//! [`Fleet::conservation`] additionally rolls all device snapshots into
//! a fleet-wide [`ConservationTotals`] check.

use super::engine::{Engine, EngineConfig};
use super::lifecycle::BreakerState;
use super::metrics::{ConservationTotals, MetricsSnapshot};
use super::router::{GemmRequest, GemmResponse, Router, RouterConfig};
use crate::coordinator::ExecBackend;
use crate::gemm::cpu::Matrix;
use crate::gemm::xla::XlaBackend;
use crate::gemm::{Algorithm, GemmShape};
use crate::gpusim::{GpuSpec, SimExecutor, Simulator, TimingModel};
use crate::selector::{Selector, TrainedModel};
use crate::util::rng::SplitMix64;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Wraps each freshly built per-worker backend — `(inner, device_idx,
/// worker_idx)` — before the engine takes it. The chaos tests use this
/// to interpose a `ChaosBackend` on exactly one device.
pub type BackendWrap =
    Arc<dyn Fn(Box<dyn ExecBackend>, usize, usize) -> Box<dyn ExecBackend> + Send + Sync>;

/// How the fleet maps a request onto a device.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PlacementPolicy {
    /// Score every (device, algorithm) candidate by estimated completion
    /// time and take the argmin — device and algorithm chosen jointly.
    #[default]
    Joint,
    /// Deal devices in rotation; the device's own selector picks the
    /// algorithm per request (the strongest non-joint baseline).
    RoundRobin,
    /// Seeded uniform device choice; selector picks the algorithm.
    Random,
}

/// Fleet configuration. `router` is cloned into every device, so the
/// online loop, breakers, deadlines, and admission policy are uniform
/// across the fleet while their *state* stays per-device.
#[derive(Clone)]
pub struct FleetConfig {
    pub policy: PlacementPolicy,
    /// Engine workers per device.
    pub workers_per_device: usize,
    /// Per-worker queue depth per device.
    pub queue_depth: usize,
    /// Per-device router configuration (online loop, breakers, deadline,
    /// admission, obs) — instantiated independently per device.
    pub router: RouterConfig,
    /// Every Nth placement re-admits breaker-Open candidates so a
    /// tripped breaker still sees the admit() traffic it needs to reach
    /// half-open and heal (0 disables recovery placements).
    pub breaker_drain_recheck: u64,
    /// Seed for the [`PlacementPolicy::Random`] baseline.
    pub seed: u64,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            policy: PlacementPolicy::default(),
            workers_per_device: 1,
            queue_depth: 64,
            router: RouterConfig::default(),
            breaker_drain_recheck: 16,
            seed: 0xF1EE7,
        }
    }
}

/// One placement decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Placement {
    /// Index into the fleet's device list.
    pub device: usize,
    /// The jointly chosen algorithm (`None` for the baseline policies,
    /// which leave the choice to the device's selector).
    pub algo: Option<Algorithm>,
    /// Estimated completion µs at decision time (backlog + wait + exec).
    pub est_us: u64,
    /// The modeled-exec component of `est_us` alone — what the dispatch
    /// charges against the device's `pending_us` (charging the full
    /// score would double-count the backlog already inside it).
    pub exec_us: u64,
}

/// One device of the fleet: a spec cell (read by the engine's worker
/// factory at every (re)build, written by [`Fleet::swap_spec`]), the
/// engine, the device's own router stack, the placement cost state, and
/// placement counters.
pub struct FleetDevice {
    spec: Arc<Mutex<&'static GpuSpec>>,
    engine: Mutex<Option<Engine>>,
    router: Router,
    /// Calibrated timing model of the *current* spec — the modeled-exec
    /// term of the placement score. Rebuilt on spec swap.
    cost: Mutex<TimingModel>,
    /// Modeled µs of work dispatched to this device and not yet resolved.
    pending_us: AtomicU64,
    /// EWMA (α = 1/8) of observed wait: wall latency beyond the modeled
    /// estimate, clamped at zero and sampled as zero for uncontended
    /// dispatches (no modeled work was queued ahead, so any overshoot is
    /// host oracle/channel overhead, not queueing — counting it would
    /// let wall-clock noise swamp the µs-scale modeled scores). Captures
    /// genuine queueing the timing model cannot see, and decays back
    /// toward zero as uncontended completions stream through.
    wait_ewma_us: AtomicU64,
    placed: AtomicU64,
    placed_nt: AtomicU64,
    placed_tnn: AtomicU64,
}

/// A point-in-time per-device report for tables and assertions.
#[derive(Debug, Clone)]
pub struct DeviceReport {
    pub device: usize,
    pub name: &'static str,
    pub gpu_id: u64,
    pub placed: u64,
    pub placed_nt: u64,
    pub placed_tnn: u64,
    pub pending_us: u64,
    pub wait_ewma_us: u64,
    pub snapshot: MetricsSnapshot,
}

/// The fleet scheduler. Share via `&Fleet` across client threads;
/// serving is thread-safe (placement state is atomic, the per-device
/// cost model sits behind a short lock).
pub struct Fleet {
    devices: Vec<FleetDevice>,
    config: FleetConfig,
    rr_tick: AtomicU64,
    heal_tick: AtomicU64,
    rand: Mutex<SplitMix64>,
    /// Σ (backlog at dispatch + modeled exec of the executed algorithm)
    /// over completed requests — the total modeled completion time the
    /// acceptance benchmarks compare across policies.
    modeled_completion_us: AtomicU64,
}

impl Fleet {
    /// Build a fleet over `specs` with the paper's production selector
    /// (GBDT trained once on the full dataset, cloned per device — each
    /// device still owns its copy, so online promotion stays local).
    pub fn new(specs: &[&'static GpuSpec], config: FleetConfig) -> anyhow::Result<Fleet> {
        let base = Selector::train_default(&crate::dataset::collect_paper_dataset());
        let g = base
            .model
            .as_gbdt()
            .cloned()
            .expect("train_default yields a GBDT");
        Fleet::with_selectors(specs, config, |_| Selector::new(TrainedModel::Gbdt(g.clone())))
    }

    /// Build a fleet with an explicit selector per device.
    pub fn with_selectors(
        specs: &[&'static GpuSpec],
        config: FleetConfig,
        selector_for: impl FnMut(usize) -> Selector,
    ) -> anyhow::Result<Fleet> {
        Fleet::with_backend_wrap(specs, config, selector_for, None)
    }

    /// Full-control constructor: explicit selectors plus an optional
    /// backend wrap applied to every worker backend (chaos injection).
    pub fn with_backend_wrap(
        specs: &[&'static GpuSpec],
        config: FleetConfig,
        mut selector_for: impl FnMut(usize) -> Selector,
        wrap: Option<BackendWrap>,
    ) -> anyhow::Result<Fleet> {
        anyhow::ensure!(!specs.is_empty(), "fleet needs at least one device");
        let ecfg = EngineConfig {
            workers: config.workers_per_device.max(1),
            queue_depth: config.queue_depth,
            ..EngineConfig::default()
        };
        let mut devices = Vec::with_capacity(specs.len());
        for (idx, &spec) in specs.iter().enumerate() {
            let cell = Arc::new(Mutex::new(spec));
            let factory_cell = Arc::clone(&cell);
            let factory_wrap = wrap.clone();
            let engine = Engine::restartable(ecfg, move |w| {
                let spec = *factory_cell.lock().unwrap();
                let base: Box<dyn ExecBackend> = Box::new(SimExecutor::new(spec));
                Ok(match &factory_wrap {
                    Some(f) => f(base, idx, w),
                    None => base,
                })
            })?;
            let router = Router::new(selector_for(idx), engine.handle(), config.router.clone());
            devices.push(FleetDevice {
                spec: cell,
                engine: Mutex::new(Some(engine)),
                router,
                cost: Mutex::new(TimingModel::new(spec)),
                pending_us: AtomicU64::new(0),
                wait_ewma_us: AtomicU64::new(0),
                placed: AtomicU64::new(0),
                placed_nt: AtomicU64::new(0),
                placed_tnn: AtomicU64::new(0),
            });
        }
        let seed = config.seed;
        Ok(Fleet {
            devices,
            config,
            rr_tick: AtomicU64::new(0),
            heal_tick: AtomicU64::new(0),
            rand: Mutex::new(SplitMix64::new(seed)),
            modeled_completion_us: AtomicU64::new(0),
        })
    }

    pub fn len(&self) -> usize {
        self.devices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }

    /// The device's router — per-device metrics, online hub, breakers.
    pub fn router(&self, device: usize) -> &Router {
        &self.devices[device].router
    }

    /// The device's *current* spec (swaps change it mid-run).
    pub fn spec(&self, device: usize) -> &'static GpuSpec {
        *self.devices[device].spec.lock().unwrap()
    }

    /// Total modeled completion µs accrued by completed requests.
    pub fn modeled_completion_us(&self) -> u64 {
        self.modeled_completion_us.load(Ordering::Relaxed)
    }

    /// Modeled execution µs of `algo` on device `device` for `shape`,
    /// under the device's current calibrated model.
    fn modeled_exec_us(&self, device: usize, shape: GemmShape, algo: Algorithm) -> u64 {
        let cost = self.devices[device].cost.lock().unwrap();
        let GemmShape { m, n, k } = shape;
        let secs = match algo {
            Algorithm::Tnn => cost.t_tnn(m, n, k),
            _ => cost.t_nt(m, n, k),
        };
        (secs * 1e6) as u64
    }

    /// Whether `algo`'s workspace fits the device's current memory.
    fn fits(&self, device: usize, shape: GemmShape, algo: Algorithm) -> bool {
        let GemmShape { m, n, k } = shape;
        let bytes = match algo {
            Algorithm::Tnn => Simulator::tnn_workspace_bytes(m, n, k),
            _ => Simulator::nt_workspace_bytes(m, n, k),
        };
        bytes <= self.spec(device).global_mem_bytes()
    }

    /// Is the device's breaker Open for the candidate artifact? A pure
    /// read — admission (and the Open→HalfOpen transition) stays with
    /// the router on the serve path.
    fn breaker_open(&self, device: usize, shape: GemmShape, algo: Algorithm) -> bool {
        let Some(reg) = self.devices[device].router.breakers() else {
            return false;
        };
        reg.state(&XlaBackend::artifact_name(shape, algo)) == BreakerState::Open
    }

    /// The device's current completion-time floor: modeled backlog plus
    /// observed queue-wait EWMA.
    fn backlog_us(&self, device: usize) -> u64 {
        let d = &self.devices[device];
        d.pending_us.load(Ordering::Relaxed) + d.wait_ewma_us.load(Ordering::Relaxed)
    }

    /// Decide where (and for Joint, how) to run `shape`.
    pub fn place(&self, shape: GemmShape) -> Placement {
        match self.config.policy {
            PlacementPolicy::Joint => self.place_joint(shape),
            PlacementPolicy::RoundRobin => {
                let device =
                    (self.rr_tick.fetch_add(1, Ordering::Relaxed) as usize) % self.devices.len();
                let exec_us = self.baseline_exec_us(device, shape);
                Placement {
                    device,
                    algo: None,
                    est_us: self.backlog_us(device) + exec_us,
                    exec_us,
                }
            }
            PlacementPolicy::Random => {
                let device = {
                    let mut rng = self.rand.lock().unwrap();
                    rng.next_u64() as usize % self.devices.len()
                };
                let exec_us = self.baseline_exec_us(device, shape);
                Placement {
                    device,
                    algo: None,
                    est_us: self.backlog_us(device) + exec_us,
                    exec_us,
                }
            }
        }
    }

    /// The exec-cost estimate when the algorithm is left to the device's
    /// selector: the cheaper fitting algorithm (what a well-trained
    /// selector converges to).
    fn baseline_exec_us(&self, device: usize, shape: GemmShape) -> u64 {
        let nt = self.modeled_exec_us(device, shape, Algorithm::Nt);
        if self.fits(device, shape, Algorithm::Tnn) {
            nt.min(self.modeled_exec_us(device, shape, Algorithm::Tnn))
        } else {
            nt
        }
    }

    fn place_joint(&self, shape: GemmShape) -> Placement {
        let recheck = self.config.breaker_drain_recheck;
        let heal = recheck > 0
            && (self.heal_tick.fetch_add(1, Ordering::Relaxed) + 1) % recheck == 0;
        // Two passes: the first respects breaker-Open skips (sick
        // candidates drain to siblings); if that empties the candidate
        // set — or this is a recovery placement — Open candidates are
        // back in, so the breaker keeps seeing admissions and can heal.
        // Memory-unfit candidates are never admitted by either pass.
        for respect_open in [!heal, false] {
            let mut best: Option<Placement> = None;
            for device in 0..self.devices.len() {
                for algo in [Algorithm::Nt, Algorithm::Tnn] {
                    if !self.fits(device, shape, algo) {
                        continue;
                    }
                    if respect_open && self.breaker_open(device, shape, algo) {
                        continue;
                    }
                    let exec_us = self.modeled_exec_us(device, shape, algo);
                    let est_us = self.backlog_us(device) + exec_us;
                    if best.map_or(true, |b| est_us < b.est_us) {
                        best = Some(Placement {
                            device,
                            algo: Some(algo),
                            est_us,
                            exec_us,
                        });
                    }
                }
            }
            if let Some(p) = best {
                return p;
            }
        }
        // Nothing fits anywhere: fall through to device 0 / NT and let
        // the router surface the memory error.
        Placement {
            device: 0,
            algo: Some(Algorithm::Nt),
            est_us: self.backlog_us(0),
            exec_us: 0,
        }
    }

    /// Serve one request through the fleet: place, dispatch to the
    /// placed device's router (the placement algorithm riding along as
    /// an execution override that never blinds the device's online
    /// loop — see [`Router::serve_with`]), and settle the cost state.
    pub fn serve(&self, shape: GemmShape, a: Matrix, b: Matrix) -> anyhow::Result<GemmResponse> {
        let p = self.place(shape);
        let dev = &self.devices[p.device];
        let gpu = *dev.spec.lock().unwrap();
        // Charge only the modeled exec of *this* request — `est_us`
        // already contains the backlog, and re-adding it would compound
        // queued work quadratically under concurrency.
        let backlog = dev.pending_us.fetch_add(p.exec_us, Ordering::Relaxed);
        dev.placed.fetch_add(1, Ordering::Relaxed);
        let t0 = Instant::now();
        let res = dev.router.serve_with(GemmRequest { gpu, shape, a, b }, p.algo);
        dev.pending_us.fetch_sub(p.exec_us, Ordering::Relaxed);
        if let Ok(resp) = &res {
            match resp.algorithm {
                Algorithm::Nt => dev.placed_nt.fetch_add(1, Ordering::Relaxed),
                Algorithm::Tnn => dev.placed_tnn.fetch_add(1, Ordering::Relaxed),
                Algorithm::Nn => 0,
            };
            // Modeled completion: what the fleet "cost" in simulated
            // time — queue ahead at dispatch plus the modeled exec of
            // the algorithm that actually ran.
            let exec = self.modeled_exec_us(p.device, shape, resp.algorithm);
            self.modeled_completion_us
                .fetch_add(backlog + exec, Ordering::Relaxed);
            // Observed wait: wall time beyond the modeled estimate, but
            // only when modeled work was actually queued ahead — an
            // uncontended dispatch's overshoot is host oracle/channel
            // overhead, not queueing, and counting it would let wall
            // noise swamp the µs-scale modeled scores. Uncontended
            // completions instead sample zero, decaying the EWMA.
            let wait = if backlog > 0 {
                (t0.elapsed().as_micros() as u64).saturating_sub(exec)
            } else {
                0
            };
            let old = dev.wait_ewma_us.load(Ordering::Relaxed);
            dev.wait_ewma_us
                .store((old * 7 + wait) / 8, Ordering::Relaxed);
        }
        res
    }

    /// Swap a device's spec mid-run: the spec cell and cost model flip
    /// first, then every engine worker is killed and restarted so the
    /// restartable factory rebuilds its `SimExecutor` against the new
    /// spec. Requests placed after this see the new device; the decision
    /// cache needs no flush because it is keyed by gpu id. Only this
    /// device's online loop will observe the drift and retrain.
    pub fn swap_spec(&self, device: usize, to: &'static GpuSpec) -> anyhow::Result<()> {
        let dev = &self.devices[device];
        *dev.spec.lock().unwrap() = to;
        *dev.cost.lock().unwrap() = TimingModel::new(to);
        let mut guard = dev.engine.lock().unwrap();
        let engine = guard
            .as_mut()
            .ok_or_else(|| anyhow::anyhow!("fleet device {device} already shut down"))?;
        for w in 0..self.config.workers_per_device.max(1) {
            engine.kill_worker(w)?;
            engine.restart_worker(w)?;
        }
        Ok(())
    }

    /// One device's report.
    pub fn device_report(&self, device: usize) -> DeviceReport {
        let d = &self.devices[device];
        let spec = *d.spec.lock().unwrap();
        DeviceReport {
            device,
            name: spec.name,
            gpu_id: spec.id,
            placed: d.placed.load(Ordering::Relaxed),
            placed_nt: d.placed_nt.load(Ordering::Relaxed),
            placed_tnn: d.placed_tnn.load(Ordering::Relaxed),
            pending_us: d.pending_us.load(Ordering::Relaxed),
            wait_ewma_us: d.wait_ewma_us.load(Ordering::Relaxed),
            snapshot: d.router.metrics.snapshot(),
        }
    }

    /// All device reports, in device order.
    pub fn reports(&self) -> Vec<DeviceReport> {
        (0..self.devices.len())
            .map(|i| self.device_report(i))
            .collect()
    }

    /// Per-device AND fleet-wide conservation at quiescence.
    pub fn conservation(&self) -> Result<(), String> {
        let mut totals = ConservationTotals::default();
        for (i, r) in self.reports().iter().enumerate() {
            r.snapshot
                .verify_conservation()
                .map_err(|e| format!("device {i} ({}): {e}", r.name))?;
            totals.absorb(&r.snapshot);
        }
        totals.verify_conservation()
    }

    /// Human-readable per-device placement/latency table — one
    /// `fleet device …` line per device (the CI smoke greps these) plus
    /// a fleet summary line.
    pub fn render(&self) -> String {
        let mut out = String::new();
        let mut totals = ConservationTotals::default();
        for r in self.reports() {
            totals.absorb(&r.snapshot);
            out.push_str(&format!(
                "fleet device {} ({}): placed={} nt={} tnn={} wait_ewma_us={} | {}\n",
                r.device,
                r.name,
                r.placed,
                r.placed_nt,
                r.placed_tnn,
                r.wait_ewma_us,
                r.snapshot.render()
            ));
        }
        out.push_str(&format!(
            "fleet total: devices={} requests={} completed={} failed={} shed={} timed_out={} modeled_completion_us={}\n",
            self.devices.len(),
            totals.requests,
            totals.completed,
            totals.failed,
            totals.shed,
            totals.timed_out,
            self.modeled_completion_us()
        ));
        out
    }

    /// Graceful stop: drain and join every device's engine. Routers (and
    /// their trainer threads) are dropped with the fleet itself.
    pub fn shutdown(mut self) {
        for dev in &mut self.devices {
            if let Some(engine) = dev.engine.get_mut().unwrap().take() {
                engine.shutdown();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::cpu::matmul_nt;
    use crate::gpusim::{GTX1080, SIMAPEX, SIMECO, TITANX};
    use crate::ml::gbdt::{Gbdt, GbdtParams};
    use crate::ml::Classifier;
    use crate::testutil::assert_allclose;

    /// A selector that always predicts `label`: a 0-estimator GBDT's
    /// base score carries the training labels' sign.
    fn constant_selector(label: i8) -> Selector {
        let p = GbdtParams {
            n_estimators: 0,
            ..GbdtParams::default()
        };
        let mut g = Gbdt::new(p);
        g.fit(
            &[vec![0.0; 8], vec![1.0; 8]],
            &[label as f64, label as f64],
        );
        Selector::new(TrainedModel::Gbdt(g))
    }

    fn request_mats(m: u64, n: u64, k: u64, seed: u64) -> (Matrix, Matrix) {
        (
            Matrix::random(m as usize, k as usize, seed),
            Matrix::random(n as usize, k as usize, seed ^ 0xBEEF),
        )
    }

    #[test]
    fn joint_placement_prefers_the_fastest_device() {
        let fleet = Fleet::with_selectors(
            &[&SIMECO, &SIMAPEX],
            FleetConfig::default(),
            |_| constant_selector(1),
        )
        .unwrap();
        let shape = GemmShape::new(32, 32, 32);
        for i in 0..4u64 {
            let (a, b) = request_mats(32, 32, 32, i);
            let expect = matmul_nt(&a, &b);
            let resp = fleet.serve(shape, a, b).unwrap();
            assert_allclose(&resp.output.data, &expect.data, 1e-4, 1e-4);
        }
        let reports = fleet.reports();
        assert_eq!(reports[0].placed, 0, "SimEco never wins the argmin");
        assert_eq!(reports[1].placed, 4);
        assert_eq!(reports[1].snapshot.completed, 4);
        fleet.conservation().unwrap();
        let table = fleet.render();
        assert!(table.contains("fleet device 1 (SimApex): placed=4"), "{table}");
        fleet.shutdown();
    }

    #[test]
    fn round_robin_deals_devices_in_rotation() {
        let fleet = Fleet::with_selectors(
            &[&SIMECO, &SIMAPEX],
            FleetConfig {
                policy: PlacementPolicy::RoundRobin,
                ..FleetConfig::default()
            },
            |_| constant_selector(1),
        )
        .unwrap();
        for i in 0..6u64 {
            let (a, b) = request_mats(16, 16, 16, i);
            fleet.serve(GemmShape::new(16, 16, 16), a, b).unwrap();
        }
        let reports = fleet.reports();
        assert_eq!(reports[0].placed, 3);
        assert_eq!(reports[1].placed, 3);
        assert!(
            fleet.modeled_completion_us() > 0,
            "modeled completion accrues"
        );
        fleet.conservation().unwrap();
        fleet.shutdown();
    }

    #[test]
    fn random_policy_is_seeded_and_conserves() {
        let run = |seed| {
            let fleet = Fleet::with_selectors(
                &[&GTX1080, &TITANX],
                FleetConfig {
                    policy: PlacementPolicy::Random,
                    seed,
                    ..FleetConfig::default()
                },
                |_| constant_selector(1),
            )
            .unwrap();
            for i in 0..8u64 {
                let (a, b) = request_mats(16, 16, 16, i);
                fleet.serve(GemmShape::new(16, 16, 16), a, b).unwrap();
            }
            fleet.conservation().unwrap();
            let placed: Vec<u64> = fleet.reports().iter().map(|r| r.placed).collect();
            fleet.shutdown();
            placed
        };
        assert_eq!(run(7), run(7), "same seed, same placements");
        assert_eq!(run(7).iter().sum::<u64>(), 8);
    }

    #[test]
    fn swap_spec_redirects_placement_and_still_serves() {
        let fleet = Fleet::with_selectors(
            &[&SIMAPEX, &GTX1080],
            FleetConfig::default(),
            |_| constant_selector(1),
        )
        .unwrap();
        let shape = GemmShape::new(32, 32, 32);
        let (a, b) = request_mats(32, 32, 32, 1);
        fleet.serve(shape, a, b).unwrap();
        assert_eq!(fleet.reports()[0].placed, 1, "SimApex wins before the swap");
        // Demote device 0 to the slowest part; the worker restarts and
        // rebuilds its SimExecutor against the new spec.
        fleet.swap_spec(0, &SIMECO).unwrap();
        assert_eq!(fleet.spec(0).id, SIMECO.id);
        for i in 2..6u64 {
            let (a, b) = request_mats(32, 32, 32, i);
            let expect = matmul_nt(&a, &b);
            let resp = fleet.serve(shape, a, b).unwrap();
            assert_allclose(&resp.output.data, &expect.data, 1e-4, 1e-4);
        }
        let reports = fleet.reports();
        assert_eq!(reports[0].placed, 1, "post-swap traffic avoids the slow part");
        assert_eq!(reports[1].placed, 4);
        fleet.conservation().unwrap();
        fleet.shutdown();
    }

    #[test]
    fn joint_beats_round_robin_on_modeled_completion() {
        // The in-crate miniature of the acceptance benchmark: identical
        // sequential traffic over a heterogeneous pair, compared on
        // total modeled completion time.
        let drive = |policy| {
            let fleet = Fleet::with_selectors(
                &[&SIMECO, &SIMAPEX],
                FleetConfig {
                    policy,
                    ..FleetConfig::default()
                },
                |_| constant_selector(1),
            )
            .unwrap();
            for i in 0..8u64 {
                let (a, b) = request_mats(64, 64, 64, i);
                fleet.serve(GemmShape::new(64, 64, 64), a, b).unwrap();
            }
            fleet.conservation().unwrap();
            let us = fleet.modeled_completion_us();
            fleet.shutdown();
            us
        };
        let joint = drive(PlacementPolicy::Joint);
        let rr = drive(PlacementPolicy::RoundRobin);
        assert!(
            rr as f64 >= 1.2 * joint as f64,
            "joint {joint}µs should beat round-robin {rr}µs by ≥1.2×"
        );
    }
}
