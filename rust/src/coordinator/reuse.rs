//! Cross-request result reuse: a bounded, epoch-aware output cache plus
//! in-flight dedup (single-flight) for idempotent engine executions.
//!
//! The paper's thesis — the fastest GEMM is the one you avoid doing
//! wrong — extends one level up: the cheapest execution is one whose
//! result the engine already has. This layer sits in the engine *submit
//! path*, in front of the worker queues:
//!
//! * **Output cache** — completed results are cached under a 128-bit
//!   content key (artifact name + every input matrix's dims and exact
//!   f32 bit pattern). A later submission with an identical key is
//!   answered straight from the cache on the submitter's own response
//!   channel — it never touches a queue, a worker, or the backend.
//! * **Single-flight dedup** — while a keyed execution is in flight, an
//!   identical submission *coalesces*: its response channel is parked on
//!   the leader's pending entry, and when the leader's worker completes,
//!   the result fans out to every waiter. N identical concurrent
//!   requests cost one execution.
//! * **Epochs** — [`ReuseLayer::invalidate`] bumps a global epoch:
//!   cached entries from older epochs are unservable (and dropped), and
//!   pending entries are keyed by `(content key, epoch)`, so a request
//!   arriving *after* an invalidation never coalesces onto a leader that
//!   started *before* it — it becomes a fresh leader. A stale leader's
//!   completion still fans out to its own (pre-invalidation) waiters but
//!   is not inserted into the cache (`stale_drops` counts these). The
//!   online loop wires model promotion to this hook so a hot-swap never
//!   leaves a result that predates it servable.
//! * **Opt-out** — artifacts whose name matches a configured deny prefix
//!   bypass the layer entirely (for non-idempotent backends/artifacts);
//!   everything the GEMM-service grammar speaks (`nt_`/`tnn_`/`nn_`/
//!   `transpose_`) is a pure function of its inputs and is reusable.
//!
//! Correctness notes: a cache hit or coalesced result is **bit-identical**
//! to fresh computation because it *is* the fresh computation's output
//! (cloned, never recomputed), and it carries the leader's measured
//! `exec_us` — a genuine measurement of this exact work. Collisions of
//! the 128-bit key (two independently seeded multiply-rotate lanes over
//! the full input content) are cryptographically unlikely but not
//! impossible; the layer is therefore default-off and opt-in per engine
//! ([`super::engine::EngineHandle::enable_reuse`]). Conservation holds
//! because every served/coalesced submission still resolves through its
//! own response channel exactly once.

use super::backend::{BreakerOpen, DeadlineExceeded, EngineBusy, TransientFault};
use super::engine::ExecReply;
use crate::gemm::cpu::Matrix;
use crate::util::rng::mix64;
use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};

/// Bounds and opt-outs for the reuse layer.
#[derive(Debug, Clone)]
pub struct ReuseConfig {
    /// Maximum cached results (LRU-evicted beyond this).
    pub capacity: usize,
    /// Results whose outputs total more floats than this are served to
    /// their waiters but not cached (memory bound per entry).
    pub max_entry_floats: usize,
    /// Artifact-name prefixes that bypass the layer entirely — the
    /// explicit opt-out for non-idempotent artifacts.
    pub deny_prefixes: Vec<String>,
}

impl Default for ReuseConfig {
    fn default() -> Self {
        ReuseConfig {
            capacity: 256,
            // 4M floats = 16 MiB per entry; a 1024³ GEMM output fits.
            max_entry_floats: 1 << 22,
            deny_prefixes: Vec::new(),
        }
    }
}

/// 128-bit content key: artifact name + input dims + exact f32 bits.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ReuseKey {
    h1: u64,
    h2: u64,
}

#[inline]
fn absorb(h: u64, v: u64, m: u64) -> u64 {
    (h ^ v).wrapping_mul(m).rotate_left(29)
}

/// Hash `(artifact, inputs)` into two independent 64-bit lanes. Covers
/// every input's dimensions and full bit-exact f32 content, so any
/// single-bit difference in any input yields a different key.
pub fn content_key(artifact: &str, inputs: &[Matrix]) -> ReuseKey {
    const M1: u64 = 0x9E37_79B9_7F4A_7C15;
    const M2: u64 = 0xC2B2_AE3D_27D4_EB4F;
    let mut h1 = 0x243F_6A88_85A3_08D3u64;
    let mut h2 = 0x1319_8A2E_0370_7344u64;
    for &b in artifact.as_bytes() {
        h1 = absorb(h1, b as u64, M1);
        h2 = absorb(h2, b as u64, M2);
    }
    let mut total = artifact.len() as u64;
    for m in inputs {
        h1 = absorb(h1, m.rows as u64, M1);
        h2 = absorb(h2, (m.cols as u64) << 1, M2);
        h1 = absorb(h1, m.cols as u64, M1);
        h2 = absorb(h2, (m.rows as u64) << 1, M2);
        for &f in &m.data {
            let v = f.to_bits() as u64;
            h1 = absorb(h1, v, M1);
            h2 = absorb(h2, v, M2);
        }
        total = total.wrapping_add(m.data.len() as u64 + 2);
    }
    ReuseKey {
        h1: mix64(h1 ^ total),
        h2: mix64(h2.rotate_left(32) ^ total),
    }
}

/// The leader's claim on an in-flight keyed execution. Carried by the
/// engine job; the worker (or a teardown sweep) must resolve it with
/// [`ReuseLayer::complete`] exactly once so waiters never hang.
#[derive(Debug, Clone, Copy)]
pub struct ReuseTicket {
    key: ReuseKey,
    epoch: u64,
}

/// Atomic reuse counters, attachable to `CoordinatorMetrics`.
#[derive(Debug, Default)]
pub struct ReuseStats {
    /// Submissions answered straight from the output cache.
    pub hits: AtomicU64,
    /// Submissions coalesced onto an in-flight identical execution.
    pub coalesced: AtomicU64,
    /// Submissions that became leaders (executed for real).
    pub misses: AtomicU64,
    /// Results inserted into the cache.
    pub inserts: AtomicU64,
    /// Cached results evicted by the LRU capacity bound.
    pub evictions: AtomicU64,
    /// Leader completions dropped from caching because an epoch bump or
    /// artifact invalidation landed while they were in flight.
    pub stale_drops: AtomicU64,
    /// Submissions that bypassed the layer via a deny prefix.
    pub bypasses: AtomicU64,
    /// Leader completions whose cache insert was suppressed because
    /// brownout disabled inserts ([`ReuseLayer::set_inserts_enabled`]).
    /// Waiters were still served.
    pub inserts_suppressed: AtomicU64,
    /// Coalesced followers whose leader failed: they resolved as
    /// failures without ever executing. A subset of `coalesced`,
    /// counted so chaos-run shed accounting can tell a follower dragged
    /// down by its leader from a request that failed on its own.
    pub coalesced_failed: AtomicU64,
}

struct Entry {
    artifact: String,
    epoch: u64,
    outputs: Vec<Matrix>,
    exec_us: f64,
    last_used: u64,
}

struct Pending {
    artifact: String,
    /// Set by [`ReuseLayer::invalidate_artifact`]: the completion still
    /// fans out to waiters (they attached before the invalidation, so
    /// the result is consistent with what they asked for) but must not
    /// enter the cache.
    poisoned: bool,
    waiters: Vec<mpsc::Sender<anyhow::Result<ExecReply>>>,
}

/// What [`ReuseLayer::begin`] decided about a submission.
pub enum Begin {
    /// Answered from the cache; the response was already sent.
    Served,
    /// Parked on an in-flight leader; the response will arrive when the
    /// leader completes.
    Coalesced,
    /// This submission leads: execute it, carry the ticket, and resolve
    /// it via [`ReuseLayer::complete`].
    Lead(ReuseTicket),
    /// Deny-listed artifact: execute without reuse bookkeeping.
    Bypass,
}

/// The engine's reuse layer. One per engine pool, shared by the submit
/// path (handle) and every worker.
pub struct ReuseLayer {
    config: ReuseConfig,
    epoch: AtomicU64,
    tick: AtomicU64,
    /// Brownout lever (level 3): when false, leader completions still fan
    /// out to their waiters but skip the cache insert — reuse stops
    /// growing memory under overload without changing correctness.
    inserts_enabled: AtomicBool,
    cache: Mutex<HashMap<ReuseKey, Entry>>,
    pending: Mutex<HashMap<(ReuseKey, u64), Pending>>,
    stats: Arc<ReuseStats>,
}

impl ReuseLayer {
    pub fn new(config: ReuseConfig) -> ReuseLayer {
        ReuseLayer {
            config,
            epoch: AtomicU64::new(0),
            tick: AtomicU64::new(0),
            inserts_enabled: AtomicBool::new(true),
            cache: Mutex::new(HashMap::new()),
            pending: Mutex::new(HashMap::new()),
            stats: Arc::new(ReuseStats::default()),
        }
    }

    pub fn stats(&self) -> Arc<ReuseStats> {
        Arc::clone(&self.stats)
    }

    /// Current reuse epoch (bumped by [`ReuseLayer::invalidate`]).
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }

    /// Enable/disable cache inserts (the brownout lever). Serving from
    /// already-cached entries and single-flight coalescing stay active
    /// either way.
    pub fn set_inserts_enabled(&self, enabled: bool) {
        self.inserts_enabled.store(enabled, Ordering::Release);
    }

    pub fn inserts_enabled(&self) -> bool {
        self.inserts_enabled.load(Ordering::Acquire)
    }

    /// Is this artifact name deny-listed (bypasses reuse — and, upstream,
    /// must never be retried: the opt-out marks non-idempotent work)?
    pub fn denied(&self, artifact: &str) -> bool {
        self.config
            .deny_prefixes
            .iter()
            .any(|p| artifact.starts_with(p.as_str()))
    }

    /// Classify a submission before it is routed to a worker queue. On
    /// [`Begin::Served`] the cached result was already sent on `respond`;
    /// on [`Begin::Coalesced`] a clone of `respond` is parked on the
    /// leader. Either way the caller must NOT enqueue the job.
    pub fn begin(
        &self,
        artifact: &str,
        inputs: &[Matrix],
        respond: &mpsc::Sender<anyhow::Result<ExecReply>>,
    ) -> Begin {
        if self.denied(artifact) {
            self.stats.bypasses.fetch_add(1, Ordering::Relaxed);
            return Begin::Bypass;
        }
        let key = content_key(artifact, inputs);
        let epoch = self.epoch.load(Ordering::Acquire);
        if let Some(reply) = self.lookup(key, epoch) {
            self.stats.hits.fetch_add(1, Ordering::Relaxed);
            let _ = respond.send(Ok(reply));
            return Begin::Served;
        }
        let mut pending = self.pending.lock().unwrap();
        if let Some(p) = pending.get_mut(&(key, epoch)) {
            p.waiters.push(respond.clone());
            self.stats.coalesced.fetch_add(1, Ordering::Relaxed);
            return Begin::Coalesced;
        }
        // Double-check the cache while holding the pending lock:
        // `complete` inserts its result and removes the pending entry
        // atomically with respect to this lock, so a leader that finished
        // between the first cache check and the lock acquisition is
        // visible here. Without this, that race would mint a duplicate
        // leader and re-execute.
        if let Some(reply) = self.lookup(key, epoch) {
            self.stats.hits.fetch_add(1, Ordering::Relaxed);
            let _ = respond.send(Ok(reply));
            return Begin::Served;
        }
        pending.insert(
            (key, epoch),
            Pending {
                artifact: artifact.to_string(),
                poisoned: false,
                waiters: Vec::new(),
            },
        );
        self.stats.misses.fetch_add(1, Ordering::Relaxed);
        Begin::Lead(ReuseTicket { key, epoch })
    }

    /// Serve `key` from the cache if an entry for the current epoch
    /// exists, touching its LRU stamp. Cross-epoch entries are lazily
    /// evicted here (invalidate() also clears eagerly; this covers
    /// entries a racing stale completion slipped in).
    fn lookup(&self, key: ReuseKey, epoch: u64) -> Option<ExecReply> {
        let mut cache = self.cache.lock().unwrap();
        match cache.get_mut(&key) {
            Some(e) if e.epoch == epoch => {
                e.last_used = self.tick.fetch_add(1, Ordering::Relaxed);
                Some(ExecReply {
                    outputs: e.outputs.clone(),
                    exec_us: e.exec_us,
                })
            }
            Some(_) => {
                cache.remove(&key);
                None
            }
            None => None,
        }
    }

    /// Resolve a leader's ticket with its execution result: cache it (if
    /// still fresh) and fan it out to every coalesced waiter. Must be
    /// called exactly once per [`Begin::Lead`] ticket — the engine worker
    /// calls it on completion, and both teardown sweeps call it with the
    /// shutdown error for ticketed jobs they fail, so no waiter ever
    /// hangs. Idempotent: a second call finds no pending entry.
    pub fn complete(&self, ticket: &ReuseTicket, result: &anyhow::Result<ExecReply>) {
        // Hold the pending lock across the cache insert: begin() re-checks
        // the cache under this lock before minting a leader, so removal
        // from pending and insertion into the cache are one atomic
        // transition from its point of view — no window where an identical
        // submission sees neither and re-executes.
        let mut pending_map = self.pending.lock().unwrap();
        let Some(p) = pending_map.remove(&(ticket.key, ticket.epoch)) else {
            return;
        };
        if let Ok(reply) = result {
            let fresh = ticket.epoch == self.epoch.load(Ordering::Acquire) && !p.poisoned;
            let floats: usize = reply.outputs.iter().map(|m| m.data.len()).sum();
            if fresh && floats <= self.config.max_entry_floats && !self.inserts_enabled() {
                self.stats.inserts_suppressed.fetch_add(1, Ordering::Relaxed);
            }
            if fresh && floats <= self.config.max_entry_floats && self.inserts_enabled() {
                let mut cache = self.cache.lock().unwrap();
                cache.insert(
                    ticket.key,
                    Entry {
                        artifact: p.artifact.clone(),
                        epoch: ticket.epoch,
                        outputs: reply.outputs.clone(),
                        exec_us: reply.exec_us,
                        last_used: self.tick.fetch_add(1, Ordering::Relaxed),
                    },
                );
                self.stats.inserts.fetch_add(1, Ordering::Relaxed);
                let cap = self.config.capacity.max(1);
                while cache.len() > cap {
                    let lru = cache
                        .iter()
                        .min_by_key(|(_, e)| e.last_used)
                        .map(|(k, _)| *k);
                    match lru {
                        Some(k) => {
                            cache.remove(&k);
                            self.stats.evictions.fetch_add(1, Ordering::Relaxed);
                        }
                        None => break,
                    }
                }
            } else if !fresh {
                self.stats.stale_drops.fetch_add(1, Ordering::Relaxed);
            }
        }
        drop(pending_map);
        if result.is_err() {
            self.stats
                .coalesced_failed
                .fetch_add(p.waiters.len() as u64, Ordering::Relaxed);
        }
        for w in p.waiters {
            let _ = w.send(clone_result(result));
        }
    }

    /// Epoch bump: every cached result becomes unservable (and is
    /// dropped), and in-flight leaders' completions will not be cached.
    /// New submissions start fresh leaders under the new epoch. Wired to
    /// online model promotion so a hot-swap never serves a result that
    /// predates it.
    pub fn invalidate(&self) {
        self.epoch.fetch_add(1, Ordering::AcqRel);
        self.cache.lock().unwrap().clear();
    }

    /// Targeted invalidation: drop cached results for one artifact and
    /// poison its in-flight leaders (their results still reach their
    /// waiters, but are not cached).
    pub fn invalidate_artifact(&self, artifact: &str) {
        self.cache
            .lock()
            .unwrap()
            .retain(|_, e| e.artifact != artifact);
        for p in self.pending.lock().unwrap().values_mut() {
            if p.artifact == artifact {
                p.poisoned = true;
            }
        }
    }

    /// Cached entries right now (tests / introspection).
    pub fn len(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Reconstruct a result for a waiter: outputs clone bit-identically;
/// errors keep the lifecycle markers typed — [`EngineBusy`] (shed),
/// [`DeadlineExceeded`] (timed out), [`BreakerOpen`] (failed fast), and
/// [`TransientFault`] (retryable) — so outcome classification survives
/// the fan-out; anything else stringifies (`anyhow::Error` is not
/// `Clone`).
fn clone_result(r: &anyhow::Result<ExecReply>) -> anyhow::Result<ExecReply> {
    match r {
        Ok(reply) => Ok(ExecReply {
            outputs: reply.outputs.clone(),
            exec_us: reply.exec_us,
        }),
        Err(e) if EngineBusy::is(e) => Err(anyhow::Error::new(EngineBusy)),
        Err(e) if DeadlineExceeded::is(e) => Err(anyhow::Error::new(DeadlineExceeded)),
        Err(e) if BreakerOpen::is(e) => Err(anyhow::Error::new(BreakerOpen)),
        Err(e) => match e.downcast_ref::<TransientFault>() {
            Some(t) => Err(anyhow::Error::new(TransientFault(t.0.clone()))),
            None => Err(anyhow::anyhow!("{e}")),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reply(seed: u64) -> ExecReply {
        ExecReply {
            outputs: vec![Matrix::random(4, 4, seed)],
            exec_us: 42.5,
        }
    }

    fn chan() -> (
        mpsc::Sender<anyhow::Result<ExecReply>>,
        mpsc::Receiver<anyhow::Result<ExecReply>>,
    ) {
        mpsc::channel()
    }

    #[test]
    fn content_key_is_input_sensitive() {
        let a = Matrix::random(8, 8, 1);
        let b = Matrix::random(8, 8, 2);
        let k1 = content_key("nt_8x8x8", &[a.clone(), b.clone()]);
        let k2 = content_key("nt_8x8x8", &[a.clone(), b.clone()]);
        assert_eq!(k1, k2, "same content, same key");
        assert_ne!(
            k1,
            content_key("tnn_8x8x8", &[a.clone(), b.clone()]),
            "artifact name is part of the key"
        );
        let mut b2 = b.clone();
        b2.data[17] = f32::from_bits(b2.data[17].to_bits() ^ 1);
        assert_ne!(
            k1,
            content_key("nt_8x8x8", &[a, b2]),
            "a single flipped bit must change the key"
        );
    }

    #[test]
    fn miss_then_hit_serves_bit_identical_outputs() {
        let layer = ReuseLayer::new(ReuseConfig::default());
        let inputs = vec![Matrix::random(4, 4, 7)];
        let (tx, _rx) = chan();
        let Begin::Lead(t) = layer.begin("nt_4x4x4", &inputs, &tx) else {
            panic!("first submission must lead");
        };
        let result = Ok(reply(9));
        layer.complete(&t, &result);
        let (tx2, rx2) = chan();
        assert!(matches!(layer.begin("nt_4x4x4", &inputs, &tx2), Begin::Served));
        let got = rx2.recv().unwrap().unwrap();
        let want = result.as_ref().unwrap();
        assert_eq!(got.outputs[0].data, want.outputs[0].data, "bit-identical");
        assert_eq!(got.exec_us, want.exec_us, "original measured latency");
        let s = layer.stats();
        assert_eq!(s.hits.load(Ordering::Relaxed), 1);
        assert_eq!(s.misses.load(Ordering::Relaxed), 1);
        assert_eq!(s.inserts.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn concurrent_identical_submissions_coalesce_onto_one_leader() {
        let layer = ReuseLayer::new(ReuseConfig::default());
        let inputs = vec![Matrix::random(4, 4, 3)];
        let (lead_tx, _lead_rx) = chan();
        let Begin::Lead(t) = layer.begin("nt_4x4x4", &inputs, &lead_tx) else {
            panic!("leader expected");
        };
        let (w1, r1) = chan();
        let (w2, r2) = chan();
        assert!(matches!(layer.begin("nt_4x4x4", &inputs, &w1), Begin::Coalesced));
        assert!(matches!(layer.begin("nt_4x4x4", &inputs, &w2), Begin::Coalesced));
        let result = Ok(reply(11));
        layer.complete(&t, &result);
        let want = &result.as_ref().unwrap().outputs[0].data;
        for rx in [r1, r2] {
            let got = rx.recv().unwrap().unwrap();
            assert_eq!(&got.outputs[0].data, want, "waiters share the leader's result");
        }
        assert_eq!(layer.stats().coalesced.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn error_fanout_keeps_engine_busy_typed() {
        let layer = ReuseLayer::new(ReuseConfig::default());
        let inputs = vec![Matrix::random(2, 2, 1)];
        let (tx, _rx) = chan();
        let Begin::Lead(t) = layer.begin("nt_2x2x2", &inputs, &tx) else {
            panic!("leader expected");
        };
        let (w, r) = chan();
        assert!(matches!(layer.begin("nt_2x2x2", &inputs, &w), Begin::Coalesced));
        layer.complete(&t, &Err(anyhow::Error::new(EngineBusy)));
        let err = r.recv().unwrap().unwrap_err();
        assert!(EngineBusy::is(&err), "busy classification survives fan-out");
        assert_eq!(layer.len(), 0, "errors are never cached");
    }

    #[test]
    fn failed_leader_counts_its_coalesced_followers() {
        let layer = ReuseLayer::new(ReuseConfig::default());
        let inputs = vec![Matrix::random(2, 2, 8)];
        let (tx, _rx) = chan();
        let Begin::Lead(t) = layer.begin("nt_2x2x2", &inputs, &tx) else {
            panic!("leader expected");
        };
        let (w1, r1) = chan();
        let (w2, r2) = chan();
        assert!(matches!(layer.begin("nt_2x2x2", &inputs, &w1), Begin::Coalesced));
        assert!(matches!(layer.begin("nt_2x2x2", &inputs, &w2), Begin::Coalesced));
        layer.complete(&t, &Err(anyhow::anyhow!("injected backend fault")));
        for rx in [r1, r2] {
            assert!(rx.recv().unwrap().is_err());
        }
        let s = layer.stats();
        assert_eq!(s.coalesced.load(Ordering::Relaxed), 2);
        assert_eq!(
            s.coalesced_failed.load(Ordering::Relaxed),
            2,
            "both followers were dragged down by the failed leader"
        );
        // A successful leader with followers leaves the counter alone.
        let inputs2 = vec![Matrix::random(2, 2, 9)];
        let (tx2, _rx2) = chan();
        let Begin::Lead(t2) = layer.begin("nt_2x2x2", &inputs2, &tx2) else {
            panic!("leader expected");
        };
        let (w3, r3) = chan();
        assert!(matches!(layer.begin("nt_2x2x2", &inputs2, &w3), Begin::Coalesced));
        layer.complete(&t2, &Ok(reply(12)));
        assert!(r3.recv().unwrap().is_ok());
        assert_eq!(layer.stats().coalesced_failed.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn epoch_bump_hides_cached_and_pending_state() {
        let layer = ReuseLayer::new(ReuseConfig::default());
        let inputs = vec![Matrix::random(4, 4, 5)];
        let (tx, _rx) = chan();
        let Begin::Lead(t) = layer.begin("nt_4x4x4", &inputs, &tx) else {
            panic!("leader expected");
        };
        layer.complete(&t, &Ok(reply(1)));
        assert_eq!(layer.len(), 1);
        layer.invalidate();
        assert_eq!(layer.len(), 0, "invalidate drops the cache");
        // The same content misses and leads again under the new epoch.
        let (tx2, _rx2) = chan();
        assert!(matches!(layer.begin("nt_4x4x4", &inputs, &tx2), Begin::Lead(_)));
    }

    #[test]
    fn stale_leader_completion_reaches_waiters_but_is_not_cached() {
        let layer = ReuseLayer::new(ReuseConfig::default());
        let inputs = vec![Matrix::random(4, 4, 6)];
        let (tx, _rx) = chan();
        let Begin::Lead(t) = layer.begin("nt_4x4x4", &inputs, &tx) else {
            panic!("leader expected");
        };
        let (w, r) = chan();
        assert!(matches!(layer.begin("nt_4x4x4", &inputs, &w), Begin::Coalesced));
        // A post-invalidation submission must NOT coalesce onto the stale
        // leader: it starts its own under the new epoch.
        layer.invalidate();
        let (tx2, _rx2) = chan();
        assert!(
            matches!(layer.begin("nt_4x4x4", &inputs, &tx2), Begin::Lead(_)),
            "new-epoch request must not join a stale leader"
        );
        layer.complete(&t, &Ok(reply(2)));
        assert!(r.recv().unwrap().is_ok(), "pre-invalidation waiter still served");
        assert_eq!(layer.len(), 0, "stale result not cached");
        assert_eq!(layer.stats().stale_drops.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn artifact_invalidation_poisons_in_flight_leaders() {
        let layer = ReuseLayer::new(ReuseConfig::default());
        let inputs = vec![Matrix::random(4, 4, 8)];
        let (tx, _rx) = chan();
        let Begin::Lead(t) = layer.begin("nt_4x4x4", &inputs, &tx) else {
            panic!("leader expected");
        };
        layer.invalidate_artifact("nt_4x4x4");
        layer.complete(&t, &Ok(reply(3)));
        assert_eq!(layer.len(), 0, "poisoned completion not cached");
        assert_eq!(layer.stats().stale_drops.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn deny_prefix_bypasses_the_layer() {
        let layer = ReuseLayer::new(ReuseConfig {
            deny_prefixes: vec!["rand_".into()],
            ..ReuseConfig::default()
        });
        let inputs = vec![Matrix::random(2, 2, 1)];
        let (tx, _rx) = chan();
        assert!(matches!(layer.begin("rand_2x2", &inputs, &tx), Begin::Bypass));
        assert!(matches!(layer.begin("nt_2x2x2", &inputs, &tx), Begin::Lead(_)));
        assert_eq!(layer.stats().bypasses.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn capacity_bound_evicts_least_recently_used() {
        let layer = ReuseLayer::new(ReuseConfig {
            capacity: 2,
            ..ReuseConfig::default()
        });
        let mk = |seed: u64| vec![Matrix::random(4, 4, seed)];
        let (tx, _rx) = chan();
        for seed in 0..3u64 {
            let inputs = mk(seed);
            let Begin::Lead(t) = layer.begin("nt_4x4x4", &inputs, &tx) else {
                panic!("distinct content must lead");
            };
            if seed == 2 {
                // Touch entry 0 so entry 1 is the LRU victim.
                let (tx0, rx0) = chan();
                assert!(matches!(layer.begin("nt_4x4x4", &mk(0), &tx0), Begin::Served));
                rx0.recv().unwrap().unwrap();
            }
            layer.complete(&t, &Ok(reply(100 + seed)));
        }
        assert_eq!(layer.len(), 2);
        assert_eq!(layer.stats().evictions.load(Ordering::Relaxed), 1);
        let (tx0, rx0) = chan();
        assert!(
            matches!(layer.begin("nt_4x4x4", &mk(0), &tx0), Begin::Served),
            "recently-touched entry survives"
        );
        rx0.recv().unwrap().unwrap();
        let (tx1, _rx1) = chan();
        assert!(
            matches!(layer.begin("nt_4x4x4", &mk(1), &tx1), Begin::Lead(_)),
            "LRU entry was evicted"
        );
    }

    #[test]
    fn oversized_outputs_are_served_but_not_cached() {
        let layer = ReuseLayer::new(ReuseConfig {
            max_entry_floats: 8,
            ..ReuseConfig::default()
        });
        let inputs = vec![Matrix::random(2, 2, 1)];
        let (tx, _rx) = chan();
        let Begin::Lead(t) = layer.begin("nt_2x2x2", &inputs, &tx) else {
            panic!("leader expected");
        };
        let (w, r) = chan();
        assert!(matches!(layer.begin("nt_2x2x2", &inputs, &w), Begin::Coalesced));
        layer.complete(&t, &Ok(reply(1))); // 16 floats > max 8
        assert!(r.recv().unwrap().is_ok());
        assert_eq!(layer.len(), 0, "oversized entry skipped");
    }

    #[test]
    fn lifecycle_errors_stay_typed_across_fanout() {
        let layer = ReuseLayer::new(ReuseConfig::default());
        let cases: Vec<(anyhow::Error, fn(&anyhow::Error) -> bool)> = vec![
            (anyhow::Error::new(DeadlineExceeded), DeadlineExceeded::is),
            (anyhow::Error::new(BreakerOpen), BreakerOpen::is),
            (
                anyhow::Error::new(TransientFault("chaos: flaky".into())),
                TransientFault::is,
            ),
        ];
        for (seed, (err, check)) in cases.into_iter().enumerate() {
            let inputs = vec![Matrix::random(2, 2, seed as u64 + 40)];
            let (tx, _rx) = chan();
            let Begin::Lead(t) = layer.begin("nt_2x2x2", &inputs, &tx) else {
                panic!("leader expected");
            };
            let (w, r) = chan();
            assert!(matches!(layer.begin("nt_2x2x2", &inputs, &w), Begin::Coalesced));
            layer.complete(&t, &Err(err));
            let got = r.recv().unwrap().unwrap_err();
            assert!(check(&got), "classification lost in fan-out: {got}");
        }
    }

    #[test]
    fn disabled_inserts_still_serve_waiters_but_skip_the_cache() {
        let layer = ReuseLayer::new(ReuseConfig::default());
        layer.set_inserts_enabled(false);
        let inputs = vec![Matrix::random(2, 2, 21)];
        let (tx, _rx) = chan();
        let Begin::Lead(t) = layer.begin("nt_2x2x2", &inputs, &tx) else {
            panic!("leader expected");
        };
        let (w, r) = chan();
        assert!(matches!(layer.begin("nt_2x2x2", &inputs, &w), Begin::Coalesced));
        layer.complete(&t, &Ok(reply(5)));
        assert!(r.recv().unwrap().is_ok(), "waiter still served");
        assert_eq!(layer.len(), 0, "insert suppressed under brownout");
        assert_eq!(layer.stats().inserts_suppressed.load(Ordering::Relaxed), 1);
        // Restoring the lever restores caching.
        layer.set_inserts_enabled(true);
        let inputs2 = vec![Matrix::random(2, 2, 22)];
        let (tx2, _rx2) = chan();
        let Begin::Lead(t2) = layer.begin("nt_2x2x2", &inputs2, &tx2) else {
            panic!("leader expected");
        };
        layer.complete(&t2, &Ok(reply(6)));
        assert_eq!(layer.len(), 1, "inserts resume after recovery");
    }

    #[test]
    fn double_complete_is_idempotent() {
        let layer = ReuseLayer::new(ReuseConfig::default());
        let inputs = vec![Matrix::random(2, 2, 2)];
        let (tx, _rx) = chan();
        let Begin::Lead(t) = layer.begin("nt_2x2x2", &inputs, &tx) else {
            panic!("leader expected");
        };
        layer.complete(&t, &Ok(reply(1)));
        layer.complete(&t, &Ok(reply(2))); // no pending entry: no-op
        assert_eq!(layer.stats().inserts.load(Ordering::Relaxed), 1);
    }
}
