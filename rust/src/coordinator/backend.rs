//! The execution-backend abstraction of the engine pool.
//!
//! [`ExecBackend`] is the seam between the coordinator's *decision* layer
//! (router + selector) and its *execution* layer (the worker pool in
//! [`super::engine`]): anything that can turn an artifact name plus host
//! matrices into output matrices can serve traffic. The crate ships three
//! implementations —
//!
//! * [`crate::runtime::Runtime`] — PJRT execution of the AOT-compiled
//!   Pallas/JAX artifact catalog;
//! * [`crate::gemm::native::NativeExecutor`] — the blocked CPU kernels,
//!   no catalog required;
//! * [`crate::gpusim::SimExecutor`] — deterministic simulated-GPU
//!   execution (oracle numerics + calibrated latency model), so latency
//!   experiments ride the same serving path as real traffic —
//!
//! and tests are free to add their own (e.g. a stalling backend to force
//! queue-full backpressure).
//!
//! The `Send` bound is what lets a worker thread own a `Box<dyn
//! ExecBackend>` built on the spawning thread. The vendored `xla` stub's
//! client is a plain struct, so [`crate::runtime::Runtime`] qualifies; with
//! the real `Rc`-based `xla-rs` client the PJRT impl would instead have to
//! be constructed on its worker thread (and the pool restricted to
//! building it there).

use crate::gemm::cpu::Matrix;
use std::fmt;

/// What actually executes artifacts on an engine worker thread.
pub trait ExecBackend: Send {
    /// Run `artifact` on `inputs`, producing the outputs.
    fn execute(&self, artifact: &str, inputs: &[&Matrix]) -> anyhow::Result<Vec<Matrix>>;

    /// Run `artifact` and report `(outputs, execution-latency µs)` — the
    /// engine-worker timing hook behind the online telemetry loop
    /// (`crate::online`). The default wall-clocks [`ExecBackend::execute`];
    /// backends with a better notion of time override it (the simulated
    /// GPU reports *modeled* latency so the online loop learns the
    /// simulated hardware, not the host CPU).
    fn execute_timed(&self, artifact: &str, inputs: &[&Matrix]) -> anyhow::Result<(Vec<Matrix>, f64)> {
        let t0 = std::time::Instant::now();
        let out = self.execute(artifact, inputs)?;
        Ok((out, t0.elapsed().as_secs_f64() * 1e6))
    }

    /// Eagerly compile / pre-touch artifacts (default: nothing to do).
    fn warmup(&self, _names: &[&str]) -> anyhow::Result<()> {
        Ok(())
    }

    /// Human-readable backend identity (for logs and reports).
    fn name(&self) -> String;
}

/// Admission-control rejection: every worker queue in the pool is full.
///
/// Returned (inside `anyhow::Error`) by `EngineHandle::try_submit` and, via
/// `RouterConfig::admission`, surfaced to clients that opted into fail-fast
/// behaviour instead of blocking backpressure. Detect it with
/// [`EngineBusy::is`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EngineBusy;

impl EngineBusy {
    /// Whether `err` is an admission-control rejection.
    pub fn is(err: &anyhow::Error) -> bool {
        err.downcast_ref::<EngineBusy>().is_some()
    }
}

impl fmt::Display for EngineBusy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("engine busy: every worker queue is full")
    }
}

impl std::error::Error for EngineBusy {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_busy_is_detectable_through_anyhow() {
        let e = anyhow::Error::new(EngineBusy);
        assert!(EngineBusy::is(&e));
        assert!(e.to_string().contains("busy"));
        let other = anyhow::anyhow!("some other failure");
        assert!(!EngineBusy::is(&other));
    }

    #[test]
    fn default_warmup_is_a_noop() {
        struct Nop;
        impl ExecBackend for Nop {
            fn execute(&self, _a: &str, _i: &[&Matrix]) -> anyhow::Result<Vec<Matrix>> {
                Ok(vec![])
            }
            fn name(&self) -> String {
                "nop".into()
            }
        }
        assert!(Nop.warmup(&["anything"]).is_ok());
        assert_eq!(Nop.name(), "nop");
    }
}
