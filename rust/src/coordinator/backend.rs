//! The execution-backend abstraction of the engine pool.
//!
//! [`ExecBackend`] is the seam between the coordinator's *decision* layer
//! (router + selector) and its *execution* layer (the worker pool in
//! [`super::engine`]): anything that can turn an artifact name plus host
//! matrices into output matrices can serve traffic. The crate ships three
//! implementations —
//!
//! * [`crate::runtime::Runtime`] — PJRT execution of the AOT-compiled
//!   Pallas/JAX artifact catalog;
//! * [`crate::gemm::native::NativeExecutor`] — the blocked CPU kernels,
//!   no catalog required;
//! * [`crate::gpusim::SimExecutor`] — deterministic simulated-GPU
//!   execution (oracle numerics + calibrated latency model), so latency
//!   experiments ride the same serving path as real traffic —
//!
//! and tests are free to add their own (e.g. a stalling backend to force
//! queue-full backpressure).
//!
//! The `Send` bound is what lets a worker thread own a `Box<dyn
//! ExecBackend>` built on the spawning thread. The vendored `xla` stub's
//! client is a plain struct, so [`crate::runtime::Runtime`] qualifies; with
//! the real `Rc`-based `xla-rs` client the PJRT impl would instead have to
//! be constructed on its worker thread (and the pool restricted to
//! building it there).

use crate::gemm::cpu::Matrix;
use std::fmt;

/// What actually executes artifacts on an engine worker thread.
pub trait ExecBackend: Send {
    /// Run `artifact` on `inputs`, producing the outputs.
    fn execute(&self, artifact: &str, inputs: &[&Matrix]) -> anyhow::Result<Vec<Matrix>>;

    /// Run `artifact` and report `(outputs, execution-latency µs)` — the
    /// engine-worker timing hook behind the online telemetry loop
    /// (`crate::online`). The default wall-clocks [`ExecBackend::execute`];
    /// backends with a better notion of time override it (the simulated
    /// GPU reports *modeled* latency so the online loop learns the
    /// simulated hardware, not the host CPU).
    fn execute_timed(&self, artifact: &str, inputs: &[&Matrix]) -> anyhow::Result<(Vec<Matrix>, f64)> {
        let t0 = std::time::Instant::now();
        let out = self.execute(artifact, inputs)?;
        Ok((out, t0.elapsed().as_secs_f64() * 1e6))
    }

    /// Eagerly compile / pre-touch artifacts (default: nothing to do).
    fn warmup(&self, _names: &[&str]) -> anyhow::Result<()> {
        Ok(())
    }

    /// Human-readable backend identity (for logs and reports).
    fn name(&self) -> String;
}

/// Admission-control rejection: every worker queue in the pool is full.
///
/// Returned (inside `anyhow::Error`) by `EngineHandle::try_submit` and, via
/// `RouterConfig::admission`, surfaced to clients that opted into fail-fast
/// behaviour instead of blocking backpressure. Detect it with
/// [`EngineBusy::is`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EngineBusy;

impl EngineBusy {
    /// Whether `err` is an admission-control rejection.
    pub fn is(err: &anyhow::Error) -> bool {
        err.downcast_ref::<EngineBusy>().is_some()
    }
}

impl fmt::Display for EngineBusy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("engine busy: every worker queue is full")
    }
}

impl std::error::Error for EngineBusy {}

/// Deadline expiry: the request ran out of its per-request time budget —
/// at admission, while waiting in a worker queue (the job is dropped
/// without executing), or while the client waited for the response.
///
/// Distinct from both [`EngineBusy`] (load shed) and ordinary execution
/// failure: the conservation ledger counts it in its own `timed_out`
/// term. Detect it with [`DeadlineExceeded::is`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DeadlineExceeded;

impl DeadlineExceeded {
    /// Whether `err` is a deadline expiry.
    pub fn is(err: &anyhow::Error) -> bool {
        err.downcast_ref::<DeadlineExceeded>().is_some()
    }
}

impl fmt::Display for DeadlineExceeded {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("deadline exceeded: request ran out of its time budget")
    }
}

impl std::error::Error for DeadlineExceeded {}

/// Fail-fast rejection because the artifact's circuit breaker is open
/// and no alternate-algorithm fallback was viable.
///
/// Distinct from [`EngineBusy`]: a shed means *the pool* has no room, a
/// breaker-open means *this artifact* is considered sick. Counted as a
/// failure (not a shed) in the conservation ledger. Detect it with
/// [`BreakerOpen::is`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BreakerOpen;

impl BreakerOpen {
    /// Whether `err` is a breaker-open rejection.
    pub fn is(err: &anyhow::Error) -> bool {
        err.downcast_ref::<BreakerOpen>().is_some()
    }
}

impl fmt::Display for BreakerOpen {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str("circuit breaker open: artifact is failing fast")
    }
}

impl std::error::Error for BreakerOpen {}

/// Typed marker for *transient* backend faults — failures a bounded
/// retry is allowed to re-attempt (injected chaos faults, recoverable
/// I/O hiccups). Anything not carrying this marker is classified
/// [`ErrorClass::Permanent`] and is never retried.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TransientFault(pub String);

impl TransientFault {
    /// Whether `err` carries the transient marker.
    pub fn is(err: &anyhow::Error) -> bool {
        err.downcast_ref::<TransientFault>().is_some()
    }
}

impl fmt::Display for TransientFault {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for TransientFault {}

/// Retry-relevant classification of an `ExecBackend` failure.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorClass {
    /// Worth re-attempting: the fault is not expected to recur.
    Transient,
    /// Retrying would repeat the same failure (or the error is a policy
    /// outcome — shed, timeout, breaker — that retries must not mask).
    Permanent,
}

/// Classify a backend error for the router's retry policy. Only errors
/// carrying the [`TransientFault`] marker are transient; sheds,
/// timeouts, and breaker rejections are policy outcomes, never retried
/// as if they were backend faults.
pub fn classify_error(err: &anyhow::Error) -> ErrorClass {
    if TransientFault::is(err) {
        ErrorClass::Transient
    } else {
        ErrorClass::Permanent
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_busy_is_detectable_through_anyhow() {
        let e = anyhow::Error::new(EngineBusy);
        assert!(EngineBusy::is(&e));
        assert!(e.to_string().contains("busy"));
        let other = anyhow::anyhow!("some other failure");
        assert!(!EngineBusy::is(&other));
    }

    #[test]
    fn lifecycle_errors_are_typed_and_distinct() {
        let timeout = anyhow::Error::new(DeadlineExceeded);
        let breaker = anyhow::Error::new(BreakerOpen);
        let busy = anyhow::Error::new(EngineBusy);
        assert!(DeadlineExceeded::is(&timeout));
        assert!(!DeadlineExceeded::is(&breaker));
        assert!(!DeadlineExceeded::is(&busy));
        assert!(BreakerOpen::is(&breaker));
        assert!(!BreakerOpen::is(&timeout));
        assert!(!EngineBusy::is(&breaker));
        assert!(timeout.to_string().contains("deadline"));
        assert!(breaker.to_string().contains("breaker"));
    }

    #[test]
    fn transient_marker_drives_classification() {
        let t = anyhow::Error::new(TransientFault("chaos: injected transient failure".into()));
        assert_eq!(classify_error(&t), ErrorClass::Transient);
        assert!(t.to_string().contains("injected transient"));
        for e in [
            anyhow::anyhow!("numerical blowup"),
            anyhow::Error::new(EngineBusy),
            anyhow::Error::new(DeadlineExceeded),
            anyhow::Error::new(BreakerOpen),
        ] {
            assert_eq!(classify_error(&e), ErrorClass::Permanent, "{e}");
        }
    }

    #[test]
    fn default_warmup_is_a_noop() {
        struct Nop;
        impl ExecBackend for Nop {
            fn execute(&self, _a: &str, _i: &[&Matrix]) -> anyhow::Result<Vec<Matrix>> {
                Ok(vec![])
            }
            fn name(&self) -> String {
                "nop".into()
            }
        }
        assert!(Nop.warmup(&["anything"]).is_ok());
        assert_eq!(Nop.name(), "nop");
    }
}
