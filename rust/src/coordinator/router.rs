//! The router: the client-facing API of the GEMM service. For each
//! request it runs Algorithm 2 (O(1) features → GBDT predict → memory
//! fallback), maps (shape, algorithm) onto a catalog artifact, and hands
//! the job to the engine. A micro-batcher groups same-artifact requests
//! submitted together so the engine executes them back-to-back.

use super::engine::EngineHandle;
use super::metrics::CoordinatorMetrics;
use crate::gemm::cpu::Matrix;
use crate::gemm::xla::XlaBackend;
use crate::gemm::{Algorithm, GemmShape};
use crate::gpusim::GpuSpec;
use crate::selector::{SelectionReason, Selector};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

/// One NT-operation request: `C = A × Bᵀ` on (virtual) GPU `gpu`.
pub struct GemmRequest {
    pub gpu: &'static GpuSpec,
    pub shape: GemmShape,
    /// A is m×k.
    pub a: Matrix,
    /// B is n×k.
    pub b: Matrix,
}

/// The response: the product plus what the coordinator decided and why.
#[derive(Debug)]
pub struct GemmResponse {
    pub output: Matrix,
    pub algorithm: Algorithm,
    pub reason: SelectionReason,
    pub artifact: String,
    pub latency: std::time::Duration,
}

/// Router configuration.
#[derive(Debug, Clone)]
pub struct RouterConfig {
    /// Force a fixed algorithm instead of MTNN (baseline modes).
    pub force: Option<Algorithm>,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig { force: None }
    }
}

/// The router. Cheap to share via `Arc`; submission is thread-safe.
pub struct Router {
    selector: Selector,
    engine: EngineHandle,
    pub metrics: Arc<CoordinatorMetrics>,
    config: RouterConfig,
}

impl Router {
    pub fn new(selector: Selector, engine: EngineHandle, config: RouterConfig) -> Router {
        Router {
            selector,
            engine,
            metrics: Arc::new(CoordinatorMetrics::default()),
            config,
        }
    }

    /// Decide the algorithm for a request (Algorithm 2 + config override).
    pub fn decide(&self, req: &GemmRequest) -> (Algorithm, SelectionReason) {
        if let Some(forced) = self.config.force {
            return (forced, SelectionReason::PredictedNt);
        }
        let GemmShape { m, n, k } = req.shape;
        self.selector.select(req.gpu, m, n, k)
    }

    /// Serve one request synchronously.
    pub fn serve(&self, req: GemmRequest) -> anyhow::Result<GemmResponse> {
        let t0 = Instant::now();
        self.metrics
            .requests
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let (algo, reason) = self.decide(&req);
        self.metrics
            .record_selection(algo, reason == SelectionReason::MemoryFallback);
        let artifact = XlaBackend::artifact_name(req.shape, algo);
        let result = self.engine.run(&artifact, vec![req.a, req.b]);
        match result {
            Ok(mut outs) => {
                anyhow::ensure!(outs.len() == 1, "{artifact}: expected one output");
                let latency = t0.elapsed();
                self.metrics
                    .completed
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                self.metrics
                    .record_latency_us(latency.as_secs_f64() * 1e6);
                Ok(GemmResponse {
                    output: outs.remove(0),
                    algorithm: algo,
                    reason,
                    artifact,
                    latency,
                })
            }
            Err(e) => {
                self.metrics
                    .failed
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                Err(e)
            }
        }
    }

    /// Serve a batch: requests are grouped by decided artifact so the
    /// engine runs same-shape executables back-to-back (dispatch
    /// amortization); responses come back in submission order.
    pub fn serve_batch(&self, reqs: Vec<GemmRequest>) -> Vec<anyhow::Result<GemmResponse>> {
        let n = reqs.len();
        // Decide everything first.
        let mut decided: Vec<(usize, GemmRequest, Algorithm, SelectionReason, String)> = reqs
            .into_iter()
            .enumerate()
            .map(|(i, r)| {
                self.metrics
                    .requests
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                let (algo, reason) = self.decide(&r);
                self.metrics
                    .record_selection(algo, reason == SelectionReason::MemoryFallback);
                let artifact = XlaBackend::artifact_name(r.shape, algo);
                (i, r, algo, reason, artifact)
            })
            .collect();
        // Group by artifact (stable sort keeps submission order per group).
        decided.sort_by(|a, b| a.4.cmp(&b.4).then(a.0.cmp(&b.0)));

        // Pipeline: submit each group's jobs, then collect.
        let mut pending: Vec<(
            usize,
            Algorithm,
            SelectionReason,
            String,
            Instant,
            mpsc::Receiver<anyhow::Result<Vec<Matrix>>>,
        )> = Vec::with_capacity(n);
        for (i, r, algo, reason, artifact) in decided {
            let t0 = Instant::now();
            match self.engine.submit(artifact.clone(), vec![r.a, r.b]) {
                Ok(rx) => pending.push((i, algo, reason, artifact, t0, rx)),
                Err(e) => {
                    self.metrics
                        .failed
                        .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    // Represent the submission failure in-order below.
                    let (tx, rx) = mpsc::channel();
                    let _ = tx.send(Err(e));
                    pending.push((i, algo, reason, artifact, t0, rx));
                }
            }
        }
        let mut out: Vec<Option<anyhow::Result<GemmResponse>>> =
            (0..n).map(|_| None).collect();
        for (i, algo, reason, artifact, t0, rx) in pending {
            let res = rx
                .recv()
                .map_err(|_| anyhow::anyhow!("engine dropped response"))
                .and_then(|r| r)
                .and_then(|mut outs| {
                    anyhow::ensure!(outs.len() == 1, "{artifact}: expected one output");
                    let latency = t0.elapsed();
                    self.metrics
                        .completed
                        .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    self.metrics.record_latency_us(latency.as_secs_f64() * 1e6);
                    Ok(GemmResponse {
                        output: outs.remove(0),
                        algorithm: algo,
                        reason,
                        artifact: artifact.clone(),
                        latency,
                    })
                });
            if res.is_err() {
                self.metrics
                    .failed
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            }
            out[i] = Some(res);
        }
        out.into_iter().map(|o| o.expect("all slots filled")).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_uses_selector() {
        let c = RouterConfig::default();
        assert!(c.force.is_none());
    }
}
