//! The router: the client-facing API of the GEMM service. For each
//! request it runs Algorithm 2 (O(1) features → GBDT predict → memory
//! fallback), maps (shape, algorithm) onto a catalog artifact, and hands
//! the job to the engine pool, whose shape-affinity sharding and adaptive
//! micro-batcher group same-artifact work engine-side. Admission control
//! decides what happens when every worker queue is full: block (bounded
//! backpressure, the default) or fail fast with [`EngineBusy`].
//!
//! With [`RouterConfig::online`] the router closes the loop
//! (`crate::online`): the model lives behind a hot-swappable
//! [`LiveSelector`], every execution's measured latency is recorded into
//! the sample ring, and an adaptive slice of predicted requests is
//! **shadow-probed** (both NT and TNN run; the measured winner becomes a
//! labeled example and feeds the drift tracker). The probe interval per
//! shape bucket tightens toward `probe_every_min` while the bucket's
//! decayed mispredict rate is high and relaxes toward `probe_every_max`
//! when it is clean, with a deterministic epsilon-greedy floor so stable
//! buckets keep a trickle of exploration; a background trainer
//! retrains/promotes without ever blocking the serving path. The hot path
//! stays lock-free: a cache hit in the epoch-checked
//! [`DecisionCache`] touches no lock, and a promotion invalidates the
//! cache atomically so stale decisions cannot outlive their model.

use super::backend::EngineBusy;
use super::engine::{EngineHandle, ExecReply};
use super::metrics::CoordinatorMetrics;
use crate::gemm::cpu::Matrix;
use crate::gemm::xla::XlaBackend;
use crate::gemm::{Algorithm, GemmShape};
use crate::gpusim::{GpuSpec, Simulator};
use crate::obs::{span as obs_span, ObsLayer, SpanHandle};
use crate::online::{trainer, Accumulator, LiveSelector, OnlineConfig, OnlineHub};
use crate::selector::cache::DecisionCache;
use crate::selector::{SelectionReason, Selector, TrainedModel};
use std::sync::atomic::Ordering;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

/// One NT-operation request: `C = A × Bᵀ` on (virtual) GPU `gpu`.
pub struct GemmRequest {
    pub gpu: &'static GpuSpec,
    pub shape: GemmShape,
    /// A is m×k.
    pub a: Matrix,
    /// B is n×k.
    pub b: Matrix,
}

/// The response: the product plus what the coordinator decided and why.
#[derive(Debug)]
pub struct GemmResponse {
    pub output: Matrix,
    pub algorithm: Algorithm,
    pub reason: SelectionReason,
    pub artifact: String,
    pub latency: std::time::Duration,
}

/// What to do when every engine worker queue is full.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AdmissionControl {
    /// Block the caller until the affine worker has room (bounded
    /// backpressure — the pre-pool semantics).
    #[default]
    Block,
    /// Try the affine worker, hand off to any worker with room, and fail
    /// fast with [`EngineBusy`] when all queues are full (counted in
    /// `CoordinatorMetrics::busy_rejections`; the rejection reaching the
    /// caller counts as `shed`, not `failed`).
    RejectWhenBusy,
}

/// Router configuration.
#[derive(Debug, Clone)]
pub struct RouterConfig {
    /// Force a fixed algorithm instead of MTNN (baseline modes).
    pub force: Option<Algorithm>,
    /// Memoize decisions by `(gpu, m, n, k)` — steady-state traffic
    /// (FCN training re-issues identical shapes every iteration) then
    /// pays a lock-free table lookup instead of a GBDT descent. On by
    /// default; disable for selection microbenchmarks.
    pub cache_decisions: bool,
    /// Queue-full policy (see [`AdmissionControl`]).
    pub admission: AdmissionControl,
    /// Online adaptive selection (`None` = the offline paper behavior).
    pub online: Option<OnlineConfig>,
    /// Observability layer (`crate::obs`): request-path tracing, windowed
    /// rates, and the flight recorder. `None` (the default) keeps the
    /// serving path exactly as before; sharing the same `Arc` across
    /// routers aggregates their traffic into one layer.
    pub obs: Option<Arc<ObsLayer>>,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            force: None,
            cache_decisions: true,
            admission: AdmissionControl::default(),
            online: None,
            obs: None,
        }
    }
}

impl RouterConfig {
    /// Default config with the online adaptive-selection loop enabled.
    pub fn online(config: OnlineConfig) -> RouterConfig {
        RouterConfig {
            online: Some(config),
            ..RouterConfig::default()
        }
    }
}

/// The online loop's runtime half owned by the router: the shared hub
/// plus the background trainer thread (joined on drop).
struct OnlineRuntime {
    hub: Arc<OnlineHub>,
    trainer: Option<std::thread::JoinHandle<()>>,
}

/// The router. Cheap to share via `Arc`; submission is thread-safe.
pub struct Router {
    live: Arc<LiveSelector>,
    engine: EngineHandle,
    pub metrics: Arc<CoordinatorMetrics>,
    config: RouterConfig,
    cache: Arc<DecisionCache>,
    online: Option<OnlineRuntime>,
}

impl Router {
    pub fn new(selector: Selector, engine: EngineHandle, config: RouterConfig) -> Router {
        let metrics = Arc::new(CoordinatorMetrics::default());
        metrics.attach_worker_depths(engine.depth_gauges());
        metrics.attach_batch_gauges(engine.batch_gauges());
        if let Some(layer) = engine.reuse() {
            metrics.attach_reuse(layer.stats());
        }
        if let Some(obs) = &config.obs {
            metrics.attach_obs(Arc::clone(obs));
        }
        let live = Arc::new(LiveSelector::new(selector));
        let cache = Arc::new(DecisionCache::default());
        let online = config.online.clone().map(|cfg| {
            let mut acc = Accumulator::for_config(&cfg);
            // Warm restart: reload the persisted dataset and, when one was
            // saved, hot-swap the persisted model in before any traffic.
            if let Some(path) = &cfg.persist_path {
                if path.exists() {
                    match trainer::load_store(path) {
                        Ok((examples, seen, model)) => {
                            acc.preload(examples, seen);
                            if let Some(g) = model {
                                live.swap(Selector::new(TrainedModel::Gbdt(g)));
                                cache.invalidate();
                            }
                        }
                        Err(e) => {
                            eprintln!("online: ignoring corrupt store {}: {e}", path.display())
                        }
                    }
                }
            }
            let hub = Arc::new(OnlineHub::new(
                cfg,
                Arc::clone(&live),
                Arc::clone(&cache),
                Arc::clone(&metrics),
            ));
            // A model promotion also bumps the engine's reuse epoch (when
            // the layer is enabled): conservative, but it keeps the hard
            // guarantee that no served-from-cache result predates the
            // live-model swap — mirroring how promotion already
            // invalidates the decision cache.
            if let Some(layer) = engine.reuse() {
                let layer = Arc::clone(layer);
                hub.add_promotion_hook(Box::new(move || layer.invalidate()));
            }
            let join = trainer::spawn(Arc::clone(&hub), acc);
            OnlineRuntime {
                hub,
                trainer: Some(join),
            }
        });
        Router {
            live,
            engine,
            metrics,
            config,
            cache,
            online,
        }
    }

    /// The online hub (drift tracker, sample ring, live-model generation)
    /// when the loop is enabled — exposed for tests, examples, and
    /// operational introspection.
    pub fn online_hub(&self) -> Option<Arc<OnlineHub>> {
        self.online.as_ref().map(|rt| Arc::clone(&rt.hub))
    }

    /// Decide the algorithm for a request (Algorithm 2 + config override),
    /// memoized by shape when `cache_decisions` is on. Selection is
    /// deterministic *within a model generation*, so the cache is
    /// epoch-stamped: it is captured before the model runs and a decision
    /// computed under a model that was swapped out mid-flight is never
    /// published.
    pub fn decide(&self, req: &GemmRequest) -> (Algorithm, SelectionReason) {
        if let Some(forced) = self.config.force {
            return (forced, SelectionReason::Forced);
        }
        let GemmShape { m, n, k } = req.shape;
        if !self.config.cache_decisions {
            return self.live.select(req.gpu, m, n, k);
        }
        let epoch = self.cache.epoch();
        if let Some(hit) = self.cache.get(req.gpu, m, n, k) {
            return hit;
        }
        let dec = self.live.select(req.gpu, m, n, k);
        self.cache.insert_at(epoch, req.gpu, m, n, k, dec);
        dec
    }

    /// Pre-compile / pre-touch the artifacts behind `shapes` on every pool
    /// worker, covering both selectable algorithms so a later decision
    /// flip never pays a cold compile. Saves callers from hand-building
    /// artifact-name strings.
    pub fn warmup(&self, shapes: &[GemmShape]) -> anyhow::Result<()> {
        let mut names = Vec::with_capacity(shapes.len() * 2);
        for &shape in shapes {
            names.push(XlaBackend::artifact_name(shape, Algorithm::Nt));
            names.push(XlaBackend::artifact_name(shape, Algorithm::Tnn));
        }
        names.sort();
        names.dedup();
        self.engine.warmup(&names)
    }

    /// Submit through the configured admission policy, counting fail-fast
    /// rejections. A trace span (if this request drew one) rides along so
    /// the engine can stamp its stage boundaries.
    fn submit(
        &self,
        artifact: String,
        inputs: Vec<Matrix>,
        span: Option<SpanHandle>,
    ) -> anyhow::Result<mpsc::Receiver<anyhow::Result<ExecReply>>> {
        let block = matches!(self.config.admission, AdmissionControl::Block);
        let res = self.engine.submit_traced(artifact, inputs, block, span);
        if res.as_ref().err().is_some_and(EngineBusy::is) {
            self.metrics.busy_rejections.fetch_add(1, Ordering::Relaxed);
        }
        res
    }

    /// Account one request-ending error: admission-control rejections are
    /// `shed` (the caller lost the request to backpressure policy, not to
    /// a malfunction), everything else is `failed`. Disjoint by
    /// construction, so `completed + failed + shed == requests` holds at
    /// quiescence — see [`super::metrics::MetricsSnapshot::verify_conservation`].
    fn record_failure(&self, e: &anyhow::Error) {
        if EngineBusy::is(e) {
            self.metrics.shed.fetch_add(1, Ordering::Relaxed);
            if let Some(o) = &self.config.obs {
                o.mark_shed();
            }
        } else {
            self.metrics.failed.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// `TraceSpan` code for the chosen algorithm.
    fn algo_code(algo: Algorithm) -> u8 {
        match algo {
            Algorithm::Nt => obs_span::ALGO_NT,
            Algorithm::Tnn => obs_span::ALGO_TNN,
            Algorithm::Nn => obs_span::ALGO_NN,
        }
    }

    /// `TraceSpan` code for the selection reason.
    fn reason_code(reason: SelectionReason) -> u8 {
        match reason {
            SelectionReason::PredictedNt => obs_span::REASON_PREDICTED_NT,
            SelectionReason::PredictedTnn => obs_span::REASON_PREDICTED_TNN,
            SelectionReason::MemoryFallback => obs_span::REASON_MEMORY_FALLBACK,
            SelectionReason::Forced => obs_span::REASON_FORCED,
        }
    }

    /// The label the live model effectively predicted, from the selection
    /// reason (0 when the model was bypassed).
    fn predicted_label(reason: SelectionReason) -> i8 {
        match reason {
            SelectionReason::PredictedNt => 1,
            SelectionReason::PredictedTnn => -1,
            SelectionReason::MemoryFallback | SelectionReason::Forced => 0,
        }
    }

    /// Whether this request should be shadow-probed: the online loop is
    /// on, the model actually predicted (never second-guess a memory
    /// fallback — TNN might not fit), and the adaptive per-bucket
    /// schedule (or its bandit floor) selects it.
    fn should_probe(&self, req: &GemmRequest, predicted: i8) -> bool {
        let Some(rt) = &self.online else {
            return false;
        };
        let GemmShape { m, n, k } = req.shape;
        predicted != 0
            && Simulator::tnn_workspace_bytes(m, n, k) <= req.gpu.global_mem_bytes()
            && rt.hub.should_probe(req.gpu.id, m, n, k)
    }

    /// Serve one request synchronously.
    pub fn serve(&self, req: GemmRequest) -> anyhow::Result<GemmResponse> {
        let t0 = Instant::now();
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        // Tracing: draw a span if this request falls on the sampling
        // lattice. Entry and selection are stamped here; the engine and
        // worker stamp the rest through the shared cell.
        let obs = self.config.obs.as_deref();
        let span = obs.and_then(|o| o.begin_span());
        if let Some(o) = obs {
            o.mark_request();
        }
        let t_entry = span.as_ref().map(|c| c.now_us()).unwrap_or(0);
        let (algo, reason) = self.decide(&req);
        let t_select = span.as_ref().map(|c| c.now_us()).unwrap_or(0);
        self.metrics.record_selection(algo, reason);
        let predicted = Router::predicted_label(reason);
        let artifact = XlaBackend::artifact_name(req.shape, algo);

        // Shadow probe: run the *other* algorithm's artifact alongside the
        // chosen one. Best-effort — a busy engine or an execution failure
        // on the shadow side only costs the training sample, never the
        // request — and it is submitted strictly *after* the primary so a
        // probe can never consume the queue slot the real request needed.
        let shadow_inputs = if self.should_probe(&req, predicted) {
            let other = match algo {
                Algorithm::Nt => Algorithm::Tnn,
                _ => Algorithm::Nt,
            };
            Some((
                XlaBackend::artifact_name(req.shape, other),
                req.a.clone(),
                req.b.clone(),
            ))
        } else {
            None
        };

        let GemmShape { m, n, k } = req.shape;
        let gpu = req.gpu;
        let submitted = self.submit(artifact.clone(), vec![req.a, req.b], span.clone());
        let shadow = match (&submitted, shadow_inputs) {
            (Ok(_), Some((shadow_artifact, a, b))) => {
                self.engine.try_submit(shadow_artifact, vec![a, b]).ok()
            }
            _ => None,
        };
        let outcome = submitted.and_then(|rx| {
            let reply = rx
                .recv()
                .map_err(|_| anyhow::anyhow!("engine dropped the response"))??;
            anyhow::ensure!(reply.outputs.len() == 1, "{artifact}: expected one output");
            Ok(reply)
        });
        match outcome {
            Ok(mut reply) => {
                let output = reply.outputs.remove(0);
                let latency = t0.elapsed();
                self.metrics.completed.fetch_add(1, Ordering::Relaxed);
                self.metrics.record_latency_us(latency.as_secs_f64() * 1e6);
                if let Some(o) = obs {
                    o.mark_completed();
                    // Flatten the stamped cell into an immutable span and
                    // hand it to the layer (stage attribution, span ring,
                    // flight recorder).
                    if let Some(cell) = &span {
                        o.complete(cell.to_span(
                            t_entry,
                            t_select,
                            cell.now_us(),
                            Router::algo_code(algo),
                            Router::reason_code(reason),
                            obs_span::OUTCOME_COMPLETED,
                        ));
                    }
                }
                if let Some(rt) = &self.online {
                    let shadow_us = shadow.and_then(|rx| {
                        rx.recv().ok().and_then(|r| r.ok()).map(|r| r.exec_us)
                    });
                    match shadow_us {
                        Some(other_us) => {
                            let (lat_nt, lat_tnn) = match algo {
                                Algorithm::Nt => (reply.exec_us, other_us),
                                _ => (other_us, reply.exec_us),
                            };
                            let mispredicted = rt
                                .hub
                                .record_probe(gpu, m, n, k, predicted, lat_nt, lat_tnn);
                            if let Some(o) = obs {
                                o.mark_probe();
                                if mispredicted {
                                    o.mark_mispredict();
                                }
                                // Regret: what the request cost versus the
                                // measured winner — the probe already paid
                                // for the counterfactual.
                                o.record_regret(
                                    reply.exec_us.round() as u64,
                                    lat_nt.min(lat_tnn).round() as u64,
                                );
                            }
                        }
                        None => rt
                            .hub
                            .record_execution(gpu, m, n, k, algo, reply.exec_us, predicted),
                    }
                }
                Ok(GemmResponse {
                    output,
                    algorithm: algo,
                    reason,
                    artifact,
                    latency,
                })
            }
            Err(e) => {
                self.record_failure(&e);
                if let (Some(o), Some(cell)) = (obs, &span) {
                    let outcome = if EngineBusy::is(&e) {
                        obs_span::OUTCOME_SHED
                    } else {
                        obs_span::OUTCOME_FAILED
                    };
                    o.complete(cell.to_span(
                        t_entry,
                        t_select,
                        cell.now_us(),
                        Router::algo_code(algo),
                        Router::reason_code(reason),
                        outcome,
                    ));
                }
                Err(e)
            }
        }
    }

    /// Serve a batch: every request is decided and submitted up front
    /// (the engine's shape-affinity sharding and micro-batcher regroup
    /// same-artifact jobs worker-side), then responses are collected in
    /// submission order. Each failure — at submit or at execution —
    /// counts toward `failed` (or `shed`, for admission-control
    /// rejections) exactly once. Batch traffic records
    /// single-sided telemetry but is never shadow-probed (probing doubles
    /// a request; the synchronous path owns that budget).
    pub fn serve_batch(&self, reqs: Vec<GemmRequest>) -> Vec<anyhow::Result<GemmResponse>> {
        enum Pending {
            Failed(anyhow::Error),
            Wait {
                algo: Algorithm,
                reason: SelectionReason,
                artifact: String,
                gpu: &'static GpuSpec,
                shape: GemmShape,
                t0: Instant,
                rx: mpsc::Receiver<anyhow::Result<ExecReply>>,
            },
        }

        let mut pending: Vec<Pending> = Vec::with_capacity(reqs.len());
        for req in reqs {
            self.metrics.requests.fetch_add(1, Ordering::Relaxed);
            // Batch traffic is window-counted but never span-traced: the
            // batch path interleaves submits and receives, so per-request
            // stage attribution belongs to the synchronous path.
            if let Some(o) = &self.config.obs {
                o.mark_request();
            }
            let (algo, reason) = self.decide(&req);
            self.metrics.record_selection(algo, reason);
            let artifact = XlaBackend::artifact_name(req.shape, algo);
            let t0 = Instant::now();
            let (gpu, shape) = (req.gpu, req.shape);
            match self.submit(artifact.clone(), vec![req.a, req.b], None) {
                Ok(rx) => pending.push(Pending::Wait {
                    algo,
                    reason,
                    artifact,
                    gpu,
                    shape,
                    t0,
                    rx,
                }),
                Err(e) => {
                    self.record_failure(&e);
                    pending.push(Pending::Failed(e));
                }
            }
        }
        pending
            .into_iter()
            .map(|p| match p {
                Pending::Failed(e) => Err(e),
                Pending::Wait {
                    algo,
                    reason,
                    artifact,
                    gpu,
                    shape,
                    t0,
                    rx,
                } => {
                    let res = rx
                        .recv()
                        .map_err(|_| anyhow::anyhow!("engine dropped the response"))
                        .and_then(|r| r)
                        .and_then(|mut reply| {
                            anyhow::ensure!(
                                reply.outputs.len() == 1,
                                "{artifact}: expected one output"
                            );
                            Ok((reply.outputs.remove(0), reply.exec_us))
                        });
                    match res {
                        Ok((output, exec_us)) => {
                            let latency = t0.elapsed();
                            self.metrics.completed.fetch_add(1, Ordering::Relaxed);
                            self.metrics
                                .record_latency_us(latency.as_secs_f64() * 1e6);
                            if let Some(o) = &self.config.obs {
                                o.mark_completed();
                            }
                            if let Some(rt) = &self.online {
                                rt.hub.record_execution(
                                    gpu,
                                    shape.m,
                                    shape.n,
                                    shape.k,
                                    algo,
                                    exec_us,
                                    Router::predicted_label(reason),
                                );
                            }
                            Ok(GemmResponse {
                                output,
                                algorithm: algo,
                                reason,
                                artifact,
                                latency,
                            })
                        }
                        Err(e) => {
                            self.record_failure(&e);
                            Err(e)
                        }
                    }
                }
            })
            .collect()
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        if let Some(rt) = &mut self.online {
            rt.hub.request_shutdown();
            if let Some(join) = rt.trainer.take() {
                let _ = join.join();
            }
        }
        // At drop no serve call can be in flight (`serve` borrows the
        // router), so every counted request has resolved — cheap place to
        // catch a leaked or double-counted outcome in every debug test.
        if cfg!(debug_assertions) && !std::thread::panicking() {
            if let Err(e) = self.metrics.snapshot().verify_conservation() {
                panic!("router drop: {e}");
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Engine;
    use crate::dataset::collect_paper_dataset;
    use crate::gemm::cpu::matmul_nt;
    use crate::gpusim::GTX1080;
    use crate::testutil::assert_allclose;

    fn native_router(config: RouterConfig) -> (Engine, Router) {
        let engine = Engine::native(32).unwrap();
        let selector = Selector::train_default(&collect_paper_dataset());
        let router = Router::new(selector, engine.handle(), config);
        (engine, router)
    }

    fn request(m: u64, n: u64, k: u64, seed: u64) -> GemmRequest {
        GemmRequest {
            gpu: &GTX1080,
            shape: GemmShape::new(m, n, k),
            a: Matrix::random(m as usize, k as usize, seed),
            b: Matrix::random(n as usize, k as usize, seed ^ 0xBEEF),
        }
    }

    #[test]
    fn default_config_uses_selector_with_caching() {
        let c = RouterConfig::default();
        assert!(c.force.is_none());
        assert!(c.cache_decisions);
        assert_eq!(c.admission, AdmissionControl::Block);
        assert!(c.online.is_none());
        assert!(RouterConfig::online(OnlineConfig::default()).online.is_some());
    }

    #[test]
    fn forced_algorithms_report_forced_reason() {
        let (engine, router) = native_router(RouterConfig {
            force: Some(Algorithm::Tnn),
            ..RouterConfig::default()
        });
        let req = request(16, 16, 16, 1);
        let expect = matmul_nt(&req.a, &req.b);
        let resp = router.serve(req).unwrap();
        assert_eq!(resp.algorithm, Algorithm::Tnn);
        assert_eq!(resp.reason, SelectionReason::Forced);
        assert_allclose(&resp.output.data, &expect.data, 1e-4, 1e-4);
        let snap = router.metrics.snapshot();
        assert_eq!(snap.forced, 1);
        assert_eq!(snap.memory_fallbacks, 0);
        engine.shutdown();
    }

    #[test]
    fn cached_and_uncached_decisions_agree() {
        let (engine, cached) = native_router(RouterConfig::default());
        let (engine2, uncached) = native_router(RouterConfig {
            cache_decisions: false,
            ..RouterConfig::default()
        });
        for &(m, n, k) in &[(128u64, 128u64, 128u64), (512, 256, 1024), (128, 128, 128)] {
            let a = cached.decide(&request(m, n, k, 3));
            let b = uncached.decide(&request(m, n, k, 3));
            assert_eq!(a, b, "shape {m}x{n}x{k}");
            // Second decide hits the cache and must still agree.
            assert_eq!(cached.decide(&request(m, n, k, 4)), a);
        }
        engine.shutdown();
        engine2.shutdown();
    }

    #[test]
    fn native_serve_matches_oracle_end_to_end() {
        let (engine, router) = native_router(RouterConfig::default());
        let req = request(64, 32, 48, 7);
        let expect = matmul_nt(&req.a, &req.b);
        let resp = router.serve(req).unwrap();
        assert!(matches!(resp.algorithm, Algorithm::Nt | Algorithm::Tnn));
        assert_allclose(&resp.output.data, &expect.data, 1e-4, 1e-4);
        assert_eq!(router.metrics.snapshot().completed, 1);
        engine.shutdown();
    }

    #[test]
    fn native_serve_batch_keeps_submission_order() {
        let (engine, router) = native_router(RouterConfig::default());
        let shapes = [(16u64, 16u64, 16u64), (32, 32, 32), (16, 16, 16), (8, 24, 40)];
        let reqs: Vec<GemmRequest> = shapes
            .iter()
            .enumerate()
            .map(|(i, &(m, n, k))| request(m, n, k, i as u64))
            .collect();
        let expects: Vec<Matrix> = reqs.iter().map(|r| matmul_nt(&r.a, &r.b)).collect();
        let resps = router.serve_batch(reqs);
        assert_eq!(resps.len(), shapes.len());
        for (i, (resp, expect)) in resps.into_iter().zip(&expects).enumerate() {
            let resp = resp.unwrap_or_else(|e| panic!("request {i}: {e}"));
            assert_allclose(&resp.output.data, &expect.data, 1e-4, 1e-4);
        }
        engine.shutdown();
    }

    #[test]
    fn warmup_maps_shapes_to_both_algorithms() {
        // Native warmup is a no-op per artifact, so this proves the
        // name-building path end-to-end (bad shapes would still be Ok on
        // native — the PJRT integration test covers compile failures).
        let (engine, router) = native_router(RouterConfig::default());
        router
            .warmup(&[GemmShape::new(128, 128, 128), GemmShape::new(64, 32, 48)])
            .unwrap();
        engine.shutdown();
    }

    #[test]
    fn online_router_records_samples_and_probes() {
        let (engine, router) = native_router(RouterConfig::online(OnlineConfig {
            // Pin the adaptive schedule to a fixed 1-in-2 so probe counts
            // are deterministic regardless of measured winners.
            probe_every_min: 2,
            probe_every_max: 2,
            probe_epsilon: 0.0,
            // Keep the trainer quiet so this test only checks telemetry.
            retrain_min_labeled: usize::MAX,
            ..OnlineConfig::default()
        }));
        for i in 0..6u64 {
            let req = request(32, 32, 32, i);
            let expect = matmul_nt(&req.a, &req.b);
            let resp = router.serve(req).unwrap();
            assert_allclose(&resp.output.data, &expect.data, 1e-4, 1e-4);
        }
        let snap = router.metrics.snapshot();
        assert_eq!(snap.completed, 6);
        // interval 2 → bucket ticks 1, 3 and 5 of the 6 predicted
        // requests fire (never tick 0 — a cold start is not probed).
        assert_eq!(snap.shadow_probes, 3, "{}", snap.render());
        assert_eq!(snap.probes_scheduled, 3, "{}", snap.render());
        assert_eq!(snap.probes_bandit, 0);
        assert_eq!(snap.probe_interval, 2);
        assert_eq!(snap.online_samples, 6, "every request recorded");
        let hub = router.online_hub().expect("online hub");
        assert!((hub.drift.probes() - 3.0).abs() < 1e-9);
        engine.shutdown();
    }

    #[test]
    fn online_forced_traffic_is_never_probed() {
        let (engine, router) = native_router(RouterConfig {
            force: Some(Algorithm::Nt),
            ..RouterConfig::online(OnlineConfig {
                probe_every_min: 1,
                probe_every_max: 1,
                retrain_min_labeled: usize::MAX,
                ..OnlineConfig::default()
            })
        });
        for i in 0..4u64 {
            router.serve(request(16, 16, 16, i)).unwrap();
        }
        let snap = router.metrics.snapshot();
        assert_eq!(snap.shadow_probes, 0, "forced traffic bypasses the model");
        assert_eq!(snap.online_samples, 4, "latency still recorded");
        engine.shutdown();
    }
}
