//! The router: the client-facing API of the GEMM service. For each
//! request it runs Algorithm 2 (O(1) features → GBDT predict → memory
//! fallback), maps (shape, algorithm) onto a catalog artifact, and hands
//! the job to the engine pool, whose shape-affinity sharding and adaptive
//! micro-batcher group same-artifact work engine-side. Admission control
//! decides what happens when every worker queue is full: block (bounded
//! backpressure, the default) or fail fast with [`EngineBusy`].
//!
//! With [`RouterConfig::online`] the router closes the loop
//! (`crate::online`): the model lives behind a hot-swappable
//! [`LiveSelector`], every execution's measured latency is recorded into
//! the sample ring, and an adaptive slice of predicted requests is
//! **shadow-probed** (both NT and TNN run; the measured winner becomes a
//! labeled example and feeds the drift tracker). The probe interval per
//! shape bucket tightens toward `probe_every_min` while the bucket's
//! decayed mispredict rate is high and relaxes toward `probe_every_max`
//! when it is clean, with a deterministic epsilon-greedy floor so stable
//! buckets keep a trickle of exploration; a background trainer
//! retrains/promotes without ever blocking the serving path. The hot path
//! stays lock-free: a cache hit in the epoch-checked
//! [`DecisionCache`] touches no lock, and a promotion invalidates the
//! cache atomically so stale decisions cannot outlive their model.
//!
//! The request-lifecycle policy layer (`super::lifecycle`) wraps the
//! serve path end to end: a [`Deadline`] is stamped at entry and
//! enforced at admission, in the engine queue, and while waiting for
//! the reply; transient failures are retried under a bounded
//! decorrelated-jitter budget; per-artifact circuit breakers fail sick
//! artifacts fast (or coerce them onto the alternate algorithm); and a
//! brownout controller sheds optional load — shadow probes, trace
//! sampling, reuse inserts — under sustained overload. See
//! [`Router::serve_with_deadline`] for the full state machine.

use super::backend::{classify_error, BreakerOpen, DeadlineExceeded, EngineBusy, ErrorClass};
use super::engine::{EngineHandle, ExecReply};
use super::lifecycle::{
    BreakerConfig, BreakerDecision, BreakerRegistry, BreakerState, BrownoutConfig,
    BrownoutController, Deadline, DecorrelatedJitter, RetryPolicy,
};
use super::metrics::CoordinatorMetrics;
use crate::gemm::cpu::Matrix;
use crate::gemm::xla::XlaBackend;
use crate::gemm::{Algorithm, GemmShape};
use crate::gpusim::{GpuSpec, Simulator};
use crate::obs::{span as obs_span, ObsLayer, SpanHandle};
use crate::online::{trainer, Accumulator, LiveSelector, OnlineConfig, OnlineHub};
use crate::selector::cache::DecisionCache;
use crate::selector::{SelectionReason, Selector, TrainedModel};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// One NT-operation request: `C = A × Bᵀ` on (virtual) GPU `gpu`.
pub struct GemmRequest {
    pub gpu: &'static GpuSpec,
    pub shape: GemmShape,
    /// A is m×k.
    pub a: Matrix,
    /// B is n×k.
    pub b: Matrix,
}

/// The response: the product plus what the coordinator decided and why.
#[derive(Debug)]
pub struct GemmResponse {
    pub output: Matrix,
    pub algorithm: Algorithm,
    pub reason: SelectionReason,
    pub artifact: String,
    pub latency: std::time::Duration,
}

/// What to do when every engine worker queue is full.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AdmissionControl {
    /// Block the caller until the affine worker has room (bounded
    /// backpressure — the pre-pool semantics).
    #[default]
    Block,
    /// Try the affine worker, hand off to any worker with room, and fail
    /// fast with [`EngineBusy`] when all queues are full (counted in
    /// `CoordinatorMetrics::busy_rejections`; the rejection reaching the
    /// caller counts as `shed`, not `failed`).
    RejectWhenBusy,
}

/// Router configuration.
#[derive(Debug, Clone)]
pub struct RouterConfig {
    /// Force a fixed algorithm instead of MTNN (baseline modes).
    pub force: Option<Algorithm>,
    /// Memoize decisions by `(gpu, m, n, k)` — steady-state traffic
    /// (FCN training re-issues identical shapes every iteration) then
    /// pays a lock-free table lookup instead of a GBDT descent. On by
    /// default; disable for selection microbenchmarks.
    pub cache_decisions: bool,
    /// Queue-full policy (see [`AdmissionControl`]).
    pub admission: AdmissionControl,
    /// Online adaptive selection (`None` = the offline paper behavior).
    pub online: Option<OnlineConfig>,
    /// Observability layer (`crate::obs`): request-path tracing, windowed
    /// rates, and the flight recorder. `None` (the default) keeps the
    /// serving path exactly as before; sharing the same `Arc` across
    /// routers aggregates their traffic into one layer.
    pub obs: Option<Arc<ObsLayer>>,
    /// Default per-request deadline, stamped at `serve` entry. `None`
    /// (the default) means requests never expire; per-call overrides go
    /// through [`Router::serve_with_deadline`].
    pub deadline: Option<Duration>,
    /// Bounded-retry policy for *transient* failures. The default
    /// (`max_retries: 0`) disables retries — the seed behavior.
    pub retry: RetryPolicy,
    /// Per-artifact circuit breakers. `None` (the default) disables the
    /// breaker layer entirely.
    pub breaker: Option<BreakerConfig>,
    /// Overload-brownout ladder, driven by the obs layer's windowed
    /// rates (requires `obs` to do anything). `None` disables.
    pub brownout: Option<BrownoutConfig>,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            force: None,
            cache_decisions: true,
            admission: AdmissionControl::default(),
            online: None,
            obs: None,
            deadline: None,
            retry: RetryPolicy::default(),
            breaker: None,
            brownout: None,
        }
    }
}

impl RouterConfig {
    /// Default config with the online adaptive-selection loop enabled.
    pub fn online(config: OnlineConfig) -> RouterConfig {
        RouterConfig {
            online: Some(config),
            ..RouterConfig::default()
        }
    }
}

/// The online loop's runtime half owned by the router: the shared hub
/// plus the background trainer thread (joined on drop).
struct OnlineRuntime {
    hub: Arc<OnlineHub>,
    trainer: Option<std::thread::JoinHandle<()>>,
}

/// The router. Cheap to share via `Arc`; submission is thread-safe.
pub struct Router {
    live: Arc<LiveSelector>,
    engine: EngineHandle,
    pub metrics: Arc<CoordinatorMetrics>,
    config: RouterConfig,
    cache: Arc<DecisionCache>,
    online: Option<OnlineRuntime>,
    breakers: Option<BreakerRegistry>,
    brownout: Option<BrownoutController>,
    /// Monotone per-request sequence seeding each retry schedule's
    /// jitter, so concurrent retriers decorrelate deterministically.
    retry_seq: AtomicU64,
}

impl Router {
    pub fn new(selector: Selector, engine: EngineHandle, config: RouterConfig) -> Router {
        let metrics = Arc::new(CoordinatorMetrics::default());
        metrics.attach_worker_depths(engine.depth_gauges());
        metrics.attach_batch_gauges(engine.batch_gauges());
        if let Some(layer) = engine.reuse() {
            metrics.attach_reuse(layer.stats());
        }
        if let Some(obs) = &config.obs {
            metrics.attach_obs(Arc::clone(obs));
        }
        let live = Arc::new(LiveSelector::new(selector));
        let cache = Arc::new(DecisionCache::default());
        let online = config.online.clone().map(|cfg| {
            let mut acc = Accumulator::for_config(&cfg);
            // Warm restart: reload the persisted dataset and, when one was
            // saved, hot-swap the persisted model in before any traffic.
            if let Some(path) = &cfg.persist_path {
                if path.exists() {
                    match trainer::load_store(path) {
                        Ok((examples, seen, model)) => {
                            acc.preload(examples, seen);
                            if let Some(g) = model {
                                live.swap(Selector::new(TrainedModel::Gbdt(g)));
                                cache.invalidate();
                            }
                        }
                        Err(e) => {
                            eprintln!("online: ignoring corrupt store {}: {e}", path.display())
                        }
                    }
                }
            }
            let hub = Arc::new(OnlineHub::new(
                cfg,
                Arc::clone(&live),
                Arc::clone(&cache),
                Arc::clone(&metrics),
            ));
            // A model promotion also bumps the engine's reuse epoch (when
            // the layer is enabled): conservative, but it keeps the hard
            // guarantee that no served-from-cache result predates the
            // live-model swap — mirroring how promotion already
            // invalidates the decision cache.
            if let Some(layer) = engine.reuse() {
                let layer = Arc::clone(layer);
                hub.add_promotion_hook(Box::new(move || layer.invalidate()));
            }
            let join = trainer::spawn(Arc::clone(&hub), acc);
            OnlineRuntime {
                hub,
                trainer: Some(join),
            }
        });
        let breakers = config.breaker.map(BreakerRegistry::new);
        let brownout = config.brownout.map(BrownoutController::new);
        Router {
            live,
            engine,
            metrics,
            config,
            cache,
            online,
            breakers,
            brownout,
            retry_seq: AtomicU64::new(0),
        }
    }

    /// The per-artifact breaker registry when breakers are enabled —
    /// exposed for tests and operational introspection (state, opens,
    /// transition events).
    pub fn breakers(&self) -> Option<&BreakerRegistry> {
        self.breakers.as_ref()
    }

    /// The brownout controller when enabled (level, transitions).
    pub fn brownout(&self) -> Option<&BrownoutController> {
        self.brownout.as_ref()
    }

    /// The online hub (drift tracker, sample ring, live-model generation)
    /// when the loop is enabled — exposed for tests, examples, and
    /// operational introspection.
    pub fn online_hub(&self) -> Option<Arc<OnlineHub>> {
        self.online.as_ref().map(|rt| Arc::clone(&rt.hub))
    }

    /// Decide the algorithm for a request (Algorithm 2 + config override),
    /// memoized by shape when `cache_decisions` is on. Selection is
    /// deterministic *within a model generation*, so the cache is
    /// epoch-stamped: it is captured before the model runs and a decision
    /// computed under a model that was swapped out mid-flight is never
    /// published.
    pub fn decide(&self, req: &GemmRequest) -> (Algorithm, SelectionReason) {
        if let Some(forced) = self.config.force {
            return (forced, SelectionReason::Forced);
        }
        let GemmShape { m, n, k } = req.shape;
        if !self.config.cache_decisions {
            return self.live.select(req.gpu, m, n, k);
        }
        let epoch = self.cache.epoch();
        if let Some(hit) = self.cache.get(req.gpu, m, n, k) {
            return hit;
        }
        let dec = self.live.select(req.gpu, m, n, k);
        self.cache.insert_at(epoch, req.gpu, m, n, k, dec);
        dec
    }

    /// Pre-compile / pre-touch the artifacts behind `shapes` on every pool
    /// worker, covering both selectable algorithms so a later decision
    /// flip never pays a cold compile. Saves callers from hand-building
    /// artifact-name strings.
    pub fn warmup(&self, shapes: &[GemmShape]) -> anyhow::Result<()> {
        let mut names = Vec::with_capacity(shapes.len() * 2);
        for &shape in shapes {
            names.push(XlaBackend::artifact_name(shape, Algorithm::Nt));
            names.push(XlaBackend::artifact_name(shape, Algorithm::Tnn));
        }
        names.sort();
        names.dedup();
        self.engine.warmup(&names)
    }

    /// Submit through the configured admission policy, counting fail-fast
    /// rejections. A trace span (if this request drew one) rides along so
    /// the engine can stamp its stage boundaries.
    fn submit(
        &self,
        artifact: String,
        inputs: Vec<Matrix>,
        span: Option<SpanHandle>,
        deadline: Option<Deadline>,
    ) -> anyhow::Result<mpsc::Receiver<anyhow::Result<ExecReply>>> {
        let block = matches!(self.config.admission, AdmissionControl::Block);
        let res = self
            .engine
            .submit_traced(artifact, inputs, block, span, deadline);
        if res.as_ref().err().is_some_and(EngineBusy::is) {
            self.metrics.busy_rejections.fetch_add(1, Ordering::Relaxed);
        }
        res
    }

    /// Account one request-ending error: admission-control rejections are
    /// `shed` (the caller lost the request to backpressure policy, not to
    /// a malfunction), deadline expiries are `timed_out`, everything else
    /// — including breaker fail-fasts — is `failed`. Disjoint by
    /// construction, so `completed + failed + shed + timed_out ==
    /// requests` holds at quiescence — see
    /// [`super::metrics::MetricsSnapshot::verify_conservation`].
    fn record_failure(&self, e: &anyhow::Error) {
        if EngineBusy::is(e) {
            self.metrics.shed.fetch_add(1, Ordering::Relaxed);
            if let Some(o) = &self.config.obs {
                o.mark_shed();
            }
        } else if DeadlineExceeded::is(e) {
            self.metrics.timed_out.fetch_add(1, Ordering::Relaxed);
            if let Some(o) = &self.config.obs {
                o.mark_timeout();
            }
        } else {
            self.metrics.failed.fetch_add(1, Ordering::Relaxed);
            if BreakerOpen::is(e) {
                if let Some(o) = &self.config.obs {
                    o.mark_breaker_open();
                }
            }
        }
    }

    /// The span outcome code for a request-ending error.
    fn outcome_code(e: &anyhow::Error) -> u8 {
        if EngineBusy::is(e) {
            obs_span::OUTCOME_SHED
        } else if DeadlineExceeded::is(e) {
            obs_span::OUTCOME_TIMED_OUT
        } else {
            obs_span::OUTCOME_FAILED
        }
    }

    /// Wait for the engine reply, bounded by the request deadline. A
    /// wait that outlives the deadline resolves as [`DeadlineExceeded`]
    /// — the worker's eventual send lands on a dropped receiver, so the
    /// client is never left hanging past its budget.
    fn recv_reply(
        rx: &mpsc::Receiver<anyhow::Result<ExecReply>>,
        deadline: Option<&Deadline>,
    ) -> anyhow::Result<ExecReply> {
        match deadline {
            None => rx
                .recv()
                .map_err(|_| anyhow::anyhow!("engine dropped the response"))?,
            Some(d) => match d.remaining() {
                None => Err(anyhow::Error::new(DeadlineExceeded)),
                Some(rem) => match rx.recv_timeout(rem) {
                    Ok(reply) => reply,
                    Err(mpsc::RecvTimeoutError::Timeout) => {
                        Err(anyhow::Error::new(DeadlineExceeded))
                    }
                    Err(mpsc::RecvTimeoutError::Disconnected) => {
                        Err(anyhow::anyhow!("engine dropped the response"))
                    }
                },
            },
        }
    }

    /// Feed one served outcome to the artifact's breaker and handle a
    /// resulting transition: a trip to Open counts in
    /// `breaker_opens` and fires the flight-recorder `breaker_open`
    /// trigger; landing back in Closed is just recorded in the event log.
    fn breaker_record(&self, artifact: &str, failed: bool) {
        let Some(reg) = &self.breakers else { return };
        if let Some(BreakerState::Open) = reg.record(artifact, failed) {
            self.metrics.breaker_opens.fetch_add(1, Ordering::Relaxed);
            if let Some(o) = &self.config.obs {
                o.trigger_breaker_open();
            }
        }
    }

    /// Breaker admission for the decided `(algo, reason)`. Returns the
    /// possibly-coerced selection plus its artifact, or a typed
    /// [`BreakerOpen`] when the artifact is tripped and no fallback is
    /// available. A coerced fallback is recorded as
    /// [`SelectionReason::Forced`] so the online loop never learns from
    /// (or shadow-probes) coerced traffic.
    fn consult_breaker(
        &self,
        req: &GemmRequest,
        algo: Algorithm,
        reason: SelectionReason,
    ) -> anyhow::Result<(Algorithm, SelectionReason, String)> {
        let artifact = XlaBackend::artifact_name(req.shape, algo);
        let Some(reg) = &self.breakers else {
            return Ok((algo, reason, artifact));
        };
        match reg.admit(&artifact) {
            BreakerDecision::Allow => Ok((algo, reason, artifact)),
            BreakerDecision::Probe => {
                self.metrics
                    .breaker_half_open_probes
                    .fetch_add(1, Ordering::Relaxed);
                Ok((algo, reason, artifact))
            }
            BreakerDecision::Open => {
                let alt = match algo {
                    Algorithm::Nt => Algorithm::Tnn,
                    _ => Algorithm::Nt,
                };
                let GemmShape { m, n, k } = req.shape;
                let alt_fits = alt != Algorithm::Tnn
                    || Simulator::tnn_workspace_bytes(m, n, k) <= req.gpu.global_mem_bytes();
                if alt_fits {
                    let alt_artifact = XlaBackend::artifact_name(req.shape, alt);
                    match reg.admit(&alt_artifact) {
                        BreakerDecision::Open => {}
                        BreakerDecision::Probe => {
                            self.metrics
                                .breaker_half_open_probes
                                .fetch_add(1, Ordering::Relaxed);
                            return Ok((alt, SelectionReason::Forced, alt_artifact));
                        }
                        BreakerDecision::Allow => {
                            return Ok((alt, SelectionReason::Forced, alt_artifact));
                        }
                    }
                }
                Err(anyhow::Error::new(BreakerOpen))
            }
        }
    }

    /// Rate-limited brownout evaluation: on its tick cadence, fold the
    /// obs layer's windowed rates (and total-latency p99) into the
    /// ladder, publish the level gauge, and throw the reuse-insert
    /// lever. Probe and tracing levers are read inline per request.
    fn brownout_tick(&self) {
        let (Some(ctrl), Some(o)) = (&self.brownout, self.config.obs.as_deref()) else {
            return;
        };
        let now_ms = o.epoch_ms();
        if !ctrl.eval_due(now_ms) {
            return;
        }
        let level = ctrl.evaluate(&o.window_rates(), o.total_p99_us(), now_ms);
        self.metrics
            .brownout_level
            .store(level as u64, Ordering::Relaxed);
        if let Some(layer) = self.engine.reuse() {
            layer.set_inserts_enabled(ctrl.allow_reuse_inserts());
        }
    }

    /// `TraceSpan` code for the chosen algorithm.
    fn algo_code(algo: Algorithm) -> u8 {
        match algo {
            Algorithm::Nt => obs_span::ALGO_NT,
            Algorithm::Tnn => obs_span::ALGO_TNN,
            Algorithm::Nn => obs_span::ALGO_NN,
        }
    }

    /// `TraceSpan` code for the selection reason.
    fn reason_code(reason: SelectionReason) -> u8 {
        match reason {
            SelectionReason::PredictedNt => obs_span::REASON_PREDICTED_NT,
            SelectionReason::PredictedTnn => obs_span::REASON_PREDICTED_TNN,
            SelectionReason::MemoryFallback => obs_span::REASON_MEMORY_FALLBACK,
            SelectionReason::Forced => obs_span::REASON_FORCED,
        }
    }

    /// The label the live model effectively predicted, from the selection
    /// reason (0 when the model was bypassed).
    fn predicted_label(reason: SelectionReason) -> i8 {
        match reason {
            SelectionReason::PredictedNt => 1,
            SelectionReason::PredictedTnn => -1,
            SelectionReason::MemoryFallback | SelectionReason::Forced => 0,
        }
    }

    /// Whether this request should be shadow-probed: the online loop is
    /// on, the model actually predicted (never second-guess a memory
    /// fallback — TNN might not fit), and the adaptive per-bucket
    /// schedule (or its bandit floor) selects it.
    fn should_probe(&self, req: &GemmRequest, predicted: i8) -> bool {
        let Some(rt) = &self.online else {
            return false;
        };
        let GemmShape { m, n, k } = req.shape;
        predicted != 0
            && Simulator::tnn_workspace_bytes(m, n, k) <= req.gpu.global_mem_bytes()
            && rt.hub.should_probe(req.gpu.id, m, n, k)
    }

    /// Serve one request synchronously under the configured default
    /// deadline (if any).
    pub fn serve(&self, req: GemmRequest) -> anyhow::Result<GemmResponse> {
        self.serve_with_deadline(req, self.config.deadline.map(Deadline::after))
    }

    /// Serve under the default deadline with an optional
    /// placement-chosen algorithm. The fleet's joint (device, algorithm)
    /// policy lands here: `placed` overrides the live model's pick for
    /// *execution* (reported as [`SelectionReason::Forced`] when they
    /// disagree), but the online loop keeps scoring the model's own
    /// prediction — a placement override must not blind drift detection
    /// the way breaker coercion deliberately does.
    pub fn serve_with(
        &self,
        req: GemmRequest,
        placed: Option<Algorithm>,
    ) -> anyhow::Result<GemmResponse> {
        self.serve_inner(req, self.config.deadline.map(Deadline::after), placed)
    }

    /// Serve one request synchronously with an explicit per-call
    /// deadline (overriding [`RouterConfig::deadline`]; `None` means no
    /// expiry). The full lifecycle state machine:
    ///
    /// ```text
    /// admit ─► decide ─► deadline check ─► breaker admit ─► submit ─► wait
    ///   │                  │ expired          │ open: NT↔TNN     │ per-attempt
    ///   │                  ▼                  │ fallback, else   ▼
    ///   │               timed_out             ▼              transient?
    ///   │                              BreakerOpen (failed)     │ retry w/
    ///   │                                                       │ jitter until
    ///   ▼                                                       ▼ budget dies
    /// completed / failed / shed / timed_out  ◄──────── resolve + breaker
    ///                                                   record + span
    /// ```
    pub fn serve_with_deadline(
        &self,
        req: GemmRequest,
        deadline: Option<Deadline>,
    ) -> anyhow::Result<GemmResponse> {
        self.serve_inner(req, deadline, None)
    }

    fn serve_inner(
        &self,
        req: GemmRequest,
        deadline: Option<Deadline>,
        placed: Option<Algorithm>,
    ) -> anyhow::Result<GemmResponse> {
        let t0 = Instant::now();
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        self.brownout_tick();
        // Tracing: draw a span if this request falls on the sampling
        // lattice (suppressed from brownout level 2). Entry and selection
        // are stamped here; the engine and worker stamp the rest through
        // the shared cell.
        let obs = self.config.obs.as_deref();
        let tracing_on = self.brownout.as_ref().map_or(true, |b| b.allow_tracing());
        let span = if tracing_on {
            obs.and_then(|o| o.begin_span())
        } else {
            None
        };
        if let Some(o) = obs {
            o.mark_request();
        }
        let t_entry = span.as_ref().map(|c| c.now_us()).unwrap_or(0);
        let (model_algo, model_reason) = self.decide(&req);
        // A placement override that agrees with the model keeps the
        // model's reason (so per-device selection counters still reflect
        // predictions); a disagreeing override executes as Forced.
        let (decided_algo, decided_reason) = match placed {
            Some(p) if p != model_algo => (p, SelectionReason::Forced),
            _ => (model_algo, model_reason),
        };
        let t_select = span.as_ref().map(|c| c.now_us()).unwrap_or(0);
        // Close out one request-ending error: ledger + window marks +
        // span outcome, all from the same error classification.
        let resolve_err = |e: anyhow::Error, algo: Algorithm, reason: SelectionReason, retries: u32| {
            self.record_failure(&e);
            if let (Some(o), Some(cell)) = (obs, &span) {
                o.complete(cell.to_span(
                    t_entry,
                    t_select,
                    cell.now_us(),
                    Router::algo_code(algo),
                    Router::reason_code(reason),
                    Router::outcome_code(&e),
                    retries,
                ));
            }
            Err(e)
        };

        // Admission: a request that arrives already expired is dropped
        // before it can touch the breaker or the engine.
        if deadline.as_ref().is_some_and(|d| d.expired()) {
            self.metrics.record_selection(decided_algo, decided_reason);
            let e = anyhow::Error::new(DeadlineExceeded);
            return resolve_err(e, decided_algo, decided_reason, 0);
        }

        // Circuit breaker: a tripped artifact is coerced onto the
        // alternate algorithm (recorded as Forced so the online loop
        // neither learns from nor probes coerced traffic) or fails fast.
        let (algo, reason, artifact) = match self.consult_breaker(&req, decided_algo, decided_reason)
        {
            Ok(sel) => sel,
            Err(e) => {
                self.metrics.record_selection(decided_algo, decided_reason);
                return resolve_err(e, decided_algo, decided_reason, 0);
            }
        };
        self.metrics.record_selection(algo, reason);
        // The model's own prediction drives the online loop even when a
        // placement override forced the executed algorithm; breaker
        // coercion (the algorithm changed underneath the decision) still
        // blinds it — never learn from or probe coerced traffic.
        let predicted = if algo == decided_algo {
            Router::predicted_label(model_reason)
        } else {
            0
        };

        // Shadow probe: run the *other* algorithm's artifact alongside the
        // chosen one (suppressed from brownout level 1). Best-effort — a
        // busy engine or an execution failure on the shadow side only
        // costs the training sample, never the request — and it is
        // submitted strictly *after* the primary so a probe can never
        // consume the queue slot the real request needed.
        let probes_on = self.brownout.as_ref().map_or(true, |b| b.allow_probes());
        let shadow_inputs = if probes_on && self.should_probe(&req, predicted) {
            let other = match algo {
                Algorithm::Nt => Algorithm::Tnn,
                _ => Algorithm::Nt,
            };
            Some((
                XlaBackend::artifact_name(req.shape, other),
                req.a.clone(),
                req.b.clone(),
            ))
        } else {
            None
        };

        let GemmShape { m, n, k } = req.shape;
        let gpu = req.gpu;
        // Retry budget: transient failures only, never for deny-listed
        // artifacts (a permanently-poisoned artifact must not burn the
        // deadline re-failing), each sleep drawn from the decorrelated
        // jitter schedule and charged against the remaining deadline.
        let policy = self.config.retry;
        let budget = if policy.max_retries > 0
            && self.engine.reuse().is_some_and(|l| l.denied(&artifact))
        {
            0
        } else {
            policy.max_retries
        };
        let mut jitter = DecorrelatedJitter::new(
            &policy,
            crate::util::rng::mix64(self.retry_seq.fetch_add(1, Ordering::Relaxed) ^ 0x5EED_CAFE),
        );
        let mut inputs = Some((req.a, req.b));
        let mut attempt: u32 = 0;
        let mut shadow = None;
        let outcome = loop {
            // The final permitted attempt moves the inputs; earlier
            // attempts clone so a retry still has them.
            let job_inputs = if attempt >= budget {
                let (a, b) = inputs.take().expect("request inputs consumed twice");
                vec![a, b]
            } else {
                let (a, b) = inputs.as_ref().expect("request inputs consumed twice");
                vec![a.clone(), b.clone()]
            };
            let submitted = self.submit(artifact.clone(), job_inputs, span.clone(), deadline);
            if attempt == 0 {
                if let (Ok(_), Some((shadow_artifact, a, b))) = (&submitted, &shadow_inputs) {
                    shadow = self
                        .engine
                        .try_submit(shadow_artifact.clone(), vec![a.clone(), b.clone()])
                        .ok();
                }
            }
            let res = submitted.and_then(|rx| {
                let reply = Router::recv_reply(&rx, deadline.as_ref())?;
                anyhow::ensure!(reply.outputs.len() == 1, "{artifact}: expected one output");
                Ok(reply)
            });
            match res {
                Ok(reply) => {
                    self.breaker_record(&artifact, false);
                    break Ok(reply);
                }
                Err(e) => {
                    // EngineBusy is load, not artifact health; a breaker
                    // fail-fast never reached the artifact at all.
                    if !EngineBusy::is(&e) && !BreakerOpen::is(&e) {
                        self.breaker_record(&artifact, true);
                    }
                    let transient = classify_error(&e) == ErrorClass::Transient;
                    if transient && attempt < budget {
                        let nap = Duration::from_micros(jitter.next_us());
                        let affordable = match deadline.as_ref().map(|d| d.remaining()) {
                            None => true,              // no deadline: always
                            Some(Some(rem)) => rem > nap,
                            Some(None) => false,       // already expired
                        };
                        if affordable {
                            attempt += 1;
                            self.metrics.retries.fetch_add(1, Ordering::Relaxed);
                            if let Some(o) = obs {
                                o.mark_retry();
                            }
                            std::thread::sleep(nap);
                            continue;
                        }
                    }
                    if transient && budget > 0 {
                        self.metrics.retries_exhausted.fetch_add(1, Ordering::Relaxed);
                        if let Some(o) = obs {
                            o.trigger_retry_exhausted();
                        }
                    }
                    break Err(e);
                }
            }
        };
        match outcome {
            Ok(mut reply) => {
                let output = reply.outputs.remove(0);
                let latency = t0.elapsed();
                self.metrics.completed.fetch_add(1, Ordering::Relaxed);
                self.metrics.record_latency_us(latency.as_secs_f64() * 1e6);
                if let Some(o) = obs {
                    o.mark_completed();
                    // Flatten the stamped cell into an immutable span and
                    // hand it to the layer (stage attribution, span ring,
                    // flight recorder).
                    if let Some(cell) = &span {
                        o.complete(cell.to_span(
                            t_entry,
                            t_select,
                            cell.now_us(),
                            Router::algo_code(algo),
                            Router::reason_code(reason),
                            obs_span::OUTCOME_COMPLETED,
                            attempt,
                        ));
                    }
                }
                if let Some(rt) = &self.online {
                    let shadow_us = shadow.and_then(|rx: mpsc::Receiver<anyhow::Result<ExecReply>>| {
                        rx.recv().ok().and_then(|r| r.ok()).map(|r| r.exec_us)
                    });
                    match shadow_us {
                        Some(other_us) => {
                            let (lat_nt, lat_tnn) = match algo {
                                Algorithm::Nt => (reply.exec_us, other_us),
                                _ => (other_us, reply.exec_us),
                            };
                            let mispredicted = rt
                                .hub
                                .record_probe(gpu, m, n, k, predicted, lat_nt, lat_tnn);
                            if let Some(o) = obs {
                                o.mark_probe();
                                if mispredicted {
                                    o.mark_mispredict();
                                }
                                // Regret: what the request cost versus the
                                // measured winner — the probe already paid
                                // for the counterfactual.
                                o.record_regret(
                                    reply.exec_us.round() as u64,
                                    lat_nt.min(lat_tnn).round() as u64,
                                );
                            }
                        }
                        None => rt
                            .hub
                            .record_execution(gpu, m, n, k, algo, reply.exec_us, predicted),
                    }
                }
                Ok(GemmResponse {
                    output,
                    algorithm: algo,
                    reason,
                    artifact,
                    latency,
                })
            }
            Err(e) => resolve_err(e, algo, reason, attempt),
        }
    }

    /// Serve a batch: every request is decided and submitted up front
    /// (the engine's shape-affinity sharding and micro-batcher regroup
    /// same-artifact jobs worker-side), then responses are collected in
    /// submission order. Each failure — at submit or at execution —
    /// counts toward `failed` (or `shed`, for admission-control
    /// rejections) exactly once. Batch traffic records
    /// single-sided telemetry but is never shadow-probed (probing doubles
    /// a request; the synchronous path owns that budget).
    pub fn serve_batch(&self, reqs: Vec<GemmRequest>) -> Vec<anyhow::Result<GemmResponse>> {
        enum Pending {
            Failed(anyhow::Error),
            Wait {
                algo: Algorithm,
                reason: SelectionReason,
                artifact: String,
                gpu: &'static GpuSpec,
                shape: GemmShape,
                t0: Instant,
                rx: mpsc::Receiver<anyhow::Result<ExecReply>>,
            },
        }

        let mut pending: Vec<Pending> = Vec::with_capacity(reqs.len());
        for req in reqs {
            self.metrics.requests.fetch_add(1, Ordering::Relaxed);
            // Batch traffic is window-counted but never span-traced: the
            // batch path interleaves submits and receives, so per-request
            // stage attribution belongs to the synchronous path.
            if let Some(o) = &self.config.obs {
                o.mark_request();
            }
            let (algo, reason) = self.decide(&req);
            self.metrics.record_selection(algo, reason);
            let artifact = XlaBackend::artifact_name(req.shape, algo);
            let t0 = Instant::now();
            let (gpu, shape) = (req.gpu, req.shape);
            match self.submit(artifact.clone(), vec![req.a, req.b], None, None) {
                Ok(rx) => pending.push(Pending::Wait {
                    algo,
                    reason,
                    artifact,
                    gpu,
                    shape,
                    t0,
                    rx,
                }),
                Err(e) => {
                    self.record_failure(&e);
                    pending.push(Pending::Failed(e));
                }
            }
        }
        pending
            .into_iter()
            .map(|p| match p {
                Pending::Failed(e) => Err(e),
                Pending::Wait {
                    algo,
                    reason,
                    artifact,
                    gpu,
                    shape,
                    t0,
                    rx,
                } => {
                    let res = rx
                        .recv()
                        .map_err(|_| anyhow::anyhow!("engine dropped the response"))
                        .and_then(|r| r)
                        .and_then(|mut reply| {
                            anyhow::ensure!(
                                reply.outputs.len() == 1,
                                "{artifact}: expected one output"
                            );
                            Ok((reply.outputs.remove(0), reply.exec_us))
                        });
                    match res {
                        Ok((output, exec_us)) => {
                            let latency = t0.elapsed();
                            self.metrics.completed.fetch_add(1, Ordering::Relaxed);
                            self.metrics
                                .record_latency_us(latency.as_secs_f64() * 1e6);
                            if let Some(o) = &self.config.obs {
                                o.mark_completed();
                            }
                            if let Some(rt) = &self.online {
                                rt.hub.record_execution(
                                    gpu,
                                    shape.m,
                                    shape.n,
                                    shape.k,
                                    algo,
                                    exec_us,
                                    Router::predicted_label(reason),
                                );
                            }
                            Ok(GemmResponse {
                                output,
                                algorithm: algo,
                                reason,
                                artifact,
                                latency,
                            })
                        }
                        Err(e) => {
                            self.record_failure(&e);
                            Err(e)
                        }
                    }
                }
            })
            .collect()
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        if let Some(rt) = &mut self.online {
            rt.hub.request_shutdown();
            if let Some(join) = rt.trainer.take() {
                let _ = join.join();
            }
        }
        // At drop no serve call can be in flight (`serve` borrows the
        // router), so every counted request has resolved — cheap place to
        // catch a leaked or double-counted outcome in every debug test.
        if cfg!(debug_assertions) && !std::thread::panicking() {
            if let Err(e) = self.metrics.snapshot().verify_conservation() {
                panic!("router drop: {e}");
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Engine, ExecBackend};
    use crate::dataset::collect_paper_dataset;
    use crate::gemm::cpu::matmul_nt;
    use crate::gpusim::GTX1080;
    use crate::testutil::assert_allclose;

    fn native_router(config: RouterConfig) -> (Engine, Router) {
        let engine = Engine::native(32).unwrap();
        let selector = Selector::train_default(&collect_paper_dataset());
        let router = Router::new(selector, engine.handle(), config);
        (engine, router)
    }

    fn request(m: u64, n: u64, k: u64, seed: u64) -> GemmRequest {
        GemmRequest {
            gpu: &GTX1080,
            shape: GemmShape::new(m, n, k),
            a: Matrix::random(m as usize, k as usize, seed),
            b: Matrix::random(n as usize, k as usize, seed ^ 0xBEEF),
        }
    }

    #[test]
    fn default_config_uses_selector_with_caching() {
        let c = RouterConfig::default();
        assert!(c.force.is_none());
        assert!(c.cache_decisions);
        assert_eq!(c.admission, AdmissionControl::Block);
        assert!(c.online.is_none());
        assert!(RouterConfig::online(OnlineConfig::default()).online.is_some());
    }

    #[test]
    fn forced_algorithms_report_forced_reason() {
        let (engine, router) = native_router(RouterConfig {
            force: Some(Algorithm::Tnn),
            ..RouterConfig::default()
        });
        let req = request(16, 16, 16, 1);
        let expect = matmul_nt(&req.a, &req.b);
        let resp = router.serve(req).unwrap();
        assert_eq!(resp.algorithm, Algorithm::Tnn);
        assert_eq!(resp.reason, SelectionReason::Forced);
        assert_allclose(&resp.output.data, &expect.data, 1e-4, 1e-4);
        let snap = router.metrics.snapshot();
        assert_eq!(snap.forced, 1);
        assert_eq!(snap.memory_fallbacks, 0);
        engine.shutdown();
    }

    #[test]
    fn cached_and_uncached_decisions_agree() {
        let (engine, cached) = native_router(RouterConfig::default());
        let (engine2, uncached) = native_router(RouterConfig {
            cache_decisions: false,
            ..RouterConfig::default()
        });
        for &(m, n, k) in &[(128u64, 128u64, 128u64), (512, 256, 1024), (128, 128, 128)] {
            let a = cached.decide(&request(m, n, k, 3));
            let b = uncached.decide(&request(m, n, k, 3));
            assert_eq!(a, b, "shape {m}x{n}x{k}");
            // Second decide hits the cache and must still agree.
            assert_eq!(cached.decide(&request(m, n, k, 4)), a);
        }
        engine.shutdown();
        engine2.shutdown();
    }

    #[test]
    fn native_serve_matches_oracle_end_to_end() {
        let (engine, router) = native_router(RouterConfig::default());
        let req = request(64, 32, 48, 7);
        let expect = matmul_nt(&req.a, &req.b);
        let resp = router.serve(req).unwrap();
        assert!(matches!(resp.algorithm, Algorithm::Nt | Algorithm::Tnn));
        assert_allclose(&resp.output.data, &expect.data, 1e-4, 1e-4);
        assert_eq!(router.metrics.snapshot().completed, 1);
        engine.shutdown();
    }

    #[test]
    fn native_serve_batch_keeps_submission_order() {
        let (engine, router) = native_router(RouterConfig::default());
        let shapes = [(16u64, 16u64, 16u64), (32, 32, 32), (16, 16, 16), (8, 24, 40)];
        let reqs: Vec<GemmRequest> = shapes
            .iter()
            .enumerate()
            .map(|(i, &(m, n, k))| request(m, n, k, i as u64))
            .collect();
        let expects: Vec<Matrix> = reqs.iter().map(|r| matmul_nt(&r.a, &r.b)).collect();
        let resps = router.serve_batch(reqs);
        assert_eq!(resps.len(), shapes.len());
        for (i, (resp, expect)) in resps.into_iter().zip(&expects).enumerate() {
            let resp = resp.unwrap_or_else(|e| panic!("request {i}: {e}"));
            assert_allclose(&resp.output.data, &expect.data, 1e-4, 1e-4);
        }
        engine.shutdown();
    }

    #[test]
    fn warmup_maps_shapes_to_both_algorithms() {
        // Native warmup is a no-op per artifact, so this proves the
        // name-building path end-to-end (bad shapes would still be Ok on
        // native — the PJRT integration test covers compile failures).
        let (engine, router) = native_router(RouterConfig::default());
        router
            .warmup(&[GemmShape::new(128, 128, 128), GemmShape::new(64, 32, 48)])
            .unwrap();
        engine.shutdown();
    }

    #[test]
    fn online_router_records_samples_and_probes() {
        let (engine, router) = native_router(RouterConfig::online(OnlineConfig {
            // Pin the adaptive schedule to a fixed 1-in-2 so probe counts
            // are deterministic regardless of measured winners.
            probe_every_min: 2,
            probe_every_max: 2,
            probe_epsilon: 0.0,
            // Keep the trainer quiet so this test only checks telemetry.
            retrain_min_labeled: usize::MAX,
            ..OnlineConfig::default()
        }));
        for i in 0..6u64 {
            let req = request(32, 32, 32, i);
            let expect = matmul_nt(&req.a, &req.b);
            let resp = router.serve(req).unwrap();
            assert_allclose(&resp.output.data, &expect.data, 1e-4, 1e-4);
        }
        let snap = router.metrics.snapshot();
        assert_eq!(snap.completed, 6);
        // interval 2 → bucket ticks 1, 3 and 5 of the 6 predicted
        // requests fire (never tick 0 — a cold start is not probed).
        assert_eq!(snap.shadow_probes, 3, "{}", snap.render());
        assert_eq!(snap.probes_scheduled, 3, "{}", snap.render());
        assert_eq!(snap.probes_bandit, 0);
        assert_eq!(snap.probe_interval, 2);
        assert_eq!(snap.online_samples, 6, "every request recorded");
        let hub = router.online_hub().expect("online hub");
        assert!((hub.drift.probes() - 3.0).abs() < 1e-9);
        engine.shutdown();
    }

    /// Fails its first `fail_first` executions with a typed transient
    /// fault, then delegates to the native kernel.
    struct FlakyExecutor {
        fail_first: u64,
        calls: std::sync::atomic::AtomicU64,
    }

    impl ExecBackend for FlakyExecutor {
        fn execute(&self, artifact: &str, inputs: &[&Matrix]) -> anyhow::Result<Vec<Matrix>> {
            let n = self.calls.fetch_add(1, Ordering::Relaxed);
            if n < self.fail_first {
                return Err(anyhow::Error::new(
                    crate::coordinator::backend::TransientFault(format!(
                        "flaky: injected transient failure #{n} on {artifact}"
                    )),
                ));
            }
            crate::gemm::native::NativeExecutor.execute(artifact, inputs)
        }
    }

    fn flaky_router(fail_first: u64, config: RouterConfig) -> (Engine, Router) {
        let engine = Engine::pool(
            crate::coordinator::engine::EngineConfig {
                workers: 1,
                queue_depth: 32,
                ..Default::default()
            },
            |_| {
                Ok(Box::new(FlakyExecutor {
                    fail_first,
                    calls: std::sync::atomic::AtomicU64::new(0),
                }) as Box<dyn ExecBackend>)
            },
        )
        .unwrap();
        let selector = Selector::train_default(&collect_paper_dataset());
        let router = Router::new(selector, engine.handle(), config);
        (engine, router)
    }

    #[test]
    fn expired_deadline_times_out_at_admission() {
        let (engine, router) = native_router(RouterConfig::default());
        let err = router
            .serve_with_deadline(request(16, 16, 16, 1), Some(Deadline::after(Duration::ZERO)))
            .unwrap_err();
        assert!(DeadlineExceeded::is(&err), "typed timeout: {err}");
        let snap = router.metrics.snapshot();
        assert_eq!(snap.timed_out, 1);
        assert_eq!(snap.failed, 0);
        assert_eq!(snap.shed, 0);
        snap.verify_conservation().unwrap();
        // An unexpired request on the same router still completes.
        router
            .serve_with_deadline(
                request(16, 16, 16, 2),
                Some(Deadline::after(Duration::from_secs(30))),
            )
            .unwrap();
        assert_eq!(router.metrics.snapshot().completed, 1);
        engine.shutdown();
    }

    #[test]
    fn transient_failures_retry_to_success_within_budget() {
        let (engine, router) = flaky_router(
            2,
            RouterConfig {
                retry: RetryPolicy {
                    max_retries: 3,
                    base: Duration::from_micros(50),
                    cap: Duration::from_micros(500),
                },
                ..RouterConfig::default()
            },
        );
        let req = request(16, 16, 16, 1);
        let expect = matmul_nt(&req.a, &req.b);
        let resp = router.serve(req).unwrap();
        assert_allclose(&resp.output.data, &expect.data, 1e-4, 1e-4);
        let snap = router.metrics.snapshot();
        assert_eq!(snap.completed, 1);
        assert_eq!(snap.failed, 0);
        assert_eq!(snap.retries, 2, "two failures, two retries");
        assert_eq!(snap.retries_exhausted, 0);
        snap.verify_conservation().unwrap();
        engine.shutdown();
    }

    #[test]
    fn retry_budget_exhaustion_fails_and_is_counted() {
        let (engine, router) = flaky_router(
            u64::MAX,
            RouterConfig {
                retry: RetryPolicy {
                    max_retries: 2,
                    base: Duration::from_micros(50),
                    cap: Duration::from_micros(500),
                },
                ..RouterConfig::default()
            },
        );
        let err = router.serve(request(16, 16, 16, 1)).unwrap_err();
        assert!(
            crate::coordinator::backend::TransientFault::is(&err),
            "the final transient error surfaces typed: {err}"
        );
        let snap = router.metrics.snapshot();
        assert_eq!(snap.failed, 1);
        assert_eq!(snap.retries, 2, "budget fully spent");
        assert_eq!(snap.retries_exhausted, 1);
        snap.verify_conservation().unwrap();
        engine.shutdown();
    }

    #[test]
    fn retries_off_is_the_seed_behavior() {
        let (engine, router) = flaky_router(1, RouterConfig::default());
        assert!(router.serve(request(16, 16, 16, 1)).is_err());
        let snap = router.metrics.snapshot();
        assert_eq!(snap.failed, 1);
        assert_eq!(snap.retries, 0);
        assert_eq!(snap.retries_exhausted, 0, "no budget, no exhaustion");
        engine.shutdown();
    }

    #[test]
    fn breaker_trips_then_falls_back_then_fails_fast() {
        // Backend fails everything forever; breaker trips after two
        // outcomes per artifact. No retries, so each request records
        // exactly one outcome.
        let (engine, router) = flaky_router(
            u64::MAX,
            RouterConfig {
                force: Some(Algorithm::Nt),
                breaker: Some(BreakerConfig {
                    window: 4,
                    min_samples: 2,
                    failure_threshold: 0.5,
                    open_cooldown: Duration::from_secs(3600),
                }),
                ..RouterConfig::default()
            },
        );
        let nt = XlaBackend::artifact_name(GemmShape::new(16, 16, 16), Algorithm::Nt);
        let tnn = XlaBackend::artifact_name(GemmShape::new(16, 16, 16), Algorithm::Tnn);
        // Two failures trip NT's breaker.
        for i in 0..2 {
            assert!(router.serve(request(16, 16, 16, i)).is_err());
        }
        let reg = router.breakers().expect("breakers enabled");
        assert_eq!(reg.state(&nt), BreakerState::Open);
        assert_eq!(router.metrics.snapshot().breaker_opens, 1);
        // NT open → coerced onto TNN, recorded as Forced; TNN fails too
        // and trips after two more requests.
        for i in 2..4 {
            assert!(router.serve(request(16, 16, 16, i)).is_err());
        }
        assert_eq!(reg.state(&tnn), BreakerState::Open);
        // Both artifacts open → typed fail-fast, distinct from shed.
        let err = router.serve(request(16, 16, 16, 4)).unwrap_err();
        assert!(BreakerOpen::is(&err), "typed breaker rejection: {err}");
        let snap = router.metrics.snapshot();
        assert_eq!(snap.failed, 5);
        assert_eq!(snap.shed, 0, "breaker rejections are failed, not shed");
        assert_eq!(snap.breaker_opens, 2);
        snap.verify_conservation().unwrap();
        engine.shutdown();
    }

    #[test]
    fn breaker_half_open_probe_closes_on_recovery() {
        // Backend heals after two failures; zero cooldown lets the very
        // next request probe the half-open breaker.
        let (engine, router) = flaky_router(
            2,
            RouterConfig {
                force: Some(Algorithm::Nt),
                breaker: Some(BreakerConfig {
                    window: 4,
                    min_samples: 2,
                    failure_threshold: 0.5,
                    open_cooldown: Duration::ZERO,
                }),
                ..RouterConfig::default()
            },
        );
        let nt = XlaBackend::artifact_name(GemmShape::new(16, 16, 16), Algorithm::Nt);
        for i in 0..2 {
            assert!(router.serve(request(16, 16, 16, i)).is_err());
        }
        let reg = router.breakers().expect("breakers enabled");
        assert_eq!(reg.state(&nt), BreakerState::Open);
        // The next request is the half-open probe; the healed backend
        // serves it on the *original* artifact and the breaker closes.
        let resp = router.serve(request(16, 16, 16, 2)).unwrap();
        assert_eq!(resp.algorithm, Algorithm::Nt);
        assert_eq!(reg.state(&nt), BreakerState::Closed);
        let snap = router.metrics.snapshot();
        assert_eq!(snap.breaker_half_open_probes, 1);
        assert_eq!(snap.completed, 1);
        let kinds: Vec<BreakerState> = reg.events().iter().map(|e| e.to).collect();
        assert_eq!(
            kinds,
            vec![
                BreakerState::Open,
                BreakerState::HalfOpen,
                BreakerState::Closed
            ]
        );
        engine.shutdown();
    }

    #[test]
    fn brownout_ladder_engages_and_gates_tracing() {
        use crate::obs::ObsConfig;
        let obs = Arc::new(ObsLayer::new(ObsConfig::default()));
        let (engine, router) = native_router(RouterConfig {
            obs: Some(Arc::clone(&obs)),
            brownout: Some(BrownoutConfig {
                shed_rate_engage: 0.0, // any traffic reads as pressure
                shed_rate_recover: -1.0,
                p99_engage_us: u64::MAX,
                engage_evals: 1,
                recover_evals: u32::MAX,
                eval_interval_ms: 0,
            }),
            ..RouterConfig::default()
        });
        for i in 0..6u64 {
            router.serve(request(16, 16, 16, i)).unwrap();
        }
        let ctrl = router.brownout().expect("brownout enabled");
        assert_eq!(
            ctrl.level(),
            crate::coordinator::lifecycle::BROWNOUT_MAX_LEVEL,
            "forced pressure saturates the ladder"
        );
        let snap = router.metrics.snapshot();
        assert_eq!(snap.brownout_level, 3, "level gauge published");
        assert!(
            snap.obs.as_ref().unwrap().spans_begun < 6,
            "tracing suppressed from level 2"
        );
        assert!(!ctrl.transitions().is_empty());
        engine.shutdown();
    }

    #[test]
    fn online_forced_traffic_is_never_probed() {
        let (engine, router) = native_router(RouterConfig {
            force: Some(Algorithm::Nt),
            ..RouterConfig::online(OnlineConfig {
                probe_every_min: 1,
                probe_every_max: 1,
                retrain_min_labeled: usize::MAX,
                ..OnlineConfig::default()
            })
        });
        for i in 0..4u64 {
            router.serve(request(16, 16, 16, i)).unwrap();
        }
        let snap = router.metrics.snapshot();
        assert_eq!(snap.shadow_probes, 0, "forced traffic bypasses the model");
        assert_eq!(snap.online_samples, 4, "latency still recorded");
        engine.shutdown();
    }
}
