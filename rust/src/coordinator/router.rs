//! The router: the client-facing API of the GEMM service. For each
//! request it runs Algorithm 2 (O(1) features → GBDT predict → memory
//! fallback), maps (shape, algorithm) onto a catalog artifact, and hands
//! the job to the engine. A micro-batcher groups same-artifact requests
//! submitted together so the engine executes them back-to-back.

use super::engine::EngineHandle;
use super::metrics::CoordinatorMetrics;
use crate::gemm::cpu::Matrix;
use crate::gemm::xla::XlaBackend;
use crate::gemm::{Algorithm, GemmShape};
use crate::gpusim::GpuSpec;
use crate::selector::cache::DecisionCache;
use crate::selector::{SelectionReason, Selector};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

/// One NT-operation request: `C = A × Bᵀ` on (virtual) GPU `gpu`.
pub struct GemmRequest {
    pub gpu: &'static GpuSpec,
    pub shape: GemmShape,
    /// A is m×k.
    pub a: Matrix,
    /// B is n×k.
    pub b: Matrix,
}

/// The response: the product plus what the coordinator decided and why.
#[derive(Debug)]
pub struct GemmResponse {
    pub output: Matrix,
    pub algorithm: Algorithm,
    pub reason: SelectionReason,
    pub artifact: String,
    pub latency: std::time::Duration,
}

/// Router configuration.
#[derive(Debug, Clone)]
pub struct RouterConfig {
    /// Force a fixed algorithm instead of MTNN (baseline modes).
    pub force: Option<Algorithm>,
    /// Memoize decisions by `(gpu, m, n, k)` — steady-state traffic
    /// (FCN training re-issues identical shapes every iteration) then
    /// pays a lock-free table lookup instead of a GBDT descent. On by
    /// default; disable for selection microbenchmarks.
    pub cache_decisions: bool,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            force: None,
            cache_decisions: true,
        }
    }
}

/// The router. Cheap to share via `Arc`; submission is thread-safe.
pub struct Router {
    selector: Selector,
    engine: EngineHandle,
    pub metrics: Arc<CoordinatorMetrics>,
    config: RouterConfig,
    cache: DecisionCache,
}

impl Router {
    pub fn new(selector: Selector, engine: EngineHandle, config: RouterConfig) -> Router {
        Router {
            selector,
            engine,
            metrics: Arc::new(CoordinatorMetrics::default()),
            config,
            cache: DecisionCache::default(),
        }
    }

    /// Decide the algorithm for a request (Algorithm 2 + config override),
    /// memoized by shape when `cache_decisions` is on. Selection is
    /// deterministic, so caching is transparent.
    pub fn decide(&self, req: &GemmRequest) -> (Algorithm, SelectionReason) {
        if let Some(forced) = self.config.force {
            return (forced, SelectionReason::Forced);
        }
        let GemmShape { m, n, k } = req.shape;
        if !self.config.cache_decisions {
            return self.selector.select(req.gpu, m, n, k);
        }
        if let Some(hit) = self.cache.get(req.gpu, m, n, k) {
            return hit;
        }
        let dec = self.selector.select(req.gpu, m, n, k);
        self.cache.insert(req.gpu, m, n, k, dec);
        dec
    }

    /// Serve one request synchronously.
    pub fn serve(&self, req: GemmRequest) -> anyhow::Result<GemmResponse> {
        let t0 = Instant::now();
        self.metrics
            .requests
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let (algo, reason) = self.decide(&req);
        self.metrics.record_selection(algo, reason);
        let artifact = XlaBackend::artifact_name(req.shape, algo);
        let result = self.engine.run(&artifact, vec![req.a, req.b]);
        match result {
            Ok(mut outs) => {
                anyhow::ensure!(outs.len() == 1, "{artifact}: expected one output");
                let latency = t0.elapsed();
                self.metrics
                    .completed
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                self.metrics
                    .record_latency_us(latency.as_secs_f64() * 1e6);
                Ok(GemmResponse {
                    output: outs.remove(0),
                    algorithm: algo,
                    reason,
                    artifact,
                    latency,
                })
            }
            Err(e) => {
                self.metrics
                    .failed
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                Err(e)
            }
        }
    }

    /// Serve a batch: requests are grouped by decided artifact so the
    /// engine runs same-shape executables back-to-back (dispatch
    /// amortization); responses come back in submission order.
    pub fn serve_batch(&self, reqs: Vec<GemmRequest>) -> Vec<anyhow::Result<GemmResponse>> {
        let n = reqs.len();
        // Decide everything first.
        let mut decided: Vec<(usize, GemmRequest, Algorithm, SelectionReason, String)> = reqs
            .into_iter()
            .enumerate()
            .map(|(i, r)| {
                self.metrics
                    .requests
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                let (algo, reason) = self.decide(&r);
                self.metrics.record_selection(algo, reason);
                let artifact = XlaBackend::artifact_name(r.shape, algo);
                (i, r, algo, reason, artifact)
            })
            .collect();
        // Group by artifact (stable sort keeps submission order per group).
        decided.sort_by(|a, b| a.4.cmp(&b.4).then(a.0.cmp(&b.0)));

        // Pipeline: submit each group's jobs, then collect.
        let mut pending: Vec<(
            usize,
            Algorithm,
            SelectionReason,
            String,
            Instant,
            mpsc::Receiver<anyhow::Result<Vec<Matrix>>>,
        )> = Vec::with_capacity(n);
        for (i, r, algo, reason, artifact) in decided {
            let t0 = Instant::now();
            match self.engine.submit(artifact.clone(), vec![r.a, r.b]) {
                Ok(rx) => pending.push((i, algo, reason, artifact, t0, rx)),
                Err(e) => {
                    self.metrics
                        .failed
                        .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    // Represent the submission failure in-order below.
                    let (tx, rx) = mpsc::channel();
                    let _ = tx.send(Err(e));
                    pending.push((i, algo, reason, artifact, t0, rx));
                }
            }
        }
        let mut out: Vec<Option<anyhow::Result<GemmResponse>>> =
            (0..n).map(|_| None).collect();
        for (i, algo, reason, artifact, t0, rx) in pending {
            let res = rx
                .recv()
                .map_err(|_| anyhow::anyhow!("engine dropped response"))
                .and_then(|r| r)
                .and_then(|mut outs| {
                    anyhow::ensure!(outs.len() == 1, "{artifact}: expected one output");
                    let latency = t0.elapsed();
                    self.metrics
                        .completed
                        .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                    self.metrics.record_latency_us(latency.as_secs_f64() * 1e6);
                    Ok(GemmResponse {
                        output: outs.remove(0),
                        algorithm: algo,
                        reason,
                        artifact: artifact.clone(),
                        latency,
                    })
                });
            if res.is_err() {
                self.metrics
                    .failed
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            }
            out[i] = Some(res);
        }
        out.into_iter().map(|o| o.expect("all slots filled")).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Engine;
    use crate::dataset::collect_paper_dataset;
    use crate::gemm::cpu::matmul_nt;
    use crate::gpusim::GTX1080;
    use crate::testutil::assert_allclose;

    fn native_router(config: RouterConfig) -> (Engine, Router) {
        let engine = Engine::native(32).unwrap();
        let selector = Selector::train_default(&collect_paper_dataset());
        let router = Router::new(selector, engine.handle(), config);
        (engine, router)
    }

    fn request(m: u64, n: u64, k: u64, seed: u64) -> GemmRequest {
        GemmRequest {
            gpu: &GTX1080,
            shape: GemmShape::new(m, n, k),
            a: Matrix::random(m as usize, k as usize, seed),
            b: Matrix::random(n as usize, k as usize, seed ^ 0xBEEF),
        }
    }

    #[test]
    fn default_config_uses_selector_with_caching() {
        let c = RouterConfig::default();
        assert!(c.force.is_none());
        assert!(c.cache_decisions);
    }

    #[test]
    fn forced_algorithms_report_forced_reason() {
        let (engine, router) = native_router(RouterConfig {
            force: Some(Algorithm::Tnn),
            ..RouterConfig::default()
        });
        let req = request(16, 16, 16, 1);
        let expect = matmul_nt(&req.a, &req.b);
        let resp = router.serve(req).unwrap();
        assert_eq!(resp.algorithm, Algorithm::Tnn);
        assert_eq!(resp.reason, SelectionReason::Forced);
        assert_allclose(&resp.output.data, &expect.data, 1e-4, 1e-4);
        let snap = router.metrics.snapshot();
        assert_eq!(snap.forced, 1);
        assert_eq!(snap.memory_fallbacks, 0);
        engine.shutdown();
    }

    #[test]
    fn cached_and_uncached_decisions_agree() {
        let (engine, cached) = native_router(RouterConfig::default());
        let (engine2, uncached) = native_router(RouterConfig {
            cache_decisions: false,
            ..RouterConfig::default()
        });
        for &(m, n, k) in &[(128u64, 128u64, 128u64), (512, 256, 1024), (128, 128, 128)] {
            let a = cached.decide(&request(m, n, k, 3));
            let b = uncached.decide(&request(m, n, k, 3));
            assert_eq!(a, b, "shape {m}x{n}x{k}");
            // Second decide hits the cache and must still agree.
            assert_eq!(cached.decide(&request(m, n, k, 4)), a);
        }
        engine.shutdown();
        engine2.shutdown();
    }

    #[test]
    fn native_serve_matches_oracle_end_to_end() {
        let (engine, router) = native_router(RouterConfig::default());
        let req = request(64, 32, 48, 7);
        let expect = matmul_nt(&req.a, &req.b);
        let resp = router.serve(req).unwrap();
        assert!(matches!(resp.algorithm, Algorithm::Nt | Algorithm::Tnn));
        assert_allclose(&resp.output.data, &expect.data, 1e-4, 1e-4);
        assert_eq!(router.metrics.snapshot().completed, 1);
        engine.shutdown();
    }

    #[test]
    fn native_serve_batch_keeps_submission_order() {
        let (engine, router) = native_router(RouterConfig::default());
        let shapes = [(16u64, 16u64, 16u64), (32, 32, 32), (16, 16, 16), (8, 24, 40)];
        let reqs: Vec<GemmRequest> = shapes
            .iter()
            .enumerate()
            .map(|(i, &(m, n, k))| request(m, n, k, i as u64))
            .collect();
        let expects: Vec<Matrix> = reqs.iter().map(|r| matmul_nt(&r.a, &r.b)).collect();
        let resps = router.serve_batch(reqs);
        assert_eq!(resps.len(), shapes.len());
        for (i, (resp, expect)) in resps.into_iter().zip(&expects).enumerate() {
            let resp = resp.unwrap_or_else(|e| panic!("request {i}: {e}"));
            assert_allclose(&resp.output.data, &expect.data, 1e-4, 1e-4);
        }
        engine.shutdown();
    }
}
