//! The router: the client-facing API of the GEMM service. For each
//! request it runs Algorithm 2 (O(1) features → GBDT predict → memory
//! fallback), maps (shape, algorithm) onto a catalog artifact, and hands
//! the job to the engine pool, whose shape-affinity sharding and adaptive
//! micro-batcher group same-artifact work engine-side. Admission control
//! decides what happens when every worker queue is full: block (bounded
//! backpressure, the default) or fail fast with [`EngineBusy`].

use super::backend::EngineBusy;
use super::engine::EngineHandle;
use super::metrics::CoordinatorMetrics;
use crate::gemm::cpu::Matrix;
use crate::gemm::xla::XlaBackend;
use crate::gemm::{Algorithm, GemmShape};
use crate::gpusim::GpuSpec;
use crate::selector::cache::DecisionCache;
use crate::selector::{SelectionReason, Selector};
use std::sync::atomic::Ordering;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

/// One NT-operation request: `C = A × Bᵀ` on (virtual) GPU `gpu`.
pub struct GemmRequest {
    pub gpu: &'static GpuSpec,
    pub shape: GemmShape,
    /// A is m×k.
    pub a: Matrix,
    /// B is n×k.
    pub b: Matrix,
}

/// The response: the product plus what the coordinator decided and why.
#[derive(Debug)]
pub struct GemmResponse {
    pub output: Matrix,
    pub algorithm: Algorithm,
    pub reason: SelectionReason,
    pub artifact: String,
    pub latency: std::time::Duration,
}

/// What to do when every engine worker queue is full.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AdmissionControl {
    /// Block the caller until the affine worker has room (bounded
    /// backpressure — the pre-pool semantics).
    #[default]
    Block,
    /// Try the affine worker, hand off to any worker with room, and fail
    /// fast with [`EngineBusy`] when all queues are full (counted in
    /// `CoordinatorMetrics::busy_rejections`).
    RejectWhenBusy,
}

/// Router configuration.
#[derive(Debug, Clone)]
pub struct RouterConfig {
    /// Force a fixed algorithm instead of MTNN (baseline modes).
    pub force: Option<Algorithm>,
    /// Memoize decisions by `(gpu, m, n, k)` — steady-state traffic
    /// (FCN training re-issues identical shapes every iteration) then
    /// pays a lock-free table lookup instead of a GBDT descent. On by
    /// default; disable for selection microbenchmarks.
    pub cache_decisions: bool,
    /// Queue-full policy (see [`AdmissionControl`]).
    pub admission: AdmissionControl,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            force: None,
            cache_decisions: true,
            admission: AdmissionControl::default(),
        }
    }
}

/// The router. Cheap to share via `Arc`; submission is thread-safe.
pub struct Router {
    selector: Selector,
    engine: EngineHandle,
    pub metrics: Arc<CoordinatorMetrics>,
    config: RouterConfig,
    cache: DecisionCache,
}

impl Router {
    pub fn new(selector: Selector, engine: EngineHandle, config: RouterConfig) -> Router {
        let metrics = Arc::new(CoordinatorMetrics::default());
        metrics.attach_worker_depths(engine.depth_gauges());
        Router {
            selector,
            engine,
            metrics,
            config,
            cache: DecisionCache::default(),
        }
    }

    /// Decide the algorithm for a request (Algorithm 2 + config override),
    /// memoized by shape when `cache_decisions` is on. Selection is
    /// deterministic, so caching is transparent.
    pub fn decide(&self, req: &GemmRequest) -> (Algorithm, SelectionReason) {
        if let Some(forced) = self.config.force {
            return (forced, SelectionReason::Forced);
        }
        let GemmShape { m, n, k } = req.shape;
        if !self.config.cache_decisions {
            return self.selector.select(req.gpu, m, n, k);
        }
        if let Some(hit) = self.cache.get(req.gpu, m, n, k) {
            return hit;
        }
        let dec = self.selector.select(req.gpu, m, n, k);
        self.cache.insert(req.gpu, m, n, k, dec);
        dec
    }

    /// Pre-compile / pre-touch the artifacts behind `shapes` on every pool
    /// worker, covering both selectable algorithms so a later decision
    /// flip never pays a cold compile. Saves callers from hand-building
    /// artifact-name strings.
    pub fn warmup(&self, shapes: &[GemmShape]) -> anyhow::Result<()> {
        let mut names = Vec::with_capacity(shapes.len() * 2);
        for &shape in shapes {
            names.push(XlaBackend::artifact_name(shape, Algorithm::Nt));
            names.push(XlaBackend::artifact_name(shape, Algorithm::Tnn));
        }
        names.sort();
        names.dedup();
        self.engine.warmup(&names)
    }

    /// Submit through the configured admission policy, counting fail-fast
    /// rejections.
    fn submit(
        &self,
        artifact: String,
        inputs: Vec<Matrix>,
    ) -> anyhow::Result<mpsc::Receiver<anyhow::Result<Vec<Matrix>>>> {
        let res = match self.config.admission {
            AdmissionControl::Block => self.engine.submit(artifact, inputs),
            AdmissionControl::RejectWhenBusy => self.engine.try_submit(artifact, inputs),
        };
        if res.as_ref().err().is_some_and(EngineBusy::is) {
            self.metrics.busy_rejections.fetch_add(1, Ordering::Relaxed);
        }
        res
    }

    /// Serve one request synchronously.
    pub fn serve(&self, req: GemmRequest) -> anyhow::Result<GemmResponse> {
        let t0 = Instant::now();
        self.metrics.requests.fetch_add(1, Ordering::Relaxed);
        let (algo, reason) = self.decide(&req);
        self.metrics.record_selection(algo, reason);
        let artifact = XlaBackend::artifact_name(req.shape, algo);
        let outcome = self.submit(artifact.clone(), vec![req.a, req.b]).and_then(|rx| {
            let mut outs = rx
                .recv()
                .map_err(|_| anyhow::anyhow!("engine dropped the response"))??;
            anyhow::ensure!(outs.len() == 1, "{artifact}: expected one output");
            Ok(outs.remove(0))
        });
        match outcome {
            Ok(output) => {
                let latency = t0.elapsed();
                self.metrics.completed.fetch_add(1, Ordering::Relaxed);
                self.metrics.record_latency_us(latency.as_secs_f64() * 1e6);
                Ok(GemmResponse {
                    output,
                    algorithm: algo,
                    reason,
                    artifact,
                    latency,
                })
            }
            Err(e) => {
                self.metrics.failed.fetch_add(1, Ordering::Relaxed);
                Err(e)
            }
        }
    }

    /// Serve a batch: every request is decided and submitted up front
    /// (the engine's shape-affinity sharding and micro-batcher regroup
    /// same-artifact jobs worker-side), then responses are collected in
    /// submission order. Each failure — at submit or at execution —
    /// counts toward `failed` exactly once.
    pub fn serve_batch(&self, reqs: Vec<GemmRequest>) -> Vec<anyhow::Result<GemmResponse>> {
        enum Pending {
            Failed(anyhow::Error),
            Wait {
                algo: Algorithm,
                reason: SelectionReason,
                artifact: String,
                t0: Instant,
                rx: mpsc::Receiver<anyhow::Result<Vec<Matrix>>>,
            },
        }

        let mut pending: Vec<Pending> = Vec::with_capacity(reqs.len());
        for req in reqs {
            self.metrics.requests.fetch_add(1, Ordering::Relaxed);
            let (algo, reason) = self.decide(&req);
            self.metrics.record_selection(algo, reason);
            let artifact = XlaBackend::artifact_name(req.shape, algo);
            let t0 = Instant::now();
            match self.submit(artifact.clone(), vec![req.a, req.b]) {
                Ok(rx) => pending.push(Pending::Wait {
                    algo,
                    reason,
                    artifact,
                    t0,
                    rx,
                }),
                Err(e) => {
                    self.metrics.failed.fetch_add(1, Ordering::Relaxed);
                    pending.push(Pending::Failed(e));
                }
            }
        }
        pending
            .into_iter()
            .map(|p| match p {
                Pending::Failed(e) => Err(e),
                Pending::Wait {
                    algo,
                    reason,
                    artifact,
                    t0,
                    rx,
                } => {
                    let res = rx
                        .recv()
                        .map_err(|_| anyhow::anyhow!("engine dropped the response"))
                        .and_then(|r| r)
                        .and_then(|mut outs| {
                            anyhow::ensure!(outs.len() == 1, "{artifact}: expected one output");
                            Ok(outs.remove(0))
                        });
                    match res {
                        Ok(output) => {
                            let latency = t0.elapsed();
                            self.metrics.completed.fetch_add(1, Ordering::Relaxed);
                            self.metrics
                                .record_latency_us(latency.as_secs_f64() * 1e6);
                            Ok(GemmResponse {
                                output,
                                algorithm: algo,
                                reason,
                                artifact,
                                latency,
                            })
                        }
                        Err(e) => {
                            self.metrics.failed.fetch_add(1, Ordering::Relaxed);
                            Err(e)
                        }
                    }
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Engine;
    use crate::dataset::collect_paper_dataset;
    use crate::gemm::cpu::matmul_nt;
    use crate::gpusim::GTX1080;
    use crate::testutil::assert_allclose;

    fn native_router(config: RouterConfig) -> (Engine, Router) {
        let engine = Engine::native(32).unwrap();
        let selector = Selector::train_default(&collect_paper_dataset());
        let router = Router::new(selector, engine.handle(), config);
        (engine, router)
    }

    fn request(m: u64, n: u64, k: u64, seed: u64) -> GemmRequest {
        GemmRequest {
            gpu: &GTX1080,
            shape: GemmShape::new(m, n, k),
            a: Matrix::random(m as usize, k as usize, seed),
            b: Matrix::random(n as usize, k as usize, seed ^ 0xBEEF),
        }
    }

    #[test]
    fn default_config_uses_selector_with_caching() {
        let c = RouterConfig::default();
        assert!(c.force.is_none());
        assert!(c.cache_decisions);
        assert_eq!(c.admission, AdmissionControl::Block);
    }

    #[test]
    fn forced_algorithms_report_forced_reason() {
        let (engine, router) = native_router(RouterConfig {
            force: Some(Algorithm::Tnn),
            ..RouterConfig::default()
        });
        let req = request(16, 16, 16, 1);
        let expect = matmul_nt(&req.a, &req.b);
        let resp = router.serve(req).unwrap();
        assert_eq!(resp.algorithm, Algorithm::Tnn);
        assert_eq!(resp.reason, SelectionReason::Forced);
        assert_allclose(&resp.output.data, &expect.data, 1e-4, 1e-4);
        let snap = router.metrics.snapshot();
        assert_eq!(snap.forced, 1);
        assert_eq!(snap.memory_fallbacks, 0);
        engine.shutdown();
    }

    #[test]
    fn cached_and_uncached_decisions_agree() {
        let (engine, cached) = native_router(RouterConfig::default());
        let (engine2, uncached) = native_router(RouterConfig {
            cache_decisions: false,
            ..RouterConfig::default()
        });
        for &(m, n, k) in &[(128u64, 128u64, 128u64), (512, 256, 1024), (128, 128, 128)] {
            let a = cached.decide(&request(m, n, k, 3));
            let b = uncached.decide(&request(m, n, k, 3));
            assert_eq!(a, b, "shape {m}x{n}x{k}");
            // Second decide hits the cache and must still agree.
            assert_eq!(cached.decide(&request(m, n, k, 4)), a);
        }
        engine.shutdown();
        engine2.shutdown();
    }

    #[test]
    fn native_serve_matches_oracle_end_to_end() {
        let (engine, router) = native_router(RouterConfig::default());
        let req = request(64, 32, 48, 7);
        let expect = matmul_nt(&req.a, &req.b);
        let resp = router.serve(req).unwrap();
        assert!(matches!(resp.algorithm, Algorithm::Nt | Algorithm::Tnn));
        assert_allclose(&resp.output.data, &expect.data, 1e-4, 1e-4);
        assert_eq!(router.metrics.snapshot().completed, 1);
        engine.shutdown();
    }

    #[test]
    fn native_serve_batch_keeps_submission_order() {
        let (engine, router) = native_router(RouterConfig::default());
        let shapes = [(16u64, 16u64, 16u64), (32, 32, 32), (16, 16, 16), (8, 24, 40)];
        let reqs: Vec<GemmRequest> = shapes
            .iter()
            .enumerate()
            .map(|(i, &(m, n, k))| request(m, n, k, i as u64))
            .collect();
        let expects: Vec<Matrix> = reqs.iter().map(|r| matmul_nt(&r.a, &r.b)).collect();
        let resps = router.serve_batch(reqs);
        assert_eq!(resps.len(), shapes.len());
        for (i, (resp, expect)) in resps.into_iter().zip(&expects).enumerate() {
            let resp = resp.unwrap_or_else(|e| panic!("request {i}: {e}"));
            assert_allclose(&resp.output.data, &expect.data, 1e-4, 1e-4);
        }
        engine.shutdown();
    }

    #[test]
    fn warmup_maps_shapes_to_both_algorithms() {
        // Native warmup is a no-op per artifact, so this proves the
        // name-building path end-to-end (bad shapes would still be Ok on
        // native — the PJRT integration test covers compile failures).
        let (engine, router) = native_router(RouterConfig::default());
        router
            .warmup(&[GemmShape::new(128, 128, 128), GemmShape::new(64, 32, 48)])
            .unwrap();
        engine.shutdown();
    }
}
