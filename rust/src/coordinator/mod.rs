//! L3 coordinator: the serving layer that turns MTNN into a GEMM service.
//!
//! Architecture (vLLM-router-like, adapted to a single-host PJRT engine):
//!
//! ```text
//!   clients ──► Router (Send + Sync handle)
//!                 │  per-request: selector.select(gpu, m, n, k)
//!                 ▼
//!               bounded queue ──► Batcher (groups by artifact)
//!                                     │
//!                                     ▼
//!                             Engine thread (owns the backend: the PJRT
//!                             Runtime — Rc-based and !Send, hence a
//!                             dedicated thread, not a pool — or the
//!                             native blocked-GEMM executor when no
//!                             artifact catalog is present)
//! ```
//!
//! Responses travel back through per-request channels; metrics count
//! selections, fallbacks, forced overrides, batching efficiency and
//! latency percentiles. Routing decisions are memoized per
//! `(gpu, m, n, k)` in a lock-free shape-keyed cache
//! ([`crate::selector::cache::DecisionCache`]), so steady-state traffic
//! pays a table lookup instead of a GBDT descent.

pub mod engine;
pub mod metrics;
pub mod router;

pub use engine::{Engine, EngineHandle};
pub use metrics::CoordinatorMetrics;
pub use router::{GemmRequest, GemmResponse, Router, RouterConfig};
