//! L3 coordinator: the serving layer that turns MTNN into a GEMM service.
//!
//! The decision layer (router + selector) is separated from a pluggable,
//! concurrent execution layer behind the [`ExecBackend`] trait:
//!
//! ```text
//!   clients ──► Router (Send + Sync; share via Arc)
//!                 │  per-request: Algorithm 2 (GBDT + memory fallback),
//!                 │  memoized in a lock-free shape-keyed DecisionCache
//!                 │  admission control: block (backpressure) or
//!                 │  fail fast with EngineBusy when every queue is full
//!                 ▼
//!         shape-affinity shard (hash(artifact) → worker)
//!          │              │              │
//!          ▼              ▼              ▼
//!     ┌─ worker 0 ─┐ ┌─ worker 1 ─┐ ┌─ worker N ─┐   bounded queue each;
//!     │ micro-     │ │ micro-     │ │ micro-     │   handoff to a free
//!     │ batcher    │ │ batcher    │ │ batcher    │   worker on queue-full
//!     │ dyn Exec-  │ │ dyn Exec-  │ │ dyn Exec-  │
//!     │ Backend    │ │ Backend    │ │ Backend    │
//!     └────────────┘ └────────────┘ └────────────┘
//! ```
//!
//! Each worker owns one backend instance — PJRT
//! ([`crate::runtime::Runtime`]), native blocked CPU kernels
//! ([`crate::gemm::native::NativeExecutor`]), or the deterministic
//! simulated GPU ([`crate::gpusim::SimExecutor`]) — and an adaptive
//! micro-batcher: after dequeuing a job it collects same-artifact jobs
//! for a small window (or up to `max_batch`) and executes them
//! back-to-back, which is why sharding is by artifact hash (same shape →
//! same worker → hot batches). Responses travel back through per-request
//! channels; metrics count selections, fallbacks, forced overrides, busy
//! rejections, per-worker queue depths, and latency percentiles from a
//! lock-free fixed-bucket histogram. Shutdown drains: every accepted job
//! executes before the workers join. A pool of size 1 reproduces the old
//! single-thread engine semantics exactly.

pub mod backend;
pub mod engine;
pub mod metrics;
pub mod router;

pub use backend::{EngineBusy, ExecBackend};
pub use engine::{Engine, EngineConfig, EngineHandle, EngineJob};
pub use metrics::{CoordinatorMetrics, MetricsSnapshot};
pub use router::{AdmissionControl, GemmRequest, GemmResponse, Router, RouterConfig};
