//! L3 coordinator: the serving layer that turns MTNN into a GEMM service
//! — and, one level up, into a *fleet* of services over heterogeneous
//! devices.
//!
//! Two layers of scheduling. At the top, the [`Fleet`]
//! ([`fleet`]) owns one complete serving stack per simulated device —
//! engine, router, selector, online loop, breakers, metrics — and
//! places each request by scoring every (device, algorithm) candidate
//! on estimated completion time: the device's modeled in-flight backlog,
//! its observed queue-wait EWMA, and the calibrated
//! [`crate::gpusim::TimingModel`]'s execution cost for that algorithm
//! on that device's spec
//! ([`PlacementPolicy::Joint`]; round-robin and random baselines ride
//! the same plumbing). Placement is where the paper's per-GPU features
//! finally act at runtime: the same shape routes to a different device
//! *and* a different algorithm depending on who is fast, who is
//! backlogged, and whose breaker is open. A device whose breaker trips
//! for an artifact drains that traffic to siblings (with periodic
//! recovery placements so the breaker can still heal), and a mid-run
//! spec swap ([`Fleet::swap_spec`]) rebuilds only that device's
//! backends — only that device's online loop sees the drift and
//! retrains. Everything below this paragraph describes the per-device
//! stack the fleet instantiates N times.
//!
//! The decision layer (router + selector) is separated from a pluggable,
//! concurrent execution layer behind the [`ExecBackend`] trait:
//!
//! ```text
//!   clients ──► Fleet::serve — joint (device, algo) placement ──┐
//!                 │ argmin est. completion over devices × algos │ per
//!                 ▼                                             ▼ device
//!               Router (Send + Sync; share via Arc)
//!                 │  per-request: Algorithm 2 (GBDT + memory fallback),
//!                 │  memoized in a lock-free shape-keyed DecisionCache
//!                 │  admission control: block (backpressure) or
//!                 │  fail fast with EngineBusy when every queue is full
//!                 ▼
//!         reuse layer (opt-in): epoch-aware output cache + single-flight
//!          │  dedup keyed by (artifact, input-content hash) — hits and
//!          │  coalesced duplicates resolve here, skipping the queues
//!          ▼
//!         shape-affinity shard (hash(artifact) → worker)
//!          │              │              │
//!          ▼              ▼              ▼
//!     ┌─ worker 0 ─┐ ┌─ worker 1 ─┐ ┌─ worker N ─┐   bounded queue each;
//!     │ micro-     │ │ micro-     │ │ micro-     │   handoff to a free
//!     │ batcher    │ │ batcher    │ │ batcher    │   worker on queue-full
//!     │ dyn Exec-  │ │ dyn Exec-  │ │ dyn Exec-  │
//!     │ Backend    │ │ Backend    │ │ Backend    │
//!     └────────────┘ └────────────┘ └────────────┘
//! ```
//!
//! Each worker owns one backend instance — PJRT
//! ([`crate::runtime::Runtime`]), native blocked CPU kernels
//! ([`crate::gemm::native::NativeExecutor`]), or the deterministic
//! simulated GPU ([`crate::gpusim::SimExecutor`]) — and an adaptive
//! micro-batcher: after dequeuing a job it collects same-artifact jobs
//! for a small window (or up to `max_batch`) and executes them
//! back-to-back, which is why sharding is by artifact hash (same shape →
//! same worker → hot batches). An *idle* worker steals a job from the
//! back of a sibling's queue rather than sleeping, so a same-artifact
//! burst that all sharded onto one worker still spreads across the pool.
//! Responses travel back through per-request channels as [`ExecReply`]s
//! carrying the worker-measured execution latency — the timing hook the
//! online adaptive-selection loop feeds on.
//!
//! **Online adaptive selection** (`crate::online`, enabled via
//! [`RouterConfig::online`]): the selector lives behind a hot-swappable
//! generation-counted pointer; every execution's measured latency is
//! recorded into a lock-free sample ring; an adaptive slice of predicted
//! requests is shadow-probed (both algorithms run, the measured winner
//! becomes a labeled example) — densely for shape buckets whose decayed
//! mispredict window is drifting, sparsely for stable ones, with a UCB
//! exploration floor so under-sampled buckets are probed sooner and a
//! per-GPU probe budget so one drifting device cannot starve siblings
//! of exploration; the drift tracker trips a background trainer that
//! refits the GBDT on a bounded reservoir of the labeled history and
//! promotes the challenger only if it beats the incumbent on held-out
//! data, atomically invalidating the decision cache on swap. Under the
//! fleet, each device runs this loop independently — specialization is
//! per-device by construction.
//!
//! **Observability** comes in two complementary layers:
//!
//! - *Lifetime counters* ([`CoordinatorMetrics`]): selections,
//!   fallbacks, forced overrides, busy rejections, per-worker queue
//!   depths, micro-batch sizes, reuse-layer classification (hits,
//!   misses, coalesced, coalesced-failed, bypasses), the online loop
//!   (samples, probes split by scheduled-vs-bandit cause, the live
//!   probe interval, mispredict rate, retrains, promotions, rollbacks),
//!   and latency percentiles from a lock-free fixed-bucket histogram.
//!   A [`MetricsSnapshot`] renders for machines as well as humans:
//!   `render_prometheus()` emits Prometheus text format 0.0.4
//!   (counters, gauges, and cumulative `le`-bucketed histograms) and
//!   `render_json()` a structured JSON document — a future network
//!   edge's `/metrics` endpoint reduces to one render call.
//! - *Per-request tracing* ([`crate::obs`], opt-in via
//!   [`RouterConfig`]`::obs`): each sampled request carries a
//!   [`crate::obs::span::TraceSpan`] stamped at every stage boundary —
//!   entry, algorithm selection, enqueue, dequeue, execute start/end,
//!   completion — threaded router → engine queue → worker and recorded
//!   lock-free into per-algorithm per-stage histograms, windowed
//!   (recent, not lifetime) rates, and a chaos-triggered flight
//!   recorder that dumps the spans surrounding a fault. See
//!   `obs/mod.rs` for the span lifecycle diagram. Tracing never
//!   changes the meaning of the lifetime counters; with `obs: None`
//!   (the default) the request path stays exactly as it was.
//!
//! **Request lifecycle** (`lifecycle` + the router's serve loop): every
//! request the router accepts walks one state machine and resolves as
//! exactly one terminal outcome:
//!
//! ```text
//!             ┌───────────────────────────────────────────────────────┐
//!             │                  Router::serve entry                  │
//!             │   deadline stamped · brownout tick · span drawn       │
//!             └───────────────┬───────────────────────────────────────┘
//!                             ▼
//!   admit ── deadline expired? ──────────────────────────► timed_out
//!     │
//!     ▼
//!   decide (Algorithm 2) ─► breaker admit per artifact
//!     │                       │ Open: coerce NT↔TNN (Forced, never
//!     │                       │ probed/learned) or, if the alternate is
//!     │                       │ open/unfit, fail fast ───► failed
//!     ▼                       ▼                            (BreakerOpen)
//!   [reuse classify] ─► enqueue ─► worker dequeue
//!     │                              │ expired in queue: dropped
//!     │                              │ without executing ─► timed_out
//!     ▼                              ▼
//!   wait (recv bounded by deadline) ◄─ execute
//!     │ EngineBusy ────────────────────────────────────────► shed
//!     │ deadline ──────────────────────────────────────────► timed_out
//!     │ transient error + retry budget + deadline headroom:
//!     │    sleep decorrelated-jitter backoff, re-submit ──┐
//!     │ transient, budget dead: retries_exhausted ───────►│ failed
//!     │ permanent error ──────────────────────────────────► failed
//!     ▼
//!   completed (breaker records the outcome either way)
//! ```
//!
//! So `completed + failed + shed + timed_out == requests` at quiescence
//! — [`CoordinatorMetrics`]`::verify_conservation` checks it per
//! device, [`metrics::ConservationTotals`] rolls the device snapshots
//! into the same check fleet-wide, the adversarial workload lab
//! (`crate::workload`) hammers both, and backend panics are contained
//! per-job (the worker survives) so chaos can't break it. Deadlines ([`lifecycle::Deadline`]) ride inside the engine
//! job so queue-expired work is dropped unexecuted; retries use
//! deterministic decorrelated jitter ([`lifecycle::DecorrelatedJitter`])
//! and never touch deny-listed artifacts; per-artifact circuit breakers
//! ([`lifecycle::BreakerRegistry`]) trip Closed→Open on rolling failure
//! rate, fail fast or reroute onto the alternate algorithm, and recover
//! through a single half-open probe; sustained overload steps the
//! brownout ladder ([`lifecycle::BrownoutController`]) through shedding
//! shadow probes, then trace sampling, then reuse-cache inserts —
//! restoring in reverse when the windowed rates calm. Shutdown drains:
//! every accepted job executes before the workers join, and a
//! chaos-killed worker's stranded queue is swept with errors rather
//! than left to hang clients. A pool of size 1 reproduces the old
//! single-thread engine semantics exactly.

pub mod backend;
pub mod engine;
pub mod fleet;
pub mod lifecycle;
pub mod metrics;
pub mod reuse;
pub mod router;

pub use backend::{
    classify_error, BreakerOpen, DeadlineExceeded, EngineBusy, ErrorClass, ExecBackend,
    TransientFault,
};
pub use engine::{Engine, EngineConfig, EngineHandle, EngineJob, ExecReply};
pub use fleet::{
    BackendWrap, DeviceReport, Fleet, FleetConfig, FleetDevice, Placement, PlacementPolicy,
};
pub use lifecycle::{
    BreakerConfig, BreakerDecision, BreakerEvent, BreakerRegistry, BreakerState, BrownoutConfig,
    BrownoutController, Deadline, DecorrelatedJitter, RetryPolicy, BROWNOUT_MAX_LEVEL,
};
pub use metrics::{BatchGauge, ConservationTotals, CoordinatorMetrics, MetricsSnapshot};
pub use reuse::{ReuseConfig, ReuseLayer, ReuseStats, ReuseTicket};
pub use router::{AdmissionControl, GemmRequest, GemmResponse, Router, RouterConfig};
