//! L3 coordinator: the serving layer that turns MTNN into a GEMM service.
//!
//! Architecture (vLLM-router-like, adapted to a single-host PJRT engine):
//!
//! ```text
//!   clients ──► Router (Send + Sync handle)
//!                 │  per-request: selector.select(gpu, m, n, k)
//!                 ▼
//!               bounded queue ──► Batcher (groups by artifact)
//!                                     │
//!                                     ▼
//!                             Engine thread (owns the PJRT Runtime,
//!                             which is Rc-based and !Send — hence a
//!                             dedicated thread, not a pool)
//! ```
//!
//! Responses travel back through per-request channels; metrics count
//! selections, fallbacks, batching efficiency and latency percentiles.

pub mod engine;
pub mod metrics;
pub mod router;

pub use engine::{Engine, EngineHandle};
pub use metrics::CoordinatorMetrics;
pub use router::{GemmRequest, GemmResponse, Router, RouterConfig};
