//! The execution engine: a dedicated thread owning the execution backend,
//! fed by a bounded command channel. Batches submitted together are
//! executed back-to-back, amortizing dispatch.
//!
//! Two backends share the same engine loop and handle type:
//!
//! * **PJRT** ([`Engine::spawn`]) — the `xla` crate's client is `Rc`-based
//!   and therefore `!Send`, hence a dedicated thread rather than a pool;
//! * **native** ([`Engine::native`]) — the blocked CPU kernels from
//!   [`crate::gemm::blocked`] via [`NativeExecutor`]; no artifact catalog
//!   required, so the coordinator serves real numerics even without
//!   `make artifacts`.

use crate::gemm::cpu::Matrix;
use crate::gemm::native::NativeExecutor;
use crate::runtime::Runtime;
use std::sync::mpsc;
use std::thread::JoinHandle;

/// One unit of engine work: run `artifact` on `inputs`, reply on `respond`.
pub struct EngineJob {
    pub artifact: String,
    pub inputs: Vec<Matrix>,
    pub respond: mpsc::Sender<anyhow::Result<Vec<Matrix>>>,
}

enum Cmd {
    Run(Box<EngineJob>),
    /// Eagerly compile artifacts.
    Warmup(Vec<String>, mpsc::Sender<anyhow::Result<()>>),
    Shutdown,
}

/// What actually executes artifacts on the engine thread.
enum Backend {
    Pjrt(Runtime),
    Native(NativeExecutor),
}

impl Backend {
    fn execute(&self, artifact: &str, inputs: &[&Matrix]) -> anyhow::Result<Vec<Matrix>> {
        match self {
            Backend::Pjrt(rt) => rt.execute(artifact, inputs),
            Backend::Native(nx) => nx.execute(artifact, inputs),
        }
    }

    fn warmup(&self, names: &[&str]) -> anyhow::Result<()> {
        match self {
            Backend::Pjrt(rt) => rt.warmup(names),
            // Native kernels have no compile step.
            Backend::Native(_) => Ok(()),
        }
    }
}

/// Cloneable, thread-safe handle to the engine.
#[derive(Clone)]
pub struct EngineHandle {
    tx: mpsc::SyncSender<Cmd>,
}

impl EngineHandle {
    /// Submit one job; returns the receiver for its result.
    pub fn submit(
        &self,
        artifact: String,
        inputs: Vec<Matrix>,
    ) -> anyhow::Result<mpsc::Receiver<anyhow::Result<Vec<Matrix>>>> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Cmd::Run(Box::new(EngineJob {
                artifact,
                inputs,
                respond: tx,
            })))
            .map_err(|_| anyhow::anyhow!("engine is shut down"))?;
        Ok(rx)
    }

    /// Submit and wait (convenience for synchronous callers).
    pub fn run(&self, artifact: &str, inputs: Vec<Matrix>) -> anyhow::Result<Vec<Matrix>> {
        let rx = self.submit(artifact.to_string(), inputs)?;
        rx.recv()
            .map_err(|_| anyhow::anyhow!("engine dropped the response"))?
    }

    /// Compile artifacts ahead of traffic (no-op on the native backend).
    pub fn warmup(&self, names: &[String]) -> anyhow::Result<()> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Cmd::Warmup(names.to_vec(), tx))
            .map_err(|_| anyhow::anyhow!("engine is shut down"))?;
        rx.recv()
            .map_err(|_| anyhow::anyhow!("engine dropped the warmup ack"))?
    }
}

/// The engine: spawn with an artifact dir (PJRT) or [`Engine::native`],
/// drop (or call shutdown) to stop.
pub struct Engine {
    handle: EngineHandle,
    join: Option<JoinHandle<()>>,
    tx: mpsc::SyncSender<Cmd>,
}

fn engine_loop(backend: Backend, rx: mpsc::Receiver<Cmd>) {
    while let Ok(cmd) = rx.recv() {
        match cmd {
            Cmd::Run(job) => {
                let refs: Vec<&Matrix> = job.inputs.iter().collect();
                let result = backend.execute(&job.artifact, &refs);
                // Receiver may have given up; that's fine.
                let _ = job.respond.send(result);
            }
            Cmd::Warmup(names, ack) => {
                let refs: Vec<&str> = names.iter().map(String::as_str).collect();
                let _ = ack.send(backend.warmup(&refs));
            }
            Cmd::Shutdown => break,
        }
    }
}

impl Engine {
    /// Spawn the PJRT engine thread. `queue_depth` bounds the command
    /// channel — the backpressure surface of the whole coordinator.
    pub fn spawn(artifact_dir: std::path::PathBuf, queue_depth: usize) -> anyhow::Result<Engine> {
        let (tx, rx) = mpsc::sync_channel::<Cmd>(queue_depth);
        // Fail fast on a bad artifact dir: probe the manifest on the caller
        // thread (cheap), then hand the dir to the engine thread which
        // builds the actual PJRT client.
        crate::runtime::Manifest::load(&artifact_dir)?;
        let (ready_tx, ready_rx) = mpsc::channel::<anyhow::Result<()>>();
        let join = std::thread::Builder::new()
            .name("mtnn-engine".into())
            .spawn(move || {
                let rt = match Runtime::new(&artifact_dir) {
                    Ok(rt) => {
                        let _ = ready_tx.send(Ok(()));
                        rt
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                engine_loop(Backend::Pjrt(rt), rx);
            })?;
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("engine thread died during startup"))??;
        let handle = EngineHandle { tx: tx.clone() };
        Ok(Engine {
            handle,
            join: Some(join),
            tx,
        })
    }

    /// Spawn the native engine thread: blocked CPU kernels, no artifact
    /// catalog. The default backend when PJRT artifacts are absent.
    pub fn native(queue_depth: usize) -> anyhow::Result<Engine> {
        let (tx, rx) = mpsc::sync_channel::<Cmd>(queue_depth);
        let join = std::thread::Builder::new()
            .name("mtnn-engine-native".into())
            .spawn(move || engine_loop(Backend::Native(NativeExecutor), rx))?;
        let handle = EngineHandle { tx: tx.clone() };
        Ok(Engine {
            handle,
            join: Some(join),
            tx,
        })
    }

    pub fn handle(&self) -> EngineHandle {
        self.handle.clone()
    }

    /// Graceful stop: drain queued commands, then join.
    pub fn shutdown(mut self) {
        let _ = self.tx.send(Cmd::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        let _ = self.tx.send(Cmd::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::cpu::matmul_nt;
    use crate::testutil::assert_allclose;

    #[test]
    fn native_engine_serves_gemm_jobs() {
        let engine = Engine::native(16).unwrap();
        let a = Matrix::random(32, 48, 1);
        let b = Matrix::random(24, 48, 2);
        let expect = matmul_nt(&a, &b);
        let out = engine
            .handle()
            .run("nt_32x24x48", vec![a, b])
            .unwrap();
        assert_eq!(out.len(), 1);
        assert_allclose(&out[0].data, &expect.data, 1e-4, 1e-4);
        engine.shutdown();
    }

    #[test]
    fn native_engine_warmup_is_noop_ok() {
        let engine = Engine::native(4).unwrap();
        engine
            .handle()
            .warmup(&["nt_128x128x128".to_string()])
            .unwrap();
        engine.shutdown();
    }

    #[test]
    fn native_engine_propagates_errors() {
        let engine = Engine::native(4).unwrap();
        let a = Matrix::zeros(2, 2);
        let err = engine
            .handle()
            .run("fcn_train_nt-nt-nt", vec![a])
            .unwrap_err()
            .to_string();
        assert!(err.contains("native backend"), "{err}");
        engine.shutdown();
    }
}
