//! The execution engine: a sharded pool of worker threads, each owning one
//! [`ExecBackend`] instance and a bounded command queue.
//!
//! * **Cross-request result reuse** (opt-in,
//!   [`EngineHandle::enable_reuse`]) — a bounded, epoch-aware output
//!   cache plus single-flight dedup sits in the *submit path*
//!   ([`super::reuse`]): a submission whose `(artifact, input-content)`
//!   key is cached is answered on its own response channel without ever
//!   touching a queue, and identical concurrent submissions coalesce
//!   onto one in-flight execution (all waiters receive the shared
//!   result). Workers resolve reuse tickets on completion; the routing
//!   error paths and both teardown sweeps resolve them on failure, so a
//!   coalesced waiter can never hang. Epoch bumps (wired to online model
//!   promotion) and per-artifact invalidation guarantee a stale result
//!   is never served.
//! * **Shape-affinity sharding** — jobs hash by artifact name onto a
//!   worker, so repeated shapes land on the same thread and its adaptive
//!   micro-batcher can run them back-to-back (caches stay hot, dispatch is
//!   amortized).
//! * **Work handoff + backpressure** — when the affine worker's queue is
//!   full, submission probes the other workers; when *every* queue is
//!   full, [`EngineHandle::submit`] blocks on the affine worker (bounded
//!   backpressure, the pre-pool semantics) while
//!   [`EngineHandle::try_submit`] fails fast with [`EngineBusy`].
//! * **Work stealing at dequeue time** — an idle worker (own queue and
//!   deferred stash empty) pops a job from the *back* of a sibling's
//!   queue instead of sleeping, so a burst of same-artifact traffic that
//!   all sharded onto one worker still spreads across the pool. Only
//!   `Run` commands are stolen: `Warmup`/`Shutdown` control stays FIFO on
//!   its owner, and LIFO stealing avoids fighting the victim's
//!   micro-batcher over the oldest entries.
//! * **Adaptive micro-batching** — after dequeuing a job, a worker
//!   collects same-artifact jobs already queued (and, when
//!   `batch_window > 0`, keeps waiting up to that window or `max_batch`)
//!   and executes the run back-to-back; different-artifact jobs pulled
//!   during collection are deferred, not reordered away. Per-worker batch
//!   gauges ([`super::metrics::BatchGauge`]) record how well batching
//!   works in practice.
//! * **Per-job timing** — workers execute through
//!   [`ExecBackend::execute_timed`] and every [`ExecReply`] carries the
//!   measured (or, for the simulated GPU, modeled) execution latency in
//!   µs. This is the telemetry hook the online adaptive-selection loop
//!   (`crate::online`) records its training samples from.
//! * **Panic containment** — a backend that panics inside
//!   `execute`/`execute_timed` fails *that job* (the caller sees an error
//!   describing the panic) instead of killing the worker thread and
//!   stranding everything queued behind it. The worker keeps serving.
//! * **Chaos kill/restart** — pools built with [`Engine::restartable`]
//!   keep their backend factory, so the chaos harness
//!   (`crate::workload`) can [`Engine::kill_worker`] mid-trace (the
//!   worker exits, its queue stays open and stealable) and
//!   [`Engine::restart_worker`] it with a fresh backend. Shutdown sweeps
//!   dead workers' stranded queues so no client ever hangs.
//! * **Graceful shutdown** — `Shutdown` is queued behind in-flight work,
//!   so every job accepted before [`Engine::shutdown`] was called is
//!   executed (drain), then workers join. A submission *racing* with
//!   shutdown either fails at submit or has its job rejected with an
//!   engine-shut-down error — it is never silently dropped.
//!
//! A pool of size 1 reproduces the old single-thread engine exactly:
//! one queue, FIFO service, blocking backpressure (and nobody to steal
//! from).

use super::backend::{DeadlineExceeded, EngineBusy, ExecBackend};
use super::lifecycle::Deadline;
use super::metrics::BatchGauge;
use super::reuse::{Begin, ReuseConfig, ReuseLayer, ReuseTicket};
use crate::gemm::cpu::Matrix;
use crate::gemm::native::NativeExecutor;
use crate::obs::SpanHandle;
use crate::gpusim::{GpuSpec, SimExecutor};
use crate::runtime::Runtime;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// One executed job's response: outputs plus the worker-measured
/// execution latency (queueing excluded — this is the backend's own time;
/// see [`ExecBackend::execute_timed`]).
#[derive(Debug)]
pub struct ExecReply {
    pub outputs: Vec<Matrix>,
    pub exec_us: f64,
}

/// One unit of engine work: run `artifact` on `inputs`, reply on `respond`.
pub struct EngineJob {
    pub artifact: String,
    pub inputs: Vec<Matrix>,
    pub respond: mpsc::Sender<anyhow::Result<ExecReply>>,
    /// Present when this job *leads* a reuse single-flight group
    /// ([`super::reuse::Begin::Lead`]): whoever finishes the job —
    /// worker, routing failure, or a teardown sweep — must resolve the
    /// ticket so coalesced waiters are released exactly once.
    pub reuse: Option<ReuseTicket>,
    /// Present when the request is traced ([`crate::obs`]): the worker
    /// stamps dequeue / batch / execute boundaries on it. `None` costs
    /// nothing on the hot path.
    pub span: Option<SpanHandle>,
    /// Per-request expiry. A worker that pulls an expired job drops it
    /// *without executing*: the reuse ticket resolves, the depth gauge
    /// balances, and the submitter receives a typed
    /// [`DeadlineExceeded`] — the backend never sees the job.
    pub deadline: Option<Deadline>,
}

enum Cmd {
    Run(Box<EngineJob>),
    /// Eagerly compile artifacts.
    Warmup(Vec<String>, mpsc::Sender<anyhow::Result<()>>),
    Shutdown,
    /// Chaos hook ([`Engine::kill_worker`]): the worker exits immediately
    /// *without* draining or closing its queue — queued work is stranded
    /// exactly as a crashed worker would strand it, until a sibling
    /// steals it, [`Engine::restart_worker`] revives the worker, or
    /// shutdown's final sweep fails it.
    Die,
}

/// Pool geometry and micro-batching policy.
#[derive(Debug, Clone, Copy)]
pub struct EngineConfig {
    /// Worker threads (each owns its own backend instance). 1 reproduces
    /// the single-thread engine semantics. The default is
    /// `available_parallelism` capped at 4: large native GEMMs fan out
    /// through the shared persistent pool (`gemm::pool`), and while its
    /// caller-participates design degrades gracefully under many
    /// concurrent engine workers, a worker per core would still leave the
    /// CPU oversubscribed on large-GEMM traffic — raise the cap for
    /// small-GEMM-dominated workloads (see perf_hotpath §8).
    pub workers: usize,
    /// Bounded queue depth *per worker* — the backpressure surface.
    pub queue_depth: usize,
    /// How long a worker waits for more same-artifact jobs before
    /// executing a partial micro-batch. Zero — the default — never
    /// waits: a lone job executes immediately (no added latency), and
    /// jobs already queued back-to-back still batch.
    pub batch_window: Duration,
    /// Micro-batch size cap.
    pub max_batch: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            workers: std::thread::available_parallelism()
                .map(|p| p.get())
                .unwrap_or(1)
                .min(4),
            queue_depth: 64,
            batch_window: Duration::ZERO,
            max_batch: 16,
        }
    }
}

// ---- the shared queue fabric -----------------------------------------------

struct QueueState {
    items: VecDeque<Cmd>,
    closed: bool,
}

/// One worker's bounded queue. Stealable: siblings may pop `Run` commands
/// from the back under the same lock the owner pops the front with.
struct WorkQueue {
    state: Mutex<QueueState>,
    /// Blocked (backpressure) submitters wait here for queue room.
    not_full: Condvar,
}

impl WorkQueue {
    fn new() -> WorkQueue {
        WorkQueue {
            state: Mutex::new(QueueState {
                items: VecDeque::new(),
                closed: false,
            }),
            not_full: Condvar::new(),
        }
    }
}

enum PushErr {
    /// Queue at capacity — the command is handed back for rerouting.
    Full(Cmd),
    /// Queue closed — the command is handed back so the caller can fail
    /// it properly (a job may carry a reuse ticket with parked waiters;
    /// silently dropping it would strand them).
    Closed(Cmd),
}

/// The queue fabric shared by the handle and every worker.
struct PoolShared {
    queues: Vec<WorkQueue>,
    cap: usize,
    /// Push ticket: bumped (under `ticket`) on every push so idle workers
    /// parked on `work` re-scan for poppable or stealable commands.
    ticket: Mutex<u64>,
    work: Condvar,
    /// Cross-request reuse layer, installed (at most once) by
    /// [`EngineHandle::enable_reuse`]. Shared by the submit path (which
    /// classifies submissions) and the workers (which resolve tickets).
    reuse: OnceLock<Arc<ReuseLayer>>,
}

impl PoolShared {
    fn bump(&self) {
        let mut t = self.ticket.lock().unwrap();
        *t += 1;
        drop(t);
        self.work.notify_all();
    }

    fn ticket_now(&self) -> u64 {
        *self.ticket.lock().unwrap()
    }

    /// Park until the ticket moves past `seen` (or a short timeout, as a
    /// lost-wakeup backstop).
    fn wait_ticket(&self, seen: u64, timeout: Duration) {
        let t = self.ticket.lock().unwrap();
        if *t != seen {
            return;
        }
        let _ = self.work.wait_timeout(t, timeout).unwrap();
    }

    /// Non-blocking push. Control commands (`Warmup`/`Shutdown`) ignore
    /// capacity so shutdown and warmup can never deadlock on a full queue.
    fn try_push(&self, idx: usize, cmd: Cmd) -> Result<(), PushErr> {
        let mut q = self.queues[idx].state.lock().unwrap();
        if q.closed {
            return Err(PushErr::Closed(cmd));
        }
        if q.items.len() >= self.cap && matches!(cmd, Cmd::Run(_)) {
            return Err(PushErr::Full(cmd));
        }
        q.items.push_back(cmd);
        drop(q);
        self.bump();
        Ok(())
    }

    /// Blocking push (bounded backpressure): waits for queue room.
    fn push_blocking(&self, idx: usize, cmd: Cmd) -> Result<(), PushErr> {
        let wq = &self.queues[idx];
        let mut q = wq.state.lock().unwrap();
        loop {
            if q.closed {
                return Err(PushErr::Closed(cmd));
            }
            if q.items.len() < self.cap || !matches!(cmd, Cmd::Run(_)) {
                q.items.push_back(cmd);
                drop(q);
                self.bump();
                return Ok(());
            }
            q = wq.not_full.wait(q).unwrap();
        }
    }

    /// Owner pops the front of its own queue.
    fn pop_own(&self, me: usize) -> Option<Cmd> {
        let mut q = self.queues[me].state.lock().unwrap();
        let c = q.items.pop_front();
        drop(q);
        if c.is_some() {
            self.queues[me].not_full.notify_one();
        }
        c
    }

    /// Owner pops with a deadline (micro-batch window collection).
    fn pop_own_deadline(&self, me: usize, deadline: Instant) -> Option<Cmd> {
        loop {
            let seen = self.ticket_now();
            if let Some(c) = self.pop_own(me) {
                return Some(c);
            }
            let now = Instant::now();
            if now >= deadline {
                return None;
            }
            self.wait_ticket(seen, deadline - now);
        }
    }

    /// Steal one `Run` from the back of a sibling's queue. Returns the
    /// victim index so the caller can move the depth gauge.
    fn steal(&self, me: usize) -> Option<(usize, Box<EngineJob>)> {
        let n = self.queues.len();
        for off in 1..n {
            let victim = (me + off) % n;
            let mut q = self.queues[victim].state.lock().unwrap();
            if matches!(q.items.back(), Some(Cmd::Run(_))) {
                let Some(Cmd::Run(job)) = q.items.pop_back() else {
                    unreachable!("back() said Run");
                };
                drop(q);
                self.queues[victim].not_full.notify_one();
                return Some((victim, job));
            }
        }
        None
    }

    /// Push a control command at the *front* of a queue, ahead of queued
    /// work. Ignores capacity like every control push. Used by
    /// [`Engine::kill_worker`] so `Die` preempts the victim's backlog
    /// instead of waiting behind it.
    fn push_front_control(&self, idx: usize, cmd: Cmd) -> Result<(), PushErr> {
        let mut q = self.queues[idx].state.lock().unwrap();
        if q.closed {
            return Err(PushErr::Closed(cmd));
        }
        q.items.push_front(cmd);
        drop(q);
        self.bump();
        Ok(())
    }

    /// Return a worker's deferred stash to the front of its queue in
    /// arrival order. A dying worker must not take deferred work to the
    /// grave: back on the queue, a sibling can steal it and a restarted
    /// worker resumes it.
    fn restash(&self, me: usize, stash: &mut VecDeque<Cmd>) {
        if stash.is_empty() {
            return;
        }
        let mut q = self.queues[me].state.lock().unwrap();
        while let Some(cmd) = stash.pop_back() {
            q.items.push_front(cmd);
        }
        drop(q);
        self.bump();
    }

    /// Mark a queue closed and take whatever is still in it (the teardown
    /// sweep: commands that raced the drain's last empty pop).
    fn close(&self, me: usize) -> Vec<Cmd> {
        let mut q = self.queues[me].state.lock().unwrap();
        q.closed = true;
        let left = q.items.drain(..).collect();
        drop(q);
        self.queues[me].not_full.notify_all();
        left
    }
}

// ---- the handle ------------------------------------------------------------

/// Cloneable, thread-safe handle to the engine pool.
#[derive(Clone)]
pub struct EngineHandle {
    shared: Arc<PoolShared>,
    /// Per-worker in-flight gauges (accepted, not yet completed).
    depths: Arc<Vec<AtomicU64>>,
    /// Per-worker micro-batch gauges.
    batches: Arc<Vec<BatchGauge>>,
}

impl EngineHandle {
    /// Pool size.
    pub fn workers(&self) -> usize {
        self.shared.queues.len()
    }

    /// Point-in-time per-worker in-flight counts (queued + executing).
    pub fn queue_depths(&self) -> Vec<u64> {
        self.depths
            .iter()
            .map(|d| d.load(Ordering::Relaxed))
            .collect()
    }

    /// The shared depth gauges (attached to `CoordinatorMetrics` so
    /// snapshots report them).
    pub fn depth_gauges(&self) -> Arc<Vec<AtomicU64>> {
        Arc::clone(&self.depths)
    }

    /// The shared per-worker micro-batch gauges (attached to
    /// `CoordinatorMetrics` so snapshots report avg/max batch size).
    pub fn batch_gauges(&self) -> Arc<Vec<BatchGauge>> {
        Arc::clone(&self.batches)
    }

    /// Affine worker for an artifact: same artifact → same worker, so its
    /// micro-batches stay hot.
    fn shard_for(&self, artifact: &str) -> usize {
        use std::hash::{Hash, Hasher};
        let mut h = std::collections::hash_map::DefaultHasher::new();
        artifact.hash(&mut h);
        (h.finish() as usize) % self.shared.queues.len()
    }

    /// A routed job failed to land on any queue: resolve its reuse ticket
    /// first (coalesced waiters may already be parked on it — they must
    /// see the same failure), then hand the error to the submitter.
    fn abort_route(&self, cmd: Cmd, err: fn() -> anyhow::Error) -> anyhow::Error {
        if let Cmd::Run(job) = cmd {
            if let (Some(t), Some(layer)) = (job.reuse.as_ref(), self.shared.reuse.get()) {
                layer.complete(t, &Err(err()));
            }
        }
        err()
    }

    /// Route a job: affine worker first, handoff to any worker with queue
    /// room, then either block on the affine worker (`block`) or reject
    /// with [`EngineBusy`].
    fn route(&self, job: Box<EngineJob>, block: bool) -> anyhow::Result<()> {
        let n = self.shared.queues.len();
        let start = self.shard_for(&job.artifact);
        let mut cmd = Cmd::Run(job);
        for probe in 0..n {
            let idx = (start + probe) % n;
            self.depths[idx].fetch_add(1, Ordering::Relaxed);
            match self.shared.try_push(idx, cmd) {
                Ok(()) => return Ok(()),
                Err(PushErr::Full(c)) => {
                    self.depths[idx].fetch_sub(1, Ordering::Relaxed);
                    cmd = c;
                }
                Err(PushErr::Closed(c)) => {
                    self.depths[idx].fetch_sub(1, Ordering::Relaxed);
                    return Err(self.abort_route(c, || anyhow::anyhow!("engine is shut down")));
                }
            }
        }
        if !block {
            return Err(self.abort_route(cmd, || anyhow::Error::new(EngineBusy)));
        }
        // Every queue is full: bounded backpressure on the affine worker.
        self.depths[start].fetch_add(1, Ordering::Relaxed);
        match self.shared.push_blocking(start, cmd) {
            Ok(()) => Ok(()),
            Err(PushErr::Full(c)) | Err(PushErr::Closed(c)) => {
                self.depths[start].fetch_sub(1, Ordering::Relaxed);
                Err(self.abort_route(c, || anyhow::anyhow!("engine is shut down")))
            }
        }
    }

    /// Shared submit path. With reuse enabled, classify the submission
    /// first: cache hits and coalesced duplicates resolve on `rx` without
    /// a job ever being routed; only leaders (and deny-listed bypasses)
    /// enter the queue fabric.
    fn submit_with(
        &self,
        artifact: String,
        inputs: Vec<Matrix>,
        block: bool,
        span: Option<SpanHandle>,
        deadline: Option<Deadline>,
    ) -> anyhow::Result<mpsc::Receiver<anyhow::Result<ExecReply>>> {
        let (tx, rx) = mpsc::channel();
        let reuse = match self.shared.reuse.get() {
            Some(layer) => match layer.begin(&artifact, &inputs, &tx) {
                Begin::Served => {
                    if let Some(cell) = &span {
                        cell.stamp_reuse(crate::obs::span::REUSE_HIT);
                    }
                    return Ok(rx);
                }
                Begin::Coalesced => {
                    if let Some(cell) = &span {
                        cell.stamp_reuse(crate::obs::span::REUSE_COALESCED);
                    }
                    return Ok(rx);
                }
                Begin::Lead(t) => {
                    if let Some(cell) = &span {
                        cell.stamp_reuse(crate::obs::span::REUSE_LEAD);
                    }
                    Some(t)
                }
                Begin::Bypass => {
                    if let Some(cell) = &span {
                        cell.stamp_reuse(crate::obs::span::REUSE_NONE);
                    }
                    None
                }
            },
            None => None,
        };
        // Admission check: a request that arrives already expired never
        // enters a queue. A reuse *leader* resolves its ticket first so
        // coalesced waiters inherit the timeout instead of hanging.
        if deadline.as_ref().is_some_and(|d| d.expired()) {
            if let (Some(t), Some(layer)) = (reuse.as_ref(), self.shared.reuse.get()) {
                layer.complete(t, &Err(anyhow::Error::new(DeadlineExceeded)));
            }
            return Err(anyhow::Error::new(DeadlineExceeded));
        }
        if let Some(cell) = &span {
            cell.stamp_enqueue();
        }
        self.route(
            Box::new(EngineJob {
                artifact,
                inputs,
                respond: tx,
                reuse,
                span,
                deadline,
            }),
            block,
        )?;
        Ok(rx)
    }

    /// Submit one job; returns the receiver for its result. Blocks when
    /// every worker queue is full (backpressure).
    pub fn submit(
        &self,
        artifact: String,
        inputs: Vec<Matrix>,
    ) -> anyhow::Result<mpsc::Receiver<anyhow::Result<ExecReply>>> {
        self.submit_with(artifact, inputs, true, None, None)
    }

    /// Fail-fast submission: hand off to any worker with queue room, and
    /// return [`EngineBusy`] instead of blocking when all queues are full.
    pub fn try_submit(
        &self,
        artifact: String,
        inputs: Vec<Matrix>,
    ) -> anyhow::Result<mpsc::Receiver<anyhow::Result<ExecReply>>> {
        self.submit_with(artifact, inputs, false, None, None)
    }

    /// Submit with an optional trace span: the engine stamps reuse
    /// classification, enqueue, and (in the worker) dequeue / batch /
    /// execute boundaries on it. `block` selects the [`Self::submit`] /
    /// [`Self::try_submit`] admission behavior. A `deadline` is checked
    /// at admission and again by the worker at dequeue — an expired job
    /// is dropped *without executing* and its submitter receives a typed
    /// [`DeadlineExceeded`].
    pub fn submit_traced(
        &self,
        artifact: String,
        inputs: Vec<Matrix>,
        block: bool,
        span: Option<SpanHandle>,
        deadline: Option<Deadline>,
    ) -> anyhow::Result<mpsc::Receiver<anyhow::Result<ExecReply>>> {
        self.submit_with(artifact, inputs, block, span, deadline)
    }

    /// Enable cross-request result reuse (output cache + single-flight
    /// dedup) on this engine. Installs at most once: the first call wins
    /// and later calls return the already-installed layer. Reuse is
    /// **off by default** — a cache hit reports the original execution's
    /// measured `exec_us` and skips the backend entirely, which changes
    /// observable timing semantics, so serving paths opt in explicitly.
    pub fn enable_reuse(&self, config: ReuseConfig) -> Arc<ReuseLayer> {
        let _ = self.shared.reuse.set(Arc::new(ReuseLayer::new(config)));
        Arc::clone(self.shared.reuse.get().expect("reuse layer just installed"))
    }

    /// The reuse layer, if [`EngineHandle::enable_reuse`] installed one.
    pub fn reuse(&self) -> Option<&Arc<ReuseLayer>> {
        self.shared.reuse.get()
    }

    /// Submit and wait (convenience for synchronous callers).
    pub fn run(&self, artifact: &str, inputs: Vec<Matrix>) -> anyhow::Result<Vec<Matrix>> {
        let rx = self.submit(artifact.to_string(), inputs)?;
        let reply = rx
            .recv()
            .map_err(|_| anyhow::anyhow!("engine dropped the response"))??;
        Ok(reply.outputs)
    }

    /// Compile / pre-touch artifacts ahead of traffic on **every** pool
    /// worker (each owns its own backend instance, hence its own compile
    /// cache). No-op on backends without a compile step.
    pub fn warmup(&self, names: &[String]) -> anyhow::Result<()> {
        let mut acks = Vec::with_capacity(self.shared.queues.len());
        for idx in 0..self.shared.queues.len() {
            let (ack_tx, ack_rx) = mpsc::channel();
            self.shared
                .try_push(idx, Cmd::Warmup(names.to_vec(), ack_tx))
                .map_err(|_| anyhow::anyhow!("engine is shut down"))?;
            acks.push(ack_rx);
        }
        for rx in acks {
            rx.recv()
                .map_err(|_| anyhow::anyhow!("engine dropped the warmup ack"))??;
        }
        Ok(())
    }
}

// ---- the worker ------------------------------------------------------------

/// One worker: owns its backend, drains its queue, steals when idle,
/// micro-batches same-artifact runs.
fn worker_loop(
    backend: Box<dyn ExecBackend>,
    shared: Arc<PoolShared>,
    depths: Arc<Vec<AtomicU64>>,
    batches: Arc<Vec<BatchGauge>>,
    me: usize,
    batch_window: Duration,
    max_batch: usize,
) {
    // Different-artifact commands pulled while collecting a micro-batch
    // wait here and are serviced, in arrival order, before the next pop.
    let mut stash: VecDeque<Cmd> = VecDeque::new();
    let mut draining = false;
    loop {
        let cmd = if let Some(c) = stash.pop_front() {
            c
        } else if draining {
            match shared.pop_own(me) {
                Some(c) => c,
                None => break, // queue drained — exit
            }
        } else {
            // Snapshot the push ticket BEFORE scanning: a push landing
            // mid-scan voids the park below, so no wakeup is ever lost.
            let seen = shared.ticket_now();
            if let Some(c) = shared.pop_own(me) {
                c
            } else if let Some((victim, job)) = shared.steal(me) {
                // Idle: steal from a sibling's back instead of sleeping.
                // The stolen job's in-flight accounting moves with it.
                depths[victim].fetch_sub(1, Ordering::Relaxed);
                depths[me].fetch_add(1, Ordering::Relaxed);
                Cmd::Run(job)
            } else {
                shared.wait_ticket(seen, Duration::from_millis(50));
                continue;
            }
        };
        match cmd {
            Cmd::Run(job) => {
                // Deadline check at dequeue: an expired job is dropped
                // without ever reaching the backend.
                if job_expired(&job) {
                    expire_job(&shared, &depths, me, job);
                    continue;
                }
                if let Some(cell) = &job.span {
                    cell.stamp_dequeue();
                }
                let mut batch = vec![job];
                // Deferred same-artifact jobs join the batch first.
                let mut i = 0;
                while i < stash.len() && batch.len() < max_batch {
                    let same =
                        matches!(&stash[i], Cmd::Run(j) if j.artifact == batch[0].artifact);
                    if same {
                        if let Some(Cmd::Run(j)) = stash.remove(i) {
                            if job_expired(&j) {
                                expire_job(&shared, &depths, me, j);
                            } else {
                                if let Some(cell) = &j.span {
                                    cell.stamp_dequeue();
                                }
                                batch.push(j);
                            }
                        }
                    } else {
                        i += 1;
                    }
                }
                // Adaptive window: wait briefly for more same-artifact
                // arrivals; anything else is deferred to the stash.
                if !draining {
                    let deadline = Instant::now() + batch_window;
                    while batch.len() < max_batch {
                        let got = if batch_window.is_zero() {
                            shared.pop_own(me)
                        } else {
                            shared.pop_own_deadline(me, deadline)
                        };
                        match got {
                            Some(Cmd::Run(j)) if j.artifact == batch[0].artifact => {
                                if job_expired(&j) {
                                    expire_job(&shared, &depths, me, j);
                                } else {
                                    if let Some(cell) = &j.span {
                                        cell.stamp_dequeue();
                                    }
                                    batch.push(j)
                                }
                            }
                            Some(Cmd::Shutdown) => {
                                draining = true;
                                break;
                            }
                            Some(other) => stash.push_back(other),
                            None => break, // window elapsed / queue empty
                        }
                    }
                }
                let g = &batches[me];
                g.batches.fetch_add(1, Ordering::Relaxed);
                g.jobs.fetch_add(batch.len() as u64, Ordering::Relaxed);
                g.max.fetch_max(batch.len() as u64, Ordering::Relaxed);
                let batch_len = batch.len();
                for job in batch {
                    // Last-chance deadline check: earlier batch members
                    // may have eaten the whole budget while this one sat
                    // collected — it still never executes.
                    if job_expired(&job) {
                        expire_job(&shared, &depths, me, job);
                        continue;
                    }
                    if let Some(cell) = &job.span {
                        cell.stamp_batch(batch_len, me);
                        cell.stamp_exec_start();
                    }
                    let refs: Vec<&Matrix> = job.inputs.iter().collect();
                    // Panic containment: a panicking backend fails THIS
                    // job — the caller gets an error (counted as `failed`
                    // upstream) — instead of killing the worker thread and
                    // stranding everything queued behind it.
                    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        backend.execute_timed(&job.artifact, &refs)
                    }))
                    .unwrap_or_else(|p| {
                        Err(anyhow::anyhow!(
                            "backend panicked executing {}: {}",
                            job.artifact,
                            panic_message(p.as_ref())
                        ))
                    })
                    .map(|(outputs, exec_us)| ExecReply { outputs, exec_us });
                    if let Some(cell) = &job.span {
                        cell.stamp_exec_end();
                    }
                    // A reuse leader resolves its single-flight group
                    // first: cache the result (if still fresh) and fan it
                    // out to coalesced waiters.
                    if let (Some(t), Some(layer)) = (job.reuse.as_ref(), shared.reuse.get()) {
                        layer.complete(t, &result);
                    }
                    // Gauge drops before the response is visible, so a
                    // caller that just received its result never observes
                    // a stale depth.
                    depths[me].fetch_sub(1, Ordering::Relaxed);
                    // Receiver may have given up; that's fine.
                    let _ = job.respond.send(result);
                }
            }
            Cmd::Warmup(names, ack) => {
                let refs: Vec<&str> = names.iter().map(String::as_str).collect();
                let _ = ack.send(backend.warmup(&refs));
            }
            // Drain: service the stash and whatever is still queued, then
            // exit instead of parking for more work.
            Cmd::Shutdown => draining = true,
            // Chaos kill: exit WITHOUT the teardown sweep — the queue
            // stays open so siblings can steal the backlog and a
            // restarted worker can resume it. Deferred work goes back to
            // the queue first; nothing rides to the grave in the stash.
            Cmd::Die => {
                shared.restash(me, &mut stash);
                return;
            }
        }
    }
    // Teardown sweep: a submit racing with shutdown can land a command
    // after the drain's last empty pop. Close the queue (so pushers get a
    // clear error from now on) and fail whatever slipped in — the
    // submitter is notified and the depth gauge stays balanced — instead
    // of dropping it silently.
    for cmd in shared.close(me) {
        match cmd {
            Cmd::Run(job) => fail_swept_job(&shared, &depths, me, job),
            Cmd::Warmup(_, ack) => {
                let _ = ack.send(Err(anyhow::anyhow!("engine is shut down")));
            }
            Cmd::Shutdown | Cmd::Die => {}
        }
    }
}

/// Has this job's deadline passed?
fn job_expired(job: &EngineJob) -> bool {
    job.deadline.as_ref().is_some_and(|d| d.expired())
}

/// Drop one expired job without executing it: balance the depth gauge,
/// resolve any reuse ticket (coalesced waiters inherit the timeout —
/// they share the leader's deadline fate), and send the submitter a
/// typed [`DeadlineExceeded`] so the router can account it as
/// `timed_out` rather than `failed`.
fn expire_job(shared: &PoolShared, depths: &[AtomicU64], idx: usize, job: Box<EngineJob>) {
    depths[idx].fetch_sub(1, Ordering::Relaxed);
    if let (Some(t), Some(layer)) = (job.reuse.as_ref(), shared.reuse.get()) {
        layer.complete(t, &Err(anyhow::Error::new(DeadlineExceeded)));
    }
    let _ = job.respond.send(Err(anyhow::Error::new(DeadlineExceeded)));
}

/// Fail one swept `Run` command: balance the depth gauge, resolve any
/// reuse ticket (coalesced waiters must see the shutdown too, or they
/// hang forever), and notify the submitter. Used by both teardown sweeps
/// — a live worker's own close and [`Engine::stop`]'s sweep of dead
/// workers' stranded queues.
fn fail_swept_job(shared: &PoolShared, depths: &[AtomicU64], idx: usize, job: Box<EngineJob>) {
    depths[idx].fetch_sub(1, Ordering::Relaxed);
    if let (Some(t), Some(layer)) = (job.reuse.as_ref(), shared.reuse.get()) {
        layer.complete(t, &Err(anyhow::anyhow!("engine is shut down")));
    }
    let _ = job.respond.send(Err(anyhow::anyhow!("engine is shut down")));
}

/// Best-effort extraction of a caught panic payload's message.
fn panic_message(p: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = p.downcast_ref::<&str>() {
        s
    } else if let Some(s) = p.downcast_ref::<String>() {
        s
    } else {
        "non-string panic payload"
    }
}

// ---- the pool --------------------------------------------------------------

/// Backend factory kept by restartable engines: rebuilds worker `i`'s
/// backend after a chaos kill.
type BackendFactory = Box<dyn FnMut(usize) -> anyhow::Result<Box<dyn ExecBackend>> + Send>;

/// The engine pool: construct with a backend factory ([`Engine::pool`]) or
/// one of the named constructors; drop (or call [`Engine::shutdown`]) to
/// drain and stop. [`Engine::restartable`] additionally keeps the factory
/// so workers can be killed and revived mid-run ([`Engine::kill_worker`] /
/// [`Engine::restart_worker`]) — the chaos-harness surface.
pub struct Engine {
    handle: EngineHandle,
    /// `None` marks a worker killed via [`Engine::kill_worker`] and not
    /// (yet) restarted.
    joins: Vec<Option<JoinHandle<()>>>,
    /// Present only on [`Engine::restartable`] pools.
    factory: Option<BackendFactory>,
    batch_window: Duration,
    max_batch: usize,
}

impl Engine {
    /// Spawn a worker pool; `make(i)` builds worker `i`'s backend (called
    /// on the caller thread, so construction failures surface before any
    /// thread starts).
    pub fn pool<F>(config: EngineConfig, mut make: F) -> anyhow::Result<Engine>
    where
        F: FnMut(usize) -> anyhow::Result<Box<dyn ExecBackend>>,
    {
        Engine::assemble(config, &mut make)
    }

    /// Like [`Engine::pool`], but keeps the factory so
    /// [`Engine::restart_worker`] can rebuild a killed worker's backend.
    /// The extra `Send + 'static` bounds are the price of storing it.
    pub fn restartable<F>(config: EngineConfig, make: F) -> anyhow::Result<Engine>
    where
        F: FnMut(usize) -> anyhow::Result<Box<dyn ExecBackend>> + Send + 'static,
    {
        let mut boxed: BackendFactory = Box::new(make);
        let mut engine = Engine::assemble(config, &mut *boxed)?;
        engine.factory = Some(boxed);
        Ok(engine)
    }

    fn assemble(
        config: EngineConfig,
        make: &mut dyn FnMut(usize) -> anyhow::Result<Box<dyn ExecBackend>>,
    ) -> anyhow::Result<Engine> {
        let workers = config.workers.max(1);
        let queue_depth = config.queue_depth.max(1);
        let max_batch = config.max_batch.max(1);
        let mut backends = Vec::with_capacity(workers);
        for i in 0..workers {
            backends.push(make(i)?);
        }
        let depths: Arc<Vec<AtomicU64>> =
            Arc::new((0..workers).map(|_| AtomicU64::new(0)).collect());
        let batches: Arc<Vec<BatchGauge>> =
            Arc::new((0..workers).map(|_| BatchGauge::default()).collect());
        let shared = Arc::new(PoolShared {
            queues: (0..workers).map(|_| WorkQueue::new()).collect(),
            cap: queue_depth,
            ticket: Mutex::new(0),
            work: Condvar::new(),
            reuse: OnceLock::new(),
        });
        let mut joins: Vec<Option<JoinHandle<()>>> = Vec::with_capacity(workers);
        for (i, backend) in backends.into_iter().enumerate() {
            let spawned = Engine::spawn_worker(
                &shared,
                &depths,
                &batches,
                i,
                backend,
                config.batch_window,
                max_batch,
            );
            match spawned {
                Ok(j) => joins.push(Some(j)),
                Err(e) => {
                    // Unwind: stop the workers already running — unlike
                    // the old mpsc design, dropping the handle does not
                    // disconnect them, so they must be told to exit.
                    for idx in 0..workers {
                        let _ = shared.try_push(idx, Cmd::Shutdown);
                    }
                    for j in joins.drain(..).flatten() {
                        let _ = j.join();
                    }
                    return Err(e.into());
                }
            }
        }
        Ok(Engine {
            handle: EngineHandle {
                shared,
                depths,
                batches,
            },
            joins,
            factory: None,
            batch_window: config.batch_window,
            max_batch,
        })
    }

    fn spawn_worker(
        shared: &Arc<PoolShared>,
        depths: &Arc<Vec<AtomicU64>>,
        batches: &Arc<Vec<BatchGauge>>,
        i: usize,
        backend: Box<dyn ExecBackend>,
        batch_window: Duration,
        max_batch: usize,
    ) -> std::io::Result<JoinHandle<()>> {
        let shared_w = Arc::clone(shared);
        let depths_w = Arc::clone(depths);
        let batches_w = Arc::clone(batches);
        std::thread::Builder::new()
            .name(format!("mtnn-engine-{i}"))
            .spawn(move || {
                worker_loop(backend, shared_w, depths_w, batches_w, i, batch_window, max_batch)
            })
    }

    /// Chaos hook: stop worker `idx` mid-run by injecting [`Cmd::Die`] at
    /// the *front* of its queue (it preempts the backlog, though a batch
    /// already collecting finishes first) and joining the thread. The
    /// queue stays open: queued jobs are stranded — stealable by siblings,
    /// resumed by [`Engine::restart_worker`], failed by shutdown's final
    /// sweep — exactly as a crashed worker would leave them.
    ///
    /// Caveat: [`EngineHandle::warmup`] waits for an ack from *every*
    /// worker and will block while one is dead.
    pub fn kill_worker(&mut self, idx: usize) -> anyhow::Result<()> {
        let slot = self
            .joins
            .get_mut(idx)
            .ok_or_else(|| anyhow::anyhow!("engine has no worker {idx}"))?;
        let join = slot
            .take()
            .ok_or_else(|| anyhow::anyhow!("worker {idx} is already dead"))?;
        if self.handle.shared.push_front_control(idx, Cmd::Die).is_err() {
            self.joins[idx] = Some(join);
            anyhow::bail!("worker {idx}'s queue is closed");
        }
        join.join()
            .map_err(|_| anyhow::anyhow!("worker {idx} panicked instead of dying cleanly"))
    }

    /// Revive a worker killed by [`Engine::kill_worker`]: build a fresh
    /// backend from the stored factory and respawn the thread on the same
    /// (still-open) queue, resuming whatever is stranded in it. Only
    /// available on pools built with [`Engine::restartable`].
    pub fn restart_worker(&mut self, idx: usize) -> anyhow::Result<()> {
        anyhow::ensure!(idx < self.joins.len(), "engine has no worker {idx}");
        anyhow::ensure!(
            self.joins[idx].is_none(),
            "worker {idx} is still running"
        );
        let make = self
            .factory
            .as_mut()
            .ok_or_else(|| anyhow::anyhow!("engine was not built with Engine::restartable"))?;
        let backend = make(idx)?;
        let join = Engine::spawn_worker(
            &self.handle.shared,
            &self.handle.depths,
            &self.handle.batches,
            idx,
            backend,
            self.batch_window,
            self.max_batch,
        )?;
        self.joins[idx] = Some(join);
        Ok(())
    }

    /// PJRT pool over an artifact directory. Every worker owns its own
    /// [`Runtime`] (client + executable cache); warmup broadcasts, so each
    /// compiles its own copy.
    pub fn pjrt(artifact_dir: std::path::PathBuf, config: EngineConfig) -> anyhow::Result<Engine> {
        Engine::pool(config, |_| {
            Ok(Box::new(Runtime::new(&artifact_dir)?) as Box<dyn ExecBackend>)
        })
    }

    /// Single-worker PJRT engine (the pre-pool constructor, kept for
    /// drop-in compatibility).
    pub fn spawn(artifact_dir: std::path::PathBuf, queue_depth: usize) -> anyhow::Result<Engine> {
        Engine::pjrt(
            artifact_dir,
            EngineConfig {
                workers: 1,
                queue_depth,
                ..EngineConfig::default()
            },
        )
    }

    /// Native pool: blocked CPU kernels, no artifact catalog required. The
    /// default backend when PJRT artifacts are absent.
    pub fn native_pool(config: EngineConfig) -> anyhow::Result<Engine> {
        Engine::pool(config, |_| Ok(Box::new(NativeExecutor) as Box<dyn ExecBackend>))
    }

    /// Single-worker native engine (the pre-pool constructor, kept for
    /// drop-in compatibility).
    pub fn native(queue_depth: usize) -> anyhow::Result<Engine> {
        Engine::native_pool(EngineConfig {
            workers: 1,
            queue_depth,
            ..EngineConfig::default()
        })
    }

    /// Simulated-GPU pool: oracle numerics plus the calibrated timing
    /// model of `gpu` — latency experiments through the serving path.
    pub fn sim(gpu: &'static GpuSpec, config: EngineConfig) -> anyhow::Result<Engine> {
        Engine::pool(config, |_| {
            Ok(Box::new(SimExecutor::new(gpu)) as Box<dyn ExecBackend>)
        })
    }

    pub fn handle(&self) -> EngineHandle {
        self.handle.clone()
    }

    /// Graceful stop: each worker drains its queue (every accepted job is
    /// executed), then joins.
    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        for idx in 0..self.handle.shared.queues.len() {
            // Control pushes ignore capacity; a closed queue means the
            // worker is already gone.
            let _ = self.handle.shared.try_push(idx, Cmd::Shutdown);
        }
        for j in self.joins.drain(..).flatten() {
            let _ = j.join();
        }
        // A worker killed mid-run and never restarted leaves its queue
        // open with work stranded in it (live workers close their own
        // queues in their teardown sweep; close() is idempotent). Close
        // every queue and fail the leftovers so no client blocks on a
        // response forever.
        for idx in 0..self.handle.shared.queues.len() {
            for cmd in self.handle.shared.close(idx) {
                match cmd {
                    Cmd::Run(job) => {
                        fail_swept_job(&self.handle.shared, &self.handle.depths, idx, job)
                    }
                    Cmd::Warmup(_, ack) => {
                        let _ = ack.send(Err(anyhow::anyhow!("engine is shut down")));
                    }
                    Cmd::Shutdown | Cmd::Die => {}
                }
            }
        }
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        self.stop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::cpu::matmul_nt;
    use crate::testutil::assert_allclose;

    #[test]
    fn native_engine_serves_gemm_jobs() {
        let engine = Engine::native(16).unwrap();
        let a = Matrix::random(32, 48, 1);
        let b = Matrix::random(24, 48, 2);
        let expect = matmul_nt(&a, &b);
        let out = engine.handle().run("nt_32x24x48", vec![a, b]).unwrap();
        assert_eq!(out.len(), 1);
        assert_allclose(&out[0].data, &expect.data, 1e-4, 1e-4);
        engine.shutdown();
    }

    #[test]
    fn native_engine_warmup_is_noop_ok() {
        let engine = Engine::native(4).unwrap();
        engine
            .handle()
            .warmup(&["nt_128x128x128".to_string()])
            .unwrap();
        engine.shutdown();
    }

    #[test]
    fn native_engine_propagates_errors() {
        let engine = Engine::native(4).unwrap();
        let a = Matrix::zeros(2, 2);
        let err = engine
            .handle()
            .run("fcn_train_nt-nt-nt", vec![a])
            .unwrap_err()
            .to_string();
        assert!(err.contains("native backend"), "{err}");
        engine.shutdown();
    }

    #[test]
    fn replies_carry_execution_latency() {
        let engine = Engine::native(8).unwrap();
        let a = Matrix::random(64, 64, 1);
        let b = Matrix::random(64, 64, 2);
        let rx = engine
            .handle()
            .submit("nt_64x64x64".into(), vec![a, b])
            .unwrap();
        let reply = rx.recv().unwrap().unwrap();
        assert_eq!(reply.outputs.len(), 1);
        assert!(
            reply.exec_us > 0.0 && reply.exec_us.is_finite(),
            "exec_us={}",
            reply.exec_us
        );
        engine.shutdown();
    }

    #[test]
    fn pool_executes_across_workers() {
        let engine = Engine::native_pool(EngineConfig {
            workers: 4,
            queue_depth: 4,
            ..EngineConfig::default()
        })
        .unwrap();
        let handle = engine.handle();
        assert_eq!(handle.workers(), 4);
        let mut pend = Vec::new();
        for i in 0..12usize {
            let m = 16 + (i % 4) * 8;
            let a = Matrix::random(m, m, i as u64);
            let b = Matrix::random(m, m, 100 + i as u64);
            let expect = matmul_nt(&a, &b);
            pend.push((
                expect,
                handle.submit(format!("nt_{m}x{m}x{m}"), vec![a, b]).unwrap(),
            ));
        }
        for (expect, rx) in pend {
            let out = rx.recv().unwrap().unwrap().outputs;
            assert_allclose(&out[0].data, &expect.data, 1e-4, 1e-4);
        }
        assert_eq!(handle.queue_depths(), vec![0, 0, 0, 0]);
        engine.shutdown();
    }

    #[test]
    fn warmup_reaches_every_worker() {
        let engine = Engine::native_pool(EngineConfig {
            workers: 3,
            queue_depth: 4,
            ..EngineConfig::default()
        })
        .unwrap();
        engine
            .handle()
            .warmup(&["nt_32x32x32".to_string()])
            .unwrap();
        engine.shutdown();
    }

    #[test]
    fn same_artifact_burst_micro_batches_correctly() {
        // Correctness under batching: a burst of identical artifacts must
        // come back right regardless of how the worker groups them.
        let engine = Engine::native_pool(EngineConfig {
            workers: 1,
            queue_depth: 32,
            batch_window: Duration::from_micros(200),
            max_batch: 4,
        })
        .unwrap();
        let handle = engine.handle();
        let mut pend = Vec::new();
        for i in 0..10u64 {
            let a = Matrix::random(24, 16, i);
            let b = Matrix::random(8, 16, 100 + i);
            let expect = matmul_nt(&a, &b);
            pend.push((expect, handle.submit("nt_24x8x16".into(), vec![a, b]).unwrap()));
        }
        for (expect, rx) in pend {
            let out = rx.recv().unwrap().unwrap().outputs;
            assert_allclose(&out[0].data, &expect.data, 1e-4, 1e-4);
        }
        // Batch gauges saw every job exactly once.
        let g = &handle.batch_gauges()[0];
        assert_eq!(g.jobs.load(Ordering::Relaxed), 10);
        let batches = g.batches.load(Ordering::Relaxed);
        assert!(batches >= 3, "max_batch=4 forces >= 3 batches, got {batches}");
        assert!(g.max.load(Ordering::Relaxed) <= 4);
        engine.shutdown();
    }

    /// Backend that records which worker executed each job and blocks each
    /// worker's FIRST execution until the shared gate opens — makes
    /// steal-while-victim-is-busy states deterministic.
    struct RecordingExecutor {
        id: usize,
        counts: Arc<Vec<AtomicU64>>,
        gate: Arc<(Mutex<bool>, Condvar)>,
        blocked_once: Mutex<bool>,
    }

    impl ExecBackend for RecordingExecutor {
        fn execute(&self, _artifact: &str, inputs: &[&Matrix]) -> anyhow::Result<Vec<Matrix>> {
            self.counts[self.id].fetch_add(1, Ordering::SeqCst);
            let mut first = self.blocked_once.lock().unwrap();
            if !*first {
                *first = true;
                drop(first);
                let (lock, cvar) = &*self.gate;
                let mut open = lock.lock().unwrap();
                while !*open {
                    open = cvar.wait(open).unwrap();
                }
            }
            Ok(vec![inputs[0].clone()])
        }

        fn name(&self) -> String {
            format!("recording-{}", self.id)
        }
    }

    #[test]
    fn idle_worker_steals_from_a_busy_siblings_queue() {
        // Every job shares one artifact, so submit-time sharding sends all
        // of them to the same (affine) worker and its queue never fills
        // (depth 32 ≫ 10 jobs) — submit-time handoff can't spread them.
        // The affine worker blocks inside its first execution; the only
        // way the sibling can ever run a job is dequeue-time stealing.
        let counts: Arc<Vec<AtomicU64>> = Arc::new((0..2).map(|_| AtomicU64::new(0)).collect());
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        let engine = Engine::pool(
            EngineConfig {
                workers: 2,
                queue_depth: 32,
                batch_window: Duration::ZERO,
                max_batch: 1,
            },
            |i| {
                Ok(Box::new(RecordingExecutor {
                    id: i,
                    counts: Arc::clone(&counts),
                    gate: Arc::clone(&gate),
                    blocked_once: Mutex::new(false),
                }) as Box<dyn ExecBackend>)
            },
        )
        .unwrap();
        let handle = engine.handle();
        let mut pend = Vec::new();
        for i in 0..10u64 {
            let a = Matrix::random(8, 8, i);
            pend.push(
                handle
                    .submit("nt_8x8x8".into(), vec![a.clone(), a])
                    .unwrap(),
            );
        }
        // Deterministic rendezvous: both workers are inside execute() (the
        // affine worker on its first job, the sibling on a stolen one)
        // before the gate opens.
        let deadline = Instant::now() + Duration::from_secs(10);
        while counts.iter().map(|c| c.load(Ordering::SeqCst)).min().unwrap() == 0 {
            assert!(
                Instant::now() < deadline,
                "sibling never stole; counts={:?}",
                counts.iter().map(|c| c.load(Ordering::SeqCst)).collect::<Vec<_>>()
            );
            std::thread::sleep(Duration::from_millis(1));
        }
        {
            let (lock, cvar) = &*gate;
            *lock.lock().unwrap() = true;
            cvar.notify_all();
        }
        for rx in pend {
            rx.recv().unwrap().unwrap();
        }
        let c0 = counts[0].load(Ordering::SeqCst);
        let c1 = counts[1].load(Ordering::SeqCst);
        assert_eq!(c0 + c1, 10, "every job executed exactly once");
        assert!(c0 >= 1 && c1 >= 1, "both workers ran jobs: {c0} vs {c1}");
        assert_eq!(handle.queue_depths(), vec![0, 0], "gauges balanced after steals");
        engine.shutdown();
    }

    /// Backend that panics on artifacts containing "boom" and works
    /// normally otherwise.
    struct PanickyExecutor;

    impl ExecBackend for PanickyExecutor {
        fn execute(&self, artifact: &str, inputs: &[&Matrix]) -> anyhow::Result<Vec<Matrix>> {
            if artifact.contains("boom") {
                panic!("injected test panic");
            }
            Ok(vec![inputs[0].clone()])
        }

        fn name(&self) -> String {
            "panicky".into()
        }
    }

    #[test]
    fn backend_panic_fails_the_job_but_not_the_worker() {
        let engine = Engine::pool(
            EngineConfig {
                workers: 1,
                queue_depth: 8,
                ..EngineConfig::default()
            },
            |_| Ok(Box::new(PanickyExecutor) as Box<dyn ExecBackend>),
        )
        .unwrap();
        let handle = engine.handle();
        let a = Matrix::random(4, 4, 1);
        let err = handle
            .run("nt_boom", vec![a.clone(), a.clone()])
            .unwrap_err()
            .to_string();
        assert!(
            err.contains("panicked") && err.contains("injected test panic"),
            "{err}"
        );
        // The same worker still serves jobs after containing the panic.
        let out = handle.run("nt_4x4x4", vec![a.clone(), a]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(handle.queue_depths(), vec![0], "gauge balanced after panic");
        engine.shutdown();
    }

    #[test]
    fn kill_and_restart_worker_resumes_the_stranded_queue() {
        let mut engine = Engine::restartable(
            EngineConfig {
                workers: 1,
                queue_depth: 16,
                ..EngineConfig::default()
            },
            |_| Ok(Box::new(NativeExecutor) as Box<dyn ExecBackend>),
        )
        .unwrap();
        let handle = engine.handle();
        let a = Matrix::random(16, 16, 1);
        let b = Matrix::random(16, 16, 2);
        let expect = matmul_nt(&a, &b);
        // Prove the worker is alive, then kill it.
        handle.run("nt_16x16x16", vec![a.clone(), b.clone()]).unwrap();
        engine.kill_worker(0).unwrap();
        assert!(
            engine.kill_worker(0).unwrap_err().to_string().contains("already dead"),
            "double kill is rejected"
        );
        // Submissions still land in the open queue and are stranded
        // (nobody to steal in a 1-worker pool) until the restart.
        let rx = handle.submit("nt_16x16x16".into(), vec![a, b]).unwrap();
        assert_eq!(handle.queue_depths(), vec![1]);
        engine.restart_worker(0).unwrap();
        let out = rx.recv().unwrap().unwrap().outputs;
        assert_allclose(&out[0].data, &expect.data, 1e-4, 1e-4);
        assert_eq!(handle.queue_depths(), vec![0]);
        engine.shutdown();
    }

    #[test]
    fn shutdown_fails_a_dead_workers_stranded_jobs_instead_of_hanging() {
        let mut engine = Engine::restartable(
            EngineConfig {
                workers: 1,
                queue_depth: 16,
                ..EngineConfig::default()
            },
            |_| Ok(Box::new(NativeExecutor) as Box<dyn ExecBackend>),
        )
        .unwrap();
        let handle = engine.handle();
        engine.kill_worker(0).unwrap();
        let a = Matrix::random(8, 8, 1);
        let rx = handle.submit("nt_8x8x8".into(), vec![a.clone(), a]).unwrap();
        engine.shutdown();
        let err = rx.recv().unwrap().unwrap_err().to_string();
        assert!(err.contains("shut down"), "{err}");
        assert_eq!(handle.queue_depths(), vec![0], "sweep balanced the gauge");
    }

    #[test]
    fn restart_requires_a_restartable_pool() {
        let mut engine = Engine::native_pool(EngineConfig {
            workers: 1,
            queue_depth: 4,
            ..EngineConfig::default()
        })
        .unwrap();
        engine.kill_worker(0).unwrap();
        let err = engine.restart_worker(0).unwrap_err().to_string();
        assert!(err.contains("restartable"), "{err}");
        engine.shutdown();
    }

    #[test]
    fn single_worker_pool_has_nobody_to_steal_from() {
        let engine = Engine::native_pool(EngineConfig {
            workers: 1,
            queue_depth: 4,
            ..EngineConfig::default()
        })
        .unwrap();
        let a = Matrix::random(16, 16, 1);
        let b = Matrix::random(16, 16, 2);
        let out = engine.handle().run("nt_16x16x16", vec![a, b]).unwrap();
        assert_eq!(out.len(), 1);
        engine.shutdown();
    }

    #[test]
    fn reuse_cache_hit_skips_the_queue_and_is_bit_identical() {
        let engine = Engine::native(8).unwrap();
        let handle = engine.handle();
        let layer = handle.enable_reuse(ReuseConfig::default());
        let a = Matrix::random(32, 48, 1);
        let b = Matrix::random(24, 48, 2);
        let first = handle.run("nt_32x24x48", vec![a.clone(), b.clone()]).unwrap();
        let second = handle.run("nt_32x24x48", vec![a, b]).unwrap();
        assert_eq!(
            first[0].data, second[0].data,
            "cached output must be bit-identical to fresh computation"
        );
        let s = layer.stats();
        assert_eq!(s.hits.load(Ordering::Relaxed), 1);
        assert_eq!(s.misses.load(Ordering::Relaxed), 1);
        assert_eq!(handle.queue_depths(), vec![0], "hit never touched the queue");
        engine.shutdown();
    }

    /// Backend that counts executions and blocks inside `execute` until
    /// the shared gate opens — holds a reuse leader in flight so
    /// concurrent identical submissions demonstrably coalesce.
    struct GatedCountingExecutor {
        entered: Arc<AtomicU64>,
        gate: Arc<(Mutex<bool>, Condvar)>,
    }

    impl ExecBackend for GatedCountingExecutor {
        fn execute(&self, _artifact: &str, inputs: &[&Matrix]) -> anyhow::Result<Vec<Matrix>> {
            self.entered.fetch_add(1, Ordering::SeqCst);
            let (lock, cvar) = &*self.gate;
            let mut open = lock.lock().unwrap();
            while !*open {
                open = cvar.wait(open).unwrap();
            }
            Ok(vec![inputs[0].clone()])
        }

        fn name(&self) -> String {
            "gated-counting".into()
        }
    }

    #[test]
    fn concurrent_identical_submissions_single_flight_one_execution() {
        let entered = Arc::new(AtomicU64::new(0));
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        let engine = Engine::pool(
            EngineConfig {
                workers: 1,
                queue_depth: 16,
                batch_window: Duration::ZERO,
                max_batch: 1,
            },
            |_| {
                Ok(Box::new(GatedCountingExecutor {
                    entered: Arc::clone(&entered),
                    gate: Arc::clone(&gate),
                }) as Box<dyn ExecBackend>)
            },
        )
        .unwrap();
        let handle = engine.handle();
        let layer = handle.enable_reuse(ReuseConfig::default());
        let a = Matrix::random(8, 8, 7);
        let lead_rx = handle
            .submit("nt_8x8x8".into(), vec![a.clone(), a.clone()])
            .unwrap();
        // Wait until the leader is inside the backend, then pile on.
        let deadline = Instant::now() + Duration::from_secs(10);
        while entered.load(Ordering::SeqCst) == 0 {
            assert!(Instant::now() < deadline, "leader never started executing");
            std::thread::sleep(Duration::from_millis(1));
        }
        let waiters: Vec<_> = (0..4)
            .map(|_| {
                handle
                    .submit("nt_8x8x8".into(), vec![a.clone(), a.clone()])
                    .unwrap()
            })
            .collect();
        assert_eq!(layer.stats().coalesced.load(Ordering::Relaxed), 4);
        {
            let (lock, cvar) = &*gate;
            *lock.lock().unwrap() = true;
            cvar.notify_all();
        }
        let lead = lead_rx.recv().unwrap().unwrap();
        for rx in waiters {
            let got = rx.recv().unwrap().unwrap();
            assert_eq!(
                got.outputs[0].data, lead.outputs[0].data,
                "every waiter receives the leader's result"
            );
        }
        assert_eq!(
            entered.load(Ordering::SeqCst),
            1,
            "five identical submissions, one backend execution"
        );
        engine.shutdown();
    }

    #[test]
    fn expired_submission_is_rejected_at_admission() {
        let engine = Engine::native(8).unwrap();
        let handle = engine.handle();
        let a = Matrix::random(8, 8, 1);
        let dead = Deadline::after(Duration::ZERO);
        std::thread::sleep(Duration::from_millis(1));
        let err = handle
            .submit_traced("nt_8x8x8".into(), vec![a.clone(), a], true, None, Some(dead))
            .unwrap_err();
        assert!(DeadlineExceeded::is(&err), "{err}");
        assert_eq!(handle.queue_depths(), vec![0], "nothing was enqueued");
        engine.shutdown();
    }

    #[test]
    fn expired_queued_jobs_are_dropped_without_executing() {
        // One worker, gated backend: the first job blocks inside
        // execute() while short-deadline jobs pile up behind it and
        // expire in the queue. When the gate opens, the worker must drop
        // them at dequeue — the backend execution count stays at 1 and
        // every expired submitter receives a typed DeadlineExceeded.
        let entered = Arc::new(AtomicU64::new(0));
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        let engine = Engine::pool(
            EngineConfig {
                workers: 1,
                queue_depth: 16,
                batch_window: Duration::ZERO,
                max_batch: 1,
            },
            |_| {
                Ok(Box::new(GatedCountingExecutor {
                    entered: Arc::clone(&entered),
                    gate: Arc::clone(&gate),
                }) as Box<dyn ExecBackend>)
            },
        )
        .unwrap();
        let handle = engine.handle();
        let a = Matrix::random(8, 8, 11);
        let lead_rx = handle
            .submit("nt_8x8x8".into(), vec![a.clone(), a.clone()])
            .unwrap();
        let deadline = Instant::now() + Duration::from_secs(10);
        while entered.load(Ordering::SeqCst) == 0 {
            assert!(Instant::now() < deadline, "leader never started executing");
            std::thread::sleep(Duration::from_millis(1));
        }
        // Queue three jobs with deadlines that expire while the worker is
        // still stuck on the lead job.
        let doomed: Vec<_> = (0..3)
            .map(|_| {
                handle
                    .submit_traced(
                        "nt_8x8x8".into(),
                        vec![a.clone(), a.clone()],
                        true,
                        None,
                        Some(Deadline::after(Duration::from_millis(5))),
                    )
                    .unwrap()
            })
            .collect();
        std::thread::sleep(Duration::from_millis(20));
        {
            let (lock, cvar) = &*gate;
            *lock.lock().unwrap() = true;
            cvar.notify_all();
        }
        lead_rx.recv().unwrap().unwrap();
        for rx in doomed {
            let err = rx.recv().unwrap().unwrap_err();
            assert!(DeadlineExceeded::is(&err), "{err}");
        }
        assert_eq!(
            entered.load(Ordering::SeqCst),
            1,
            "expired jobs never reached the backend"
        );
        assert_eq!(handle.queue_depths(), vec![0], "gauges balanced after expiry");
        engine.shutdown();
    }

    #[test]
    fn shutdown_resolves_stranded_reuse_tickets_without_hanging_waiters() {
        let mut engine = Engine::restartable(
            EngineConfig {
                workers: 1,
                queue_depth: 16,
                ..EngineConfig::default()
            },
            |_| Ok(Box::new(NativeExecutor) as Box<dyn ExecBackend>),
        )
        .unwrap();
        let handle = engine.handle();
        handle.enable_reuse(ReuseConfig::default());
        engine.kill_worker(0).unwrap();
        let a = Matrix::random(8, 8, 3);
        // Leader strands in the dead worker's open queue; the duplicate
        // coalesces onto its pending ticket.
        let lead_rx = handle
            .submit("nt_8x8x8".into(), vec![a.clone(), a.clone()])
            .unwrap();
        let waiter_rx = handle
            .submit("nt_8x8x8".into(), vec![a.clone(), a])
            .unwrap();
        engine.shutdown();
        for rx in [lead_rx, waiter_rx] {
            let err = rx.recv().unwrap().unwrap_err().to_string();
            assert!(err.contains("shut down"), "{err}");
        }
        assert_eq!(handle.queue_depths(), vec![0], "sweep balanced the gauge");
    }
}
