//! The execution engine: a sharded pool of worker threads, each owning one
//! [`ExecBackend`] instance and a bounded command queue.
//!
//! * **Shape-affinity sharding** — jobs hash by artifact name onto a
//!   worker, so repeated shapes land on the same thread and its adaptive
//!   micro-batcher can run them back-to-back (caches stay hot, dispatch is
//!   amortized).
//! * **Work handoff + backpressure** — when the affine worker's queue is
//!   full, submission probes the other workers; when *every* queue is
//!   full, [`EngineHandle::submit`] blocks on the affine worker (bounded
//!   backpressure, the pre-pool semantics) while
//!   [`EngineHandle::try_submit`] fails fast with [`EngineBusy`].
//! * **Adaptive micro-batching** — after dequeuing a job, a worker
//!   collects same-artifact jobs already queued (and, when
//!   `batch_window > 0`, keeps waiting up to that window or `max_batch`)
//!   and executes the run back-to-back; different-artifact jobs pulled
//!   during collection are deferred, not reordered away.
//! * **Graceful shutdown** — `Shutdown` is queued behind in-flight work,
//!   so every job accepted before [`Engine::shutdown`] was called is
//!   executed (drain), then workers join. A submission *racing* with
//!   shutdown either fails at submit or has its job rejected with an
//!   engine-shut-down error — it is never silently dropped.
//!
//! A pool of size 1 reproduces the old single-thread engine exactly:
//! one queue, FIFO service, blocking backpressure.

use super::backend::{EngineBusy, ExecBackend};
use crate::gemm::cpu::Matrix;
use crate::gemm::native::NativeExecutor;
use crate::gpusim::{GpuSpec, SimExecutor};
use crate::runtime::Runtime;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// One unit of engine work: run `artifact` on `inputs`, reply on `respond`.
pub struct EngineJob {
    pub artifact: String,
    pub inputs: Vec<Matrix>,
    pub respond: mpsc::Sender<anyhow::Result<Vec<Matrix>>>,
}

enum Cmd {
    Run(Box<EngineJob>),
    /// Eagerly compile artifacts.
    Warmup(Vec<String>, mpsc::Sender<anyhow::Result<()>>),
    Shutdown,
}

/// Pool geometry and micro-batching policy.
#[derive(Debug, Clone, Copy)]
pub struct EngineConfig {
    /// Worker threads (each owns its own backend instance). 1 reproduces
    /// the single-thread engine semantics. The default is
    /// `available_parallelism` capped at 4: the native blocked kernels
    /// are internally multi-threaded above ~2 MFLOP, so a worker per
    /// core would oversubscribe the CPU quadratically on large GEMMs —
    /// raise it for small-GEMM-dominated traffic (see perf_hotpath §8).
    pub workers: usize,
    /// Bounded queue depth *per worker* — the backpressure surface.
    pub queue_depth: usize,
    /// How long a worker waits for more same-artifact jobs before
    /// executing a partial micro-batch. Zero — the default — never
    /// waits: a lone job executes immediately (no added latency), and
    /// jobs already queued back-to-back still batch.
    pub batch_window: Duration,
    /// Micro-batch size cap.
    pub max_batch: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            workers: std::thread::available_parallelism()
                .map(|p| p.get())
                .unwrap_or(1)
                .min(4),
            queue_depth: 64,
            batch_window: Duration::ZERO,
            max_batch: 16,
        }
    }
}

/// Cloneable, thread-safe handle to the engine pool.
#[derive(Clone)]
pub struct EngineHandle {
    txs: Arc<Vec<mpsc::SyncSender<Cmd>>>,
    /// Per-worker in-flight gauges (accepted, not yet completed).
    depths: Arc<Vec<AtomicU64>>,
}

impl EngineHandle {
    /// Pool size.
    pub fn workers(&self) -> usize {
        self.txs.len()
    }

    /// Point-in-time per-worker in-flight counts (queued + executing).
    pub fn queue_depths(&self) -> Vec<u64> {
        self.depths
            .iter()
            .map(|d| d.load(Ordering::Relaxed))
            .collect()
    }

    /// The shared depth gauges (attached to `CoordinatorMetrics` so
    /// snapshots report them).
    pub fn depth_gauges(&self) -> Arc<Vec<AtomicU64>> {
        Arc::clone(&self.depths)
    }

    /// Affine worker for an artifact: same artifact → same worker, so its
    /// micro-batches stay hot.
    fn shard_for(&self, artifact: &str) -> usize {
        use std::hash::{Hash, Hasher};
        let mut h = std::collections::hash_map::DefaultHasher::new();
        artifact.hash(&mut h);
        (h.finish() as usize) % self.txs.len()
    }

    /// Route a job: affine worker first, handoff to any worker with queue
    /// room, then either block on the affine worker (`block`) or reject
    /// with [`EngineBusy`].
    fn route(&self, job: Box<EngineJob>, block: bool) -> anyhow::Result<()> {
        let n = self.txs.len();
        let start = self.shard_for(&job.artifact);
        let mut cmd = Cmd::Run(job);
        for probe in 0..n {
            let idx = (start + probe) % n;
            self.depths[idx].fetch_add(1, Ordering::Relaxed);
            match self.txs[idx].try_send(cmd) {
                Ok(()) => return Ok(()),
                Err(mpsc::TrySendError::Full(c)) => {
                    self.depths[idx].fetch_sub(1, Ordering::Relaxed);
                    cmd = c;
                }
                Err(mpsc::TrySendError::Disconnected(_)) => {
                    self.depths[idx].fetch_sub(1, Ordering::Relaxed);
                    anyhow::bail!("engine is shut down");
                }
            }
        }
        if !block {
            return Err(anyhow::Error::new(EngineBusy));
        }
        // Every queue is full: bounded backpressure on the affine worker.
        self.depths[start].fetch_add(1, Ordering::Relaxed);
        match self.txs[start].send(cmd) {
            Ok(()) => Ok(()),
            Err(_) => {
                self.depths[start].fetch_sub(1, Ordering::Relaxed);
                anyhow::bail!("engine is shut down")
            }
        }
    }

    /// Submit one job; returns the receiver for its result. Blocks when
    /// every worker queue is full (backpressure).
    pub fn submit(
        &self,
        artifact: String,
        inputs: Vec<Matrix>,
    ) -> anyhow::Result<mpsc::Receiver<anyhow::Result<Vec<Matrix>>>> {
        let (tx, rx) = mpsc::channel();
        self.route(
            Box::new(EngineJob {
                artifact,
                inputs,
                respond: tx,
            }),
            true,
        )?;
        Ok(rx)
    }

    /// Fail-fast submission: hand off to any worker with queue room, and
    /// return [`EngineBusy`] instead of blocking when all queues are full.
    pub fn try_submit(
        &self,
        artifact: String,
        inputs: Vec<Matrix>,
    ) -> anyhow::Result<mpsc::Receiver<anyhow::Result<Vec<Matrix>>>> {
        let (tx, rx) = mpsc::channel();
        self.route(
            Box::new(EngineJob {
                artifact,
                inputs,
                respond: tx,
            }),
            false,
        )?;
        Ok(rx)
    }

    /// Submit and wait (convenience for synchronous callers).
    pub fn run(&self, artifact: &str, inputs: Vec<Matrix>) -> anyhow::Result<Vec<Matrix>> {
        let rx = self.submit(artifact.to_string(), inputs)?;
        rx.recv()
            .map_err(|_| anyhow::anyhow!("engine dropped the response"))?
    }

    /// Compile / pre-touch artifacts ahead of traffic on **every** pool
    /// worker (each owns its own backend instance, hence its own compile
    /// cache). No-op on backends without a compile step.
    pub fn warmup(&self, names: &[String]) -> anyhow::Result<()> {
        let mut acks = Vec::with_capacity(self.txs.len());
        for tx in self.txs.iter() {
            let (ack_tx, ack_rx) = mpsc::channel();
            tx.send(Cmd::Warmup(names.to_vec(), ack_tx))
                .map_err(|_| anyhow::anyhow!("engine is shut down"))?;
            acks.push(ack_rx);
        }
        for rx in acks {
            rx.recv()
                .map_err(|_| anyhow::anyhow!("engine dropped the warmup ack"))??;
        }
        Ok(())
    }
}

/// One worker: owns its backend, drains its queue, micro-batches
/// same-artifact runs.
fn worker_loop(
    backend: Box<dyn ExecBackend>,
    rx: mpsc::Receiver<Cmd>,
    depths: Arc<Vec<AtomicU64>>,
    me: usize,
    batch_window: Duration,
    max_batch: usize,
) {
    // Different-artifact commands pulled while collecting a micro-batch
    // wait here and are serviced, in arrival order, before the next recv.
    let mut stash: VecDeque<Cmd> = VecDeque::new();
    let mut draining = false;
    loop {
        let cmd = if let Some(c) = stash.pop_front() {
            c
        } else if draining {
            match rx.try_recv() {
                Ok(c) => c,
                Err(_) => break,
            }
        } else {
            match rx.recv() {
                Ok(c) => c,
                Err(_) => break, // all handles dropped
            }
        };
        match cmd {
            Cmd::Run(job) => {
                let mut batch = vec![job];
                // Deferred same-artifact jobs join the batch first.
                let mut i = 0;
                while i < stash.len() && batch.len() < max_batch {
                    let same =
                        matches!(&stash[i], Cmd::Run(j) if j.artifact == batch[0].artifact);
                    if same {
                        if let Some(Cmd::Run(j)) = stash.remove(i) {
                            batch.push(j);
                        }
                    } else {
                        i += 1;
                    }
                }
                // Adaptive window: wait briefly for more same-artifact
                // arrivals; anything else is deferred to the stash.
                if !draining {
                    let deadline = Instant::now() + batch_window;
                    while batch.len() < max_batch {
                        let wait = deadline.saturating_duration_since(Instant::now());
                        let got = if wait.is_zero() {
                            rx.try_recv().ok()
                        } else {
                            rx.recv_timeout(wait).ok()
                        };
                        match got {
                            Some(Cmd::Run(j)) if j.artifact == batch[0].artifact => {
                                batch.push(j)
                            }
                            Some(Cmd::Shutdown) => {
                                draining = true;
                                break;
                            }
                            Some(other) => stash.push_back(other),
                            None => break, // window elapsed / queue empty
                        }
                    }
                }
                for job in batch {
                    let refs: Vec<&Matrix> = job.inputs.iter().collect();
                    let result = backend.execute(&job.artifact, &refs);
                    // Gauge drops before the response is visible, so a
                    // caller that just received its result never observes
                    // a stale depth.
                    depths[me].fetch_sub(1, Ordering::Relaxed);
                    // Receiver may have given up; that's fine.
                    let _ = job.respond.send(result);
                }
            }
            Cmd::Warmup(names, ack) => {
                let refs: Vec<&str> = names.iter().map(String::as_str).collect();
                let _ = ack.send(backend.warmup(&refs));
            }
            // Drain: service the stash and whatever is still queued, then
            // exit instead of blocking for more work.
            Cmd::Shutdown => draining = true,
        }
    }
    // Teardown sweep: a submit racing with shutdown can land a command
    // after the drain's last empty `try_recv`. Fail those explicitly —
    // the submitter gets a clear error and the depth gauge stays
    // balanced — instead of letting the channel drop them silently.
    while let Ok(cmd) = rx.try_recv() {
        match cmd {
            Cmd::Run(job) => {
                depths[me].fetch_sub(1, Ordering::Relaxed);
                let _ = job.respond.send(Err(anyhow::anyhow!("engine is shut down")));
            }
            Cmd::Warmup(_, ack) => {
                let _ = ack.send(Err(anyhow::anyhow!("engine is shut down")));
            }
            Cmd::Shutdown => {}
        }
    }
}

/// The engine pool: construct with a backend factory ([`Engine::pool`]) or
/// one of the named constructors; drop (or call [`Engine::shutdown`]) to
/// drain and stop.
pub struct Engine {
    handle: EngineHandle,
    joins: Vec<JoinHandle<()>>,
}

impl Engine {
    /// Spawn a worker pool; `make(i)` builds worker `i`'s backend (called
    /// on the caller thread, so construction failures surface before any
    /// thread starts).
    pub fn pool<F>(config: EngineConfig, mut make: F) -> anyhow::Result<Engine>
    where
        F: FnMut(usize) -> anyhow::Result<Box<dyn ExecBackend>>,
    {
        let workers = config.workers.max(1);
        let queue_depth = config.queue_depth.max(1);
        let max_batch = config.max_batch.max(1);
        let mut backends = Vec::with_capacity(workers);
        for i in 0..workers {
            backends.push(make(i)?);
        }
        let depths: Arc<Vec<AtomicU64>> =
            Arc::new((0..workers).map(|_| AtomicU64::new(0)).collect());
        let mut txs = Vec::with_capacity(workers);
        let mut joins = Vec::with_capacity(workers);
        for (i, backend) in backends.into_iter().enumerate() {
            let (tx, rx) = mpsc::sync_channel::<Cmd>(queue_depth);
            txs.push(tx);
            let depths = Arc::clone(&depths);
            joins.push(
                std::thread::Builder::new()
                    .name(format!("mtnn-engine-{i}"))
                    .spawn(move || {
                        worker_loop(backend, rx, depths, i, config.batch_window, max_batch)
                    })?,
            );
        }
        Ok(Engine {
            handle: EngineHandle {
                txs: Arc::new(txs),
                depths,
            },
            joins,
        })
    }

    /// PJRT pool over an artifact directory. Every worker owns its own
    /// [`Runtime`] (client + executable cache); warmup broadcasts, so each
    /// compiles its own copy.
    pub fn pjrt(artifact_dir: std::path::PathBuf, config: EngineConfig) -> anyhow::Result<Engine> {
        Engine::pool(config, |_| {
            Ok(Box::new(Runtime::new(&artifact_dir)?) as Box<dyn ExecBackend>)
        })
    }

    /// Single-worker PJRT engine (the pre-pool constructor, kept for
    /// drop-in compatibility).
    pub fn spawn(artifact_dir: std::path::PathBuf, queue_depth: usize) -> anyhow::Result<Engine> {
        Engine::pjrt(
            artifact_dir,
            EngineConfig {
                workers: 1,
                queue_depth,
                ..EngineConfig::default()
            },
        )
    }

    /// Native pool: blocked CPU kernels, no artifact catalog required. The
    /// default backend when PJRT artifacts are absent.
    pub fn native_pool(config: EngineConfig) -> anyhow::Result<Engine> {
        Engine::pool(config, |_| Ok(Box::new(NativeExecutor) as Box<dyn ExecBackend>))
    }

    /// Single-worker native engine (the pre-pool constructor, kept for
    /// drop-in compatibility).
    pub fn native(queue_depth: usize) -> anyhow::Result<Engine> {
        Engine::native_pool(EngineConfig {
            workers: 1,
            queue_depth,
            ..EngineConfig::default()
        })
    }

    /// Simulated-GPU pool: oracle numerics plus the calibrated timing
    /// model of `gpu` — latency experiments through the serving path.
    pub fn sim(gpu: &'static GpuSpec, config: EngineConfig) -> anyhow::Result<Engine> {
        Engine::pool(config, |_| {
            Ok(Box::new(SimExecutor::new(gpu)) as Box<dyn ExecBackend>)
        })
    }

    pub fn handle(&self) -> EngineHandle {
        self.handle.clone()
    }

    /// Graceful stop: each worker drains its queue (every accepted job is
    /// executed), then joins.
    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        for tx in self.handle.txs.iter() {
            let _ = tx.send(Cmd::Shutdown);
        }
        for j in self.joins.drain(..) {
            let _ = j.join();
        }
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        self.stop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::cpu::matmul_nt;
    use crate::testutil::assert_allclose;

    #[test]
    fn native_engine_serves_gemm_jobs() {
        let engine = Engine::native(16).unwrap();
        let a = Matrix::random(32, 48, 1);
        let b = Matrix::random(24, 48, 2);
        let expect = matmul_nt(&a, &b);
        let out = engine.handle().run("nt_32x24x48", vec![a, b]).unwrap();
        assert_eq!(out.len(), 1);
        assert_allclose(&out[0].data, &expect.data, 1e-4, 1e-4);
        engine.shutdown();
    }

    #[test]
    fn native_engine_warmup_is_noop_ok() {
        let engine = Engine::native(4).unwrap();
        engine
            .handle()
            .warmup(&["nt_128x128x128".to_string()])
            .unwrap();
        engine.shutdown();
    }

    #[test]
    fn native_engine_propagates_errors() {
        let engine = Engine::native(4).unwrap();
        let a = Matrix::zeros(2, 2);
        let err = engine
            .handle()
            .run("fcn_train_nt-nt-nt", vec![a])
            .unwrap_err()
            .to_string();
        assert!(err.contains("native backend"), "{err}");
        engine.shutdown();
    }

    #[test]
    fn pool_executes_across_workers() {
        let engine = Engine::native_pool(EngineConfig {
            workers: 4,
            queue_depth: 4,
            ..EngineConfig::default()
        })
        .unwrap();
        let handle = engine.handle();
        assert_eq!(handle.workers(), 4);
        let mut pend = Vec::new();
        for i in 0..12usize {
            let m = 16 + (i % 4) * 8;
            let a = Matrix::random(m, m, i as u64);
            let b = Matrix::random(m, m, 100 + i as u64);
            let expect = matmul_nt(&a, &b);
            pend.push((
                expect,
                handle.submit(format!("nt_{m}x{m}x{m}"), vec![a, b]).unwrap(),
            ));
        }
        for (expect, rx) in pend {
            let out = rx.recv().unwrap().unwrap();
            assert_allclose(&out[0].data, &expect.data, 1e-4, 1e-4);
        }
        assert_eq!(handle.queue_depths(), vec![0, 0, 0, 0]);
        engine.shutdown();
    }

    #[test]
    fn warmup_reaches_every_worker() {
        let engine = Engine::native_pool(EngineConfig {
            workers: 3,
            queue_depth: 4,
            ..EngineConfig::default()
        })
        .unwrap();
        engine
            .handle()
            .warmup(&["nt_32x32x32".to_string()])
            .unwrap();
        engine.shutdown();
    }

    #[test]
    fn same_artifact_burst_micro_batches_correctly() {
        // Correctness under batching: a burst of identical artifacts must
        // come back right regardless of how the worker groups them.
        let engine = Engine::native_pool(EngineConfig {
            workers: 1,
            queue_depth: 32,
            batch_window: Duration::from_micros(200),
            max_batch: 4,
        })
        .unwrap();
        let handle = engine.handle();
        let mut pend = Vec::new();
        for i in 0..10u64 {
            let a = Matrix::random(24, 16, i);
            let b = Matrix::random(8, 16, 100 + i);
            let expect = matmul_nt(&a, &b);
            pend.push((expect, handle.submit("nt_24x8x16".into(), vec![a, b]).unwrap()));
        }
        for (expect, rx) in pend {
            let out = rx.recv().unwrap().unwrap();
            assert_allclose(&out[0].data, &expect.data, 1e-4, 1e-4);
        }
        engine.shutdown();
    }
}
