//! The execution engine: a dedicated thread owning the PJRT [`Runtime`]
//! (the `xla` crate's client is `Rc`-based and therefore `!Send`), fed by
//! a bounded command channel. Batches submitted together are executed
//! back-to-back, amortizing dispatch.

use crate::gemm::cpu::Matrix;
use crate::runtime::Runtime;
use std::sync::mpsc;
use std::thread::JoinHandle;

/// One unit of engine work: run `artifact` on `inputs`, reply on `respond`.
pub struct EngineJob {
    pub artifact: String,
    pub inputs: Vec<Matrix>,
    pub respond: mpsc::Sender<anyhow::Result<Vec<Matrix>>>,
}

enum Cmd {
    Run(Box<EngineJob>),
    /// Eagerly compile artifacts.
    Warmup(Vec<String>, mpsc::Sender<anyhow::Result<()>>),
    Shutdown,
}

/// Cloneable, thread-safe handle to the engine.
#[derive(Clone)]
pub struct EngineHandle {
    tx: mpsc::SyncSender<Cmd>,
}

impl EngineHandle {
    /// Submit one job; returns the receiver for its result.
    pub fn submit(
        &self,
        artifact: String,
        inputs: Vec<Matrix>,
    ) -> anyhow::Result<mpsc::Receiver<anyhow::Result<Vec<Matrix>>>> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Cmd::Run(Box::new(EngineJob {
                artifact,
                inputs,
                respond: tx,
            })))
            .map_err(|_| anyhow::anyhow!("engine is shut down"))?;
        Ok(rx)
    }

    /// Submit and wait (convenience for synchronous callers).
    pub fn run(&self, artifact: &str, inputs: Vec<Matrix>) -> anyhow::Result<Vec<Matrix>> {
        let rx = self.submit(artifact.to_string(), inputs)?;
        rx.recv()
            .map_err(|_| anyhow::anyhow!("engine dropped the response"))?
    }

    /// Compile artifacts ahead of traffic.
    pub fn warmup(&self, names: &[String]) -> anyhow::Result<()> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Cmd::Warmup(names.to_vec(), tx))
            .map_err(|_| anyhow::anyhow!("engine is shut down"))?;
        rx.recv()
            .map_err(|_| anyhow::anyhow!("engine dropped the warmup ack"))?
    }
}

/// The engine: spawn with an artifact dir, drop (or call shutdown) to stop.
pub struct Engine {
    handle: EngineHandle,
    join: Option<JoinHandle<()>>,
    tx: mpsc::SyncSender<Cmd>,
}

impl Engine {
    /// Spawn the engine thread. `queue_depth` bounds the command channel —
    /// the backpressure surface of the whole coordinator.
    pub fn spawn(artifact_dir: std::path::PathBuf, queue_depth: usize) -> anyhow::Result<Engine> {
        let (tx, rx) = mpsc::sync_channel::<Cmd>(queue_depth);
        // Fail fast on a bad artifact dir: probe the manifest on the caller
        // thread (cheap), then hand the dir to the engine thread which
        // builds the actual PJRT client.
        crate::runtime::Manifest::load(&artifact_dir)?;
        let (ready_tx, ready_rx) = mpsc::channel::<anyhow::Result<()>>();
        let join = std::thread::Builder::new()
            .name("mtnn-engine".into())
            .spawn(move || {
                let rt = match Runtime::new(&artifact_dir) {
                    Ok(rt) => {
                        let _ = ready_tx.send(Ok(()));
                        rt
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(cmd) = rx.recv() {
                    match cmd {
                        Cmd::Run(job) => {
                            let refs: Vec<&Matrix> = job.inputs.iter().collect();
                            let result = rt.execute(&job.artifact, &refs);
                            // Receiver may have given up; that's fine.
                            let _ = job.respond.send(result);
                        }
                        Cmd::Warmup(names, ack) => {
                            let refs: Vec<&str> = names.iter().map(String::as_str).collect();
                            let _ = ack.send(rt.warmup(&refs));
                        }
                        Cmd::Shutdown => break,
                    }
                }
            })?;
        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("engine thread died during startup"))??;
        let handle = EngineHandle { tx: tx.clone() };
        Ok(Engine {
            handle,
            join: Some(join),
            tx,
        })
    }

    pub fn handle(&self) -> EngineHandle {
        self.handle.clone()
    }

    /// Graceful stop: drain queued commands, then join.
    pub fn shutdown(mut self) {
        let _ = self.tx.send(Cmd::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        let _ = self.tx.send(Cmd::Shutdown);
        if let Some(j) = self.join.take() {
            let _ = j.join();
        }
    }
}
