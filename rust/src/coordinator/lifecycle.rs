//! Request-lifecycle policy: deadlines, bounded-retry backoff,
//! per-artifact circuit breakers, and the overload-brownout ladder.
//!
//! The router (`super::router`) is the only consumer; everything here is
//! mechanism, deliberately free of engine or selector types so each
//! policy is unit-testable in isolation:
//!
//! * [`Deadline`] — an absolute expiry stamped at `Router::serve` entry
//!   and carried through the engine queue, so expiry is checked at
//!   admission, at worker dequeue (expired jobs are dropped without
//!   executing), and while the client waits for the response.
//! * [`DecorrelatedJitter`] — the retry backoff schedule: each sleep is
//!   drawn uniformly from `[base, min(cap, base·3^attempt)]` with a
//!   deterministic per-request RNG, so concurrent retriers decorrelate
//!   while the effective upper bound grows monotonically to `cap` and
//!   any seed replays the exact same schedule.
//! * [`BreakerRegistry`] — per-artifact circuit breakers over rolling
//!   outcome windows: Closed →(failure rate over threshold)→ Open
//!   (fail fast) →(cooldown)→ HalfOpen (one probe) →(probe success)→
//!   Closed, with every transition recorded for metrics and logs.
//! * [`BrownoutController`] — the graceful-degradation ladder driven by
//!   the observability layer's windowed rates: sustained shed-rate /
//!   p99 pressure steps the level up one rung at a time (disable shadow
//!   probes → disable trace sampling → disable reuse-cache inserts) and
//!   sustained calm steps it back down in reverse.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::obs::WindowRates;
use crate::util::rng::SplitMix64;

// ---- deadlines -------------------------------------------------------------

/// An absolute per-request expiry. `Copy` so it rides inside
/// `EngineJob` and across retry re-entries without bookkeeping.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Deadline {
    at: Instant,
}

impl Deadline {
    /// A deadline `budget` from now.
    pub fn after(budget: Duration) -> Deadline {
        Deadline {
            at: Instant::now() + budget,
        }
    }

    /// A deadline at an absolute instant.
    pub fn at(at: Instant) -> Deadline {
        Deadline { at }
    }

    /// Whether the deadline has passed.
    pub fn expired(&self) -> bool {
        Instant::now() >= self.at
    }

    /// Budget remaining, or `None` once expired.
    pub fn remaining(&self) -> Option<Duration> {
        let now = Instant::now();
        if now >= self.at {
            None
        } else {
            Some(self.at - now)
        }
    }
}

// ---- bounded retries -------------------------------------------------------

/// How many times (and how patiently) the router re-attempts a
/// transient backend failure. `max_retries: 0` (the default) disables
/// retries entirely — the seed behaviour.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Re-attempts after the first try (0 = no retries).
    pub max_retries: u32,
    /// Backoff floor.
    pub base: Duration,
    /// Backoff ceiling.
    pub cap: Duration,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy {
            max_retries: 0,
            base: Duration::from_micros(200),
            cap: Duration::from_millis(20),
        }
    }
}

/// Decorrelated-jitter backoff: attempt `k` sleeps a uniform draw from
/// `[base, min(cap, base·3^k)]`. The upper bound is monotone
/// non-decreasing and saturates at `cap`; the draw itself is jittered so
/// a thundering herd of retriers spreads out. Deterministic under its
/// seed — the chaos proofs replay exact schedules.
#[derive(Debug, Clone)]
pub struct DecorrelatedJitter {
    base_us: u64,
    cap_us: u64,
    upper_us: u64,
    rng: SplitMix64,
}

impl DecorrelatedJitter {
    pub fn new(policy: &RetryPolicy, seed: u64) -> DecorrelatedJitter {
        let base_us = (policy.base.as_micros() as u64).max(1);
        let cap_us = (policy.cap.as_micros() as u64).max(base_us);
        DecorrelatedJitter {
            base_us,
            cap_us,
            upper_us: base_us,
            rng: SplitMix64::new(seed),
        }
    }

    /// The next sleep, in µs. Always within `base ..= cap`.
    pub fn next_us(&mut self) -> u64 {
        self.upper_us = self.upper_us.saturating_mul(3).min(self.cap_us);
        let span = self.upper_us - self.base_us;
        self.base_us + if span == 0 { 0 } else { self.rng.next_u64() % (span + 1) }
    }

    /// Current effective upper bound in µs (monotone non-decreasing
    /// across `next_us` calls; exposed for the property tests).
    pub fn upper_us(&self) -> u64 {
        self.upper_us
    }
}

// ---- per-artifact circuit breakers -----------------------------------------

/// Breaker tuning. The rolling window is per artifact; an artifact
/// whose recent failure rate crosses `failure_threshold` trips open.
#[derive(Debug, Clone, Copy)]
pub struct BreakerConfig {
    /// Rolling outcome-window size per artifact.
    pub window: usize,
    /// Outcomes required in the window before the breaker may trip.
    pub min_samples: usize,
    /// Failure fraction (within the window) that trips Closed → Open.
    pub failure_threshold: f64,
    /// How long an open breaker fails fast before allowing a half-open
    /// probe through.
    pub open_cooldown: Duration,
}

impl Default for BreakerConfig {
    fn default() -> BreakerConfig {
        BreakerConfig {
            window: 16,
            min_samples: 4,
            failure_threshold: 0.5,
            open_cooldown: Duration::from_millis(100),
        }
    }
}

/// Breaker state, snapshotted for metrics and tests.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BreakerState {
    Closed,
    Open,
    HalfOpen,
}

impl BreakerState {
    pub fn name(&self) -> &'static str {
        match self {
            BreakerState::Closed => "closed",
            BreakerState::Open => "open",
            BreakerState::HalfOpen => "half_open",
        }
    }
}

/// What `admit` tells the router to do with a request for an artifact.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BreakerDecision {
    /// Breaker closed: serve normally.
    Allow,
    /// Breaker half-open: this request is the recovery probe — serve it
    /// on the original artifact and report the outcome.
    Probe,
    /// Breaker open: fail fast (or fall back to the alternate
    /// algorithm's artifact).
    Open,
}

/// One recorded state transition (bounded log; oldest dropped).
#[derive(Debug, Clone)]
pub struct BreakerEvent {
    pub artifact: String,
    pub to: BreakerState,
}

struct ArtifactBreaker {
    state: BreakerState,
    /// Rolling recent outcomes; `true` = failure.
    outcomes: VecDeque<bool>,
    opened_at: Instant,
    /// A half-open probe currently in flight.
    probe_in_flight: bool,
}

impl ArtifactBreaker {
    fn new() -> ArtifactBreaker {
        ArtifactBreaker {
            state: BreakerState::Closed,
            outcomes: VecDeque::new(),
            opened_at: Instant::now(),
            probe_in_flight: false,
        }
    }

    fn push_outcome(&mut self, failed: bool, window: usize) {
        if self.outcomes.len() == window.max(1) {
            self.outcomes.pop_front();
        }
        self.outcomes.push_back(failed);
    }

    fn failure_rate(&self) -> f64 {
        if self.outcomes.is_empty() {
            return 0.0;
        }
        self.outcomes.iter().filter(|&&f| f).count() as f64 / self.outcomes.len() as f64
    }
}

const MAX_BREAKER_EVENTS: usize = 256;

/// All per-artifact breakers behind one lock. Every router touch is a
/// short critical section over a small map — the breaker path is far
/// off the per-request hot path until something is actually failing.
pub struct BreakerRegistry {
    config: BreakerConfig,
    inner: Mutex<BreakerInner>,
    opens: AtomicU64,
    half_open_probes: AtomicU64,
}

struct BreakerInner {
    breakers: HashMap<String, ArtifactBreaker>,
    events: VecDeque<BreakerEvent>,
}

impl BreakerRegistry {
    pub fn new(config: BreakerConfig) -> BreakerRegistry {
        BreakerRegistry {
            config,
            inner: Mutex::new(BreakerInner {
                breakers: HashMap::new(),
                events: VecDeque::new(),
            }),
            opens: AtomicU64::new(0),
            half_open_probes: AtomicU64::new(0),
        }
    }

    fn push_event(events: &mut VecDeque<BreakerEvent>, artifact: &str, to: BreakerState) {
        if events.len() == MAX_BREAKER_EVENTS {
            events.pop_front();
        }
        events.push_back(BreakerEvent {
            artifact: artifact.to_string(),
            to,
        });
    }

    /// Admission decision for a request targeting `artifact`.
    pub fn admit(&self, artifact: &str) -> BreakerDecision {
        let mut inner = self.inner.lock().unwrap();
        let inner = &mut *inner;
        let Some(b) = inner.breakers.get_mut(artifact) else {
            return BreakerDecision::Allow; // never failed: no entry
        };
        match b.state {
            BreakerState::Closed => BreakerDecision::Allow,
            BreakerState::Open => {
                if b.opened_at.elapsed() >= self.config.open_cooldown {
                    b.state = BreakerState::HalfOpen;
                    b.probe_in_flight = true;
                    Self::push_event(&mut inner.events, artifact, BreakerState::HalfOpen);
                    self.half_open_probes.fetch_add(1, Ordering::Relaxed);
                    BreakerDecision::Probe
                } else {
                    BreakerDecision::Open
                }
            }
            BreakerState::HalfOpen => {
                if b.probe_in_flight {
                    BreakerDecision::Open // one probe at a time
                } else {
                    b.probe_in_flight = true;
                    self.half_open_probes.fetch_add(1, Ordering::Relaxed);
                    BreakerDecision::Probe
                }
            }
        }
    }

    /// Record a served outcome for `artifact`. Returns the state the
    /// breaker *transitioned to*, if this outcome caused a transition —
    /// the router counts opens and fires recorder triggers off it.
    pub fn record(&self, artifact: &str, failed: bool) -> Option<BreakerState> {
        let mut inner = self.inner.lock().unwrap();
        let inner = &mut *inner;
        let b = inner
            .breakers
            .entry(artifact.to_string())
            .or_insert_with(ArtifactBreaker::new);
        match b.state {
            BreakerState::Closed => {
                b.push_outcome(failed, self.config.window);
                if failed
                    && b.outcomes.len() >= self.config.min_samples
                    && b.failure_rate() >= self.config.failure_threshold
                {
                    b.state = BreakerState::Open;
                    b.opened_at = Instant::now();
                    b.outcomes.clear();
                    self.opens.fetch_add(1, Ordering::Relaxed);
                    Self::push_event(&mut inner.events, artifact, BreakerState::Open);
                    return Some(BreakerState::Open);
                }
                None
            }
            BreakerState::HalfOpen => {
                b.probe_in_flight = false;
                if failed {
                    b.state = BreakerState::Open;
                    b.opened_at = Instant::now();
                    self.opens.fetch_add(1, Ordering::Relaxed);
                    Self::push_event(&mut inner.events, artifact, BreakerState::Open);
                    Some(BreakerState::Open)
                } else {
                    b.state = BreakerState::Closed;
                    b.outcomes.clear();
                    Self::push_event(&mut inner.events, artifact, BreakerState::Closed);
                    Some(BreakerState::Closed)
                }
            }
            // An outcome landing while Open belongs to a request admitted
            // before the trip; it neither re-opens nor closes anything.
            BreakerState::Open => None,
        }
    }

    /// Current state of `artifact`'s breaker (Closed if never touched).
    pub fn state(&self, artifact: &str) -> BreakerState {
        self.inner
            .lock()
            .unwrap()
            .breakers
            .get(artifact)
            .map(|b| b.state)
            .unwrap_or(BreakerState::Closed)
    }

    /// Closed → Open transitions, lifetime.
    pub fn opens(&self) -> u64 {
        self.opens.load(Ordering::Relaxed)
    }

    /// Half-open probes admitted, lifetime.
    pub fn half_open_probes(&self) -> u64 {
        self.half_open_probes.load(Ordering::Relaxed)
    }

    /// Copies of the recorded transitions, oldest first.
    pub fn events(&self) -> Vec<BreakerEvent> {
        self.inner.lock().unwrap().events.iter().cloned().collect()
    }
}

// ---- overload brownout -----------------------------------------------------

/// Number of rungs above normal on the degradation ladder.
pub const BROWNOUT_MAX_LEVEL: u8 = 3;

/// Brownout tuning. Pressure = windowed shed rate over
/// `shed_rate_engage` (or total p99 over `p99_engage_us`); calm =
/// shed rate under `shed_rate_recover` and p99 back under threshold.
/// Streak requirements make both directions *sustained* rather than
/// single-sample reactions.
#[derive(Debug, Clone, Copy)]
pub struct BrownoutConfig {
    /// Windowed shed rate at or above this is pressure.
    pub shed_rate_engage: f64,
    /// Windowed shed rate at or below this is calm.
    pub shed_rate_recover: f64,
    /// Total-latency p99 (µs) at or above this is pressure
    /// (`u64::MAX` disables the latency signal).
    pub p99_engage_us: u64,
    /// Consecutive pressured evaluations required to step up one level.
    pub engage_evals: u32,
    /// Consecutive calm evaluations required to step down one level.
    pub recover_evals: u32,
    /// Minimum ms between evaluations (requests between ticks see the
    /// last decided level).
    pub eval_interval_ms: u64,
}

impl Default for BrownoutConfig {
    fn default() -> BrownoutConfig {
        BrownoutConfig {
            shed_rate_engage: 0.10,
            shed_rate_recover: 0.02,
            p99_engage_us: u64::MAX,
            engage_evals: 2,
            recover_evals: 3,
            eval_interval_ms: 250,
        }
    }
}

struct BrownoutInner {
    pressured_streak: u32,
    calm_streak: u32,
    /// (now_ms, level) transitions, bounded.
    transitions: Vec<(u64, u8)>,
}

/// The degradation ladder. Level 0 is normal service; each rung sheds
/// one more optional load source:
///
/// | level | shadow probes | trace sampling | reuse inserts |
/// |------:|:-------------:|:--------------:|:-------------:|
/// |   0   |      on       |       on       |      on       |
/// |   1   |     off       |       on       |      on       |
/// |   2   |     off       |      off       |      on       |
/// |   3   |     off       |      off       |     off       |
///
/// Levels move one rung per sustained streak, so a single noisy window
/// never slams the ladder to the top or bottom.
pub struct BrownoutController {
    config: BrownoutConfig,
    level: AtomicU8,
    last_eval_ms: AtomicU64,
    inner: Mutex<BrownoutInner>,
}

const MAX_BROWNOUT_TRANSITIONS: usize = 64;

impl BrownoutController {
    pub fn new(config: BrownoutConfig) -> BrownoutController {
        BrownoutController {
            config,
            level: AtomicU8::new(0),
            last_eval_ms: AtomicU64::new(0),
            inner: Mutex::new(BrownoutInner {
                pressured_streak: 0,
                calm_streak: 0,
                transitions: Vec::new(),
            }),
        }
    }

    pub fn level(&self) -> u8 {
        self.level.load(Ordering::Relaxed)
    }

    /// Whether an evaluation is due at `now_ms` (cheap pre-check so the
    /// per-request path is one atomic load almost always).
    pub fn eval_due(&self, now_ms: u64) -> bool {
        let last = self.last_eval_ms.load(Ordering::Relaxed);
        now_ms.saturating_sub(last) >= self.config.eval_interval_ms
    }

    /// Evaluate the ladder against the current windowed rates (and the
    /// total-latency p99 if the caller has one). Returns the level in
    /// force after this evaluation.
    pub fn evaluate(&self, rates: &WindowRates, p99_us: u64, now_ms: u64) -> u8 {
        let last = self.last_eval_ms.load(Ordering::Relaxed);
        if now_ms.saturating_sub(last) < self.config.eval_interval_ms {
            return self.level();
        }
        if self
            .last_eval_ms
            .compare_exchange(last, now_ms, Ordering::Relaxed, Ordering::Relaxed)
            .is_err()
        {
            return self.level(); // another thread took this tick
        }
        let pressured = (rates.requests > 0 && rates.shed_rate >= self.config.shed_rate_engage)
            || (self.config.p99_engage_us != u64::MAX && p99_us >= self.config.p99_engage_us);
        let calm = rates.shed_rate <= self.config.shed_rate_recover
            && (self.config.p99_engage_us == u64::MAX || p99_us < self.config.p99_engage_us);
        let mut inner = self.inner.lock().unwrap();
        let mut level = self.level();
        if pressured {
            inner.calm_streak = 0;
            inner.pressured_streak += 1;
            if inner.pressured_streak >= self.config.engage_evals && level < BROWNOUT_MAX_LEVEL {
                level += 1;
                inner.pressured_streak = 0;
                Self::push_transition(&mut inner.transitions, now_ms, level);
                self.level.store(level, Ordering::Relaxed);
            }
        } else if calm {
            inner.pressured_streak = 0;
            inner.calm_streak += 1;
            if inner.calm_streak >= self.config.recover_evals && level > 0 {
                level -= 1;
                inner.calm_streak = 0;
                Self::push_transition(&mut inner.transitions, now_ms, level);
                self.level.store(level, Ordering::Relaxed);
            }
        } else {
            // Between thresholds: hold the level, reset both streaks.
            inner.pressured_streak = 0;
            inner.calm_streak = 0;
        }
        level
    }

    fn push_transition(ts: &mut Vec<(u64, u8)>, now_ms: u64, level: u8) {
        if ts.len() == MAX_BROWNOUT_TRANSITIONS {
            ts.remove(0);
        }
        ts.push((now_ms, level));
    }

    /// Shadow probes allowed (disabled from level 1).
    pub fn allow_probes(&self) -> bool {
        self.level() < 1
    }

    /// Trace-span sampling allowed (disabled from level 2).
    pub fn allow_tracing(&self) -> bool {
        self.level() < 2
    }

    /// Reuse-cache inserts allowed (disabled from level 3).
    pub fn allow_reuse_inserts(&self) -> bool {
        self.level() < 3
    }

    /// `(now_ms, level)` transitions, oldest first.
    pub fn transitions(&self) -> Vec<(u64, u8)> {
        self.inner.lock().unwrap().transitions.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rates(requests: u64, shed: u64) -> WindowRates {
        WindowRates {
            requests,
            shed,
            shed_rate: if requests == 0 {
                0.0
            } else {
                shed as f64 / requests as f64
            },
            ..WindowRates::default()
        }
    }

    // -- deadlines --

    #[test]
    fn deadline_expires_and_reports_remaining() {
        let d = Deadline::after(Duration::from_secs(60));
        assert!(!d.expired());
        assert!(d.remaining().unwrap() > Duration::from_secs(59));
        let past = Deadline::at(Instant::now() - Duration::from_millis(1));
        assert!(past.expired());
        assert!(past.remaining().is_none());
    }

    // -- backoff --

    fn policy(base_us: u64, cap_us: u64) -> RetryPolicy {
        RetryPolicy {
            max_retries: 8,
            base: Duration::from_micros(base_us),
            cap: Duration::from_micros(cap_us),
        }
    }

    #[test]
    fn backoff_is_deterministic_under_seed() {
        let p = policy(100, 10_000);
        let a: Vec<u64> = {
            let mut j = DecorrelatedJitter::new(&p, 42);
            (0..8).map(|_| j.next_us()).collect()
        };
        let b: Vec<u64> = {
            let mut j = DecorrelatedJitter::new(&p, 42);
            (0..8).map(|_| j.next_us()).collect()
        };
        let c: Vec<u64> = {
            let mut j = DecorrelatedJitter::new(&p, 43);
            (0..8).map(|_| j.next_us()).collect()
        };
        assert_eq!(a, b, "same seed replays the schedule");
        assert_ne!(a, c, "different seed decorrelates");
    }

    #[test]
    fn backoff_bounded_and_cap_monotone() {
        let p = policy(100, 3_000);
        let mut j = DecorrelatedJitter::new(&p, 7);
        let mut prev_upper = 0;
        for _ in 0..32 {
            let s = j.next_us();
            assert!((100..=3_000).contains(&s), "sleep {s} out of bounds");
            assert!(j.upper_us() >= prev_upper, "effective cap regressed");
            prev_upper = j.upper_us();
        }
        assert_eq!(prev_upper, 3_000, "upper bound saturates at cap");
    }

    #[test]
    fn backoff_degenerate_base_equals_cap() {
        let p = policy(500, 500);
        let mut j = DecorrelatedJitter::new(&p, 1);
        for _ in 0..4 {
            assert_eq!(j.next_us(), 500);
        }
    }

    // -- breaker --

    fn breaker_cfg(cooldown_ms: u64) -> BreakerConfig {
        BreakerConfig {
            window: 8,
            min_samples: 4,
            failure_threshold: 0.5,
            open_cooldown: Duration::from_millis(cooldown_ms),
        }
    }

    #[test]
    fn breaker_trips_open_on_failure_rate() {
        let reg = BreakerRegistry::new(breaker_cfg(10_000));
        assert_eq!(reg.admit("a"), BreakerDecision::Allow);
        // Three failures: under min_samples, still closed.
        for _ in 0..3 {
            assert_eq!(reg.record("a", true), None);
        }
        assert_eq!(reg.state("a"), BreakerState::Closed);
        // Fourth failure reaches min_samples at 100% failure rate.
        assert_eq!(reg.record("a", true), Some(BreakerState::Open));
        assert_eq!(reg.state("a"), BreakerState::Open);
        assert_eq!(reg.admit("a"), BreakerDecision::Open, "fails fast");
        assert_eq!(reg.opens(), 1);
        // A different artifact is unaffected.
        assert_eq!(reg.admit("b"), BreakerDecision::Allow);
    }

    #[test]
    fn breaker_successes_keep_it_closed() {
        let reg = BreakerRegistry::new(breaker_cfg(10_000));
        for _ in 0..20 {
            assert_eq!(reg.record("a", false), None);
        }
        // A minority of failures in the window stays under threshold.
        for _ in 0..3 {
            reg.record("a", true);
        }
        assert_eq!(reg.state("a"), BreakerState::Closed);
    }

    #[test]
    fn breaker_half_open_probe_closes_on_success() {
        let reg = BreakerRegistry::new(breaker_cfg(0)); // immediate cooldown
        for _ in 0..4 {
            reg.record("a", true);
        }
        assert_eq!(reg.state("a"), BreakerState::Open);
        assert_eq!(reg.admit("a"), BreakerDecision::Probe, "cooldown elapsed");
        assert_eq!(reg.state("a"), BreakerState::HalfOpen);
        // A second request while the probe is in flight still fails fast.
        assert_eq!(reg.admit("a"), BreakerDecision::Open);
        assert_eq!(reg.record("a", false), Some(BreakerState::Closed));
        assert_eq!(reg.state("a"), BreakerState::Closed);
        assert_eq!(reg.admit("a"), BreakerDecision::Allow);
        assert_eq!(reg.half_open_probes(), 1);
        let kinds: Vec<BreakerState> = reg.events().iter().map(|e| e.to).collect();
        assert_eq!(
            kinds,
            vec![
                BreakerState::Open,
                BreakerState::HalfOpen,
                BreakerState::Closed
            ]
        );
    }

    #[test]
    fn breaker_half_open_probe_failure_reopens() {
        let reg = BreakerRegistry::new(breaker_cfg(0));
        for _ in 0..4 {
            reg.record("a", true);
        }
        assert_eq!(reg.admit("a"), BreakerDecision::Probe);
        assert_eq!(reg.record("a", true), Some(BreakerState::Open));
        assert_eq!(reg.state("a"), BreakerState::Open);
        assert_eq!(reg.opens(), 2);
    }

    #[test]
    fn breaker_open_cooldown_gates_the_probe() {
        let reg = BreakerRegistry::new(breaker_cfg(10_000));
        for _ in 0..4 {
            reg.record("a", true);
        }
        assert_eq!(reg.admit("a"), BreakerDecision::Open, "inside cooldown");
        assert_eq!(reg.state("a"), BreakerState::Open);
    }

    // -- brownout --

    fn brownout_cfg() -> BrownoutConfig {
        BrownoutConfig {
            shed_rate_engage: 0.2,
            shed_rate_recover: 0.05,
            p99_engage_us: u64::MAX,
            engage_evals: 2,
            recover_evals: 2,
            eval_interval_ms: 100,
        }
    }

    #[test]
    fn brownout_engages_one_rung_per_sustained_streak() {
        let b = BrownoutController::new(brownout_cfg());
        let hot = rates(100, 50);
        let mut now = 0;
        assert_eq!(b.evaluate(&hot, 0, now), 0, "one pressured tick holds");
        now += 100;
        assert_eq!(b.evaluate(&hot, 0, now), 1, "second tick engages");
        assert!(!b.allow_probes());
        assert!(b.allow_tracing());
        assert!(b.allow_reuse_inserts());
        for _ in 0..6 {
            now += 100;
            b.evaluate(&hot, 0, now);
        }
        assert_eq!(b.level(), BROWNOUT_MAX_LEVEL, "ladder saturates");
        assert!(!b.allow_tracing());
        assert!(!b.allow_reuse_inserts());
    }

    #[test]
    fn brownout_recovers_in_reverse_under_sustained_calm() {
        let b = BrownoutController::new(brownout_cfg());
        let hot = rates(100, 50);
        let calm = rates(100, 0);
        let mut now = 0;
        for _ in 0..8 {
            now += 100;
            b.evaluate(&hot, 0, now);
        }
        assert_eq!(b.level(), 3);
        let mut levels = vec![];
        for _ in 0..12 {
            now += 100;
            levels.push(b.evaluate(&calm, 0, now));
        }
        assert_eq!(b.level(), 0, "fully recovered");
        let mut sorted = levels.clone();
        sorted.sort_unstable_by(|a, b| b.cmp(a));
        assert_eq!(levels, sorted, "recovery steps down monotonically");
        let ts = b.transitions();
        assert!(ts.len() >= 6, "3 up + 3 down transitions recorded");
    }

    #[test]
    fn brownout_between_thresholds_holds_level() {
        let b = BrownoutController::new(brownout_cfg());
        let hot = rates(100, 50);
        let middling = rates(100, 10); // 0.10: between recover and engage
        let mut now = 0;
        for _ in 0..4 {
            now += 100;
            b.evaluate(&hot, 0, now);
        }
        let level = b.level();
        assert!(level >= 1);
        for _ in 0..10 {
            now += 100;
            b.evaluate(&middling, 0, now);
        }
        assert_eq!(b.level(), level, "held between thresholds");
    }

    #[test]
    fn brownout_p99_signal_engages() {
        let b = BrownoutController::new(BrownoutConfig {
            p99_engage_us: 1_000,
            ..brownout_cfg()
        });
        let calm = rates(100, 0);
        assert_eq!(b.evaluate(&calm, 5_000, 100), 0);
        assert_eq!(b.evaluate(&calm, 5_000, 200), 1, "p99 pressure engages");
        assert_eq!(b.evaluate(&calm, 10, 300), 1);
        assert_eq!(b.evaluate(&calm, 10, 400), 0, "p99 calm recovers");
    }

    #[test]
    fn brownout_rate_limits_evaluations() {
        let b = BrownoutController::new(brownout_cfg());
        let hot = rates(100, 50);
        // Many evaluations within one interval count as one tick.
        for now in [100, 110, 120, 130, 140] {
            b.evaluate(&hot, 0, now);
        }
        assert_eq!(b.level(), 0, "streak needs two *spaced* ticks");
        b.evaluate(&hot, 0, 250);
        assert_eq!(b.level(), 1);
    }
}
