//! Coordinator metrics: selection counts, fallbacks, latency distribution,
//! throughput. Lock-free-enough (atomics + a mutex-guarded latency buffer).

use crate::selector::SelectionReason;
use crate::util::stats::percentile;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Shared metrics sink.
#[derive(Default)]
pub struct CoordinatorMetrics {
    pub requests: AtomicU64,
    pub completed: AtomicU64,
    pub failed: AtomicU64,
    pub selected_nt: AtomicU64,
    pub selected_tnn: AtomicU64,
    pub memory_fallbacks: AtomicU64,
    /// Selections dictated by `RouterConfig::force` (MTNN bypassed).
    /// Forced traffic still counts toward the per-algorithm NT/TNN split
    /// (those are execution counts); this counter is what lets a reader
    /// tell a forced baseline run from genuine MTNN predictions.
    pub forced: AtomicU64,
    latencies_us: Mutex<Vec<f64>>,
}

/// Point-in-time snapshot for reporting.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub completed: u64,
    pub failed: u64,
    pub selected_nt: u64,
    pub selected_tnn: u64,
    pub memory_fallbacks: u64,
    pub forced: u64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    pub mean_us: f64,
}

impl CoordinatorMetrics {
    pub fn record_selection(&self, algo: crate::gemm::Algorithm, reason: SelectionReason) {
        match algo {
            crate::gemm::Algorithm::Nt => self.selected_nt.fetch_add(1, Ordering::Relaxed),
            crate::gemm::Algorithm::Tnn => self.selected_tnn.fetch_add(1, Ordering::Relaxed),
            crate::gemm::Algorithm::Nn => 0,
        };
        match reason {
            SelectionReason::MemoryFallback => {
                self.memory_fallbacks.fetch_add(1, Ordering::Relaxed);
            }
            SelectionReason::Forced => {
                self.forced.fetch_add(1, Ordering::Relaxed);
            }
            SelectionReason::PredictedNt | SelectionReason::PredictedTnn => {}
        }
    }

    pub fn record_latency_us(&self, us: f64) {
        self.latencies_us.lock().unwrap().push(us);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let lat = self.latencies_us.lock().unwrap();
        let mean = if lat.is_empty() {
            f64::NAN
        } else {
            lat.iter().sum::<f64>() / lat.len() as f64
        };
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            selected_nt: self.selected_nt.load(Ordering::Relaxed),
            selected_tnn: self.selected_tnn.load(Ordering::Relaxed),
            memory_fallbacks: self.memory_fallbacks.load(Ordering::Relaxed),
            forced: self.forced.load(Ordering::Relaxed),
            p50_us: percentile(&lat, 50.0),
            p95_us: percentile(&lat, 95.0),
            p99_us: percentile(&lat, 99.0),
            mean_us: mean,
        }
    }
}

impl MetricsSnapshot {
    pub fn render(&self) -> String {
        format!(
            "requests={} completed={} failed={} | NT={} TNN={} fallback={} forced={} | \
             latency p50={:.0}us p95={:.0}us p99={:.0}us mean={:.0}us",
            self.requests,
            self.completed,
            self.failed,
            self.selected_nt,
            self.selected_tnn,
            self.memory_fallbacks,
            self.forced,
            self.p50_us,
            self.p95_us,
            self.p99_us,
            self.mean_us
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::Algorithm;

    #[test]
    fn selection_counters() {
        let m = CoordinatorMetrics::default();
        m.record_selection(Algorithm::Nt, SelectionReason::PredictedNt);
        m.record_selection(Algorithm::Tnn, SelectionReason::PredictedTnn);
        m.record_selection(Algorithm::Nt, SelectionReason::MemoryFallback);
        let s = m.snapshot();
        assert_eq!(s.selected_nt, 2);
        assert_eq!(s.selected_tnn, 1);
        assert_eq!(s.memory_fallbacks, 1);
        assert_eq!(s.forced, 0);
    }

    #[test]
    fn forced_selections_counted_separately() {
        let m = CoordinatorMetrics::default();
        m.record_selection(Algorithm::Tnn, SelectionReason::Forced);
        m.record_selection(Algorithm::Nt, SelectionReason::Forced);
        let s = m.snapshot();
        assert_eq!(s.forced, 2);
        assert_eq!(s.memory_fallbacks, 0);
        // Forced traffic still counts toward the per-algorithm split.
        assert_eq!(s.selected_nt, 1);
        assert_eq!(s.selected_tnn, 1);
        assert!(s.render().contains("forced=2"), "{}", s.render());
    }

    #[test]
    fn latency_percentiles() {
        let m = CoordinatorMetrics::default();
        for i in 1..=100 {
            m.record_latency_us(i as f64);
        }
        let s = m.snapshot();
        assert!((s.p50_us - 50.5).abs() < 1.0);
        assert!(s.p99_us > 98.0);
        assert!(s.render().contains("p50"));
    }

    #[test]
    fn empty_latencies_are_nan_not_panic() {
        let s = CoordinatorMetrics::default().snapshot();
        assert!(s.p50_us.is_nan());
        assert!(s.mean_us.is_nan());
    }
}
