//! Coordinator metrics: selection counts, fallbacks, admission-control
//! rejections, per-worker queue-depth gauges, and latency percentiles from
//! a lock-free fixed-bucket histogram — nothing on the hot path takes a
//! lock or allocates (the pre-pool implementation pushed every latency
//! into a `Mutex<Vec<f64>>`, which serialized concurrent clients exactly
//! where the worker pool is supposed to let them scale).

use super::reuse::ReuseStats;
use crate::obs::{ObsLayer, ObsSnapshot};
use crate::selector::SelectionReason;
use crate::util::json::Json;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Histogram buckets: 4 linear sub-buckets per power of two of
/// microseconds (~19% relative resolution), 256 buckets covering the full
/// `u64` µs range.
pub const BUCKETS: usize = 256;

/// Bucket for a latency in whole microseconds. Monotone in `us`.
pub fn bucket_index(us: u64) -> usize {
    if us < 4 {
        return us as usize;
    }
    let l = 63 - us.leading_zeros() as usize; // floor(log2), >= 2
    let sub = ((us >> (l - 2)) & 3) as usize;
    ((l - 1) * 4 + sub).min(BUCKETS - 1)
}

/// Inclusive lower bound of bucket `i`, in µs.
pub fn bucket_lower(i: usize) -> u64 {
    if i < 4 {
        return i as u64;
    }
    let l = i / 4 + 1;
    let sub = (i % 4) as u64;
    (4 + sub) << (l - 2)
}

/// Width of bucket `i`, in µs.
pub fn bucket_width(i: usize) -> u64 {
    if i < 4 {
        1
    } else {
        1u64 << (i / 4 - 1)
    }
}

/// Estimate the `q`-th percentile from bucket counts: find the bucket
/// holding the rank, interpolate linearly inside it, and clamp to the
/// observed maximum (interpolation can overshoot in a sparse top bucket).
pub fn percentile_of(counts: &[u64], total: u64, max_us: u64, q: f64) -> f64 {
    let rank = ((q / 100.0) * total as f64).ceil().max(1.0) as u64;
    let mut cum = 0u64;
    for (i, &c) in counts.iter().enumerate() {
        if c == 0 {
            continue;
        }
        if cum + c >= rank {
            let into = (rank - cum) as f64 / c as f64;
            let est = bucket_lower(i) as f64 + into * bucket_width(i) as f64;
            return est.min(max_us as f64);
        }
        cum += c;
    }
    max_us as f64
}

/// Lock-free latency histogram (µs). Recording is a few relaxed atomic
/// adds; percentile queries copy the counts once and walk them.
pub struct LatencyHistogram {
    counts: Box<[AtomicU64]>,
    sum_ns: AtomicU64,
    max_us: AtomicU64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram {
            counts: (0..BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            sum_ns: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }
}

impl LatencyHistogram {
    pub fn record_us(&self, us: f64) {
        let us_u = if us.is_finite() && us > 0.0 {
            us.round() as u64
        } else {
            0
        };
        self.counts[bucket_index(us_u)].fetch_add(1, Ordering::Relaxed);
        self.sum_ns
            .fetch_add((us.max(0.0) * 1e3) as u64, Ordering::Relaxed);
        self.max_us.fetch_max(us_u, Ordering::Relaxed);
    }

    /// `(p50, p95, p99, mean)` in µs; all NaN when empty.
    pub fn summary(&self) -> (f64, f64, f64, f64) {
        let counts: Vec<u64> = self
            .counts
            .iter()
            .map(|c| c.load(Ordering::Relaxed))
            .collect();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return (f64::NAN, f64::NAN, f64::NAN, f64::NAN);
        }
        let max_us = self.max_us.load(Ordering::Relaxed);
        let mean = self.sum_ns.load(Ordering::Relaxed) as f64 / 1e3 / total as f64;
        (
            percentile_of(&counts, total, max_us, 50.0),
            percentile_of(&counts, total, max_us, 95.0),
            percentile_of(&counts, total, max_us, 99.0),
            mean,
        )
    }

    /// Total recorded observations.
    pub fn count(&self) -> u64 {
        self.counts.iter().map(|c| c.load(Ordering::Relaxed)).sum()
    }

    /// Largest observation, in whole µs (0 when empty).
    pub fn max_observed_us(&self) -> u64 {
        self.max_us.load(Ordering::Relaxed)
    }

    /// Sum of observations, in µs.
    pub fn sum_us(&self) -> f64 {
        self.sum_ns.load(Ordering::Relaxed) as f64 / 1e3
    }

    /// Cumulative histogram points for exposition: `(upper_bound_us,
    /// count ≤ upper_bound)` for every bucket holding at least one
    /// observation, ascending. Upper bounds are exclusive bucket edges
    /// (`lower + width`), i.e. Prometheus `le` boundaries.
    pub fn bucket_points(&self) -> Vec<(u64, u64)> {
        let mut points = Vec::new();
        let mut cum = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            let n = c.load(Ordering::Relaxed);
            if n == 0 {
                continue;
            }
            cum += n;
            points.push((bucket_lower(i).saturating_add(bucket_width(i)), cum));
        }
        points
    }
}

/// Per-worker micro-batch gauge, owned by the engine pool and attached to
/// the metrics sink by `Router::new` (mirrors the queue-depth gauges).
#[derive(Debug, Default)]
pub struct BatchGauge {
    /// Micro-batches executed.
    pub batches: AtomicU64,
    /// Jobs executed (sum of batch sizes).
    pub jobs: AtomicU64,
    /// Largest batch observed.
    pub max: AtomicU64,
}

/// Shared metrics sink.
#[derive(Default)]
pub struct CoordinatorMetrics {
    pub requests: AtomicU64,
    pub completed: AtomicU64,
    /// Requests that errored for any reason *other* than admission
    /// control (backend error, injected fault, engine shutdown mid-job,
    /// a circuit breaker failing fast, or a retry budget exhausting on a
    /// transient error).
    pub failed: AtomicU64,
    /// Requests that ran out of their deadline budget — at admission, in
    /// a queue (dropped without executing), or waiting on a response.
    /// Disjoint from `failed` and `shed`: a fourth way to resolve.
    pub timed_out: AtomicU64,
    /// Retry *attempts* made after a transient failure (a request that
    /// retried twice counts 2). Not part of conservation — attempts are
    /// not requests.
    pub retries: AtomicU64,
    /// Requests whose transient failures outlived their retry budget (or
    /// deadline) and resolved as `failed`.
    pub retries_exhausted: AtomicU64,
    /// Circuit-breaker trip events (Closed/HalfOpen → Open transitions).
    pub breaker_opens: AtomicU64,
    /// Half-open probe admissions (trial requests let through a cooling
    /// breaker).
    pub breaker_half_open_probes: AtomicU64,
    /// Gauge: the brownout degradation ladder's current level (0 =
    /// healthy … 3 = max degradation).
    pub brownout_level: AtomicU64,
    /// Requests a caller lost to admission control: every worker queue
    /// was full and the router was configured to fail fast, so the
    /// caller saw `EngineBusy`. Disjoint from `failed` — together with
    /// `completed` they partition every resolved request, which is what
    /// [`MetricsSnapshot::verify_conservation`] checks.
    pub shed: AtomicU64,
    /// Admission-control rejections observed at submit time (`EngineBusy`
    /// from every worker queue). Kept as its own counter — `shed` counts
    /// the request outcome, this counts the submit-path event — so the
    /// two can diverge if a future router retries rejected submissions.
    pub busy_rejections: AtomicU64,
    pub selected_nt: AtomicU64,
    pub selected_tnn: AtomicU64,
    pub memory_fallbacks: AtomicU64,
    /// Selections dictated by `RouterConfig::force` (MTNN bypassed).
    /// Forced traffic still counts toward the per-algorithm NT/TNN split
    /// (those are execution counts); this counter is what lets a reader
    /// tell a forced baseline run from genuine MTNN predictions.
    pub forced: AtomicU64,
    // ---- online adaptive-selection loop (`crate::online`) ----
    /// Telemetry samples accepted into the online sample ring.
    pub online_samples: AtomicU64,
    /// Telemetry samples dropped because the ring was full.
    pub online_dropped: AtomicU64,
    /// Shadow probes served (both algorithms executed and timed).
    pub shadow_probes: AtomicU64,
    /// Probe decisions fired by the adaptive drift-interpolated schedule.
    pub probes_scheduled: AtomicU64,
    /// Probe decisions fired by the UCB exploration floor (the schedule
    /// had declined the request).
    pub probes_bandit: AtomicU64,
    /// Probe decisions (scheduled or floor) denied by the per-GPU probe
    /// budget (`OnlineConfig::probe_budget`).
    pub probes_budget_denied: AtomicU64,
    /// Gauge: the effective probe interval (1-in-N) in force when the
    /// adaptive schedule last fired a probe; 0 until the first scheduled
    /// probe. Written only on scheduled fires, so declined hot-path
    /// requests never dirty this cacheline. Per-bucket intervals differ —
    /// this reports the last-probed bucket's, not a fleet aggregate.
    pub probe_interval_gauge: AtomicU64,
    /// Shadow probes whose measured winner contradicted the prediction.
    pub shadow_mispredicts: AtomicU64,
    /// Background retrain attempts.
    pub retrains: AtomicU64,
    /// Retrains whose challenger beat the incumbent and was hot-swapped in.
    pub promotions: AtomicU64,
    /// Retrains whose challenger lost (or tied) and was discarded.
    pub rollbacks: AtomicU64,
    latency: LatencyHistogram,
    /// Engine worker queue-depth gauges, attached by `Router::new`.
    worker_depths: Mutex<Option<Arc<Vec<AtomicU64>>>>,
    /// Engine worker micro-batch gauges, attached by `Router::new`.
    batch_gauges: Mutex<Option<Arc<Vec<BatchGauge>>>>,
    /// Cross-request reuse counters (`coordinator::reuse`), attached by
    /// `Router::new` when the engine has the layer enabled.
    reuse_stats: Mutex<Option<Arc<ReuseStats>>>,
    /// Observability layer (`crate::obs`), attached by `Router::new`
    /// when the router config carries one; embedded in snapshots for
    /// the Prometheus/JSON exposition.
    obs: Mutex<Option<Arc<ObsLayer>>>,
}

/// Point-in-time snapshot for reporting.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub completed: u64,
    pub failed: u64,
    /// Requests lost to admission control (caller saw `EngineBusy`);
    /// disjoint from `failed`.
    pub shed: u64,
    /// Requests that ran out of their deadline (admission, in-queue, or
    /// awaiting a response); disjoint from `failed` and `shed`.
    pub timed_out: u64,
    /// Retry attempts after transient failures (not part of conservation).
    pub retries: u64,
    /// Requests whose retry budget exhausted on transient failures.
    pub retries_exhausted: u64,
    /// Circuit-breaker trip events (transitions to Open).
    pub breaker_opens: u64,
    /// Half-open probe admissions.
    pub breaker_half_open_probes: u64,
    /// Brownout ladder level at snapshot time (0 = healthy).
    pub brownout_level: u64,
    pub busy_rejections: u64,
    pub selected_nt: u64,
    pub selected_tnn: u64,
    pub memory_fallbacks: u64,
    pub forced: u64,
    pub online_samples: u64,
    pub online_dropped: u64,
    pub shadow_probes: u64,
    /// Probe decisions from the adaptive schedule vs the UCB floor.
    pub probes_scheduled: u64,
    pub probes_bandit: u64,
    /// Probe decisions denied by the per-GPU probe budget.
    pub probes_budget_denied: u64,
    /// The effective probe interval (1-in-N) at the last *scheduled*
    /// probe (0 until one fires). Per-bucket intervals differ; this is
    /// the last-probed bucket's.
    pub probe_interval: u64,
    /// `1 / probe_interval` — the inverse of the last scheduled interval.
    /// NOT the realized probe fraction: it excludes the epsilon bandit
    /// floor and per-bucket variation (compute `shadow_probes / requests`
    /// for that, as `serve_gemm --online` does).
    pub probe_rate: f64,
    pub shadow_mispredicts: u64,
    /// `shadow_mispredicts / shadow_probes` (NaN when no probes ran).
    pub mispredict_rate: f64,
    pub retrains: u64,
    pub promotions: u64,
    pub rollbacks: u64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    pub mean_us: f64,
    /// Per-worker in-flight counts at snapshot time (empty when no engine
    /// gauges are attached).
    pub worker_depths: Vec<u64>,
    /// Mean micro-batch size across the pool (NaN before any batch ran or
    /// when no engine gauges are attached).
    pub avg_batch: f64,
    /// Largest micro-batch any worker executed.
    pub max_batch: u64,
    // ---- cross-request reuse (`coordinator::reuse`); all zero when the
    // ---- layer is absent or disabled ----
    /// Submissions answered straight from the output cache.
    pub reuse_hits: u64,
    /// Submissions coalesced onto an in-flight identical execution.
    pub reuse_coalesced: u64,
    /// Submissions that led a single-flight group (executed for real).
    pub reuse_misses: u64,
    /// Results inserted into the output cache.
    pub reuse_inserts: u64,
    /// Cached results evicted by the LRU capacity bound.
    pub reuse_evictions: u64,
    /// Leader completions not cached because an invalidation landed
    /// while they were in flight.
    pub reuse_stale_drops: u64,
    /// Submissions that bypassed the layer via a deny prefix.
    pub reuse_bypasses: u64,
    /// Coalesced followers whose single-flight leader failed: they
    /// resolved as failures without executing. Subset-adjacent to
    /// `failed` at the router level, distinct from ordinary failures so
    /// shed accounting under chaos is attributable.
    pub reuse_coalesced_failed: u64,
    /// End-to-end latency histogram as cumulative `(upper_us, count)`
    /// exposition points (non-empty buckets only).
    pub latency_buckets: Vec<(u64, u64)>,
    pub latency_count: u64,
    pub latency_sum_us: f64,
    /// Observability-layer view (tracing, windows, regret, flight
    /// recorder); `None` when no layer is attached.
    pub obs: Option<ObsSnapshot>,
}

impl CoordinatorMetrics {
    pub fn record_selection(&self, algo: crate::gemm::Algorithm, reason: SelectionReason) {
        match algo {
            crate::gemm::Algorithm::Nt => self.selected_nt.fetch_add(1, Ordering::Relaxed),
            crate::gemm::Algorithm::Tnn => self.selected_tnn.fetch_add(1, Ordering::Relaxed),
            crate::gemm::Algorithm::Nn => 0,
        };
        match reason {
            SelectionReason::MemoryFallback => {
                self.memory_fallbacks.fetch_add(1, Ordering::Relaxed);
            }
            SelectionReason::Forced => {
                self.forced.fetch_add(1, Ordering::Relaxed);
            }
            SelectionReason::PredictedNt | SelectionReason::PredictedTnn => {}
        }
    }

    pub fn record_latency_us(&self, us: f64) {
        self.latency.record_us(us);
    }

    /// Wire the engine pool's per-worker depth gauges into snapshots.
    pub fn attach_worker_depths(&self, gauges: Arc<Vec<AtomicU64>>) {
        *self.worker_depths.lock().unwrap() = Some(gauges);
    }

    /// Wire the engine pool's per-worker micro-batch gauges into snapshots.
    pub fn attach_batch_gauges(&self, gauges: Arc<Vec<BatchGauge>>) {
        *self.batch_gauges.lock().unwrap() = Some(gauges);
    }

    /// Wire the engine's reuse-layer counters into snapshots.
    pub fn attach_reuse(&self, stats: Arc<ReuseStats>) {
        *self.reuse_stats.lock().unwrap() = Some(stats);
    }

    /// Wire the observability layer into snapshots.
    pub fn attach_obs(&self, obs: Arc<ObsLayer>) {
        *self.obs.lock().unwrap() = Some(obs);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let (p50_us, p95_us, p99_us, mean_us) = self.latency.summary();
        let worker_depths = self
            .worker_depths
            .lock()
            .unwrap()
            .as_ref()
            .map(|g| g.iter().map(|d| d.load(Ordering::Relaxed)).collect())
            .unwrap_or_default();
        let (avg_batch, max_batch) = self
            .batch_gauges
            .lock()
            .unwrap()
            .as_ref()
            .map(|gauges| {
                let mut batches = 0u64;
                let mut jobs = 0u64;
                let mut max = 0u64;
                for g in gauges.iter() {
                    batches += g.batches.load(Ordering::Relaxed);
                    jobs += g.jobs.load(Ordering::Relaxed);
                    max = max.max(g.max.load(Ordering::Relaxed));
                }
                let avg = if batches == 0 {
                    f64::NAN
                } else {
                    jobs as f64 / batches as f64
                };
                (avg, max)
            })
            .unwrap_or((f64::NAN, 0));
        let shadow_probes = self.shadow_probes.load(Ordering::Relaxed);
        let shadow_mispredicts = self.shadow_mispredicts.load(Ordering::Relaxed);
        let probe_interval = self.probe_interval_gauge.load(Ordering::Relaxed);
        let reuse = self.reuse_stats.lock().unwrap();
        let ld = |c: &AtomicU64| c.load(Ordering::Relaxed);
        let (
            reuse_hits,
            reuse_coalesced,
            reuse_misses,
            reuse_inserts,
            reuse_evictions,
            reuse_stale_drops,
            reuse_bypasses,
            reuse_coalesced_failed,
        ) = reuse
            .as_ref()
            .map(|r| {
                (
                    ld(&r.hits),
                    ld(&r.coalesced),
                    ld(&r.misses),
                    ld(&r.inserts),
                    ld(&r.evictions),
                    ld(&r.stale_drops),
                    ld(&r.bypasses),
                    ld(&r.coalesced_failed),
                )
            })
            .unwrap_or_default();
        drop(reuse);
        let obs = self.obs.lock().unwrap().as_ref().map(|o| o.snapshot());
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            timed_out: self.timed_out.load(Ordering::Relaxed),
            retries: self.retries.load(Ordering::Relaxed),
            retries_exhausted: self.retries_exhausted.load(Ordering::Relaxed),
            breaker_opens: self.breaker_opens.load(Ordering::Relaxed),
            breaker_half_open_probes: self.breaker_half_open_probes.load(Ordering::Relaxed),
            brownout_level: self.brownout_level.load(Ordering::Relaxed),
            busy_rejections: self.busy_rejections.load(Ordering::Relaxed),
            selected_nt: self.selected_nt.load(Ordering::Relaxed),
            selected_tnn: self.selected_tnn.load(Ordering::Relaxed),
            memory_fallbacks: self.memory_fallbacks.load(Ordering::Relaxed),
            forced: self.forced.load(Ordering::Relaxed),
            online_samples: self.online_samples.load(Ordering::Relaxed),
            online_dropped: self.online_dropped.load(Ordering::Relaxed),
            shadow_probes,
            probes_scheduled: self.probes_scheduled.load(Ordering::Relaxed),
            probes_bandit: self.probes_bandit.load(Ordering::Relaxed),
            probes_budget_denied: self.probes_budget_denied.load(Ordering::Relaxed),
            probe_interval,
            probe_rate: if probe_interval == 0 {
                0.0
            } else {
                1.0 / probe_interval as f64
            },
            shadow_mispredicts,
            mispredict_rate: if shadow_probes == 0 {
                f64::NAN
            } else {
                shadow_mispredicts as f64 / shadow_probes as f64
            },
            retrains: self.retrains.load(Ordering::Relaxed),
            promotions: self.promotions.load(Ordering::Relaxed),
            rollbacks: self.rollbacks.load(Ordering::Relaxed),
            p50_us,
            p95_us,
            p99_us,
            mean_us,
            worker_depths,
            avg_batch,
            max_batch,
            reuse_hits,
            reuse_coalesced,
            reuse_misses,
            reuse_inserts,
            reuse_evictions,
            reuse_stale_drops,
            reuse_bypasses,
            reuse_coalesced_failed,
            latency_buckets: self.latency.bucket_points(),
            latency_count: self.latency.count(),
            latency_sum_us: self.latency.sum_us(),
            obs,
        }
    }
}

impl MetricsSnapshot {
    /// The conservation invariant the chaos tests assert at quiescence:
    /// every submitted request resolved exactly one way —
    /// `completed + failed + shed + timed_out == requests`. Only
    /// meaningful once no serve call is in flight (a mid-flight request
    /// has been counted in `requests` but not yet resolved).
    pub fn verify_conservation(&self) -> Result<(), String> {
        let resolved = self.completed + self.failed + self.shed + self.timed_out;
        if resolved == self.requests {
            Ok(())
        } else {
            Err(format!(
                "conservation violated: completed={} + failed={} + shed={} + timed_out={} = {resolved} != requests={}",
                self.completed, self.failed, self.shed, self.timed_out, self.requests
            ))
        }
    }

    pub fn render(&self) -> String {
        let mut s = format!(
            "requests={} completed={} failed={} shed={} timed_out={} busy={} | NT={} TNN={} fallback={} forced={} | \
             latency p50={:.0}us p95={:.0}us p99={:.0}us mean={:.0}us | queues={:?} | \
             batch avg={:.2} max={}",
            self.requests,
            self.completed,
            self.failed,
            self.shed,
            self.timed_out,
            self.busy_rejections,
            self.selected_nt,
            self.selected_tnn,
            self.memory_fallbacks,
            self.forced,
            self.p50_us,
            self.p95_us,
            self.p99_us,
            self.mean_us,
            self.worker_depths,
            self.avg_batch,
            self.max_batch,
        );
        // The online section only appears once the loop is active, so
        // offline reports stay as terse as before.
        if self.online_samples > 0 || self.shadow_probes > 0 || self.retrains > 0 {
            let rate = if self.mispredict_rate.is_finite() {
                format!("{:.1}%", self.mispredict_rate * 100.0)
            } else {
                "n/a".to_string() // no probes yet — don't print NaN%
            };
            s.push_str(&format!(
                " | online samples={} dropped={} probes={} (sched={} bandit={} budget_denied={}) \
                 probe_interval={} mispredicts={} rate={rate} \
                 retrains={} promotions={} rollbacks={}",
                self.online_samples,
                self.online_dropped,
                self.shadow_probes,
                self.probes_scheduled,
                self.probes_bandit,
                self.probes_budget_denied,
                self.probe_interval,
                self.shadow_mispredicts,
                self.retrains,
                self.promotions,
                self.rollbacks,
            ));
        }
        // The lifecycle section only appears once retries, breakers, or
        // brownout have actually engaged, so steady-state reports stay
        // terse.
        if self.retries
            + self.retries_exhausted
            + self.breaker_opens
            + self.breaker_half_open_probes
            + self.brownout_level
            > 0
        {
            s.push_str(&format!(
                " | lifecycle retries={} exhausted={} breaker_opens={} \
                 half_open_probes={} brownout_level={}",
                self.retries,
                self.retries_exhausted,
                self.breaker_opens,
                self.breaker_half_open_probes,
                self.brownout_level,
            ));
        }
        // The reuse section only appears once the layer has seen traffic,
        // so reports from engines without it stay unchanged.
        if self.reuse_hits + self.reuse_coalesced + self.reuse_misses + self.reuse_bypasses > 0 {
            s.push_str(&format!(
                " | reuse hits={} coalesced={} misses={} inserts={} evictions={} \
                 stale_drops={} bypasses={} coalesced_failed={}",
                self.reuse_hits,
                self.reuse_coalesced,
                self.reuse_misses,
                self.reuse_inserts,
                self.reuse_evictions,
                self.reuse_stale_drops,
                self.reuse_bypasses,
                self.reuse_coalesced_failed,
            ));
        }
        s
    }

    /// Render the snapshot in Prometheus text exposition format 0.0.4.
    /// Counters end in `_total`; the end-to-end latency histogram and
    /// the per-stage per-algorithm attribution histograms emit
    /// cumulative `_bucket{le=…}` series plus `_sum`/`_count`; windowed
    /// rates, queue depths, and the regret gauge are gauges. This is
    /// the body a future `/metrics` endpoint returns verbatim.
    pub fn render_prometheus(&self) -> String {
        fn counter_into(out: &mut String, name: &str, help: &str, v: u64) {
            out.push_str(&format!(
                "# HELP {name} {help}\n# TYPE {name} counter\n{name} {v}\n"
            ));
        }
        fn gauge_into(out: &mut String, name: &str, help: &str, v: f64) {
            out.push_str(&format!(
                "# HELP {name} {help}\n# TYPE {name} gauge\n{name} {v}\n"
            ));
        }
        let mut out = String::with_capacity(4096);
        counter_into(
            &mut out,
            "mtnn_requests_total",
            "Requests entering the router.",
            self.requests,
        );
        counter_into(
            &mut out,
            "mtnn_completed_total",
            "Requests that completed successfully.",
            self.completed,
        );
        counter_into(
            &mut out,
            "mtnn_failed_total",
            "Requests that failed (non-admission errors).",
            self.failed,
        );
        counter_into(
            &mut out,
            "mtnn_shed_total",
            "Requests shed by admission control.",
            self.shed,
        );
        counter_into(
            &mut out,
            "mtnn_timed_out_total",
            "Requests that exhausted their deadline budget.",
            self.timed_out,
        );
        counter_into(
            &mut out,
            "mtnn_retries_total",
            "Retry attempts after transient failures.",
            self.retries,
        );
        counter_into(
            &mut out,
            "mtnn_retries_exhausted_total",
            "Requests whose retry budget exhausted on transient failures.",
            self.retries_exhausted,
        );
        counter_into(
            &mut out,
            "mtnn_breaker_opens_total",
            "Circuit-breaker trip events (transitions to Open).",
            self.breaker_opens,
        );
        counter_into(
            &mut out,
            "mtnn_breaker_half_open_probes_total",
            "Half-open probe admissions through a cooling breaker.",
            self.breaker_half_open_probes,
        );
        gauge_into(
            &mut out,
            "mtnn_brownout_level",
            "Brownout degradation ladder level (0 = healthy).",
            self.brownout_level as f64,
        );
        counter_into(
            &mut out,
            "mtnn_busy_rejections_total",
            "Submit-path EngineBusy rejections.",
            self.busy_rejections,
        );
        out.push_str(
            "# HELP mtnn_selected_total Algorithm selections by the router.\n\
             # TYPE mtnn_selected_total counter\n",
        );
        out.push_str(&format!(
            "mtnn_selected_total{{algo=\"nt\"}} {}\n",
            self.selected_nt
        ));
        out.push_str(&format!(
            "mtnn_selected_total{{algo=\"tnn\"}} {}\n",
            self.selected_tnn
        ));
        counter_into(
            &mut out,
            "mtnn_memory_fallbacks_total",
            "Selections forced to NT by the workspace memory cap.",
            self.memory_fallbacks,
        );
        counter_into(
            &mut out,
            "mtnn_forced_total",
            "Selections dictated by RouterConfig::force.",
            self.forced,
        );
        counter_into(
            &mut out,
            "mtnn_shadow_probes_total",
            "Shadow probes served (both algorithms executed).",
            self.shadow_probes,
        );
        counter_into(
            &mut out,
            "mtnn_shadow_mispredicts_total",
            "Shadow probes whose measured winner contradicted the prediction.",
            self.shadow_mispredicts,
        );
        counter_into(
            &mut out,
            "mtnn_retrains_total",
            "Background retrain attempts.",
            self.retrains,
        );
        counter_into(
            &mut out,
            "mtnn_promotions_total",
            "Retrains promoted via hot-swap.",
            self.promotions,
        );
        if self.reuse_hits + self.reuse_coalesced + self.reuse_misses + self.reuse_bypasses > 0 {
            counter_into(
                &mut out,
                "mtnn_reuse_hits_total",
                "Submissions answered from the output cache.",
                self.reuse_hits,
            );
            counter_into(
                &mut out,
                "mtnn_reuse_coalesced_total",
                "Submissions coalesced onto an in-flight execution.",
                self.reuse_coalesced,
            );
            counter_into(
                &mut out,
                "mtnn_reuse_coalesced_failed_total",
                "Coalesced followers resolved as failures by a failed leader.",
                self.reuse_coalesced_failed,
            );
        }
        // End-to-end latency histogram.
        out.push_str(
            "# HELP mtnn_request_latency_us End-to-end request latency in microseconds.\n\
             # TYPE mtnn_request_latency_us histogram\n",
        );
        for &(upper, cum) in &self.latency_buckets {
            out.push_str(&format!(
                "mtnn_request_latency_us_bucket{{le=\"{upper}\"}} {cum}\n"
            ));
        }
        out.push_str(&format!(
            "mtnn_request_latency_us_bucket{{le=\"+Inf\"}} {}\n",
            self.latency_count
        ));
        out.push_str(&format!(
            "mtnn_request_latency_us_sum {}\nmtnn_request_latency_us_count {}\n",
            self.latency_sum_us, self.latency_count
        ));
        // Worker queue depth gauges.
        if !self.worker_depths.is_empty() {
            out.push_str(
                "# HELP mtnn_worker_queue_depth In-flight jobs per engine worker.\n\
                 # TYPE mtnn_worker_queue_depth gauge\n",
            );
            for (i, d) in self.worker_depths.iter().enumerate() {
                out.push_str(&format!(
                    "mtnn_worker_queue_depth{{worker=\"{i}\"}} {d}\n"
                ));
            }
        }
        if let Some(obs) = &self.obs {
            counter_into(
                &mut out,
                "mtnn_spans_recorded_total",
                "Completed trace spans accepted by the span ring.",
                obs.spans_recorded,
            );
            counter_into(
                &mut out,
                "mtnn_spans_dropped_total",
                "Completed trace spans dropped (ring full).",
                obs.spans_dropped,
            );
            counter_into(
                &mut out,
                "mtnn_flight_dumps_total",
                "Flight-recorder dumps captured.",
                obs.recorder_dumps,
            );
            // Per-stage per-algorithm attribution histograms.
            out.push_str(
                "# HELP mtnn_stage_latency_us Per-stage per-algorithm latency in microseconds.\n\
                 # TYPE mtnn_stage_latency_us histogram\n",
            );
            for st in &self.stages_nonempty() {
                let labels = format!("stage=\"{}\",algo=\"{}\"", st.stage, st.algo);
                for &(upper, cum) in &st.buckets {
                    out.push_str(&format!(
                        "mtnn_stage_latency_us_bucket{{{labels},le=\"{upper}\"}} {cum}\n"
                    ));
                }
                out.push_str(&format!(
                    "mtnn_stage_latency_us_bucket{{{labels},le=\"+Inf\"}} {}\n",
                    st.count
                ));
                out.push_str(&format!(
                    "mtnn_stage_latency_us_sum{{{labels}}} {}\n",
                    st.sum_us
                ));
                out.push_str(&format!(
                    "mtnn_stage_latency_us_count{{{labels}}} {}\n",
                    st.count
                ));
            }
            // Windowed rates.
            let w = &obs.window;
            gauge_into(
                &mut out,
                "mtnn_window_req_per_s",
                "Requests per second over the rate window.",
                w.req_per_s,
            );
            gauge_into(
                &mut out,
                "mtnn_window_shed_rate",
                "Shed fraction over the rate window.",
                w.shed_rate,
            );
            gauge_into(
                &mut out,
                "mtnn_window_reuse_hit_rate",
                "Reuse-hit fraction of completions over the rate window.",
                w.reuse_hit_rate,
            );
            gauge_into(
                &mut out,
                "mtnn_window_probe_rate",
                "Shadow-probe fraction over the rate window.",
                w.probe_rate,
            );
            gauge_into(
                &mut out,
                "mtnn_window_mispredict_rate",
                "Mispredict fraction of probes over the rate window.",
                w.mispredict_rate,
            );
            gauge_into(
                &mut out,
                "mtnn_regret_mean_us",
                "Mean shadow-probe regret (served minus winner latency) in microseconds.",
                obs.regret_mean_us,
            );
            gauge_into(
                &mut out,
                "mtnn_regret_last_us",
                "Most recent shadow-probe regret in microseconds.",
                obs.regret_last_us as f64,
            );
        }
        out
    }

    fn stages_nonempty(&self) -> Vec<crate::obs::StageStats> {
        self.obs
            .as_ref()
            .map(|o| o.stages.iter().filter(|s| s.count > 0).cloned().collect())
            .unwrap_or_default()
    }

    /// The same snapshot as a JSON object (see `util::json`). NaN
    /// values (empty percentiles) serialize as null.
    pub fn render_json(&self) -> Json {
        let mut j = Json::obj()
            .set("requests", self.requests)
            .set("completed", self.completed)
            .set("failed", self.failed)
            .set("shed", self.shed)
            .set("timed_out", self.timed_out)
            .set("retries", self.retries)
            .set("retries_exhausted", self.retries_exhausted)
            .set("breaker_opens", self.breaker_opens)
            .set("breaker_half_open_probes", self.breaker_half_open_probes)
            .set("brownout_level", self.brownout_level)
            .set("busy_rejections", self.busy_rejections)
            .set("selected_nt", self.selected_nt)
            .set("selected_tnn", self.selected_tnn)
            .set("memory_fallbacks", self.memory_fallbacks)
            .set("forced", self.forced)
            .set("shadow_probes", self.shadow_probes)
            .set("shadow_mispredicts", self.shadow_mispredicts)
            .set("retrains", self.retrains)
            .set("promotions", self.promotions)
            .set("rollbacks", self.rollbacks)
            .set("p50_us", self.p50_us)
            .set("p95_us", self.p95_us)
            .set("p99_us", self.p99_us)
            .set("mean_us", self.mean_us)
            .set("latency_count", self.latency_count)
            .set(
                "worker_depths",
                Json::Arr(self.worker_depths.iter().map(|&d| Json::from(d)).collect()),
            )
            .set("reuse_hits", self.reuse_hits)
            .set("reuse_coalesced", self.reuse_coalesced)
            .set("reuse_coalesced_failed", self.reuse_coalesced_failed)
            .set("reuse_misses", self.reuse_misses);
        if let Some(obs) = &self.obs {
            let w = &obs.window;
            j = j.set(
                "obs",
                Json::obj()
                    .set("spans_begun", obs.spans_begun)
                    .set("spans_recorded", obs.spans_recorded)
                    .set("spans_dropped", obs.spans_dropped)
                    .set("recorder_triggered", obs.recorder_triggered)
                    .set("recorder_dumps", obs.recorder_dumps)
                    .set("regret_count", obs.regret_count)
                    .set("regret_mean_us", obs.regret_mean_us)
                    .set("regret_last_us", obs.regret_last_us)
                    .set(
                        "window",
                        Json::obj()
                            .set("window_secs", w.window_secs)
                            .set("req_per_s", w.req_per_s)
                            .set("shed_rate", w.shed_rate)
                            .set("reuse_hit_rate", w.reuse_hit_rate)
                            .set("probe_rate", w.probe_rate)
                            .set("mispredict_rate", w.mispredict_rate),
                    )
                    .set(
                        "stages",
                        Json::Arr(
                            self.stages_nonempty()
                                .iter()
                                .map(|st| {
                                    Json::obj()
                                        .set("stage", st.stage)
                                        .set("algo", st.algo)
                                        .set("count", st.count)
                                        .set("p50_us", st.p50_us)
                                        .set("p95_us", st.p95_us)
                                        .set("p99_us", st.p99_us)
                                        .set("mean_us", st.mean_us)
                                })
                                .collect(),
                        ),
                    ),
            );
        }
        j
    }
}

/// Fleet-wide conservation roll-up. Each device in a fleet owns its own
/// `CoordinatorMetrics`, so per-device conservation is just that
/// device's [`MetricsSnapshot::verify_conservation`]; this accumulator
/// sums outcome counters across devices and checks the widened
/// invariant `Σ completed + Σ failed + Σ shed + Σ timed_out ==
/// Σ requests` fleet-wide. A request double-counted across devices (or
/// dropped between placement and dispatch) violates the sum even when
/// every individual device balances.
#[derive(Debug, Default, Clone, Copy)]
pub struct ConservationTotals {
    pub requests: u64,
    pub completed: u64,
    pub failed: u64,
    pub shed: u64,
    pub timed_out: u64,
}

impl ConservationTotals {
    /// Fold one device's snapshot into the fleet totals.
    pub fn absorb(&mut self, s: &MetricsSnapshot) {
        self.requests += s.requests;
        self.completed += s.completed;
        self.failed += s.failed;
        self.shed += s.shed;
        self.timed_out += s.timed_out;
    }

    /// Fleet-wide conservation at quiescence; same caveat as the
    /// per-device check (only meaningful with no serve call in flight).
    pub fn verify_conservation(&self) -> Result<(), String> {
        let resolved = self.completed + self.failed + self.shed + self.timed_out;
        if resolved == self.requests {
            Ok(())
        } else {
            Err(format!(
                "fleet conservation violated: completed={} + failed={} + shed={} + timed_out={} = {resolved} != requests={}",
                self.completed, self.failed, self.shed, self.timed_out, self.requests
            ))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::Algorithm;

    #[test]
    fn selection_counters() {
        let m = CoordinatorMetrics::default();
        m.record_selection(Algorithm::Nt, SelectionReason::PredictedNt);
        m.record_selection(Algorithm::Tnn, SelectionReason::PredictedTnn);
        m.record_selection(Algorithm::Nt, SelectionReason::MemoryFallback);
        let s = m.snapshot();
        assert_eq!(s.selected_nt, 2);
        assert_eq!(s.selected_tnn, 1);
        assert_eq!(s.memory_fallbacks, 1);
        assert_eq!(s.forced, 0);
    }

    #[test]
    fn forced_selections_counted_separately() {
        let m = CoordinatorMetrics::default();
        m.record_selection(Algorithm::Tnn, SelectionReason::Forced);
        m.record_selection(Algorithm::Nt, SelectionReason::Forced);
        let s = m.snapshot();
        assert_eq!(s.forced, 2);
        assert_eq!(s.memory_fallbacks, 0);
        // Forced traffic still counts toward the per-algorithm split.
        assert_eq!(s.selected_nt, 1);
        assert_eq!(s.selected_tnn, 1);
        assert!(s.render().contains("forced=2"), "{}", s.render());
    }

    #[test]
    fn latency_percentiles() {
        let m = CoordinatorMetrics::default();
        for i in 1..=100 {
            m.record_latency_us(i as f64);
        }
        let s = m.snapshot();
        assert!((s.p50_us - 50.5).abs() < 4.0, "p50={}", s.p50_us);
        assert!(s.p99_us > 98.0, "p99={}", s.p99_us);
        assert!(s.p99_us <= 100.0, "p99 clamps to the observed max");
        assert!((s.mean_us - 50.5).abs() < 0.1, "mean={}", s.mean_us);
        assert!(s.render().contains("p50"));
    }

    #[test]
    fn empty_latencies_are_nan_not_panic() {
        let s = CoordinatorMetrics::default().snapshot();
        assert!(s.p50_us.is_nan());
        assert!(s.mean_us.is_nan());
    }

    #[test]
    fn histogram_buckets_partition_the_axis() {
        // Every value lands in exactly the bucket whose [lower, lower+width)
        // range contains it, and indices are monotone.
        let mut prev = 0usize;
        for us in 0..100_000u64 {
            let i = bucket_index(us);
            assert!(i >= prev, "monotone: us={us} i={i} prev={prev}");
            assert!(
                bucket_lower(i) <= us && us < bucket_lower(i) + bucket_width(i),
                "us={us} i={i} lower={} width={}",
                bucket_lower(i),
                bucket_width(i)
            );
            prev = i;
        }
        // The top bucket absorbs everything up to u64::MAX without panic.
        assert!(bucket_index(u64::MAX) < BUCKETS);
    }

    #[test]
    fn percentiles_respect_bucket_resolution() {
        let m = CoordinatorMetrics::default();
        // A single value: every percentile is (approximately) it.
        m.record_latency_us(1000.0);
        let s = m.snapshot();
        for p in [s.p50_us, s.p95_us, s.p99_us] {
            assert!((p - 1000.0).abs() / 1000.0 < 0.25, "p={p}");
        }
    }

    #[test]
    fn busy_rejections_render() {
        let m = CoordinatorMetrics::default();
        m.busy_rejections.fetch_add(3, Ordering::Relaxed);
        assert!(m.snapshot().render().contains("busy=3"));
    }

    #[test]
    fn shed_counts_separately_and_renders() {
        let m = CoordinatorMetrics::default();
        m.shed.fetch_add(2, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.shed, 2);
        assert_eq!(s.failed, 0, "shed requests are not failures");
        assert!(s.render().contains("shed=2"), "{}", s.render());
    }

    #[test]
    fn conservation_partitions_resolved_requests() {
        let m = CoordinatorMetrics::default();
        m.requests.fetch_add(10, Ordering::Relaxed);
        m.completed.fetch_add(5, Ordering::Relaxed);
        m.failed.fetch_add(3, Ordering::Relaxed);
        m.timed_out.fetch_add(1, Ordering::Relaxed);
        assert!(m.snapshot().verify_conservation().is_err(), "one unresolved");
        m.shed.fetch_add(1, Ordering::Relaxed);
        m.snapshot().verify_conservation().unwrap();
        // A double-counted outcome breaks it from the other side.
        m.completed.fetch_add(1, Ordering::Relaxed);
        let err = m.snapshot().verify_conservation().unwrap_err();
        assert!(err.contains("completed=6"), "{err}");
        assert!(err.contains("timed_out=1"), "{err}");
    }

    #[test]
    fn fleet_totals_absorb_per_device_snapshots() {
        let a = CoordinatorMetrics::default();
        a.requests.fetch_add(4, Ordering::Relaxed);
        a.completed.fetch_add(3, Ordering::Relaxed);
        a.shed.fetch_add(1, Ordering::Relaxed);
        let b = CoordinatorMetrics::default();
        b.requests.fetch_add(2, Ordering::Relaxed);
        b.failed.fetch_add(1, Ordering::Relaxed);
        b.timed_out.fetch_add(1, Ordering::Relaxed);
        let mut fleet = ConservationTotals::default();
        fleet.absorb(&a.snapshot());
        fleet.absorb(&b.snapshot());
        assert_eq!(fleet.requests, 6);
        fleet.verify_conservation().unwrap();
        // An extra unresolved request on either device breaks the sum
        // fleet-wide even though it is a per-device imbalance.
        b.requests.fetch_add(1, Ordering::Relaxed);
        let mut broken = ConservationTotals::default();
        broken.absorb(&a.snapshot());
        broken.absorb(&b.snapshot());
        let err = broken.verify_conservation().unwrap_err();
        assert!(err.contains("fleet conservation"), "{err}");
    }

    #[test]
    fn lifecycle_counters_flow_through_every_renderer() {
        let m = CoordinatorMetrics::default();
        let terse = m.snapshot().render();
        assert!(terse.contains("timed_out=0"), "{terse}");
        assert!(
            !terse.contains("lifecycle"),
            "quiet lifecycle stays out of the report: {terse}"
        );
        m.timed_out.fetch_add(2, Ordering::Relaxed);
        m.retries.fetch_add(5, Ordering::Relaxed);
        m.retries_exhausted.fetch_add(1, Ordering::Relaxed);
        m.breaker_opens.fetch_add(1, Ordering::Relaxed);
        m.breaker_half_open_probes.fetch_add(1, Ordering::Relaxed);
        m.brownout_level.store(2, Ordering::Relaxed);
        let s = m.snapshot();
        let r = s.render();
        for needle in [
            "timed_out=2",
            "retries=5",
            "exhausted=1",
            "breaker_opens=1",
            "half_open_probes=1",
            "brownout_level=2",
        ] {
            assert!(r.contains(needle), "missing {needle} in {r}");
        }
        let prom = s.render_prometheus();
        for needle in [
            "# TYPE mtnn_timed_out_total counter\nmtnn_timed_out_total 2\n",
            "# TYPE mtnn_retries_total counter\nmtnn_retries_total 5\n",
            "mtnn_retries_exhausted_total 1\n",
            "mtnn_breaker_opens_total 1\n",
            "mtnn_breaker_half_open_probes_total 1\n",
            "# TYPE mtnn_brownout_level gauge\nmtnn_brownout_level 2\n",
        ] {
            assert!(prom.contains(needle), "missing {needle:?} in:\n{prom}");
        }
        let j = s.render_json();
        assert_eq!(j.get("timed_out").and_then(|v| v.as_usize()), Some(2));
        assert_eq!(j.get("retries").and_then(|v| v.as_usize()), Some(5));
        assert_eq!(j.get("breaker_opens").and_then(|v| v.as_usize()), Some(1));
        assert_eq!(j.get("brownout_level").and_then(|v| v.as_usize()), Some(2));
    }

    #[test]
    fn batch_gauges_aggregate_avg_and_max() {
        let m = CoordinatorMetrics::default();
        let s = m.snapshot();
        assert!(s.avg_batch.is_nan(), "no gauges attached yet");
        assert_eq!(s.max_batch, 0);
        let gauges = Arc::new(vec![BatchGauge::default(), BatchGauge::default()]);
        m.attach_batch_gauges(Arc::clone(&gauges));
        assert!(m.snapshot().avg_batch.is_nan(), "no batches ran yet");
        // Worker 0: two batches of 4 and 2; worker 1: one batch of 6.
        gauges[0].batches.fetch_add(2, Ordering::Relaxed);
        gauges[0].jobs.fetch_add(6, Ordering::Relaxed);
        gauges[0].max.fetch_max(4, Ordering::Relaxed);
        gauges[1].batches.fetch_add(1, Ordering::Relaxed);
        gauges[1].jobs.fetch_add(6, Ordering::Relaxed);
        gauges[1].max.fetch_max(6, Ordering::Relaxed);
        let s = m.snapshot();
        assert!((s.avg_batch - 4.0).abs() < 1e-12, "avg={}", s.avg_batch);
        assert_eq!(s.max_batch, 6);
        assert!(s.render().contains("batch avg=4.00 max=6"), "{}", s.render());
    }

    #[test]
    fn online_counters_render_only_when_active() {
        let m = CoordinatorMetrics::default();
        assert!(
            !m.snapshot().render().contains("online"),
            "offline reports stay terse"
        );
        m.shadow_probes.fetch_add(4, Ordering::Relaxed);
        m.probes_scheduled.fetch_add(3, Ordering::Relaxed);
        m.probes_bandit.fetch_add(1, Ordering::Relaxed);
        m.probes_budget_denied.fetch_add(2, Ordering::Relaxed);
        m.probe_interval_gauge.store(16, Ordering::Relaxed);
        m.shadow_mispredicts.fetch_add(1, Ordering::Relaxed);
        m.retrains.fetch_add(2, Ordering::Relaxed);
        m.promotions.fetch_add(1, Ordering::Relaxed);
        m.rollbacks.fetch_add(1, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.shadow_probes, 4);
        assert_eq!(s.probes_scheduled, 3);
        assert_eq!(s.probes_bandit, 1);
        assert_eq!(s.probes_budget_denied, 2);
        assert_eq!(s.probe_interval, 16);
        assert!((s.probe_rate - 1.0 / 16.0).abs() < 1e-12);
        assert!((s.mispredict_rate - 0.25).abs() < 1e-12);
        let r = s.render();
        for needle in [
            "probes=4",
            "sched=3",
            "bandit=1",
            "budget_denied=2",
            "probe_interval=16",
            "mispredicts=1",
            "rate=25.0%",
            "retrains=2",
            "promotions=1",
            "rollbacks=1",
        ] {
            assert!(r.contains(needle), "missing {needle} in {r}");
        }
    }

    #[test]
    fn probe_rate_is_zero_before_any_online_request() {
        let s = CoordinatorMetrics::default().snapshot();
        assert_eq!(s.probe_interval, 0);
        assert_eq!(s.probe_rate, 0.0);
    }

    #[test]
    fn mispredict_rate_is_nan_without_probes() {
        let s = CoordinatorMetrics::default().snapshot();
        assert!(s.mispredict_rate.is_nan());
    }

    #[test]
    fn reuse_counters_render_only_when_active() {
        let m = CoordinatorMetrics::default();
        assert!(
            !m.snapshot().render().contains("reuse"),
            "no-reuse reports stay terse"
        );
        let stats = Arc::new(ReuseStats::default());
        m.attach_reuse(Arc::clone(&stats));
        assert!(
            !m.snapshot().render().contains("reuse"),
            "attached but idle: still terse"
        );
        stats.hits.fetch_add(5, Ordering::Relaxed);
        stats.coalesced.fetch_add(3, Ordering::Relaxed);
        stats.misses.fetch_add(2, Ordering::Relaxed);
        stats.inserts.fetch_add(2, Ordering::Relaxed);
        stats.stale_drops.fetch_add(1, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.reuse_hits, 5);
        assert_eq!(s.reuse_coalesced, 3);
        assert_eq!(s.reuse_misses, 2);
        assert_eq!(s.reuse_inserts, 2);
        assert_eq!(s.reuse_stale_drops, 1);
        let r = s.render();
        for needle in ["reuse hits=5", "coalesced=3", "misses=2", "stale_drops=1"] {
            assert!(r.contains(needle), "missing {needle} in {r}");
        }
    }

    #[test]
    fn worker_depth_gauges_appear_in_snapshots() {
        let m = CoordinatorMetrics::default();
        assert!(m.snapshot().worker_depths.is_empty());
        let gauges = Arc::new(vec![AtomicU64::new(2), AtomicU64::new(0)]);
        m.attach_worker_depths(Arc::clone(&gauges));
        assert_eq!(m.snapshot().worker_depths, vec![2, 0]);
        gauges[1].fetch_add(5, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.worker_depths, vec![2, 5]);
        assert!(s.render().contains("queues=[2, 5]"), "{}", s.render());
    }

    #[test]
    fn coalesced_failed_snapshots_and_renders() {
        let m = CoordinatorMetrics::default();
        let stats = Arc::new(ReuseStats::default());
        m.attach_reuse(Arc::clone(&stats));
        stats.coalesced.fetch_add(4, Ordering::Relaxed);
        stats.coalesced_failed.fetch_add(2, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.reuse_coalesced_failed, 2);
        assert!(
            s.render().contains("coalesced_failed=2"),
            "{}",
            s.render()
        );
    }

    #[test]
    fn bucket_points_are_cumulative_at_bucket_edges() {
        let h = LatencyHistogram::default();
        h.record_us(3.0);
        h.record_us(3.0);
        h.record_us(100.0);
        assert_eq!(h.count(), 3);
        let pts = h.bucket_points();
        assert_eq!(pts.len(), 2, "two non-empty buckets");
        assert_eq!(pts[0], (4, 2), "value 3 lives in [3,4)");
        assert_eq!(pts[1].1, 3, "last point is the total count");
        assert!(pts[0].0 < pts[1].0, "upper bounds ascend");
        assert!(bucket_lower(bucket_index(100)) < pts[1].0);
    }

    #[test]
    fn prometheus_render_is_well_formed() {
        let m = CoordinatorMetrics::default();
        m.requests.fetch_add(7, Ordering::Relaxed);
        m.completed.fetch_add(7, Ordering::Relaxed);
        m.record_latency_us(120.0);
        m.record_latency_us(140.0);
        let text = m.snapshot().render_prometheus();
        for needle in [
            "# TYPE mtnn_requests_total counter\nmtnn_requests_total 7\n",
            "# TYPE mtnn_request_latency_us histogram\n",
            "mtnn_request_latency_us_bucket{le=\"+Inf\"} 2\n",
            "mtnn_request_latency_us_count 2\n",
            "mtnn_selected_total{algo=\"nt\"} 0\n",
        ] {
            assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
        }
        // Every non-comment line is `name{labels} value` or `name value`.
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            let (name, value) = line.rsplit_once(' ').expect(line);
            assert!(!name.is_empty() && value.parse::<f64>().is_ok(), "{line}");
        }
    }

    #[test]
    fn prometheus_render_includes_obs_sections_when_attached() {
        let m = CoordinatorMetrics::default();
        let obs = Arc::new(ObsLayer::new(crate::obs::ObsConfig::default()));
        m.attach_obs(Arc::clone(&obs));
        obs.mark_request();
        obs.record_regret(150, 100);
        let text = m.snapshot().render_prometheus();
        for needle in [
            "# TYPE mtnn_window_req_per_s gauge\n",
            "# TYPE mtnn_regret_mean_us gauge\nmtnn_regret_mean_us 50\n",
            "mtnn_spans_recorded_total 0\n",
        ] {
            assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
        }
    }

    #[test]
    fn json_render_carries_core_and_obs_fields() {
        let m = CoordinatorMetrics::default();
        m.requests.fetch_add(3, Ordering::Relaxed);
        let j = m.snapshot().render_json();
        assert_eq!(j.get("requests").and_then(|v| v.as_usize()), Some(3));
        assert!(j.get("obs").is_none(), "no obs layer attached");
        m.attach_obs(Arc::new(ObsLayer::new(crate::obs::ObsConfig::default())));
        let j = m.snapshot().render_json();
        assert!(j.get("obs").is_some());
        let rendered = j.to_pretty();
        assert!(rendered.contains("\"window\""), "{rendered}");
    }
}
