//! Data-collection pipeline (§V.A of the paper): benchmark NT and TNN over
//! the size grid on each GPU, apply the memory-fit rule, attach the GPU's
//! five characteristics, and emit labeled records
//! `(gm, sm, cc, mbw, l2c, m, n, k) → label`.

use crate::gpusim::{GpuSpec, Simulator, PAPER_GPUS};
use crate::ml::data::Dataset;
use crate::util::csv::CsvTable;

/// One benchmarked case with its label and both measured performances
/// (the performances are kept so the selection experiments — GOW / LUB,
/// Table VIII — can be computed without re-running the sweep).
#[derive(Debug, Clone, PartialEq)]
pub struct Record {
    pub gpu: String,
    /// The paper's 5 GPU features (gm, sm, cc, mbw, l2c).
    pub gpu_features: [f64; 5],
    pub m: u64,
    pub n: u64,
    pub k: u64,
    /// GFLOPS of each algorithm on this case.
    pub p_nn: f64,
    pub p_nt: f64,
    pub p_tnn: f64,
    /// +1 ⇔ P_NT ≥ P_TNN (choose NT); −1 ⇔ choose TNN.
    pub label: i8,
}

impl Record {
    /// The 8-dimensional MTNN input vector.
    pub fn features(&self) -> Vec<f64> {
        let g = &self.gpu_features;
        vec![
            g[0], g[1], g[2], g[3], g[4], self.m as f64, self.n as f64, self.k as f64,
        ]
    }
}

/// Benchmark one GPU (the paper's per-GPU sweep of §V.A).
pub fn collect_gpu(sim: &Simulator) -> Vec<Record> {
    let spec = sim.spec();
    sim.sweep()
        .into_iter()
        .map(|c| Record {
            gpu: spec.name.to_string(),
            gpu_features: spec.features(),
            m: c.m,
            n: c.n,
            k: c.k,
            p_nn: c.p_nn,
            p_nt: c.p_nt,
            p_tnn: c.p_tnn,
            label: c.label(),
        })
        .collect()
}

/// The paper's full two-GPU dataset (Table II: 891 + ~941 records).
pub fn collect_paper_dataset() -> Vec<Record> {
    let mut out = Vec::new();
    for gpu in PAPER_GPUS {
        out.extend(collect_gpu(&Simulator::new(gpu)));
    }
    out
}

/// Convert records to an ML dataset (8 features, ±1 labels, grouped by GPU
/// so splits can stratify per GPU as the paper does).
pub fn to_ml_dataset(records: &[Record]) -> Dataset {
    let mut d = Dataset::new();
    for r in records {
        d.push(r.features(), r.label as f64, gpu_group_id(&r.gpu));
    }
    d
}

fn gpu_group_id(name: &str) -> u64 {
    GpuSpec::by_name(name).map(|g| g.id).unwrap_or(0)
}

// ---- CSV persistence -------------------------------------------------------

const COLS: [&str; 12] = [
    "gpu", "gm", "sm", "cc", "mbw", "l2c", "m", "n", "k", "p_nt", "p_tnn", "label",
];

/// Save records to CSV (schema documented in DESIGN.md §7).
pub fn save_csv(records: &[Record], path: impl AsRef<std::path::Path>) -> std::io::Result<()> {
    let mut t = CsvTable::new(&COLS);
    for r in records {
        t.push_row(vec![
            r.gpu.clone(),
            format!("{}", r.gpu_features[0]),
            format!("{}", r.gpu_features[1]),
            format!("{}", r.gpu_features[2]),
            format!("{}", r.gpu_features[3]),
            format!("{}", r.gpu_features[4]),
            r.m.to_string(),
            r.n.to_string(),
            r.k.to_string(),
            format!("{:.6}", r.p_nt),
            format!("{:.6}", r.p_tnn),
            r.label.to_string(),
        ]);
    }
    t.save(path)
}

/// Load records back (p_nn is not persisted; it is reconstructable from the
/// simulator and unused by the selection experiments).
pub fn load_csv(path: impl AsRef<std::path::Path>) -> anyhow::Result<Vec<Record>> {
    let t = CsvTable::load(path)?;
    for c in COLS {
        anyhow::ensure!(t.col(c).is_some(), "missing column {c}");
    }
    let mut out = Vec::with_capacity(t.rows.len());
    for i in 0..t.rows.len() {
        let f = |name: &str| -> anyhow::Result<f64> {
            t.get_f64(i, name)
                .ok_or_else(|| anyhow::anyhow!("row {i}: bad {name}"))
        };
        out.push(Record {
            gpu: t.get(i, "gpu").unwrap().to_string(),
            gpu_features: [f("gm")?, f("sm")?, f("cc")?, f("mbw")?, f("l2c")?],
            m: f("m")? as u64,
            n: f("n")? as u64,
            k: f("k")? as u64,
            p_nn: f64::NAN,
            p_nt: f("p_nt")?,
            p_tnn: f("p_tnn")?,
            label: if f("label")? >= 0.0 { 1 } else { -1 },
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::{GTX1080, TITANX};

    #[test]
    fn collection_counts_match_table2() {
        let data = collect_paper_dataset();
        let gtx = data.iter().filter(|r| r.gpu == "GTX1080").count();
        let titan = data.iter().filter(|r| r.gpu == "TitanX").count();
        assert_eq!(gtx, 891);
        assert!((930..=945).contains(&titan));
        // Paper total: 1832; ours is 891 + 937 = 1828 (see EXPERIMENTS.md).
        assert!((1820..=1836).contains(&data.len()));
    }

    #[test]
    fn labels_match_performance_ordering() {
        for r in collect_gpu(&Simulator::new(&GTX1080)).iter().take(200) {
            assert_eq!(r.label == 1, r.p_nt >= r.p_tnn, "{r:?}");
        }
    }

    #[test]
    fn features_are_8d_and_o1() {
        let r = &collect_gpu(&Simulator::new(&TITANX))[0];
        let f = r.features();
        assert_eq!(f.len(), 8);
        assert_eq!(f[0], 10.0); // gm
        assert_eq!(f[4], 3072.0); // l2c
        assert_eq!(f[5], r.m as f64);
    }

    #[test]
    fn ml_dataset_groups_by_gpu() {
        let data = collect_paper_dataset();
        let d = to_ml_dataset(&data);
        assert_eq!(d.len(), data.len());
        let g1 = d.group.iter().filter(|&&g| g == 1).count();
        assert_eq!(g1, 891);
    }

    #[test]
    fn csv_roundtrip() {
        let records = collect_gpu(&Simulator::new(&GTX1080));
        let path = std::env::temp_dir().join("mtnn_dataset_test.csv");
        save_csv(&records, &path).unwrap();
        let back = load_csv(&path).unwrap();
        assert_eq!(back.len(), records.len());
        for (a, b) in records.iter().zip(&back) {
            assert_eq!(a.gpu, b.gpu);
            assert_eq!((a.m, a.n, a.k), (b.m, b.n, b.k));
            assert_eq!(a.label, b.label);
            assert!((a.p_nt - b.p_nt).abs() < 1e-3);
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn load_rejects_missing_columns() {
        let path = std::env::temp_dir().join("mtnn_dataset_bad.csv");
        std::fs::write(&path, "gpu,m\nGTX1080,128\n").unwrap();
        assert!(load_csv(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
