//! `mtnn` — leader entrypoint / CLI for the MTNN reproduction.
//!
//! Subcommands:
//!   collect     benchmark the simulated GPUs and write the labeled dataset
//!   train       train the GBDT selector from a dataset CSV and save it
//!   predict     one Algorithm-2 selection for (gpu, m, n, k)
//!   calibrate   print the simulator-vs-paper calibration report
//!   pipeline    run the full paper reproduction (all tables/figures)
//!   info        show artifact catalog + runtime status

use mtnn::dataset;
use mtnn::experiments;
use mtnn::gpusim::{calib, GpuSpec, Simulator, GTX1080, PAPER_GPUS};
use mtnn::runtime::Runtime;
use mtnn::selector::Selector;
use mtnn::util::cli::Args;

fn usage() -> ! {
    eprintln!(
        "usage: mtnn <collect|train|predict|calibrate|pipeline|info> [options]\n\
         \n\
         mtnn collect   [--out results/samples.csv]\n\
         mtnn train     [--data results/samples.csv] [--out results/mtnn_selector.json]\n\
         mtnn predict   --m M --n N --k K [--gpu gtx1080] [--model results/mtnn_selector.json]\n\
         mtnn calibrate\n\
         mtnn pipeline\n\
         mtnn info      [--artifacts <dir>]"
    );
    std::process::exit(2);
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env(true);
    match args.subcommand.as_deref() {
        Some("collect") => {
            let out = args.get("out", "results/samples.csv");
            args.finish()?;
            let records = dataset::collect_paper_dataset();
            dataset::save_csv(&records, &out)?;
            for gpu in PAPER_GPUS {
                let n = records.iter().filter(|r| r.gpu == gpu.name).count();
                let neg = records
                    .iter()
                    .filter(|r| r.gpu == gpu.name && r.label == -1)
                    .count();
                println!("{:>8}: {n} samples ({neg} × label -1, {} × label +1)", gpu.name, n - neg);
            }
            println!("wrote {} records to {out}", records.len());
        }
        Some("train") => {
            let data = args.get("data", "results/samples.csv");
            let out = args.get("out", "results/mtnn_selector.json");
            args.finish()?;
            let records = if std::path::Path::new(&data).exists() {
                dataset::load_csv(&data)?
            } else {
                println!("{data} not found — collecting fresh");
                dataset::collect_paper_dataset()
            };
            let selector = Selector::train_default(&records);
            selector.save(&out)?;
            println!("trained GBDT selector on {} samples → {out}", records.len());
        }
        Some("predict") => {
            let m: u64 = args.get_num("m", 0);
            let n: u64 = args.get_num("n", 0);
            let k: u64 = args.get_num("k", 0);
            let gpu_name = args.get("gpu", "gtx1080");
            let model = args.opt("model");
            args.finish()?;
            if m == 0 || n == 0 || k == 0 {
                usage();
            }
            let gpu: &'static GpuSpec =
                GpuSpec::by_name(&gpu_name).unwrap_or_else(|| usage());
            let selector = match model {
                Some(path) => Selector::load(path)?,
                None => Selector::train_default(&dataset::collect_paper_dataset()),
            };
            let (algo, reason) = selector.select(gpu, m, n, k);
            let sim = Simulator::new(if gpu.id == GTX1080.id { &GTX1080 } else { gpu });
            let c = sim.time_case(m, n, k);
            println!(
                "{} {m}x{n} k={k} → {} ({reason:?}); simulated P_NT={:.0} P_TNN={:.0} GFLOPS",
                gpu.name,
                algo.name(),
                c.p_nt,
                c.p_tnn
            );
        }
        Some("calibrate") => {
            args.finish()?;
            for gpu in PAPER_GPUS {
                let sim = Simulator::new(gpu);
                let (_, targets) = calib::report(&sim);
                println!("{}", calib::render_report(gpu.name, &targets));
            }
        }
        Some("pipeline") => {
            args.finish()?;
            let records = dataset::collect_paper_dataset();
            let selector = Selector::train_default(&records);
            let (f1, _) = experiments::fig1::run();
            experiments::emit("fig1_nn_vs_nt.txt", &f1);
            let (f23, _) = experiments::fig23::run();
            experiments::emit("fig2_fig3_table2.txt", &f23);
            experiments::emit("table4_table6_fig4.txt", &experiments::classifiers::run(42));
            experiments::emit("fig5_fig6_table8.txt", &experiments::mtnn_eval::run(&selector));
            experiments::emit(
                "fig7_fig8_table9_table10.txt",
                &experiments::fcn_eval::run(&selector),
            );
        }
        Some("info") => {
            let dir = args.get(
                "artifacts",
                Runtime::default_dir().to_string_lossy().as_ref(),
            );
            args.finish()?;
            let rt = Runtime::new(&dir)?;
            println!("platform: {}", rt.platform());
            println!("artifacts ({}):", rt.manifest.entries.len());
            for (name, e) in &rt.manifest.entries {
                println!(
                    "  {name:<28} {} inputs, {} outputs",
                    e.inputs.len(),
                    e.n_outputs
                );
            }
        }
        _ => usage(),
    }
    Ok(())
}
