//! # MTNN — supervised-learning-based algorithm selection for DNN GEMM
//!
//! A full reproduction of *"Supervised Learning Based Algorithm Selection
//! for Deep Neural Networks"* (Shi, Xu, Chu — 2017) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **L1** (`python/compile/kernels/`) — Pallas kernels for the tiled NN
//!   matmul, the direct NT matmul, and the out-of-place transpose.
//! * **L2** (`python/compile/model.py`) — the FCN forward/backward/train
//!   step in JAX, AOT-lowered to HLO text artifacts.
//! * **L3** (this crate) — the coordination contribution: the MTNN
//!   selector (GBDT trained on GPU features + matrix sizes) and the GEMM
//!   service built as a decision layer over a pluggable execution layer —
//!   a sharded engine worker pool ([`coordinator::Engine`]) whose workers
//!   each own an [`coordinator::ExecBackend`] (PJRT runtime, native
//!   blocked CPU kernels — SIMD micro-kernels fed by packed panels and
//!   striped across a persistent worker pool ([`gemm::kernels`],
//!   [`gemm::pool`]) — or the deterministic GPU-timing simulator) and
//!   micro-batch same-artifact jobs and steal work when idle — plus the
//!   online adaptive-selection loop ([`online`]: runtime telemetry,
//!   shadow probing, drift detection, background GBDT retraining with
//!   atomic model hot-swap), the adversarial workload lab ([`workload`]:
//!   seeded trace generation, replay, and chaos injection against the
//!   serving stack), and the experiment harness reproducing every table
//!   and figure of the paper.
//!
//! See `DESIGN.md` for the system inventory and experiment index.

pub mod coordinator;
pub mod dataset;
pub mod experiments;
pub mod fcn;
pub mod gemm;
pub mod gpusim;
pub mod ml;
pub mod obs;
pub mod online;
pub mod runtime;
pub mod selector;
pub mod testutil;
pub mod util;
pub mod workload;
