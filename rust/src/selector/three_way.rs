//! Three-way selection — the paper's §VII future work, implemented:
//! choose among {direct NT, TNN with out-of-place transpose, TNN with
//! in-place transpose}. The in-place variant needs no Bᵀ buffer, so it
//! extends TNN-class wins into the memory region where the 2-way MTNN is
//! forced back to NT.
//!
//! Architecture: two binary GBDTs in a gate/variant cascade —
//! `gate` predicts "direct NT vs any TNN" (the paper's original label),
//! `variant` predicts "out-of-place vs in-place" among TNN-better cases —
//! keeping each learner exactly the paper's model class.

use crate::gpusim::{GpuSpec, Simulator, PAPER_GPUS};
use crate::ml::gbdt::{Gbdt, GbdtParams};
use crate::ml::Classifier;

/// The three implementations.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ThreeWay {
    Nt,
    TnnOutOfPlace,
    TnnInPlace,
}

impl ThreeWay {
    pub fn name(self) -> &'static str {
        match self {
            ThreeWay::Nt => "NT",
            ThreeWay::TnnOutOfPlace => "TNN-oop",
            ThreeWay::TnnInPlace => "TNN-ip",
        }
    }
}

/// Simulated timings of all three implementations for one case.
#[derive(Debug, Clone, Copy)]
pub struct Case3 {
    pub m: u64,
    pub n: u64,
    pub k: u64,
    pub t_nt: f64,
    /// None when Bᵀ does not fit.
    pub t_tnn_oop: Option<f64>,
    pub t_tnn_ip: f64,
}

impl Case3 {
    pub fn best(&self) -> ThreeWay {
        let mut best = (ThreeWay::Nt, self.t_nt);
        if let Some(t) = self.t_tnn_oop {
            if t < best.1 {
                best = (ThreeWay::TnnOutOfPlace, t);
            }
        }
        if self.t_tnn_ip < best.1 {
            best = (ThreeWay::TnnInPlace, self.t_tnn_ip);
        }
        best.0
    }

    pub fn time_of(&self, algo: ThreeWay) -> Option<f64> {
        match algo {
            ThreeWay::Nt => Some(self.t_nt),
            ThreeWay::TnnOutOfPlace => self.t_tnn_oop,
            ThreeWay::TnnInPlace => Some(self.t_tnn_ip),
        }
    }
}

/// Time all three implementations on a simulated GPU. Valid whenever the
/// plain NT workspace fits (in-place needs nothing extra).
pub fn time_case3(sim: &Simulator, m: u64, n: u64, k: u64) -> Option<Case3> {
    if Simulator::nt_workspace_bytes(m, n, k) > sim.spec().global_mem_bytes() {
        return None;
    }
    let t_tnn_oop = sim.fits(m, n, k).then(|| sim.model.t_tnn(m, n, k));
    Some(Case3 {
        m,
        n,
        k,
        t_nt: sim.model.t_nt(m, n, k),
        t_tnn_oop,
        t_tnn_ip: sim.model.t_tnn_inplace(m, n, k),
    })
}

/// The cascade selector.
pub struct ThreeWaySelector {
    /// +1 → NT, −1 → some TNN variant.
    gate: Gbdt,
    /// +1 → out-of-place, −1 → in-place (among TNN-better cases).
    variant: Gbdt,
}

impl ThreeWaySelector {
    /// Train both stages from simulated sweeps over the paper's GPUs.
    pub fn train_default() -> ThreeWaySelector {
        let mut gate_x = Vec::new();
        let mut gate_y = Vec::new();
        let mut var_x = Vec::new();
        let mut var_y = Vec::new();
        for gpu in PAPER_GPUS {
            let sim = Simulator::new(gpu);
            for &m in &crate::gpusim::SIZE_GRID {
                for &n in &crate::gpusim::SIZE_GRID {
                    for &k in &crate::gpusim::SIZE_GRID {
                        let Some(c) = time_case3(&sim, m, n, k) else {
                            continue;
                        };
                        let row = super::features(gpu, m, n, k).to_vec();
                        let best = c.best();
                        gate_x.push(row.clone());
                        gate_y.push(if best == ThreeWay::Nt { 1.0 } else { -1.0 });
                        if best != ThreeWay::Nt {
                            var_x.push(row);
                            var_y.push(if best == ThreeWay::TnnOutOfPlace {
                                1.0
                            } else {
                                -1.0
                            });
                        }
                    }
                }
            }
        }
        let mut gate = Gbdt::new(GbdtParams::default());
        gate.fit(&gate_x, &gate_y);
        let mut variant = Gbdt::new(GbdtParams::default());
        variant.fit(&var_x, &var_y);
        ThreeWaySelector { gate, variant }
    }

    /// Select among the three implementations with memory awareness:
    /// out-of-place is only offered when Bᵀ fits.
    pub fn select(&self, gpu: &GpuSpec, m: u64, n: u64, k: u64) -> ThreeWay {
        let row = super::features(gpu, m, n, k);
        if self.gate.predict_one(&row) > 0.0 {
            return ThreeWay::Nt;
        }
        let oop_fits =
            Simulator::tnn_workspace_bytes(m, n, k) <= gpu.global_mem_bytes();
        if oop_fits && self.variant.predict_one(&row) > 0.0 {
            ThreeWay::TnnOutOfPlace
        } else {
            ThreeWay::TnnInPlace
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::GTX1080;

    #[test]
    fn inplace_is_slower_than_outofplace_when_both_fit() {
        let sim = Simulator::new(&GTX1080);
        // Double in-place pass at ~23% BW vs single out-of-place at 72%.
        let c = time_case3(&sim, 1024, 4096, 4096).unwrap();
        assert!(c.t_tnn_ip > c.t_tnn_oop.unwrap());
    }

    #[test]
    fn inplace_available_where_oop_is_not() {
        let sim = Simulator::new(&GTX1080);
        // From ablation 4: NT-only region (oop OOM, NT fits).
        let mut found = false;
        for &m in &crate::gpusim::SIZE_GRID {
            for &n in &crate::gpusim::SIZE_GRID {
                for &k in &crate::gpusim::SIZE_GRID {
                    if sim.fits_nt_only(m, n, k) {
                        let c = time_case3(&sim, m, n, k).unwrap();
                        assert!(c.t_tnn_oop.is_none());
                        assert!(c.t_tnn_ip.is_finite());
                        found = true;
                    }
                }
            }
        }
        assert!(found, "grid should contain NT-only cases");
    }

    #[test]
    fn selector_respects_memory() {
        let sel = ThreeWaySelector::train_default();
        let mut oop_in_oom_region = 0;
        let sim = Simulator::new(&GTX1080);
        for &m in &crate::gpusim::SIZE_GRID {
            for &n in &crate::gpusim::SIZE_GRID {
                for &k in &crate::gpusim::SIZE_GRID {
                    if sim.fits_nt_only(m, n, k)
                        && sel.select(&GTX1080, m, n, k) == ThreeWay::TnnOutOfPlace
                    {
                        oop_in_oom_region += 1;
                    }
                }
            }
        }
        assert_eq!(oop_in_oom_region, 0, "must never pick oop where Bᵀ cannot fit");
    }

    #[test]
    fn three_way_beats_two_way_on_average() {
        // The future-work claim: the 3-way selector's average time over the
        // NT-feasible grid is no worse than the 2-way (oop-or-NT) policy.
        let sel = ThreeWaySelector::train_default();
        let sim = Simulator::new(&GTX1080);
        let (mut t3, mut t2, mut n) = (0.0, 0.0, 0);
        for &m in &crate::gpusim::SIZE_GRID {
            for &nn in &crate::gpusim::SIZE_GRID {
                for &k in &crate::gpusim::SIZE_GRID {
                    let Some(c) = time_case3(&sim, m, nn, k) else {
                        continue;
                    };
                    let choice3 = sel.select(&GTX1080, m, nn, k);
                    t3 += c.time_of(choice3).unwrap_or(c.t_nt);
                    // 2-way policy: oracle-free gate + forced NT when oop OOM.
                    let choice2 = if super_gate(&sel, m, nn, k) {
                        ThreeWay::Nt
                    } else if c.t_tnn_oop.is_some() {
                        ThreeWay::TnnOutOfPlace
                    } else {
                        ThreeWay::Nt
                    };
                    t2 += c.time_of(choice2).unwrap_or(c.t_nt);
                    n += 1;
                }
            }
        }
        assert!(n > 800);
        assert!(
            t3 <= t2 * 1.01,
            "3-way total {t3:.3}s should not exceed 2-way {t2:.3}s"
        );
    }

    fn super_gate(sel: &ThreeWaySelector, m: u64, n: u64, k: u64) -> bool {
        let row = crate::selector::features(&GTX1080, m, n, k);
        sel.gate.predict_one(&row) > 0.0
    }
}
