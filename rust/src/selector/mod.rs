//! MTNN — the supervised-learning algorithm selector (§V, Algorithm 2).
//!
//! Given the GPU's five characteristics and the matrix sizes, predict which
//! NT implementation is faster and dispatch accordingly, with the paper's
//! memory-fit fallback: if `Bᵀ` would not fit in GPU memory, always choose
//! the direct NT call.

pub mod cache;
pub mod three_way;

use crate::gemm::Algorithm;
use crate::gpusim::{GpuSpec, Simulator};
use crate::ml::gbdt::{Gbdt, GbdtParams};
use crate::ml::scaler::MinMaxScaler;
use crate::ml::svm::Svm;
use crate::ml::tree::DecisionTreeClassifier;
use crate::ml::Classifier;
use crate::util::json::Json;

/// Build the 8-dimensional input vector `(gm, sm, cc, mbw, l2c, m, n, k)`.
/// O(1), as the paper requires for negligible runtime overhead.
#[inline]
pub fn features(gpu: &GpuSpec, m: u64, n: u64, k: u64) -> [f64; 8] {
    let g = gpu.features();
    [g[0], g[1], g[2], g[3], g[4], m as f64, n as f64, k as f64]
}

/// A trained predictor of the paper's label (+1 → NT, −1 → TNN).
///
/// SVM variants carry their min-max scaler since the paper normalizes
/// features to (0, 1) for SVMs only.
pub enum TrainedModel {
    Gbdt(Gbdt),
    Dt(DecisionTreeClassifier),
    Svm { model: Svm, scaler: MinMaxScaler },
}

impl TrainedModel {
    pub fn name(&self) -> String {
        match self {
            TrainedModel::Gbdt(m) => m.name(),
            TrainedModel::Dt(m) => m.name(),
            TrainedModel::Svm { model, .. } => model.name(),
        }
    }

    /// The underlying GBDT, when this is the paper's production model
    /// (exposed for flat-vs-recursive inference benchmarks).
    pub fn as_gbdt(&self) -> Option<&Gbdt> {
        match self {
            TrainedModel::Gbdt(m) => Some(m),
            _ => None,
        }
    }

    /// Predict the label for a raw (unscaled) feature row. The GBDT arm
    /// runs on the flattened SoA forest ([`crate::ml::flat::FlatForest`],
    /// built at fit/load time) — iterative descent, bit-identical to the
    /// recursive walk, and the reason the 5 µs prediction budget holds.
    #[inline]
    pub fn predict_label(&self, row: &[f64]) -> i8 {
        let v = match self {
            TrainedModel::Gbdt(m) => m.predict_one(row),
            TrainedModel::Dt(m) => m.predict_one(row),
            TrainedModel::Svm { model, scaler } => {
                model.predict_one(&scaler.transform_row(row))
            }
        };
        if v >= 0.0 {
            1
        } else {
            -1
        }
    }
}

/// The MTNN selection system: a trained model + the memory-fallback policy.
pub struct Selector {
    pub model: TrainedModel,
}

/// Why the selector chose what it chose (exposed for metrics/logging).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SelectionReason {
    /// Model predicted NT (+1).
    PredictedNt,
    /// Model predicted TNN (−1).
    PredictedTnn,
    /// `Bᵀ` does not fit in GPU memory — forced NT (paper §II).
    MemoryFallback,
    /// Configuration override (`RouterConfig::force`) — MTNN was bypassed.
    Forced,
}

impl Selector {
    pub fn new(model: TrainedModel) -> Selector {
        Selector { model }
    }

    /// Train the paper's production model: GBDT on the FULL dataset
    /// (§VI.B — "the integrated predictor is trained with all the data
    /// set"), with the paper's hyper-parameters.
    pub fn train_default(records: &[crate::dataset::Record]) -> Selector {
        let d = crate::dataset::to_ml_dataset(records);
        let mut g = Gbdt::new(GbdtParams::default());
        g.fit(&d.x, &d.y);
        Selector::new(TrainedModel::Gbdt(g))
    }

    /// Algorithm 2 of the paper: O(1) feature build, model predict,
    /// memory-fit fallback.
    #[inline]
    pub fn select(&self, gpu: &GpuSpec, m: u64, n: u64, k: u64) -> (Algorithm, SelectionReason) {
        if Simulator::tnn_workspace_bytes(m, n, k) > gpu.global_mem_bytes() {
            return (Algorithm::Nt, SelectionReason::MemoryFallback);
        }
        let row = features(gpu, m, n, k);
        match self.model.predict_label(&row) {
            1 => (Algorithm::Nt, SelectionReason::PredictedNt),
            _ => (Algorithm::Tnn, SelectionReason::PredictedTnn),
        }
    }

    /// Plain predicted algorithm (no fallback), for classifier evaluation.
    #[inline]
    pub fn predict(&self, gpu: &GpuSpec, m: u64, n: u64, k: u64) -> Algorithm {
        Algorithm::from_label(self.model.predict_label(&features(gpu, m, n, k)))
    }

    // ---- persistence (GBDT models only — the shipped production format) ----

    pub fn save(&self, path: impl AsRef<std::path::Path>) -> anyhow::Result<()> {
        match &self.model {
            TrainedModel::Gbdt(g) => {
                let j = Json::obj()
                    .set("format", "mtnn-selector-v1")
                    .set("model", g.to_json());
                if let Some(dir) = path.as_ref().parent() {
                    std::fs::create_dir_all(dir)?;
                }
                std::fs::write(path, j.to_pretty())?;
                Ok(())
            }
            other => anyhow::bail!(
                "only GBDT selectors are persisted (got {}); \
                 retrain baselines from the dataset instead",
                other.name()
            ),
        }
    }

    pub fn load(path: impl AsRef<std::path::Path>) -> anyhow::Result<Selector> {
        let text = std::fs::read_to_string(&path)?;
        let j = Json::parse(&text)?;
        anyhow::ensure!(
            j.get("format").as_str() == Some("mtnn-selector-v1"),
            "unknown selector format in {}",
            path.as_ref().display()
        );
        Ok(Selector::new(TrainedModel::Gbdt(Gbdt::from_json(
            j.get("model"),
        )?)))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::collect_paper_dataset;
    use crate::gpusim::{GTX1080, TITANX};
    use crate::ml::metrics::accuracy;

    fn trained() -> (Selector, Vec<crate::dataset::Record>) {
        let records = collect_paper_dataset();
        let s = Selector::train_default(&records);
        (s, records)
    }

    #[test]
    fn features_layout() {
        let f = features(&GTX1080, 128, 256, 512);
        assert_eq!(f, [8.0, 20.0, 1607.0, 256.0, 2048.0, 128.0, 256.0, 512.0]);
    }

    #[test]
    fn full_train_accuracy_matches_paper_ballpark() {
        // Paper Fig 4: training on 100% of the data reaches 96.39% on the
        // full set. Noise-flipped boundary labels cap us similarly.
        let (s, records) = trained();
        let pred: Vec<f64> = records
            .iter()
            .map(|r| {
                let gpu = GpuSpec::by_name(&r.gpu).unwrap();
                s.predict(gpu, r.m, r.n, r.k).label() as f64
            })
            .collect();
        let truth: Vec<f64> = records.iter().map(|r| r.label as f64).collect();
        let acc = accuracy(&pred, &truth);
        assert!(
            acc.total > 0.90 && acc.total <= 1.0,
            "full-train accuracy {:.4} out of expected band",
            acc.total
        );
    }

    #[test]
    fn memory_fallback_forces_nt() {
        let (s, _) = trained();
        // 32768×32768 with k=32768: Bᵀ extra 4 GiB pushes beyond 8 GiB.
        let (algo, reason) = s.select(&GTX1080, 32768, 32768, 32768);
        assert_eq!(algo, Algorithm::Nt);
        assert_eq!(reason, SelectionReason::MemoryFallback);
        // Small case goes through the model.
        let (_, reason) = s.select(&GTX1080, 128, 128, 128);
        assert_ne!(reason, SelectionReason::MemoryFallback);
    }

    #[test]
    fn selector_is_gpu_sensitive() {
        // The model must read GPU features: predictions over the sweep
        // should not be identical across GPUs.
        let (s, _) = trained();
        let mut diff = 0;
        for &m in &crate::gpusim::SIZE_GRID[..6] {
            for &n in &crate::gpusim::SIZE_GRID[..6] {
                for &k in &crate::gpusim::SIZE_GRID[..6] {
                    if s.predict(&GTX1080, m, n, k) != s.predict(&TITANX, m, n, k) {
                        diff += 1;
                    }
                }
            }
        }
        assert!(diff > 0, "predictions identical across GPUs — GPU features unused");
    }

    #[test]
    fn save_load_roundtrip_preserves_selection() {
        let (s, _) = trained();
        let path = std::env::temp_dir().join("mtnn_selector_test.json");
        s.save(&path).unwrap();
        let back = Selector::load(&path).unwrap();
        for &m in &[128u64, 1024, 8192] {
            for &n in &[256u64, 4096] {
                for &k in &[128u64, 16384] {
                    assert_eq!(
                        s.select(&GTX1080, m, n, k),
                        back.select(&GTX1080, m, n, k)
                    );
                }
            }
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn non_gbdt_models_refuse_to_persist() {
        let mut dt = DecisionTreeClassifier::default();
        dt.fit(&[vec![0.0], vec![1.0]], &[-1.0, 1.0]);
        let s = Selector::new(TrainedModel::Dt(dt));
        assert!(s.save(std::env::temp_dir().join("x.json")).is_err());
    }

    #[test]
    fn load_rejects_wrong_format() {
        let path = std::env::temp_dir().join("mtnn_selector_bad.json");
        std::fs::write(&path, r#"{"format": "something-else"}"#).unwrap();
        assert!(Selector::load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
