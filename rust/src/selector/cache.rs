//! Shape-keyed selection caching: FCN training (and any steady-state GEMM
//! client) re-issues identical `(gpu, m, n, k)` NT calls every iteration,
//! so after the first step an Algorithm-2 selection should cost a table
//! lookup, not a GBDT descent.
//!
//! [`DecisionCache`] is a fixed-capacity, lock-free open-addressing table
//! with **epoch-based invalidation** for the online hot-swap loop: every
//! published entry is stamped with the epoch it was computed under, and
//! [`DecisionCache::invalidate`] bumps the global epoch so all existing
//! entries become misses at once — no sweep, no lock, nothing on the hot
//! path. Callers that may race a model swap capture the epoch *before*
//! computing a decision and publish with [`DecisionCache::insert_at`]; an
//! insert stamped with a pre-invalidation epoch is rejected, so a decision
//! computed under a retired model can never be served after the swap.
//!
//! Each slot is a tiny seqlock: a version word (0 = empty, odd =
//! mid-write, even ≥ 2 = published) guards the key/value/epoch words.
//! Readers re-check the version after reading, so a concurrent in-place
//! refresh degrades to a cache miss (the caller recomputes — selection is
//! deterministic, so duplicate inserts of the same key are harmless),
//! never to a wrong or torn answer. A full probe neighborhood simply stops
//! caching that key: correctness does not depend on capacity.

use super::{SelectionReason, Selector};
use crate::gemm::Algorithm;
use crate::gpusim::GpuSpec;
use std::sync::atomic::{fence, AtomicU64, Ordering};

/// Linear-probe window before giving up on caching a key.
const MAX_PROBES: usize = 8;

struct Slot {
    /// Seqlock version: 0 empty, odd mid-write, even ≥ 2 published.
    ver: AtomicU64,
    epoch: AtomicU64,
    gpu: AtomicU64,
    m: AtomicU64,
    n: AtomicU64,
    k: AtomicU64,
    val: AtomicU64,
}

impl Slot {
    fn new() -> Slot {
        Slot {
            ver: AtomicU64::new(0),
            epoch: AtomicU64::new(0),
            gpu: AtomicU64::new(0),
            m: AtomicU64::new(0),
            n: AtomicU64::new(0),
            k: AtomicU64::new(0),
            val: AtomicU64::new(0),
        }
    }
}

fn encode(dec: (Algorithm, SelectionReason)) -> u64 {
    let a = match dec.0 {
        Algorithm::Nt => 0u64,
        Algorithm::Tnn => 1,
        Algorithm::Nn => 2,
    };
    let r = match dec.1 {
        SelectionReason::PredictedNt => 0u64,
        SelectionReason::PredictedTnn => 1,
        SelectionReason::MemoryFallback => 2,
        SelectionReason::Forced => 3,
    };
    (a << 8) | r
}

fn decode(v: u64) -> (Algorithm, SelectionReason) {
    let a = match v >> 8 {
        0 => Algorithm::Nt,
        1 => Algorithm::Tnn,
        _ => Algorithm::Nn,
    };
    let r = match v & 0xFF {
        0 => SelectionReason::PredictedNt,
        1 => SelectionReason::PredictedTnn,
        2 => SelectionReason::MemoryFallback,
        _ => SelectionReason::Forced,
    };
    (a, r)
}

/// Fast 4×u64 mix (FxHash-style multiply-rotate; SipHash would dominate
/// the lookup cost this cache exists to remove).
#[inline]
fn hash_key(gpu: u64, m: u64, n: u64, k: u64) -> u64 {
    const K: u64 = 0x517C_C1B7_2722_0A95;
    let mut h = gpu.wrapping_mul(K);
    h = (h.rotate_left(26) ^ m).wrapping_mul(K);
    h = (h.rotate_left(26) ^ n).wrapping_mul(K);
    h = (h.rotate_left(26) ^ k).wrapping_mul(K);
    h ^ (h >> 32)
}

/// Lock-free fixed-capacity decision cache keyed by `(gpu.id, m, n, k)`,
/// epoch-stamped for O(1) whole-cache invalidation.
/// `GpuSpec::id` is the GPU's identity here — its contract (see the field
/// doc) requires process-wide uniqueness, since a cached decision bakes in
/// the full spec (memory size drives the fallback rule).
pub struct DecisionCache {
    slots: Box<[Slot]>,
    mask: usize,
    epoch: AtomicU64,
}

impl DecisionCache {
    /// Create a cache with at least `capacity` slots (rounded up to a
    /// power of two, minimum 64).
    pub fn new(capacity: usize) -> DecisionCache {
        let cap = capacity.max(64).next_power_of_two();
        DecisionCache {
            slots: (0..cap).map(|_| Slot::new()).collect(),
            mask: cap - 1,
            epoch: AtomicU64::new(0),
        }
    }

    /// The current epoch. Capture it *before* computing a decision that
    /// will be published with [`DecisionCache::insert_at`].
    #[inline]
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }

    /// Invalidate every cached decision at once by bumping the epoch.
    /// Existing entries become misses; in-flight inserts stamped with the
    /// old epoch are rejected at publish or ignored at read.
    pub fn invalidate(&self) {
        self.epoch.fetch_add(1, Ordering::AcqRel);
    }

    /// Look up a cached decision (current-epoch entries only).
    #[inline]
    pub fn get(&self, gpu: &GpuSpec, m: u64, n: u64, k: u64) -> Option<(Algorithm, SelectionReason)> {
        let cur = self.epoch.load(Ordering::Acquire);
        let h = hash_key(gpu.id, m, n, k) as usize;
        for p in 0..MAX_PROBES {
            let slot = &self.slots[(h + p) & self.mask];
            let v1 = slot.ver.load(Ordering::Acquire);
            if v1 == 0 {
                return None; // inserts claim the first empty slot
            }
            if v1 & 1 == 1 {
                continue; // mid-write: treat as occupied, keep probing
            }
            let key_match = slot.gpu.load(Ordering::Relaxed) == gpu.id
                && slot.m.load(Ordering::Relaxed) == m
                && slot.n.load(Ordering::Relaxed) == n
                && slot.k.load(Ordering::Relaxed) == k;
            let ep = slot.epoch.load(Ordering::Relaxed);
            let val = slot.val.load(Ordering::Relaxed);
            // Seqlock re-check: if the version moved while we read, the
            // fields may be torn — fall back to a miss, never serve them.
            fence(Ordering::Acquire);
            if slot.ver.load(Ordering::Relaxed) != v1 {
                return None;
            }
            if key_match {
                // A key lives in exactly one slot (refreshes are
                // in-place), so a stale-epoch hit means "recompute".
                return if ep == cur { Some(decode(val)) } else { None };
            }
        }
        None
    }

    /// Publish a decision computed under the **current** epoch (see
    /// [`DecisionCache::insert_at`] for swap-racing callers).
    pub fn insert(&self, gpu: &GpuSpec, m: u64, n: u64, k: u64, dec: (Algorithm, SelectionReason)) {
        let ep = self.epoch();
        self.insert_at(ep, gpu, m, n, k, dec);
    }

    /// Publish a decision stamped with the epoch the caller captured
    /// before computing it. No-ops when that epoch has since been
    /// invalidated, when the probe window is full, or when an up-to-date
    /// entry is already present. Concurrent duplicate inserts are harmless
    /// because selection is deterministic within an epoch.
    pub fn insert_at(
        &self,
        epoch: u64,
        gpu: &GpuSpec,
        m: u64,
        n: u64,
        k: u64,
        dec: (Algorithm, SelectionReason),
    ) {
        if self.epoch.load(Ordering::Acquire) != epoch {
            return; // the model that made this decision is gone
        }
        let h = hash_key(gpu.id, m, n, k) as usize;
        for p in 0..MAX_PROBES {
            let slot = &self.slots[(h + p) & self.mask];
            let v1 = slot.ver.load(Ordering::Acquire);
            if v1 & 1 == 1 {
                continue; // another writer: duplicate publish is pointless
            }
            if v1 == 0 {
                // Claim the empty slot (ver 0 → 1 = writing).
                if slot
                    .ver
                    .compare_exchange(0, 1, Ordering::AcqRel, Ordering::Relaxed)
                    .is_ok()
                {
                    slot.gpu.store(gpu.id, Ordering::Relaxed);
                    slot.m.store(m, Ordering::Relaxed);
                    slot.n.store(n, Ordering::Relaxed);
                    slot.k.store(k, Ordering::Relaxed);
                    slot.val.store(encode(dec), Ordering::Relaxed);
                    slot.epoch.store(epoch, Ordering::Relaxed);
                    slot.ver.store(2, Ordering::Release);
                    return;
                }
                continue; // lost the claim race: probe onward
            }
            // Published: is it our key?
            let key_match = slot.gpu.load(Ordering::Relaxed) == gpu.id
                && slot.m.load(Ordering::Relaxed) == m
                && slot.n.load(Ordering::Relaxed) == n
                && slot.k.load(Ordering::Relaxed) == k;
            let ep = slot.epoch.load(Ordering::Relaxed);
            fence(Ordering::Acquire);
            if slot.ver.load(Ordering::Relaxed) != v1 {
                return; // concurrent refresh of this neighborhood — give up
            }
            if !key_match {
                continue;
            }
            if ep == epoch {
                return; // already cached at this epoch
            }
            // In-place refresh: bump to odd (writing), rewrite value +
            // epoch, publish the next even version. The key never changes,
            // so readers only ever see a consistent (key, epoch, val).
            if slot
                .ver
                .compare_exchange(v1, v1 + 1, Ordering::AcqRel, Ordering::Relaxed)
                .is_ok()
            {
                slot.val.store(encode(dec), Ordering::Relaxed);
                slot.epoch.store(epoch, Ordering::Relaxed);
                slot.ver.store(v1 + 2, Ordering::Release);
            }
            return; // refreshed, or a concurrent refresher beat us
        }
    }

    /// Number of entries published at the current epoch (scan; for
    /// tests/metrics, not hot path).
    pub fn len(&self) -> usize {
        let cur = self.epoch.load(Ordering::Acquire);
        self.slots
            .iter()
            .filter(|s| {
                let v = s.ver.load(Ordering::Acquire);
                v != 0 && v & 1 == 0 && s.epoch.load(Ordering::Relaxed) == cur
            })
            .count()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl Default for DecisionCache {
    fn default() -> Self {
        DecisionCache::new(1024)
    }
}

/// A [`Selector`] wrapped with a [`DecisionCache`] — transparent (identical
/// decisions, selection is deterministic) but amortized to a lookup for
/// repeated shapes. Used by the coordinator router and the simulated FCN
/// trainer's MTNN policy.
pub struct CachedSelector<'a> {
    sel: &'a Selector,
    cache: DecisionCache,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl<'a> CachedSelector<'a> {
    pub fn new(sel: &'a Selector) -> CachedSelector<'a> {
        CachedSelector {
            sel,
            cache: DecisionCache::default(),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Algorithm 2 with shape-keyed memoization.
    #[inline]
    pub fn select(&self, gpu: &GpuSpec, m: u64, n: u64, k: u64) -> (Algorithm, SelectionReason) {
        if let Some(hit) = self.cache.get(gpu, m, n, k) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return hit;
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let dec = self.sel.select(gpu, m, n, k);
        self.cache.insert(gpu, m, n, k, dec);
        dec
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::collect_paper_dataset;
    use crate::gpusim::{GTX1080, PAPER_GPUS, TITANX};
    use crate::testutil::prop::check;
    use std::sync::OnceLock;

    fn selector() -> &'static Selector {
        static SEL: OnceLock<Selector> = OnceLock::new();
        SEL.get_or_init(|| Selector::train_default(&collect_paper_dataset()))
    }

    #[test]
    fn roundtrip_all_decisions() {
        let c = DecisionCache::new(64);
        let cases = [
            (Algorithm::Nt, SelectionReason::PredictedNt),
            (Algorithm::Tnn, SelectionReason::PredictedTnn),
            (Algorithm::Nt, SelectionReason::MemoryFallback),
            (Algorithm::Tnn, SelectionReason::Forced),
        ];
        for (i, &dec) in cases.iter().enumerate() {
            c.insert(&GTX1080, i as u64 + 1, 2, 3, dec);
            assert_eq!(c.get(&GTX1080, i as u64 + 1, 2, 3), Some(dec));
        }
        assert_eq!(c.len(), cases.len());
        assert_eq!(c.get(&GTX1080, 999, 2, 3), None);
        // Same shape on a different GPU is a different key.
        assert_eq!(c.get(&TITANX, 1, 2, 3), None);
    }

    #[test]
    fn insert_is_idempotent() {
        let c = DecisionCache::new(64);
        let dec = (Algorithm::Nt, SelectionReason::PredictedNt);
        for _ in 0..10 {
            c.insert(&GTX1080, 128, 256, 512, dec);
        }
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn full_probe_window_degrades_to_miss_not_error() {
        // Tiny cache, many keys: some keys must fail to cache; none may
        // return a wrong value.
        let c = DecisionCache::new(64);
        let dec = (Algorithm::Tnn, SelectionReason::PredictedTnn);
        for m in 1..=500u64 {
            c.insert(&GTX1080, m, 7, 9, dec);
        }
        let mut cached = 0;
        for m in 1..=500u64 {
            if let Some(v) = c.get(&GTX1080, m, 7, 9) {
                assert_eq!(v, dec);
                cached += 1;
            }
        }
        assert!(cached > 0 && cached <= 64, "cached {cached}");
    }

    #[test]
    fn invalidate_hides_every_entry_at_once() {
        let c = DecisionCache::new(128);
        let dec = (Algorithm::Nt, SelectionReason::PredictedNt);
        for m in 1..=20u64 {
            c.insert(&GTX1080, m, 4, 4, dec);
        }
        assert_eq!(c.len(), 20);
        c.invalidate();
        assert_eq!(c.len(), 0);
        for m in 1..=20u64 {
            assert_eq!(c.get(&GTX1080, m, 4, 4), None, "m={m}");
        }
    }

    #[test]
    fn reinsert_after_invalidate_refreshes_in_place() {
        let c = DecisionCache::new(64);
        let old = (Algorithm::Nt, SelectionReason::PredictedNt);
        let new = (Algorithm::Tnn, SelectionReason::PredictedTnn);
        c.insert(&GTX1080, 100, 100, 100, old);
        c.invalidate();
        assert_eq!(c.get(&GTX1080, 100, 100, 100), None);
        c.insert(&GTX1080, 100, 100, 100, new);
        assert_eq!(c.get(&GTX1080, 100, 100, 100), Some(new));
        assert_eq!(c.len(), 1, "the key reuses its slot across epochs");
    }

    #[test]
    fn stale_epoch_inserts_are_rejected() {
        let c = DecisionCache::new(64);
        let dec = (Algorithm::Nt, SelectionReason::PredictedNt);
        let ep = c.epoch();
        c.invalidate(); // the model that computed `dec` is retired
        c.insert_at(ep, &GTX1080, 50, 50, 50, dec);
        assert_eq!(c.get(&GTX1080, 50, 50, 50), None);
        assert_eq!(c.len(), 0);
        // At the current epoch it publishes fine.
        c.insert_at(c.epoch(), &GTX1080, 50, 50, 50, dec);
        assert_eq!(c.get(&GTX1080, 50, 50, 50), Some(dec));
    }

    #[test]
    fn prop_cached_selector_is_transparent() {
        // The cache must never change a decision — cold, warm, any GPU.
        let cached = CachedSelector::new(selector());
        check("cached select == plain select", 300, |g| {
            let gpu = *g.choose(&PAPER_GPUS);
            let m = g.pow2(7, 16) as u64;
            let n = g.pow2(7, 16) as u64;
            let k = g.pow2(7, 16) as u64;
            assert_eq!(cached.select(gpu, m, n, k), selector().select(gpu, m, n, k));
            // Warm path must agree too.
            assert_eq!(cached.select(gpu, m, n, k), selector().select(gpu, m, n, k));
        });
        assert!(cached.hits() > 0, "repeat selections must hit the cache");
    }

    #[test]
    fn concurrent_inserts_and_gets_are_consistent() {
        let c = std::sync::Arc::new(DecisionCache::new(256));
        let dec = (Algorithm::Nt, SelectionReason::PredictedNt);
        std::thread::scope(|s| {
            for t in 0..4 {
                let c = c.clone();
                s.spawn(move || {
                    for i in 0..200u64 {
                        let m = (i % 32) + t; // overlapping key sets
                        c.insert(&GTX1080, m, 64, 64, dec);
                        if let Some(v) = c.get(&GTX1080, m, 64, 64) {
                            assert_eq!(v, dec);
                        }
                    }
                });
            }
        });
        assert!(c.len() >= 32);
    }

    #[test]
    fn concurrent_invalidation_storm_never_serves_cross_epoch_values() {
        // Writers publish epoch-tagged values (NT at even epochs, TNN at
        // odd) while one thread keeps invalidating. Readers must only ever
        // observe the value that matches the epoch they captured — i.e. a
        // hit is always internally consistent, even mid-storm.
        let c = std::sync::Arc::new(DecisionCache::new(64));
        let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
        let nt = (Algorithm::Nt, SelectionReason::PredictedNt);
        let tnn = (Algorithm::Tnn, SelectionReason::PredictedTnn);
        std::thread::scope(|s| {
            for _ in 0..3 {
                let c = c.clone();
                let stop = stop.clone();
                s.spawn(move || {
                    while !stop.load(Ordering::Acquire) {
                        for m in 0..16u64 {
                            let ep = c.epoch();
                            let dec = if ep % 2 == 0 { nt } else { tnn };
                            c.insert_at(ep, &GTX1080, m, 8, 8, dec);
                            if let Some(v) = c.get(&GTX1080, m, 8, 8) {
                                assert!(v == nt || v == tnn);
                                // The value a *stable* epoch serves matches
                                // that epoch's parity.
                                let before = c.epoch();
                                if before == ep {
                                    assert_eq!(v, dec, "epoch {ep}");
                                }
                            }
                        }
                    }
                });
            }
            let c2 = c.clone();
            s.spawn(move || {
                for _ in 0..2000 {
                    c2.invalidate();
                    std::thread::yield_now();
                }
                stop.store(true, Ordering::Release);
            });
        });
    }
}
