//! Shape-keyed selection caching: FCN training (and any steady-state GEMM
//! client) re-issues identical `(gpu, m, n, k)` NT calls every iteration,
//! so after the first step an Algorithm-2 selection should cost a table
//! lookup, not a GBDT descent.
//!
//! [`DecisionCache`] is a fixed-capacity, lock-free open-addressing table.
//! Each slot publishes its key fields before flipping a state word to
//! READY with release ordering; readers acquire the state first, so a
//! matching slot is always fully visible. Races degrade to cache misses
//! (the caller recomputes — selection is deterministic, so duplicate
//! inserts of the same key are harmless), never to wrong answers. A full
//! neighborhood simply stops caching that key: correctness does not depend
//! on capacity.

use super::{SelectionReason, Selector};
use crate::gemm::Algorithm;
use crate::gpusim::GpuSpec;
use std::sync::atomic::{AtomicU64, Ordering};

const EMPTY: u64 = 0;
const CLAIMED: u64 = 1;
const READY: u64 = 2;

/// Linear-probe window before giving up on caching a key.
const MAX_PROBES: usize = 8;

struct Slot {
    state: AtomicU64,
    gpu: AtomicU64,
    m: AtomicU64,
    n: AtomicU64,
    k: AtomicU64,
    val: AtomicU64,
}

impl Slot {
    fn new() -> Slot {
        Slot {
            state: AtomicU64::new(EMPTY),
            gpu: AtomicU64::new(0),
            m: AtomicU64::new(0),
            n: AtomicU64::new(0),
            k: AtomicU64::new(0),
            val: AtomicU64::new(0),
        }
    }
}

fn encode(dec: (Algorithm, SelectionReason)) -> u64 {
    let a = match dec.0 {
        Algorithm::Nt => 0u64,
        Algorithm::Tnn => 1,
        Algorithm::Nn => 2,
    };
    let r = match dec.1 {
        SelectionReason::PredictedNt => 0u64,
        SelectionReason::PredictedTnn => 1,
        SelectionReason::MemoryFallback => 2,
        SelectionReason::Forced => 3,
    };
    (a << 8) | r
}

fn decode(v: u64) -> (Algorithm, SelectionReason) {
    let a = match v >> 8 {
        0 => Algorithm::Nt,
        1 => Algorithm::Tnn,
        _ => Algorithm::Nn,
    };
    let r = match v & 0xFF {
        0 => SelectionReason::PredictedNt,
        1 => SelectionReason::PredictedTnn,
        2 => SelectionReason::MemoryFallback,
        _ => SelectionReason::Forced,
    };
    (a, r)
}

/// Fast 4×u64 mix (FxHash-style multiply-rotate; SipHash would dominate
/// the lookup cost this cache exists to remove).
#[inline]
fn hash_key(gpu: u64, m: u64, n: u64, k: u64) -> u64 {
    const K: u64 = 0x517C_C1B7_2722_0A95;
    let mut h = gpu.wrapping_mul(K);
    h = (h.rotate_left(26) ^ m).wrapping_mul(K);
    h = (h.rotate_left(26) ^ n).wrapping_mul(K);
    h = (h.rotate_left(26) ^ k).wrapping_mul(K);
    h ^ (h >> 32)
}

/// Lock-free fixed-capacity decision cache keyed by `(gpu.id, m, n, k)`.
/// `GpuSpec::id` is the GPU's identity here — its contract (see the field
/// doc) requires process-wide uniqueness, since a cached decision bakes in
/// the full spec (memory size drives the fallback rule).
pub struct DecisionCache {
    slots: Box<[Slot]>,
    mask: usize,
}

impl DecisionCache {
    /// Create a cache with at least `capacity` slots (rounded up to a
    /// power of two, minimum 64).
    pub fn new(capacity: usize) -> DecisionCache {
        let cap = capacity.max(64).next_power_of_two();
        DecisionCache {
            slots: (0..cap).map(|_| Slot::new()).collect(),
            mask: cap - 1,
        }
    }

    /// Look up a cached decision.
    #[inline]
    pub fn get(&self, gpu: &GpuSpec, m: u64, n: u64, k: u64) -> Option<(Algorithm, SelectionReason)> {
        let h = hash_key(gpu.id, m, n, k) as usize;
        for p in 0..MAX_PROBES {
            let slot = &self.slots[(h + p) & self.mask];
            match slot.state.load(Ordering::Acquire) {
                EMPTY => return None, // inserts claim the first empty slot
                READY => {
                    if slot.gpu.load(Ordering::Relaxed) == gpu.id
                        && slot.m.load(Ordering::Relaxed) == m
                        && slot.n.load(Ordering::Relaxed) == n
                        && slot.k.load(Ordering::Relaxed) == k
                    {
                        return Some(decode(slot.val.load(Ordering::Relaxed)));
                    }
                }
                _ => {} // mid-insert: treat as occupied, keep probing
            }
        }
        None
    }

    /// Publish a decision. No-ops when the probe window is full or the key
    /// is already present; concurrent duplicate inserts are harmless
    /// because selection is deterministic.
    pub fn insert(&self, gpu: &GpuSpec, m: u64, n: u64, k: u64, dec: (Algorithm, SelectionReason)) {
        let h = hash_key(gpu.id, m, n, k) as usize;
        for p in 0..MAX_PROBES {
            let slot = &self.slots[(h + p) & self.mask];
            match slot.state.load(Ordering::Acquire) {
                READY => {
                    if slot.gpu.load(Ordering::Relaxed) == gpu.id
                        && slot.m.load(Ordering::Relaxed) == m
                        && slot.n.load(Ordering::Relaxed) == n
                        && slot.k.load(Ordering::Relaxed) == k
                    {
                        return; // already cached
                    }
                }
                EMPTY => {
                    if slot
                        .state
                        .compare_exchange(EMPTY, CLAIMED, Ordering::AcqRel, Ordering::Acquire)
                        .is_ok()
                    {
                        slot.gpu.store(gpu.id, Ordering::Relaxed);
                        slot.m.store(m, Ordering::Relaxed);
                        slot.n.store(n, Ordering::Relaxed);
                        slot.k.store(k, Ordering::Relaxed);
                        slot.val.store(encode(dec), Ordering::Relaxed);
                        slot.state.store(READY, Ordering::Release);
                        return;
                    }
                    // Lost the claim race: fall through and probe onward.
                }
                _ => {}
            }
        }
    }

    /// Number of published entries (scan; for tests/metrics, not hot path).
    pub fn len(&self) -> usize {
        self.slots
            .iter()
            .filter(|s| s.state.load(Ordering::Acquire) == READY)
            .count()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl Default for DecisionCache {
    fn default() -> Self {
        DecisionCache::new(1024)
    }
}

/// A [`Selector`] wrapped with a [`DecisionCache`] — transparent (identical
/// decisions, selection is deterministic) but amortized to a lookup for
/// repeated shapes. Used by the coordinator router and the simulated FCN
/// trainer's MTNN policy.
pub struct CachedSelector<'a> {
    sel: &'a Selector,
    cache: DecisionCache,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl<'a> CachedSelector<'a> {
    pub fn new(sel: &'a Selector) -> CachedSelector<'a> {
        CachedSelector {
            sel,
            cache: DecisionCache::default(),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Algorithm 2 with shape-keyed memoization.
    #[inline]
    pub fn select(&self, gpu: &GpuSpec, m: u64, n: u64, k: u64) -> (Algorithm, SelectionReason) {
        if let Some(hit) = self.cache.get(gpu, m, n, k) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return hit;
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        let dec = self.sel.select(gpu, m, n, k);
        self.cache.insert(gpu, m, n, k, dec);
        dec
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::collect_paper_dataset;
    use crate::gpusim::{GTX1080, PAPER_GPUS, TITANX};
    use crate::testutil::prop::check;
    use std::sync::OnceLock;

    fn selector() -> &'static Selector {
        static SEL: OnceLock<Selector> = OnceLock::new();
        SEL.get_or_init(|| Selector::train_default(&collect_paper_dataset()))
    }

    #[test]
    fn roundtrip_all_decisions() {
        let c = DecisionCache::new(64);
        let cases = [
            (Algorithm::Nt, SelectionReason::PredictedNt),
            (Algorithm::Tnn, SelectionReason::PredictedTnn),
            (Algorithm::Nt, SelectionReason::MemoryFallback),
            (Algorithm::Tnn, SelectionReason::Forced),
        ];
        for (i, &dec) in cases.iter().enumerate() {
            c.insert(&GTX1080, i as u64 + 1, 2, 3, dec);
            assert_eq!(c.get(&GTX1080, i as u64 + 1, 2, 3), Some(dec));
        }
        assert_eq!(c.len(), cases.len());
        assert_eq!(c.get(&GTX1080, 999, 2, 3), None);
        // Same shape on a different GPU is a different key.
        assert_eq!(c.get(&TITANX, 1, 2, 3), None);
    }

    #[test]
    fn insert_is_idempotent() {
        let c = DecisionCache::new(64);
        let dec = (Algorithm::Nt, SelectionReason::PredictedNt);
        for _ in 0..10 {
            c.insert(&GTX1080, 128, 256, 512, dec);
        }
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn full_probe_window_degrades_to_miss_not_error() {
        // Tiny cache, many keys: some keys must fail to cache; none may
        // return a wrong value.
        let c = DecisionCache::new(64);
        let dec = (Algorithm::Tnn, SelectionReason::PredictedTnn);
        for m in 1..=500u64 {
            c.insert(&GTX1080, m, 7, 9, dec);
        }
        let mut cached = 0;
        for m in 1..=500u64 {
            if let Some(v) = c.get(&GTX1080, m, 7, 9) {
                assert_eq!(v, dec);
                cached += 1;
            }
        }
        assert!(cached > 0 && cached <= 64, "cached {cached}");
    }

    #[test]
    fn prop_cached_selector_is_transparent() {
        // The cache must never change a decision — cold, warm, any GPU.
        let cached = CachedSelector::new(selector());
        check("cached select == plain select", 300, |g| {
            let gpu = *g.choose(&PAPER_GPUS);
            let m = g.pow2(7, 16) as u64;
            let n = g.pow2(7, 16) as u64;
            let k = g.pow2(7, 16) as u64;
            assert_eq!(cached.select(gpu, m, n, k), selector().select(gpu, m, n, k));
            // Warm path must agree too.
            assert_eq!(cached.select(gpu, m, n, k), selector().select(gpu, m, n, k));
        });
        assert!(cached.hits() > 0, "repeat selections must hit the cache");
    }

    #[test]
    fn concurrent_inserts_and_gets_are_consistent() {
        let c = std::sync::Arc::new(DecisionCache::new(256));
        let dec = (Algorithm::Nt, SelectionReason::PredictedNt);
        std::thread::scope(|s| {
            for t in 0..4 {
                let c = c.clone();
                s.spawn(move || {
                    for i in 0..200u64 {
                        let m = (i % 32) + t; // overlapping key sets
                        c.insert(&GTX1080, m, 64, 64, dec);
                        if let Some(v) = c.get(&GTX1080, m, 64, 64) {
                            assert_eq!(v, dec);
                        }
                    }
                });
            }
        });
        assert!(c.len() >= 32);
    }
}
