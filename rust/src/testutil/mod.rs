//! Test-only utilities: a minimal property-based testing harness
//! (replacing `proptest`, unavailable offline) and numeric assert helpers.

pub mod prop;

/// Assert two f32 slices are elementwise close with combined abs/rel
/// tolerance — the Rust analogue of `np.testing.assert_allclose`.
pub fn assert_allclose(actual: &[f32], expected: &[f32], atol: f32, rtol: f32) {
    assert_eq!(
        actual.len(),
        expected.len(),
        "length mismatch: {} vs {}",
        actual.len(),
        expected.len()
    );
    for (i, (&a, &e)) in actual.iter().zip(expected).enumerate() {
        let tol = atol + rtol * e.abs();
        assert!(
            (a - e).abs() <= tol,
            "element {i}: {a} vs {e} (|diff|={} > tol={tol})",
            (a - e).abs()
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn allclose_accepts_within_tol() {
        assert_allclose(&[1.0, 2.0], &[1.0001, 1.9999], 1e-3, 0.0);
    }

    #[test]
    #[should_panic(expected = "element 1")]
    fn allclose_rejects_outside_tol() {
        assert_allclose(&[1.0, 2.1], &[1.0, 2.0], 1e-3, 1e-3);
    }
}
