//! Minimal property-based testing, standing in for `proptest` (offline).
//!
//! Usage (`no_run`: doctest binaries lack the xla rpath in this image):
//! ```no_run
//! use mtnn::testutil::prop::{check, Gen};
//! check("addition commutes", 200, |g| {
//!     let a = g.i64_in(-1000, 1000);
//!     let b = g.i64_in(-1000, 1000);
//!     assert_eq!(a + b, b + a);
//! });
//! ```
//!
//! Each case gets a fresh deterministic [`Gen`] derived from the property
//! name and the case index. On failure the harness retries the failing case
//! with the *same* seed to confirm determinism, then panics with the seed so
//! the case can be replayed via [`replay`]. Shrinking is "restart-lite": the
//! generator records every draw, and on failure the harness re-runs with
//! each recorded integer draw halved toward its minimum, keeping the
//! smallest still-failing assignment — cruder than proptest's integrated
//! shrinking but effective for the size-shaped inputs this repo generates.

use crate::util::rng::{mix_parts, Xoshiro256pp};
use std::panic::{catch_unwind, AssertUnwindSafe};

/// Draw recorder: (min, value) per integer draw, enabling shrinking.
#[derive(Debug, Clone, Default)]
struct Trace {
    draws: Vec<(i64, i64)>,
}

/// The value generator handed to properties.
pub struct Gen {
    rng: Xoshiro256pp,
    trace: Trace,
    /// When replaying a shrunk trace, draws come from here instead of rng.
    replay: Option<Vec<i64>>,
    cursor: usize,
}

impl Gen {
    fn new(seed: u64) -> Gen {
        Gen {
            rng: Xoshiro256pp::new(seed),
            trace: Trace::default(),
            replay: None,
            cursor: 0,
        }
    }

    fn with_replay(seed: u64, draws: Vec<i64>) -> Gen {
        Gen {
            rng: Xoshiro256pp::new(seed),
            trace: Trace::default(),
            replay: Some(draws),
            cursor: 0,
        }
    }

    fn draw(&mut self, lo: i64, hi: i64) -> i64 {
        let v = if let Some(replayed) = &self.replay {
            // Replay a recorded (possibly shrunk) value; fall back to fresh
            // randomness if the trace is shorter than the draw sequence.
            match replayed.get(self.cursor) {
                Some(&v) => v.clamp(lo, hi),
                None => lo + self.rng.next_bounded((hi - lo + 1) as u64) as i64,
            }
        } else {
            lo + self.rng.next_bounded((hi - lo + 1) as u64) as i64
        };
        self.cursor += 1;
        self.trace.draws.push((lo, v));
        v
    }

    /// Uniform i64 in [lo, hi] inclusive.
    pub fn i64_in(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        self.draw(lo, hi)
    }

    /// Uniform usize in [lo, hi] inclusive.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.draw(lo as i64, hi as i64) as usize
    }

    /// A power of two 2^e with e in [elo, ehi] — matches the paper's size
    /// grid S = {2^7 .. 2^16}.
    pub fn pow2(&mut self, elo: u32, ehi: u32) -> usize {
        1usize << self.draw(elo as i64, ehi as i64) as u32
    }

    /// Uniform f64 in [lo, hi) with 1e-6 granularity (recorded as integer
    /// so it can shrink).
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        let steps = 1_000_000i64;
        let t = self.draw(0, steps) as f64 / steps as f64;
        lo + t * (hi - lo)
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        assert!(!items.is_empty());
        let i = self.draw(0, items.len() as i64 - 1) as usize;
        &items[i]
    }

    /// Bernoulli.
    pub fn bool(&mut self) -> bool {
        self.draw(0, 1) == 1
    }

    /// A vector of f32 in [-1, 1) of the given length (not shrunk
    /// element-wise; length should come from a shrinkable draw).
    pub fn f32_vec(&mut self, len: usize) -> Vec<f32> {
        (0..len).map(|_| self.rng.next_f32() * 2.0 - 1.0).collect()
    }
}

/// Outcome of running one case.
fn run_case(
    prop: &mut dyn FnMut(&mut Gen),
    seed: u64,
    replay: Option<Vec<i64>>,
) -> Result<Trace, Trace> {
    let mut g = match replay {
        Some(d) => Gen::with_replay(seed, d),
        None => Gen::new(seed),
    };
    let result = catch_unwind(AssertUnwindSafe(|| prop(&mut g)));
    match result {
        Ok(()) => Ok(g.trace),
        Err(_) => Err(g.trace),
    }
}

/// Run `cases` random cases of `prop`. Panics with a replayable seed and the
/// shrunk draw assignment on the first failure.
pub fn check(name: &str, cases: usize, mut prop: impl FnMut(&mut Gen)) {
    let base = mix_parts(&name.bytes().map(|b| b as u64).collect::<Vec<_>>());
    for case in 0..cases {
        let seed = mix_parts(&[base, case as u64]);
        if let Err(trace) = run_case(&mut prop, seed, None) {
            let shrunk = shrink(&mut prop, seed, trace);
            let draws: Vec<i64> = shrunk.draws.iter().map(|&(_, v)| v).collect();
            panic!(
                "property '{name}' failed (case {case}, seed {seed:#x}).\n\
                 shrunk draws: {draws:?}\n\
                 replay with: mtnn::testutil::prop::replay(\"{name}\", {seed:#x}, &{draws:?}, prop)"
            );
        }
    }
}

/// Re-run a specific failing case (from a `check` panic message).
pub fn replay(name: &str, seed: u64, draws: &[i64], mut prop: impl FnMut(&mut Gen)) {
    let _ = name;
    if run_case(&mut prop, seed, Some(draws.to_vec())).is_ok() {
        panic!("replay did not fail — property may be flaky or fixed");
    }
}

/// Restart-lite shrinking: repeatedly try halving each draw toward its
/// minimum; keep any variant that still fails. Bounded effort.
fn shrink(prop: &mut dyn FnMut(&mut Gen), seed: u64, mut failing: Trace) -> Trace {
    let mut budget = 400usize;
    loop {
        let mut improved = false;
        for i in 0..failing.draws.len() {
            if budget == 0 {
                return failing;
            }
            let (lo, v) = failing.draws[i];
            if v == lo {
                continue;
            }
            // Candidate: halve the distance to the minimum.
            let candidate_v = lo + (v - lo) / 2;
            let mut draws: Vec<i64> = failing.draws.iter().map(|&(_, x)| x).collect();
            draws[i] = candidate_v;
            budget -= 1;
            if let Err(trace) = run_case(prop, seed, Some(draws)) {
                failing = trace;
                improved = true;
            }
        }
        if !improved {
            return failing;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("abs is nonneg", 100, |g| {
            let x = g.i64_in(-1_000_000, 1_000_000);
            assert!(x.abs() >= 0);
        });
    }

    #[test]
    fn failing_property_reports_and_shrinks() {
        let result = catch_unwind(AssertUnwindSafe(|| {
            check("find big", 200, |g| {
                let x = g.i64_in(0, 10_000);
                assert!(x < 500, "x={x}");
            });
        }));
        let msg = match result {
            Ok(()) => panic!("property should have failed"),
            Err(e) => e
                .downcast_ref::<String>()
                .cloned()
                .unwrap_or_else(|| "?".into()),
        };
        assert!(msg.contains("find big"), "{msg}");
        // Shrinker should have pulled the counterexample near the boundary.
        let draws_part = msg.split("shrunk draws: ").nth(1).unwrap();
        let v: i64 = draws_part
            .trim_start_matches('[')
            .split(']')
            .next()
            .unwrap()
            .trim()
            .parse()
            .unwrap();
        assert!((500..1000).contains(&v), "shrunk to {v}, expected near 500");
    }

    #[test]
    fn generators_respect_bounds() {
        check("bounds", 300, |g| {
            let p = g.pow2(7, 16);
            assert!(p >= 128 && p <= 65536 && p.is_power_of_two());
            let f = g.f64_in(-2.5, 3.5);
            assert!((-2.5..=3.5).contains(&f));
            let c = *g.choose(&[1, 2, 3]);
            assert!((1..=3).contains(&c));
            let n = g.usize_in(0, 5);
            assert_eq!(g.f32_vec(n).len(), n);
        });
    }

    #[test]
    fn determinism_same_name_same_stream() {
        let mut first: Vec<i64> = Vec::new();
        check("det", 5, |g| {
            first.push(g.i64_in(0, 1_000_000));
        });
        let mut second: Vec<i64> = Vec::new();
        check("det", 5, |g| {
            second.push(g.i64_in(0, 1_000_000));
        });
        assert_eq!(first, second);
    }
}
