//! k-fold cross-validation — the paper's 5-fold protocol (§V.B, Table IV).

use super::data::Dataset;
use super::metrics::{accuracy, Accuracy};
use super::Classifier;

/// Deterministic k-fold index split of `n` rows (shuffle first with
/// [`Dataset::shuffled`] if the data has order structure).
pub fn kfold_indices(n: usize, k: usize) -> Vec<(Vec<usize>, Vec<usize>)> {
    assert!(k >= 2 && k <= n, "need 2 <= k <= n");
    let mut folds: Vec<Vec<usize>> = vec![Vec::new(); k];
    for i in 0..n {
        folds[i % k].push(i);
    }
    (0..k)
        .map(|f| {
            let test = folds[f].clone();
            let train: Vec<usize> = (0..k)
                .filter(|&g| g != f)
                .flat_map(|g| folds[g].iter().copied())
                .collect();
            (train, test)
        })
        .collect()
}

/// Run k-fold CV of a classifier factory over a dataset; returns one
/// [`Accuracy`] per fold. The dataset is shuffled once with `seed`,
/// mirroring the paper's random 80/20 protocol.
pub fn cross_validate<C: Classifier>(
    data: &Dataset,
    k: usize,
    seed: u64,
    mut make: impl FnMut() -> C,
) -> Vec<Accuracy> {
    let d = data.shuffled(seed);
    kfold_indices(d.len(), k)
        .into_iter()
        .map(|(train_idx, test_idx)| {
            let train = d.subset(&train_idx);
            let test = d.subset(&test_idx);
            let mut model = make();
            model.fit(&train.x, &train.y);
            let pred = model.predict(&test.x);
            accuracy(&pred, &test.y)
        })
        .collect()
}

/// Min / max / average over folds for one field, the layout of Table IV.
pub fn fold_stats(folds: &[Accuracy], field: impl Fn(&Accuracy) -> f64) -> (f64, f64, f64) {
    let vals: Vec<f64> = folds.iter().map(field).filter(|v| !v.is_nan()).collect();
    let min = vals.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = vals.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let avg = vals.iter().sum::<f64>() / vals.len() as f64;
    (min, max, avg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::gbdt::{Gbdt, GbdtParams};

    #[test]
    fn kfold_partitions_disjointly() {
        let folds = kfold_indices(23, 5);
        assert_eq!(folds.len(), 5);
        let mut all_test: Vec<usize> = folds.iter().flat_map(|(_, t)| t.clone()).collect();
        all_test.sort_unstable();
        assert_eq!(all_test, (0..23).collect::<Vec<_>>());
        for (train, test) in &folds {
            assert_eq!(train.len() + test.len(), 23);
            assert!(test.iter().all(|i| !train.contains(i)));
        }
    }

    #[test]
    fn fold_sizes_balanced() {
        let folds = kfold_indices(10, 5);
        for (_, test) in &folds {
            assert_eq!(test.len(), 2);
        }
    }

    #[test]
    #[should_panic]
    fn k_larger_than_n_panics() {
        kfold_indices(3, 5);
    }

    #[test]
    fn cv_on_learnable_data_scores_high() {
        // Simple threshold dataset — every fold should be ~perfect.
        let mut d = Dataset::new();
        for i in 0..100 {
            d.push(
                vec![i as f64],
                if i < 50 { -1.0 } else { 1.0 },
                0,
            );
        }
        let folds = cross_validate(&d, 5, 42, || Gbdt::new(GbdtParams::default()));
        assert_eq!(folds.len(), 5);
        let (min, max, avg) = fold_stats(&folds, |a| a.total);
        assert!(min > 0.85, "min fold accuracy {min}");
        assert!(avg > 0.9, "avg {avg}");
        assert!(max <= 1.0);
    }

    #[test]
    fn cv_deterministic_for_seed() {
        let mut d = Dataset::new();
        for i in 0..60 {
            d.push(vec![(i % 7) as f64, i as f64], if i % 2 == 0 { 1.0 } else { -1.0 }, 0);
        }
        let a = cross_validate(&d, 3, 9, || Gbdt::new(GbdtParams::default()));
        let b = cross_validate(&d, 3, 9, || Gbdt::new(GbdtParams::default()));
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.total, y.total);
        }
    }
}
