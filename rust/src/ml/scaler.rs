//! Min-max feature scaling to (0, 1) — required by the paper for the SVM
//! baselines ("each dimension of the input feature should be normalized to
//! the range of (0, 1) when training SVMs"); explicitly NOT applied for
//! the tree learners.

/// Per-feature min/max learned from a training set.
#[derive(Debug, Clone, PartialEq)]
pub struct MinMaxScaler {
    pub mins: Vec<f64>,
    pub maxs: Vec<f64>,
}

impl MinMaxScaler {
    /// Learn ranges from rows. Constant features map to 0.5.
    pub fn fit(x: &[Vec<f64>]) -> MinMaxScaler {
        assert!(!x.is_empty(), "cannot fit scaler on empty data");
        let d = x[0].len();
        let mut mins = vec![f64::INFINITY; d];
        let mut maxs = vec![f64::NEG_INFINITY; d];
        for row in x {
            assert_eq!(row.len(), d);
            for (j, &v) in row.iter().enumerate() {
                mins[j] = mins[j].min(v);
                maxs[j] = maxs[j].max(v);
            }
        }
        MinMaxScaler { mins, maxs }
    }

    /// Scale one row into [0, 1] (values outside the fitted range clamp
    /// so test-time extrapolation cannot explode the kernel).
    pub fn transform_row(&self, row: &[f64]) -> Vec<f64> {
        row.iter()
            .enumerate()
            .map(|(j, &v)| {
                let range = self.maxs[j] - self.mins[j];
                if range <= 0.0 {
                    0.5
                } else {
                    ((v - self.mins[j]) / range).clamp(0.0, 1.0)
                }
            })
            .collect()
    }

    pub fn transform(&self, x: &[Vec<f64>]) -> Vec<Vec<f64>> {
        x.iter().map(|r| self.transform_row(r)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scales_to_unit_interval() {
        let x = vec![vec![0.0, 100.0], vec![10.0, 200.0], vec![5.0, 150.0]];
        let s = MinMaxScaler::fit(&x);
        let t = s.transform(&x);
        assert_eq!(t[0], vec![0.0, 0.0]);
        assert_eq!(t[1], vec![1.0, 1.0]);
        assert_eq!(t[2], vec![0.5, 0.5]);
    }

    #[test]
    fn constant_feature_maps_to_half() {
        let x = vec![vec![7.0], vec![7.0]];
        let s = MinMaxScaler::fit(&x);
        assert_eq!(s.transform_row(&[7.0]), vec![0.5]);
    }

    #[test]
    fn out_of_range_clamps() {
        let x = vec![vec![0.0], vec![10.0]];
        let s = MinMaxScaler::fit(&x);
        assert_eq!(s.transform_row(&[-5.0]), vec![0.0]);
        assert_eq!(s.transform_row(&[50.0]), vec![1.0]);
    }

    #[test]
    #[should_panic]
    fn empty_fit_panics() {
        MinMaxScaler::fit(&[]);
    }
}
