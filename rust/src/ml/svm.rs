//! C-SVM trained with Platt's Sequential Minimal Optimization — the
//! paper's libSVM baselines (Table VI): RBF and polynomial kernels,
//! C = 1000, gamma = 0.01, features min-max normalized to (0, 1) before
//! training (done by the caller via [`crate::ml::scaler::MinMaxScaler`]).

use super::Classifier;
use crate::util::rng::Xoshiro256pp;

/// Kernel functions offered by the paper's comparison.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Kernel {
    /// exp(−gamma · ‖u − v‖²)
    Rbf { gamma: f64 },
    /// (gamma · ⟨u, v⟩ + coef0)^degree — libSVM defaults degree 3, coef0 0.
    Poly { gamma: f64, degree: i32, coef0: f64 },
}

impl Kernel {
    #[inline]
    pub fn eval(&self, u: &[f64], v: &[f64]) -> f64 {
        match *self {
            Kernel::Rbf { gamma } => {
                let mut d2 = 0.0;
                for (a, b) in u.iter().zip(v) {
                    let d = a - b;
                    d2 += d * d;
                }
                (-gamma * d2).exp()
            }
            Kernel::Poly {
                gamma,
                degree,
                coef0,
            } => {
                let mut dot = 0.0;
                for (a, b) in u.iter().zip(v) {
                    dot += a * b;
                }
                (gamma * dot + coef0).powi(degree)
            }
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Kernel::Rbf { .. } => "SVM-RBF",
            Kernel::Poly { .. } => "SVM-Poly",
        }
    }
}

/// SVM hyper-parameters (defaults = the paper's: C = 1000, gamma = 0.01).
#[derive(Debug, Clone)]
pub struct SvmParams {
    pub c: f64,
    pub kernel: Kernel,
    /// KKT violation tolerance.
    pub tol: f64,
    /// Passes without alpha changes before declaring convergence.
    pub max_stall_passes: usize,
    /// Hard cap on optimization sweeps.
    pub max_passes: usize,
    pub seed: u64,
}

impl SvmParams {
    pub fn rbf() -> SvmParams {
        SvmParams {
            c: 1000.0,
            kernel: Kernel::Rbf { gamma: 0.01 },
            tol: 1e-3,
            max_stall_passes: 3,
            max_passes: 200,
            seed: 17,
        }
    }

    pub fn poly() -> SvmParams {
        SvmParams {
            kernel: Kernel::Poly {
                gamma: 0.01,
                degree: 3,
                coef0: 0.0,
            },
            ..SvmParams::rbf()
        }
    }
}

/// A fitted C-SVM (dual form: support vectors + alphas + bias).
#[derive(Debug, Clone)]
pub struct Svm {
    pub params: SvmParams,
    support_x: Vec<Vec<f64>>,
    support_ay: Vec<f64>, // alpha_i * y_i
    bias: f64,
}

impl Svm {
    pub fn new(params: SvmParams) -> Svm {
        Svm {
            params,
            support_x: Vec::new(),
            support_ay: Vec::new(),
            bias: 0.0,
        }
    }

    pub fn n_support(&self) -> usize {
        self.support_x.len()
    }

    /// Decision value f(x) = Σ α_i y_i K(x_i, x) + b.
    pub fn decision_function(&self, row: &[f64]) -> f64 {
        let mut f = self.bias;
        for (sv, ay) in self.support_x.iter().zip(&self.support_ay) {
            f += ay * self.params.kernel.eval(sv, row);
        }
        f
    }
}

impl Classifier for Svm {
    fn fit(&mut self, x: &[Vec<f64>], y: &[f64]) {
        assert_eq!(x.len(), y.len());
        let n = x.len();
        assert!(n >= 2, "need at least two samples");
        let p = self.params.clone();
        let alpha = vec![0.0f64; n];
        let b = 0.0f64;
        let mut rng = Xoshiro256pp::new(p.seed);

        // Full decision value via current alphas (recomputed through the
        // error cache below; this closure is the cold path).
        // Error cache: E_i = f(x_i) − y_i, kept incrementally updated.
        let err: Vec<f64> = (0..n).map(|i| -y[i]).collect();
        // Kernel row cache for the two active indices per step is enough —
        // the dataset (~1.5k rows) keeps full K out of necessity only for
        // speed; n² f64 at n=1466 is ~17 MB, acceptable and much faster.
        let full_k: Option<Vec<f64>> = if n <= 4096 {
            let mut kk = vec![0.0f64; n * n];
            for i in 0..n {
                for j in i..n {
                    let v = p.kernel.eval(&x[i], &x[j]);
                    kk[i * n + j] = v;
                    kk[j * n + i] = v;
                }
            }
            Some(kk)
        } else {
            None
        };
        let kval = |kk: &Option<Vec<f64>>, i: usize, j: usize| -> f64 {
            match kk {
                Some(m) => m[i * n + j],
                None => p.kernel.eval(&x[i], &x[j]),
            }
        };

        // One SMO step on the pair (i, j); returns true if alphas moved.
        // State lives in (alpha, err, b) captured by the caller loop below.
        struct Smo<'a> {
            alpha: Vec<f64>,
            err: Vec<f64>,
            b: f64,
            x: &'a [Vec<f64>],
            y: &'a [f64],
            c: f64,
        }
        let mut st = Smo {
            alpha,
            err,
            b,
            x,
            y,
            c: p.c,
        };
        impl<'a> Smo<'a> {
            fn step(
                &mut self,
                i: usize,
                j: usize,
                kval: &dyn Fn(usize, usize) -> f64,
            ) -> bool {
                if i == j {
                    return false;
                }
                let n = self.x.len();
                let (y, c) = (self.y, self.c);
                let (ai_old, aj_old) = (self.alpha[i], self.alpha[j]);
                let (lo, hi) = if y[i] != y[j] {
                    ((aj_old - ai_old).max(0.0), (c + aj_old - ai_old).min(c))
                } else {
                    ((ai_old + aj_old - c).max(0.0), (ai_old + aj_old).min(c))
                };
                if lo >= hi {
                    return false;
                }
                let kii = kval(i, i);
                let kjj = kval(j, j);
                let kij = kval(i, j);
                let eta = kii + kjj - 2.0 * kij;
                if eta <= 1e-12 {
                    return false;
                }
                let ei = self.err[i];
                let ej = self.err[j];
                let mut aj = aj_old + y[j] * (ei - ej) / eta;
                aj = aj.clamp(lo, hi);
                if (aj - aj_old).abs() < 1e-7 * (aj + aj_old + 1e-7) {
                    return false;
                }
                let ai = ai_old + y[i] * y[j] * (aj_old - aj);
                self.alpha[i] = ai;
                self.alpha[j] = aj;

                // Bias update (Platt).
                let b1 = self.b - ei - y[i] * (ai - ai_old) * kii - y[j] * (aj - aj_old) * kij;
                let b2 = self.b - ej - y[i] * (ai - ai_old) * kij - y[j] * (aj - aj_old) * kjj;
                let new_b = if ai > 0.0 && ai < c {
                    b1
                } else if aj > 0.0 && aj < c {
                    b2
                } else {
                    0.5 * (b1 + b2)
                };
                let db = new_b - self.b;
                self.b = new_b;

                // Incremental error-cache update.
                let di = y[i] * (ai - ai_old);
                let dj = y[j] * (aj - aj_old);
                for t in 0..n {
                    self.err[t] += di * kval(i, t) + dj * kval(j, t) + db;
                }
                true
            }
        }
        let kfun = |i: usize, j: usize| kval(&full_k, i, j);

        let mut stall = 0usize;
        let mut pass = 0usize;
        while stall < p.max_stall_passes && pass < p.max_passes {
            pass += 1;
            let mut changed = 0usize;
            for i in 0..n {
                let ei = st.err[i];
                let ri = ei * y[i];
                // KKT check with tolerance.
                if !((ri < -p.tol && st.alpha[i] < p.c) || (ri > p.tol && st.alpha[i] > 0.0)) {
                    continue;
                }
                // Second-choice heuristic: argmax |E_i − E_j| first…
                let mut j_best = usize::MAX;
                let mut best_gap = -1.0;
                for (cand, &e) in st.err.iter().enumerate() {
                    if cand == i {
                        continue;
                    }
                    let gap = (ei - e).abs();
                    if gap > best_gap {
                        best_gap = gap;
                        j_best = cand;
                    }
                }
                let mut moved = st.step(i, j_best, &kfun);
                // …then, if wedged, sweep all j from a random start (Platt).
                if !moved {
                    let start = rng.next_range(0, n);
                    for off in 0..n {
                        let j = (start + off) % n;
                        if st.step(i, j, &kfun) {
                            moved = true;
                            break;
                        }
                    }
                }
                if moved {
                    changed += 1;
                }
            }
            if changed == 0 {
                stall += 1;
            } else {
                stall = 0;
            }
        }
        let (alpha, b) = (st.alpha, st.b);

        // Keep support vectors only.
        self.support_x.clear();
        self.support_ay.clear();
        for i in 0..n {
            if alpha[i] > 1e-9 {
                self.support_x.push(x[i].clone());
                self.support_ay.push(alpha[i] * y[i]);
            }
        }
        self.bias = b;
    }

    fn predict_one(&self, row: &[f64]) -> f64 {
        if self.decision_function(row) >= 0.0 {
            1.0
        } else {
            -1.0
        }
    }

    fn name(&self) -> String {
        self.params.kernel.name().into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256pp;

    fn ring_data(n: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<f64>) {
        // Inner disc = +1, outer ring = −1: RBF-separable, not linear.
        let mut rng = Xoshiro256pp::new(seed);
        let mut x = Vec::new();
        let mut y = Vec::new();
        for i in 0..n {
            let r = if i % 2 == 0 {
                0.2 * rng.next_f64()
            } else {
                0.6 + 0.3 * rng.next_f64()
            };
            let th = rng.next_f64() * std::f64::consts::TAU;
            x.push(vec![0.5 + r * th.cos(), 0.5 + r * th.sin()]);
            y.push(if i % 2 == 0 { 1.0 } else { -1.0 });
        }
        (x, y)
    }

    #[test]
    fn rbf_separates_ring() {
        let (x, y) = ring_data(120, 3);
        let mut p = SvmParams::rbf();
        p.kernel = Kernel::Rbf { gamma: 10.0 }; // scale to the ring geometry
        let mut m = Svm::new(p);
        m.fit(&x, &y);
        let acc = m
            .predict(&x)
            .iter()
            .zip(&y)
            .filter(|(a, b)| a == b)
            .count() as f64
            / y.len() as f64;
        assert!(acc > 0.97, "ring accuracy {acc}");
        assert!(m.n_support() > 0 && m.n_support() <= x.len());
    }

    #[test]
    fn linearly_separable_margin() {
        let mut x = Vec::new();
        let mut y = Vec::new();
        for i in 0..30 {
            x.push(vec![i as f64 / 30.0, 0.0]);
            y.push(if i < 15 { -1.0 } else { 1.0 });
        }
        let mut m = Svm::new(SvmParams::rbf());
        m.fit(&x, &y);
        assert_eq!(m.predict_one(&[0.0, 0.0]), -1.0);
        assert_eq!(m.predict_one(&[1.0, 0.0]), 1.0);
        // Margin ordering: decision value grows along the feature.
        assert!(m.decision_function(&[0.9, 0.0]) > m.decision_function(&[0.6, 0.0]));
    }

    #[test]
    fn poly_kernel_evaluates_correctly() {
        let k = Kernel::Poly {
            gamma: 0.5,
            degree: 2,
            coef0: 1.0,
        };
        // (0.5 * (1*2 + 2*1) + 1)^2 = (0.5*4 + 1)^2 = 9
        assert!((k.eval(&[1.0, 2.0], &[2.0, 1.0]) - 9.0).abs() < 1e-12);
        let r = Kernel::Rbf { gamma: 1.0 };
        assert!((r.eval(&[0.0], &[0.0]) - 1.0).abs() < 1e-12);
        assert!(r.eval(&[0.0], &[3.0]) < 1e-3);
    }

    #[test]
    fn poly_learns_quadratic_boundary() {
        // y = +1 iff |u| > 0.5 — poly degree ≥ 2 can express u².
        let mut x = Vec::new();
        let mut y = Vec::new();
        for i in 0..60 {
            let u = -1.0 + 2.0 * i as f64 / 59.0;
            x.push(vec![u]);
            y.push(if u.abs() > 0.5 { 1.0 } else { -1.0 });
        }
        let mut p = SvmParams::poly();
        p.kernel = Kernel::Poly {
            gamma: 1.0,
            degree: 3,
            coef0: 1.0,
        };
        let mut m = Svm::new(p);
        m.fit(&x, &y);
        let acc = m
            .predict(&x)
            .iter()
            .zip(&y)
            .filter(|(a, b)| a == b)
            .count() as f64
            / y.len() as f64;
        assert!(acc > 0.9, "poly accuracy {acc}");
    }

    #[test]
    fn deterministic_given_seed() {
        let (x, y) = ring_data(80, 9);
        let mut m1 = Svm::new(SvmParams::rbf());
        let mut m2 = Svm::new(SvmParams::rbf());
        m1.fit(&x, &y);
        m2.fit(&x, &y);
        assert_eq!(m1.n_support(), m2.n_support());
        assert_eq!(m1.decision_function(&x[0]), m2.decision_function(&x[0]));
    }

    #[test]
    fn alphas_respect_box_constraint() {
        let (x, y) = ring_data(60, 1);
        let mut p = SvmParams::rbf();
        p.c = 2.0;
        p.kernel = Kernel::Rbf { gamma: 5.0 };
        let mut m = Svm::new(p);
        m.fit(&x, &y);
        for &ay in &m.support_ay {
            assert!(ay.abs() <= 2.0 + 1e-9, "alpha beyond C: {ay}");
        }
    }
}

#[cfg(test)]
mod dbg_tests {
    use super::*;
    use crate::ml::Classifier;

    #[test]
    #[ignore]
    fn dbg_poly() {
        let mut x = Vec::new();
        let mut y = Vec::new();
        for i in 0..60 {
            let u = -1.0 + 2.0 * i as f64 / 59.0;
            x.push(vec![u]);
            y.push(if (u as f64).abs() > 0.5 { 1.0 } else { -1.0 });
        }
        let mut p = SvmParams::poly();
        p.kernel = Kernel::Poly { gamma: 1.0, degree: 3, coef0: 1.0 };
        let mut m = Svm::new(p);
        m.fit(&x, &y);
        println!("n_support={} bias={}", m.n_support(), m.bias);
        for u in [-1.0, -0.7, -0.3, 0.0, 0.3, 0.7, 1.0] {
            println!("f({u}) = {}", m.decision_function(&[u]));
        }
    }
}
