//! k-nearest-neighbours baseline (extended Table VI comparison). Features
//! should be min-max scaled by the caller, as for the SVMs.

use super::Classifier;

/// kNN over Euclidean distance, majority vote.
#[derive(Debug, Clone)]
pub struct Knn {
    pub k: usize,
    train_x: Vec<Vec<f64>>,
    train_y: Vec<f64>,
}

impl Knn {
    pub fn new(k: usize) -> Knn {
        assert!(k >= 1);
        Knn {
            k,
            train_x: Vec::new(),
            train_y: Vec::new(),
        }
    }
}

fn dist2(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

impl Classifier for Knn {
    fn fit(&mut self, x: &[Vec<f64>], y: &[f64]) {
        assert_eq!(x.len(), y.len());
        assert!(!x.is_empty());
        self.train_x = x.to_vec();
        self.train_y = y.to_vec();
    }

    fn predict_one(&self, row: &[f64]) -> f64 {
        assert!(!self.train_x.is_empty(), "kNN not fitted");
        let k = self.k.min(self.train_x.len());
        // Partial selection of the k smallest distances.
        let mut d: Vec<(f64, f64)> = self
            .train_x
            .iter()
            .zip(&self.train_y)
            .map(|(tx, &ty)| (dist2(row, tx), ty))
            .collect();
        d.select_nth_unstable_by(k - 1, |a, b| a.0.total_cmp(&b.0));
        let vote: f64 = d[..k].iter().map(|&(_, y)| y).sum();
        if vote >= 0.0 {
            1.0
        } else {
            -1.0
        }
    }

    fn name(&self) -> String {
        format!("kNN(k={})", self.k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nearest_neighbour_memorizes() {
        let x = vec![vec![0.0, 0.0], vec![1.0, 1.0], vec![0.1, 0.0]];
        let y = vec![-1.0, 1.0, -1.0];
        let mut m = Knn::new(1);
        m.fit(&x, &y);
        assert_eq!(m.predict_one(&[0.05, 0.0]), -1.0);
        assert_eq!(m.predict_one(&[0.9, 1.0]), 1.0);
    }

    #[test]
    fn k_larger_than_train_clamps() {
        let mut m = Knn::new(99);
        m.fit(&[vec![0.0], vec![1.0]], &[1.0, 1.0]);
        assert_eq!(m.predict_one(&[0.5]), 1.0);
    }

    #[test]
    fn majority_vote() {
        // 2 of 3 neighbours negative → negative.
        let x = vec![vec![0.0], vec![0.2], vec![0.4], vec![10.0]];
        let y = vec![-1.0, -1.0, 1.0, 1.0];
        let mut m = Knn::new(3);
        m.fit(&x, &y);
        assert_eq!(m.predict_one(&[0.1]), -1.0);
    }
}
