//! Gradient-boosted decision trees with binomial log-loss — the paper's
//! chosen learner (§V.B): CART base learners in an XGBoost-style boosting
//! loop, max depth 8, 8 estimators, step size (eta) 1, minimum loss
//! reduction (gamma) 0.
//!
//! Labels are −1/+1 at the API; internally y ∈ {0, 1} with
//! `p = sigmoid(F)`, gradient `p − y`, hessian `p(1 − p)`, leaf weights by
//! one Newton step `−G/(H + λ)`.

use super::flat::FlatForest;
use super::tree::{DecisionTree, TreeParams};
use super::Classifier;
use crate::util::json::Json;

/// GBDT hyper-parameters (defaults = the paper's configuration).
#[derive(Debug, Clone)]
pub struct GbdtParams {
    pub n_estimators: usize,
    /// Step size shrinkage — the paper sets eta = 1 ("more progressive").
    pub eta: f64,
    pub tree: TreeParams,
}

impl Default for GbdtParams {
    fn default() -> Self {
        GbdtParams {
            n_estimators: 8,
            eta: 1.0,
            tree: TreeParams {
                max_depth: 8,
                min_samples_leaf: 1,
                min_split_gain: 0.0, // gamma = 0
                lambda: 1.0,
                min_child_weight: 1.0,
            },
        }
    }
}

/// A fitted gradient-boosted tree ensemble.
#[derive(Debug, Clone, Default)]
pub struct Gbdt {
    pub params: GbdtParams,
    /// Initial log-odds F0.
    pub base_score: f64,
    pub trees: Vec<DecisionTree>,
    /// Flattened SoA mirror of `trees` for hot-path inference; rebuilt by
    /// [`Classifier::fit`] and [`Gbdt::from_json`], bit-identical to the
    /// recursive walk. Private so direct mutation of the public `trees`
    /// field cannot silently be served stale predictions — call
    /// [`Gbdt::rebuild_flat`] after hand-editing `trees`.
    flat: Option<FlatForest>,
}

fn sigmoid(z: f64) -> f64 {
    1.0 / (1.0 + (-z).exp())
}

impl Gbdt {
    pub fn new(params: GbdtParams) -> Gbdt {
        Gbdt {
            params,
            base_score: 0.0,
            trees: Vec::new(),
            flat: None,
        }
    }

    /// Rebuild the flattened inference mirror from `trees`. Called by
    /// `fit`/`from_json`; call manually after mutating `trees` directly.
    pub fn rebuild_flat(&mut self) {
        let flat = FlatForest::from_gbdt(self);
        self.flat = Some(flat);
    }

    /// Raw additive score F(x) (log-odds of the +1 class). Uses the
    /// flattened SoA forest when available (bit-identical, much faster);
    /// falls back to the recursive walk otherwise.
    pub fn decision_function(&self, row: &[f64]) -> f64 {
        match &self.flat {
            Some(f) => f.decision_function(row),
            None => self.decision_function_recursive(row),
        }
    }

    /// Raw additive score via the original recursive tree walk — kept as
    /// the reference implementation and for flat-vs-recursive benchmarks.
    pub fn decision_function_recursive(&self, row: &[f64]) -> f64 {
        let mut f = self.base_score;
        for t in &self.trees {
            f += self.params.eta * t.predict_value(row);
        }
        f
    }

    /// P(label = +1 | x).
    pub fn predict_proba(&self, row: &[f64]) -> f64 {
        sigmoid(self.decision_function(row))
    }

    /// Mean binomial log-loss on a labeled set (training diagnostic).
    pub fn log_loss(&self, x: &[Vec<f64>], y: &[f64]) -> f64 {
        let mut s = 0.0;
        for (row, &label) in x.iter().zip(y) {
            let p = self.predict_proba(row).clamp(1e-12, 1.0 - 1e-12);
            let t = if label > 0.0 { p } else { 1.0 - p };
            s -= t.ln();
        }
        s / y.len() as f64
    }

    // ---- persistence -------------------------------------------------------

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("kind", "gbdt")
            .set("base_score", self.base_score)
            .set("eta", self.params.eta)
            .set("n_estimators", self.params.n_estimators)
            .set("max_depth", self.params.tree.max_depth)
            .set(
                "trees",
                Json::Arr(self.trees.iter().map(|t| t.to_json()).collect()),
            )
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Gbdt> {
        if j.get("kind").as_str() != Some("gbdt") {
            anyhow::bail!("not a gbdt model");
        }
        let mut params = GbdtParams::default();
        params.eta = j
            .get("eta")
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("gbdt json: missing eta"))?;
        if let Some(d) = j.get("max_depth").as_usize() {
            params.tree.max_depth = d;
        }
        if let Some(n) = j.get("n_estimators").as_usize() {
            params.n_estimators = n;
        }
        let trees_j = j
            .get("trees")
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("gbdt json: missing trees"))?;
        let trees = trees_j
            .iter()
            .map(DecisionTree::from_json)
            .collect::<anyhow::Result<Vec<_>>>()?;
        let mut g = Gbdt {
            params,
            base_score: j
                .get("base_score")
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("gbdt json: missing base_score"))?,
            trees,
            flat: None,
        };
        g.rebuild_flat();
        Ok(g)
    }

    pub fn save(&self, path: impl AsRef<std::path::Path>) -> anyhow::Result<()> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json().to_pretty())?;
        Ok(())
    }

    pub fn load(path: impl AsRef<std::path::Path>) -> anyhow::Result<Gbdt> {
        let text = std::fs::read_to_string(&path)?;
        Self::from_json(&Json::parse(&text)?)
    }
}

impl Classifier for Gbdt {
    fn fit(&mut self, x: &[Vec<f64>], y: &[f64]) {
        assert_eq!(x.len(), y.len());
        assert!(!x.is_empty(), "empty training set");
        let n = x.len();
        let pos = y.iter().filter(|&&v| v > 0.0).count() as f64;
        let neg = n as f64 - pos;
        // F0 = log-odds of the positive class (clamped for degenerate sets).
        self.base_score = (pos.max(0.5) / neg.max(0.5)).ln();
        self.trees.clear();
        self.flat = None;

        let mut f: Vec<f64> = vec![self.base_score; n];
        let mut grad = vec![0.0; n];
        let mut hess = vec![0.0; n];
        for _ in 0..self.params.n_estimators {
            for i in 0..n {
                let p = sigmoid(f[i]);
                let t = if y[i] > 0.0 { 1.0 } else { 0.0 };
                grad[i] = p - t;
                hess[i] = (p * (1.0 - p)).max(1e-16);
            }
            let tree = DecisionTree::fit_grad_hess(x, &grad, &hess, &self.params.tree);
            for i in 0..n {
                f[i] += self.params.eta * tree.predict_value(&x[i]);
            }
            self.trees.push(tree);
        }
        self.rebuild_flat();
    }

    fn predict_one(&self, row: &[f64]) -> f64 {
        if self.decision_function(row) >= 0.0 {
            1.0
        } else {
            -1.0
        }
    }

    fn name(&self) -> String {
        "GBDT".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256pp;

    fn xor_data(n_side: usize) -> (Vec<Vec<f64>>, Vec<f64>) {
        let mut x = Vec::new();
        let mut y = Vec::new();
        for i in 0..n_side {
            for j in 0..n_side {
                let (a, b) = (i as f64 / n_side as f64, j as f64 / n_side as f64);
                x.push(vec![a, b]);
                y.push(if (a < 0.5) ^ (b < 0.5) { 1.0 } else { -1.0 });
            }
        }
        (x, y)
    }

    #[test]
    fn learns_xor_perfectly() {
        let (x, y) = xor_data(12);
        let mut m = Gbdt::new(GbdtParams::default());
        m.fit(&x, &y);
        let acc = m
            .predict(&x)
            .iter()
            .zip(&y)
            .filter(|(p, t)| p == t)
            .count() as f64
            / y.len() as f64;
        assert_eq!(acc, 1.0);
    }

    #[test]
    fn boosting_reduces_log_loss_monotonically_on_train() {
        let (x, y) = xor_data(10);
        let mut prev = f64::INFINITY;
        for rounds in 1..=6 {
            let mut p = GbdtParams::default();
            p.n_estimators = rounds;
            p.tree.max_depth = 2;
            let mut m = Gbdt::new(p);
            m.fit(&x, &y);
            let ll = m.log_loss(&x, &y);
            assert!(
                ll <= prev + 1e-9,
                "round {rounds}: loss {ll} should not exceed {prev}"
            );
            prev = ll;
        }
    }

    #[test]
    fn probabilities_are_calibrated_on_separable_data() {
        let x: Vec<Vec<f64>> = (0..40).map(|i| vec![i as f64]).collect();
        let y: Vec<f64> = (0..40).map(|i| if i < 20 { -1.0 } else { 1.0 }).collect();
        let mut m = Gbdt::new(GbdtParams::default());
        m.fit(&x, &y);
        assert!(m.predict_proba(&[0.0]) < 0.05);
        assert!(m.predict_proba(&[39.0]) > 0.95);
    }

    #[test]
    fn imbalanced_base_score_sign() {
        // 90% negative: with zero trees the base score must lean negative.
        let x: Vec<Vec<f64>> = (0..100).map(|i| vec![i as f64]).collect();
        let y: Vec<f64> = (0..100).map(|i| if i < 10 { 1.0 } else { -1.0 }).collect();
        let mut p = GbdtParams::default();
        p.n_estimators = 0;
        let mut m = Gbdt::new(p);
        m.fit(&x, &y);
        assert!(m.base_score < 0.0);
        assert_eq!(m.predict_one(&[50.0]), -1.0);
    }

    #[test]
    fn noisy_labels_still_mostly_learned() {
        let (x, mut y) = xor_data(14);
        let mut rng = Xoshiro256pp::new(5);
        // Flip 5% of labels.
        let flips = y.len() / 20;
        for _ in 0..flips {
            let i = rng.next_range(0, y.len());
            y[i] = -y[i];
        }
        let mut m = Gbdt::new(GbdtParams::default());
        m.fit(&x, &y);
        // Against the CLEAN labels we should still be well above 90%.
        let (_, clean) = xor_data(14);
        let acc = m
            .predict(&x)
            .iter()
            .zip(&clean)
            .filter(|(p, t)| p == t)
            .count() as f64
            / clean.len() as f64;
        assert!(acc > 0.9, "accuracy {acc}");
    }

    #[test]
    fn json_roundtrip_preserves_predictions() {
        let (x, y) = xor_data(8);
        let mut m = Gbdt::new(GbdtParams::default());
        m.fit(&x, &y);
        let back = Gbdt::from_json(&m.to_json()).unwrap();
        for row in &x {
            assert_eq!(m.predict_one(row), back.predict_one(row));
            assert!((m.decision_function(row) - back.decision_function(row)).abs() < 1e-12);
        }
    }

    #[test]
    fn save_load_file() {
        let (x, y) = xor_data(6);
        let mut m = Gbdt::new(GbdtParams::default());
        m.fit(&x, &y);
        let path = std::env::temp_dir().join("mtnn_gbdt_test.json");
        m.save(&path).unwrap();
        let back = Gbdt::load(&path).unwrap();
        assert_eq!(back.trees.len(), m.trees.len());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn respects_paper_hyperparameters() {
        let p = GbdtParams::default();
        assert_eq!(p.n_estimators, 8);
        assert_eq!(p.eta, 1.0);
        assert_eq!(p.tree.max_depth, 8);
        assert_eq!(p.tree.min_split_gain, 0.0);
    }
}
