//! Flattened GBDT inference: the whole ensemble as struct-of-arrays with
//! iterative descent — the hot-path representation behind
//! `selector::TrainedModel::predict_label`.
//!
//! The recursive [`super::gbdt::Gbdt`] walk chases one boxed tree at a time
//! through per-tree node vectors; prediction cost is dominated by dependent
//! pointer loads. [`FlatForest`] concatenates every tree's nodes into five
//! parallel arrays (feature / threshold / left / right / value) with
//! child indices rebased to absolute offsets, so a prediction is a tight
//! loop over array indices: one contiguous working set, no recursion, no
//! per-tree indirection. Leaf values, the base score, and the eta
//! multiplication are applied in exactly the same order as the recursive
//! walk, so decision functions (and therefore labels) are **bit-identical**
//! — asserted against the full paper dataset in the tests below.

use super::gbdt::Gbdt;

/// Sentinel in `left` marking a leaf (mirrors the tree arena's NO_CHILD).
const LEAF: u32 = u32::MAX;

/// The flattened ensemble.
#[derive(Debug, Clone, Default)]
pub struct FlatForest {
    feature: Vec<u32>,
    threshold: Vec<f64>,
    left: Vec<u32>,
    right: Vec<u32>,
    value: Vec<f64>,
    /// Absolute index of each tree's root.
    roots: Vec<u32>,
    base_score: f64,
    eta: f64,
}

impl FlatForest {
    /// Flatten a fitted GBDT. Empty ensembles (zero estimators) flatten to
    /// a base-score-only predictor.
    pub fn from_gbdt(g: &Gbdt) -> FlatForest {
        let total: usize = g.trees.iter().map(|t| t.nodes.len()).sum();
        let mut f = FlatForest {
            feature: Vec::with_capacity(total),
            threshold: Vec::with_capacity(total),
            left: Vec::with_capacity(total),
            right: Vec::with_capacity(total),
            value: Vec::with_capacity(total),
            roots: Vec::with_capacity(g.trees.len()),
            base_score: g.base_score,
            eta: g.params.eta,
        };
        for tree in &g.trees {
            let offset = f.feature.len() as u32;
            f.roots.push(offset); // tree roots are node 0 in the arena
            for node in &tree.nodes {
                f.feature.push(node.feature);
                f.threshold.push(node.threshold);
                if node.is_leaf() {
                    f.left.push(LEAF);
                    f.right.push(LEAF);
                } else {
                    f.left.push(node.left + offset);
                    f.right.push(node.right + offset);
                }
                f.value.push(node.value);
            }
        }
        f
    }

    pub fn n_trees(&self) -> usize {
        self.roots.len()
    }

    pub fn n_nodes(&self) -> usize {
        self.feature.len()
    }

    /// Raw additive score F(x) — iterative descent through every tree,
    /// accumulating `eta * leaf` in tree order exactly like the recursive
    /// walk.
    #[inline]
    pub fn decision_function(&self, row: &[f64]) -> f64 {
        let mut f = self.base_score;
        for &root in &self.roots {
            let mut i = root as usize;
            loop {
                let l = self.left[i];
                if l == LEAF {
                    break;
                }
                i = if row[self.feature[i] as usize] <= self.threshold[i] {
                    l as usize
                } else {
                    self.right[i] as usize
                };
            }
            f += self.eta * self.value[i];
        }
        f
    }

    /// The paper's label (+1 → NT, −1 → TNN).
    #[inline]
    pub fn predict_label(&self, row: &[f64]) -> i8 {
        if self.decision_function(row) >= 0.0 {
            1
        } else {
            -1
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::{collect_paper_dataset, to_ml_dataset};
    use crate::ml::gbdt::GbdtParams;
    use crate::ml::Classifier;
    use crate::testutil::prop::check;

    fn xor_model(depth: usize, rounds: usize) -> (Gbdt, Vec<Vec<f64>>) {
        let mut x = Vec::new();
        let mut y = Vec::new();
        for i in 0..12 {
            for j in 0..12 {
                let (a, b) = (i as f64 / 12.0, j as f64 / 12.0);
                x.push(vec![a, b]);
                y.push(if (a < 0.5) ^ (b < 0.5) { 1.0 } else { -1.0 });
            }
        }
        let mut p = GbdtParams::default();
        p.tree.max_depth = depth;
        p.n_estimators = rounds;
        let mut m = Gbdt::new(p);
        m.fit(&x, &y);
        (m, x)
    }

    #[test]
    fn bit_identical_to_recursive_on_full_paper_dataset() {
        // The satellite requirement: on all ~1828 paper samples the flat
        // descent must reproduce the recursive decision function exactly
        // (f64 equality, not tolerance).
        let d = to_ml_dataset(&collect_paper_dataset());
        let mut g = Gbdt::new(GbdtParams::default());
        g.fit(&d.x, &d.y);
        let flat = FlatForest::from_gbdt(&g);
        assert_eq!(flat.n_trees(), g.trees.len());
        for row in &d.x {
            let rec = g.decision_function_recursive(row);
            let fl = flat.decision_function(row);
            assert!(rec == fl, "flat {fl} != recursive {rec} for {row:?}");
            assert_eq!(flat.predict_label(row) as f64, g.predict_one(row));
        }
    }

    #[test]
    fn prop_flat_matches_recursive_on_random_rows() {
        let (g, _) = xor_model(8, 8);
        let flat = FlatForest::from_gbdt(&g);
        check("flat forest == recursive gbdt", 200, |gen| {
            let a = gen.f64_in(-0.5, 1.5);
            let b = gen.f64_in(-0.5, 1.5);
            let row = [a, b];
            assert!(flat.decision_function(&row) == g.decision_function_recursive(&row));
        });
    }

    #[test]
    fn empty_ensemble_is_base_score_only() {
        let (g, x) = xor_model(2, 0);
        let flat = FlatForest::from_gbdt(&g);
        assert_eq!(flat.n_trees(), 0);
        assert_eq!(flat.n_nodes(), 0);
        assert_eq!(flat.decision_function(&x[0]), g.base_score);
    }

    #[test]
    fn stump_forest_descends_correctly() {
        // Depth-1 trees exercise the smallest non-leaf arenas.
        let (g, x) = xor_model(1, 3);
        let flat = FlatForest::from_gbdt(&g);
        for row in x.iter().take(40) {
            assert!(flat.decision_function(row) == g.decision_function_recursive(row));
        }
    }
}
