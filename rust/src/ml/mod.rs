//! From-scratch supervised learning library — the substrate replacing
//! XGBoost / libSVM / sklearn in the paper's pipeline (DESIGN.md §2).
//!
//! Implements exactly the learners the paper evaluates in Table VI:
//!
//! * [`tree::DecisionTree`] — CART (gini for classification, variance for
//!   the regression trees inside boosting);
//! * [`gbdt::Gbdt`] — gradient-boosted decision trees with binomial
//!   log-loss, the paper's chosen model (depth 8, 8 estimators, eta 1,
//!   gamma 0);
//! * [`svm::Svm`] — C-SVM trained by SMO with RBF and polynomial kernels
//!   (the paper's libSVM baselines, C = 1000, gamma = 0.01);
//!
//! plus the shared machinery: [`data::Dataset`], [`scaler::MinMaxScaler`],
//! [`cv`] (k-fold cross-validation) and [`metrics`].

pub mod cv;
pub mod data;
pub mod flat;
pub mod forest;
pub mod gbdt;
pub mod knn;
pub mod linear;
pub mod metrics;
pub mod scaler;
pub mod svm;
pub mod tree;

/// A binary classifier over dense f64 features with labels −1 / +1.
pub trait Classifier {
    /// Fit on rows `x` with labels `y` (each −1.0 or +1.0).
    fn fit(&mut self, x: &[Vec<f64>], y: &[f64]);

    /// Predict the label (−1.0 or +1.0) for one row.
    fn predict_one(&self, row: &[f64]) -> f64;

    /// Predict labels for many rows.
    fn predict(&self, x: &[Vec<f64>]) -> Vec<f64> {
        x.iter().map(|r| self.predict_one(r)).collect()
    }

    /// Short display name ("GBDT", "SVM-RBF", ...).
    fn name(&self) -> String;
}
