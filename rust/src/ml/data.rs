//! Dense dataset container with deterministic splitting utilities.

use crate::util::rng::Xoshiro256pp;

/// A dense binary-classification dataset: rows of f64 features, labels
/// −1.0 / +1.0, and an optional group id per row (used for per-GPU
/// stratification, mirroring the paper's "80% samples *from each GPU*"
/// split protocol).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Dataset {
    pub x: Vec<Vec<f64>>,
    pub y: Vec<f64>,
    pub group: Vec<u64>,
}

impl Dataset {
    pub fn new() -> Dataset {
        Dataset::default()
    }

    pub fn push(&mut self, features: Vec<f64>, label: f64, group: u64) {
        debug_assert!(label == -1.0 || label == 1.0, "label must be ±1");
        if let Some(first) = self.x.first() {
            assert_eq!(first.len(), features.len(), "feature arity mismatch");
        }
        self.x.push(features);
        self.y.push(label);
        self.group.push(group);
    }

    pub fn len(&self) -> usize {
        self.y.len()
    }

    pub fn is_empty(&self) -> bool {
        self.y.is_empty()
    }

    pub fn n_features(&self) -> usize {
        self.x.first().map_or(0, Vec::len)
    }

    /// Count of labels equal to `label`.
    pub fn count_label(&self, label: f64) -> usize {
        self.y.iter().filter(|&&v| v == label).count()
    }

    /// Select a subset by row indices.
    pub fn subset(&self, idx: &[usize]) -> Dataset {
        Dataset {
            x: idx.iter().map(|&i| self.x[i].clone()).collect(),
            y: idx.iter().map(|&i| self.y[i]).collect(),
            group: idx.iter().map(|&i| self.group[i]).collect(),
        }
    }

    /// Concatenate two datasets (arity-checked).
    pub fn concat(&self, other: &Dataset) -> Dataset {
        if !self.is_empty() && !other.is_empty() {
            assert_eq!(self.n_features(), other.n_features());
        }
        let mut out = self.clone();
        out.x.extend(other.x.iter().cloned());
        out.y.extend(other.y.iter().cloned());
        out.group.extend(other.group.iter().cloned());
        out
    }

    /// The paper's split: shuffle, take `train_frac` of the rows *within
    /// each group* for training, the remainder for testing.
    pub fn split_by_group(&self, train_frac: f64, seed: u64) -> (Dataset, Dataset) {
        assert!((0.0..=1.0).contains(&train_frac));
        let mut rng = Xoshiro256pp::new(seed);
        let mut train_idx = Vec::new();
        let mut test_idx = Vec::new();
        let mut groups: Vec<u64> = self.group.clone();
        groups.sort_unstable();
        groups.dedup();
        for g in groups {
            let mut idx: Vec<usize> = (0..self.len()).filter(|&i| self.group[i] == g).collect();
            rng.shuffle(&mut idx);
            let cut = (idx.len() as f64 * train_frac).round() as usize;
            train_idx.extend_from_slice(&idx[..cut]);
            test_idx.extend_from_slice(&idx[cut..]);
        }
        (self.subset(&train_idx), self.subset(&test_idx))
    }

    /// Plain shuffled split ignoring groups.
    pub fn split(&self, train_frac: f64, seed: u64) -> (Dataset, Dataset) {
        let mut rng = Xoshiro256pp::new(seed);
        let mut idx: Vec<usize> = (0..self.len()).collect();
        rng.shuffle(&mut idx);
        let cut = (idx.len() as f64 * train_frac).round() as usize;
        (self.subset(&idx[..cut]), self.subset(&idx[cut..]))
    }

    /// A shuffled copy (used before k-fold splitting).
    pub fn shuffled(&self, seed: u64) -> Dataset {
        let mut rng = Xoshiro256pp::new(seed);
        let idx = rng.permutation(self.len());
        self.subset(&idx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy(n: usize) -> Dataset {
        let mut d = Dataset::new();
        for i in 0..n {
            let g = (i % 2) as u64;
            d.push(vec![i as f64, (i * 2) as f64], if i % 3 == 0 { 1.0 } else { -1.0 }, g);
        }
        d
    }

    #[test]
    fn push_and_count() {
        let d = toy(9);
        assert_eq!(d.len(), 9);
        assert_eq!(d.n_features(), 2);
        assert_eq!(d.count_label(1.0), 3);
        assert_eq!(d.count_label(-1.0), 6);
    }

    #[test]
    fn split_partitions_everything() {
        let d = toy(100);
        let (tr, te) = d.split(0.8, 7);
        assert_eq!(tr.len(), 80);
        assert_eq!(te.len(), 20);
        // Each original row's feature vector appears exactly once overall.
        let mut seen: Vec<f64> = tr.x.iter().chain(te.x.iter()).map(|r| r[0]).collect();
        seen.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(seen, (0..100).map(|i| i as f64).collect::<Vec<_>>());
    }

    #[test]
    fn group_split_is_per_group() {
        let d = toy(100); // 50 rows per group
        let (tr, te) = d.split_by_group(0.8, 3);
        for g in [0u64, 1] {
            let tr_g = tr.group.iter().filter(|&&x| x == g).count();
            let te_g = te.group.iter().filter(|&&x| x == g).count();
            assert_eq!(tr_g, 40, "group {g} train");
            assert_eq!(te_g, 10, "group {g} test");
        }
    }

    #[test]
    fn split_deterministic_per_seed() {
        let d = toy(50);
        let (a, _) = d.split(0.5, 11);
        let (b, _) = d.split(0.5, 11);
        assert_eq!(a, b);
        let (c, _) = d.split(0.5, 12);
        assert_ne!(a, c);
    }

    #[test]
    fn concat_appends() {
        let d = toy(4);
        let e = toy(6);
        let c = d.concat(&e);
        assert_eq!(c.len(), 10);
    }

    #[test]
    #[should_panic(expected = "feature arity")]
    fn arity_checked() {
        let mut d = toy(2);
        d.push(vec![1.0], 1.0, 0);
    }
}
