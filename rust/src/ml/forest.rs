//! Random forest: bagged CART trees with per-split feature subsampling —
//! an extended-comparison baseline (the paper cites Caruana's 10-algorithm
//! study when motivating boosted trees; bagging is the natural contrast).

use super::tree::{DecisionTree, TreeParams};
use super::Classifier;
use crate::util::rng::Xoshiro256pp;

/// Random-forest hyper-parameters.
#[derive(Debug, Clone)]
pub struct ForestParams {
    pub n_trees: usize,
    pub tree: TreeParams,
    /// Bootstrap sample fraction.
    pub sample_frac: f64,
    pub seed: u64,
}

impl Default for ForestParams {
    fn default() -> Self {
        ForestParams {
            n_trees: 32,
            tree: TreeParams {
                max_depth: 12,
                ..TreeParams::default()
            },
            sample_frac: 1.0,
            seed: 2017,
        }
    }
}

/// A bagged ensemble of gini CART trees voting by majority.
#[derive(Debug, Clone, Default)]
pub struct RandomForest {
    pub params: ForestParams,
    pub trees: Vec<DecisionTree>,
}

impl RandomForest {
    pub fn new(params: ForestParams) -> RandomForest {
        RandomForest {
            params,
            trees: Vec::new(),
        }
    }

    /// Fraction of trees voting +1.
    pub fn vote_fraction(&self, row: &[f64]) -> f64 {
        if self.trees.is_empty() {
            return 0.5;
        }
        let pos = self
            .trees
            .iter()
            .filter(|t| t.predict_value(row) > 0.0)
            .count();
        pos as f64 / self.trees.len() as f64
    }
}

impl Classifier for RandomForest {
    fn fit(&mut self, x: &[Vec<f64>], y: &[f64]) {
        assert_eq!(x.len(), y.len());
        assert!(!x.is_empty());
        let n = x.len();
        let take = ((n as f64) * self.params.sample_frac).round().max(1.0) as usize;
        let mut rng = Xoshiro256pp::new(self.params.seed);
        self.trees.clear();
        for _ in 0..self.params.n_trees {
            // Bootstrap resample (with replacement).
            let mut bx = Vec::with_capacity(take);
            let mut by = Vec::with_capacity(take);
            for _ in 0..take {
                let i = rng.next_range(0, n);
                bx.push(x[i].clone());
                by.push(y[i]);
            }
            self.trees
                .push(DecisionTree::fit_gini(&bx, &by, &self.params.tree));
        }
    }

    fn predict_one(&self, row: &[f64]) -> f64 {
        if self.vote_fraction(row) >= 0.5 {
            1.0
        } else {
            -1.0
        }
    }

    fn name(&self) -> String {
        "RF".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blob_data(n: usize) -> (Vec<Vec<f64>>, Vec<f64>) {
        let mut rng = Xoshiro256pp::new(5);
        let mut x = Vec::new();
        let mut y = Vec::new();
        for i in 0..n {
            let label = if i % 2 == 0 { 1.0 } else { -1.0 };
            let cx = if label > 0.0 { 1.0 } else { -1.0 };
            x.push(vec![cx + rng.next_gaussian() * 0.4, rng.next_gaussian()]);
            y.push(label);
        }
        (x, y)
    }

    #[test]
    fn learns_blobs() {
        let (x, y) = blob_data(200);
        let mut f = RandomForest::new(ForestParams::default());
        f.fit(&x, &y);
        let acc = f
            .predict(&x)
            .iter()
            .zip(&y)
            .filter(|(a, b)| a == b)
            .count() as f64
            / y.len() as f64;
        assert!(acc > 0.95, "forest accuracy {acc}");
    }

    #[test]
    fn vote_fraction_bounded() {
        let (x, y) = blob_data(50);
        let mut f = RandomForest::new(ForestParams {
            n_trees: 7,
            ..Default::default()
        });
        f.fit(&x, &y);
        assert_eq!(f.trees.len(), 7);
        for row in &x {
            let v = f.vote_fraction(row);
            assert!((0.0..=1.0).contains(&v));
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let (x, y) = blob_data(80);
        let mut a = RandomForest::new(ForestParams::default());
        let mut b = RandomForest::new(ForestParams::default());
        a.fit(&x, &y);
        b.fit(&x, &y);
        for row in x.iter().take(20) {
            assert_eq!(a.predict_one(row), b.predict_one(row));
        }
    }
}
