//! L2-regularized logistic regression via gradient descent — the linear
//! baseline of the extended comparison (fails on this task's non-linear
//! decision surface, which is exactly the point).

use super::Classifier;

/// Logistic-regression hyper-parameters.
#[derive(Debug, Clone)]
pub struct LogRegParams {
    pub lr: f64,
    pub l2: f64,
    pub epochs: usize,
}

impl Default for LogRegParams {
    fn default() -> Self {
        LogRegParams {
            lr: 0.1,
            l2: 1e-4,
            epochs: 300,
        }
    }
}

/// Fitted logistic regression (weights + bias). Scale features first.
#[derive(Debug, Clone, Default)]
pub struct LogReg {
    pub params: LogRegParams,
    pub w: Vec<f64>,
    pub b: f64,
}

fn sigmoid(z: f64) -> f64 {
    1.0 / (1.0 + (-z).exp())
}

impl LogReg {
    pub fn new(params: LogRegParams) -> LogReg {
        LogReg {
            params,
            w: Vec::new(),
            b: 0.0,
        }
    }

    pub fn decision_function(&self, row: &[f64]) -> f64 {
        self.b + self.w.iter().zip(row).map(|(w, x)| w * x).sum::<f64>()
    }
}

impl Classifier for LogReg {
    fn fit(&mut self, x: &[Vec<f64>], y: &[f64]) {
        assert_eq!(x.len(), y.len());
        assert!(!x.is_empty());
        let (n, d) = (x.len(), x[0].len());
        self.w = vec![0.0; d];
        self.b = 0.0;
        let p = self.params.clone();
        for _ in 0..p.epochs {
            // Full-batch gradient (n ≈ 1.5k → cheap).
            let mut gw = vec![0.0; d];
            let mut gb = 0.0;
            for (row, &label) in x.iter().zip(y) {
                let t = if label > 0.0 { 1.0 } else { 0.0 };
                let e = sigmoid(self.decision_function(row)) - t;
                for (g, &v) in gw.iter_mut().zip(row) {
                    *g += e * v;
                }
                gb += e;
            }
            for (w, g) in self.w.iter_mut().zip(&gw) {
                *w -= p.lr * (g / n as f64 + p.l2 * *w);
            }
            self.b -= p.lr * gb / n as f64;
        }
    }

    fn predict_one(&self, row: &[f64]) -> f64 {
        if self.decision_function(row) >= 0.0 {
            1.0
        } else {
            -1.0
        }
    }

    fn name(&self) -> String {
        "LogReg".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn separates_linear_data() {
        let x: Vec<Vec<f64>> = (0..100).map(|i| vec![i as f64 / 100.0]).collect();
        let y: Vec<f64> = (0..100).map(|i| if i < 50 { -1.0 } else { 1.0 }).collect();
        let mut m = LogReg::new(LogRegParams::default());
        m.fit(&x, &y);
        assert_eq!(m.predict_one(&[0.1]), -1.0);
        assert_eq!(m.predict_one(&[0.9]), 1.0);
    }

    #[test]
    fn fails_on_xor_as_expected() {
        // A linear model cannot express XOR — documents why the paper
        // needs trees. Accuracy should hover near chance.
        let mut x = Vec::new();
        let mut y = Vec::new();
        for i in 0..20 {
            for j in 0..20 {
                let (a, b) = (i as f64 / 20.0, j as f64 / 20.0);
                x.push(vec![a, b]);
                y.push(if (a < 0.5) ^ (b < 0.5) { 1.0 } else { -1.0 });
            }
        }
        let mut m = LogReg::new(LogRegParams::default());
        m.fit(&x, &y);
        let acc = m
            .predict(&x)
            .iter()
            .zip(&y)
            .filter(|(p, t)| p == t)
            .count() as f64
            / y.len() as f64;
        assert!(acc < 0.65, "linear model should not solve XOR: {acc}");
    }

    #[test]
    fn regularization_shrinks_weights() {
        // Scaled features (like the real pipeline); heavy L2 must yield a
        // smaller weight than no L2 on the same separable data.
        let x: Vec<Vec<f64>> = (0..40).map(|i| vec![i as f64 / 40.0]).collect();
        let y: Vec<f64> = (0..40).map(|i| if i < 20 { -1.0 } else { 1.0 }).collect();
        let mut regularized = LogReg::new(LogRegParams {
            lr: 0.5,
            l2: 0.5,
            epochs: 2000,
        });
        let mut free = LogReg::new(LogRegParams {
            lr: 0.5,
            l2: 0.0,
            epochs: 2000,
        });
        regularized.fit(&x, &y);
        free.fit(&x, &y);
        assert!(
            regularized.w[0].abs() < free.w[0].abs(),
            "regularized {} vs free {}",
            regularized.w[0],
            free.w[0]
        );
    }
}
