//! Classification metrics in the paper's vocabulary: total accuracy plus
//! per-class ("negative" = −1, "positive" = +1) accuracies, as reported in
//! Table IV for the imbalanced test sets.

/// Accuracy breakdown of a prediction run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Accuracy {
    /// Overall fraction correct.
    pub total: f64,
    /// Recall of the −1 class ("Negative" row of Table IV).
    pub negative: f64,
    /// Recall of the +1 class ("Positive" row of Table IV).
    pub positive: f64,
    pub n: usize,
    pub n_neg: usize,
    pub n_pos: usize,
}

/// Compute accuracy metrics from predictions vs truth (labels ±1).
pub fn accuracy(pred: &[f64], truth: &[f64]) -> Accuracy {
    assert_eq!(pred.len(), truth.len());
    assert!(!pred.is_empty(), "empty evaluation set");
    let mut correct = 0usize;
    let (mut n_neg, mut neg_ok, mut n_pos, mut pos_ok) = (0usize, 0usize, 0usize, 0usize);
    for (&p, &t) in pred.iter().zip(truth) {
        debug_assert!(t == 1.0 || t == -1.0);
        if p == t {
            correct += 1;
        }
        if t < 0.0 {
            n_neg += 1;
            if p == t {
                neg_ok += 1;
            }
        } else {
            n_pos += 1;
            if p == t {
                pos_ok += 1;
            }
        }
    }
    let frac = |a: usize, b: usize| {
        if b == 0 {
            f64::NAN
        } else {
            a as f64 / b as f64
        }
    };
    Accuracy {
        total: frac(correct, pred.len()),
        negative: frac(neg_ok, n_neg),
        positive: frac(pos_ok, n_pos),
        n: pred.len(),
        n_neg,
        n_pos,
    }
}

/// 2×2 confusion counts (rows: truth −1/+1; cols: predicted −1/+1).
pub fn confusion(pred: &[f64], truth: &[f64]) -> [[usize; 2]; 2] {
    let mut m = [[0usize; 2]; 2];
    for (&p, &t) in pred.iter().zip(truth) {
        let r = usize::from(t > 0.0);
        let c = usize::from(p > 0.0);
        m[r][c] += 1;
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_predictions() {
        let y = vec![1.0, -1.0, 1.0, -1.0];
        let a = accuracy(&y, &y);
        assert_eq!(a.total, 1.0);
        assert_eq!(a.negative, 1.0);
        assert_eq!(a.positive, 1.0);
        assert_eq!(a.n_neg, 2);
        assert_eq!(a.n_pos, 2);
    }

    #[test]
    fn per_class_breakdown() {
        let truth = vec![-1.0, -1.0, -1.0, 1.0];
        let pred = vec![-1.0, 1.0, -1.0, 1.0];
        let a = accuracy(&pred, &truth);
        assert!((a.total - 0.75).abs() < 1e-12);
        assert!((a.negative - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(a.positive, 1.0);
    }

    #[test]
    fn single_class_gives_nan_for_absent() {
        let truth = vec![-1.0, -1.0];
        let pred = vec![-1.0, 1.0];
        let a = accuracy(&pred, &truth);
        assert!(a.positive.is_nan());
        assert_eq!(a.n_pos, 0);
    }

    #[test]
    fn confusion_counts() {
        let truth = vec![-1.0, -1.0, 1.0, 1.0];
        let pred = vec![-1.0, 1.0, -1.0, 1.0];
        let m = confusion(&pred, &truth);
        assert_eq!(m, [[1, 1], [1, 1]]);
    }
}
