//! CART decision trees: exact greedy splits, gini impurity for standalone
//! classification, and XGBoost-style gradient/hessian regression for the
//! boosting stages of [`crate::ml::gbdt`].

use crate::util::json::Json;

/// Hyper-parameters shared by classification and regression trees.
#[derive(Debug, Clone)]
pub struct TreeParams {
    pub max_depth: usize,
    pub min_samples_leaf: usize,
    /// Minimum gain to accept a split — the paper's `gamma` (set to 0).
    pub min_split_gain: f64,
    /// L2 regularization on leaf weights (XGBoost `lambda`), regression only.
    pub lambda: f64,
    /// Minimum hessian mass per child (XGBoost `min_child_weight`),
    /// regression only — the regularizer that keeps eta=1 boosting from
    /// memorizing label noise.
    pub min_child_weight: f64,
}

impl Default for TreeParams {
    fn default() -> Self {
        TreeParams {
            max_depth: 8,
            min_samples_leaf: 1,
            min_split_gain: 0.0,
            lambda: 1.0,
            min_child_weight: 1.0,
        }
    }
}

/// A tree node in the flat arena. `left == NO_CHILD` marks a leaf.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Node {
    pub feature: u32,
    pub threshold: f64,
    pub left: u32,
    pub right: u32,
    pub value: f64,
}

const NO_CHILD: u32 = u32::MAX;

/// Row-major → column-major copy (one allocation per fit; the split
/// search is columnar).
fn to_columns(x: &[Vec<f64>]) -> Vec<Vec<f64>> {
    let d = x[0].len();
    let mut cols = vec![Vec::with_capacity(x.len()); d];
    for row in x {
        debug_assert_eq!(row.len(), d);
        for (c, &v) in cols.iter_mut().zip(row) {
            c.push(v);
        }
    }
    cols
}

impl Node {
    pub fn is_leaf(&self) -> bool {
        self.left == NO_CHILD
    }
}

/// A single CART tree.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct DecisionTree {
    pub nodes: Vec<Node>,
    pub n_features: usize,
}

impl DecisionTree {
    /// Depth of the tree (leaf-only tree has depth 0).
    pub fn depth(&self) -> usize {
        fn walk(nodes: &[Node], i: u32) -> usize {
            let n = &nodes[i as usize];
            if n.is_leaf() {
                0
            } else {
                1 + walk(nodes, n.left).max(walk(nodes, n.right))
            }
        }
        if self.nodes.is_empty() {
            0
        } else {
            walk(&self.nodes, 0)
        }
    }

    pub fn n_leaves(&self) -> usize {
        self.nodes.iter().filter(|n| n.is_leaf()).count()
    }

    /// Raw value at the leaf reached by `row` (class score or regression
    /// weight depending on how the tree was fitted).
    #[inline]
    pub fn predict_value(&self, row: &[f64]) -> f64 {
        debug_assert_eq!(row.len(), self.n_features);
        let mut i = 0u32;
        loop {
            let n = &self.nodes[i as usize];
            if n.is_leaf() {
                return n.value;
            }
            i = if row[n.feature as usize] <= n.threshold {
                n.left
            } else {
                n.right
            };
        }
    }

    // ---- fitting -----------------------------------------------------------

    /// Fit a gini-impurity classification tree on labels ±1.
    /// Leaf values are the signed class majority (±1).
    pub fn fit_gini(x: &[Vec<f64>], y: &[f64], params: &TreeParams) -> DecisionTree {
        assert_eq!(x.len(), y.len());
        assert!(!x.is_empty(), "empty training set");
        let mut tree = DecisionTree {
            nodes: Vec::new(),
            n_features: x[0].len(),
        };
        // Column-major copy: split search sorts/scans one feature at a
        // time, so columnar access is the cache-friendly layout (§Perf).
        let cols = to_columns(x);
        let idx: Vec<usize> = (0..x.len()).collect();
        tree.grow_gini(&cols, y, idx, 0, params);
        tree
    }

    /// Fit an XGBoost-style regression tree on per-sample gradients and
    /// hessians: leaf weight = −G/(H+λ), split gain is the standard
    /// structure-score improvement.
    pub fn fit_grad_hess(
        x: &[Vec<f64>],
        grad: &[f64],
        hess: &[f64],
        params: &TreeParams,
    ) -> DecisionTree {
        assert_eq!(x.len(), grad.len());
        assert_eq!(x.len(), hess.len());
        assert!(!x.is_empty(), "empty training set");
        let mut tree = DecisionTree {
            nodes: Vec::new(),
            n_features: x[0].len(),
        };
        let cols = to_columns(x);
        let idx: Vec<usize> = (0..x.len()).collect();
        tree.grow_gh(&cols, grad, hess, idx, 0, params);
        tree
    }

    fn push_leaf(&mut self, value: f64) -> u32 {
        self.nodes.push(Node {
            feature: 0,
            threshold: 0.0,
            left: NO_CHILD,
            right: NO_CHILD,
            value,
        });
        (self.nodes.len() - 1) as u32
    }

    fn grow_gini(
        &mut self,
        cols: &[Vec<f64>],
        y: &[f64],
        idx: Vec<usize>,
        depth: usize,
        params: &TreeParams,
    ) -> u32 {
        let n = idx.len() as f64;
        let pos = idx.iter().filter(|&&i| y[i] > 0.0).count() as f64;
        let majority = if pos * 2.0 >= n { 1.0 } else { -1.0 };
        let gini = |p: f64, total: f64| {
            if total <= 0.0 {
                0.0
            } else {
                let q = p / total;
                2.0 * q * (1.0 - q) * total
            }
        };
        let node_impurity = gini(pos, n);
        if depth >= params.max_depth
            || idx.len() < 2 * params.min_samples_leaf
            || node_impurity == 0.0
        {
            return self.push_leaf(majority);
        }

        // Exact greedy search: best (feature, threshold) by gini decrease.
        let mut best: Option<(usize, f64, f64)> = None; // (feat, thr, gain)
        let mut order = idx.clone();
        for (f, col) in cols.iter().enumerate() {
            order.sort_unstable_by(|&a, &b| col[a].total_cmp(&col[b]));
            let mut pos_l = 0.0;
            for (cut, &i) in order.iter().enumerate().take(order.len() - 1) {
                if y[i] > 0.0 {
                    pos_l += 1.0;
                }
                let nl = (cut + 1) as f64;
                // Can't split between equal feature values.
                if col[i] == col[order[cut + 1]] {
                    continue;
                }
                if (cut + 1) < params.min_samples_leaf
                    || (order.len() - cut - 1) < params.min_samples_leaf
                {
                    continue;
                }
                let gain = node_impurity - gini(pos_l, nl) - gini(pos - pos_l, n - nl);
                // `>=`: zero-gain splits are allowed (sklearn semantics) —
                // greedy CART cannot learn XOR-shaped data otherwise.
                if gain >= params.min_split_gain
                    && best.map_or(true, |(_, _, g)| gain > g)
                {
                    let thr = 0.5 * (col[i] + col[order[cut + 1]]);
                    best = Some((f, thr, gain));
                }
            }
        }

        match best {
            None => self.push_leaf(majority),
            Some((f, thr, _)) => {
                let (li, ri): (Vec<usize>, Vec<usize>) =
                    idx.into_iter().partition(|&i| cols[f][i] <= thr);
                let me = self.push_leaf(0.0); // reserve slot
                let l = self.grow_gini(cols, y, li, depth + 1, params);
                let r = self.grow_gini(cols, y, ri, depth + 1, params);
                self.nodes[me as usize] = Node {
                    feature: f as u32,
                    threshold: thr,
                    left: l,
                    right: r,
                    value: majority,
                };
                me
            }
        }
    }

    fn grow_gh(
        &mut self,
        cols: &[Vec<f64>],
        grad: &[f64],
        hess: &[f64],
        idx: Vec<usize>,
        depth: usize,
        params: &TreeParams,
    ) -> u32 {
        let g_sum: f64 = idx.iter().map(|&i| grad[i]).sum();
        let h_sum: f64 = idx.iter().map(|&i| hess[i]).sum();
        let leaf_weight = -g_sum / (h_sum + params.lambda);
        if depth >= params.max_depth || idx.len() < 2 * params.min_samples_leaf {
            return self.push_leaf(leaf_weight);
        }
        let score = |g: f64, h: f64| g * g / (h + params.lambda);
        let parent_score = score(g_sum, h_sum);

        let mut best: Option<(usize, f64, f64)> = None;
        let mut order = idx.clone();
        for (f, col) in cols.iter().enumerate() {
            order.sort_unstable_by(|&a, &b| col[a].total_cmp(&col[b]));
            let (mut gl, mut hl) = (0.0, 0.0);
            for (cut, &i) in order.iter().enumerate().take(order.len() - 1) {
                gl += grad[i];
                hl += hess[i];
                if col[i] == col[order[cut + 1]] {
                    continue;
                }
                if (cut + 1) < params.min_samples_leaf
                    || (order.len() - cut - 1) < params.min_samples_leaf
                    || hl < params.min_child_weight
                    || (h_sum - hl) < params.min_child_weight
                {
                    continue;
                }
                let gain =
                    0.5 * (score(gl, hl) + score(g_sum - gl, h_sum - hl) - parent_score);
                // `>=` as above: gamma = 0 admits zero-gain splits so the
                // boosting stages can carve XOR-like balanced regions.
                if gain >= params.min_split_gain
                    && best.map_or(true, |(_, _, g)| gain > g)
                {
                    let thr = 0.5 * (col[i] + col[order[cut + 1]]);
                    best = Some((f, thr, gain));
                }
            }
        }

        match best {
            None => self.push_leaf(leaf_weight),
            Some((f, thr, _)) => {
                let (li, ri): (Vec<usize>, Vec<usize>) =
                    idx.into_iter().partition(|&i| cols[f][i] <= thr);
                let me = self.push_leaf(0.0);
                let l = self.grow_gh(cols, grad, hess, li, depth + 1, params);
                let r = self.grow_gh(cols, grad, hess, ri, depth + 1, params);
                self.nodes[me as usize] = Node {
                    feature: f as u32,
                    threshold: thr,
                    left: l,
                    right: r,
                    value: leaf_weight,
                };
                me
            }
        }
    }

    // ---- persistence -------------------------------------------------------

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("n_features", self.n_features)
            .set(
                "nodes",
                Json::Arr(
                    self.nodes
                        .iter()
                        .map(|n| {
                            Json::Arr(vec![
                                Json::Num(n.feature as f64),
                                Json::Num(n.threshold),
                                Json::Num(if n.left == NO_CHILD {
                                    -1.0
                                } else {
                                    n.left as f64
                                }),
                                Json::Num(if n.right == NO_CHILD {
                                    -1.0
                                } else {
                                    n.right as f64
                                }),
                                Json::Num(n.value),
                            ])
                        })
                        .collect(),
                ),
            )
    }

    pub fn from_json(j: &Json) -> anyhow::Result<DecisionTree> {
        let n_features = j
            .get("n_features")
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("tree json: missing n_features"))?;
        let nodes_j = j
            .get("nodes")
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("tree json: missing nodes"))?;
        let mut nodes = Vec::with_capacity(nodes_j.len());
        for nj in nodes_j {
            let a = nj
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("tree json: node not an array"))?;
            if a.len() != 5 {
                anyhow::bail!("tree json: node arity {}", a.len());
            }
            let num = |i: usize| a[i].as_f64().ok_or_else(|| anyhow::anyhow!("bad node"));
            let child = |v: f64| if v < 0.0 { NO_CHILD } else { v as u32 };
            nodes.push(Node {
                feature: num(0)? as u32,
                threshold: num(1)?,
                left: child(num(2)?),
                right: child(num(3)?),
                value: num(4)?,
            });
        }
        let tree = DecisionTree { nodes, n_features };
        // Validate child indices.
        for n in &tree.nodes {
            if !n.is_leaf()
                && (n.left as usize >= tree.nodes.len()
                    || n.right as usize >= tree.nodes.len())
            {
                anyhow::bail!("tree json: child index out of range");
            }
        }
        Ok(tree)
    }
}

/// Standalone CART classifier (the paper's "DT" baseline in Table VI).
#[derive(Debug, Clone, Default)]
pub struct DecisionTreeClassifier {
    pub params: TreeParams,
    pub tree: Option<DecisionTree>,
}

impl DecisionTreeClassifier {
    pub fn new(params: TreeParams) -> Self {
        Self {
            params,
            tree: None,
        }
    }
}

impl crate::ml::Classifier for DecisionTreeClassifier {
    fn fit(&mut self, x: &[Vec<f64>], y: &[f64]) {
        self.tree = Some(DecisionTree::fit_gini(x, y, &self.params));
    }

    fn predict_one(&self, row: &[f64]) -> f64 {
        let t = self.tree.as_ref().expect("DecisionTree not fitted");
        if t.predict_value(row) >= 0.0 {
            1.0
        } else {
            -1.0
        }
    }

    fn name(&self) -> String {
        "DT".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ml::Classifier;

    fn xor_data() -> (Vec<Vec<f64>>, Vec<f64>) {
        // 2D XOR grid with margin — requires depth ≥ 2 to separate.
        let mut x = Vec::new();
        let mut y = Vec::new();
        for i in 0..10 {
            for j in 0..10 {
                let (a, b) = (i as f64 / 10.0, j as f64 / 10.0);
                x.push(vec![a, b]);
                y.push(if (a < 0.5) ^ (b < 0.5) { 1.0 } else { -1.0 });
            }
        }
        (x, y)
    }

    #[test]
    fn gini_tree_learns_xor() {
        let (x, y) = xor_data();
        let t = DecisionTree::fit_gini(&x, &y, &TreeParams::default());
        for (row, &label) in x.iter().zip(&y) {
            assert_eq!(t.predict_value(row).signum(), label, "row {row:?}");
        }
        assert!(t.depth() >= 2);
    }

    #[test]
    fn depth_limit_respected() {
        let (x, y) = xor_data();
        for d in 0..5 {
            let t = DecisionTree::fit_gini(
                &x,
                &y,
                &TreeParams {
                    max_depth: d,
                    ..TreeParams::default()
                },
            );
            assert!(t.depth() <= d, "depth {} > limit {d}", t.depth());
        }
    }

    #[test]
    fn pure_node_becomes_leaf() {
        let x = vec![vec![0.0], vec![1.0], vec![2.0]];
        let y = vec![1.0, 1.0, 1.0];
        let t = DecisionTree::fit_gini(&x, &y, &TreeParams::default());
        assert_eq!(t.nodes.len(), 1);
        assert_eq!(t.predict_value(&[5.0]), 1.0);
    }

    #[test]
    fn grad_hess_tree_fits_residuals() {
        // Regression toward -g/(h+λ): single feature step function.
        let x: Vec<Vec<f64>> = (0..20).map(|i| vec![i as f64]).collect();
        let grad: Vec<f64> = (0..20).map(|i| if i < 10 { -1.0 } else { 1.0 }).collect();
        let hess = vec![1.0; 20];
        let t = DecisionTree::fit_grad_hess(
            &x,
            &grad,
            &hess,
            &TreeParams {
                max_depth: 1,
                ..TreeParams::default()
            },
        );
        // Left leaf ≈ 10/(10+1), right ≈ -10/11.
        let l = t.predict_value(&[0.0]);
        let r = t.predict_value(&[19.0]);
        assert!((l - 10.0 / 11.0).abs() < 1e-9, "left {l}");
        assert!((r + 10.0 / 11.0).abs() < 1e-9, "right {r}");
    }

    #[test]
    fn min_samples_leaf_enforced() {
        let x: Vec<Vec<f64>> = (0..10).map(|i| vec![i as f64]).collect();
        let y: Vec<f64> = (0..10).map(|i| if i == 0 { 1.0 } else { -1.0 }).collect();
        let t = DecisionTree::fit_gini(
            &x,
            &y,
            &TreeParams {
                min_samples_leaf: 3,
                ..TreeParams::default()
            },
        );
        // No leaf may hold fewer than 3 samples → the lone positive cannot
        // be isolated, so at least one side misclassifies it; but structure
        // must respect the constraint (≤ 2 internal splits for n=10).
        assert!(t.n_leaves() <= 3, "leaves {}", t.n_leaves());
    }

    #[test]
    fn json_roundtrip() {
        let (x, y) = xor_data();
        let t = DecisionTree::fit_gini(&x, &y, &TreeParams::default());
        let j = t.to_json();
        let back = DecisionTree::from_json(&j).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn json_rejects_corrupt() {
        assert!(DecisionTree::from_json(&Json::Null).is_err());
        let j = Json::obj().set("n_features", 2usize).set(
            "nodes",
            Json::Arr(vec![Json::Arr(vec![
                Json::Num(0.0),
                Json::Num(0.5),
                Json::Num(99.0), // out-of-range child
                Json::Num(100.0),
                Json::Num(0.0),
            ])]),
        );
        assert!(DecisionTree::from_json(&j).is_err());
    }

    #[test]
    fn classifier_wrapper_api() {
        let (x, y) = xor_data();
        let mut c = DecisionTreeClassifier::new(TreeParams::default());
        c.fit(&x, &y);
        let preds = c.predict(&x);
        let acc = preds
            .iter()
            .zip(&y)
            .filter(|(p, t)| p == t)
            .count() as f64
            / y.len() as f64;
        assert_eq!(acc, 1.0);
        assert_eq!(c.name(), "DT");
    }

    #[test]
    fn duplicate_feature_values_never_split_between() {
        // All feature values identical → no valid split → single leaf.
        let x = vec![vec![3.0]; 8];
        let y = vec![1.0, -1.0, 1.0, -1.0, 1.0, -1.0, 1.0, -1.0];
        let t = DecisionTree::fit_gini(&x, &y, &TreeParams::default());
        assert_eq!(t.nodes.len(), 1);
    }
}
