//! Simulated execution backend: answers "how long would this GEMM take on
//! GPU G with algorithm X" from the calibrated timing model. Drives every
//! paper experiment (the physical-testbed plane of DESIGN.md §9).

use super::{Algorithm, GemmShape};
use crate::gpusim::{GpuSpec, Simulator};

/// Simulated timing backend for one GPU.
#[derive(Debug, Clone)]
pub struct SimBackend {
    pub sim: Simulator,
}

impl SimBackend {
    pub fn new(gpu: &'static GpuSpec) -> SimBackend {
        SimBackend {
            sim: Simulator::new(gpu),
        }
    }

    /// Seconds to execute `shape` with `algo`; `None` if the workspace does
    /// not fit in GPU memory.
    pub fn execute_time(&self, shape: GemmShape, algo: Algorithm) -> Option<f64> {
        let GemmShape { m, n, k } = shape;
        match algo {
            Algorithm::Nt => {
                if Simulator::nt_workspace_bytes(m, n, k) > self.sim.spec().global_mem_bytes()
                {
                    return None;
                }
                Some(self.sim.model.t_nt(m, n, k))
            }
            Algorithm::Tnn => {
                if !self.sim.fits(m, n, k) {
                    return None;
                }
                Some(self.sim.model.t_tnn(m, n, k))
            }
            Algorithm::Nn => {
                if Simulator::nt_workspace_bytes(m, n, k) > self.sim.spec().global_mem_bytes()
                {
                    return None;
                }
                Some(self.sim.model.t_nn(m, n, k))
            }
        }
    }

    /// GFLOPS for the given execution.
    pub fn perf_gflops(&self, shape: GemmShape, algo: Algorithm) -> Option<f64> {
        self.execute_time(shape, algo)
            .map(|t| shape.flops() / t / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::GTX1080;

    #[test]
    fn oom_cases_return_none() {
        let b = SimBackend::new(&GTX1080);
        let huge = GemmShape::new(65536, 65536, 65536);
        assert_eq!(b.execute_time(huge, Algorithm::Nt), None);
        assert_eq!(b.execute_time(huge, Algorithm::Tnn), None);
    }

    #[test]
    fn tnn_oom_before_nt() {
        // A shape where NT fits but the extra Bᵀ does not.
        let b = SimBackend::new(&GTX1080);
        // 4*(mk+nk+mn) ≤ 8 GiB < 4*(mk+2nk+mn) requires nk huge vs mk, mn:
        let s = GemmShape::new(128, 32768, 16384);
        // NT: 4*(2^21 + 2^29 + 2^22) ≈ 2.17 GB fits; TNN adds 2 GB more.
        assert!(b.execute_time(s, Algorithm::Nt).is_some());
        let tnn_bytes = Simulator::tnn_workspace_bytes(128, 32768, 16384);
        if tnn_bytes > GTX1080.global_mem_bytes() {
            assert!(b.execute_time(s, Algorithm::Tnn).is_none());
        }
    }

    #[test]
    fn timing_consistent_with_simulator() {
        let b = SimBackend::new(&GTX1080);
        let s = GemmShape::new(1024, 2048, 512);
        let t = b.execute_time(s, Algorithm::Nt).unwrap();
        assert_eq!(t, b.sim.model.t_nt(1024, 2048, 512));
        let p = b.perf_gflops(s, Algorithm::Nt).unwrap();
        assert!((p - s.flops() / t / 1e9).abs() < 1e-9);
    }
}
