//! Row-major f32 matrices and naive reference GEMM — the numeric oracle
//! every execution backend is validated against (the Rust-side analogue of
//! `python/compile/kernels/ref.py`).

use crate::util::rng::Xoshiro256pp;

/// Dense row-major f32 matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Matrix {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Matrix { rows, cols, data }
    }

    /// Deterministic uniform [-1, 1) fill.
    pub fn random(rows: usize, cols: usize, seed: u64) -> Matrix {
        let mut rng = Xoshiro256pp::new(seed);
        Matrix {
            rows,
            cols,
            data: (0..rows * cols)
                .map(|_| rng.next_f32() * 2.0 - 1.0)
                .collect(),
        }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        self.data[r * self.cols + c] = v;
    }

    /// Out-of-place transpose (the reference for the Pallas kernel).
    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        out
    }
}

/// `C[m,n] = A[m,k] × B[k,n]` — naive triple loop (f32 accumulate in f64
/// would diverge from the XLA f32 path; accumulate in f32 like the kernels).
pub fn matmul_nn(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.rows, "NN inner-dim mismatch");
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut c = Matrix::zeros(m, n);
    for i in 0..m {
        for l in 0..k {
            let av = a.at(i, l);
            if av == 0.0 {
                continue;
            }
            for j in 0..n {
                c.data[i * n + j] += av * b.at(l, j);
            }
        }
    }
    c
}

/// `C[m,n] = A[m,k] × B[n,k]ᵀ` — the paper's NT operation, computed
/// directly (no materialized transpose).
pub fn matmul_nt(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.cols, "NT inner-dim mismatch (B is n×k)");
    let (m, k, n) = (a.rows, a.cols, b.rows);
    let mut c = Matrix::zeros(m, n);
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0f32;
            for l in 0..k {
                acc += a.at(i, l) * b.at(j, l);
            }
            c.data[i * n + j] = acc;
        }
    }
    c
}

/// TNN reference: materialize `Bᵀ` then run NN (Algorithm 1 of the paper).
pub fn matmul_tnn(a: &Matrix, b: &Matrix) -> Matrix {
    let bt = b.transpose();
    matmul_nn(a, &bt)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::assert_allclose;
    use crate::testutil::prop::check;

    #[test]
    fn known_product() {
        // A = [[1,2],[3,4]], B(kxn) = [[5,6],[7,8]] → AB = [[19,22],[43,50]]
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(2, 2, vec![5.0, 6.0, 7.0, 8.0]);
        assert_eq!(matmul_nn(&a, &b).data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn nt_equals_tnn_exactly_in_structure() {
        let a = Matrix::random(7, 5, 1);
        let b = Matrix::random(9, 5, 2); // n×k
        let nt = matmul_nt(&a, &b);
        let tnn = matmul_tnn(&a, &b);
        assert_eq!(nt.rows, 7);
        assert_eq!(nt.cols, 9);
        // Different summation orders ⇒ allow f32 tolerance.
        assert_allclose(&nt.data, &tnn.data, 1e-5, 1e-5);
    }

    #[test]
    fn transpose_involution() {
        let m = Matrix::random(13, 4, 3);
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    fn transpose_layout() {
        let m = Matrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let t = m.transpose();
        assert_eq!(t.rows, 3);
        assert_eq!(t.data, vec![1.0, 4.0, 2.0, 5.0, 3.0, 6.0]);
    }

    #[test]
    fn prop_nt_matches_tnn_on_random_shapes() {
        check("nt == tnn (cpu oracle)", 25, |g| {
            let m = g.usize_in(1, 12);
            let n = g.usize_in(1, 12);
            let k = g.usize_in(1, 12);
            let seed = g.i64_in(0, 1 << 30) as u64;
            let a = Matrix::random(m, k, seed);
            let b = Matrix::random(n, k, seed ^ 0xABCD);
            let nt = matmul_nt(&a, &b);
            let tnn = matmul_tnn(&a, &b);
            assert_allclose(&nt.data, &tnn.data, 1e-4, 1e-4);
        });
    }

    #[test]
    fn prop_identity_is_neutral() {
        check("A × I = A", 20, |g| {
            let m = g.usize_in(1, 10);
            let k = g.usize_in(1, 10);
            let a = Matrix::random(m, k, 9);
            let mut eye = Matrix::zeros(k, k);
            for i in 0..k {
                eye.set(i, i, 1.0);
            }
            let c = matmul_nn(&a, &eye);
            assert_allclose(&c.data, &a.data, 1e-6, 1e-6);
            // NT with identity (k×k, symmetric) is also neutral.
            let c2 = matmul_nt(&a, &eye);
            assert_allclose(&c2.data, &a.data, 1e-6, 1e-6);
        });
    }

    #[test]
    #[should_panic(expected = "mismatch")]
    fn shape_mismatch_panics() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(2, 4);
        matmul_nn(&a, &b);
    }
}
