//! Register-tiled GEMM micro-kernels, packed-panel layouts, and the
//! per-thread packing scratch behind [`super::blocked`].
//!
//! # Kernel geometry
//!
//! Every kernel computes one `MR × NR` tile of `C` from an **A panel** and a
//! **B panel** packed for unit-stride streaming:
//!
//! * A panel — `kb` mini-columns of `MR` rows: `ap[l*MR + r] = A[r, l]`
//!   (rows beyond the block edge are zero-padded, so the kernel never
//!   branches on the remainder);
//! * B panel — `kb` mini-rows of `NR` columns: `bp[l*NR + j] = B[l, j]`
//!   (columns beyond the edge zero-padded likewise).
//!
//! The AVX2+FMA kernel ([`tile_avx2`]) holds the 6×16 tile in twelve YMM
//! accumulators and issues two fused multiply-adds per packed `l` step per
//! row; the AArch64 NEON kernel ([`tile_neon`]) holds it in twenty-four
//! 128-bit Q accumulators (four per row) with `vfmaq_f32`; the portable
//! scalar kernel ([`tile_scalar`]) is the reference path, the
//! other-architecture fallback, and the `MTNN_NO_SIMD=1` escape hatch.
//! All consume *identical* panels, so the NT/TNN bit-identity argument of
//! [`super::blocked`] holds on any path — what the paper's §IV calls
//! the same kernel fed through two memory-access plans.
//!
//! # Dispatch
//!
//! [`active_kernel`] picks the kernel once per GEMM call: forced override
//! (test/bench hook, [`with_forced_kernel`]) → `MTNN_NO_SIMD` environment
//! hatch → hardware (runtime `is_x86_feature_detected!("avx2") && ("fma")`
//! on x86-64; NEON is baseline on AArch64, no probe needed) → scalar.
//! Detection and the environment read are cached for the process lifetime.
//!
//! # Scratch
//!
//! Packing buffers (and the out-of-place transpose buffer of the TNN /
//! TN routes) live in thread-local [`Vec`]s that are taken, grown only when
//! too small, and put back — steady-state traffic re-packs into warm
//! buffers with zero heap allocation. Every capacity growth bumps a global
//! counter ([`scratch_grow_events`]) so benches and tests can assert the
//! hot path is allocation-free after warmup.

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::{Mutex, OnceLock};

/// Register-blocked rows per micro-kernel tile.
pub const MR: usize = 6;
/// Register-blocked columns per micro-kernel tile (two 8-lane f32 vectors).
pub const NR: usize = 16;

/// How the B operand is stored relative to the logical `k × n` operand the
/// packing step consumes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BLayout {
    /// B is stored row-major `k × n` — plain NN.
    KxN,
    /// B is stored row-major `n × k`; packing gathers panels transposed on
    /// the fly — the direct NT access pattern.
    NxK,
}

/// Which micro-kernel implementation executes the tiles of a GEMM call.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelKind {
    /// Portable reference kernel — auto-vectorized at best.
    Scalar,
    /// Explicit AVX2 + FMA 6×16 kernel (x86-64 only, runtime-detected).
    Avx2,
    /// Explicit NEON (ASIMD) 6×16 kernel (AArch64 only; NEON is part of
    /// the AArch64 baseline, so no runtime probe is needed).
    Neon,
}

impl KernelKind {
    pub fn name(self) -> &'static str {
        match self {
            KernelKind::Scalar => "scalar",
            KernelKind::Avx2 => "avx2",
            KernelKind::Neon => "neon",
        }
    }
}

/// Forced-kernel override: 0 = auto, 1 = scalar, 2 = SIMD-if-supported.
static FORCED: AtomicU8 = AtomicU8::new(0);
/// Serializes [`with_forced_kernel`] sections (and anything that must see a
/// stable kernel choice across several GEMM calls, e.g. bit-identity tests).
static MODE_LOCK: Mutex<()> = Mutex::new(());

/// Whether `MTNN_NO_SIMD` disables the SIMD kernels ("" and "0" mean no).
fn env_disables_simd(v: Option<std::ffi::OsString>) -> bool {
    match v {
        Some(s) => !s.is_empty() && s != "0",
        None => false,
    }
}

/// Best kernel the hardware supports, ignoring the environment hatch.
fn hw_kernel() -> KernelKind {
    static HW: OnceLock<KernelKind> = OnceLock::new();
    *HW.get_or_init(|| {
        #[cfg(target_arch = "x86_64")]
        {
            if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
                return KernelKind::Avx2;
            }
        }
        #[cfg(target_arch = "aarch64")]
        {
            // NEON/ASIMD is mandatory in the AArch64 baseline — every
            // target this crate builds for has it.
            return KernelKind::Neon;
        }
        #[allow(unreachable_code)]
        KernelKind::Scalar
    })
}

/// Hardware detection gated by the `MTNN_NO_SIMD` escape hatch (read once
/// per process).
fn detected() -> KernelKind {
    static DET: OnceLock<KernelKind> = OnceLock::new();
    *DET.get_or_init(|| {
        if env_disables_simd(std::env::var_os("MTNN_NO_SIMD")) {
            KernelKind::Scalar
        } else {
            hw_kernel()
        }
    })
}

/// The kernel the next GEMM call will use.
pub fn active_kernel() -> KernelKind {
    match FORCED.load(Ordering::Relaxed) {
        1 => KernelKind::Scalar,
        2 => hw_kernel(),
        _ => detected(),
    }
}

/// The kernels worth testing on this host under the current environment:
/// always the scalar reference, plus the SIMD kernel when it would actually
/// dispatch (so `MTNN_NO_SIMD=1` CI runs stay scalar-only).
pub fn available_kernels() -> Vec<KernelKind> {
    let mut out = vec![KernelKind::Scalar];
    let hw = detected();
    if hw != KernelKind::Scalar {
        out.push(hw);
    }
    out
}

/// Run `f` with the kernel choice pinned: `Some(Scalar)` forces the
/// reference kernel, `Some(Avx2)`/`Some(Neon)` forces this host's SIMD
/// kernel when the hardware supports one (scalar otherwise), `None` pins
/// the default dispatch. Sections are
/// serialized by a global lock, so concurrent tests cannot flip the kernel
/// out from under a caller mid-section — which is what keeps NT/TNN
/// bit-identity assertions race-free. Test/bench hook, not a serving API.
pub fn with_forced_kernel<R>(kind: Option<KernelKind>, f: impl FnOnce() -> R) -> R {
    struct Restore(u8);
    impl Drop for Restore {
        fn drop(&mut self) {
            FORCED.store(self.0, Ordering::Relaxed);
        }
    }
    let _section = MODE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let _restore = Restore(FORCED.load(Ordering::Relaxed));
    FORCED.store(
        match kind {
            None => 0,
            Some(KernelKind::Scalar) => 1,
            Some(KernelKind::Avx2) | Some(KernelKind::Neon) => 2,
        },
        Ordering::Relaxed,
    );
    f()
}

// ---- packing ----------------------------------------------------------------

/// Pack the `mb × kb` block of row-major `A` (leading dimension `lda`,
/// origin `(i0, l0)`) into `MR`-row panels: panel `ip` holds rows
/// `ip*MR..ip*MR+MR` as `ap[ip*kb*MR + l*MR + r]`, rows past `mb`
/// zero-padded so the kernel always runs a full tile.
pub(crate) fn pack_a(
    a: &[f32],
    lda: usize,
    i0: usize,
    l0: usize,
    mb: usize,
    kb: usize,
    ap: &mut [f32],
) {
    let mpanels = mb.div_ceil(MR);
    for ip in 0..mpanels {
        let base = ip * kb * MR;
        let rows = MR.min(mb - ip * MR);
        for r in 0..rows {
            let src = &a[(i0 + ip * MR + r) * lda + l0..][..kb];
            for (l, &v) in src.iter().enumerate() {
                ap[base + l * MR + r] = v;
            }
        }
        for r in rows..MR {
            for l in 0..kb {
                ap[base + l * MR + r] = 0.0;
            }
        }
    }
}

/// Pack the `kb × nb` panel of the logical `k × n` B operand starting at
/// `(l0, j0)` into `NR`-column panels: `bp[jp*kb*NR + l*NR + j]`, columns
/// past `nb` zero-padded. For [`BLayout::NxK`] this is where the transposed
/// gather happens (panel-sized, so the strided reads stay cache-resident)
/// — the NT memory-access pattern; both layouts produce bit-identical
/// panels for the same logical operand.
#[allow(clippy::too_many_arguments)]
pub(crate) fn pack_b(
    b: &[f32],
    layout: BLayout,
    l0: usize,
    j0: usize,
    kb: usize,
    nb: usize,
    k: usize,
    n: usize,
    bp: &mut [f32],
) {
    let npanels = nb.div_ceil(NR);
    match layout {
        BLayout::KxN => {
            for jp in 0..npanels {
                let base = jp * kb * NR;
                let cols = NR.min(nb - jp * NR);
                for l in 0..kb {
                    let src = &b[(l0 + l) * n + j0 + jp * NR..][..cols];
                    let dst = &mut bp[base + l * NR..base + l * NR + NR];
                    dst[..cols].copy_from_slice(src);
                    dst[cols..].fill(0.0);
                }
            }
        }
        BLayout::NxK => {
            // B row j is contiguous in l: read sequentially, scatter into
            // the panel columns.
            for jp in 0..npanels {
                let base = jp * kb * NR;
                let cols = NR.min(nb - jp * NR);
                if cols < NR {
                    for l in 0..kb {
                        bp[base + l * NR + cols..base + l * NR + NR].fill(0.0);
                    }
                }
                for j in 0..cols {
                    let src = &b[(j0 + jp * NR + j) * k + l0..][..kb];
                    for (l, &v) in src.iter().enumerate() {
                        bp[base + l * NR + j] = v;
                    }
                }
            }
        }
    }
}

// ---- micro-kernels ----------------------------------------------------------

/// Compute one full `MR × NR` tile from packed panels:
/// `out[r*NR + j] = Σ_l ap[l*MR + r] · bp[l*NR + j]`. The caller merges the
/// valid sub-rectangle into `C` (padded lanes are zero, so the full tile is
/// always safe to compute).
pub(crate) fn tile(kind: KernelKind, kb: usize, ap: &[f32], bp: &[f32], out: &mut [f32; MR * NR]) {
    debug_assert!(ap.len() >= kb * MR && bp.len() >= kb * NR);
    match kind {
        // The arm re-checks hardware support itself (a cached OnceLock
        // load, negligible next to the kernel work) rather than trusting
        // callers: `KernelKind::Avx2` is a freely constructible pub enum
        // variant, so a caller bypassing `active_kernel` must degrade to
        // scalar, not hit SIGILL.
        #[cfg(target_arch = "x86_64")]
        // SAFETY: guarded by `hw_kernel()` == Avx2, i.e. runtime
        // `is_x86_feature_detected!` confirmed AVX2+FMA on this CPU.
        KernelKind::Avx2 if hw_kernel() == KernelKind::Avx2 => unsafe {
            tile_avx2(kb, ap, bp, out)
        },
        #[cfg(target_arch = "aarch64")]
        // SAFETY: NEON is baseline on AArch64; `hw_kernel()` returns Neon
        // only there.
        KernelKind::Neon if hw_kernel() == KernelKind::Neon => unsafe {
            tile_neon(kb, ap, bp, out)
        },
        _ => tile_scalar(kb, ap, bp, out),
    }
}

/// Portable reference kernel; also the remainder-free non-x86 fallback.
fn tile_scalar(kb: usize, ap: &[f32], bp: &[f32], out: &mut [f32; MR * NR]) {
    let mut acc = [[0.0f32; NR]; MR];
    for l in 0..kb {
        let arow = &ap[l * MR..l * MR + MR];
        let brow = &bp[l * NR..l * NR + NR];
        for (accr, &av) in acc.iter_mut().zip(arow) {
            for (dst, &bv) in accr.iter_mut().zip(brow) {
                *dst += av * bv;
            }
        }
    }
    for (r, accr) in acc.iter().enumerate() {
        out[r * NR..(r + 1) * NR].copy_from_slice(accr);
    }
}

/// 6×16 AVX2+FMA kernel: twelve YMM accumulators, two FMAs per row per
/// packed depth step.
///
/// # Safety
/// Requires AVX2 and FMA support on the running CPU ([`hw_kernel`] checks
/// at runtime before this kind can be dispatched).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
#[allow(clippy::needless_range_loop)]
unsafe fn tile_avx2(kb: usize, ap: &[f32], bp: &[f32], out: &mut [f32; MR * NR]) {
    use std::arch::x86_64::*;
    let mut acc_lo = [_mm256_setzero_ps(); MR];
    let mut acc_hi = [_mm256_setzero_ps(); MR];
    let mut a_ptr = ap.as_ptr();
    let mut b_ptr = bp.as_ptr();
    for _ in 0..kb {
        let b_lo = _mm256_loadu_ps(b_ptr);
        let b_hi = _mm256_loadu_ps(b_ptr.add(8));
        for r in 0..MR {
            let av = _mm256_set1_ps(*a_ptr.add(r));
            acc_lo[r] = _mm256_fmadd_ps(av, b_lo, acc_lo[r]);
            acc_hi[r] = _mm256_fmadd_ps(av, b_hi, acc_hi[r]);
        }
        a_ptr = a_ptr.add(MR);
        b_ptr = b_ptr.add(NR);
    }
    let out_ptr = out.as_mut_ptr();
    for r in 0..MR {
        _mm256_storeu_ps(out_ptr.add(r * NR), acc_lo[r]);
        _mm256_storeu_ps(out_ptr.add(r * NR + 8), acc_hi[r]);
    }
}

/// 6×16 NEON (ASIMD) kernel: four 128-bit Q accumulators per row
/// (24 total — AArch64 has 32 SIMD registers, so accumulators, the four
/// B vectors, and the A broadcast all stay resident), one fused
/// multiply-add per accumulator per packed depth step.
///
/// # Safety
/// Requires NEON, which is part of the AArch64 baseline ([`hw_kernel`]
/// only ever dispatches this kind on AArch64).
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
#[allow(clippy::needless_range_loop)]
unsafe fn tile_neon(kb: usize, ap: &[f32], bp: &[f32], out: &mut [f32; MR * NR]) {
    use std::arch::aarch64::*;
    let mut acc = [[vdupq_n_f32(0.0); 4]; MR];
    let mut a_ptr = ap.as_ptr();
    let mut b_ptr = bp.as_ptr();
    for _ in 0..kb {
        let b0 = vld1q_f32(b_ptr);
        let b1 = vld1q_f32(b_ptr.add(4));
        let b2 = vld1q_f32(b_ptr.add(8));
        let b3 = vld1q_f32(b_ptr.add(12));
        for r in 0..MR {
            let av = vdupq_n_f32(*a_ptr.add(r));
            acc[r][0] = vfmaq_f32(acc[r][0], av, b0);
            acc[r][1] = vfmaq_f32(acc[r][1], av, b1);
            acc[r][2] = vfmaq_f32(acc[r][2], av, b2);
            acc[r][3] = vfmaq_f32(acc[r][3], av, b3);
        }
        a_ptr = a_ptr.add(MR);
        b_ptr = b_ptr.add(NR);
    }
    let out_ptr = out.as_mut_ptr();
    for r in 0..MR {
        for (q, &v) in acc[r].iter().enumerate() {
            vst1q_f32(out_ptr.add(r * NR + q * 4), v);
        }
    }
}

// ---- per-thread packing scratch ---------------------------------------------

/// Global count of scratch-buffer capacity growths (any thread). Flat under
/// steady-state traffic — the zero-alloc invariant benches and tests check.
static GROW_EVENTS: AtomicU64 = AtomicU64::new(0);

pub fn scratch_grow_events() -> u64 {
    GROW_EVENTS.load(Ordering::Relaxed)
}

struct Scratch {
    /// Packed A panels.
    ap: Vec<f32>,
    /// Packed B panels.
    bp: Vec<f32>,
    /// Out-of-place transpose buffer (TNN's `Bᵀ`, TN's `Aᵀ`).
    tr: Vec<f32>,
}

thread_local! {
    static SCRATCH: RefCell<Scratch> = const {
        RefCell::new(Scratch { ap: Vec::new(), bp: Vec::new(), tr: Vec::new() })
    };
}

/// Grow `v` to at least `n` elements, counting real (re)allocations.
pub(crate) fn ensure_len(v: &mut Vec<f32>, n: usize) {
    if v.len() < n {
        if n > v.capacity() {
            GROW_EVENTS.fetch_add(1, Ordering::Relaxed);
        }
        v.resize(n, 0.0);
    }
}

/// Take this thread's (A, B) panel buffers. Borrows are released before
/// returning, so a stripe running on the caller thread can take panels
/// while the same thread's transpose buffer is checked out.
pub(crate) fn take_panels() -> (Vec<f32>, Vec<f32>) {
    SCRATCH.with(|s| {
        let mut s = s.borrow_mut();
        (std::mem::take(&mut s.ap), std::mem::take(&mut s.bp))
    })
}

pub(crate) fn put_panels(ap: Vec<f32>, bp: Vec<f32>) {
    SCRATCH.with(|s| {
        let mut s = s.borrow_mut();
        s.ap = ap;
        s.bp = bp;
    })
}

pub(crate) fn take_transpose() -> Vec<f32> {
    SCRATCH.with(|s| std::mem::take(&mut s.borrow_mut().tr))
}

pub(crate) fn put_transpose(tr: Vec<f32>) {
    SCRATCH.with(|s| s.borrow_mut().tr = tr)
}

/// Pre-size this thread's panel buffers (used by [`super::blocked::prewarm`]
/// to warm every pool worker to the largest panels any shape can need, so
/// later traffic never grows them).
pub(crate) fn warm_thread_panels(ap_len: usize, bp_len: usize) {
    SCRATCH.with(|s| {
        let mut s = s.borrow_mut();
        ensure_len(&mut s.ap, ap_len);
        ensure_len(&mut s.bp, bp_len);
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Unpacked reference for one tile: `Σ_l ap[l][r] · bp[l][j]`.
    fn tile_ref(kb: usize, ap: &[f32], bp: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0f32; MR * NR];
        for l in 0..kb {
            for r in 0..MR {
                for j in 0..NR {
                    out[r * NR + j] += ap[l * MR + r] * bp[l * NR + j];
                }
            }
        }
        out
    }

    fn panel(seed: u64, len: usize) -> Vec<f32> {
        let mut rng = crate::util::rng::Xoshiro256pp::new(seed);
        (0..len).map(|_| rng.next_f32() * 2.0 - 1.0).collect()
    }

    #[test]
    fn scalar_tile_matches_unpacked_reference() {
        for kb in [1usize, 2, 7, 64] {
            let ap = panel(kb as u64, kb * MR);
            let bp = panel(kb as u64 ^ 0xB, kb * NR);
            let mut out = [0.0f32; MR * NR];
            tile_scalar(kb, &ap, &bp, &mut out);
            let want = tile_ref(kb, &ap, &bp);
            crate::testutil::assert_allclose(&out, &want, 1e-5, 1e-5);
        }
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn avx2_tile_matches_scalar_tile() {
        if hw_kernel() != KernelKind::Avx2 {
            return; // host without AVX2+FMA: nothing to compare
        }
        for kb in [1usize, 3, 17, 256] {
            let ap = panel(kb as u64 + 5, kb * MR);
            let bp = panel(kb as u64 + 55, kb * NR);
            let mut simd = [0.0f32; MR * NR];
            let mut scalar = [0.0f32; MR * NR];
            unsafe { tile_avx2(kb, &ap, &bp, &mut simd) };
            tile_scalar(kb, &ap, &bp, &mut scalar);
            // FMA fuses the rounding step, so allow f32 tolerance.
            crate::testutil::assert_allclose(&simd, &scalar, 1e-4, 1e-4);
        }
    }

    #[cfg(target_arch = "aarch64")]
    #[test]
    fn neon_tile_matches_scalar_tile() {
        assert_eq!(hw_kernel(), KernelKind::Neon, "NEON is baseline on AArch64");
        for kb in [1usize, 3, 17, 256] {
            let ap = panel(kb as u64 + 5, kb * MR);
            let bp = panel(kb as u64 + 55, kb * NR);
            let mut simd = [0.0f32; MR * NR];
            let mut scalar = [0.0f32; MR * NR];
            unsafe { tile_neon(kb, &ap, &bp, &mut simd) };
            tile_scalar(kb, &ap, &bp, &mut scalar);
            // vfmaq fuses the rounding step, so allow f32 tolerance.
            crate::testutil::assert_allclose(&simd, &scalar, 1e-4, 1e-4);
        }
    }

    #[test]
    fn pack_a_layout_and_padding() {
        // 4×3 block of a 5×4 matrix at origin (1,1): one MR panel, rows
        // 4..MR zero-padded.
        let lda = 4;
        let a: Vec<f32> = (0..20).map(|v| v as f32).collect();
        let (mb, kb) = (4usize, 3usize);
        let mut ap = vec![f32::NAN; mb.div_ceil(MR) * MR * kb];
        pack_a(&a, lda, 1, 1, mb, kb, &mut ap);
        for l in 0..kb {
            for r in 0..MR {
                let want = if r < mb { a[(1 + r) * lda + 1 + l] } else { 0.0 };
                assert_eq!(ap[l * MR + r], want, "l={l} r={r}");
            }
        }
    }

    #[test]
    fn pack_b_layouts_are_bit_identical() {
        // The same logical k×n operand stored both ways must pack to the
        // same panels — the bit-identity foundation of NT vs TNN.
        let (k, n) = (7usize, 21usize);
        let b_kxn = panel(1, k * n);
        let mut b_nxk = vec![0.0f32; n * k];
        for l in 0..k {
            for j in 0..n {
                b_nxk[j * k + l] = b_kxn[l * n + j];
            }
        }
        let (l0, j0, kb, nb) = (2usize, 3usize, 4usize, 18usize);
        let len = nb.div_ceil(NR) * NR * kb;
        let mut from_kxn = vec![f32::NAN; len];
        let mut from_nxk = vec![f32::NAN; len];
        pack_b(&b_kxn, BLayout::KxN, l0, j0, kb, nb, k, n, &mut from_kxn);
        pack_b(&b_nxk, BLayout::NxK, l0, j0, kb, nb, k, n, &mut from_nxk);
        assert_eq!(from_kxn, from_nxk);
        // Spot-check values and padding.
        assert_eq!(from_kxn[0], b_kxn[l0 * n + j0]);
        let cols2 = nb - NR; // second panel has nb-NR=2 valid columns
        assert_eq!(from_kxn[kb * NR + cols2], 0.0, "padding must be zero");
    }

    #[test]
    fn env_hatch_parsing() {
        assert!(!env_disables_simd(None));
        assert!(!env_disables_simd(Some("".into())));
        assert!(!env_disables_simd(Some("0".into())));
        assert!(env_disables_simd(Some("1".into())));
        assert!(env_disables_simd(Some("yes".into())));
    }

    #[test]
    fn forced_kernel_override_applies_per_section() {
        // Assertions live *inside* the serialized sections: outside them
        // another test's forced section may be active concurrently.
        with_forced_kernel(Some(KernelKind::Scalar), || {
            assert_eq!(active_kernel(), KernelKind::Scalar);
            assert_eq!(FORCED.load(Ordering::Relaxed), 1);
        });
        with_forced_kernel(Some(KernelKind::Avx2), || {
            assert_eq!(active_kernel(), hw_kernel());
        });
        with_forced_kernel(None, || {
            assert_eq!(FORCED.load(Ordering::Relaxed), 0);
            assert_eq!(active_kernel(), detected());
        });
    }

    #[test]
    fn available_kernels_always_include_scalar() {
        let av = available_kernels();
        assert!(av.contains(&KernelKind::Scalar));
        assert!(av.len() <= 2);
    }

    #[test]
    fn scratch_roundtrip_and_growth_counting() {
        let (ap, bp) = take_panels();
        put_panels(ap, bp);
        let g0 = scratch_grow_events();
        let mut v = take_transpose();
        let target = v.capacity().max(16) * 2;
        ensure_len(&mut v, target);
        assert!(scratch_grow_events() > g0, "capacity growth must count");
        // Re-ensuring a satisfied length must not reallocate (the counter
        // itself is global, so check the buffer identity instead).
        let (cap, ptr) = (v.capacity(), v.as_ptr());
        ensure_len(&mut v, target);
        assert_eq!(v.capacity(), cap);
        assert_eq!(v.as_ptr(), ptr);
        put_transpose(v);
    }
}
