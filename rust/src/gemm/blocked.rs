//! Cache-blocked, multi-threaded native GEMM — the high-performance CPU
//! execution backend of the GEMM service.
//!
//! # Tiling scheme
//!
//! The classic three-level blocking (Goto & van de Geijn):
//!
//! * **NC** columns of `C`/`B` per outer block — bounds the packed B panel;
//! * **KC** depth per block — the panel `bp` is `KC × NC` f32 (256 KiB),
//!   sized to live in L2 while it is reused by every row block;
//! * **MC** rows of `A` per block — the stripe of `A` touched per panel
//!   stays L1/L2-resident;
//! * **MR** register rows — the micro-kernel keeps `MR × NC` accumulators
//!   on the stack and streams one packed B row against MR broadcast A
//!   elements, which the compiler auto-vectorizes over the `j` axis.
//!
//! On top, [`std::thread::scope`] splits `C` into disjoint row stripes, one
//! per core (row-block parallelism; no synchronization in the hot loop).
//!
//! # Why this mirrors the paper's NT vs TNN argument
//!
//! The paper's §IV observation is that `C = A × Bᵀ` has two implementations
//! whose relative speed is a *memory-access-pattern* question: the direct
//! NT kernel reads `B` with a transposed access pattern, while Algorithm 1
//! (TNN) pays an out-of-place transpose once to make every subsequent read
//! sequential. The packed-panel design here is the CPU analogue: for
//! [`matmul_nt`] the packing step itself performs the transposed gather
//! (`bp[l][j] = B[j][l]`) on a panel-sized working set, while
//! [`matmul_tnn`] materializes `Bᵀ` with a tiled out-of-place
//! [`transpose`] — exactly Algorithm 1 — and then runs the sequential-read
//! NN kernel. Both routes feed bit-identical packed panels to the same
//! micro-kernel, so their outputs are bit-identical; what differs is where
//! the transposed traffic happens, which is the effect MTNN learns to
//! predict on GPUs.
//!
//! Everything is validated against the naive [`super::cpu`] oracle (see the
//! tests and `rust/tests/prop_invariants.rs`).

use super::cpu::Matrix;

/// Rows of A per cache block.
const MC: usize = 64;
/// Shared dimension per cache block.
const KC: usize = 256;
/// Columns of C per cache block (also the packed-panel width).
const NC: usize = 256;
/// Register-blocked rows per micro-kernel invocation.
const MR: usize = 4;

/// How the B operand is stored relative to the logical `k × n` operand the
/// kernel consumes.
#[derive(Debug, Clone, Copy)]
enum BLayout {
    /// B is stored row-major `k × n` — plain NN.
    KxN,
    /// B is stored row-major `n × k`; the packing step transposes panels
    /// on the fly — the direct NT access pattern.
    NxK,
}

/// `C[m,n] = A[m,k] × B[k,n]` — blocked, packed, multi-threaded.
pub fn matmul_nn(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.rows, "NN inner-dim mismatch");
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut c = Matrix::zeros(m, n);
    gemm(&a.data, &b.data, BLayout::KxN, &mut c.data, m, k, n, auto_threads(m, n, k));
    c
}

/// `C[m,n] = A[m,k] × B[n,k]ᵀ` — the paper's direct NT call: no transpose
/// is materialized; the packing step gathers B panels transposed.
pub fn matmul_nt(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.cols, "NT inner-dim mismatch (B is n×k)");
    let (m, k, n) = (a.rows, a.cols, b.rows);
    let mut c = Matrix::zeros(m, n);
    gemm(&a.data, &b.data, BLayout::NxK, &mut c.data, m, k, n, auto_threads(m, n, k));
    c
}

/// `C[m,n] = A[m,k] × B[n,k]ᵀ` via the paper's Algorithm 1: materialize
/// `Bᵀ` with a tiled out-of-place [`transpose`], then run the NN kernel.
/// Bit-identical to [`matmul_nt`] (both feed the same packed panels to the
/// same micro-kernel); only the location of the transposed traffic differs.
pub fn matmul_tnn(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.cols, "NT inner-dim mismatch (B is n×k)");
    let bt = transpose(b);
    matmul_nn(a, &bt)
}

/// Tiled out-of-place transpose (the CPU analogue of the paper's
/// Algorithm 1 transpose kernel). Bit-identical to [`Matrix::transpose`];
/// the 32×32 tiling keeps both source rows and destination columns within
/// cache lines instead of striding the full matrix.
pub fn transpose(src: &Matrix) -> Matrix {
    const TB: usize = 32;
    let (r, c) = (src.rows, src.cols);
    let mut out = Matrix::zeros(c, r);
    for i0 in (0..r).step_by(TB) {
        let i_end = (i0 + TB).min(r);
        for j0 in (0..c).step_by(TB) {
            let j_end = (j0 + TB).min(c);
            for i in i0..i_end {
                let row = &src.data[i * c..(i + 1) * c];
                for j in j0..j_end {
                    out.data[j * r + i] = row[j];
                }
            }
        }
    }
    out
}

/// Pick a thread count: one stripe per core, but never more threads than
/// rows, and stay single-threaded below ~2 MFLOP where spawn overhead
/// would dominate.
fn auto_threads(m: usize, n: usize, k: usize) -> usize {
    let flops = 2.0 * m as f64 * n as f64 * k as f64;
    if flops < 2e6 {
        return 1;
    }
    std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1)
        .min(m.max(1))
}

/// Full blocked GEMM: accumulate `A × B` into `c` (which must be zeroed),
/// splitting row stripes across `threads` scoped threads. Per-row results
/// are independent of the stripe partition, so outputs are deterministic
/// for any thread count.
fn gemm(a: &[f32], b: &[f32], layout: BLayout, c: &mut [f32], m: usize, k: usize, n: usize, threads: usize) {
    if m == 0 || n == 0 || k == 0 {
        return; // zero-sized product: c stays all-zero
    }
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(c.len(), m * n);
    if threads <= 1 {
        gemm_stripe(a, b, layout, c, m, k, n);
        return;
    }
    let rows_per = (m + threads - 1) / threads;
    std::thread::scope(|s| {
        for (ti, c_chunk) in c.chunks_mut(rows_per * n).enumerate() {
            let row0 = ti * rows_per;
            let rows = c_chunk.len() / n;
            let a_stripe = &a[row0 * k..(row0 + rows) * k];
            s.spawn(move || gemm_stripe(a_stripe, b, layout, c_chunk, rows, k, n));
        }
    });
}

/// One row stripe: the three-level blocked loop with B-panel packing.
fn gemm_stripe(a: &[f32], b: &[f32], layout: BLayout, c: &mut [f32], m: usize, k: usize, n: usize) {
    let mut bp = vec![0.0f32; KC.min(k) * NC.min(n)];
    for j0 in (0..n).step_by(NC) {
        let nb = NC.min(n - j0);
        for l0 in (0..k).step_by(KC) {
            let kb = KC.min(k - l0);
            pack_b(b, layout, l0, j0, kb, nb, k, n, &mut bp);
            for i0 in (0..m).step_by(MC) {
                let mb = MC.min(m - i0);
                micro_kernel(a, k, &bp, c, n, i0, mb, l0, kb, j0, nb);
            }
        }
    }
}

/// Pack the `kb × nb` panel of the logical `k × n` B operand starting at
/// `(l0, j0)` into `bp`, row-major. For [`BLayout::NxK`] this is where the
/// transposed gather happens (panel-sized, so the strided reads stay cache
/// resident) — the NT memory-access pattern.
#[allow(clippy::too_many_arguments)]
fn pack_b(b: &[f32], layout: BLayout, l0: usize, j0: usize, kb: usize, nb: usize, k: usize, n: usize, bp: &mut [f32]) {
    match layout {
        BLayout::KxN => {
            for l in 0..kb {
                let src = &b[(l0 + l) * n + j0..(l0 + l) * n + j0 + nb];
                bp[l * nb..l * nb + nb].copy_from_slice(src);
            }
        }
        BLayout::NxK => {
            // B row j is contiguous in l: read sequentially, scatter into
            // the panel columns.
            for j in 0..nb {
                let src = &b[(j0 + j) * k + l0..(j0 + j) * k + l0 + kb];
                for (l, &v) in src.iter().enumerate() {
                    bp[l * nb + j] = v;
                }
            }
        }
    }
}

/// Register-blocked micro-kernel: MR rows of A against the packed panel,
/// accumulating into stack-resident `MR × NC` buffers before a single
/// write-back pass into C.
#[allow(clippy::too_many_arguments)]
fn micro_kernel(
    a: &[f32],
    lda: usize,
    bp: &[f32],
    c: &mut [f32],
    ldc: usize,
    i0: usize,
    mb: usize,
    l0: usize,
    kb: usize,
    j0: usize,
    nb: usize,
) {
    let mut acc = [[0.0f32; NC]; MR];
    let mut i = 0;
    while i < mb {
        let rows = MR.min(mb - i);
        for accr in acc.iter_mut().take(rows) {
            accr[..nb].fill(0.0);
        }
        for l in 0..kb {
            let brow = &bp[l * nb..l * nb + nb];
            for (r, accr) in acc.iter_mut().enumerate().take(rows) {
                let av = a[(i0 + i + r) * lda + l0 + l];
                for (dst, &bv) in accr[..nb].iter_mut().zip(brow) {
                    *dst += av * bv;
                }
            }
        }
        for (r, accr) in acc.iter().enumerate().take(rows) {
            let base = (i0 + i + r) * ldc + j0;
            let crow = &mut c[base..base + nb];
            for (dst, &v) in crow.iter_mut().zip(&accr[..nb]) {
                *dst += v;
            }
        }
        i += rows;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::cpu;
    use crate::testutil::assert_allclose;
    use crate::testutil::prop::check;

    #[test]
    fn known_product() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(2, 2, vec![5.0, 6.0, 7.0, 8.0]);
        assert_eq!(matmul_nn(&a, &b).data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn unit_case_exact() {
        let a = Matrix::from_vec(1, 1, vec![3.0]);
        let b = Matrix::from_vec(1, 1, vec![-2.0]);
        assert_eq!(matmul_nn(&a, &b).data, vec![-6.0]);
        assert_eq!(matmul_nt(&a, &b).data, vec![-6.0]);
        assert_eq!(matmul_tnn(&a, &b).data, vec![-6.0]);
    }

    #[test]
    fn degenerate_and_prime_shapes_match_oracle() {
        // 1×N, N×1, odd/prime dims — the shapes where blocking remainders
        // do all the work.
        for &(m, n, k) in &[
            (1usize, 17usize, 5usize),
            (17, 1, 5),
            (5, 17, 1),
            (7, 13, 31),
            (31, 7, 13),
            (1, 1, 29),
            (3, 3, 3),
        ] {
            let a = Matrix::random(m, k, (m * 100 + n * 10 + k) as u64);
            let b_nn = Matrix::random(k, n, 99);
            let b_nt = Matrix::random(n, k, 77);
            assert_allclose(&matmul_nn(&a, &b_nn).data, &cpu::matmul_nn(&a, &b_nn).data, 1e-4, 1e-4);
            assert_allclose(&matmul_nt(&a, &b_nt).data, &cpu::matmul_nt(&a, &b_nt).data, 1e-4, 1e-4);
            assert_allclose(&matmul_tnn(&a, &b_nt).data, &cpu::matmul_tnn(&a, &b_nt).data, 1e-4, 1e-4);
        }
    }

    #[test]
    fn prop_blocked_matches_naive_oracle() {
        check("blocked nn/nt/tnn == naive oracle", 40, |g| {
            let m = g.usize_in(1, 33);
            let n = g.usize_in(1, 33);
            let k = g.usize_in(1, 33);
            let seed = g.i64_in(0, 1 << 30) as u64;
            let a = Matrix::random(m, k, seed);
            let b_nn = Matrix::random(k, n, seed ^ 0xA5A5);
            let b_nt = Matrix::random(n, k, seed ^ 0x5A5A);
            assert_allclose(&matmul_nn(&a, &b_nn).data, &cpu::matmul_nn(&a, &b_nn).data, 1e-4, 1e-4);
            assert_allclose(&matmul_nt(&a, &b_nt).data, &cpu::matmul_nt(&a, &b_nt).data, 1e-4, 1e-4);
            assert_allclose(&matmul_tnn(&a, &b_nt).data, &cpu::matmul_tnn(&a, &b_nt).data, 1e-4, 1e-4);
        });
    }

    #[test]
    fn blocked_nt_and_tnn_are_bit_identical() {
        // Both routes feed identical packed panels to the same kernel in
        // the same order; the results must agree exactly, not just within
        // tolerance (see the module docs).
        let a = Matrix::random(37, 53, 1);
        let b = Matrix::random(41, 53, 2);
        assert_eq!(matmul_nt(&a, &b).data, matmul_tnn(&a, &b).data);
    }

    #[test]
    fn threaded_path_matches_single_thread() {
        // Force the threaded path on shapes that straddle stripe
        // boundaries, including more threads than rows.
        for &(m, n, k, threads) in &[
            (37usize, 29usize, 23usize, 4usize),
            (8, 300, 300, 3),
            (2, 16, 16, 8),
            (65, 17, 513, 2),
        ] {
            let a = Matrix::random(m, k, 11);
            let b = Matrix::random(k, n, 12);
            let mut c_mt = Matrix::zeros(m, n);
            gemm(&a.data, &b.data, BLayout::KxN, &mut c_mt.data, m, k, n, threads);
            let mut c_st = Matrix::zeros(m, n);
            gemm(&a.data, &b.data, BLayout::KxN, &mut c_st.data, m, k, n, 1);
            // Same per-row operation order regardless of partition.
            assert_eq!(c_mt.data, c_st.data, "m={m} n={n} k={k} threads={threads}");
            assert_allclose(&c_mt.data, &cpu::matmul_nn(&a, &b).data, 1e-4, 1e-4);
        }
    }

    #[test]
    fn spans_multiple_cache_blocks() {
        // Exceed MC/KC/NC in every dimension so all block loops iterate.
        let (m, n, k) = (2 * MC + 5, NC + 7, KC + 9);
        let a = Matrix::random(m, k, 21);
        let b = Matrix::random(n, k, 22);
        assert_allclose(&matmul_nt(&a, &b).data, &cpu::matmul_nt(&a, &b).data, 2e-3, 2e-3);
    }

    #[test]
    fn tiled_transpose_is_exact() {
        let m = Matrix::random(45, 33, 6);
        assert_eq!(transpose(&m).data, m.transpose().data);
        let back = transpose(&transpose(&m));
        assert_eq!(back, m);
    }

    #[test]
    #[should_panic(expected = "mismatch")]
    fn shape_mismatch_panics() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(2, 4);
        matmul_nn(&a, &b);
    }
}
