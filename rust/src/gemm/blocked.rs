//! Cache-blocked, SIMD, pool-threaded native GEMM — the high-performance
//! CPU execution backend of the GEMM service.
//!
//! # Architecture (kernel / packing / pool / cooperation)
//!
//! * **Micro-kernels** ([`super::kernels`]) — an `MR×NR` (6×16)
//!   register-tiled kernel chosen by runtime dispatch: AVX2+FMA on x86-64,
//!   NEON on aarch64, with a portable scalar kernel as the reference path,
//!   the fallback for everything else, and the `MTNN_NO_SIMD=1` escape
//!   hatch. Kernels consume *packed panels only*: A in `MR`-row panels,
//!   B in `NR`-column panels, both zero-padded so remainders never branch
//!   in the kernel.
//! * **Cache blocking** — the classic Goto three-level loop: `NC` columns
//!   (packed-B working set), `KC` depth (panels sized for L2), `MC` rows
//!   (A panels stay L1/L2-resident).
//! * **Persistent pool** ([`super::pool`]) — parked worker threads plus
//!   the participating caller replace the old per-call `thread::scope`
//!   spawns; [`auto_threads`] sizes the split with a cost model built on
//!   the pool's *measured* dispatch overhead (constants documented on the
//!   function).
//! * **Cooperative shared packing** ([`gemm_shared`], the multi-stripe
//!   path) — packing work is done once per cache block and shared,
//!   instead of once per stripe: the pool packs every A panel of a
//!   `KC`-deep slab in parallel (one task per `MC` block, disjoint writes
//!   into one shared buffer — `MC % MR == 0` keeps panel boundaries
//!   aligned), the caller packs each `KC×NC` B block exactly once, and
//!   only then do compute stripes fan out, reading both buffers
//!   read-only. The per-stripe legacy loop ([`gemm_stripe`]) packed the
//!   *same* B panels in every stripe (`stripes×` redundant gathers) and
//!   re-packed its A panels once per `NC` column; it remains the
//!   single-thread path and the reference the shared path must match
//!   bit-for-bit.
//!
//! Two scratch tiers back this: per-thread panel/transpose buffers in
//! [`super::kernels`] (thread-local, [`prewarm`]-able to a
//! shape-independent maximum, growth counted by
//! [`super::kernels::scratch_grow_events`]) serve the single-stripe path,
//! while the shared path checks shape-sized buffers out of a process-wide
//! pool (growth counted separately by [`shared_scratch_grow_events`]).
//! Steady-state traffic allocates in neither tier.
//!
//! **NUMA seam**: when `MTNN_NUMA=1` opts in and
//! [`super::pool::numa_nodes`] detects a multi-node machine, the shared
//! path replicates each packed B block per node and compute lanes read
//! the copy at `lane % nodes` ([`super::pool::current_lane`]). This is a
//! placement *hint* — `std` cannot pin threads — and on single-node or
//! ungated machines the replica set is empty and the code path is
//! byte-identical to pre-seam behavior.
//!
//! Per-row summation order is fixed (depth within a `KC` block, blocks in
//! ascending order) and independent of both the stripe partition and the
//! packing strategy, so outputs are deterministic for any thread count —
//! and the shared path is asserted *bit-identical* to the striped
//! reference in the tests, not merely close.
//!
//! # Why this mirrors the paper's NT vs TNN argument
//!
//! The paper's §IV observation is that `C = A × Bᵀ` has two implementations
//! whose relative speed is a *memory-access-pattern* question: the direct
//! NT kernel reads `B` with a transposed access pattern, while Algorithm 1
//! (TNN) pays an out-of-place transpose once to make every subsequent read
//! sequential. The packed-panel design here is the CPU analogue: for
//! [`matmul_nt`] the packing step itself performs the transposed gather
//! (`bp[l][j] = B[j][l]`) on a panel-sized working set, while
//! [`matmul_tnn`] materializes `Bᵀ` with a tiled out-of-place transpose
//! (into thread-local scratch) — exactly Algorithm 1 — and then runs the
//! sequential-read NN path. Both routes feed bit-identical packed panels
//! to the same micro-kernel in the same order, so their outputs are
//! bit-identical **on both the SIMD and scalar paths**; what differs is
//! where the transposed traffic happens, which is the effect MTNN learns
//! to predict on GPUs.
//!
//! Everything is validated against the naive [`super::cpu`] oracle (see
//! the tests and `rust/tests/prop_invariants.rs`; pool behaviour is
//! covered by `rust/tests/pool_hygiene.rs`).

use super::cpu::Matrix;
use super::kernels::{self, BLayout, KernelKind, MR, NR};
use super::pool;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Rows of A per cache block (multiple of `MR`).
pub const MC: usize = 72;
/// Shared dimension per cache block.
pub const KC: usize = 256;
/// Columns of C per cache block (multiple of `NR`; bounds the packed-B
/// working set).
pub const NC: usize = 256;

/// Largest packed-A scratch any shape can need (`MC/MR` panels of
/// `MR × KC`).
const AP_CAP: usize = MC.div_ceil(MR) * MR * KC;
/// Largest packed-B scratch any shape can need (`NC/NR` panels of
/// `KC × NR`).
const BP_CAP: usize = NC.div_ceil(NR) * NR * KC;

/// `C[m,n] = A[m,k] × B[k,n]` — blocked, packed, SIMD, pool-threaded.
pub fn matmul_nn(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.rows, "NN inner-dim mismatch");
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut c = Matrix::zeros(m, n);
    gemm(&a.data, &b.data, BLayout::KxN, &mut c.data, m, k, n, auto_threads(m, n, k));
    c
}

/// `C[m,n] = A[m,k] × B[n,k]ᵀ` — the paper's direct NT call: no transpose
/// is materialized; the packing step gathers B panels transposed.
pub fn matmul_nt(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.cols, "NT inner-dim mismatch (B is n×k)");
    let (m, k, n) = (a.rows, a.cols, b.rows);
    let mut c = Matrix::zeros(m, n);
    gemm(&a.data, &b.data, BLayout::NxK, &mut c.data, m, k, n, auto_threads(m, n, k));
    c
}

/// `C[m,n] = A[m,k] × B[n,k]ᵀ` via the paper's Algorithm 1: materialize
/// `Bᵀ` with a tiled out-of-place transpose into thread-local scratch,
/// then run the NN path. Bit-identical to [`matmul_nt`] (both feed the
/// same packed panels to the same micro-kernel); only the location of the
/// transposed traffic differs.
pub fn matmul_tnn(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.cols, "NT inner-dim mismatch (B is n×k)");
    let (m, k, n) = (a.rows, a.cols, b.rows);
    let mut c = Matrix::zeros(m, n);
    if m == 0 || n == 0 || k == 0 {
        return c;
    }
    let mut bt = kernels::take_transpose();
    kernels::ensure_len(&mut bt, k * n);
    transpose_into(&b.data, b.rows, b.cols, &mut bt);
    gemm(&a.data, &bt[..k * n], BLayout::KxN, &mut c.data, m, k, n, auto_threads(m, n, k));
    kernels::put_transpose(bt);
    c
}

/// `C[m,n] = A[k,m]ᵀ × B[k,n]` — Caffe's backward-weights TN call:
/// transpose `A` out-of-place into thread-local scratch (the same
/// Algorithm-1 trick as [`matmul_tnn`]), then run the NN path.
/// Bit-identical to `matmul_nn(&transpose(a), b)` without the intermediate
/// allocation.
pub fn matmul_tn(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.rows, b.rows, "TN inner-dim mismatch (A is k×m)");
    let (k, m, n) = (a.rows, a.cols, b.cols);
    let mut c = Matrix::zeros(m, n);
    if m == 0 || n == 0 || k == 0 {
        return c;
    }
    let mut at = kernels::take_transpose();
    kernels::ensure_len(&mut at, m * k);
    transpose_into(&a.data, a.rows, a.cols, &mut at);
    gemm(&at[..m * k], &b.data, BLayout::KxN, &mut c.data, m, k, n, auto_threads(m, n, k));
    kernels::put_transpose(at);
    c
}

/// Tiled out-of-place transpose (the CPU analogue of the paper's
/// Algorithm 1 transpose kernel). Bit-identical to [`Matrix::transpose`];
/// the 32×32 tiling keeps both source rows and destination columns within
/// cache lines instead of striding the full matrix.
pub fn transpose(src: &Matrix) -> Matrix {
    let mut out = Matrix::zeros(src.cols, src.rows);
    transpose_into(&src.data, src.rows, src.cols, &mut out.data);
    out
}

/// `dst[j*rows + i] = src[i*cols + j]`, 32×32 tiled.
fn transpose_into(src: &[f32], rows: usize, cols: usize, dst: &mut [f32]) {
    const TB: usize = 32;
    debug_assert!(src.len() >= rows * cols && dst.len() >= rows * cols);
    for i0 in (0..rows).step_by(TB) {
        let i_end = (i0 + TB).min(rows);
        for j0 in (0..cols).step_by(TB) {
            let j_end = (j0 + TB).min(cols);
            for i in i0..i_end {
                let row = &src[i * cols..(i + 1) * cols];
                for j in j0..j_end {
                    dst[j * rows + i] = row[j];
                }
            }
        }
    }
}

/// Warm the execution path: spawn the persistent pool (measuring its
/// dispatch overhead) and pre-size every pool thread's packing *panels*
/// to the shape-independent maximum, so steady-state traffic neither
/// spawns threads nor allocates panel scratch. The TNN/TN transpose
/// buffer is shape-sized (`k × n`, unbounded) and therefore warms on the
/// first such call per shape per thread instead. Called by backend warmup
/// and the native trainer; safe (and cheap) to call repeatedly.
pub fn prewarm() {
    let p = pool::get();
    p.broadcast(&|| kernels::warm_thread_panels(AP_CAP, BP_CAP));
    kernels::warm_thread_panels(AP_CAP, BP_CAP);
}

// ---- threading policy -------------------------------------------------------

/// Assumed sustained single-core kernel throughput, in flops per
/// nanosecond. Deliberately on the high side of what the scalar kernel
/// reaches so the model *under*-threads rather than over-threads (an AVX2
/// core peaks at ~2×8×2 flops/cycle; 12 flops/ns ≈ a third of that at
/// 3 GHz).
const EST_FLOPS_PER_NS: f64 = 12.0;
/// A stripe must carry at least this multiple of the measured dispatch
/// overhead in estimated compute for a pool hand-off to pay for itself.
const DISPATCH_AMORTIZE: f64 = 4.0;
/// Work below this many flops (≈ 5 µs of estimated compute, well under
/// any plausible dispatch round-trip) stays inline without even touching —
/// and therefore lazily initializing — the pool.
const INLINE_FLOPS: f64 = 64_000.0;

/// Pool-aware splitting heuristic, replacing the old hard 2-MFLOP cliff:
/// thread count is bounded by (i) the pool's parallelism, (ii) whole
/// `MR`-rows to stripe, and (iii) a cost model requiring each stripe's
/// estimated compute (`flops / EST_FLOPS_PER_NS`) to amortize the pool's
/// *measured* per-dispatch overhead `DISPATCH_AMORTIZE` times over.
fn auto_threads(m: usize, n: usize, k: usize) -> usize {
    let flops = 2.0 * m as f64 * n as f64 * k as f64;
    if flops < INLINE_FLOPS {
        return 1;
    }
    let pool = pool::get();
    if pool.parallelism() <= 1 {
        return 1;
    }
    let overhead_ns = (pool.dispatch_overhead_ns() as f64).max(200.0);
    let est_ns = flops / EST_FLOPS_PER_NS;
    let by_cost = (est_ns / (DISPATCH_AMORTIZE * overhead_ns)) as usize;
    let cap = pool.parallelism().min(m.div_ceil(MR));
    by_cost.clamp(1, cap.max(1))
}

// ---- shared-packing scratch -------------------------------------------------

/// How many shared packing buffers the checkout pool retains. A burst of
/// concurrent callers beyond this simply re-allocates for the excess.
const SHARED_SCRATCH_KEEP: usize = 8;

/// Checkout pool for the shared A/B packing buffers of [`gemm_shared`].
/// Deliberately separate from the kernels' thread-local scratch so
/// [`super::kernels::scratch_grow_events`] keeps meaning "per-thread panel
/// growth" and the pool-hygiene tests stay attributable.
static SHARED_SCRATCH: Mutex<Vec<Vec<f32>>> = Mutex::new(Vec::new());
static SHARED_GROW_EVENTS: AtomicU64 = AtomicU64::new(0);

/// Times a shared packing buffer had to (re)allocate. Flat at steady state
/// once the checkout pool holds buffers sized for the traffic.
pub fn shared_scratch_grow_events() -> u64 {
    SHARED_GROW_EVENTS.load(Ordering::Relaxed)
}

/// Check a buffer of at least `min_len` out of the shared pool, preferring
/// the roomiest retained buffer so repeat shapes stop growing quickly.
fn take_shared(min_len: usize) -> Vec<f32> {
    let mut v = {
        let mut pool = SHARED_SCRATCH.lock().unwrap_or_else(|e| e.into_inner());
        match (0..pool.len()).max_by_key(|&i| pool[i].capacity()) {
            Some(i) => pool.swap_remove(i),
            None => Vec::new(),
        }
    };
    if v.len() < min_len {
        if min_len > v.capacity() {
            SHARED_GROW_EVENTS.fetch_add(1, Ordering::Relaxed);
        }
        v.resize(min_len, 0.0);
    }
    v
}

fn put_shared(v: Vec<f32>) {
    let mut pool = SHARED_SCRATCH.lock().unwrap_or_else(|e| e.into_inner());
    if pool.len() < SHARED_SCRATCH_KEEP {
        pool.push(v);
    }
}

// ---- driver -----------------------------------------------------------------

/// Raw output pointer smuggled into stripe tasks; stripes write disjoint
/// row ranges.
struct SendPtr(*mut f32);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

/// Full blocked GEMM: accumulate `A × B` into `c` (which must be zeroed).
/// Single stripe runs the thread-local [`gemm_stripe`] loop; multi-stripe
/// runs the cooperative shared-packing path ([`gemm_shared`]). Per-row
/// results are independent of the partition and the packing strategy, so
/// outputs are deterministic — and bit-identical — for any thread count.
#[allow(clippy::too_many_arguments)]
fn gemm(
    a: &[f32],
    b: &[f32],
    layout: BLayout,
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
) {
    if m == 0 || n == 0 || k == 0 {
        return; // zero-sized product: c stays all-zero
    }
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(c.len(), m * n);
    let kind = kernels::active_kernel();
    let threads = threads.max(1);
    let rows_per = m.div_ceil(threads).div_ceil(MR) * MR;
    let stripes = m.div_ceil(rows_per);
    if stripes <= 1 {
        gemm_stripe(a, b, layout, c, m, k, n, kind);
        return;
    }
    gemm_shared(a, b, layout, c, m, k, n, kind, rows_per, stripes);
}

/// Panel boundaries of the shared A buffer must coincide with `MC`-block
/// boundaries for the parallel pack's disjoint-write argument to hold.
const _: () = assert!(MC % MR == 0);

/// Cooperative multi-stripe GEMM (see the module docs): per `KC` slab the
/// pool packs every A panel once in parallel, then per `KC×NC` B block the
/// caller packs B once (plus optional per-NUMA-node replicas) and compute
/// stripes fan out over the pool reading the shared panels. Identical
/// packed bits, kernel, and per-element accumulation order as
/// [`gemm_stripe`] ⇒ bit-identical output.
#[allow(clippy::too_many_arguments)]
fn gemm_shared(
    a: &[f32],
    b: &[f32],
    layout: BLayout,
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    kind: KernelKind,
    rows_per: usize,
    stripes: usize,
) {
    let pool = pool::get();
    let kc = KC.min(k);
    let total_panels = m.div_ceil(MR);
    let mut ap = take_shared(total_panels * MR * kc);
    let bp_len = NC.min(n).div_ceil(NR) * NR * kc;
    let mut bp = take_shared(bp_len);
    // Per-NUMA-node B replicas: empty unless MTNN_NUMA opts in on a
    // multi-node machine, in which case node 0 shares the primary buffer
    // and nodes 1.. read their own copy.
    let nodes = pool::numa_nodes();
    let mut replicas: Vec<Vec<f32>> = (1..nodes).map(|_| take_shared(bp_len)).collect();
    let mc_blocks = m.div_ceil(MC);
    for l0 in (0..k).step_by(KC) {
        let kb = KC.min(k - l0);
        let ap_ptr = SendPtr(ap.as_mut_ptr());
        pool.run(mc_blocks, &|t| {
            let i0 = t * MC;
            let mb = MC.min(m - i0);
            let off = (i0 / MR) * kb * MR;
            let len = mb.div_ceil(MR) * MR * kb;
            // SAFETY: MC % MR == 0, so block `t` exclusively owns packed
            // panels `i0/MR .. i0/MR + mb.div_ceil(MR)` — disjoint,
            // in-bounds ranges — and the caller blocks in `run` until
            // every pack task finishes.
            let dst = unsafe { std::slice::from_raw_parts_mut(ap_ptr.0.add(off), len) };
            kernels::pack_a(a, k, i0, l0, mb, kb, dst);
        });
        let ap_ro: &[f32] = &ap;
        for j0 in (0..n).step_by(NC) {
            let nb = NC.min(n - j0);
            let npanels = nb.div_ceil(NR);
            kernels::pack_b(b, layout, l0, j0, kb, nb, k, n, &mut bp);
            let used = npanels * kb * NR;
            for r in &mut replicas {
                r[..used].copy_from_slice(&bp[..used]);
            }
            let bp_ro: &[f32] = &bp;
            let replicas_ro: &[Vec<f32>] = &replicas;
            let c_ptr = SendPtr(c.as_mut_ptr());
            pool.run(stripes, &|t| {
                let row0 = t * rows_per;
                let rows = rows_per.min(m - row0);
                // Bias reads toward the executing lane's node-local copy
                // (lane % nodes == 0 shares the primary buffer).
                let node = pool::current_lane() % nodes;
                let my_bp = if node == 0 { bp_ro } else { &replicas_ro[node - 1][..] };
                // SAFETY: stripe `t` exclusively owns rows
                // `row0..row0+rows` of `c`; ranges are disjoint across
                // tasks and in-bounds, and the caller blocks in `run`
                // until all stripes finish.
                let c_chunk =
                    unsafe { std::slice::from_raw_parts_mut(c_ptr.0.add(row0 * n), rows * n) };
                let mut tile = [0.0f32; MR * NR];
                // rows_per is MR-aligned, so panels never straddle stripes.
                let p0 = row0 / MR;
                let pend = (row0 + rows).div_ceil(MR);
                for jp in 0..npanels {
                    let cols = NR.min(nb - jp * NR);
                    let bpan = &my_bp[jp * kb * NR..(jp + 1) * kb * NR];
                    for p in p0..pend {
                        let prows = MR.min(m - p * MR);
                        let apan = &ap_ro[p * kb * MR..(p + 1) * kb * MR];
                        kernels::tile(kind, kb, apan, bpan, &mut tile);
                        merge_tile(c_chunk, n, p * MR - row0, j0 + jp * NR, prows, cols, &tile);
                    }
                }
            });
        }
    }
    put_shared(ap);
    put_shared(bp);
    for r in replicas {
        put_shared(r);
    }
}

/// Per-call `thread::scope` variant of [`matmul_nt`], kept solely so
/// `perf_hotpath` can price the persistent pool against the spawn-per-call
/// strategy it replaced. Not a serving API.
#[doc(hidden)]
pub fn matmul_nt_scoped(a: &Matrix, b: &Matrix, threads: usize) -> Matrix {
    assert_eq!(a.cols, b.cols, "NT inner-dim mismatch (B is n×k)");
    let (m, k, n) = (a.rows, a.cols, b.rows);
    let mut c = Matrix::zeros(m, n);
    if m == 0 || n == 0 || k == 0 {
        return c;
    }
    let kind = kernels::active_kernel();
    let threads = threads.max(1);
    let rows_per = m.div_ceil(threads).div_ceil(MR) * MR;
    if m.div_ceil(rows_per) <= 1 {
        gemm_stripe(&a.data, &b.data, BLayout::NxK, &mut c.data, m, k, n, kind);
        return c;
    }
    std::thread::scope(|s| {
        for (ti, c_chunk) in c.data.chunks_mut(rows_per * n).enumerate() {
            let row0 = ti * rows_per;
            let rows = c_chunk.len() / n;
            let a_stripe = &a.data[row0 * k..(row0 + rows) * k];
            let b = &b.data;
            s.spawn(move || gemm_stripe(a_stripe, b, BLayout::NxK, c_chunk, rows, k, n, kind));
        }
    });
    c
}

/// One row stripe: the three-level blocked loop over panels packed into
/// this thread's reusable scratch.
#[allow(clippy::too_many_arguments)]
fn gemm_stripe(
    a: &[f32],
    b: &[f32],
    layout: BLayout,
    c: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    kind: KernelKind,
) {
    let (mut ap, mut bp) = kernels::take_panels();
    let kc = KC.min(k);
    kernels::ensure_len(&mut ap, MC.min(m).div_ceil(MR) * MR * kc);
    kernels::ensure_len(&mut bp, NC.min(n).div_ceil(NR) * NR * kc);
    let mut tile = [0.0f32; MR * NR];
    for j0 in (0..n).step_by(NC) {
        let nb = NC.min(n - j0);
        let npanels = nb.div_ceil(NR);
        for l0 in (0..k).step_by(KC) {
            let kb = KC.min(k - l0);
            kernels::pack_b(b, layout, l0, j0, kb, nb, k, n, &mut bp);
            for i0 in (0..m).step_by(MC) {
                let mb = MC.min(m - i0);
                let mpanels = mb.div_ceil(MR);
                kernels::pack_a(a, k, i0, l0, mb, kb, &mut ap);
                for jp in 0..npanels {
                    let cols = NR.min(nb - jp * NR);
                    let bpan = &bp[jp * kb * NR..(jp + 1) * kb * NR];
                    for ip in 0..mpanels {
                        let rows = MR.min(mb - ip * MR);
                        let apan = &ap[ip * kb * MR..(ip + 1) * kb * MR];
                        kernels::tile(kind, kb, apan, bpan, &mut tile);
                        merge_tile(c, n, i0 + ip * MR, j0 + jp * NR, rows, cols, &tile);
                    }
                }
            }
        }
    }
    kernels::put_panels(ap, bp);
}

/// Accumulate the valid `rows × cols` sub-rectangle of a computed tile
/// into `C` (padded lanes hold exact zeros and are skipped).
#[allow(clippy::too_many_arguments)]
fn merge_tile(
    c: &mut [f32],
    ldc: usize,
    i0: usize,
    j0: usize,
    rows: usize,
    cols: usize,
    tile: &[f32; MR * NR],
) {
    for r in 0..rows {
        let base = (i0 + r) * ldc + j0;
        let crow = &mut c[base..base + cols];
        for (dst, &v) in crow.iter_mut().zip(&tile[r * NR..r * NR + cols]) {
            *dst += v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::cpu;
    use crate::testutil::assert_allclose;
    use crate::testutil::prop::check;

    #[test]
    fn known_product() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(2, 2, vec![5.0, 6.0, 7.0, 8.0]);
        assert_eq!(matmul_nn(&a, &b).data, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn unit_case_exact() {
        let a = Matrix::from_vec(1, 1, vec![3.0]);
        let b = Matrix::from_vec(1, 1, vec![-2.0]);
        assert_eq!(matmul_nn(&a, &b).data, vec![-6.0]);
        assert_eq!(matmul_nt(&a, &b).data, vec![-6.0]);
        assert_eq!(matmul_tnn(&a, &b).data, vec![-6.0]);
    }

    #[test]
    fn degenerate_and_prime_shapes_match_oracle() {
        // 1×N, N×1, odd/prime dims — the shapes where blocking remainders
        // do all the work.
        for &(m, n, k) in &[
            (1usize, 17usize, 5usize),
            (17, 1, 5),
            (5, 17, 1),
            (7, 13, 31),
            (31, 7, 13),
            (1, 1, 29),
            (3, 3, 3),
        ] {
            let a = Matrix::random(m, k, (m * 100 + n * 10 + k) as u64);
            let b_nn = Matrix::random(k, n, 99);
            let b_nt = Matrix::random(n, k, 77);
            assert_allclose(&matmul_nn(&a, &b_nn).data, &cpu::matmul_nn(&a, &b_nn).data, 1e-4, 1e-4);
            assert_allclose(&matmul_nt(&a, &b_nt).data, &cpu::matmul_nt(&a, &b_nt).data, 1e-4, 1e-4);
            assert_allclose(&matmul_tnn(&a, &b_nt).data, &cpu::matmul_tnn(&a, &b_nt).data, 1e-4, 1e-4);
        }
    }

    #[test]
    fn prop_blocked_matches_naive_oracle() {
        check("blocked nn/nt/tnn == naive oracle", 40, |g| {
            let m = g.usize_in(1, 33);
            let n = g.usize_in(1, 33);
            let k = g.usize_in(1, 33);
            let seed = g.i64_in(0, 1 << 30) as u64;
            let a = Matrix::random(m, k, seed);
            let b_nn = Matrix::random(k, n, seed ^ 0xA5A5);
            let b_nt = Matrix::random(n, k, seed ^ 0x5A5A);
            assert_allclose(&matmul_nn(&a, &b_nn).data, &cpu::matmul_nn(&a, &b_nn).data, 1e-4, 1e-4);
            assert_allclose(&matmul_nt(&a, &b_nt).data, &cpu::matmul_nt(&a, &b_nt).data, 1e-4, 1e-4);
            assert_allclose(&matmul_tnn(&a, &b_nt).data, &cpu::matmul_tnn(&a, &b_nt).data, 1e-4, 1e-4);
        });
    }

    #[test]
    fn blocked_nt_and_tnn_are_bit_identical() {
        // Both routes feed identical packed panels to the same kernel in
        // the same order; the results must agree exactly, not just within
        // tolerance (see the module docs). Pin the kernel choice so a
        // concurrent forced-kernel section can't flip it mid-test.
        kernels::with_forced_kernel(None, || {
            let a = Matrix::random(37, 53, 1);
            let b = Matrix::random(41, 53, 2);
            assert_eq!(matmul_nt(&a, &b).data, matmul_tnn(&a, &b).data);
        });
    }

    #[test]
    fn matmul_tn_matches_transpose_then_nn_exactly() {
        kernels::with_forced_kernel(None, || {
            let a = Matrix::random(29, 37, 5); // k×m
            let b = Matrix::random(29, 17, 6); // k×n
            let via_scratch = matmul_tn(&a, &b);
            let via_alloc = matmul_nn(&transpose(&a), &b);
            assert_eq!(via_scratch.data, via_alloc.data);
            assert_allclose(
                &via_scratch.data,
                &cpu::matmul_nn(&a.transpose(), &b).data,
                1e-4,
                1e-4,
            );
        });
    }

    #[test]
    fn threaded_path_matches_single_thread() {
        // Force the threaded path on shapes that straddle stripe
        // boundaries, including more threads than rows.
        for &(m, n, k, threads) in &[
            (37usize, 29usize, 23usize, 4usize),
            (8, 300, 300, 3),
            (2, 16, 16, 8),
            (65, 17, 513, 2),
        ] {
            let a = Matrix::random(m, k, 11);
            let b = Matrix::random(k, n, 12);
            let mut c_mt = Matrix::zeros(m, n);
            gemm(&a.data, &b.data, BLayout::KxN, &mut c_mt.data, m, k, n, threads);
            let mut c_st = Matrix::zeros(m, n);
            gemm(&a.data, &b.data, BLayout::KxN, &mut c_st.data, m, k, n, 1);
            // Same per-row operation order regardless of partition.
            assert_eq!(c_mt.data, c_st.data, "m={m} n={n} k={k} threads={threads}");
            assert_allclose(&c_mt.data, &cpu::matmul_nn(&a, &b).data, 1e-4, 1e-4);
        }
    }

    #[test]
    fn shared_packing_is_bit_identical_to_striped_reference() {
        // gemm_shared must be a pure scheduling change: same packed bits,
        // same kernel, same per-element accumulation order (ascending l0,
        // ascending depth inside the kernel) ⇒ assert_eq, not allclose.
        // Shapes chosen to hit partial MC blocks, partial panels, multiple
        // KC slabs, and more requested threads than rows.
        kernels::with_forced_kernel(None, || {
            let kind = kernels::active_kernel();
            for &(m, n, k, threads) in &[
                (150usize, 96usize, 300usize, 3usize),
                (2 * MC + 5, NC + 7, KC + 9, 4),
                (13, 500, 64, 5),
                (MC, NC, KC, 2),
            ] {
                let a = Matrix::random(m, k, 31);
                let b = Matrix::random(k, n, 32);
                let mut c_shared = Matrix::zeros(m, n);
                gemm(&a.data, &b.data, BLayout::KxN, &mut c_shared.data, m, k, n, threads);
                let mut c_ref = Matrix::zeros(m, n);
                gemm_stripe(&a.data, &b.data, BLayout::KxN, &mut c_ref.data, m, k, n, kind);
                assert_eq!(c_shared.data, c_ref.data, "m={m} n={n} k={k} threads={threads}");
            }
        });
    }

    #[test]
    fn shared_scratch_reaches_allocation_free_steady_state() {
        let (m, n, k) = (2 * MC, NC, 2 * KC);
        let a = Matrix::random(m, k, 41);
        let b = Matrix::random(k, n, 42);
        let mut c = Matrix::zeros(m, n);
        // Other tests may run concurrently and check buffers in and out of
        // the process-global pool, so demand convergence rather than an
        // exact count: some repeat of the same shape must stop growing.
        let mut stable = false;
        for _ in 0..10 {
            let before = shared_scratch_grow_events();
            c.data.fill(0.0);
            gemm(&a.data, &b.data, BLayout::KxN, &mut c.data, m, k, n, 4);
            if shared_scratch_grow_events() == before {
                stable = true;
                break;
            }
        }
        assert!(stable, "repeat-shape shared packing must stop allocating");
    }

    #[test]
    fn scoped_variant_matches_pooled_path() {
        kernels::with_forced_kernel(None, || {
            let a = Matrix::random(97, 71, 13);
            let b = Matrix::random(53, 71, 14);
            assert_eq!(matmul_nt_scoped(&a, &b, 4).data, matmul_nt(&a, &b).data);
        });
    }

    #[test]
    fn spans_multiple_cache_blocks() {
        // Exceed MC/KC/NC in every dimension so all block loops iterate.
        let (m, n, k) = (2 * MC + 5, NC + 7, KC + 9);
        let a = Matrix::random(m, k, 21);
        let b = Matrix::random(n, k, 22);
        assert_allclose(&matmul_nt(&a, &b).data, &cpu::matmul_nt(&a, &b).data, 2e-3, 2e-3);
    }

    #[test]
    fn tiled_transpose_is_exact() {
        let m = Matrix::random(45, 33, 6);
        assert_eq!(transpose(&m).data, m.transpose().data);
        let back = transpose(&transpose(&m));
        assert_eq!(back, m);
    }

    #[test]
    fn prewarm_is_idempotent() {
        prewarm();
        prewarm();
        // After prewarm, a bounded-panel GEMM must not grow pool scratch —
        // asserted for real in rust/tests/pool_hygiene.rs; here we only
        // check the call is safe to repeat.
    }

    #[test]
    #[should_panic(expected = "mismatch")]
    fn shape_mismatch_panics() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(2, 4);
        matmul_nn(&a, &b);
    }
}
