//! Native execution backend: serves the GEMM service's artifact catalog
//! names (`nt_MxNxK`, `tnn_MxNxK`, `nn_MxNxK`, `transpose_RxC`) with the
//! blocked CPU kernels from [`super::blocked`] instead of PJRT.
//!
//! This is the coordinator engine's non-PJRT path: the router and batcher
//! keep speaking artifact names, and the engine executes them natively when
//! no compiled catalog is present (`Engine::native`). Numerics match the
//! naive oracle within f32 tolerance because the blocked kernels are
//! validated against it.

use super::blocked;
use super::cpu::Matrix;

/// Stateless executor mapping artifact names onto blocked kernels.
#[derive(Debug, Clone, Copy, Default)]
pub struct NativeExecutor;

/// Parse `"512x256x128"` → `[512, 256, 128]` (or 2 dims for transpose).
/// Shared with the simulated-GPU executor, which speaks the same artifact
/// grammar ([`crate::gpusim::SimExecutor`]).
pub(crate) fn parse_dims(spec: &str, want: usize) -> anyhow::Result<Vec<usize>> {
    let dims: Vec<usize> = spec
        .split('x')
        .map(|p| p.parse::<usize>())
        .collect::<Result<_, _>>()
        .map_err(|_| anyhow::anyhow!("bad artifact dims '{spec}'"))?;
    anyhow::ensure!(
        dims.len() == want && dims.iter().all(|&d| d > 0),
        "artifact dims '{spec}': expected {want} positive sizes"
    );
    Ok(dims)
}

pub(crate) fn check_shape(
    name: &str,
    idx: usize,
    m: &Matrix,
    rows: usize,
    cols: usize,
) -> anyhow::Result<()> {
    anyhow::ensure!(
        m.rows == rows && m.cols == cols,
        "{name}: input {idx} is {}x{}, expected {rows}x{cols}",
        m.rows,
        m.cols
    );
    Ok(())
}

impl NativeExecutor {
    /// Execute one artifact on host matrices. Supports the GEMM-service
    /// grammar only; FCN train-step artifacts have a dedicated native path
    /// in `fcn::real_trainer::train_native`.
    pub fn execute(&self, artifact: &str, inputs: &[&Matrix]) -> anyhow::Result<Vec<Matrix>> {
        let (tag, spec) = artifact
            .split_once('_')
            .ok_or_else(|| anyhow::anyhow!("native backend: malformed artifact '{artifact}'"))?;
        match tag {
            "nt" | "tnn" | "nn" => {
                let d = parse_dims(spec, 3)?;
                let (m, n, k) = (d[0], d[1], d[2]);
                anyhow::ensure!(
                    inputs.len() == 2,
                    "{artifact}: expected 2 inputs, got {}",
                    inputs.len()
                );
                let (a, b) = (inputs[0], inputs[1]);
                check_shape(artifact, 0, a, m, k)?;
                let out = match tag {
                    "nt" => {
                        check_shape(artifact, 1, b, n, k)?;
                        blocked::matmul_nt(a, b)
                    }
                    "tnn" => {
                        check_shape(artifact, 1, b, n, k)?;
                        blocked::matmul_tnn(a, b)
                    }
                    _ => {
                        check_shape(artifact, 1, b, k, n)?;
                        blocked::matmul_nn(a, b)
                    }
                };
                Ok(vec![out])
            }
            "transpose" => {
                let d = parse_dims(spec, 2)?;
                anyhow::ensure!(
                    inputs.len() == 1,
                    "{artifact}: expected 1 input, got {}",
                    inputs.len()
                );
                check_shape(artifact, 0, inputs[0], d[0], d[1])?;
                Ok(vec![blocked::transpose(inputs[0])])
            }
            other => anyhow::bail!(
                "artifact '{artifact}' not supported by the native backend (kind '{other}')"
            ),
        }
    }
}

impl crate::coordinator::backend::ExecBackend for NativeExecutor {
    fn execute(&self, artifact: &str, inputs: &[&Matrix]) -> anyhow::Result<Vec<Matrix>> {
        NativeExecutor::execute(self, artifact, inputs)
    }

    /// Native kernels have no compile step; warmup instead spawns the
    /// persistent GEMM pool and pre-sizes every pool thread's packing
    /// panels, so the first real request pays no thread-spawn or
    /// panel-allocation cost. (The TNN/TN transpose buffer is
    /// shape-sized, so it still warms on each shape's first such
    /// request.)
    fn warmup(&self, _names: &[&str]) -> anyhow::Result<()> {
        blocked::prewarm();
        Ok(())
    }

    fn name(&self) -> String {
        "native".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::cpu;
    use crate::testutil::assert_allclose;

    #[test]
    fn executes_all_gemm_kinds() {
        // Kernel pinned: the NT≡TNN assertion needs both calls on the same
        // micro-kernel (see gemm::kernels::with_forced_kernel).
        crate::gemm::kernels::with_forced_kernel(None, || {
            let nx = NativeExecutor;
            let a = Matrix::random(16, 24, 1);
            let b_nt = Matrix::random(8, 24, 2);
            let b_nn = Matrix::random(24, 8, 3);

            let nt = nx.execute("nt_16x8x24", &[&a, &b_nt]).unwrap();
            assert_allclose(&nt[0].data, &cpu::matmul_nt(&a, &b_nt).data, 1e-4, 1e-4);

            let tnn = nx.execute("tnn_16x8x24", &[&a, &b_nt]).unwrap();
            assert_eq!(tnn[0].data, nt[0].data, "blocked NT and TNN agree exactly");

            let nn = nx.execute("nn_16x8x24", &[&a, &b_nn]).unwrap();
            assert_allclose(&nn[0].data, &cpu::matmul_nn(&a, &b_nn).data, 1e-4, 1e-4);

            let t = nx.execute("transpose_16x24", &[&a]).unwrap();
            assert_eq!(t[0].data, a.transpose().data);
        });
    }

    #[test]
    fn rejects_bad_requests() {
        let nx = NativeExecutor;
        let a = Matrix::random(4, 4, 1);
        assert!(nx.execute("nope", &[&a]).is_err());
        assert!(nx.execute("fcn_train_nt-nt-nt", &[&a]).is_err());
        assert!(nx.execute("nt_4xbad", &[&a, &a]).is_err());
        assert!(nx.execute("nt_4x4x0", &[&a, &a]).is_err());
        // Arity and shape mismatches report the artifact name.
        let err = nx.execute("nt_4x4x4", &[&a]).unwrap_err().to_string();
        assert!(err.contains("expected 2 inputs"), "{err}");
        let small = Matrix::random(2, 2, 2);
        let err = nx.execute("nt_4x4x4", &[&a, &small]).unwrap_err().to_string();
        assert!(err.contains("input 1"), "{err}");
    }
}
