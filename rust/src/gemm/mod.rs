//! The GEMM service: algorithm definitions, the naive CPU oracle, and the
//! execution backends — blocked native CPU kernels (SIMD micro-kernels +
//! persistent worker pool + zero-alloc packing scratch), simulated GPU
//! timing, and real PJRT execution.

pub mod blocked;
pub mod cpu;
pub mod kernels;
pub mod native;
pub mod pool;
pub mod sim;
pub mod xla;

/// The two implementations MTNN selects between (§V of the paper), plus NN
/// for the underlying plain product.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Algorithm {
    /// Direct `C = A × Bᵀ` (the cuBLAS NT call in the paper).
    Nt,
    /// Transpose-then-multiply (the paper's Algorithm 1).
    Tnn,
    /// Plain `C = A × B` (not selectable; used by NN workloads).
    Nn,
}

impl Algorithm {
    /// The paper's class encoding: NT = +1, TNN = −1.
    pub fn label(self) -> i8 {
        match self {
            Algorithm::Nt => 1,
            Algorithm::Tnn => -1,
            Algorithm::Nn => panic!("NN is not a selectable NT implementation"),
        }
    }

    pub fn from_label(label: i8) -> Algorithm {
        if label >= 0 {
            Algorithm::Nt
        } else {
            Algorithm::Tnn
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Algorithm::Nt => "NT",
            Algorithm::Tnn => "TNN",
            Algorithm::Nn => "NN",
        }
    }
}

/// Shape of an NT-operation request: `C[m,n] = A[m,k] × B[n,k]ᵀ`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct GemmShape {
    pub m: u64,
    pub n: u64,
    pub k: u64,
}

impl GemmShape {
    pub fn new(m: u64, n: u64, k: u64) -> GemmShape {
        GemmShape { m, n, k }
    }

    pub fn flops(&self) -> f64 {
        2.0 * self.m as f64 * self.n as f64 * self.k as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn label_encoding_matches_paper() {
        assert_eq!(Algorithm::Nt.label(), 1);
        assert_eq!(Algorithm::Tnn.label(), -1);
        assert_eq!(Algorithm::from_label(1), Algorithm::Nt);
        assert_eq!(Algorithm::from_label(-1), Algorithm::Tnn);
    }

    #[test]
    #[should_panic]
    fn nn_has_no_label() {
        Algorithm::Nn.label();
    }

    #[test]
    fn shape_flops() {
        assert_eq!(GemmShape::new(10, 20, 30).flops(), 12000.0);
    }
}
