//! Persistent GEMM worker pool: parked threads that execute row-stripe
//! tasks, replacing the per-call `std::thread::scope` spawns the blocked
//! kernels used to pay (~100 µs per call on small GEMMs).
//!
//! # Design
//!
//! * **Lazy global** — [`get`] spawns `available_parallelism − 1` workers
//!   on first use (the dispatching caller is the final lane, so total
//!   parallelism equals the core count). Workers park on a condvar and
//!   never exit; there is deliberately no shutdown — the pool lives for
//!   the process.
//! * **Caller participates** — [`Pool::run`] enqueues a task set, wakes
//!   the workers, then the caller itself loops claiming task indices like
//!   any worker and finally waits for completion. Because the caller
//!   always makes progress on its own dispatch, a saturated or even
//!   zero-worker pool degrades to inline execution — concurrent callers
//!   (e.g. several engine workers) can never deadlock each other.
//! * **Work claiming** — task indices are claimed from a shared atomic
//!   counter (no per-task queue), and every in-flight dispatch sits in one
//!   FIFO so idle workers drain older dispatches first. Broadcast
//!   dispatches ([`Pool::broadcast`]) instead carry a claimed-flag per
//!   worker, guaranteeing exactly-once-per-worker execution (used to
//!   pre-size thread-local scratch).
//! * **Measured dispatch overhead** — init times a handful of no-op
//!   dispatches and records the best ([`Pool::dispatch_overhead_ns`]);
//!   `blocked::auto_threads` feeds it into a cost model instead of the old
//!   hard-coded 2-MFLOP cliff.
//! * **NUMA placement seam** — every pool thread has a stable *lane* id
//!   ([`current_lane`]: worker `w` is lane `w + 1`, any dispatching caller
//!   is lane 0) and [`numa_nodes`] reports how many memory nodes the
//!   machine exposes. Both are hints, not bindings: `std` cannot pin
//!   threads, so the consumer (`blocked`'s shared-packing path replicates
//!   read-mostly B panels per node and routes each lane to
//!   `lane % numa_nodes()`) merely biases traffic. Detection is opt-in via
//!   `MTNN_NUMA=1`; without it — and on single-node machines or non-Linux
//!   hosts — `numa_nodes()` is 1 and behavior is exactly the pre-seam
//!   code path.
//! * **Panic containment** — worker tasks run under `catch_unwind`; a
//!   panicking task marks the dispatch and the *caller* re-panics after
//!   completion, so a poisoned stripe can't wedge the pool or silently
//!   produce partial output.
//!
//! # Soundness of the lifetime erasure
//!
//! `run`/`broadcast` smuggle a `&dyn Fn` across threads as a raw pointer.
//! This is sound because the calls do not return until `done == total`,
//! every dereference happens before the task's `done` increment, and a
//! drained dispatch (claim counter ≥ total) is never dereferenced again —
//! only pruned. The closure therefore strictly outlives every use.

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::Instant;

thread_local! {
    /// Pool lane of the current thread: worker `w` is lane `w + 1`; every
    /// other thread — including any dispatching caller — is lane 0.
    static LANE: std::cell::Cell<usize> = const { std::cell::Cell::new(0) };
}

/// Stable lane id of the calling thread (see [`LANE`]). Used by the
/// NUMA-aware B-replica selection in `blocked::gemm_shared`.
pub fn current_lane() -> usize {
    LANE.with(|l| l.get())
}

static NUMA_NODES: OnceLock<usize> = OnceLock::new();

/// NUMA node count the placement seam should target. Always ≥ 1; exactly 1
/// (replication disabled, pre-seam behavior) unless `MTNN_NUMA=1` opts in
/// *and* the host exposes multiple nodes under `/sys/devices/system/node`.
pub fn numa_nodes() -> usize {
    *NUMA_NODES.get_or_init(|| {
        if env_enables_numa(std::env::var("MTNN_NUMA").ok()) {
            detect_numa_nodes().max(1)
        } else {
            1
        }
    })
}

/// `MTNN_NUMA` is truthy for any non-empty value other than `0`.
fn env_enables_numa(v: Option<String>) -> bool {
    matches!(v.as_deref().map(str::trim), Some(s) if !s.is_empty() && s != "0")
}

/// Count `/sys/devices/system/node/node<N>` entries that expose a
/// `cpulist` (i.e. actually hold CPUs). Non-Linux hosts have no such dir
/// and fall through to 1 in [`numa_nodes`].
fn detect_numa_nodes() -> usize {
    let Ok(entries) = std::fs::read_dir("/sys/devices/system/node") else {
        return 1;
    };
    entries
        .flatten()
        .filter(|e| {
            let name = e.file_name();
            let name = name.to_string_lossy();
            name.strip_prefix("node")
                .is_some_and(|s| !s.is_empty() && s.bytes().all(|b| b.is_ascii_digit()))
                && e.path().join("cpulist").is_file()
        })
        .count()
}

/// Lifetime-erased pointer to a caller-owned task closure (see the module
/// docs for why this is sound).
struct TaskPtr(*const (dyn Fn(usize) + Sync));
unsafe impl Send for TaskPtr {}
unsafe impl Sync for TaskPtr {}

impl TaskPtr {
    /// # Safety
    /// The dispatch owning this pointer must not have completed (the
    /// caller is still blocked in `run`/`broadcast`).
    unsafe fn call(&self, i: usize) {
        (*self.0)(i)
    }
}

/// How a dispatch's tasks are claimed.
enum Work {
    /// Anyone claims the next index from the counter.
    Shared(AtomicUsize),
    /// Task `w` runs on pool worker `w` exactly once (caller excluded).
    PerWorker(Vec<AtomicBool>),
}

struct Dispatch {
    task: TaskPtr,
    total: usize,
    work: Work,
    done: AtomicUsize,
    done_lock: Mutex<()>,
    done_cv: Condvar,
    panicked: AtomicBool,
}

impl Dispatch {
    fn new(task: TaskPtr, total: usize, work: Work) -> Dispatch {
        Dispatch {
            task,
            total,
            work,
            done: AtomicUsize::new(0),
            done_lock: Mutex::new(()),
            done_cv: Condvar::new(),
            panicked: AtomicBool::new(false),
        }
    }

    fn finished(&self) -> bool {
        self.done.load(Ordering::Acquire) >= self.total
    }

    /// Whether worker `w` could still claim work here.
    fn has_work_for(&self, w: usize) -> bool {
        match &self.work {
            Work::Shared(next) => next.load(Ordering::Relaxed) < self.total,
            Work::PerWorker(claimed) => w < claimed.len() && !claimed[w].load(Ordering::Relaxed),
        }
    }

    fn mark_done(&self) {
        if self.done.fetch_add(1, Ordering::AcqRel) + 1 == self.total {
            // Taking the lock orders this notify after any waiter's
            // check-then-wait, so the wakeup cannot be lost.
            let _g = self.done_lock.lock().unwrap_or_else(|e| e.into_inner());
            self.done_cv.notify_all();
        }
    }

    fn wait(&self) {
        let mut g = self.done_lock.lock().unwrap_or_else(|e| e.into_inner());
        while self.done.load(Ordering::Acquire) < self.total {
            g = self.done_cv.wait(g).unwrap_or_else(|e| e.into_inner());
        }
    }

    fn run_one(&self, i: usize) {
        if catch_unwind(AssertUnwindSafe(|| unsafe { self.task.call(i) })).is_err() {
            self.panicked.store(true, Ordering::Release);
        }
        self.mark_done();
    }

    /// Claim-and-run shared tasks until the counter drains; returns how
    /// many this thread executed.
    fn run_shared(&self, next: &AtomicUsize) -> u64 {
        let mut ran = 0u64;
        loop {
            let i = next.fetch_add(1, Ordering::Relaxed);
            if i >= self.total {
                return ran;
            }
            self.run_one(i);
            ran += 1;
        }
    }
}

struct PoolState {
    queue: VecDeque<Arc<Dispatch>>,
}

struct Shared {
    state: Mutex<PoolState>,
    work_cv: Condvar,
    worker_tasks: AtomicU64,
}

impl Shared {
    fn prune_finished(state: &mut PoolState) {
        while let Some(front) = state.queue.front() {
            if front.finished() {
                state.queue.pop_front();
            } else {
                break;
            }
        }
    }
}

fn worker_main(shared: Arc<Shared>, idx: usize) {
    LANE.with(|l| l.set(idx + 1));
    loop {
        let d: Arc<Dispatch> = {
            let mut st = shared.state.lock().unwrap_or_else(|e| e.into_inner());
            loop {
                Shared::prune_finished(&mut st);
                if let Some(d) = st.queue.iter().find(|d| d.has_work_for(idx)).cloned() {
                    break d;
                }
                st = shared.work_cv.wait(st).unwrap_or_else(|e| e.into_inner());
            }
        };
        match &d.work {
            Work::Shared(next) => {
                let ran = d.run_shared(next);
                shared.worker_tasks.fetch_add(ran, Ordering::Relaxed);
            }
            Work::PerWorker(claimed) => {
                if idx < claimed.len() && !claimed[idx].swap(true, Ordering::AcqRel) {
                    d.run_one(idx);
                    shared.worker_tasks.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
    }
}

/// Point-in-time pool counters (see [`Pool::stats`]).
#[derive(Debug, Clone, Copy)]
pub struct PoolStats {
    /// Parked worker threads (spawned once, never replaced).
    pub workers: usize,
    /// Workers + the participating caller lane.
    pub parallelism: usize,
    /// Total threads ever spawned by the pool — equals `workers` for the
    /// process lifetime; tests assert it never grows after warmup.
    pub threads_spawned: u64,
    /// `run`/`broadcast` calls that actually enqueued a dispatch.
    pub dispatches: u64,
    /// Tasks executed on pool workers (excludes the caller's own share).
    pub worker_tasks: u64,
    /// Best-of-N no-op dispatch round-trip measured at init.
    pub dispatch_overhead_ns: u64,
    /// NUMA nodes the placement seam targets (1 = replication off).
    pub numa_nodes: usize,
}

pub struct Pool {
    shared: Arc<Shared>,
    workers: usize,
    dispatch_overhead_ns: u64,
    dispatches: AtomicU64,
}

static POOL: OnceLock<Pool> = OnceLock::new();

/// The process-wide pool, spawned (and its dispatch overhead measured) on
/// first use.
pub fn get() -> &'static Pool {
    POOL.get_or_init(Pool::new)
}

impl Pool {
    fn new() -> Pool {
        let target = std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1)
            .saturating_sub(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(PoolState { queue: VecDeque::new() }),
            work_cv: Condvar::new(),
            worker_tasks: AtomicU64::new(0),
        });
        let mut workers = 0usize;
        for idx in 0..target {
            let sh = Arc::clone(&shared);
            // Worker indices must stay contiguous (broadcast claims are
            // indexed), so stop at the first failed spawn.
            match std::thread::Builder::new()
                .name(format!("gemm-pool-{idx}"))
                .spawn(move || worker_main(sh, idx))
            {
                Ok(_) => workers += 1,
                Err(_) => break,
            }
        }
        let mut pool = Pool {
            shared,
            workers,
            dispatch_overhead_ns: 0,
            dispatches: AtomicU64::new(0),
        };
        // Measure the no-op dispatch round-trip: the first probes also wake
        // the freshly spawned workers, so take the best of several.
        let mut best = u64::MAX;
        if pool.workers > 0 {
            for _ in 0..8 {
                let t0 = Instant::now();
                pool.run(pool.workers + 1, &|_| {});
                best = best.min(t0.elapsed().as_nanos() as u64);
            }
        }
        pool.dispatch_overhead_ns = if best == u64::MAX { 1_000 } else { best.max(1) };
        pool
    }

    /// Workers + the participating caller lane.
    pub fn parallelism(&self) -> usize {
        self.workers + 1
    }

    /// Best-of-N no-op dispatch round-trip measured at init — the per-call
    /// price of handing work to the pool, fed into `auto_threads`.
    pub fn dispatch_overhead_ns(&self) -> u64 {
        self.dispatch_overhead_ns
    }

    pub fn stats(&self) -> PoolStats {
        PoolStats {
            workers: self.workers,
            parallelism: self.parallelism(),
            threads_spawned: self.workers as u64,
            dispatches: self.dispatches.load(Ordering::Relaxed),
            worker_tasks: self.shared.worker_tasks.load(Ordering::Relaxed),
            dispatch_overhead_ns: self.dispatch_overhead_ns,
            numa_nodes: numa_nodes(),
        }
    }

    /// Execute `f(0..total)` across the pool (caller included), returning
    /// once every task has finished. Tasks must be independent; panics in
    /// any task re-panic here after the dispatch drains.
    pub fn run(&self, total: usize, f: &(dyn Fn(usize) + Sync)) {
        if total == 0 {
            return;
        }
        if self.workers == 0 || total == 1 {
            for i in 0..total {
                f(i);
            }
            return;
        }
        self.dispatches.fetch_add(1, Ordering::Relaxed);
        let d = Arc::new(Dispatch::new(
            TaskPtr(f as *const (dyn Fn(usize) + Sync)),
            total,
            Work::Shared(AtomicUsize::new(0)),
        ));
        self.enqueue(&d);
        if let Work::Shared(next) = &d.work {
            d.run_shared(next);
        }
        self.finish(&d);
    }

    /// Run `f` exactly once on every pool worker (not the caller), e.g. to
    /// pre-size thread-local scratch. No-op with zero workers.
    pub fn broadcast(&self, f: &(dyn Fn() + Sync)) {
        if self.workers == 0 {
            return;
        }
        self.dispatches.fetch_add(1, Ordering::Relaxed);
        let wrap = |_: usize| f();
        let wrap_ref: &(dyn Fn(usize) + Sync) = &wrap;
        let claimed: Vec<AtomicBool> = (0..self.workers).map(|_| AtomicBool::new(false)).collect();
        let d = Arc::new(Dispatch::new(
            TaskPtr(wrap_ref as *const (dyn Fn(usize) + Sync)),
            self.workers,
            Work::PerWorker(claimed),
        ));
        self.enqueue(&d);
        self.finish(&d);
    }

    fn enqueue(&self, d: &Arc<Dispatch>) {
        let mut st = self.shared.state.lock().unwrap_or_else(|e| e.into_inner());
        st.queue.push_back(Arc::clone(d));
        drop(st);
        self.shared.work_cv.notify_all();
    }

    fn finish(&self, d: &Arc<Dispatch>) {
        d.wait();
        let mut st = self.shared.state.lock().unwrap_or_else(|e| e.into_inner());
        Shared::prune_finished(&mut st);
        drop(st);
        if d.panicked.load(Ordering::Acquire) {
            panic!("gemm pool task panicked");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_executes_every_task_exactly_once() {
        let pool = get();
        let hits: Vec<AtomicUsize> = (0..97).map(|_| AtomicUsize::new(0)).collect();
        pool.run(hits.len(), &|i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "task {i}");
        }
    }

    #[test]
    fn run_handles_degenerate_sizes() {
        let pool = get();
        pool.run(0, &|_| panic!("no tasks to run"));
        let ran = AtomicUsize::new(0);
        pool.run(1, &|i| {
            assert_eq!(i, 0);
            ran.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(ran.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn concurrent_dispatches_from_many_callers() {
        let pool = get();
        let total = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..6 {
                s.spawn(|| {
                    for _ in 0..20 {
                        pool.run(8, &|_| {
                            total.fetch_add(1, Ordering::Relaxed);
                        });
                    }
                });
            }
        });
        assert_eq!(total.load(Ordering::Relaxed), 6 * 20 * 8);
    }

    #[test]
    fn broadcast_runs_once_per_worker() {
        let pool = get();
        let hits = AtomicUsize::new(0);
        pool.broadcast(&|| {
            hits.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(hits.load(Ordering::Relaxed), pool.stats().workers);
    }

    #[test]
    fn task_panic_propagates_to_the_caller() {
        let pool = get();
        if pool.workers == 0 {
            return; // inline path: the panic propagates natively
        }
        let res = catch_unwind(AssertUnwindSafe(|| {
            pool.run(pool.parallelism() + 2, &|i| {
                if i == 1 {
                    panic!("boom");
                }
            });
        }));
        assert!(res.is_err(), "caller must observe the task panic");
    }

    #[test]
    fn numa_env_gate_parsing() {
        assert!(!env_enables_numa(None));
        assert!(!env_enables_numa(Some("".into())));
        assert!(!env_enables_numa(Some("  ".into())));
        assert!(!env_enables_numa(Some("0".into())));
        assert!(env_enables_numa(Some("1".into())));
        assert!(env_enables_numa(Some("yes".into())));
    }

    #[test]
    fn numa_nodes_is_at_least_one_and_stable() {
        let n = numa_nodes();
        assert!(n >= 1);
        assert_eq!(numa_nodes(), n, "cached value must not change");
        assert_eq!(get().stats().numa_nodes, n);
    }

    #[test]
    fn lanes_are_zero_for_callers_and_distinct_for_workers() {
        let pool = get();
        assert_eq!(current_lane(), 0, "non-pool threads are lane 0");
        let lanes = Mutex::new(Vec::new());
        pool.broadcast(&|| {
            lanes
                .lock()
                .unwrap_or_else(|e| e.into_inner())
                .push(current_lane());
        });
        let mut lanes = lanes.into_inner().unwrap_or_else(|e| e.into_inner());
        lanes.sort_unstable();
        lanes.dedup();
        assert_eq!(lanes.len(), pool.stats().workers, "one distinct lane per worker");
        assert!(lanes.iter().all(|&l| l >= 1), "worker lanes start at 1");
    }

    #[test]
    fn overhead_and_stats_are_sane() {
        let pool = get();
        let s = pool.stats();
        assert_eq!(s.parallelism, s.workers + 1);
        assert!(s.dispatch_overhead_ns >= 1);
        let avail = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
        assert!(s.parallelism <= avail.max(1), "pool must respect available_parallelism");
    }
}
