//! Real execution backend: runs the AOT-compiled Pallas/JAX artifacts on
//! the PJRT CPU client. This is the *functional* plane of the GEMM service
//! — numerics are real; GPU timing comes from [`super::sim::SimBackend`].
//!
//! NOTE: with the real `xla-rs` crate, `xla::PjRtClient` is `Rc`-based and
//! not `Send`, so an `XlaBackend` lives on one thread. The coordinator's
//! engine pool gives each worker its own `Runtime` instance instead (see
//! `coordinator::engine`); the vendored stub client is a plain `Send`
//! struct, which is what lets those instances be built on the caller
//! thread.

use super::cpu::Matrix;
use super::{Algorithm, GemmShape};
use crate::runtime::Runtime;
use std::time::Instant;

/// Result of one real execution.
#[derive(Debug, Clone)]
pub struct ExecOutcome {
    pub output: Matrix,
    /// Wall time of the PJRT execution (not a GPU estimate!).
    pub elapsed: std::time::Duration,
    pub artifact: String,
}

/// PJRT-backed GEMM execution over the artifact catalog.
pub struct XlaBackend {
    pub rt: Runtime,
}

impl XlaBackend {
    pub fn new(rt: Runtime) -> XlaBackend {
        XlaBackend { rt }
    }

    /// Artifact name for a shape + algorithm (must be in the catalog).
    pub fn artifact_name(shape: GemmShape, algo: Algorithm) -> String {
        let GemmShape { m, n, k } = shape;
        match algo {
            Algorithm::Nt => format!("nt_{m}x{n}x{k}"),
            Algorithm::Tnn => format!("tnn_{m}x{n}x{k}"),
            Algorithm::Nn => format!("nn_{m}x{n}x{k}"),
        }
    }

    /// Shapes available in the catalog for a given algorithm.
    pub fn catalog_shapes(&self, algo: Algorithm) -> Vec<GemmShape> {
        let tag = match algo {
            Algorithm::Nt => "nt",
            Algorithm::Tnn => "tnn",
            Algorithm::Nn => "nn",
        };
        self.rt
            .manifest
            .gemm_entries(tag)
            .iter()
            .map(|e| {
                GemmShape::new(
                    e.meta.get("m").as_usize().unwrap_or(0) as u64,
                    e.meta.get("n").as_usize().unwrap_or(0) as u64,
                    e.meta.get("k").as_usize().unwrap_or(0) as u64,
                )
            })
            .collect()
    }

    /// Whether the catalog can serve this (shape, algo).
    pub fn supports(&self, shape: GemmShape, algo: Algorithm) -> bool {
        self.rt
            .manifest
            .get(&Self::artifact_name(shape, algo))
            .is_ok()
    }

    /// Execute `C = A × Bᵀ` (or plain NN for [`Algorithm::Nn`]) for real.
    /// `a` is m×k; `b` is n×k for NT/TNN and k×n for NN.
    pub fn execute(
        &self,
        shape: GemmShape,
        algo: Algorithm,
        a: &Matrix,
        b: &Matrix,
    ) -> anyhow::Result<ExecOutcome> {
        let name = Self::artifact_name(shape, algo);
        let t0 = Instant::now();
        let mut outs = self.rt.execute(&name, &[a, b])?;
        anyhow::ensure!(outs.len() == 1, "{name}: expected 1 output");
        Ok(ExecOutcome {
            output: outs.remove(0),
            elapsed: t0.elapsed(),
            artifact: name,
        })
    }
}
