//! Deterministic simulated-GPU execution backend.
//!
//! [`SimExecutor`] serves the coordinator's artifact grammar (`nt_MxNxK`,
//! `tnn_MxNxK`, `nn_MxNxK`, `transpose_RxC`) with **oracle numerics**
//! (the naive [`crate::gemm::cpu`] kernels) while accounting latency from
//! the calibrated [`super::TimingModel`] of one GPU — so simulated-GPU latency
//! experiments ride the exact same router/engine path as real traffic.
//! The paper's memory-fit rule applies: a case whose workspace exceeds the
//! simulated GPU's global memory fails *before* any compute, mirroring a
//! device OOM.
//!
//! Accrued simulated time is shared across clones, so a caller can keep
//! one clone as a probe while handing others to every pool worker. When
//! `time_scale > 0` the executor also sleeps `simulated × scale`, turning
//! the model's timings into real wall-clock pacing.

use crate::coordinator::backend::ExecBackend;
use crate::gemm::cpu::{self, Matrix};
use crate::gemm::native::{check_shape, parse_dims};
use crate::gemm::Algorithm;

use super::{GpuSpec, Simulator};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Simulated-GPU executor: oracle numerics + modeled latency.
#[derive(Clone)]
pub struct SimExecutor {
    sim: Simulator,
    /// Sleep `simulated_seconds × time_scale` per execution (0 = don't).
    time_scale: f64,
    /// Total simulated nanoseconds, shared by clones.
    simulated_ns: Arc<AtomicU64>,
}

impl SimExecutor {
    pub fn new(gpu: &'static GpuSpec) -> SimExecutor {
        SimExecutor::with_time_scale(gpu, 0.0)
    }

    /// An executor that also sleeps `simulated × time_scale` per run.
    pub fn with_time_scale(gpu: &'static GpuSpec, time_scale: f64) -> SimExecutor {
        SimExecutor {
            sim: Simulator::new(gpu),
            time_scale,
            simulated_ns: Arc::new(AtomicU64::new(0)),
        }
    }

    pub fn spec(&self) -> &'static GpuSpec {
        self.sim.spec()
    }

    /// Total simulated GPU time accrued across all executions (shared by
    /// clones, so one probe clone observes a whole pool).
    pub fn simulated(&self) -> Duration {
        Duration::from_nanos(self.simulated_ns.load(Ordering::Relaxed))
    }

    fn accrue(&self, seconds: f64) {
        self.simulated_ns
            .fetch_add((seconds * 1e9) as u64, Ordering::Relaxed);
        if self.time_scale > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(seconds * self.time_scale));
        }
    }

    /// Modeled execution latency for an artifact, in µs — deterministic
    /// (the calibrated timing model has no run-to-run noise), independent
    /// of host CPU speed, and what `execute_timed` reports so the online
    /// loop learns the *simulated* GPU's NT/TNN trade-off.
    pub fn modeled_us(&self, artifact: &str) -> Option<f64> {
        let (tag, spec) = artifact.split_once('_')?;
        let seconds = match tag {
            "nt" | "tnn" | "nn" => {
                let d = parse_dims(spec, 3).ok()?;
                let (m, n, k) = (d[0] as u64, d[1] as u64, d[2] as u64);
                match tag {
                    "nt" => self.sim.model.t_nt(m, n, k),
                    "tnn" => self.sim.model.t_tnn(m, n, k),
                    _ => self.sim.model.t_nn(m, n, k),
                }
            }
            "transpose" => {
                let d = parse_dims(spec, 2).ok()?;
                self.sim.model.t_transpose(d[0] as u64, d[1] as u64)
            }
            _ => return None,
        };
        Some(seconds * 1e6)
    }
}

impl ExecBackend for SimExecutor {
    fn execute(&self, artifact: &str, inputs: &[&Matrix]) -> anyhow::Result<Vec<Matrix>> {
        let (tag, spec) = artifact.split_once('_').ok_or_else(|| {
            anyhow::anyhow!("sim backend: malformed artifact '{artifact}'")
        })?;
        match tag {
            "nt" | "tnn" | "nn" => {
                let d = parse_dims(spec, 3)?;
                let (m, n, k) = (d[0], d[1], d[2]);
                anyhow::ensure!(
                    inputs.len() == 2,
                    "{artifact}: expected 2 inputs, got {}",
                    inputs.len()
                );
                let algo = match tag {
                    "nt" => Algorithm::Nt,
                    "tnn" => Algorithm::Tnn,
                    _ => Algorithm::Nn,
                };
                // Memory-fit rule first — a simulated OOM must not depend
                // on the caller being able to materialize the operands.
                let (mu, nu, ku) = (m as u64, n as u64, k as u64);
                let fits = match algo {
                    Algorithm::Tnn => self.sim.fits(mu, nu, ku),
                    _ => {
                        Simulator::nt_workspace_bytes(mu, nu, ku)
                            <= self.spec().global_mem_bytes()
                    }
                };
                anyhow::ensure!(
                    fits,
                    "{artifact}: workspace does not fit in {}'s simulated {} GiB memory",
                    self.spec().name,
                    self.spec().global_mem_gib
                );
                let (a, b) = (inputs[0], inputs[1]);
                check_shape(artifact, 0, a, m, k)?;
                let out = match algo {
                    Algorithm::Nt => {
                        check_shape(artifact, 1, b, n, k)?;
                        cpu::matmul_nt(a, b)
                    }
                    Algorithm::Tnn => {
                        check_shape(artifact, 1, b, n, k)?;
                        cpu::matmul_tnn(a, b)
                    }
                    Algorithm::Nn => {
                        check_shape(artifact, 1, b, k, n)?;
                        cpu::matmul_nn(a, b)
                    }
                };
                let t = match algo {
                    Algorithm::Nt => self.sim.model.t_nt(mu, nu, ku),
                    Algorithm::Tnn => self.sim.model.t_tnn(mu, nu, ku),
                    Algorithm::Nn => self.sim.model.t_nn(mu, nu, ku),
                };
                self.accrue(t);
                Ok(vec![out])
            }
            "transpose" => {
                let d = parse_dims(spec, 2)?;
                anyhow::ensure!(
                    inputs.len() == 1,
                    "{artifact}: expected 1 input, got {}",
                    inputs.len()
                );
                check_shape(artifact, 0, inputs[0], d[0], d[1])?;
                self.accrue(self.sim.model.t_transpose(d[0] as u64, d[1] as u64));
                Ok(vec![inputs[0].transpose()])
            }
            other => anyhow::bail!(
                "artifact '{artifact}' not supported by the sim backend (kind '{other}')"
            ),
        }
    }

    /// Report the *modeled* latency instead of host wall-clock: the whole
    /// point of the sim backend is that timing experiments (and the online
    /// retraining loop) see the calibrated GPU, not the oracle kernels'
    /// CPU cost.
    fn execute_timed(&self, artifact: &str, inputs: &[&Matrix]) -> anyhow::Result<(Vec<Matrix>, f64)> {
        let out = self.execute(artifact, inputs)?;
        let us = self.modeled_us(artifact).unwrap_or(0.0);
        Ok((out, us))
    }

    fn name(&self) -> String {
        format!("sim:{}", self.spec().name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::GTX1080;
    use crate::testutil::assert_allclose;

    #[test]
    fn numerics_match_the_oracle() {
        let sx = SimExecutor::new(&GTX1080);
        let a = Matrix::random(16, 24, 1);
        let b_nt = Matrix::random(8, 24, 2);
        let b_nn = Matrix::random(24, 8, 3);

        let nt = sx.execute("nt_16x8x24", &[&a, &b_nt]).unwrap();
        assert_allclose(&nt[0].data, &cpu::matmul_nt(&a, &b_nt).data, 1e-6, 1e-6);

        let tnn = sx.execute("tnn_16x8x24", &[&a, &b_nt]).unwrap();
        assert_allclose(&tnn[0].data, &nt[0].data, 1e-6, 1e-6);

        let nn = sx.execute("nn_16x8x24", &[&a, &b_nn]).unwrap();
        assert_allclose(&nn[0].data, &cpu::matmul_nn(&a, &b_nn).data, 1e-6, 1e-6);

        let t = sx.execute("transpose_16x24", &[&a]).unwrap();
        assert_eq!(t[0].data, a.transpose().data);
    }

    #[test]
    fn accrues_deterministic_simulated_time() {
        let run = || {
            let sx = SimExecutor::new(&GTX1080);
            let a = Matrix::random(128, 128, 1);
            let b = Matrix::random(128, 128, 2);
            sx.execute("nt_128x128x128", &[&a, &b]).unwrap();
            sx.execute("tnn_128x128x128", &[&a, &b]).unwrap();
            sx.simulated()
        };
        let first = run();
        assert!(first > Duration::ZERO, "modeled time must accrue: {first:?}");
        assert_eq!(first, run(), "the timing model is deterministic");
    }

    #[test]
    fn clones_share_the_accrued_time() {
        let sx = SimExecutor::new(&GTX1080);
        let probe = sx.clone();
        let a = Matrix::random(128, 128, 4);
        let b = Matrix::random(128, 128, 5);
        sx.execute("nt_128x128x128", &[&a, &b]).unwrap();
        assert_eq!(probe.simulated(), sx.simulated());
        assert!(probe.simulated() > Duration::ZERO);
    }

    #[test]
    fn oom_shapes_fail_before_compute() {
        let sx = SimExecutor::new(&GTX1080);
        // 64Ki³ would need far more than 8 GiB; the tiny dummies prove the
        // fit rule fires before any shape/compute work.
        let tiny = Matrix::zeros(2, 2);
        let err = sx
            .execute("tnn_65536x65536x65536", &[&tiny, &tiny])
            .unwrap_err()
            .to_string();
        assert!(err.contains("does not fit"), "{err}");
        assert_eq!(sx.simulated(), Duration::ZERO);
    }

    #[test]
    fn execute_timed_reports_the_modeled_latency() {
        let sx = SimExecutor::new(&GTX1080);
        let a = Matrix::random(128, 128, 7);
        let b = Matrix::random(128, 128, 8);
        let (out, us) = sx.execute_timed("nt_128x128x128", &[&a, &b]).unwrap();
        assert_eq!(out.len(), 1);
        let expect = sx.modeled_us("nt_128x128x128").unwrap();
        assert_eq!(us, expect, "timed latency is the calibrated model's");
        assert!(us > 0.0);
        // The NT/TNN split the timing model defines is what the hook
        // reports — the online loop's labels hinge on this.
        let nt = sx.modeled_us("nt_128x128x128").unwrap();
        let tnn = sx.modeled_us("tnn_128x128x128").unwrap();
        assert_ne!(nt, tnn);
        assert!(sx.modeled_us("bogus").is_none());
        assert!(sx.modeled_us("nt_1x2").is_none());
    }

    #[test]
    fn rejects_unknown_artifacts() {
        let sx = SimExecutor::new(&GTX1080);
        let a = Matrix::zeros(2, 2);
        assert!(sx.execute("nope", &[&a]).is_err());
        assert!(sx.execute("fcn_train_nt-nt-nt", &[&a]).is_err());
        assert_eq!(sx.name(), "sim:GTX1080");
    }
}
