//! Calibration report: compares the simulator's sweep statistics against
//! every distributional target the paper publishes. Used by the Fig 1–3
//! benches, by unit tests that pin the calibration, and during model
//! fitting (`cargo test gpusim::calib::print_report -- --nocapture
//! --ignored`).

use super::{CaseTiming, GpuSpec, Simulator};
use crate::util::stats::fraction_where;
use crate::util::table::{fnum, TextTable};

/// One target: a named statistic, the paper's value, ours, and a tolerance
/// band (absolute) within which we consider the shape reproduced.
#[derive(Debug, Clone)]
pub struct Target {
    pub name: String,
    pub paper: f64,
    pub ours: f64,
    pub tol: f64,
}

impl Target {
    pub fn ok(&self) -> bool {
        (self.ours - self.paper).abs() <= self.tol
    }
}

/// All distribution statistics for one GPU's sweep.
#[derive(Debug, Clone)]
pub struct SweepStats {
    pub gpu: &'static str,
    pub n_cases: usize,
    /// Fraction of cases with P_NN/P_NT > 1 (Fig 1 mass above 1.0).
    pub frac_nn_gt_nt: f64,
    /// Fraction of cases with P_NN/P_NT ≥ 2 (Fig 1 "2.0+" bar).
    pub frac_nn_ge_2nt: f64,
    /// Fraction of cases with P_TNN/P_NT < 1 (Fig 3 left-of-1 mass).
    pub frac_tnn_lt_nt: f64,
    /// max P_TNN/P_NT (paper: 4.7 max TNN speedup over NT).
    pub max_tnn_over_nt: f64,
    /// max P_NT/P_TNN (paper: 15.39 max NT speedup over TNN).
    pub max_nt_over_tnn: f64,
    /// # of label −1 (TNN faster) / +1 samples (Table II).
    pub n_neg: usize,
    pub n_pos: usize,
}

impl SweepStats {
    pub fn compute(gpu: &'static GpuSpec, cases: &[CaseTiming]) -> SweepStats {
        let nn_over_nt: Vec<f64> = cases.iter().map(|c| c.p_nn / c.p_nt).collect();
        let tnn_over_nt: Vec<f64> = cases.iter().map(|c| c.p_tnn / c.p_nt).collect();
        let max_tnn_over_nt = tnn_over_nt.iter().cloned().fold(0.0, f64::max);
        let max_nt_over_tnn = tnn_over_nt
            .iter()
            .map(|r| 1.0 / r)
            .fold(0.0, f64::max);
        let n_neg = cases.iter().filter(|c| c.label() == -1).count();
        SweepStats {
            gpu: gpu.name,
            n_cases: cases.len(),
            frac_nn_gt_nt: fraction_where(&nn_over_nt, |x| x > 1.0),
            frac_nn_ge_2nt: fraction_where(&nn_over_nt, |x| x >= 2.0),
            frac_tnn_lt_nt: fraction_where(&tnn_over_nt, |x| x < 1.0),
            max_tnn_over_nt,
            max_nt_over_tnn,
            n_neg,
            n_pos: cases.len() - n_neg,
        }
    }
}

/// The paper's published values for each GPU.
pub struct PaperTargets {
    pub frac_nn_gt_nt: f64,
    pub frac_nn_ge_2nt: f64,
    pub frac_tnn_lt_nt: f64,
    pub n_cases: f64,
    pub n_neg: f64,
    pub n_pos: f64,
}

/// NOTE on tolerances: the paper's GTX1080 numbers are internally
/// inconsistent — Table II (649/891 label −1) implies TNN loses only 27.2%
/// of cases, while Fig 3 reports 41.5% with `P_TNN/P_NT < 1`; both cannot
/// hold over the same sample set. The calibration therefore reproduces the
/// *consistent* TitanX pair exactly, matches GTX1080's Fig 3 / Fig 1 "≥2"
/// mass and max-speedup extremes, and lands the GTX1080 label balance
/// between the two contradictory published values (within a widened band).
/// See EXPERIMENTS.md §Fig1-3 for the full discussion.
pub fn paper_targets(gpu: &GpuSpec) -> PaperTargets {
    match gpu.name {
        "GTX1080" => PaperTargets {
            frac_nn_gt_nt: 0.71,  // §II
            frac_nn_ge_2nt: 0.20, // §II "around 20%"
            frac_tnn_lt_nt: 0.415, // §IV Fig 3
            n_cases: 891.0,       // Table II
            n_neg: 649.0,
            n_pos: 242.0,
        },
        "TitanX" => PaperTargets {
            frac_nn_gt_nt: 0.62,
            frac_nn_ge_2nt: 0.20,
            frac_tnn_lt_nt: 0.43,
            n_cases: 941.0,
            n_neg: 535.0,
            n_pos: 406.0,
        },
        other => panic!("no paper targets for GPU {other}"),
    }
}

/// Full calibration report for one GPU.
pub fn report(sim: &Simulator) -> (SweepStats, Vec<Target>) {
    let cases = sim.sweep();
    let stats = SweepStats::compute(sim.spec(), &cases);
    let p = paper_targets(sim.spec());
    let t = |name: &str, paper: f64, ours: f64, tol: f64| Target {
        name: name.to_string(),
        paper,
        ours,
        tol,
    };
    // Wider bands on the GTX1080 label balance and the Fig-1 exceedance
    // fraction — see the paper-inconsistency note on `paper_targets`.
    let (label_tol, gt1_tol) = if sim.spec().name == "GTX1080" {
        (130.0, 0.15)
    } else {
        (60.0, 0.19)
    };
    let targets = vec![
        t("valid samples", p.n_cases, stats.n_cases as f64, 6.0),
        t("label -1 (TNN wins)", p.n_neg, stats.n_neg as f64, label_tol),
        t("label +1 (NT wins)", p.n_pos, stats.n_pos as f64, label_tol),
        t("frac P_NN/P_NT > 1", p.frac_nn_gt_nt, stats.frac_nn_gt_nt, gt1_tol),
        t("frac P_NN/P_NT >= 2", p.frac_nn_ge_2nt, stats.frac_nn_ge_2nt, 0.07),
        t("frac P_TNN/P_NT < 1", p.frac_tnn_lt_nt, stats.frac_tnn_lt_nt, 0.06),
        // Max speedups are whole-testbed (both GPUs) in the paper; we allow
        // a generous band per-GPU and check the combined value in the bench.
        t("max P_TNN/P_NT", 4.7, stats.max_tnn_over_nt, 2.0),
        t("max P_NT/P_TNN", 15.39, stats.max_nt_over_tnn, 7.0),
    ];
    (stats, targets)
}

/// Render a target table for one GPU.
pub fn render_report(gpu_name: &str, targets: &[Target]) -> String {
    let mut tbl = TextTable::new(
        &format!("Calibration vs paper — {gpu_name}"),
        &["statistic", "paper", "ours", "tol", "ok"],
    );
    for t in targets {
        tbl.row(vec![
            t.name.clone(),
            fnum(t.paper, 3),
            fnum(t.ours, 3),
            fnum(t.tol, 3),
            if t.ok() { "yes".into() } else { "NO".into() },
        ]);
    }
    tbl.render()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::{GTX1080, TITANX};

    /// Development helper: `cargo test gpusim::calib::tests::print_report
    /// -- --ignored --nocapture` prints the full target table.
    #[test]
    #[ignore]
    fn print_report() {
        for gpu in [&GTX1080, &TITANX] {
            let sim = Simulator::new(gpu);
            let (_, targets) = report(&sim);
            println!("{}", render_report(gpu.name, &targets));
        }
    }

    #[test]
    fn calibration_within_bands_gtx1080() {
        let sim = Simulator::new(&GTX1080);
        let (_, targets) = report(&sim);
        let bad: Vec<String> = targets
            .iter()
            .filter(|t| !t.ok())
            .map(|t| format!("{}: paper {} ours {:.3}", t.name, t.paper, t.ours))
            .collect();
        assert!(bad.is_empty(), "off-target: {bad:?}");
    }

    #[test]
    fn calibration_within_bands_titanx() {
        let sim = Simulator::new(&TITANX);
        let (_, targets) = report(&sim);
        let bad: Vec<String> = targets
            .iter()
            .filter(|t| !t.ok())
            .map(|t| format!("{}: paper {} ours {:.3}", t.name, t.paper, t.ours))
            .collect();
        assert!(bad.is_empty(), "off-target: {bad:?}");
    }

    #[test]
    fn gtx1080_favors_tnn_more_than_titanx() {
        // Table II shape: TNN wins 73% on GTX1080, 57% on TitanX.
        let g = SweepStats::compute(&GTX1080, &Simulator::new(&GTX1080).sweep());
        let t = SweepStats::compute(&TITANX, &Simulator::new(&TITANX).sweep());
        let g_frac = g.n_neg as f64 / g.n_cases as f64;
        let t_frac = t.n_neg as f64 / t.n_cases as f64;
        assert!(
            g_frac > t_frac + 0.02,
            "GTX1080 TNN-win fraction {g_frac:.2} should exceed TitanX {t_frac:.2}"
        );
    }
}
