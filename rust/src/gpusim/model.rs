//! Analytical timing model for cuBLAS-style SGEMM (NN and NT), the
//! out-of-place transpose kernel, and device alloc/free — the substrate
//! standing in for the paper's physical GTX 1080 / Titan X measurements.
//!
//! The model is a roofline (compute vs memory bound) augmented with the
//! effects the paper's distributions hinge on:
//!
//! * **tile quantization** — cuBLAS launches 128×128 C-tiles; partial tiles
//!   waste MXU^H^H^H SM cycles;
//! * **wave quantization** — the last wave of blocks underfills the SMs;
//! * **K-pipeline fill** — short reduction dims underutilize the FMA
//!   pipelines (cuBLAS SGEMM is latency-bound at small K);
//! * **NT access penalty** — the NT kernel streams `B` with transposed tile
//!   access; once the active panel set spills the L2, effective bandwidth
//!   and pipeline efficiency drop, growing with K (longer strided panels)
//!   — this is the low-`P_NT` phenomenon of Fig. 1;
//! * **alloc/transpose overhead** — TNN pays `cudaMalloc` + transpose +
//!   `cudaFree`; for small products that fixed cost dominates (the region
//!   where NT beats TNN by up to ~15× in Fig. 2);
//! * **deterministic measurement noise** — multiplicative log-normal noise
//!   keyed by `(gpu, op, m, n, k)`, so labels near the decision boundary
//!   flip "randomly" exactly as run-to-run variance does on real hardware
//!   (this is what caps attainable classifier accuracy near the paper's
//!   96%).
//!
//! All returned times are **seconds**; performance is GFLOPS of the
//! 2·m·n·k useful work, matching the paper's `P_algorithm` metric.

use super::spec::GpuSpec;
use crate::util::rng::{mix_parts, SplitMix64};

/// Operation tags for noise derivation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Op {
    Nn = 1,
    Nt = 2,
    Transpose = 3,
    Alloc = 4,
}

/// Calibration constants. Defaults were fitted against the paper's
/// published distributions (see `rust/benches/fig1_nn_vs_nt.rs` and
/// EXPERIMENTS.md): Fig 1 exceedance fractions, Fig 3 crossover mass,
/// Table II class balance, and the max speedups quoted in §IV.
#[derive(Debug, Clone)]
pub struct ModelParams {
    /// cuBLAS C-tile edge (elements).
    pub tile: u64,
    /// Resident thread blocks per SM.
    pub blocks_per_sm: f64,
    /// Peak fraction achievable by the NN kernel at large sizes.
    pub base_eff_nn: f64,
    /// K-pipeline fill constant: eff_k = k / (k + k_fill).
    pub k_fill: f64,
    /// Fraction of peak DRAM bandwidth GEMM streaming achieves.
    pub gemm_bw_eff: f64,
    /// Floor on wave efficiency: cuBLAS switches to narrower-tile kernels
    /// for small problems, so a single block never runs at 1/(2·SMs) of
    /// peak.
    pub wave_floor: f64,
    /// K (log2) below which the NT kernel has no transposed-access
    /// penalty (short panels stay resident; Fig 2 shows NT winning half
    /// the K=128 column).
    pub nt_k_onset_log2: f64,
    /// Fraction of peak DRAM bandwidth the tiled transpose achieves
    /// (paper cites ~80% for the out-of-place kernel).
    pub transpose_bw_eff: f64,
    /// Kernel launch overhead, seconds.
    pub launch_s: f64,
    /// Fixed cudaMalloc cost, seconds.
    pub alloc_fixed_s: f64,
    /// cudaMalloc size-dependent cost: seconds per byte (page mapping).
    pub alloc_per_byte_s: f64,
    /// Fixed cudaFree cost, seconds.
    pub free_fixed_s: f64,
    /// Baseline NT inefficiency applied at every size (transposed tile
    /// loads are never free); scaled by the same L2 arch factor.
    pub nt_base_pen: f64,
    /// NT penalty magnitude at full saturation.
    pub nt_pen_scale: f64,
    /// NT penalty growth exponent over normalized log2(k).
    pub nt_pen_gamma: f64,
    /// L2 capacity softening: panels fitting in `l2_mult × L2` see no
    /// penalty.
    pub nt_l2_mult: f64,
    /// Per-GPU architectural sensitivity: a larger L2 (reference
    /// 2048 KiB = GTX1080) delays the K onset of the penalty — it changes
    /// how *often* NT suffers, not how badly (the paper reports ~20% of
    /// cases at ratio ≥ 2 on both GPUs).
    pub nt_l2_ref_kb: f64,
    pub nt_onset_l2_coef: f64,
    /// Multiplicative log-normal noise sigma.
    pub noise_sigma: f64,
    /// Global noise seed salt (lets tests draw independent "re-runs").
    pub noise_salt: u64,
}

impl Default for ModelParams {
    fn default() -> Self {
        ModelParams {
            tile: 128,
            blocks_per_sm: 2.0,
            base_eff_nn: 0.86,
            k_fill: 48.0,
            gemm_bw_eff: 0.80,
            wave_floor: 0.125,
            nt_k_onset_log2: 8.5,
            transpose_bw_eff: 0.72,
            launch_s: 6.0e-6,
            alloc_fixed_s: 70.0e-6,
            alloc_per_byte_s: 1.0 / 220.0e9, // ~220 GB/s page mapping
            free_fixed_s: 25.0e-6,
            nt_base_pen: 0.02,
            nt_pen_scale: 2.4,
            nt_pen_gamma: 1.8,
            nt_l2_mult: 4.0,
            nt_l2_ref_kb: 2048.0,
            nt_onset_l2_coef: 2.2,
            noise_sigma: 0.06,
            noise_salt: 0,
        }
    }
}

/// The timing model for one GPU.
#[derive(Debug, Clone)]
pub struct TimingModel {
    pub spec: &'static GpuSpec,
    pub params: ModelParams,
}

impl TimingModel {
    pub fn new(spec: &'static GpuSpec) -> Self {
        Self {
            spec,
            params: ModelParams::default(),
        }
    }

    pub fn with_params(spec: &'static GpuSpec, params: ModelParams) -> Self {
        Self { spec, params }
    }

    // ---- noise -------------------------------------------------------------

    /// Deterministic multiplicative noise factor for one measurement.
    fn noise(&self, op: Op, m: u64, n: u64, k: u64) -> f64 {
        let key = mix_parts(&[
            self.params.noise_salt,
            self.spec.id,
            op as u64,
            m,
            n,
            k,
        ]);
        let mut rng = SplitMix64::new(key);
        // Approximate standard normal from 4 uniforms (Irwin–Hall, var 1/3
        // each → scale) — cheap and smooth enough for noise purposes.
        let g: f64 = (0..4).map(|_| rng.next_f64() - 0.5).sum::<f64>() * (12.0f64 / 4.0).sqrt();
        (self.params.noise_sigma * g).exp()
    }

    // ---- building blocks ---------------------------------------------------

    fn ceil_div(a: u64, b: u64) -> u64 {
        a.div_ceil(b)
    }

    /// Shared GEMM core: compute and memory times for an NN-shaped kernel
    /// over an (m × k) · (k × n) product, before any NT penalty or noise.
    fn gemm_core(&self, m: u64, n: u64, k: u64) -> (f64, f64) {
        let p = &self.params;
        let tiles_m = Self::ceil_div(m, p.tile);
        let tiles_n = Self::ceil_div(n, p.tile);
        let blocks = (tiles_m * tiles_n) as f64;

        // Tile quantization: padded fraction does no useful work.
        let eff_tile =
            (m as f64 / (tiles_m * p.tile) as f64) * (n as f64 / (tiles_n * p.tile) as f64);
        // Wave quantization across SMs, floored because cuBLAS switches to
        // narrower-tile kernels when a 128×128 grid would underfill the GPU.
        let conc = p.blocks_per_sm * self.spec.sms as f64;
        let eff_wave = (blocks / ((blocks / conc).ceil() * conc)).max(p.wave_floor);
        // Short-K pipeline fill.
        let eff_k = k as f64 / (k as f64 + p.k_fill);

        let eff = p.base_eff_nn * eff_tile * eff_wave * eff_k;
        let flops = 2.0 * m as f64 * n as f64 * k as f64;
        let t_compute = flops / (self.spec.peak_sp_gflops() * 1e9 * eff);

        // DRAM traffic of the blocked kernel: each C-tile streams a
        // 128×k panel of A and of B; C written once.
        let bytes = blocks * (2.0 * p.tile as f64 * k as f64) * 4.0
            + 4.0 * m as f64 * n as f64;
        let t_mem = bytes / (p.gemm_bw_eff * self.spec.peak_bw_gbs() * 1e9);
        (t_compute, t_mem)
    }

    /// NT access penalty factor (≥ 1): grows with K once the streamed
    /// B-panel working set spills L2; larger L2 (Titan X) softens it.
    fn nt_penalty(&self, _m: u64, n: u64, k: u64) -> f64 {
        let p = &self.params;
        // Larger L2 delays the K at which transposed panel streaming starts
        // thrashing: shift the onset right by ~1.5 octaves per L2 doubling.
        let onset = p.nt_k_onset_log2
            + p.nt_onset_l2_coef * (self.spec.l2_cache_kb as f64 / p.nt_l2_ref_kb).log2();
        // Normalized K position on the paper's grid, zero until the onset.
        let sat = (((k as f64).log2() - onset) / (16.0 - onset)).clamp(0.0, 1.0);
        // Working set of transposed-access panels vs L2 capacity.
        let panel_bytes = 4.0 * n as f64 * k as f64;
        let l2 = self.spec.l2_bytes() as f64 * p.nt_l2_mult;
        let spill = 1.0 - (-panel_bytes / l2).exp();
        1.0 + p.nt_base_pen + p.nt_pen_scale * sat.powf(p.nt_pen_gamma) * spill
    }

    // ---- public op timings (seconds) ---------------------------------------

    /// NN GEMM: C[m,n] = A[m,k] × B[k,n].
    pub fn t_nn(&self, m: u64, n: u64, k: u64) -> f64 {
        let (tc, tm) = self.gemm_core(m, n, k);
        (tc.max(tm) + self.params.launch_s) * self.noise(Op::Nn, m, n, k)
    }

    /// NT GEMM: C[m,n] = A[m,k] × B[n,k]ᵀ via the direct cuBLAS-style
    /// transposed-access kernel.
    pub fn t_nt(&self, m: u64, n: u64, k: u64) -> f64 {
        let (tc, tm) = self.gemm_core(m, n, k);
        let pen = self.nt_penalty(m, n, k);
        (tc.max(tm) * pen + self.params.launch_s) * self.noise(Op::Nt, m, n, k)
    }

    /// Out-of-place tiled transpose of an n×k matrix (read + write).
    pub fn t_transpose(&self, n: u64, k: u64) -> f64 {
        let bytes = 2.0 * 4.0 * n as f64 * k as f64;
        let t = bytes / (self.params.transpose_bw_eff * self.spec.peak_bw_gbs() * 1e9);
        (t + self.params.launch_s) * self.noise(Op::Transpose, n, k, 0)
    }

    /// In-place transpose of an n×k matrix — the paper's §VII future-work
    /// alternative. Cycle-following achieves a small fraction of peak
    /// bandwidth (Gomez-Luna et al. report 51.56 GB/s on a 224 GB/s GTX 980
    /// ≈ 23% of peak), degrading further for skewed rectangles whose
    /// permutation cycles are few and long.
    pub fn t_transpose_inplace(&self, n: u64, k: u64) -> f64 {
        let bytes = 2.0 * 4.0 * n as f64 * k as f64;
        let skew = (n.max(k) as f64 / n.min(k) as f64).powf(0.25);
        let eff = (0.23 / skew).max(0.05);
        let t = bytes / (eff * self.spec.peak_bw_gbs() * 1e9);
        (t + self.params.launch_s) * self.noise(Op::Transpose, n, k, 1)
    }

    /// TNN with the in-place transpose: no Bᵀ allocation, but B must be
    /// transposed *back* after the GEMM (the caller does not own B), so
    /// the in-place cost is paid twice and there is no alloc/free.
    pub fn t_tnn_inplace(&self, m: u64, n: u64, k: u64) -> f64 {
        2.0 * self.t_transpose_inplace(n, k) + self.t_nn(m, n, k)
    }

    /// cudaMalloc of `bytes` (fixed + page-mapping cost).
    pub fn t_alloc(&self, bytes: u64) -> f64 {
        (self.params.alloc_fixed_s + bytes as f64 * self.params.alloc_per_byte_s)
            * self.noise(Op::Alloc, bytes, 0, 0)
    }

    /// cudaFree.
    pub fn t_free(&self, _bytes: u64) -> f64 {
        self.params.free_fixed_s
    }

    /// TNN (Algorithm 1): alloc Bᵀ → transpose → NN → free. Reuses the same
    /// NN sample as [`t_nn`] — within one benchmark case the NN kernel run
    /// is the same measurement.
    pub fn t_tnn(&self, m: u64, n: u64, k: u64) -> f64 {
        let bt_bytes = 4 * n * k;
        self.t_alloc(bt_bytes) + self.t_transpose(n, k) + self.t_nn(m, n, k)
            + self.t_free(bt_bytes)
    }

    /// Performance of an algorithm timing in GFLOPS, `P = 2mnk / t`.
    pub fn perf_gflops(m: u64, n: u64, k: u64, t_seconds: f64) -> f64 {
        2.0 * m as f64 * n as f64 * k as f64 / t_seconds / 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::spec::{GTX1080, TITANX};

    fn model() -> TimingModel {
        TimingModel::new(&GTX1080)
    }

    #[test]
    fn nn_large_gemm_near_peak() {
        let m = model();
        let t = m.t_nn(4096, 4096, 4096);
        let p = TimingModel::perf_gflops(4096, 4096, 4096, t);
        let peak = GTX1080.peak_sp_gflops();
        assert!(
            p > 0.55 * peak && p < 0.95 * peak,
            "4096³ NN at {p:.0} GFLOPS vs peak {peak:.0}"
        );
    }

    #[test]
    fn nn_small_gemm_is_inefficient() {
        let m = model();
        let t = m.t_nn(128, 128, 128);
        let p = TimingModel::perf_gflops(128, 128, 128, t);
        assert!(
            p < 0.15 * GTX1080.peak_sp_gflops(),
            "128³ should be launch/latency bound, got {p:.0} GFLOPS"
        );
    }

    #[test]
    fn nt_never_faster_than_nn_modulo_noise() {
        let m = model();
        for &(a, b, c) in &[(128, 128, 128), (1024, 1024, 1024), (8192, 512, 4096)] {
            let ratio = m.t_nt(a, b, c) / m.t_nn(a, b, c);
            assert!(ratio > 0.8, "NT/NN ratio {ratio} at {a}x{b}x{c}");
        }
    }

    #[test]
    fn nt_penalty_grows_with_k() {
        let m = model();
        let p_small = m.nt_penalty(1024, 1024, 128);
        let p_big = m.nt_penalty(1024, 1024, 65536);
        assert!(p_small < 1.15, "small-K penalty should be mild: {p_small}");
        assert!(p_big > 2.0, "large-K penalty should be severe: {p_big}");
        assert!(p_big <= 1.0 + m.params.nt_base_pen + m.params.nt_pen_scale + 1e-9);
    }

    #[test]
    fn titanx_penalty_softer_than_gtx1080() {
        let g = TimingModel::new(&GTX1080);
        let t = TimingModel::new(&TITANX);
        let (n, k) = (4096, 16384);
        assert!(
            t.nt_penalty(0, n, k) < g.nt_penalty(0, n, k),
            "bigger L2 should soften the NT penalty"
        );
    }

    #[test]
    fn tnn_dominated_by_overhead_at_small_sizes() {
        let m = model();
        let t_nt = m.t_nt(128, 128, 128);
        let t_tnn = m.t_tnn(128, 128, 128);
        let ratio = t_tnn / t_nt;
        assert!(
            ratio > 3.0 && ratio < 40.0,
            "TNN should lose badly at 128³ (ratio {ratio:.1})"
        );
    }

    #[test]
    fn tnn_wins_at_large_k() {
        let m = model();
        // Large K, large panels: NT penalty outweighs transpose overhead.
        let (a, b, c) = (8192, 8192, 8192);
        assert!(
            m.t_tnn(a, b, c) < m.t_nt(a, b, c),
            "TNN should win at 8192³"
        );
    }

    #[test]
    fn transpose_is_bandwidth_bound() {
        let m = model();
        let (n, k) = (8192u64, 8192u64);
        let t = m.t_transpose(n, k);
        let gbs = 2.0 * 4.0 * (n * k) as f64 / t / 1e9;
        let peak = GTX1080.peak_bw_gbs();
        assert!(
            gbs > 0.5 * peak && gbs <= peak,
            "transpose at {gbs:.0} GB/s vs peak {peak:.0}"
        );
    }

    #[test]
    fn noise_is_deterministic_and_bounded() {
        let m = model();
        let a = m.t_nt(512, 512, 512);
        let b = m.t_nt(512, 512, 512);
        assert_eq!(a, b, "same case must time identically");
        // Different salt gives a different draw.
        let mut p2 = ModelParams::default();
        p2.noise_salt = 99;
        let m2 = TimingModel::with_params(&GTX1080, p2);
        assert_ne!(a, m2.t_nt(512, 512, 512));
        // Bounded: |log factor| < 6 sigma.
        let ratio = a / (m.t_nt(512, 512, 512) / m.noise(Op::Nt, 512, 512, 512));
        assert!(ratio.ln().abs() < 6.0 * m.params.noise_sigma);
    }

    #[test]
    fn perf_metric_matches_definition() {
        let p = TimingModel::perf_gflops(1000, 1000, 1000, 1.0);
        assert!((p - 2.0).abs() < 1e-12); // 2e9 flops / 1 s = 2 GFLOPS
    }

    #[test]
    fn alloc_scales_with_bytes() {
        let m = model();
        assert!(m.t_alloc(1 << 30) > m.t_alloc(1 << 20) * 5.0);
        assert!(m.t_alloc(0) >= m.params.alloc_fixed_s * 0.8);
    }
}
