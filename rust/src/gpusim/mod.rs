//! GPU timing simulator — the substrate standing in for the paper's
//! physical GTX 1080 / Titan X testbed (DESIGN.md §2).
//!
//! [`Simulator`] wraps a [`TimingModel`] and exposes the paper's benchmark
//! protocol: time NN / NT / TNN for a case `(m, n, k)`, convert to GFLOPS,
//! apply the memory-fit rule, and produce labeled samples.

pub mod calib;
pub mod exec;
pub mod model;
pub mod spec;

pub use exec::SimExecutor;
pub use model::{ModelParams, TimingModel};
pub use spec::{GpuSpec, ALL_GPUS, GTX1070, GTX1080, PAPER_GPUS, SIMAPEX, SIMECO, TITANX};

/// The paper's benchmark size grid S = {2^7, 2^8, ..., 2^16}.
pub const SIZE_GRID: [u64; 10] = [128, 256, 512, 1024, 2048, 4096, 8192, 16384, 32768, 65536];

/// Timings and performances for one (m, n, k) case on one GPU.
#[derive(Debug, Clone, Copy)]
pub struct CaseTiming {
    pub m: u64,
    pub n: u64,
    pub k: u64,
    /// Seconds.
    pub t_nn: f64,
    pub t_nt: f64,
    pub t_tnn: f64,
    /// GFLOPS of the 2mnk useful work.
    pub p_nn: f64,
    pub p_nt: f64,
    pub p_tnn: f64,
}

impl CaseTiming {
    /// The paper's label: `+1` if `P_NT ≥ P_TNN` (choose NT),
    /// `-1` otherwise (choose TNN). `D = P_NT − P_TNN`.
    pub fn label(&self) -> i8 {
        if self.p_nt >= self.p_tnn {
            1
        } else {
            -1
        }
    }

    /// `D(m,n,k) = P_NT − P_TNN` in GFLOPS.
    pub fn d(&self) -> f64 {
        self.p_nt - self.p_tnn
    }
}

/// Simulator for one GPU.
#[derive(Debug, Clone)]
pub struct Simulator {
    pub model: TimingModel,
}

impl Simulator {
    pub fn new(spec: &'static GpuSpec) -> Simulator {
        Simulator {
            model: TimingModel::new(spec),
        }
    }

    pub fn with_params(spec: &'static GpuSpec, params: ModelParams) -> Simulator {
        Simulator {
            model: TimingModel::with_params(spec, params),
        }
    }

    pub fn spec(&self) -> &'static GpuSpec {
        self.model.spec
    }

    /// Bytes needed to run NT in-place: A + B + C.
    pub fn nt_workspace_bytes(m: u64, n: u64, k: u64) -> u64 {
        4 * (m * k + n * k + m * n)
    }

    /// Bytes needed by TNN: A + B + Bᵀ + C.
    pub fn tnn_workspace_bytes(m: u64, n: u64, k: u64) -> u64 {
        Self::nt_workspace_bytes(m, n, k) + 4 * n * k
    }

    /// The dataset validity rule (Table II): the case must fit with the
    /// extra Bᵀ buffer, since benchmarking measured both algorithms.
    pub fn fits(&self, m: u64, n: u64, k: u64) -> bool {
        Self::tnn_workspace_bytes(m, n, k) <= self.spec().global_mem_bytes()
    }

    /// Whether only NT fits (MTNN must then fall back to NT at runtime).
    pub fn fits_nt_only(&self, m: u64, n: u64, k: u64) -> bool {
        Self::nt_workspace_bytes(m, n, k) <= self.spec().global_mem_bytes()
            && !self.fits(m, n, k)
    }

    /// Benchmark one case (both algorithms + the underlying NN).
    pub fn time_case(&self, m: u64, n: u64, k: u64) -> CaseTiming {
        let t_nn = self.model.t_nn(m, n, k);
        let t_nt = self.model.t_nt(m, n, k);
        let t_tnn = self.model.t_tnn(m, n, k);
        CaseTiming {
            m,
            n,
            k,
            t_nn,
            t_nt,
            t_tnn,
            p_nn: TimingModel::perf_gflops(m, n, k, t_nn),
            p_nt: TimingModel::perf_gflops(m, n, k, t_nt),
            p_tnn: TimingModel::perf_gflops(m, n, k, t_tnn),
        }
    }

    /// The paper's full 1000-case sweep over S³, keeping only cases that
    /// satisfy the memory-fit rule.
    pub fn sweep(&self) -> Vec<CaseTiming> {
        let mut out = Vec::new();
        for &m in &SIZE_GRID {
            for &n in &SIZE_GRID {
                for &k in &SIZE_GRID {
                    if self.fits(m, n, k) {
                        out.push(self.time_case(m, n, k));
                    }
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_matches_paper() {
        assert_eq!(SIZE_GRID.len(), 10);
        assert_eq!(SIZE_GRID[0], 1 << 7);
        assert_eq!(SIZE_GRID[9], 1 << 16);
    }

    #[test]
    fn valid_sample_counts_match_table2() {
        // Paper Table II: 891 valid samples on GTX1080, 941 on TitanX.
        // Our memory rule reproduces 891 exactly and 937 (≈941) — the
        // 4-sample delta is borderline allocator granularity (EXPERIMENTS.md).
        let g = Simulator::new(&GTX1080).sweep().len();
        let t = Simulator::new(&TITANX).sweep().len();
        assert_eq!(g, 891, "GTX1080 valid samples");
        assert!((930..=945).contains(&t), "TitanX valid samples: {t}");
    }

    #[test]
    fn label_follows_paper_convention() {
        let c = CaseTiming {
            m: 1,
            n: 1,
            k: 1,
            t_nn: 1.0,
            t_nt: 1.0,
            t_tnn: 2.0,
            p_nn: 2.0,
            p_nt: 2.0,
            p_tnn: 1.0,
        };
        assert_eq!(c.label(), 1); // NT faster → +1
        assert!(c.d() > 0.0);
    }

    #[test]
    fn workspace_accounting() {
        assert_eq!(Simulator::nt_workspace_bytes(2, 3, 4), 4 * (8 + 12 + 6));
        assert_eq!(
            Simulator::tnn_workspace_bytes(2, 3, 4),
            Simulator::nt_workspace_bytes(2, 3, 4) + 48
        );
    }

    #[test]
    fn biggest_case_does_not_fit() {
        let s = Simulator::new(&GTX1080);
        assert!(!s.fits(65536, 65536, 65536));
        assert!(s.fits(128, 128, 128));
    }

    #[test]
    fn sweep_is_deterministic() {
        let s = Simulator::new(&GTX1080);
        let a = s.sweep();
        let b = s.sweep();
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.t_nt, y.t_nt);
        }
    }
}
