//! GPU hardware descriptions — the paper's Table I / Table III.
//!
//! These five characteristics (global memory, #SMs, core clock, memory bus
//! width, L2 size) are exactly the GPU-side features of the MTNN input
//! vector `(gm, sm, cc, mbw, l2c, m, n, k)`.

/// Static description of a GPU, mirroring the paper's Table III plus the
/// core count from Table I (used to derive peak FLOPS).
#[derive(Debug, Clone, PartialEq)]
pub struct GpuSpec {
    pub name: &'static str,
    /// Stable **unique** id: seeds deterministic measurement noise and is
    /// the identity key for shape-keyed selection caching
    /// (`selector::cache::DecisionCache`). Custom specs must use an id
    /// distinct from every other spec in the process, or cached decisions
    /// computed for one GPU will be served for the other.
    pub id: u64,
    pub compute_capability: f64,
    /// Global memory in GiB (paper writes "8 GB" / "10 GB").
    pub global_mem_gib: u64,
    /// Number of streaming multiprocessors.
    pub sms: u64,
    /// CUDA cores (Table I).
    pub cuda_cores: u64,
    /// Core clock in MHz.
    pub core_clock_mhz: f64,
    /// Memory clock in MHz (DDR: effective transfer rate is 2×).
    pub mem_clock_mhz: f64,
    /// Memory bus width in bits.
    pub mem_bus_width_bits: u64,
    /// L2 cache in KiB.
    pub l2_cache_kb: u64,
}

/// NVIDIA GeForce GTX 1080 (Pascal), as characterized in Tables I & III.
pub const GTX1080: GpuSpec = GpuSpec {
    name: "GTX1080",
    id: 1,
    compute_capability: 6.1,
    global_mem_gib: 8,
    sms: 20,
    cuda_cores: 2560,
    core_clock_mhz: 1607.0,
    mem_clock_mhz: 5005.0,
    mem_bus_width_bits: 256,
    l2_cache_kb: 2048,
};

/// NVIDIA Titan X (Pascal), as characterized in Tables I & III.
pub const TITANX: GpuSpec = GpuSpec {
    name: "TitanX",
    id: 2,
    compute_capability: 6.1,
    global_mem_gib: 10,
    sms: 28,
    cuda_cores: 3584,
    core_clock_mhz: 1417.0,
    mem_clock_mhz: 5005.0,
    mem_bus_width_bits: 384,
    l2_cache_kb: 3072,
};

/// NVIDIA GeForce GTX 1070 (Pascal) — NOT part of the paper's testbed.
/// Used by the cross-GPU generalization study (EXPERIMENTS.md §Gen):
/// train the selector on the paper's two GPUs, test on this unseen one.
pub const GTX1070: GpuSpec = GpuSpec {
    name: "GTX1070",
    id: 3,
    compute_capability: 6.1,
    global_mem_gib: 8,
    sms: 15,
    cuda_cores: 1920,
    core_clock_mhz: 1506.0,
    mem_clock_mhz: 4004.0, // 8 Gbps GDDR5 → 256 GB/s on a 256-bit bus
    mem_bus_width_bits: 256,
    l2_cache_kb: 2048,
};

/// Imagined next-generation part — NOT a real product. Faster than
/// everything in the paper's testbed (more SMs, more cores, higher
/// clocks, a wider bus, a bigger L2), it anchors the fast end of the
/// heterogeneous fleet the placement tests schedule across.
pub const SIMAPEX: GpuSpec = GpuSpec {
    name: "SimApex",
    id: 4,
    compute_capability: 7.0,
    global_mem_gib: 16,
    sms: 40,
    cuda_cores: 5120,
    core_clock_mhz: 1800.0,
    mem_clock_mhz: 6000.0, // 12 Gbps effective → 576 GB/s on a 384-bit bus
    mem_bus_width_bits: 384,
    l2_cache_kb: 4096,
};

/// Imagined low-power part — NOT a real product. Far slower than the
/// testbed (few SMs, low clocks, a narrow bus) with a deliberately tiny
/// 256 KiB L2: the NT layout spills L2 at k depths the Pascal parts
/// shrug off, so the NT/TNN crossover sits somewhere genuinely
/// different. The fleet's device-swap drift tests rely on that flip.
pub const SIMECO: GpuSpec = GpuSpec {
    name: "SimEco",
    id: 5,
    compute_capability: 6.2,
    global_mem_gib: 4,
    sms: 5,
    cuda_cores: 640,
    core_clock_mhz: 1000.0,
    mem_clock_mhz: 1500.0, // 3 Gbps effective → 48 GB/s on a 128-bit bus
    mem_bus_width_bits: 128,
    l2_cache_kb: 256,
};

/// Both GPUs of the paper's testbed, in paper order.
pub const PAPER_GPUS: [&GpuSpec; 2] = [&GTX1080, &TITANX];

/// Testbed + the held-out GPU for the generalization study + the two
/// imagined parts bounding the heterogeneous fleet (fast and slow).
pub const ALL_GPUS: [&GpuSpec; 5] = [&GTX1080, &TITANX, &GTX1070, &SIMAPEX, &SIMECO];

impl GpuSpec {
    /// Theoretical single-precision peak in GFLOPS (2 FLOPs/core/cycle FMA).
    pub fn peak_sp_gflops(&self) -> f64 {
        2.0 * self.cuda_cores as f64 * self.core_clock_mhz / 1e3
    }

    /// Peak memory bandwidth in GB/s (DDR: 2 transfers/clock).
    pub fn peak_bw_gbs(&self) -> f64 {
        self.mem_clock_mhz * 1e6 * 2.0 * (self.mem_bus_width_bits as f64 / 8.0) / 1e9
    }

    /// Usable global memory in bytes.
    pub fn global_mem_bytes(&self) -> u64 {
        self.global_mem_gib * (1 << 30)
    }

    /// L2 size in bytes.
    pub fn l2_bytes(&self) -> u64 {
        self.l2_cache_kb * 1024
    }

    /// The paper's 5 GPU-side input features `(gm, sm, cc, mbw, l2c)`.
    /// Feature generation is O(1) as the paper requires.
    pub fn features(&self) -> [f64; 5] {
        [
            self.global_mem_gib as f64,
            self.sms as f64,
            self.core_clock_mhz,
            self.mem_bus_width_bits as f64,
            self.l2_cache_kb as f64,
        ]
    }

    /// Look up a known GPU by (case-insensitive) name.
    pub fn by_name(name: &str) -> Option<&'static GpuSpec> {
        ALL_GPUS
            .iter()
            .copied()
            .find(|g| g.name.eq_ignore_ascii_case(name))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derived_peaks_match_datasheets() {
        // GTX1080: 2×2560×1.607 GHz ≈ 8228 GFLOPS, 320 GB/s.
        assert!((GTX1080.peak_sp_gflops() - 8227.8).abs() < 1.0);
        assert!((GTX1080.peak_bw_gbs() - 320.3).abs() < 1.0);
        // TitanX: ≈ 10157 GFLOPS, 480 GB/s.
        assert!((TITANX.peak_sp_gflops() - 10157.0).abs() < 5.0);
        assert!((TITANX.peak_bw_gbs() - 480.5).abs() < 1.0);
    }

    #[test]
    fn features_are_the_papers_five() {
        let f = GTX1080.features();
        assert_eq!(f, [8.0, 20.0, 1607.0, 256.0, 2048.0]);
        assert_eq!(TITANX.features()[4], 3072.0);
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(GpuSpec::by_name("gtx1080").unwrap().id, 1);
        assert_eq!(GpuSpec::by_name("TITANX").unwrap().id, 2);
        assert!(GpuSpec::by_name("a100").is_none());
    }

    #[test]
    fn ids_are_distinct() {
        for (i, a) in ALL_GPUS.iter().enumerate() {
            for b in &ALL_GPUS[i + 1..] {
                assert_ne!(a.id, b.id, "{} vs {}", a.name, b.name);
                assert!(
                    !a.name.eq_ignore_ascii_case(b.name),
                    "names must be unique for by_name: {} vs {}",
                    a.name,
                    b.name
                );
            }
        }
    }

    #[test]
    fn gtx1070_derived_peaks() {
        // 2×1920×1.506 GHz ≈ 5783 GFLOPS, 256 GB/s.
        assert!((GTX1070.peak_sp_gflops() - 5783.0).abs() < 5.0);
        assert!((GTX1070.peak_bw_gbs() - 256.3).abs() < 1.0);
        assert!(GpuSpec::by_name("gtx1070").is_some());
    }

    #[test]
    fn imagined_parts_bound_the_fleet() {
        // SimApex must be the fastest part in the process; SimEco the
        // slowest — the fleet placement tests assume that ordering.
        for g in ALL_GPUS {
            assert!(SIMAPEX.peak_sp_gflops() >= g.peak_sp_gflops(), "{}", g.name);
            assert!(SIMECO.peak_sp_gflops() <= g.peak_sp_gflops(), "{}", g.name);
        }
        // 2×5120×1.8 GHz ≈ 18432 GFLOPS; 2×640×1.0 GHz = 1280 GFLOPS.
        assert!((SIMAPEX.peak_sp_gflops() - 18432.0).abs() < 1.0);
        assert!((SIMECO.peak_sp_gflops() - 1280.0).abs() < 1.0);
        assert!((SIMAPEX.peak_bw_gbs() - 576.0).abs() < 1.0);
        assert!((SIMECO.peak_bw_gbs() - 48.0).abs() < 1.0);
        // SimEco's tiny L2 is load-bearing for the NT/TNN crossover flip.
        assert_eq!(SIMECO.l2_cache_kb, 256);
        assert_eq!(GpuSpec::by_name("simapex").unwrap().id, 4);
        assert_eq!(GpuSpec::by_name("SimEco").unwrap().id, 5);
    }
}
