//! PJRT runtime: load AOT-lowered HLO-text artifacts and execute them on
//! the CPU PJRT client from the L3 request path (no Python anywhere).
//!
//! Pattern follows /opt/xla-example/load_hlo: `HloModuleProto::from_text_file`
//! → `XlaComputation::from_proto` → `client.compile` → `execute`. Compiled
//! executables are cached per artifact name; compilation happens at most
//! once per process (or eagerly via [`Runtime::warmup`]).

pub mod manifest;

pub use manifest::{ArtifactEntry, Manifest, TensorSpec};

use crate::gemm::cpu::Matrix;
use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex};

/// Execution statistics (exposed to the coordinator's metrics).
#[derive(Debug, Clone, Copy, Default)]
pub struct RuntimeStats {
    pub compiles: u64,
    pub executions: u64,
    pub cache_hits: u64,
}

/// The PJRT runtime: client + artifact manifest + executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: Mutex<HashMap<String, Arc<xla::PjRtLoadedExecutable>>>,
    stats: Mutex<RuntimeStats>,
}

impl Runtime {
    /// Create a CPU-PJRT runtime over an artifact directory.
    pub fn new(artifact_dir: impl AsRef<Path>) -> anyhow::Result<Runtime> {
        let manifest = Manifest::load(&artifact_dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("creating PJRT CPU client: {e:?}"))?;
        Ok(Runtime {
            client,
            manifest,
            cache: Mutex::new(HashMap::new()),
            stats: Mutex::new(RuntimeStats::default()),
        })
    }

    /// Default artifact location relative to the repo root.
    pub fn default_dir() -> std::path::PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn stats(&self) -> RuntimeStats {
        *self.stats.lock().unwrap()
    }

    /// Compile (or fetch from cache) the executable for an artifact.
    pub fn executable(&self, name: &str) -> anyhow::Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(name) {
            self.stats.lock().unwrap().cache_hits += 1;
            return Ok(exe.clone());
        }
        let entry = self.manifest.get(name)?;
        let proto = xla::HloModuleProto::from_text_file(&entry.file)
            .map_err(|e| anyhow::anyhow!("parsing {}: {e:?}", entry.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compiling {name}: {e:?}"))?;
        let exe = Arc::new(exe);
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), exe.clone());
        self.stats.lock().unwrap().compiles += 1;
        Ok(exe)
    }

    /// Eagerly compile a set of artifacts (e.g. at server start).
    pub fn warmup(&self, names: &[&str]) -> anyhow::Result<()> {
        for n in names {
            self.executable(n)?;
        }
        Ok(())
    }

    /// Execute an artifact on row-major f32 matrices. 1-D inputs (biases)
    /// are passed as matrices with `rows == 1` and reshaped per manifest.
    pub fn execute(&self, name: &str, inputs: &[&Matrix]) -> anyhow::Result<Vec<Matrix>> {
        let entry = self.manifest.get(name)?.clone();
        anyhow::ensure!(
            inputs.len() == entry.inputs.len(),
            "{name}: expected {} inputs, got {}",
            entry.inputs.len(),
            inputs.len()
        );
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, (m, spec)) in inputs.iter().zip(&entry.inputs).enumerate() {
            anyhow::ensure!(
                m.data.len() == spec.elements(),
                "{name}: input {i} has {} elements, manifest says {:?}",
                m.data.len(),
                spec.shape
            );
            let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(&m.data)
                .reshape(&dims)
                .map_err(|e| anyhow::anyhow!("{name}: reshaping input {i}: {e:?}"))?;
            literals.push(lit);
        }
        let exe = self.executable(name)?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow::anyhow!("executing {name}: {e:?}"))?;
        self.stats.lock().unwrap().executions += 1;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("{name}: fetching result: {e:?}"))?;
        // aot.py lowers with return_tuple=True → always a tuple literal.
        let parts = tuple
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("{name}: untupling result: {e:?}"))?;
        anyhow::ensure!(
            parts.len() == entry.n_outputs,
            "{name}: manifest says {} outputs, got {}",
            entry.n_outputs,
            parts.len()
        );
        let mut out = Vec::with_capacity(parts.len());
        for (i, p) in parts.into_iter().enumerate() {
            let shape = p
                .array_shape()
                .map_err(|e| anyhow::anyhow!("{name}: output {i} shape: {e:?}"))?;
            let dims = shape.dims();
            let (rows, cols) = match dims.len() {
                0 => (1usize, 1usize),
                1 => (1, dims[0] as usize),
                2 => (dims[0] as usize, dims[1] as usize),
                d => anyhow::bail!("{name}: output {i} has rank {d} > 2"),
            };
            let data = p
                .to_vec::<f32>()
                .map_err(|e| anyhow::anyhow!("{name}: output {i} data: {e:?}"))?;
            out.push(Matrix::from_vec(rows, cols, data));
        }
        Ok(out)
    }
}

/// The PJRT arm of the engine pool's execution layer. The vendored `xla`
/// stub's client is a plain (`Send`) struct, so a `Runtime` built on the
/// caller thread can move into a worker; with the real `Rc`-based `xla-rs`
/// client this impl would have to be constructed on its worker thread.
/// Each pool worker owns its own `Runtime`, hence its own executable
/// cache — warmup broadcasts so every worker compiles its copy.
impl crate::coordinator::backend::ExecBackend for Runtime {
    fn execute(&self, artifact: &str, inputs: &[&Matrix]) -> anyhow::Result<Vec<Matrix>> {
        Runtime::execute(self, artifact, inputs)
    }

    fn warmup(&self, names: &[&str]) -> anyhow::Result<()> {
        Runtime::warmup(self, names)
    }

    fn name(&self) -> String {
        format!("pjrt:{}", self.platform())
    }
}
