//! Artifact manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime. Parsed from `artifacts/manifest.json`.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Shape + dtype of one artifact input.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT-lowered HLO module.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub n_outputs: usize,
    /// Free-form metadata from the catalog (op kind, plan, tiles, ...).
    pub meta: Json,
}

/// The full manifest, indexed by artifact name.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: BTreeMap<String, ArtifactEntry>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e} (run `make artifacts`)", path.display()))?;
        let j = Json::parse(&text)?;
        anyhow::ensure!(
            j.get("format").as_str() == Some("mtnn-artifacts-v1"),
            "unknown manifest format"
        );
        let mut entries = BTreeMap::new();
        let arr = j
            .get("entries")
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("manifest: entries missing"))?;
        for e in arr {
            let name = e
                .get("name")
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("manifest: entry without name"))?
                .to_string();
            let file = dir.join(
                e.get("file")
                    .as_str()
                    .ok_or_else(|| anyhow::anyhow!("manifest: {name} without file"))?,
            );
            let mut inputs = Vec::new();
            for inp in e
                .get("inputs")
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("manifest: {name} without inputs"))?
            {
                let shape = inp
                    .get("shape")
                    .as_arr()
                    .ok_or_else(|| anyhow::anyhow!("manifest: bad shape in {name}"))?
                    .iter()
                    .map(|v| v.as_usize().unwrap_or(0))
                    .collect();
                inputs.push(TensorSpec {
                    shape,
                    dtype: inp.get("dtype").as_str().unwrap_or("f32").to_string(),
                });
            }
            let n_outputs = e
                .get("n_outputs")
                .as_usize()
                .ok_or_else(|| anyhow::anyhow!("manifest: {name} without n_outputs"))?;
            entries.insert(
                name.clone(),
                ArtifactEntry {
                    name,
                    file,
                    inputs,
                    n_outputs,
                    meta: e.get("meta").clone(),
                },
            );
        }
        Ok(Manifest { dir, entries })
    }

    pub fn get(&self, name: &str) -> anyhow::Result<&ArtifactEntry> {
        self.entries.get(name).ok_or_else(|| {
            anyhow::anyhow!(
                "artifact '{name}' not in manifest ({} entries); run `make artifacts`",
                self.entries.len()
            )
        })
    }

    /// Names of GEMM-service artifacts of a given algorithm kind.
    pub fn gemm_entries(&self, algo: &str) -> Vec<&ArtifactEntry> {
        self.entries
            .values()
            .filter(|e| {
                e.meta.get("op").as_str() == Some("gemm")
                    && e.meta.get("algo").as_str() == Some(algo)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
    }

    #[test]
    fn parses_minimal_manifest() {
        let dir = std::env::temp_dir().join("mtnn_manifest_test");
        write_manifest(
            &dir,
            r#"{"format": "mtnn-artifacts-v1", "entries": [
                {"name": "nt_2x2x2", "file": "nt.hlo.txt",
                 "inputs": [{"shape": [2,2], "dtype": "f32"}],
                 "n_outputs": 1,
                 "meta": {"op": "gemm", "algo": "nt"}}
            ]}"#,
        );
        let m = Manifest::load(&dir).unwrap();
        let e = m.get("nt_2x2x2").unwrap();
        assert_eq!(e.inputs[0].shape, vec![2, 2]);
        assert_eq!(e.inputs[0].elements(), 4);
        assert_eq!(e.n_outputs, 1);
        assert_eq!(m.gemm_entries("nt").len(), 1);
        assert!(m.gemm_entries("tnn").is_empty());
        assert!(m.get("missing").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_wrong_format() {
        let dir = std::env::temp_dir().join("mtnn_manifest_bad");
        write_manifest(&dir, r#"{"format": "v999", "entries": []}"#);
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_dir_mentions_make_artifacts() {
        let err = Manifest::load("/nonexistent/path").unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }

    #[test]
    fn real_manifest_loads_if_built() {
        // Exercised against the actual artifacts when present (CI runs
        // `make artifacts` first; unit tests skip gracefully otherwise).
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.entries.len() >= 20);
            assert!(m.get("nt_128x128x128").is_ok());
            assert!(m.get("fcn_train_nt-nt-nt").is_ok());
        }
    }
}
