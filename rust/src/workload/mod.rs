//! The adversarial workload lab: seeded trace generation, trace replay
//! through the serving stack, and chaos injection — the robustness
//! harness for the coordinator and the online adaptive-selection loop.
//!
//! Three pieces, composable and all deterministic under a fixed seed:
//!
//! * [`generator`] — a composable phase-based trace generator. A
//!   [`Phase`] describes a traffic regime (steady load, a flash crowd,
//!   a shape migration, a diurnal ramp, a device swap, a Zipf-repeating
//!   repeat-heavy working set); chaining phases yields a [`Trace`] of
//!   timed [`TraceEvent`]s with seeded exponential inter-arrivals. Each
//!   event carries a content-identity `payload` the replay seeds request
//!   matrices from, so repeat-heavy traffic repeats *byte-for-byte* —
//!   the regime that exercises the engine's result-reuse layer. Regime
//!   *changes* — the thing the online loop must survive — are just
//!   phase boundaries.
//! * [`replay`] — drives a [`Trace`] through a live [`Router`] from a
//!   configurable number of client threads, either paced against the
//!   trace's own clock ([`ReplayClock::Paced`]) or as fast as possible
//!   ([`ReplayClock::Afap`]). Every request resolves into exactly one
//!   of completed / failed / shed (admission-control rejections,
//!   classified via [`EngineBusy`]) / timed out (deadline expiries,
//!   classified via [`DeadlineExceeded`]), so the returned
//!   [`ReplayReport`] is a client-side conservation ledger to check
//!   against `CoordinatorMetrics::verify_conservation`. [`replay_with_chaos`]
//!   additionally kills and restarts an engine worker mid-trace
//!   ([`Engine::kill_worker`] / [`Engine::restart_worker`]), triggered
//!   by submitted-request counts, elapsed trace time, or both
//!   ([`WorkerChaos`]). [`replay_fleet`] drives the same traces through
//!   a whole [`Fleet`] — placement, not the event's `gpu`, decides the
//!   device — and a [`FleetSwap`] schedule (derivable from a
//!   `DeviceSwap` phase via [`FleetSwap::from_trace`]) performs the
//!   real mid-run spec swap the phase describes.
//! * [`chaos`] — [`ChaosBackend`], a fault-injecting [`ExecBackend`]
//!   wrapper: per-call seeded rolls inject typed transient failures
//!   (retryable by the router's bounded-retry policy), panics
//!   (contained by the engine's worker loop, surfacing as failed jobs),
//!   and capped latency spikes, plus a deterministic sick-artifact
//!   knob for circuit-breaker proofs, with atomic [`ChaosStats`]
//!   counters so tests can assert faults actually fired.
//!
//! The invariant the whole lab exists to check:
//! `completed + failed + shed + timed_out == submitted` — no request is
//! ever silently dropped and no client ever hangs, no matter what the
//! trace, the deadlines, or the chaos does.
//!
//! [`Router`]: crate::coordinator::Router
//! [`Fleet`]: crate::coordinator::Fleet
//! [`EngineBusy`]: crate::coordinator::EngineBusy
//! [`DeadlineExceeded`]: crate::coordinator::DeadlineExceeded
//! [`ExecBackend`]: crate::coordinator::ExecBackend
//! [`Engine::kill_worker`]: crate::coordinator::Engine::kill_worker
//! [`Engine::restart_worker`]: crate::coordinator::Engine::restart_worker

pub mod chaos;
pub mod generator;
pub mod replay;

pub use chaos::{ChaosBackend, ChaosConfig, ChaosStats};
pub use generator::{Phase, PhaseKind, Trace, TraceEvent};
pub use replay::{
    replay, replay_fleet, replay_with_chaos, FleetSwap, ReplayClock, ReplayOptions, ReplayReport,
    WorkerChaos,
};
