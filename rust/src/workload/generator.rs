//! Seeded, deterministic, phase-composable workload traces.
//!
//! A trace is a time-ordered list of GEMM requests. Each [`Phase`]
//! contributes a segment with its own traffic regime; inter-arrival
//! times are exponential (a seeded Poisson process whose rate the phase
//! kind modulates over the phase), so the same `(phases, seed)` pair
//! always yields the identical trace — replayable experiments, byte-for-
//! byte.

use crate::gemm::GemmShape;
use crate::gpusim::GpuSpec;
use crate::util::rng::{mix_parts, Xoshiro256pp};
use std::time::Duration;

/// What a phase's traffic looks like.
#[derive(Debug, Clone)]
pub enum PhaseKind {
    /// Constant rate, shapes drawn uniformly from the phase pool.
    Steady,
    /// Rate ramps linearly up to `peak_x ×` the base rate at the phase
    /// midpoint and back down — a flash crowd.
    FlashCrowd { peak_x: f64 },
    /// Rate stays constant while the shape pool crossfades: an event at
    /// fraction `f` through the phase draws from `to` with probability
    /// `f`, from the phase pool otherwise — the gradual regime change
    /// that drift detection must catch.
    ShapeMigration { to: Vec<GemmShape> },
    /// Rate oscillates between `trough_x ×` and `1 ×` the base rate over
    /// `cycles` full cosine cycles — compressed diurnal traffic.
    DiurnalRamp { cycles: f64, trough_x: f64 },
    /// Requests switch from the phase GPU to `to` at fraction `at_frac`
    /// of the phase — an abrupt hardware regime change.
    DeviceSwap {
        to: &'static GpuSpec,
        at_frac: f64,
    },
    /// Constant rate over a Zipf-repeating working set: each event draws
    /// a rank from Zipf(`exponent`) over `distinct` identities; rank `r`
    /// maps to shape `pool[r % pool.len()]` and a rank-deterministic
    /// [`TraceEvent::payload`], so hot identities repeat *byte-for-byte*
    /// — the regime a result-reuse cache feeds on.
    RepeatHeavy { distinct: usize, exponent: f64 },
}

/// Payload-derivation domain separators (see [`TraceEvent::payload`]).
const UNIQUE_PAYLOAD: u64 = 0x6E57_11E0;
const REPEAT_PAYLOAD: u64 = 0x5E9A_7B2C;

/// Precomputed Zipf CDF over ranks `0..n`: rank `r` weighs `1/(r+1)^s`.
struct ZipfTable {
    cum: Vec<f64>,
}

impl ZipfTable {
    fn new(n: usize, s: f64) -> ZipfTable {
        assert!(n > 0, "RepeatHeavy needs a non-empty working set");
        let mut cum = Vec::with_capacity(n);
        let mut total = 0.0f64;
        for r in 0..n {
            total += 1.0 / ((r + 1) as f64).powf(s);
            cum.push(total);
        }
        ZipfTable { cum }
    }

    /// First rank whose cumulative weight exceeds `u × total` (binary
    /// search; `u ∈ [0,1)`).
    fn sample(&self, u: f64) -> usize {
        let target = u * self.cum[self.cum.len() - 1];
        self.cum.partition_point(|&c| c <= target).min(self.cum.len() - 1)
    }
}

/// One segment of a trace: a regime, its shape pool, its base rate.
#[derive(Debug, Clone)]
pub struct Phase {
    pub kind: PhaseKind,
    /// GPU the phase's requests target (the starting GPU for
    /// [`PhaseKind::DeviceSwap`]).
    pub gpu: &'static GpuSpec,
    /// Shape pool events draw from (uniformly, except during a
    /// [`PhaseKind::ShapeMigration`] crossfade).
    pub shapes: Vec<GemmShape>,
    /// Base request rate, requests/second of *trace* time.
    pub rps: f64,
    pub duration: Duration,
}

/// One timed request in a trace.
#[derive(Debug, Clone, Copy)]
pub struct TraceEvent {
    /// Offset from trace start.
    pub at: Duration,
    pub gpu: &'static GpuSpec,
    pub shape: GemmShape,
    /// Content identity of the request: replay derives the input matrices
    /// from this, so equal `(shape, payload)` means bit-identical request
    /// content. [`PhaseKind::RepeatHeavy`] deliberately repeats
    /// identities; every other phase emits unique payloads. Derived from
    /// counters/ranks via `mix_parts`, *not* from the phase's rng stream,
    /// so adding it changed no existing trace's event sequence.
    pub payload: u64,
    /// Index of the [`Phase`] that emitted this event.
    pub phase: usize,
}

/// A generated trace: time-ordered events plus the seed that made it.
#[derive(Debug, Clone)]
pub struct Trace {
    pub events: Vec<TraceEvent>,
    pub seed: u64,
}

impl Trace {
    /// Generate the deterministic trace for `(phases, seed)`.
    ///
    /// Panics if a phase has an empty shape pool, a non-positive rate,
    /// or a zero duration — a trace that can't emit events is a bug in
    /// the experiment, not a workload.
    pub fn generate(phases: &[Phase], seed: u64) -> Trace {
        let mut events = Vec::new();
        let mut base = Duration::ZERO;
        for (pi, phase) in phases.iter().enumerate() {
            assert!(!phase.shapes.is_empty(), "phase {pi}: empty shape pool");
            assert!(phase.rps > 0.0, "phase {pi}: non-positive rate");
            assert!(!phase.duration.is_zero(), "phase {pi}: zero duration");
            let mut rng = Xoshiro256pp::new(mix_parts(&[seed, pi as u64]));
            let zipf = match &phase.kind {
                PhaseKind::RepeatHeavy { distinct, exponent } => {
                    Some(ZipfTable::new(*distinct, *exponent))
                }
                _ => None,
            };
            let total = phase.duration.as_secs_f64();
            let mut t = 0.0f64;
            let mut emitted = 0u64;
            loop {
                let frac = t / total;
                let rate = phase.rps * rate_multiplier(&phase.kind, frac);
                // Exponential inter-arrival at the local rate; 1−u ∈ (0,1]
                // keeps ln finite.
                t += -(1.0 - rng.next_f64()).ln() / rate;
                if t >= total {
                    break;
                }
                let frac = t / total;
                let (shape, payload) = match &zipf {
                    Some(table) => {
                        let rank = table.sample(rng.next_f64());
                        (
                            phase.shapes[rank % phase.shapes.len()],
                            mix_parts(&[seed, REPEAT_PAYLOAD, pi as u64, rank as u64]),
                        )
                    }
                    None => (
                        event_shape(&phase.kind, &phase.shapes, frac, &mut rng),
                        mix_parts(&[seed, UNIQUE_PAYLOAD, pi as u64, emitted]),
                    ),
                };
                emitted += 1;
                events.push(TraceEvent {
                    at: base + Duration::from_secs_f64(t),
                    gpu: event_gpu(&phase.kind, phase.gpu, frac),
                    shape,
                    payload,
                    phase: pi,
                });
            }
            base += phase.duration;
        }
        Trace { events, seed }
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Total trace span (sum of phase durations is an upper bound; this
    /// is the last event's offset, zero for an empty trace).
    pub fn span(&self) -> Duration {
        self.events.last().map(|e| e.at).unwrap_or(Duration::ZERO)
    }

    /// Distinct shapes in the trace, in first-appearance order — the
    /// warmup set for a replay.
    pub fn distinct_shapes(&self) -> Vec<GemmShape> {
        let mut seen = std::collections::HashSet::new();
        let mut out = Vec::new();
        for e in &self.events {
            if seen.insert(e.shape) {
                out.push(e.shape);
            }
        }
        out
    }
}

/// Instantaneous rate multiplier at fraction `frac` of the phase.
fn rate_multiplier(kind: &PhaseKind, frac: f64) -> f64 {
    match kind {
        PhaseKind::Steady
        | PhaseKind::ShapeMigration { .. }
        | PhaseKind::DeviceSwap { .. }
        | PhaseKind::RepeatHeavy { .. } => 1.0,
        PhaseKind::FlashCrowd { peak_x } => {
            // Triangle: 1× at the edges, peak_x× at the midpoint.
            1.0 + (peak_x - 1.0) * (1.0 - (2.0 * frac - 1.0).abs())
        }
        PhaseKind::DiurnalRamp { cycles, trough_x } => {
            let swing = 0.5 * (1.0 - (std::f64::consts::TAU * cycles * frac).cos());
            trough_x + (1.0 - trough_x) * swing
        }
    }
}

fn event_gpu(kind: &PhaseKind, base: &'static GpuSpec, frac: f64) -> &'static GpuSpec {
    match kind {
        PhaseKind::DeviceSwap { to, at_frac } if frac >= *at_frac => to,
        _ => base,
    }
}

fn event_shape(
    kind: &PhaseKind,
    pool: &[GemmShape],
    frac: f64,
    rng: &mut Xoshiro256pp,
) -> GemmShape {
    let draw = |pool: &[GemmShape], rng: &mut Xoshiro256pp| {
        pool[rng.next_bounded(pool.len() as u64) as usize]
    };
    match kind {
        PhaseKind::ShapeMigration { to } if !to.is_empty() && rng.next_f64() < frac => {
            draw(to, rng)
        }
        _ => draw(pool, rng),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpusim::{GTX1080, TITANX};

    fn shapes(ms: &[u64]) -> Vec<GemmShape> {
        ms.iter().map(|&m| GemmShape::new(m, m, m)).collect()
    }

    fn steady(rps: f64, secs: f64) -> Phase {
        Phase {
            kind: PhaseKind::Steady,
            gpu: &GTX1080,
            shapes: shapes(&[32, 64]),
            rps,
            duration: Duration::from_secs_f64(secs),
        }
    }

    #[test]
    fn same_seed_same_trace_different_seed_different_trace() {
        let phases = [steady(100.0, 2.0)];
        let a = Trace::generate(&phases, 7);
        let b = Trace::generate(&phases, 7);
        let c = Trace::generate(&phases, 8);
        assert!(!a.is_empty());
        assert_eq!(a.len(), b.len());
        for (x, y) in a.events.iter().zip(&b.events) {
            assert_eq!(x.at, y.at);
            assert_eq!(x.shape, y.shape);
        }
        let same = a.len() == c.len()
            && a.events.iter().zip(&c.events).all(|(x, y)| x.at == y.at);
        assert!(!same, "different seeds should diverge");
    }

    #[test]
    fn steady_phase_emits_roughly_rate_times_duration() {
        let t = Trace::generate(&[steady(200.0, 4.0)], 1);
        let n = t.len() as f64;
        assert!((600.0..=1000.0).contains(&n), "expected ~800 events, got {n}");
        assert!(t.span() < Duration::from_secs(4));
    }

    #[test]
    fn flash_crowd_outnumbers_steady_at_equal_base_rate() {
        let mut crowd = steady(100.0, 4.0);
        crowd.kind = PhaseKind::FlashCrowd { peak_x: 5.0 };
        let s = Trace::generate(&[steady(100.0, 4.0)], 3).len();
        let f = Trace::generate(&[crowd], 3).len();
        assert!(
            f as f64 > 1.5 * s as f64,
            "flash crowd should inflate volume: steady={s} flash={f}"
        );
    }

    #[test]
    fn shape_migration_crossfades_the_pool() {
        let to = shapes(&[128]);
        let phase = Phase {
            kind: PhaseKind::ShapeMigration { to: to.clone() },
            gpu: &GTX1080,
            shapes: shapes(&[32]),
            rps: 500.0,
            duration: Duration::from_secs(2),
        };
        let t = Trace::generate(&[phase], 5);
        let half = t.span() / 2;
        let late_migrated = t
            .events
            .iter()
            .filter(|e| e.at > half && e.shape == to[0])
            .count();
        let early_migrated = t
            .events
            .iter()
            .filter(|e| e.at <= half && e.shape == to[0])
            .count();
        assert!(
            late_migrated > 2 * early_migrated,
            "migration should skew late: early={early_migrated} late={late_migrated}"
        );
        assert_eq!(t.distinct_shapes().len(), 2);
    }

    #[test]
    fn device_swap_flips_the_gpu_at_the_cut() {
        let phase = Phase {
            kind: PhaseKind::DeviceSwap {
                to: &TITANX,
                at_frac: 0.5,
            },
            gpu: &GTX1080,
            shapes: shapes(&[32]),
            rps: 300.0,
            duration: Duration::from_secs(2),
        };
        let t = Trace::generate(&[phase], 9);
        let cut = Duration::from_secs(1);
        assert!(t.events.iter().filter(|e| e.at < cut).all(|e| e.gpu.id == GTX1080.id));
        assert!(t.events.iter().filter(|e| e.at >= cut).all(|e| e.gpu.id == TITANX.id));
    }

    #[test]
    fn repeat_heavy_repeats_hot_identities_zipf_style() {
        let phase = Phase {
            kind: PhaseKind::RepeatHeavy { distinct: 16, exponent: 1.2 },
            gpu: &GTX1080,
            shapes: shapes(&[32, 64]),
            rps: 500.0,
            duration: Duration::from_secs(2),
        };
        let t = Trace::generate(&[phase], 13);
        assert!(t.len() > 200, "expected ~1000 events, got {}", t.len());
        let mut counts = std::collections::HashMap::new();
        for e in &t.events {
            *counts.entry((e.shape, e.payload)).or_insert(0usize) += 1;
        }
        assert!(counts.len() <= 16, "at most `distinct` identities, got {}", counts.len());
        assert!(counts.len() >= 4, "the working set should spread, got {}", counts.len());
        let max = *counts.values().max().unwrap();
        assert!(
            max * 5 > t.len(),
            "the Zipf head should dominate: max={max} of {}",
            t.len()
        );
        // Determinism: same seed, same identity sequence.
        let t2 = Trace::generate(
            &[Phase {
                kind: PhaseKind::RepeatHeavy { distinct: 16, exponent: 1.2 },
                gpu: &GTX1080,
                shapes: shapes(&[32, 64]),
                rps: 500.0,
                duration: Duration::from_secs(2),
            }],
            13,
        );
        assert!(t
            .events
            .iter()
            .zip(&t2.events)
            .all(|(x, y)| x.payload == y.payload && x.shape == y.shape));
    }

    #[test]
    fn non_repeat_phases_emit_unique_payloads() {
        let t = Trace::generate(&[steady(200.0, 2.0), steady(150.0, 1.0)], 21);
        let unique: std::collections::HashSet<u64> =
            t.events.iter().map(|e| e.payload).collect();
        assert_eq!(unique.len(), t.len(), "non-repeat payloads must never collide");
    }

    #[test]
    fn zipf_table_is_head_heavy_and_monotone() {
        let z = ZipfTable::new(8, 1.0);
        assert_eq!(z.sample(0.0), 0);
        assert_eq!(z.sample(0.999_999), 7);
        let mut last = 0usize;
        for i in 0..100 {
            let r = z.sample(i as f64 / 100.0);
            assert!(r >= last, "CDF inversion must be monotone");
            last = r;
        }
        // Rank 0 carries 1/H(8) ≈ 37% of the mass under s=1.
        let head = (0..100).filter(|&i| z.sample(i as f64 / 100.0) == 0).count();
        assert!((25..=50).contains(&head), "head mass off: {head}%");
    }

    #[test]
    fn phases_chain_in_time_order() {
        let t = Trace::generate(&[steady(100.0, 1.0), steady(100.0, 1.0)], 2);
        for w in t.events.windows(2) {
            assert!(w[0].at <= w[1].at, "events must be time-ordered");
        }
        let boundary = Duration::from_secs(1);
        assert!(t.events.iter().filter(|e| e.phase == 0).all(|e| e.at < boundary));
        assert!(t.events.iter().filter(|e| e.phase == 1).all(|e| e.at >= boundary));
    }
}
