//! Trace replay: drive a generated [`Trace`] through a live [`Router`]
//! from concurrent client threads, with an optional chaos controller
//! killing and restarting an engine worker mid-trace.
//!
//! Every replayed request resolves into exactly one of four outcomes —
//! completed, failed (execution error, including contained backend
//! panics and breaker fail-fasts), shed (admission-control rejection,
//! detected via [`EngineBusy`]), or timed out (deadline expiry,
//! detected via [`DeadlineExceeded`]) — so the returned [`ReplayReport`]
//! is a client-side conservation ledger: `completed + failed + shed +
//! timed_out == submitted` holds by construction here, and
//! cross-checking it against `CoordinatorMetrics::verify_conservation`
//! proves the *server* side dropped nothing either. A replay call
//! returning at all is the zero-hung-clients check.

use super::generator::Trace;
use crate::coordinator::{DeadlineExceeded, Engine, EngineBusy, Fleet, GemmRequest, Router};
use crate::gemm::cpu::Matrix;
use crate::gpusim::GpuSpec;
use crate::util::rng::mix_parts;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::time::{Duration, Instant};

/// How replay maps trace time onto wall time.
#[derive(Debug, Clone, Copy)]
pub enum ReplayClock {
    /// Honor inter-arrival gaps, compressed by `speedup` (2.0 = replay
    /// twice as fast as the trace's own clock).
    Paced { speedup: f64 },
    /// As fast as possible: ignore timestamps, saturate the engine —
    /// the mode that exercises admission control.
    Afap,
}

/// Replay parameters.
#[derive(Debug, Clone, Copy)]
pub struct ReplayOptions {
    pub clock: ReplayClock,
    /// Client threads; events are dealt round-robin across them.
    pub clients: usize,
    /// Seed for the request matrices' contents.
    pub seed: u64,
}

impl Default for ReplayOptions {
    fn default() -> Self {
        ReplayOptions {
            clock: ReplayClock::Afap,
            clients: 4,
            seed: 0x5EED,
        }
    }
}

/// Client-side outcome ledger of one replay.
#[derive(Debug, Clone, Copy, Default)]
pub struct ReplayReport {
    pub submitted: u64,
    pub completed: u64,
    pub failed: u64,
    pub shed: u64,
    pub timed_out: u64,
    pub wall: Duration,
}

impl ReplayReport {
    /// The conservation invariant, checked on the client-side ledger.
    pub fn verify_conservation(&self) -> Result<(), String> {
        let resolved = self.completed + self.failed + self.shed + self.timed_out;
        if resolved == self.submitted {
            Ok(())
        } else {
            Err(format!(
                "replay conservation violated: completed={} + failed={} + shed={} + timed_out={} = {resolved} != submitted={}",
                self.completed, self.failed, self.shed, self.timed_out, self.submitted
            ))
        }
    }
}

/// Kill/restart schedule for [`replay_with_chaos`]. Each edge fires on
/// whichever of its two thresholds is crossed first:
///
/// * a *submitted-request count* (`kill_after` / `restart_after`) —
///   deterministic under [`ReplayClock::Afap`] up to scheduling;
/// * an *elapsed trace time* (`kill_at` / `restart_at`) — the trace's
///   own clock, so a schedule written against a trace's phase
///   boundaries holds at any [`ReplayClock::Paced`] speedup. Under
///   [`ReplayClock::Afap`] trace time degenerates to wall time.
///
/// Build with [`WorkerChaos::at_counts`] or [`WorkerChaos::at_times`];
/// the unused dimension is set to a never-fires sentinel
/// (`u64::MAX` / `None`).
#[derive(Debug, Clone, Copy)]
pub struct WorkerChaos {
    /// Which engine worker dies.
    pub worker: usize,
    /// Kill once this many requests have been submitted.
    pub kill_after: u64,
    /// Restart once this many have been submitted (≥ `kill_after`). If
    /// the trace ends first, the controller restarts the worker before
    /// returning so the pool is whole at shutdown.
    pub restart_after: u64,
    /// Kill once this much trace time has elapsed.
    pub kill_at: Option<Duration>,
    /// Restart once this much trace time has elapsed (≥ `kill_at`).
    pub restart_at: Option<Duration>,
}

impl WorkerChaos {
    /// Count-triggered schedule: kill after `kill_after` submissions,
    /// restart after `restart_after`. Time triggers disabled.
    pub fn at_counts(worker: usize, kill_after: u64, restart_after: u64) -> Self {
        WorkerChaos {
            worker,
            kill_after,
            restart_after,
            kill_at: None,
            restart_at: None,
        }
    }

    /// Time-triggered schedule against the trace's own clock: kill at
    /// `kill_at`, restart at `restart_at`. Count triggers disabled.
    pub fn at_times(worker: usize, kill_at: Duration, restart_at: Duration) -> Self {
        WorkerChaos {
            worker,
            kill_after: u64::MAX,
            restart_after: u64::MAX,
            kill_at: Some(kill_at),
            restart_at: Some(restart_at),
        }
    }

    /// Should the kill edge fire, given the submission count and
    /// elapsed trace time? Pure — the controller loop and tests share
    /// this exact predicate.
    pub fn kill_due(&self, submitted: u64, trace_elapsed: Duration) -> bool {
        submitted >= self.kill_after || self.kill_at.is_some_and(|t| trace_elapsed >= t)
    }

    /// Should the restart edge fire? Same contract as [`Self::kill_due`].
    pub fn restart_due(&self, submitted: u64, trace_elapsed: Duration) -> bool {
        submitted >= self.restart_after || self.restart_at.is_some_and(|t| trace_elapsed >= t)
    }
}

/// Wall elapsed mapped back onto the trace's clock: paced replay at
/// `speedup` compresses trace time by that factor, so trace time is
/// wall time *times* the speedup. Afap has no pacing — trace time
/// degenerates to wall time.
fn trace_elapsed(clock: ReplayClock, wall: Duration) -> Duration {
    match clock {
        ReplayClock::Paced { speedup } => wall.mul_f64(speedup.max(1e-9)),
        ReplayClock::Afap => wall,
    }
}

#[derive(Default)]
struct Counters {
    submitted: AtomicU64,
    completed: AtomicU64,
    failed: AtomicU64,
    shed: AtomicU64,
    timed_out: AtomicU64,
}

impl Counters {
    fn report(&self, wall: Duration) -> ReplayReport {
        ReplayReport {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            timed_out: self.timed_out.load(Ordering::Relaxed),
            wall,
        }
    }
}

/// One client's share of the trace: events `client, client+stride, …`.
fn client_run(
    router: &Router,
    trace: &Trace,
    opts: &ReplayOptions,
    counters: &Counters,
    start: Instant,
    client: usize,
) {
    let stride = opts.clients.max(1);
    let mut i = client;
    while i < trace.events.len() {
        let ev = &trace.events[i];
        if let ReplayClock::Paced { speedup } = opts.clock {
            let due = start + ev.at.div_f64(speedup.max(1e-9));
            let now = Instant::now();
            if due > now {
                std::thread::sleep(due - now);
            }
        }
        // Seed from the event's content identity, not its trace index:
        // equal (shape, payload) events — the repeats a RepeatHeavy phase
        // emits — produce bit-identical matrices, which is what lets the
        // engine's reuse layer cache and coalesce them.
        let s = mix_parts(&[opts.seed, ev.payload]);
        let a = Matrix::random(ev.shape.m as usize, ev.shape.k as usize, s);
        let b = Matrix::random(ev.shape.n as usize, ev.shape.k as usize, s ^ 1);
        counters.submitted.fetch_add(1, Ordering::Relaxed);
        match router.serve(GemmRequest {
            gpu: ev.gpu,
            shape: ev.shape,
            a,
            b,
        }) {
            Ok(_) => counters.completed.fetch_add(1, Ordering::Relaxed),
            Err(e) if EngineBusy::is(&e) => counters.shed.fetch_add(1, Ordering::Relaxed),
            Err(e) if DeadlineExceeded::is(&e) => counters.timed_out.fetch_add(1, Ordering::Relaxed),
            Err(_) => counters.failed.fetch_add(1, Ordering::Relaxed),
        };
        i += stride;
    }
}

/// Replay `trace` through `router`. Returns when every client thread
/// has resolved every one of its events — a return IS the proof that no
/// client hung.
pub fn replay(router: &Router, trace: &Trace, opts: &ReplayOptions) -> ReplayReport {
    let counters = Counters::default();
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for c in 0..opts.clients.max(1) {
            let counters = &counters;
            s.spawn(move || client_run(router, trace, opts, counters, t0, c));
        }
    });
    counters.report(t0.elapsed())
}

/// Replay with a chaos controller: once the kill edge of `chaos` fires
/// (submission count or elapsed trace time, whichever first — see
/// [`WorkerChaos`]) the controller kills `chaos.worker` (its queue
/// stays open; siblings steal the backlog), and once the restart edge
/// fires it restarts the worker on the same queue. The engine must
/// come from [`Engine::restartable`].
///
/// Use ≥ 2 workers (or a `restart_after` the trace will reach): in a
/// 1-worker pool nobody can steal a dead worker's backlog, so requests
/// queued while it is down wait for the restart.
pub fn replay_with_chaos(
    router: &Router,
    engine: &mut Engine,
    trace: &Trace,
    opts: &ReplayOptions,
    chaos: &WorkerChaos,
) -> anyhow::Result<ReplayReport> {
    let counters = Counters::default();
    let done = AtomicBool::new(false);
    let t0 = Instant::now();
    let ctl_result = std::thread::scope(|s| {
        let (counters_ref, done_ref) = (&counters, &done);
        let ctl = s.spawn(move || -> anyhow::Result<()> {
            let mut killed = false;
            let mut restarted = false;
            loop {
                let n = counters_ref.submitted.load(Ordering::Relaxed);
                let te = trace_elapsed(opts.clock, t0.elapsed());
                if !killed && chaos.kill_due(n, te) {
                    engine.kill_worker(chaos.worker)?;
                    killed = true;
                }
                if killed && !restarted && chaos.restart_due(n, te) {
                    engine.restart_worker(chaos.worker)?;
                    restarted = true;
                }
                if done_ref.load(Ordering::Relaxed) {
                    if killed && !restarted {
                        engine.restart_worker(chaos.worker)?;
                    }
                    return Ok(());
                }
                std::thread::sleep(Duration::from_micros(200));
            }
        });
        let mut clients = Vec::with_capacity(opts.clients.max(1));
        for c in 0..opts.clients.max(1) {
            let counters = &counters;
            clients.push(s.spawn(move || client_run(router, trace, opts, counters, t0, c)));
        }
        for c in clients {
            let _ = c.join();
        }
        done.store(true, Ordering::Relaxed);
        ctl.join().expect("chaos controller panicked")
    });
    ctl_result?;
    Ok(counters.report(t0.elapsed()))
}

/// Mid-replay device-spec swap schedule for [`replay_fleet`]: once
/// `after` requests have been submitted, [`Fleet::swap_spec`] flips
/// `device` to `to` — the real engine-worker rebuild behind the trace
/// generator's `DeviceSwap` phase.
#[derive(Debug, Clone, Copy)]
pub struct FleetSwap {
    /// Which fleet device swaps.
    pub device: usize,
    /// The spec it swaps to.
    pub to: &'static GpuSpec,
    /// Swap once this many requests have been submitted.
    pub after: u64,
}

impl FleetSwap {
    /// Derive a schedule from a trace containing a `DeviceSwap` phase:
    /// the swap fires at the first event whose gpu differs from the
    /// trace's opening gpu, and targets that gpu. `None` when the trace
    /// never changes gpu.
    pub fn from_trace(trace: &Trace, device: usize) -> Option<FleetSwap> {
        let first = trace.events.first()?.gpu;
        trace.events.iter().enumerate().find_map(|(i, ev)| {
            (ev.gpu.id != first.id).then_some(FleetSwap {
                device,
                to: ev.gpu,
                after: i as u64,
            })
        })
    }
}

/// One client's share of the trace, served through the fleet scheduler
/// (the fleet picks the device, so the event's own `gpu` is ignored —
/// placement is the thing under test).
fn fleet_client_run(
    fleet: &Fleet,
    trace: &Trace,
    opts: &ReplayOptions,
    counters: &Counters,
    start: Instant,
    client: usize,
) {
    let stride = opts.clients.max(1);
    let mut i = client;
    while i < trace.events.len() {
        let ev = &trace.events[i];
        if let ReplayClock::Paced { speedup } = opts.clock {
            let due = start + ev.at.div_f64(speedup.max(1e-9));
            let now = Instant::now();
            if due > now {
                std::thread::sleep(due - now);
            }
        }
        let s = mix_parts(&[opts.seed, ev.payload]);
        let a = Matrix::random(ev.shape.m as usize, ev.shape.k as usize, s);
        let b = Matrix::random(ev.shape.n as usize, ev.shape.k as usize, s ^ 1);
        counters.submitted.fetch_add(1, Ordering::Relaxed);
        match fleet.serve(ev.shape, a, b) {
            Ok(_) => counters.completed.fetch_add(1, Ordering::Relaxed),
            Err(e) if EngineBusy::is(&e) => counters.shed.fetch_add(1, Ordering::Relaxed),
            Err(e) if DeadlineExceeded::is(&e) => counters.timed_out.fetch_add(1, Ordering::Relaxed),
            Err(_) => counters.failed.fetch_add(1, Ordering::Relaxed),
        };
        i += stride;
    }
}

/// Replay `trace` through a [`Fleet`], optionally swapping one device's
/// spec mid-run per `swap`. Chaos injection rides the fleet's backend
/// wrap (set at construction), so unlike [`replay_with_chaos`] no
/// `&mut Engine` is needed — [`Fleet::swap_spec`] restarts workers
/// behind its own locks. The returned [`ReplayReport`] is the
/// client-side ledger; cross-check the server side per device AND
/// fleet-wide with [`Fleet::conservation`].
pub fn replay_fleet(
    fleet: &Fleet,
    trace: &Trace,
    opts: &ReplayOptions,
    swap: Option<&FleetSwap>,
) -> anyhow::Result<ReplayReport> {
    let counters = Counters::default();
    let done = AtomicBool::new(false);
    let t0 = Instant::now();
    let ctl_result = std::thread::scope(|s| {
        let (counters_ref, done_ref) = (&counters, &done);
        let ctl = swap.map(|swap| {
            s.spawn(move || -> anyhow::Result<()> {
                let mut swapped = false;
                loop {
                    let n = counters_ref.submitted.load(Ordering::Relaxed);
                    if !swapped && n >= swap.after {
                        fleet.swap_spec(swap.device, swap.to)?;
                        swapped = true;
                    }
                    if done_ref.load(Ordering::Relaxed) {
                        // The trace ended before the edge: still swap, so
                        // a schedule is never silently skipped.
                        if !swapped {
                            fleet.swap_spec(swap.device, swap.to)?;
                        }
                        return Ok(());
                    }
                    std::thread::sleep(Duration::from_micros(200));
                }
            })
        });
        let mut clients = Vec::with_capacity(opts.clients.max(1));
        for c in 0..opts.clients.max(1) {
            let counters = &counters;
            clients.push(s.spawn(move || fleet_client_run(fleet, trace, opts, counters, t0, c)));
        }
        for c in clients {
            let _ = c.join();
        }
        done.store(true, Ordering::Relaxed);
        match ctl {
            Some(h) => h.join().expect("fleet swap controller panicked"),
            None => Ok(()),
        }
    });
    ctl_result?;
    Ok(counters.report(t0.elapsed()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_conservation_check_catches_a_lost_request() {
        let ok = ReplayReport {
            submitted: 10,
            completed: 6,
            failed: 2,
            shed: 1,
            timed_out: 1,
            wall: Duration::ZERO,
        };
        ok.verify_conservation().unwrap();
        let bad = ReplayReport {
            submitted: 10,
            completed: 7,
            failed: 2,
            shed: 0,
            timed_out: 0,
            wall: Duration::ZERO,
        };
        let msg = bad.verify_conservation().unwrap_err();
        assert!(msg.contains("submitted=10"), "{msg}");
        assert!(msg.contains("timed_out=0"), "{msg}");
    }

    #[test]
    fn count_schedule_ignores_elapsed_time() {
        let c = WorkerChaos::at_counts(0, 100, 220);
        assert!(!c.kill_due(99, Duration::from_secs(3600)));
        assert!(c.kill_due(100, Duration::ZERO));
        assert!(!c.restart_due(219, Duration::from_secs(3600)));
        assert!(c.restart_due(220, Duration::ZERO));
    }

    #[test]
    fn time_schedule_ignores_submission_count() {
        let c = WorkerChaos::at_times(0, Duration::from_millis(50), Duration::from_millis(120));
        assert!(!c.kill_due(u64::MAX - 1, Duration::from_millis(49)));
        assert!(c.kill_due(0, Duration::from_millis(50)));
        assert!(!c.restart_due(u64::MAX - 1, Duration::from_millis(119)));
        assert!(c.restart_due(0, Duration::from_millis(120)));
    }

    #[test]
    fn mixed_schedule_fires_on_whichever_threshold_crosses_first() {
        let c = WorkerChaos {
            worker: 0,
            kill_after: 100,
            restart_after: 220,
            kill_at: Some(Duration::from_millis(50)),
            restart_at: Some(Duration::from_millis(120)),
        };
        // Count crosses first.
        assert!(c.kill_due(100, Duration::from_millis(1)));
        // Time crosses first.
        assert!(c.kill_due(1, Duration::from_millis(50)));
        // Neither crossed.
        assert!(!c.kill_due(99, Duration::from_millis(49)));
    }

    #[test]
    fn fleet_swap_schedule_derives_from_a_device_swap_trace() {
        use crate::gemm::GemmShape;
        use crate::gpusim::{GTX1080, SIMECO};
        use crate::workload::generator::{Phase, PhaseKind};
        let trace = Trace::generate(
            &[Phase {
                kind: PhaseKind::DeviceSwap {
                    to: &SIMECO,
                    at_frac: 0.5,
                },
                gpu: &GTX1080,
                shapes: vec![GemmShape::new(16, 16, 16)],
                rps: 100.0,
                duration: Duration::from_secs(1),
            }],
            42,
        );
        let swap = FleetSwap::from_trace(&trace, 0).expect("trace swaps gpus");
        assert_eq!(swap.device, 0);
        assert_eq!(swap.to.id, SIMECO.id);
        assert!(swap.after > 0, "swap fires mid-trace");
        assert_eq!(trace.events[swap.after as usize].gpu.id, SIMECO.id);
        assert_eq!(trace.events[swap.after as usize - 1].gpu.id, GTX1080.id);
        // A trace that never swaps yields no schedule.
        let steady = Trace::generate(
            &[Phase {
                kind: PhaseKind::Steady,
                gpu: &GTX1080,
                shapes: vec![GemmShape::new(16, 16, 16)],
                rps: 100.0,
                duration: Duration::from_secs(1),
            }],
            42,
        );
        assert!(FleetSwap::from_trace(&steady, 0).is_none());
    }

    #[test]
    fn trace_elapsed_scales_wall_time_by_paced_speedup() {
        let wall = Duration::from_millis(100);
        let paced = trace_elapsed(ReplayClock::Paced { speedup: 4.0 }, wall);
        assert_eq!(paced, Duration::from_millis(400));
        let afap = trace_elapsed(ReplayClock::Afap, wall);
        assert_eq!(afap, wall);
    }
}
