//! [`ChaosBackend`]: a fault-injecting [`ExecBackend`] wrapper.
//!
//! Every execution rolls a *deterministic* per-call fate from
//! `(seed, worker, call-counter)`, so a chaos run is reproducible: the
//! same seed injects the same faults at the same calls. Three fault
//! kinds, checked in order against one uniform draw:
//!
//! * **transient failure** — the job errors without touching the inner
//!   backend (counted as `failed` upstream);
//! * **panic** — the backend panics mid-execute; the engine's worker
//!   loop contains it and fails that one job, which is exactly the
//!   behaviour this wrapper exists to exercise;
//! * **latency spike** — the call sleeps before delegating, inflating
//!   the measured latency the online loop trains on.
//!
//! [`ChaosStats`] counts what actually fired so tests can assert the
//! faults happened instead of silently passing on a too-low probability.
//!
//! Injected transient failures carry the typed
//! [`TransientFault`](crate::coordinator::backend::TransientFault)
//! marker, so the router's retry classifier sees them as retryable
//! without string matching. A dedicated *sick-artifact* knob makes one
//! artifact prefix fail deterministically for its first N calls — the
//! persistently-failing backend the circuit-breaker proofs need.

use crate::coordinator::backend::TransientFault;
use crate::coordinator::ExecBackend;
use crate::gemm::cpu::Matrix;
use crate::util::rng::mix_parts;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Fault mix for a [`ChaosBackend`]. Probabilities are per-execution
/// and mutually exclusive (failure is checked first, then panic, then
/// spike); their sum should stay well below 1.
#[derive(Debug, Clone)]
pub struct ChaosConfig {
    pub seed: u64,
    pub fail_prob: f64,
    pub panic_prob: f64,
    pub spike_prob: f64,
    /// How long an injected latency spike sleeps — clamped to
    /// `spike_cap` when it actually fires, so a mis-sized spike can
    /// never hold a worker (and every job queued behind it) hostage for
    /// an unbounded stretch of the trace clock.
    pub spike: Duration,
    /// Hard ceiling on a single injected spike.
    pub spike_cap: Duration,
    /// Artifacts whose name starts with this prefix fail (transiently)
    /// on every call while the per-backend call counter is below
    /// `sick_calls` — a deterministic persistently-sick artifact for
    /// breaker tests. Empty = disabled.
    pub sick_prefix: String,
    /// How many leading calls the sick artifact stays sick for.
    pub sick_calls: u64,
}

impl Default for ChaosConfig {
    fn default() -> Self {
        ChaosConfig {
            seed: 0xC4A05,
            fail_prob: 0.05,
            panic_prob: 0.02,
            spike_prob: 0.05,
            spike: Duration::from_millis(2),
            spike_cap: Duration::from_millis(50),
            sick_prefix: String::new(),
            sick_calls: 0,
        }
    }
}

/// What a [`ChaosBackend`] actually injected. Share one across the pool
/// to count faults fleet-wide.
#[derive(Debug, Default)]
pub struct ChaosStats {
    pub injected_failures: AtomicU64,
    pub injected_panics: AtomicU64,
    pub injected_spikes: AtomicU64,
    /// Failures injected by the sick-artifact knob (also included in
    /// `injected_failures`).
    pub injected_sick_failures: AtomicU64,
    /// Total wall time actually slept by injected spikes, µs — the
    /// ground truth deadline tests assert injected delay against.
    pub injected_delay_us: AtomicU64,
}

impl ChaosStats {
    pub fn total(&self) -> u64 {
        self.injected_failures.load(Ordering::Relaxed)
            + self.injected_panics.load(Ordering::Relaxed)
            + self.injected_spikes.load(Ordering::Relaxed)
    }

    /// Total injected spike sleep, µs.
    pub fn delay_us(&self) -> u64 {
        self.injected_delay_us.load(Ordering::Relaxed)
    }
}

enum Fate {
    Fail,
    Panic,
    Spike,
    Clean,
}

/// Fault-injecting wrapper around any [`ExecBackend`].
pub struct ChaosBackend {
    inner: Box<dyn ExecBackend>,
    cfg: ChaosConfig,
    /// Worker index, so pool siblings sharing one seed roll distinct
    /// fault sequences.
    worker: u64,
    calls: AtomicU64,
    stats: Arc<ChaosStats>,
}

impl ChaosBackend {
    pub fn new(
        inner: Box<dyn ExecBackend>,
        cfg: ChaosConfig,
        worker: usize,
        stats: Arc<ChaosStats>,
    ) -> ChaosBackend {
        ChaosBackend {
            inner,
            cfg,
            worker: worker as u64,
            calls: AtomicU64::new(0),
            stats,
        }
    }

    /// Roll this call's fate; deterministic in `(seed, worker, call#)`.
    /// The sick-artifact knob outranks the random fates so a breaker
    /// test's sick traffic is sick on *every* call, not probabilistically.
    fn fate(&self, artifact: &str) -> Fate {
        let n = self.calls.fetch_add(1, Ordering::Relaxed);
        if !self.cfg.sick_prefix.is_empty()
            && n < self.cfg.sick_calls
            && artifact.starts_with(self.cfg.sick_prefix.as_str())
        {
            self.stats.injected_failures.fetch_add(1, Ordering::Relaxed);
            self.stats
                .injected_sick_failures
                .fetch_add(1, Ordering::Relaxed);
            return Fate::Fail;
        }
        let u = (mix_parts(&[self.cfg.seed, self.worker, n]) >> 11) as f64
            / (1u64 << 53) as f64;
        if u < self.cfg.fail_prob {
            self.stats.injected_failures.fetch_add(1, Ordering::Relaxed);
            Fate::Fail
        } else if u < self.cfg.fail_prob + self.cfg.panic_prob {
            self.stats.injected_panics.fetch_add(1, Ordering::Relaxed);
            Fate::Panic
        } else if u < self.cfg.fail_prob + self.cfg.panic_prob + self.cfg.spike_prob {
            self.stats.injected_spikes.fetch_add(1, Ordering::Relaxed);
            Fate::Spike
        } else {
            Fate::Clean
        }
    }

    fn apply(&self, artifact: &str) -> anyhow::Result<()> {
        match self.fate(artifact) {
            Fate::Fail => Err(anyhow::Error::new(TransientFault(format!(
                "chaos: injected transient failure on {artifact}"
            )))),
            Fate::Panic => panic!("chaos: injected panic on {artifact}"),
            Fate::Spike => {
                let nap = self.cfg.spike.min(self.cfg.spike_cap);
                std::thread::sleep(nap);
                self.stats
                    .injected_delay_us
                    .fetch_add(nap.as_micros() as u64, Ordering::Relaxed);
                Ok(())
            }
            Fate::Clean => Ok(()),
        }
    }
}

impl ExecBackend for ChaosBackend {
    fn execute(&self, artifact: &str, inputs: &[&Matrix]) -> anyhow::Result<Vec<Matrix>> {
        self.apply(artifact)?;
        self.inner.execute(artifact, inputs)
    }

    fn execute_timed(
        &self,
        artifact: &str,
        inputs: &[&Matrix],
    ) -> anyhow::Result<(Vec<Matrix>, f64)> {
        self.apply(artifact)?;
        self.inner.execute_timed(artifact, inputs)
    }

    fn warmup(&self, names: &[&str]) -> anyhow::Result<()> {
        // Warmup is infrastructure, not traffic — never inject there.
        self.inner.warmup(names)
    }

    fn name(&self) -> String {
        format!("chaos({})", self.inner.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Nop;
    impl ExecBackend for Nop {
        fn execute(&self, _a: &str, _i: &[&Matrix]) -> anyhow::Result<Vec<Matrix>> {
            Ok(vec![])
        }
        fn name(&self) -> String {
            "nop".into()
        }
    }

    fn chaos(cfg: ChaosConfig) -> (ChaosBackend, Arc<ChaosStats>) {
        let stats = Arc::new(ChaosStats::default());
        (
            ChaosBackend::new(Box::new(Nop), cfg, 0, Arc::clone(&stats)),
            stats,
        )
    }

    #[test]
    fn fault_sequence_is_deterministic_for_a_seed() {
        let cfg = ChaosConfig {
            fail_prob: 0.3,
            panic_prob: 0.0,
            spike_prob: 0.0,
            ..ChaosConfig::default()
        };
        let run = |cfg: ChaosConfig| {
            let (b, _) = chaos(cfg);
            (0..200)
                .map(|_| b.execute("nt_8x8x8", &[]).is_err())
                .collect::<Vec<_>>()
        };
        let a = run(cfg.clone());
        let b = run(cfg);
        assert_eq!(a, b);
        let fails = a.iter().filter(|&&e| e).count();
        assert!(
            (30..=90).contains(&fails),
            "~30% of 200 calls should fail, got {fails}"
        );
    }

    #[test]
    fn injected_failures_are_errors_and_counted() {
        let (b, stats) = chaos(ChaosConfig {
            fail_prob: 1.0,
            panic_prob: 0.0,
            spike_prob: 0.0,
            ..ChaosConfig::default()
        });
        let err = b.execute_timed("nt_8x8x8", &[]).unwrap_err().to_string();
        assert!(err.contains("chaos"), "{err}");
        assert_eq!(stats.injected_failures.load(Ordering::Relaxed), 1);
        assert_eq!(stats.total(), 1);
    }

    #[test]
    fn zero_probabilities_delegate_cleanly() {
        let (b, stats) = chaos(ChaosConfig {
            fail_prob: 0.0,
            panic_prob: 0.0,
            spike_prob: 0.0,
            ..ChaosConfig::default()
        });
        for _ in 0..50 {
            b.execute("nt_8x8x8", &[]).unwrap();
        }
        assert_eq!(stats.total(), 0);
        assert_eq!(b.name(), "chaos(nop)");
    }

    #[test]
    #[should_panic(expected = "chaos: injected panic")]
    fn injected_panics_panic() {
        let (b, _) = chaos(ChaosConfig {
            fail_prob: 0.0,
            panic_prob: 1.0,
            spike_prob: 0.0,
            ..ChaosConfig::default()
        });
        let _ = b.execute("nt_8x8x8", &[]);
    }

    #[test]
    fn injected_failures_carry_the_transient_marker() {
        let (b, _) = chaos(ChaosConfig {
            fail_prob: 1.0,
            panic_prob: 0.0,
            spike_prob: 0.0,
            ..ChaosConfig::default()
        });
        let err = b.execute("nt_8x8x8", &[]).unwrap_err();
        assert!(TransientFault::is(&err), "typed for the retry classifier");
        assert!(err.to_string().contains("injected transient failure"));
    }

    #[test]
    fn spike_is_capped_and_delay_totals_are_surfaced() {
        let (b, stats) = chaos(ChaosConfig {
            fail_prob: 0.0,
            panic_prob: 0.0,
            spike_prob: 1.0,
            spike: Duration::from_secs(3600), // mis-sized: would hang a worker
            spike_cap: Duration::from_millis(2),
            ..ChaosConfig::default()
        });
        let t0 = std::time::Instant::now();
        for _ in 0..3 {
            b.execute("nt_8x8x8", &[]).unwrap();
        }
        assert!(
            t0.elapsed() < Duration::from_secs(1),
            "spike must be clamped to the cap"
        );
        assert_eq!(stats.injected_spikes.load(Ordering::Relaxed), 3);
        assert_eq!(stats.delay_us(), 3 * 2_000, "actual slept time surfaced");
    }

    #[test]
    fn sick_artifact_fails_deterministically_then_recovers() {
        let (b, stats) = chaos(ChaosConfig {
            fail_prob: 0.0,
            panic_prob: 0.0,
            spike_prob: 0.0,
            sick_prefix: "tnn_".into(),
            sick_calls: 5,
            ..ChaosConfig::default()
        });
        // Sick prefix fails on every call inside the sick window…
        assert!(b.execute("tnn_8x8x8", &[]).is_err());
        assert!(b.execute("tnn_8x8x8", &[]).is_err());
        // …while other artifacts are untouched…
        b.execute("nt_8x8x8", &[]).unwrap();
        assert!(b.execute("tnn_8x8x8", &[]).is_err());
        b.execute("nt_8x8x8", &[]).unwrap();
        // …and after `sick_calls` total calls the artifact heals.
        b.execute("tnn_8x8x8", &[]).unwrap();
        b.execute("tnn_8x8x8", &[]).unwrap();
        assert_eq!(stats.injected_sick_failures.load(Ordering::Relaxed), 3);
        assert_eq!(stats.injected_failures.load(Ordering::Relaxed), 3);
    }
}
