//! [`ChaosBackend`]: a fault-injecting [`ExecBackend`] wrapper.
//!
//! Every execution rolls a *deterministic* per-call fate from
//! `(seed, worker, call-counter)`, so a chaos run is reproducible: the
//! same seed injects the same faults at the same calls. Three fault
//! kinds, checked in order against one uniform draw:
//!
//! * **transient failure** — the job errors without touching the inner
//!   backend (counted as `failed` upstream);
//! * **panic** — the backend panics mid-execute; the engine's worker
//!   loop contains it and fails that one job, which is exactly the
//!   behaviour this wrapper exists to exercise;
//! * **latency spike** — the call sleeps before delegating, inflating
//!   the measured latency the online loop trains on.
//!
//! [`ChaosStats`] counts what actually fired so tests can assert the
//! faults happened instead of silently passing on a too-low probability.

use crate::coordinator::ExecBackend;
use crate::gemm::cpu::Matrix;
use crate::util::rng::mix_parts;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Fault mix for a [`ChaosBackend`]. Probabilities are per-execution
/// and mutually exclusive (failure is checked first, then panic, then
/// spike); their sum should stay well below 1.
#[derive(Debug, Clone, Copy)]
pub struct ChaosConfig {
    pub seed: u64,
    pub fail_prob: f64,
    pub panic_prob: f64,
    pub spike_prob: f64,
    /// How long an injected latency spike sleeps.
    pub spike: Duration,
}

impl Default for ChaosConfig {
    fn default() -> Self {
        ChaosConfig {
            seed: 0xC4A05,
            fail_prob: 0.05,
            panic_prob: 0.02,
            spike_prob: 0.05,
            spike: Duration::from_millis(2),
        }
    }
}

/// What a [`ChaosBackend`] actually injected. Share one across the pool
/// to count faults fleet-wide.
#[derive(Debug, Default)]
pub struct ChaosStats {
    pub injected_failures: AtomicU64,
    pub injected_panics: AtomicU64,
    pub injected_spikes: AtomicU64,
}

impl ChaosStats {
    pub fn total(&self) -> u64 {
        self.injected_failures.load(Ordering::Relaxed)
            + self.injected_panics.load(Ordering::Relaxed)
            + self.injected_spikes.load(Ordering::Relaxed)
    }
}

enum Fate {
    Fail,
    Panic,
    Spike,
    Clean,
}

/// Fault-injecting wrapper around any [`ExecBackend`].
pub struct ChaosBackend {
    inner: Box<dyn ExecBackend>,
    cfg: ChaosConfig,
    /// Worker index, so pool siblings sharing one seed roll distinct
    /// fault sequences.
    worker: u64,
    calls: AtomicU64,
    stats: Arc<ChaosStats>,
}

impl ChaosBackend {
    pub fn new(
        inner: Box<dyn ExecBackend>,
        cfg: ChaosConfig,
        worker: usize,
        stats: Arc<ChaosStats>,
    ) -> ChaosBackend {
        ChaosBackend {
            inner,
            cfg,
            worker: worker as u64,
            calls: AtomicU64::new(0),
            stats,
        }
    }

    /// Roll this call's fate; deterministic in `(seed, worker, call#)`.
    fn fate(&self) -> Fate {
        let n = self.calls.fetch_add(1, Ordering::Relaxed);
        let u = (mix_parts(&[self.cfg.seed, self.worker, n]) >> 11) as f64
            / (1u64 << 53) as f64;
        if u < self.cfg.fail_prob {
            self.stats.injected_failures.fetch_add(1, Ordering::Relaxed);
            Fate::Fail
        } else if u < self.cfg.fail_prob + self.cfg.panic_prob {
            self.stats.injected_panics.fetch_add(1, Ordering::Relaxed);
            Fate::Panic
        } else if u < self.cfg.fail_prob + self.cfg.panic_prob + self.cfg.spike_prob {
            self.stats.injected_spikes.fetch_add(1, Ordering::Relaxed);
            Fate::Spike
        } else {
            Fate::Clean
        }
    }

    fn apply(&self, artifact: &str) -> anyhow::Result<()> {
        match self.fate() {
            Fate::Fail => anyhow::bail!("chaos: injected transient failure on {artifact}"),
            Fate::Panic => panic!("chaos: injected panic on {artifact}"),
            Fate::Spike => {
                std::thread::sleep(self.cfg.spike);
                Ok(())
            }
            Fate::Clean => Ok(()),
        }
    }
}

impl ExecBackend for ChaosBackend {
    fn execute(&self, artifact: &str, inputs: &[&Matrix]) -> anyhow::Result<Vec<Matrix>> {
        self.apply(artifact)?;
        self.inner.execute(artifact, inputs)
    }

    fn execute_timed(
        &self,
        artifact: &str,
        inputs: &[&Matrix],
    ) -> anyhow::Result<(Vec<Matrix>, f64)> {
        self.apply(artifact)?;
        self.inner.execute_timed(artifact, inputs)
    }

    fn warmup(&self, names: &[&str]) -> anyhow::Result<()> {
        // Warmup is infrastructure, not traffic — never inject there.
        self.inner.warmup(names)
    }

    fn name(&self) -> String {
        format!("chaos({})", self.inner.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Nop;
    impl ExecBackend for Nop {
        fn execute(&self, _a: &str, _i: &[&Matrix]) -> anyhow::Result<Vec<Matrix>> {
            Ok(vec![])
        }
        fn name(&self) -> String {
            "nop".into()
        }
    }

    fn chaos(cfg: ChaosConfig) -> (ChaosBackend, Arc<ChaosStats>) {
        let stats = Arc::new(ChaosStats::default());
        (
            ChaosBackend::new(Box::new(Nop), cfg, 0, Arc::clone(&stats)),
            stats,
        )
    }

    #[test]
    fn fault_sequence_is_deterministic_for_a_seed() {
        let cfg = ChaosConfig {
            fail_prob: 0.3,
            panic_prob: 0.0,
            spike_prob: 0.0,
            ..ChaosConfig::default()
        };
        let run = |cfg| {
            let (b, _) = chaos(cfg);
            (0..200)
                .map(|_| b.execute("nt_8x8x8", &[]).is_err())
                .collect::<Vec<_>>()
        };
        let a = run(cfg);
        let b = run(cfg);
        assert_eq!(a, b);
        let fails = a.iter().filter(|&&e| e).count();
        assert!(
            (30..=90).contains(&fails),
            "~30% of 200 calls should fail, got {fails}"
        );
    }

    #[test]
    fn injected_failures_are_errors_and_counted() {
        let (b, stats) = chaos(ChaosConfig {
            fail_prob: 1.0,
            panic_prob: 0.0,
            spike_prob: 0.0,
            ..ChaosConfig::default()
        });
        let err = b.execute_timed("nt_8x8x8", &[]).unwrap_err().to_string();
        assert!(err.contains("chaos"), "{err}");
        assert_eq!(stats.injected_failures.load(Ordering::Relaxed), 1);
        assert_eq!(stats.total(), 1);
    }

    #[test]
    fn zero_probabilities_delegate_cleanly() {
        let (b, stats) = chaos(ChaosConfig {
            fail_prob: 0.0,
            panic_prob: 0.0,
            spike_prob: 0.0,
            ..ChaosConfig::default()
        });
        for _ in 0..50 {
            b.execute("nt_8x8x8", &[]).unwrap();
        }
        assert_eq!(stats.total(), 0);
        assert_eq!(b.name(), "chaos(nop)");
    }

    #[test]
    #[should_panic(expected = "chaos: injected panic")]
    fn injected_panics_panic() {
        let (b, _) = chaos(ChaosConfig {
            fail_prob: 0.0,
            panic_prob: 1.0,
            spike_prob: 0.0,
            ..ChaosConfig::default()
        });
        let _ = b.execute("nt_8x8x8", &[]);
    }
}
